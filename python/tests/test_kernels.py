"""Layer-1 correctness: Pallas ELL kernels vs the pure-jnp oracle, with
hypothesis sweeping shapes and row-fill patterns (the oracle itself is
cross-checked against a dense matmul)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ell, ref


def make_ell(rng, nrows, ncols, k, fill):
    """Random padded-ELL planes: per-row lengths ≤ k, padding = (0, col 0)."""
    vals = np.zeros((nrows, k), dtype=np.float32)
    cols = np.zeros((nrows, k), dtype=np.int32)
    for i in range(nrows):
        length = rng.integers(0, k + 1) if fill == "ragged" else k
        if length > 0:
            # duplicates within a row are legal (they accumulate)
            cols[i, :length] = rng.choice(ncols, size=length, replace=True)
            vals[i, :length] = rng.uniform(0.1, 2.0, size=length).astype(np.float32)
    return jnp.asarray(vals), jnp.asarray(cols)


@st.composite
def ell_case(draw):
    tile = 8
    nrows = tile * draw(st.integers(1, 6))
    ncols = draw(st.integers(1, 96))
    k = draw(st.integers(1, 9))
    fill = draw(st.sampled_from(["ragged", "full"]))
    seed = draw(st.integers(0, 2**31 - 1))
    return nrows, ncols, k, fill, seed


@settings(max_examples=40, deadline=None)
@given(ell_case())
def test_spmv_matches_ref(case):
    nrows, ncols, k, fill, seed = case
    rng = np.random.default_rng(seed)
    vals, cols = make_ell(rng, nrows, ncols, k, fill)
    x = jnp.asarray(rng.uniform(-1, 1, size=ncols).astype(np.float32))
    got = ell.ell_spmv(vals, cols, x, tile=8)
    want = ref.ell_spmv_ref(vals, cols, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(ell_case(), st.integers(1, 12))
def test_spmm_matches_ref(case, kcols):
    nrows, ncols, k, fill, seed = case
    rng = np.random.default_rng(seed)
    vals, cols = make_ell(rng, nrows, ncols, k, fill)
    b = jnp.asarray(rng.uniform(-1, 1, size=(ncols, kcols)).astype(np.float32))
    got = ell.ell_spmm(vals, cols, b, tile=8)
    want = ref.ell_spmm_ref(vals, cols, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(ell_case())
def test_ref_matches_dense(case):
    """The oracle itself against a dense matmul."""
    nrows, ncols, k, fill, seed = case
    rng = np.random.default_rng(seed)
    vals, cols = make_ell(rng, nrows, ncols, k, fill)
    x = jnp.asarray(rng.uniform(-1, 1, size=ncols).astype(np.float32))
    dense = ref.dense_of_ell(vals, cols, ncols)
    np.testing.assert_allclose(
        ref.ell_spmv_ref(vals, cols, x), dense @ x, rtol=1e-4, atol=1e-4
    )


def test_spmv_empty_rows():
    vals = jnp.zeros((8, 3), dtype=jnp.float32)
    cols = jnp.zeros((8, 3), dtype=jnp.int32)
    x = jnp.ones((5,), dtype=jnp.float32)
    got = ell.ell_spmv(vals, cols, x, tile=8)
    np.testing.assert_array_equal(np.asarray(got), np.zeros(8, dtype=np.float32))


def test_spmv_rejects_unaligned_rows():
    vals = jnp.zeros((7, 2), dtype=jnp.float32)
    cols = jnp.zeros((7, 2), dtype=jnp.int32)
    x = jnp.ones((4,), dtype=jnp.float32)
    with pytest.raises(AssertionError):
        ell.ell_spmv(vals, cols, x, tile=8)


def test_spmm_kcols_one_equals_spmv():
    rng = np.random.default_rng(7)
    vals, cols = make_ell(rng, 16, 20, 4, "ragged")
    x = rng.uniform(-1, 1, size=20).astype(np.float32)
    y1 = ell.ell_spmv(vals, cols, jnp.asarray(x), tile=8)
    y2 = ell.ell_spmm(vals, cols, jnp.asarray(x[:, None]), tile=8)[:, 0]
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)


def test_duplicate_columns_accumulate():
    # Two slots of the same row referencing the same column must sum.
    vals = jnp.asarray([[1.0, 2.0]] * 8, dtype=jnp.float32)
    cols = jnp.asarray([[3, 3]] * 8, dtype=jnp.int32)
    x = jnp.asarray([0.0, 0.0, 0.0, 5.0], dtype=jnp.float32)
    got = ell.ell_spmv(vals, cols, x, tile=8)
    np.testing.assert_allclose(np.asarray(got), np.full(8, 15.0), rtol=1e-6)


def test_vmem_estimate_monotone():
    a = ell.vmem_estimate_bytes(128, 16, 4096)
    b = ell.vmem_estimate_bytes(256, 16, 4096)
    c = ell.vmem_estimate_bytes(128, 64, 4096)
    assert b > a and c > a
