"""Layer-2 / AOT pipeline tests: the jitted model functions execute
correctly at bucket shapes and lower to parseable HLO text."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def small_bucket_data(nrows=256, k=8, seed=0):
    rng = np.random.default_rng(seed)
    vals = rng.uniform(0.1, 1.0, size=(nrows, k)).astype(np.float32)
    cols = rng.integers(0, nrows, size=(nrows, k)).astype(np.int32)
    # make some rows ragged: zero out a suffix
    for i in range(0, nrows, 3):
        vals[i, k // 2 :] = 0.0
        cols[i, k // 2 :] = 0
    return jnp.asarray(vals), jnp.asarray(cols)


def test_spmv_model_executes():
    vals, cols = small_bucket_data()
    x = jnp.asarray(np.random.default_rng(1).uniform(-1, 1, 256).astype(np.float32))
    (y,) = jax.jit(model.spmv_ell)(vals, cols, x)
    want = ref.ell_spmv_ref(vals, cols, x)
    np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)


def test_spmm_model_executes():
    vals, cols = small_bucket_data()
    b = jnp.asarray(np.random.default_rng(2).uniform(-1, 1, (256, 10)).astype(np.float32))
    (c,) = jax.jit(model.spmm_ell)(vals, cols, b)
    want = ref.ell_spmm_ref(vals, cols, b)
    np.testing.assert_allclose(c, want, rtol=1e-4, atol=1e-4)


def test_fused_axpy_composes():
    vals, cols = small_bucket_data(seed=3)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.uniform(-1, 1, 256).astype(np.float32))
    y0 = jnp.asarray(rng.uniform(-1, 1, 256).astype(np.float32))
    (y,) = jax.jit(model.spmv_ell_fused_axpy)(vals, cols, x, jnp.float32(2.5), y0)
    want = 2.5 * ref.ell_spmv_ref(vals, cols, x) + y0
    np.testing.assert_allclose(y, want, rtol=1e-5, atol=1e-5)


def test_lowering_produces_hlo_text():
    txt = aot.lower_spmv(256, 8)
    assert "HloModule" in txt
    assert "f32[256,8]" in txt
    # interpret-mode pallas must lower to plain HLO, not a Mosaic custom-call
    assert "tpu_custom_call" not in txt and "mosaic" not in txt.lower()


def test_lowering_spmm_shapes():
    txt = aot.lower_spmm(256, 8, 10)
    assert "HloModule" in txt
    assert "f32[256,10]" in txt or "f32[256,10]{1,0}" in txt


def test_quick_build_writes_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.build(out, quick=True)
    assert len(manifest) == 2  # one spmv + one spmm bucket
    mpath = os.path.join(out, "manifest.txt")
    assert os.path.exists(mpath)
    lines = [l for l in open(mpath).read().splitlines() if not l.startswith("#")]
    assert len(lines) == 2
    for line in lines:
        fname = line.split()[0]
        text = open(os.path.join(out, fname)).read()
        assert "HloModule" in text
