"""AOT pipeline: lower the Layer-2 jax functions (which embed the Layer-1
Pallas kernels) to HLO *text* artifacts the Rust runtime loads via the
`xla` crate's PJRT CPU client.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser reassigns ids.
See /opt/xla-example/README.md.

Artifacts are shape-*bucketed*: the Rust coordinator pads a matrix's
generated ELL storage up to the nearest (nrows, K) bucket — padding is
exactly the paper's "padded ℕ* materialization", so bucketing is itself
a forelem transformation. One executable per (kernel, bucket).

Usage: python -m compile.aot [--out-dir ../artifacts] [--quick]
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (nrows == ncols) buckets × slot-width buckets. SpMM kcols is fixed at
# 100 (the paper's "sparse matrix times 100-column dense matrix").
NROW_BUCKETS = [2048, 8192, 32768]
K_BUCKETS = [8, 16, 32, 64]
SPMM_KCOLS = 100


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_spmv(nrows: int, k: int) -> str:
    specs = model.specs_spmv(nrows, k, nrows)
    return to_hlo_text(jax.jit(model.spmv_ell).lower(*specs))


def lower_spmm(nrows: int, k: int, kcols: int) -> str:
    specs = model.specs_spmm(nrows, k, nrows, kcols)
    return to_hlo_text(jax.jit(model.spmm_ell).lower(*specs))


def build(out_dir: str, quick: bool = False) -> list:
    os.makedirs(out_dir, exist_ok=True)
    rows = NROW_BUCKETS[:1] if quick else NROW_BUCKETS
    ks = K_BUCKETS[:1] if quick else K_BUCKETS
    manifest = []
    for n in rows:
        for k in ks:
            name = f"ell_spmv_n{n}_k{k}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            print(f"[aot] lowering {name} ...", flush=True)
            with open(path, "w") as f:
                f.write(lower_spmv(n, k))
            manifest.append((f"{name}.hlo.txt", "spmv", n, k, n, 1))

            name = f"ell_spmm_n{n}_k{k}_c{SPMM_KCOLS}"
            path = os.path.join(out_dir, f"{name}.hlo.txt")
            print(f"[aot] lowering {name} ...", flush=True)
            with open(path, "w") as f:
                f.write(lower_spmm(n, k, SPMM_KCOLS))
            manifest.append((f"{name}.hlo.txt", "spmm", n, k, n, SPMM_KCOLS))

    mpath = os.path.join(out_dir, "manifest.txt")
    with open(mpath, "w") as f:
        f.write("# file kernel nrows k ncols kcols\n")
        for row in manifest:
            f.write(" ".join(str(x) for x in row) + "\n")
    print(f"[aot] wrote {len(manifest)} artifacts + manifest to {out_dir}")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--out", default=None, help="(compat) ignored; use --out-dir")
    ap.add_argument("--quick", action="store_true", help="single small bucket (tests)")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or out_dir
    build(out_dir, quick=args.quick)
    return 0


if __name__ == "__main__":
    sys.exit(main())
