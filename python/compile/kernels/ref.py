"""Pure-jnp oracles for the Layer-1 kernels — the correctness reference
pytest checks every Pallas kernel against (and itself checked against a
plain-Python dense computation in the tests)."""

import jax.numpy as jnp


def ell_spmv_ref(vals, cols, x):
    """Reference padded-ELL SpMV: sum_p vals[i, p] * x[cols[i, p]]."""
    return jnp.sum(vals * jnp.take(x, cols, axis=0), axis=1)


def ell_spmm_ref(vals, cols, b):
    """Reference padded-ELL SpMM: C[i, :] = sum_p vals[i, p] * B[cols[i, p], :]."""
    # (nrows, K, kcols) gather — fine at oracle scale.
    gathered = jnp.take(b, cols, axis=0)
    return jnp.einsum("rk,rkc->rc", vals, gathered)


def dense_of_ell(vals, cols, ncols):
    """Expand padded ELL to a dense matrix (for oracle cross-checks).

    Padding slots (val == 0) contribute nothing by construction.
    """
    nrows, k = vals.shape
    dense = jnp.zeros((nrows, ncols), dtype=vals.dtype)
    rows = jnp.repeat(jnp.arange(nrows), k)
    return dense.at[rows, cols.reshape(-1)].add(vals.reshape(-1))
