"""Layer-1 Pallas kernels: SpMV / SpMM over the *generated* padded
ITPACK/ELLPACK layout.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the forelem chain
`orthogonalize(row) → materialize → split → padded ℕ*` produces exactly
the rectangular, unit-stride layout a TPU wants. The kernels tile the
`(nrows × K)` value/column planes into VMEM row-blocks via `BlockSpec`;
the dense `x` / `B` operand stays resident per tile; the K-reduction runs
on the VPU (SpMV) or feeds `(tile×K)·(K×kcols)` contractions toward the
MXU (SpMM).

All kernels use ``interpret=True`` — the CPU PJRT plugin cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO so the AOT
artifacts run anywhere (see /opt/xla-example/README.md).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile size: 8 sublanes × 16 — a multiple of the f32 (8, 128) VPU
# tile when K is folded in; also divides every AOT bucket size.
TILE_ROWS = 128


def _spmv_kernel(vals_ref, cols_ref, x_ref, o_ref):
    """One row-tile of padded-ELL SpMV.

    vals_ref: (TILE, K) f32 — padded row values (0.0 in padding slots)
    cols_ref: (TILE, K) i32 — padded column indices (0 in padding slots)
    x_ref:    (ncols,)  f32 — dense operand, whole-array resident
    o_ref:    (TILE,)   f32
    """
    vals = vals_ref[...]
    cols = cols_ref[...]
    x = x_ref[...]
    # Gather x per slot; padding gathers x[0] but multiplies by 0.0.
    gathered = jnp.take(x, cols, axis=0)
    o_ref[...] = jnp.sum(vals * gathered, axis=1)


def ell_spmv(vals, cols, x, *, tile=TILE_ROWS):
    """Padded-ELL SpMV via Pallas. vals/cols are (nrows, K); x is (ncols,)."""
    nrows, k = vals.shape
    assert cols.shape == (nrows, k)
    assert nrows % tile == 0, f"nrows {nrows} must be a multiple of {tile}"
    ncols = x.shape[0]
    grid = (nrows // tile,)
    return pl.pallas_call(
        _spmv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((ncols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((nrows,), vals.dtype),
        interpret=True,
    )(vals, cols, x)


def _spmm_kernel(vals_ref, cols_ref, b_ref, o_ref, *, k):
    """One row-tile of padded-ELL SpMM against dense B (ncols × kcols).

    The K (slot) reduction is a fori_loop so the emitted HLO stays small
    for large K; each step is a rank-1 update `o += vals[:, p] ⊗ B[cols[:, p], :]`
    — tile-shaped work the VPU/MXU pipelines well.
    """
    vals = vals_ref[...]
    cols = cols_ref[...]
    b = b_ref[...]

    def body(p, acc):
        brows = jnp.take(b, cols[:, p], axis=0)  # (TILE, kcols)
        return acc + vals[:, p][:, None] * brows

    acc0 = jnp.zeros(o_ref.shape, dtype=vals.dtype)
    o_ref[...] = jax.lax.fori_loop(0, k, body, acc0)


def ell_spmm(vals, cols, b, *, tile=TILE_ROWS):
    """Padded-ELL SpMM via Pallas. b is (ncols, kcols) dense, row-major."""
    nrows, k = vals.shape
    assert cols.shape == (nrows, k)
    assert nrows % tile == 0, f"nrows {nrows} must be a multiple of {tile}"
    ncols, kcols = b.shape
    grid = (nrows // tile,)
    return pl.pallas_call(
        partial(_spmm_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((tile, k), lambda i: (i, 0)),
            pl.BlockSpec((ncols, kcols), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, kcols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nrows, kcols), vals.dtype),
        interpret=True,
    )(vals, cols, b)


def vmem_estimate_bytes(tile, k, ncols, kcols=None, dtype_bytes=4):
    """Static VMEM footprint estimate for one grid step (DESIGN §Perf):
    value+column tiles, the resident dense operand, and the output tile.
    Used to pick `tile` so the working set fits the ~16 MiB VMEM budget.
    """
    vals_cols = 2 * tile * k * dtype_bytes
    if kcols is None:  # spmv
        operand = ncols * dtype_bytes
        out = tile * dtype_bytes
    else:
        operand = ncols * kcols * dtype_bytes
        out = tile * kcols * dtype_bytes
    return vals_cols + operand + out
