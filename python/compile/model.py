"""Layer-2: the jax compute graphs the coordinator executes, built on the
Layer-1 Pallas kernels. Each function is shape-specialized at AOT time
(`aot.py`) into one PJRT executable per bucket (DESIGN.md §3).

Python never runs on the request path: these functions exist to be
`jax.jit(...).lower(...)`-ed once into `artifacts/*.hlo.txt`.
"""

import jax
import jax.numpy as jnp

from compile.kernels import ell


def spmv_ell(vals, cols, x):
    """SpMV over generated padded-ELL storage (f32)."""
    return (ell.ell_spmv(vals, cols, x),)


def spmm_ell(vals, cols, b):
    """SpMM over generated padded-ELL storage against dense B (f32)."""
    return (ell.ell_spmm(vals, cols, b),)


def spmv_ell_fused_axpy(vals, cols, x, alpha, y0):
    """`y = alpha * A x + y0` — the fused form XLA produces when the
    surrounding L2 graph composes the kernel with scaling/accumulation;
    exercises that the Pallas call fuses into a larger computation."""
    (ax,) = spmv_ell(vals, cols, x)
    return (alpha * ax + y0,)


def specs_spmv(nrows, k, ncols, dtype=jnp.float32):
    """ShapeDtypeStructs for lowering spmv_ell at a bucket shape."""
    return (
        jax.ShapeDtypeStruct((nrows, k), dtype),
        jax.ShapeDtypeStruct((nrows, k), jnp.int32),
        jax.ShapeDtypeStruct((ncols,), dtype),
    )


def specs_spmm(nrows, k, ncols, kcols, dtype=jnp.float32):
    """ShapeDtypeStructs for lowering spmm_ell at a bucket shape."""
    return (
        jax.ShapeDtypeStruct((nrows, k), dtype),
        jax.ShapeDtypeStruct((nrows, k), jnp.int32),
        jax.ShapeDtypeStruct((ncols, kcols), dtype),
    )
