//! Quickstart: express SpMV as a forelem program over a tuple reservoir,
//! let the framework derive a data structure + routine, and run it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use forelem::baselines::Kernel;
use forelem::concretize;
use forelem::forelem::ir::{NStarMat, Orth};
use forelem::forelem::{build, pretty};
use forelem::matrix::TriMat;
use forelem::transforms::{apply_chain, Step};

fn main() {
    // 1. A sparse matrix is just a reservoir of ⟨row, col⟩_A tuples.
    let mut a = TriMat::new(4, 4);
    a.push(0, 0, 2.0);
    a.push(0, 3, 1.0);
    a.push(1, 1, 3.0);
    a.push(2, 0, -1.0);
    a.push(2, 2, 4.0);
    a.push(3, 3, 5.0);

    // 2. The computation, specified with no data structure and no
    //    iteration order — the forelem normal form.
    let initial = apply_chain(Kernel::Spmv, &[]).unwrap();
    println!("== specification ==\n{}", pretty::render(&build::program(&initial)));

    // 3. Apply a transformation chain; the compiler derives CSR.
    let chain = [
        Step::Orthogonalize(Orth::Row),
        Step::Materialize,
        Step::Split,
        Step::NStar(NStarMat::Exact),
        Step::DimReduce,
    ];
    let state = apply_chain(Kernel::Spmv, &chain).unwrap();
    println!("== after {} ==\n{}", state.history.join(" → "), pretty::render(&build::program(&state)));

    // 4. Concretize: physical storage + executable routine.
    let plan = concretize::plans(&state).unwrap()[0];
    println!("derived data structure: {}", plan.layout.literature_name());
    println!("{}", concretize::codegen::emit(Kernel::Spmv, &plan));

    let prepared = concretize::prepare(plan, &a);
    let x = vec![1.0, 2.0, 3.0, 4.0];
    let mut y = vec![0.0; 4];
    prepared.spmv(&x, &mut y);
    println!("y = A x = {y:?}");
    assert_eq!(y, a.spmv_ref(&x));
    println!("matches the tuple-reservoir oracle ✓");
}
