//! Quickstart: express SpMV as a forelem program over a tuple reservoir,
//! let the engine derive a data structure + routine, and run it —
//! specification in, tuned executable out, in under ten lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use forelem::engine::{Engine, Kernel};
use forelem::forelem::{build, pretty};
use forelem::matrix::TriMat;
use forelem::transforms::apply_chain;

fn main() {
    // 1. A sparse matrix is just a reservoir of ⟨row, col⟩_A tuples.
    let mut a = TriMat::new(4, 4);
    a.push(0, 0, 2.0);
    a.push(0, 3, 1.0);
    a.push(1, 1, 3.0);
    a.push(2, 0, -1.0);
    a.push(2, 2, 4.0);
    a.push(3, 3, 5.0);

    // 2. The computation, specified with no data structure and no
    //    iteration order — the forelem normal form.
    let initial = apply_chain(Kernel::Spmv, &[]).unwrap();
    println!("== specification ==\n{}", pretty::render(&build::program(&initial)));

    // 3. The compiler does the rest: enumerate the transformation
    //    tree, rank the plans on this matrix, assemble the storage.
    let engine = Engine::builder().build();
    let exe = engine.compile(Kernel::Spmv, &a).expect("a hand-built 4x4 matrix is valid");
    println!("== derived ==");
    println!("plan {} via: {}", exe.plan().id, exe.plan().derivation);
    println!("{}", exe.codegen());

    // 4. Execute the generated routine on its generated structure.
    let x = vec![1.0, 2.0, 3.0, 4.0];
    let mut y = vec![0.0; 4];
    exe.spmv(&x, &mut y);
    println!("y = A x = {y:?}");
    assert_eq!(y, a.spmv_ref(&x));
    println!("matches the tuple-reservoir oracle ✓");

    // 5. Observability: why the engine picked this plan.
    println!("\n{}", exe.explain());
}
