//! Autotuning walk-through on one matrix: the engine ranks the
//! cost-model's shortlist, measures the top-K plans
//! (`Autotune::TopK`), keeps the fastest, and archives every
//! measurement as a calibration sample — the per-matrix specialization
//! the paper's framework delivers, served through the one-call
//! `Engine::compile` API. The winner is then compared against all 7
//! library routines.
//!
//! ```bash
//! cargo run --release --example autotune -- [matrix-name] [--quick]
//! ```

use forelem::baselines::ALL_ROUTINES;
use forelem::bench::harness::{black_box, time_fn, BenchConfig};
use forelem::engine::{Autotune, Engine, Kernel};
use forelem::matrix::suite;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let name = args.get(1).map(|s| s.as_str()).filter(|s| !s.starts_with("--")).unwrap_or("Raj1");
    let quick = args.iter().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::from_env() };

    let entry = suite::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown matrix '{name}'; available:");
        for e in &suite::SUITE {
            eprintln!("  {}", e.name);
        }
        std::process::exit(2);
    });
    let m = entry.build();
    println!(
        "matrix {name}: {}×{}, nnz {}, max row {}, mean row {:.1}",
        m.nrows,
        m.ncols,
        m.nnz(),
        m.max_row_nnz(),
        m.nnz() as f64 / m.nrows as f64
    );

    // One call: enumerate → calibrated predict → measure the top-8 →
    // prepare the winner. Samples land in the tuning archive so
    // `forelem calibrate` can refit the profile from this very run.
    let topk = 8;
    let engine = Engine::builder().autotune(Autotune::TopK(topk)).bench(cfg).build();
    let t0 = std::time::Instant::now();
    let exe = engine.compile(Kernel::Spmv, &m).expect("generated matrices are valid");
    println!(
        "\nengine.compile: ranked {} plans, measured top-{topk}, in {:.1} ms",
        engine.plans(Kernel::Spmv).len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("{}", exe.explain());

    // Validate + time the winner against the library baselines.
    let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.013).sin()).collect();
    let want = m.spmv_ref(&x);
    let mut y = vec![0.0; m.nrows];
    exe.spmv(&x, &mut y);
    forelem::util::prop::assert_close(&y, &want, 1e-9)
        .unwrap_or_else(|e| panic!("{} diverged from the oracle: {e}", exe.plan().id));
    let s = time_fn(&cfg, || {
        exe.spmv(&x, &mut y);
        black_box(&y);
    });
    let mut results: Vec<(String, f64)> =
        vec![(format!("[gen] {} ({} B)", exe.plan().id, exe.bytes()), s.median)];
    for r in ALL_ROUTINES {
        let inst = r.prepare(&m);
        let mut y = vec![0.0; m.nrows];
        let s = time_fn(&cfg, || {
            inst.spmv(&x, &mut y);
            black_box(&y);
        });
        results.push((format!("[lib] {}", r.label()), s.median));
    }
    results.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    println!("{:<52} {:>12} {:>9}", "routine", "median", "vs best");
    let best = results[0].1;
    for (name, t) in &results {
        println!("{name:<52} {:>9.2} µs {:>8.2}x", t * 1e6, t / best);
    }
    let gen_time = results.iter().find(|(n, _)| n.starts_with("[gen]")).unwrap().1;
    let best_lib = results.iter().find(|(n, _)| n.starts_with("[lib]")).unwrap();
    println!(
        "\nengine winner: {} — derivation: {}",
        exe.plan().id,
        exe.plan().derivation
    );
    println!(
        "reduction vs best library routine ({}): {:.1}%",
        best_lib.0,
        100.0 * (1.0 - gen_time / best_lib.1)
    );
}
