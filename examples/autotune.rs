//! Autotuning walk-through on one matrix: enumerate the cost-ranked
//! plan space, benchmark every generated plan and all 7 library
//! routines, and report the winner (plus where the analytic cost model
//! had ranked it) — the per-matrix specialization the paper's
//! framework delivers, now with the predict→measure planner visible.
//!
//! ```bash
//! cargo run --release --example autotune -- [matrix-name] [--quick]
//! ```

use forelem::baselines::{Kernel, ALL_ROUTINES};
use forelem::bench::harness::{black_box, time_fn, BenchConfig};
use forelem::concretize;
use forelem::matrix::suite;
use forelem::search::plan::PlanSpace;
use forelem::search::tree;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let name = args.get(1).map(|s| s.as_str()).filter(|s| !s.starts_with("--")).unwrap_or("Raj1");
    let quick = args.iter().any(|a| a == "--quick");
    let cfg = if quick { BenchConfig::quick() } else { BenchConfig::from_env() };

    let entry = suite::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown matrix '{name}'; available:");
        for e in &suite::SUITE {
            eprintln!("  {}", e.name);
        }
        std::process::exit(2);
    });
    let m = entry.build();
    println!(
        "matrix {name}: {}×{}, nnz {}, max row {}, mean row {:.1}",
        m.nrows,
        m.ncols,
        m.nnz(),
        m.max_row_nnz(),
        m.nnz() as f64 / m.nrows as f64
    );

    let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.013).sin()).collect();
    let want = m.spmv_ref(&x);

    let mut results: Vec<(String, f64, String)> = Vec::new();

    // Generated plans, ranked by the analytic cost model on this
    // matrix's statistics.
    let space = PlanSpace::serial_only()
        .with_rank_stats(forelem::matrix::MatrixStats::of(&m));
    let t = tree::enumerate(Kernel::Spmv, &space);
    println!("benchmarking {} generated plans + {} library routines ...", t.plans.len(), 7);
    for (rank, v) in t.plans.iter().enumerate() {
        let p = concretize::prepare(v.exec, &m);
        let mut y = vec![0.0; m.nrows];
        p.spmv(&x, &mut y);
        for (i, (g, w)) in y.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= 1e-9 * w.abs().max(1.0), "{} wrong at {i}", v.id);
        }
        let s = time_fn(&cfg, || {
            p.spmv(&x, &mut y);
            black_box(&y);
        });
        results.push((
            format!("{} {} (predicted #{})", v.id, v.name(), rank + 1),
            s.median,
            v.derivation.clone(),
        ));
    }

    // Library baselines.
    for r in ALL_ROUTINES {
        let inst = r.prepare(&m);
        let mut y = vec![0.0; m.nrows];
        let s = time_fn(&cfg, || {
            inst.spmv(&x, &mut y);
            black_box(&y);
        });
        results.push((format!("[lib] {}", r.label()), s.median, "hand-written library".into()));
    }

    results.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("\n{:<52} {:>12} {:>9}", "routine", "median", "vs best");
    let best = results[0].1;
    for (name, t, _) in &results {
        println!("{name:<52} {:>9.2} µs {:>8.2}x", t * 1e6, t / best);
    }
    let (winner, tbest, derivation) = &results[0];
    println!("\nwinner: {winner}");
    println!("derivation: {derivation}");
    let best_lib = results
        .iter()
        .find(|(n, ..)| n.starts_with("[lib]"))
        .expect("library routines present");
    println!(
        "reduction vs best library routine ({}): {:.1}%",
        best_lib.0,
        100.0 * (1.0 - tbest / best_lib.1)
    );
}
