//! Reproduces the paper's §2 motivating example (Fig 1): computing the
//! average weight of the out-edges of a vertex X from a graph specified
//! as a reservoir of ⟨u, v, w⟩ edge tuples — and the *different versions
//! the compiler can generate automatically* from that one specification:
//!
//!   1. array iteration (full scan with condition)
//!   2. array iteration with mask
//!   3. array iteration with index set
//!   4. orthogonalized-on-u array iteration (CSR-like adjacency)
//!   5. orthogonalized-on-u linked-list iteration
//!   6. value-based orthogonalization, parallelized scan
//!
//! All versions compute identical results; their *cost profiles* differ
//! exactly as §2 argues (the scan versions visit every edge; the
//! orthogonalized versions visit only the edges of X).
//!
//! ```bash
//! cargo run --release --example graph_queries
//! ```

use forelem::util::rng::Rng;

/// The tuple reservoir: edges ⟨u, v, w⟩.
#[derive(Clone)]
struct EdgeReservoir {
    n_vertices: usize,
    edges: Vec<(u32, u32, f64)>,
}

impl EdgeReservoir {
    fn random(n_vertices: usize, n_edges: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let edges = (0..n_edges)
            .map(|_| {
                (
                    rng.gen_range(n_vertices) as u32,
                    rng.gen_range(n_vertices) as u32,
                    rng.gen_f64_range(0.1, 10.0),
                )
            })
            .collect();
        EdgeReservoir { n_vertices, edges }
    }
}

/// Version 1 — plain array iteration (the paper's first listing).
fn avg_v1_scan(g: &EdgeReservoir, x: u32) -> Option<f64> {
    let mut sum = 0.0;
    let mut count = 0usize;
    for &(u, _v, w) in &g.edges {
        if u == x {
            count += 1;
            sum += w;
        }
    }
    (count > 0).then(|| sum / count as f64)
}

/// Version 2 — array iteration with a precomputed mask.
struct MaskIndex {
    mask: Vec<bool>,
}

fn build_mask(g: &EdgeReservoir, x: u32) -> MaskIndex {
    MaskIndex { mask: g.edges.iter().map(|&(u, ..)| u == x).collect() }
}

fn avg_v2_mask(g: &EdgeReservoir, idx: &MaskIndex) -> Option<f64> {
    let mut sum = 0.0;
    let mut count = 0usize;
    for (i, &(_, _, w)) in g.edges.iter().enumerate() {
        if idx.mask[i] {
            count += 1;
            sum += w;
        }
    }
    (count > 0).then(|| sum / count as f64)
}

/// Version 3 — array iteration with a materialized index set.
struct SetIndex {
    set: Vec<u32>,
}

fn build_set(g: &EdgeReservoir, x: u32) -> SetIndex {
    SetIndex {
        set: g
            .edges
            .iter()
            .enumerate()
            .filter(|(_, &(u, ..))| u == x)
            .map(|(i, _)| i as u32)
            .collect(),
    }
}

fn avg_v3_set(g: &EdgeReservoir, idx: &SetIndex) -> Option<f64> {
    if idx.set.is_empty() {
        return None;
    }
    let sum: f64 = idx.set.iter().map(|&i| g.edges[i as usize].2).sum();
    Some(sum / idx.set.len() as f64)
}

/// Version 4 — orthogonalization on `u`, materialized + dimensionality-
/// reduced: the CSR-like adjacency structure `edges[X][i]` of the paper.
struct CsrAdjacency {
    ptr: Vec<u32>,
    w: Vec<f64>,
}

fn build_csr_adj(g: &EdgeReservoir) -> CsrAdjacency {
    let mut ptr = vec![0u32; g.n_vertices + 1];
    for &(u, ..) in &g.edges {
        ptr[u as usize + 1] += 1;
    }
    for i in 0..g.n_vertices {
        ptr[i + 1] += ptr[i];
    }
    let mut w = vec![0.0; g.edges.len()];
    let mut next = ptr.clone();
    for &(u, _v, wt) in &g.edges {
        let p = next[u as usize] as usize;
        w[p] = wt;
        next[u as usize] += 1;
    }
    CsrAdjacency { ptr, w }
}

fn avg_v4_orthogonalized(adj: &CsrAdjacency, x: u32) -> Option<f64> {
    let (s, e) = (adj.ptr[x as usize] as usize, adj.ptr[x as usize + 1] as usize);
    if s == e {
        return None;
    }
    let sum: f64 = adj.w[s..e].iter().sum();
    Some(sum / (e - s) as f64)
}

/// Version 5 — orthogonalization on `u`, linked-list concretization
/// (the paper's `edge_list[X]` version): per-vertex chains in an arena.
struct ListAdjacency {
    head: Vec<i32>,
    next: Vec<i32>,
    w: Vec<f64>,
}

fn build_list_adj(g: &EdgeReservoir) -> ListAdjacency {
    let mut head = vec![-1i32; g.n_vertices];
    let mut next = Vec::with_capacity(g.edges.len());
    let mut w = Vec::with_capacity(g.edges.len());
    for &(u, _v, wt) in &g.edges {
        next.push(head[u as usize]);
        head[u as usize] = w.len() as i32;
        w.push(wt);
    }
    ListAdjacency { head, next, w }
}

fn avg_v5_list(adj: &ListAdjacency, x: u32) -> Option<f64> {
    let mut sum = 0.0;
    let mut count = 0usize;
    let mut l = adj.head[x as usize];
    while l >= 0 {
        sum += adj.w[l as usize];
        count += 1;
        l = adj.next[l as usize];
    }
    (count > 0).then(|| sum / count as f64)
}

/// Version 6 — value-based orthogonalization, parallelized scan:
/// `forall` over partitions (paper Fig 1, top right).
fn avg_v6_parallel(g: &EdgeReservoir, x: u32) -> Option<f64> {
    let parts = 8.min(g.edges.len().max(1));
    let chunk = g.edges.len().div_ceil(parts);
    let partials = forelem::util::pool::parallel_map(parts, parts, |p| {
        let lo = p * chunk;
        let hi = ((p + 1) * chunk).min(g.edges.len());
        let mut sum = 0.0;
        let mut count = 0usize;
        for &(u, _v, w) in &g.edges[lo..hi] {
            if u == x {
                sum += w;
                count += 1;
            }
        }
        (sum, count)
    });
    let (sum, count) = partials.into_iter().fold((0.0, 0), |(s, c), (ps, pc)| (s + ps, c + pc));
    (count > 0).then(|| sum / count as f64)
}

fn main() {
    let g = EdgeReservoir::random(2_000, 60_000, 42);
    let x = 123u32;

    let mask = build_mask(&g, x);
    let set = build_set(&g, x);
    let csr = build_csr_adj(&g);
    let list = build_list_adj(&g);

    let versions: Vec<(&str, Option<f64>)> = vec![
        ("v1 array scan", avg_v1_scan(&g, x)),
        ("v2 mask", avg_v2_mask(&g, &mask)),
        ("v3 index set", avg_v3_set(&g, &set)),
        ("v4 orthogonalized (CSR-like)", avg_v4_orthogonalized(&csr, x)),
        ("v5 orthogonalized (linked list)", avg_v5_list(&list, x)),
        ("v6 parallel scan", avg_v6_parallel(&g, x)),
    ];
    let reference = versions[0].1;
    println!("average out-edge weight of vertex {x}:");
    for (name, v) in &versions {
        println!("  {name:<34} {v:?}");
        match (v, reference) {
            (Some(a), Some(b)) => assert!((a - b).abs() < 1e-9, "{name} diverged"),
            (a, b) => assert_eq!(a.is_some(), b.is_some()),
        }
    }
    println!("all generated versions agree ✓");

    // Cost profile: the orthogonalized versions touch only deg(X) edges.
    use forelem::bench::harness::{black_box, time_fn, BenchConfig};
    let cfg = BenchConfig::quick();
    println!("\ncost profile (per query):");
    let t1 = time_fn(&cfg, || {
        black_box(avg_v1_scan(&g, x));
    });
    let t4 = time_fn(&cfg, || {
        black_box(avg_v4_orthogonalized(&csr, x));
    });
    let t5 = time_fn(&cfg, || {
        black_box(avg_v5_list(&list, x));
    });
    println!("  v1 full scan       {:>10.2} µs", t1.median * 1e6);
    println!("  v4 CSR adjacency   {:>10.2} µs", t4.median * 1e6);
    println!("  v5 linked list     {:>10.2} µs", t5.median * 1e6);
    println!(
        "  orthogonalization speedup: {:.0}x (visits deg(X) ≈ {} of {} edges)",
        t1.median / t4.median,
        set.set.len(),
        g.edges.len()
    );
    assert!(t4.median < t1.median, "orthogonalized version must beat the scan");
}
