//! Reproduces the paper's Fig 8 derivations: the same SpMV specification
//! driven through different transformation chains, printing the IR after
//! every step and the generated C-like code, ending at ITPACK, CSR, CCS,
//! JDS, BCSR, hybrid and DIA — formats "up till now only specified by
//! hand". Also demonstrates the whilelem sorted-list example (§2.3).
//!
//! ```bash
//! cargo run --release --example derive_formats
//! ```

use forelem::baselines::Kernel;
use forelem::concretize;
use forelem::forelem::ir::{NStarMat, Orth};
use forelem::forelem::whilelem::ChainReservoir;
use forelem::forelem::{build, pretty};
use forelem::transforms::{apply_chain, BlockStep, Step};
use forelem::util::rng::Rng;

fn show_chain(title: &str, steps: &[Step]) {
    println!("\n######## {title} ########");
    let mut prefix: Vec<Step> = Vec::new();
    println!("{}", pretty::render(&build::program(&apply_chain(Kernel::Spmv, &[]).unwrap())));
    for &st in steps {
        prefix.push(st);
        let s = apply_chain(Kernel::Spmv, &prefix).unwrap();
        println!("{}", pretty::render(&build::program(&s)));
    }
    let s = apply_chain(Kernel::Spmv, &prefix).unwrap();
    match concretize::plans(&s) {
        Ok(plans) => {
            for p in plans {
                println!("→ concretization: {} [{:?}]", p.layout.literature_name(), p.traversal);
                println!("{}", concretize::codegen::emit(Kernel::Spmv, &p));
            }
        }
        Err(e) => println!("(not concretizable: {e})"),
    }
}

fn main() {
    show_chain(
        "Fig 8 main path → ITPACK",
        &[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Padded),
            Step::Interchange,
        ],
    );
    show_chain(
        "structure splitting + dimensionality reduction → CSR",
        &[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Exact),
            Step::DimReduce,
        ],
    );
    show_chain(
        "orthogonalization on column → CCS",
        &[
            Step::Orthogonalize(Orth::Col),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Exact),
            Step::DimReduce,
        ],
    );
    show_chain(
        "ℕ* sorting + interchange + dim reduction → JDS",
        &[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStarSort,
            Step::NStar(NStarMat::Exact),
            Step::Interchange,
            Step::DimReduce,
        ],
    );
    show_chain(
        "loop blocking on (row, col) → BCSR 3×3 (Fig 9)",
        &[
            Step::Orthogonalize(Orth::RowCol),
            Step::Block(BlockStep::Tile3x3),
            Step::Materialize,
        ],
    );
    show_chain(
        "fill-cutoff blocking of ℕ* → hybrid ELL+COO (§6.2.3)",
        &[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Block(BlockStep::FillCutoff),
        ],
    );
    show_chain(
        "orthogonalization on col−row → DIA",
        &[Step::Orthogonalize(Orth::Diag), Step::Materialize],
    );

    // whilelem (§2.3): the insertion-sort specification, three generated
    // execution strategies, one fixpoint.
    println!("\n######## whilelem sorted-list example (§2.3) ########");
    let mut rng = Rng::new(2022);
    let mut vals: Vec<f64> = (0..24).map(|i| i as f64).collect();
    rng.shuffle(&mut vals);
    println!("input:            {vals:?}");
    let mut a = ChainReservoir::new(vals.clone());
    let rounds = a.run_array_sweep();
    println!("array sweep:      sorted in {rounds} whilelem rounds");
    let mut b = ChainReservoir::new(vals.clone());
    let rounds = b.run_just_scheduled(&mut rng);
    println!("just scheduling:  sorted in {rounds} rounds (fair random order)");
    let mut c = ChainReservoir::new(vals);
    let rounds = c.run_levelized();
    println!("levelized:        sorted in {rounds} rounds (merge-sort schedule)");
    assert_eq!(a.v, b.v);
    assert_eq!(b.v, c.v);
    println!("all three generated strategies agree ✓");
}
