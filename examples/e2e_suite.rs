//! End-to-end driver (DESIGN.md §3, EXPERIMENTS.md): exercises all three
//! layers on the real workload — the 20-matrix suite × 3 kernels × 2
//! architectures, with the generated-variant pool (native executors +
//! the XLA-PJRT AOT backend), producing every paper table and figure and
//! appending them to `EXPERIMENTS.out.md`.
//!
//! ```bash
//! make artifacts                       # AOT: jax/pallas → HLO text
//! cargo run --release --example e2e_suite            # full (minutes)
//! cargo run --release --example e2e_suite -- --quick # smoke (seconds)
//! ```

use forelem::bench::tables;
use forelem::coordinator::sweep::SweepConfig;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let cfg = if quick { SweepConfig::quick() } else { SweepConfig::default() };
    let out = "EXPERIMENTS.out.md";
    let _ = std::fs::remove_file(out);

    let xla = tables::try_xla();
    match &xla {
        Some(b) => println!(
            "XLA backend up: platform={}, {} AOT executables",
            b.platform(),
            b.manifest.entries.len()
        ),
        None => println!("XLA backend absent (run `make artifacts`); native-only sweep"),
    }

    let mut sections: Vec<String> = Vec::new();
    sections.push(tables::fig10());

    println!("== Table 1 (SpMV) ==");
    let (t1, a1, b1) = tables::table1(&cfg, xla.as_ref());
    println!("{t1}");
    sections.push(t1);

    println!("== Table 2 (SpMM) ==");
    let (t2, a2, b2) = tables::table2(&cfg, xla.as_ref());
    println!("{t2}");
    sections.push(t2);

    println!("== Table 3 (TrSv) ==");
    let (t3, a3, b3) = tables::table3(&cfg, xla.as_ref());
    println!("{t3}");
    sections.push(t3);

    let sweeps = [&a1, &b1, &a2, &b2, &a3, &b3];
    let t4 = tables::table4(&sweeps);
    println!("{t4}");
    sections.push(t4);
    let t5 = tables::table5(&sweeps, 2022);
    println!("{t5}");
    sections.push(t5);
    let f11a = tables::fig11(&a1);
    let f11b = tables::fig11(&b1);
    println!("{f11a}\n{f11b}");
    sections.push(f11a);
    sections.push(f11b);

    for s in &sections {
        tables::record(out, s).expect("write EXPERIMENTS.out.md");
    }
    println!("\nwrote {} sections to {out}", sections.len());

    // Headline check (the paper's core claims, as assertions):
    // 1. generated variants beat the per-matrix best library routine on
    //    a majority of matrices for SpMV/SpMM;
    let wins = |s: &forelem::coordinator::sweep::SweepResult| {
        let bg = s.best_gen();
        let bl = s.libs.best_per_matrix(None);
        bg.iter().zip(&bl).filter(|(g, l)| g < l).count()
    };
    let n = a1.libs.matrices.len();
    println!("SpMV host-small: generated wins {}/{n} matrices", wins(&a1));
    println!("SpMM host-small: generated wins {}/{n} matrices", wins(&a2));
    println!("TrSv host-small: generated wins {}/{n} matrices (paper: limited headroom)", wins(&a3));
}
