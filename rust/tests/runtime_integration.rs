//! Integration of the XLA-PJRT backend with the rest of the stack:
//! artifacts load, compile, execute, and agree with the native executors
//! over suite matrices. Skips (with a notice) when artifacts are absent
//! so `cargo test` stays green before `make artifacts`.

use forelem::matrix::suite;
use forelem::runtime::XlaBackend;
use forelem::storage::{Ell, EllOrder};

fn backend() -> Option<XlaBackend> {
    let b = XlaBackend::from_default_dir().ok()?;
    if b.manifest.entries.is_empty() {
        eprintln!("NOTE: artifacts/ empty — run `make artifacts`; skipping XLA integration");
        return None;
    }
    Some(b)
}

#[test]
fn xla_agrees_with_native_on_suite_matrices() {
    let Some(b) = backend() else { return };
    let mut tested = 0;
    for name in ["Erdos971", "blckhole", "Orsreg_1", "stomach", "or2010"] {
        let m = suite::by_name(name).unwrap().build();
        let ell = Ell::from_tuples(&m, EllOrder::ColMajor);
        let n = m.nrows.max(m.ncols);
        if b.bucket_for(forelem::baselines::Kernel::Spmv, n, ell.k, 1).is_none() {
            eprintln!("{name}: no bucket (n={n}, k={}); skipped", ell.k);
            continue;
        }
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.003).sin()).collect();
        let want = m.spmv_ref(&x);
        let got = b.spmv(&ell, &x).unwrap();
        for i in 0..want.len() {
            let scale = want[i].abs().max(1.0);
            assert!(
                (got[i] - want[i]).abs() < 5e-4 * scale,
                "{name} row {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
        tested += 1;
    }
    assert!(tested >= 2, "too few suite matrices fit the AOT buckets: {tested}");
}

#[test]
fn xla_spmm_100_columns_matches() {
    let Some(b) = backend() else { return };
    let m = suite::by_name("blckhole").unwrap().build();
    let ell = Ell::from_tuples(&m, EllOrder::ColMajor);
    let kcols = 100;
    let bmat: Vec<f64> = (0..m.ncols * kcols).map(|i| ((i % 41) as f64 - 20.0) * 0.02).collect();
    let want = m.spmm_ref(&bmat, kcols);
    let got = b.spmm(&ell, &bmat, kcols).unwrap();
    let mut max_rel: f64 = 0.0;
    for i in 0..want.len() {
        max_rel = max_rel.max((got[i] - want[i]).abs() / want[i].abs().max(1.0));
    }
    assert!(max_rel < 2e-3, "max rel err {max_rel}");
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(b) = backend() else { return };
    let m = suite::by_name("Orsreg_1").unwrap().build();
    let ell = Ell::from_tuples(&m, EllOrder::ColMajor);
    let x: Vec<f64> = vec![1.0; m.ncols];
    // First call compiles; the repeat must be much faster (cache hit).
    let t0 = std::time::Instant::now();
    let _ = b.spmv(&ell, &x).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    for _ in 0..3 {
        let _ = b.spmv(&ell, &x).unwrap();
    }
    let repeat = t1.elapsed() / 3;
    assert!(
        repeat < first,
        "cache ineffective: first {first:?}, repeat {repeat:?}"
    );
}
