//! Integration suite for the versioned-matrix subsystem
//! (`matrix::delta` + `engine::version` + `SparseOps::repair`) through
//! the public API only.
//!
//! The contract under test is the one DESIGN.md §"Versioned matrices &
//! delta repair" states:
//!
//! * **Bit identity** — a storage repaired in place for a delta batch
//!   is bit-for-bit the storage a from-scratch build of the post-delta
//!   reservoir produces, whenever `repair` claims success (`Some`);
//!   formats that cannot absorb a batch (ELL plane-width change,
//!   SELL-σ structural ops) must return `None`, never an approximation.
//! * **Generation atomicity** — every serve through a
//!   `VersionedMatrix` names the generation that answered, and those
//!   bits match that generation's own from-scratch prepare exactly,
//!   even while `apply_delta` hot-swaps generations under the serves.
//! * **Lineage** — the `Transition<Fingerprint>` chain stays rooted at
//!   genesis and always arrives at the live fingerprint.
//!
//! (The fault-injection halves — panicking repair degrading to rebuild,
//! swap faults leaving the generation untouched — live in the chaos
//! drill: `forelem chaos` arms `delta.repair` and `delta.swap`.)

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use forelem::concretize::{self, Layout, Traversal};
use forelem::engine::{Engine, VersionedMatrix};
use forelem::matrix::delta::DeltaBatch;
use forelem::matrix::{gen, TriMat};
use forelem::storage::{Ell, EllOrder, SparseOps};
use forelem::{Arch, Kernel};

fn engine_small() -> Engine {
    Engine::builder().arch(Arch::HostSmall).profile(false).archive(false).build()
}

/// A matrix whose odd rows are all empty and whose even rows ramp from
/// one entry to a long one — the CSR row-splice adversary (splices into
/// and out of zero-length rows).
fn with_empty_rows() -> TriMat {
    let mut m = TriMat::new(16, 16);
    for r in (0..16).step_by(2) {
        for c in 0..=r / 2 {
            m.push(r, c, (r * 16 + c) as f64 * 0.03125 + 1.0);
        }
    }
    m
}

/// An insert + update + delete batch valid against `m`: updates the
/// first stored entry, deletes the last, inserts at the first absent
/// coordinate (exactly-representable values so failures are structural,
/// never rounding). `lower_only` restricts the insert hunt to strictly
/// lower coordinates, keeping TrSv reservoirs solvable.
fn mixed_batch_in(m: &TriMat, lower_only: bool) -> DeltaBatch {
    let mut b = DeltaBatch::new(m.nrows, m.ncols);
    let first = m.entries[0];
    let last = m.entries[m.entries.len() - 1];
    b.update(first.row as usize, first.col as usize, first.val + 0.625);
    b.delete(last.row as usize, last.col as usize);
    'hunt: for r in 0..m.nrows {
        for c in 0..m.ncols {
            if lower_only && c >= r {
                continue;
            }
            if !m.entries.iter().any(|e| e.row as usize == r && e.col as usize == c) {
                b.insert(r, c, 0.4375);
                break 'hunt;
            }
        }
    }
    b
}

fn mixed_batch(m: &TriMat) -> DeltaBatch {
    mixed_batch_in(m, false)
}

/// A value-only batch touching `k` distinct stored entries — keeps
/// every repair-capable format (ELL and SELL-σ included) on the
/// repair path.
fn update_batch(m: &TriMat, k: usize, salt: f64) -> DeltaBatch {
    let mut b = DeltaBatch::new(m.nrows, m.ncols);
    let stride = (m.entries.len() / k.max(1)).max(1);
    for e in m.entries.iter().step_by(stride).take(k) {
        b.update(e.row as usize, e.col as usize, e.val + salt);
    }
    b
}

fn spmv_bits(ops: &dyn SparseOps, t: Traversal, x: &[f64], nrows: usize) -> Vec<u64> {
    let mut y = vec![0.0; nrows];
    ops.spmv_serial(t, x, &mut y);
    y.iter().map(|v| v.to_bits()).collect()
}

fn probe_vector(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i as f64) * 0.17).sin() + 0.5).collect()
}

/// Property: for EVERY serial plan in the SpMV pool, on adversarial
/// shapes, a claimed repair (`Some`) serves exactly the bits a
/// from-scratch build of the post-delta reservoir serves.
#[test]
fn storage_repair_is_bit_identical_to_a_from_scratch_build() {
    let engine = engine_small();
    let shapes: Vec<(&str, TriMat)> = vec![
        ("uniform", gen::uniform_random(40, 40, 320, 4001)),
        ("empty-rows", with_empty_rows()),
        ("banded", gen::banded(48, 3, 0.8, 4002)),
        ("powerlaw", gen::powerlaw(48, 2.2, 24, 4003)),
    ];
    let mut repaired_layouts: Vec<String> = Vec::new();
    for (name, m) in &shapes {
        let x = probe_vector(m.ncols);
        for batch in [mixed_batch(m), update_batch(m, 6, 0.8125)] {
            let resolved = batch.resolved().expect("clean batch");
            let post = batch.apply(m).expect("clean batch");
            for plan in engine.plans(Kernel::Spmv) {
                if !plan.exec.schedule.is_serial() || plan.exec.lanes != 1 {
                    continue;
                }
                let ops = concretize::build_ops(plan.exec.layout, m);
                let Some(rep) = ops.repair(&resolved) else { continue };
                let fresh = concretize::build_ops(plan.exec.layout, &post);
                assert_eq!(
                    spmv_bits(rep.as_ref(), plan.exec.traversal, &x, post.nrows),
                    spmv_bits(fresh.as_ref(), plan.exec.traversal, &x, post.nrows),
                    "{name}/{}: repaired storage drifted from the from-scratch build",
                    plan.id
                );
                repaired_layouts.push(plan.exec.layout.slug());
            }
        }
    }
    // The suite must actually have exercised the three repair-capable
    // format families, or the property above is vacuous.
    for want in ["csr", "ell", "sell"] {
        assert!(
            repaired_layouts.iter().any(|s| s.starts_with(want)),
            "no {want}* plan ever took the repair path (exercised: {repaired_layouts:?})"
        );
    }
}

/// ELL's padding contract: a batch that changes the global maximum row
/// length changes the plane width, so `repair` must refuse (a fresh
/// build would lay out differently) — while an in-padding value patch
/// must succeed and match the fresh build exactly.
#[test]
fn ell_padding_overflow_refuses_repair_and_value_patches_do_not() {
    // Every row holds 3 entries except row 7, the unique longest with
    // 5 — so the plane width is pinned to exactly one row's fate.
    let mut m = TriMat::new(16, 16);
    for r in 0..16 {
        for c in 0..3 {
            m.push(r, (r + c) % 16, 1.0 + (r * 16 + c) as f64 * 0.0625);
        }
    }
    m.push(7, 10, 3.5);
    m.push(7, 11, 3.75);
    m.sort_row_major();
    let ell = Ell::from_tuples(&m, EllOrder::ColMajor);

    // Growing the longest row widens the plane: repair must refuse.
    let mut grow = DeltaBatch::new(m.nrows, m.ncols);
    grow.insert(7, 0, 1.0);
    assert!(
        ell.repaired(&grow.resolved().expect("clean batch")).is_none(),
        "an insert past the plane width must force a rebuild, not an approximate repair"
    );

    // Deleting from the unique longest row shrinks the plane: also a
    // refusal.
    let mut shrink = DeltaBatch::new(m.nrows, m.ncols);
    shrink.delete(7, 10);
    assert!(ell.repaired(&shrink.resolved().expect("clean batch")).is_none());

    // In-padding value rewrites are the repair sweet spot.
    let patch = update_batch(&m, 5, 0.1875);
    let resolved = patch.resolved().expect("clean batch");
    let rep = ell.repaired(&resolved).expect("value patches stay within the plane");
    let post = patch.apply(&m).expect("clean batch");
    let fresh = Ell::from_tuples(&post, EllOrder::ColMajor);
    let x = probe_vector(m.ncols);
    assert_eq!(
        spmv_bits(&rep, Traversal::RowWise, &x, post.nrows),
        spmv_bits(&fresh, Traversal::RowWise, &x, post.nrows),
        "in-padding ELL repair drifted from the fresh build"
    );

    // SELL-σ is value-patch-only: structural ops refuse at the trait.
    let sell = concretize::build_ops(Layout::SellSigma { s: 32, sigma: 256 }, &m);
    assert!(sell.repair(&grow.resolved().expect("clean batch")).is_none());
    assert!(sell.repair(&resolved).is_some(), "SELL-σ must absorb pure value patches");
}

/// Serve the versioned matrix with `kernel` and return
/// `(fingerprint, bits)`; `k_dense` sizes the SpMM panel.
fn serve_bits(vm: &VersionedMatrix, kernel: Kernel, k_dense: usize) -> (u64, Vec<u64>) {
    let m = vm.snapshot();
    match kernel {
        Kernel::Spmv => {
            let x = probe_vector(m.ncols);
            let mut y = vec![0.0; m.nrows];
            let fp = vm.spmv(&x, &mut y).expect("spmv requested");
            (fp.0, y.iter().map(|v| v.to_bits()).collect())
        }
        Kernel::Spmm => {
            let b = probe_vector(m.ncols * k_dense);
            let mut c = vec![0.0; m.nrows * k_dense];
            let fp = vm.spmm(&b, &mut c).expect("spmm requested");
            (fp.0, c.iter().map(|v| v.to_bits()).collect())
        }
        Kernel::Trsv => {
            let b = probe_vector(m.nrows);
            let mut x = vec![0.0; m.nrows];
            let fp = vm.trsv(&b, &mut x).expect("trsv requested");
            (fp.0, x.iter().map(|v| v.to_bits()).collect())
        }
    }
}

/// The answering generation's reference bits: a from-scratch prepare of
/// its own reservoir under the executable's own plan.
fn reference_bits(vm: &VersionedMatrix, kernel: Kernel, k_dense: usize) -> Vec<u64> {
    let exe = vm.executable(kernel).expect("kernel requested");
    let m = vm.snapshot();
    let prep = concretize::prepare(exe.plan().exec, &m);
    match kernel {
        Kernel::Spmv => {
            let x = probe_vector(m.ncols);
            let mut y = vec![0.0; m.nrows];
            prep.spmv(&x, &mut y);
            y.iter().map(|v| v.to_bits()).collect()
        }
        Kernel::Spmm => {
            let b = probe_vector(m.ncols * k_dense);
            let mut c = vec![0.0; m.nrows * k_dense];
            prep.spmm(&b, k_dense, &mut c);
            c.iter().map(|v| v.to_bits()).collect()
        }
        Kernel::Trsv => {
            let b = probe_vector(m.nrows);
            let mut x = vec![0.0; m.nrows];
            prep.trsv(&b, &mut x);
            x.iter().map(|v| v.to_bits()).collect()
        }
    }
}

/// Property: across all three kernels, every post-delta generation
/// serves exactly the bits a from-scratch prepare of its reservoir
/// serves — whichever route (repair / rebuild / re-plan) `apply_delta`
/// took — and the lineage chain stays rooted at genesis.
#[test]
fn every_generation_serves_its_own_from_scratch_bits_across_kernels() {
    const K_DENSE: usize = 8;
    let engine = Engine::builder()
        .arch(Arch::HostSmall)
        .profile(false)
        .archive(false)
        .spmm_k(K_DENSE)
        .build();

    for kernel in [Kernel::Spmv, Kernel::Spmm, Kernel::Trsv] {
        let base = gen::uniform_random(36, 36, 260, 4020);
        let m = if kernel == Kernel::Trsv { base.strictly_lower() } else { base };
        let vm = engine.versioned(&m, &[kernel]).expect("valid matrix");
        let genesis = vm.fingerprint();

        for round in 0..4u32 {
            let live = vm.snapshot();
            let batch = match round {
                // Value patches (the repair fast path), then structural
                // rounds (splice or rebuild), then a mixed one.
                0 | 2 => update_batch(&live, 4, 0.25 * f64::from(round + 1)),
                _ => mixed_batch_in(&live, kernel == Kernel::Trsv),
            };
            let report = vm.apply_delta(&batch).expect("clean batch");
            assert_eq!(report.generation, u64::from(round) + 1);
            assert_eq!(report.outcomes.len(), 1, "one requested kernel, one route");
            assert_eq!(*report.chain.from(), genesis, "chain re-rooted away from genesis");
            assert_eq!(*report.chain.to(), vm.fingerprint());

            let (fp, served) = serve_bits(&vm, kernel, K_DENSE);
            assert_eq!(fp, vm.fingerprint().0);
            assert_eq!(
                served,
                reference_bits(&vm, kernel, K_DENSE),
                "{kernel:?} round {round}: generation drifted from its from-scratch prepare"
            );
        }
        assert_eq!(vm.generation(), 4);
    }
}

/// TrSv level-set adversary: deletes that cut the dependency chain —
/// emptying whole levels — must be followed by serves computed on
/// re-derived level structure, bit-identical to a fresh prepare.
#[test]
fn trsv_survives_level_emptying_deletes() {
    // A strict chain: row i depends only on row i-1 → n-deep levels,
    // plus a few long-range entries to keep the planner honest.
    let n = 24;
    let mut m = TriMat::new(n, n);
    for i in 1..n {
        m.push(i, i - 1, 1.0 + i as f64 * 0.0625);
    }
    for i in (6..n).step_by(6) {
        m.push(i, 1, 0.5);
    }
    m.sort_row_major();

    let vm = engine_small().versioned(&m, &[Kernel::Trsv]).expect("valid matrix");

    // Cut the chain at its midpoint, then sever rows 1..=3 entirely:
    // the first levels collapse and later rows jump levels.
    let mut cut = DeltaBatch::new(n, n);
    cut.delete(n / 2, n / 2 - 1);
    cut.delete(1, 0);
    cut.delete(2, 1);
    cut.delete(3, 2);
    let report = vm.apply_delta(&cut).expect("all deleted coordinates are present");
    assert_eq!(report.ops, 4);

    let (fp, served) = serve_bits(&vm, Kernel::Trsv, 1);
    assert_eq!(fp, vm.fingerprint().0);
    assert_eq!(
        served,
        reference_bits(&vm, Kernel::Trsv, 1),
        "level-set re-derivation after chain-cutting deletes drifted"
    );
    // And the solve is still the unit-lower solve of the live matrix.
    let live = vm.snapshot();
    let b = probe_vector(n);
    let mut x = vec![0.0; n];
    vm.trsv(&b, &mut x).expect("trsv requested");
    let want = live.trsv_unit_lower_ref(&b);
    for (got, want) in x.iter().zip(&want) {
        assert!((got - want).abs() <= 1e-8 * want.abs().max(1.0), "{got} vs {want}");
    }
}

/// CSR empty-row adversary at the engine level: splicing entries into
/// previously-empty rows and emptying rows out again, with the old
/// generation's cache entries retired at the swap.
#[test]
fn empty_row_splices_round_trip_and_retire_the_old_generation() {
    let m = with_empty_rows();
    let vm = engine_small().versioned(&m, &[Kernel::Spmv]).expect("valid matrix");

    // Fill two empty rows, empty row 0 (its single entry), patch one.
    let mut b = DeltaBatch::new(m.nrows, m.ncols);
    b.insert(3, 7, 2.5);
    b.insert(5, 0, -1.25);
    b.delete(0, 0);
    let probe = m.entries[m.entries.len() - 1];
    b.update(probe.row as usize, probe.col as usize, probe.val + 0.375);
    let report = vm.apply_delta(&b).expect("clean batch");
    assert_eq!(report.ops, 4);
    assert!(
        report.cache_evicted >= 1,
        "the genesis compile was cached under the old fingerprint and must retire with it"
    );

    let (fp, served) = serve_bits(&vm, Kernel::Spmv, 1);
    assert_eq!(fp, vm.fingerprint().0);
    assert_eq!(served, reference_bits(&vm, Kernel::Spmv, 1));
}

/// Hot-swap hammer: serve threads race a stream of delta applications;
/// every answer must carry a fingerprint of a generation that existed
/// and exactly that generation's bits — never a torn mix of two.
#[test]
fn concurrent_serves_never_observe_a_torn_generation() {
    const ROUNDS: u32 = 12;
    const CLIENTS: usize = 4;
    let m = gen::uniform_random(48, 48, 400, 4040);
    let vm = engine_small().versioned(&m, &[Kernel::Spmv]).expect("valid matrix");
    let genesis = vm.fingerprint();
    let x = probe_vector(m.ncols);

    // fingerprint → that generation's reference bits. Generations are
    // only ever created by the single mutator below, which records each
    // one right after its swap; threads only collect and are checked
    // after the fact, so a serve racing the recording is still judged
    // against a complete map.
    let mut expected: HashMap<u64, Vec<u64>> = HashMap::new();
    expected.insert(vm.fingerprint().0, reference_bits(&vm, Kernel::Spmv, 1));

    let stop = AtomicBool::new(false);
    let observed: Mutex<Vec<(u64, Vec<u64>)>> = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for _ in 0..CLIENTS {
            let vm = &vm;
            let stop = &stop;
            let observed = &observed;
            let x = &x;
            let nrows = m.nrows;
            s.spawn(move || {
                let mut y = vec![0.0; nrows];
                let mut local = Vec::new();
                while !stop.load(Ordering::Relaxed) {
                    let fp = vm.spmv(x, &mut y).expect("spmv requested");
                    local.push((fp.0, y.iter().map(|v| v.to_bits()).collect::<Vec<u64>>()));
                }
                observed.lock().unwrap_or_else(|p| p.into_inner()).extend(local);
            });
        }
        for round in 0..ROUNDS {
            let live = vm.snapshot();
            let batch = update_batch(&live, 5, 0.125 * f64::from(round + 1));
            vm.apply_delta(&batch).expect("clean batch");
            expected.insert(vm.fingerprint().0, reference_bits(&vm, Kernel::Spmv, 1));
        }
        stop.store(true, Ordering::Relaxed);
    });

    assert_eq!(vm.generation(), u64::from(ROUNDS));
    assert_eq!(*vm.chain().from(), genesis);
    assert_eq!(*vm.chain().to(), vm.fingerprint());
    let observed = observed.into_inner().unwrap_or_else(|p| p.into_inner());
    assert!(!observed.is_empty(), "the serve threads never got a request through");
    for (fp, bits) in &observed {
        let want = expected
            .get(fp)
            .unwrap_or_else(|| panic!("serve answered from unknown generation fp{fp:016x}"));
        assert_eq!(bits, want, "fp{fp:016x}: serve bits are not that generation's bits");
    }
}
