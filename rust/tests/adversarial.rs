//! Adversarial-input contract of the ingestion seams: randomized
//! reservoirs through `TriMat::validate`, hostile MatrixMarket files
//! through `mmio::read_matrix_market`, and invalid matrices through
//! `Engine::compile` / `concretize::try_prepare`. The property under
//! test is totality — every bad input comes back as a typed error
//! (`ForelemError` / `MmError`), never a panic, never a silently
//! corrupt data structure.

use std::io::Cursor;

use forelem::concretize::{self, Layout, Traversal};
use forelem::engine::{Arch, Engine, Kernel};
use forelem::matrix::mmio::{self, MmError};
use forelem::matrix::{gen, Entry, TriMat};
use forelem::util::rng::Rng;

fn hermetic() -> Engine {
    Engine::builder().arch(Arch::HostSmall).profile(false).archive(false).build()
}

/// Property sweep: random valid reservoirs validate `Ok`, and each
/// single-fault mutation (row/col out of bounds, NaN, Inf, duplicate
/// coordinate) flips exactly to an `invalid-matrix` error.
#[test]
fn validate_accepts_random_valid_and_rejects_every_mutation() {
    let mut rng = Rng::new(0xAD5E_2026);
    for round in 0..32 {
        let nrows = 2 + rng.gen_range(30);
        let ncols = 2 + rng.gen_range(30);
        let nnz = 1 + rng.gen_range(nrows * ncols / 2);
        let mut m = gen::uniform_random(nrows, ncols, nnz, 0x5EED + round);
        m.validate().unwrap_or_else(|e| panic!("generator emitted an invalid reservoir: {e}"));

        let victim = rng.gen_range(m.nnz());
        let mut oob_row = m.clone();
        oob_row.entries[0].row = m.nrows as u32;
        let mut oob_col = m.clone();
        oob_col.entries[0].col = u32::MAX;
        let mut nan = m.clone();
        nan.entries[victim].val = f64::NAN;
        let mut inf = m.clone();
        inf.entries[victim].val = f64::INFINITY;
        let mut dup = m.clone();
        dup.entries.push(m.entries[victim]);
        let mutated = [
            ("row out of bounds", oob_row),
            ("col out of bounds", oob_col),
            ("NaN value", nan),
            ("Inf value", inf),
            ("duplicate coordinate", dup),
        ];
        for (what, bad) in &mutated {
            let err = match bad.validate() {
                Err(e) => e,
                Ok(()) => panic!("{what} must not validate (round {round})"),
            };
            assert_eq!(err.class(), "invalid-matrix", "{what}: wrong error class");
        }
    }
}

/// Hostile MatrixMarket inputs: structural garbage surfaces as
/// `Parse`/`Unsupported`, while files that *parse* into an invalid
/// reservoir (non-finite values, degenerate dimensions) surface as
/// `MmError::Invalid` carrying the typed reservoir error.
#[test]
fn matrix_market_rejects_hostile_files_with_typed_errors() {
    let parse = |txt: &str| mmio::read_matrix_market(Cursor::new(txt.to_string()));

    // Structurally broken files.
    assert!(matches!(parse(""), Err(MmError::Parse { .. })), "empty file");
    assert!(matches!(parse("junk header\n1 1 0\n"), Err(MmError::Parse { .. })), "bad header");
    let arr = "%%MatrixMarket matrix array real general\n2 2\n1.0\n";
    assert!(matches!(parse(arr), Err(MmError::Unsupported(_))), "array format");
    let cx = "%%MatrixMarket matrix coordinate complex general\n1 1 1\n1 1 1.0 0.0\n";
    assert!(matches!(parse(cx), Err(MmError::Unsupported(_))), "complex field");
    let trunc = "%%MatrixMarket matrix coordinate real general\n3 3 2\n1 1 1.0\n";
    assert!(matches!(parse(trunc), Err(MmError::Parse { .. })), "truncated entries");
    let zero_idx = "%%MatrixMarket matrix coordinate real general\n2 2 1\n0 1 1.0\n";
    assert!(matches!(parse(zero_idx), Err(MmError::Parse { .. })), "1-based index 0");
    let oob = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 3 1.0\n";
    assert!(matches!(parse(oob), Err(MmError::Parse { .. })), "column past size line");

    // Parse fine, validate badly: the reservoir error rides inside.
    for (what, txt) in [
        ("nan value", "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 nan\n"),
        ("inf value", "%%MatrixMarket matrix coordinate real general\n2 2 1\n2 2 inf\n"),
        ("zero-dimension size line", "%%MatrixMarket matrix coordinate real general\n0 0 0\n"),
    ] {
        match parse(txt) {
            Err(MmError::Invalid(e)) => assert_eq!(e.class(), "invalid-matrix", "{what}"),
            other => panic!("{what}: expected MmError::Invalid, got {other:?}"),
        }
    }

    // Duplicates are data, not hostility: MatrixMarket semantics sum
    // them, so the parsed reservoir still validates.
    let dup = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.5\n1 1 2.5\n";
    let m = parse(dup).expect("duplicates are summed, not rejected");
    assert_eq!(m.nnz(), 1);
    assert_eq!(m.to_dense()[0], 4.0);
    m.validate().expect("summed reservoir is valid");
}

/// The engine's one hard error: an invalid reservoir is refused up
/// front with `InvalidMatrix` on both compile entry points — it never
/// reaches plan selection or storage assembly.
#[test]
fn engine_refuses_invalid_reservoirs_before_building_anything() {
    let engine = hermetic();
    let hostile = [
        ("zero-dimension", TriMat::new(0, 8)),
        (
            "NaN entry",
            TriMat::with_entries(4, 4, vec![Entry { row: 1, col: 2, val: f64::NAN }]),
        ),
        (
            "out-of-bounds entry",
            TriMat::with_entries(4, 4, vec![Entry { row: 9, col: 0, val: 1.0 }]),
        ),
    ];
    for (what, m) in &hostile {
        for kernel in [Kernel::Spmv, Kernel::Spmm, Kernel::Trsv] {
            let err = engine.compile(kernel, m).expect_err(what);
            assert_eq!(err.class(), "invalid-matrix", "{what} via compile({kernel:?})");
        }
        let err = engine.compile_pinned(Kernel::Spmv, m, "csr.row.serial").expect_err(what);
        assert_eq!(err.class(), "invalid-matrix", "{what} via compile_pinned");
    }
}

/// `concretize::try_prepare` is the fallible seam below the engine:
/// hostile reservoirs come back as typed errors, valid ones produce a
/// working storage whose SpMV matches the triplet oracle.
#[test]
fn try_prepare_is_total_over_hostile_and_valid_reservoirs() {
    let plan = concretize::Plan::serial(Layout::Csr, Traversal::RowWise);
    let bad = TriMat::with_entries(3, 3, vec![Entry { row: 0, col: 0, val: f64::NEG_INFINITY }]);
    let err = concretize::try_prepare(plan, &bad).expect_err("non-finite reservoir");
    assert_eq!(err.class(), "invalid-matrix");

    let m = gen::uniform_random(24, 24, 96, 0xFACE);
    let prepared = concretize::try_prepare(plan, &m).expect("valid reservoir");
    let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.031).cos()).collect();
    let mut y = vec![0.0; m.nrows];
    prepared.spmv(&x, &mut y);
    forelem::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
}
