//! Integration: specification → transformation chain → concretization →
//! execution ≡ tuple-reservoir oracle, across the whole enumerated tree,
//! all kernels, several matrix classes — the end-to-end correctness
//! contract of the framework.

use forelem::baselines::Kernel;
use forelem::concretize;
use forelem::matrix::gen;
use forelem::matrix::TriMat;
use forelem::search::plan::PlanSpace;
use forelem::search::tree;
use forelem::util::prop::assert_close;

fn matrices() -> Vec<(&'static str, TriMat)> {
    vec![
        ("uniform", gen::uniform_random(60, 70, 500, 100)),
        ("powerlaw", gen::powerlaw(80, 1.9, 40, 101)),
        ("banded", gen::banded(90, 6, 0.6, 102)),
        ("fem", gen::fem_blocks(20, 3, 5, 103)),
        ("stencil", gen::laplacian_2d(9, 9, 104)),
    ]
}

#[test]
fn every_spmv_variant_matches_oracle_on_every_class() {
    let t = tree::enumerate(Kernel::Spmv, &PlanSpace::serial_only());
    assert!(t.plans.len() >= 15);
    for (name, m) in matrices() {
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.7).cos() + 0.2).collect();
        let want = m.spmv_ref(&x);
        for v in &t.plans {
            let p = concretize::prepare(v.exec, &m);
            let mut y = vec![0.0; m.nrows];
            p.spmv(&x, &mut y);
            assert_close(&y, &want, 1e-10)
                .unwrap_or_else(|e| panic!("{name}/{}: {e}\nderivation: {}", v.id, v.derivation));
        }
    }
}

#[test]
fn every_spmm_variant_matches_oracle() {
    let t = tree::enumerate(Kernel::Spmm, &PlanSpace::serial_only());
    let k = 7;
    for (name, m) in matrices() {
        let b: Vec<f64> = (0..m.ncols * k).map(|i| ((i * 13 % 29) as f64 - 14.0) * 0.1).collect();
        let want = m.spmm_ref(&b, k);
        for v in &t.plans {
            let p = concretize::prepare(v.exec, &m);
            let mut c = vec![0.0; m.nrows * k];
            p.spmm(&b, k, &mut c);
            assert_close(&c, &want, 1e-10)
                .unwrap_or_else(|e| panic!("{name}/{}: {e}", v.id));
        }
    }
}

#[test]
fn every_trsv_variant_matches_oracle() {
    let t = tree::enumerate(Kernel::Trsv, &PlanSpace::serial_only());
    for (name, m) in matrices() {
        if m.nrows != m.ncols {
            continue;
        }
        let l = m.strictly_lower();
        let b: Vec<f64> = (0..l.nrows).map(|i| 1.0 - (i % 9) as f64 * 0.2).collect();
        let want = l.trsv_unit_lower_ref(&b);
        for v in &t.plans {
            let p = concretize::prepare(v.exec, &l);
            let mut x = vec![0.0; l.nrows];
            p.trsv(&b, &mut x);
            assert_close(&x, &want, 1e-8)
                .unwrap_or_else(|e| panic!("{name}/{}: {e}", v.id));
        }
    }
}

#[test]
fn codegen_exists_for_every_variant() {
    for kernel in [Kernel::Spmv, Kernel::Spmm, Kernel::Trsv] {
        let t = tree::enumerate(kernel, &PlanSpace::serial_only());
        for v in &t.plans {
            let txt = concretize::codegen::emit(kernel, &v.exec);
            assert!(txt.starts_with("/* generated:"), "{}: {txt}", v.id);
            assert!(txt.len() > 50, "{}: suspiciously short codegen", v.id);
        }
    }
}

#[test]
fn derivations_are_replayable() {
    // Each variant's recorded history must replay to the same plan.
    use forelem::forelem::ir::{NStarMat, Orth};
    use forelem::transforms::{apply_chain, BlockStep, Step};
    let parse = |h: &str| -> Option<Step> {
        Some(match h {
            "orthogonalize(row)" => Step::Orthogonalize(Orth::Row),
            "orthogonalize(col)" => Step::Orthogonalize(Orth::Col),
            "orthogonalize(row,col)" => Step::Orthogonalize(Orth::RowCol),
            "orthogonalize(col-row)" => Step::Orthogonalize(Orth::Diag),
            "materialize(dep)" | "materialize(indep)" => Step::Materialize,
            "split" => Step::Split,
            "nstar(padded)" => Step::NStar(NStarMat::Padded),
            "nstar(exact)" => Step::NStar(NStarMat::Exact),
            "nstar_sort" => Step::NStarSort,
            "interchange" => Step::Interchange,
            "dim_reduce" => Step::DimReduce,
            "block(fill)" => Step::Block(BlockStep::FillCutoff),
            // tile/slice sizes are not recoverable from the history text
            "block(tile)" | "block(slice)" => return None,
            other => panic!("unknown history entry '{other}'"),
        })
    };
    let t = tree::enumerate(Kernel::Spmv, &PlanSpace::serial_only());
    let mut replayed = 0;
    for v in &t.plans {
        let steps: Option<Vec<Step>> = v.state.history.iter().map(|h| parse(h)).collect();
        let Some(steps) = steps else { continue };
        let s = apply_chain(Kernel::Spmv, &steps).unwrap();
        let plans = concretize::plans(&s).unwrap();
        assert!(plans.contains(&v.exec), "{}: replay diverged", v.id);
        replayed += 1;
    }
    assert!(replayed >= 10, "too few replayable variants: {replayed}");
}
