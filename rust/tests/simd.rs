//! The SIMD-vs-scalar accuracy contract (DESIGN.md "Vectorization as
//! a plan axis"), held on both the scalar lane-structured path and —
//! under `--features simd` on an AVX2 machine — the gather+FMA path:
//!
//! * SELL-σ lane kernels are **bit-identical** to the serial kernel at
//!   every width (the vector runs *across* rows, so each output row
//!   accumulates in the exact serial plane order).
//! * The scalar SpMM lane micro-kernel is bit-identical (element-wise
//!   axpy never reassociates); the AVX2 path fuses each mul+add and is
//!   held to tight relative tolerance instead.
//! * CSR/ELL lane kernels reassociate the per-row reduction, so on
//!   exactly-representable (integer-valued) data they stay within
//!   2 ULP of serial — 0 in practice — on adversarial shapes, and on
//!   continuous mixed-sign data within 1e-12 relative.
//! * Rows shorter than the lane count never enter the wide loop, so
//!   every path degenerates to the serial scalar tail bit-for-bit.

use forelem::engine::{Arch, Engine, Kernel};
use forelem::kernels::{simd, spmm, spmv};
use forelem::matrix::{gen, TriMat};
use forelem::storage::{Csr, Ell, EllOrder, SellSigma};
use forelem::util::prop::{forall, Gen};

/// Distance in units-in-the-last-place between two doubles (same
/// sign assumed by the callers; integer-valued data keeps it at 0).
fn ulps(a: f64, b: f64) -> u64 {
    fn key(x: f64) -> u64 {
        let b = x.to_bits();
        if b >> 63 == 0 {
            b | (1 << 63)
        } else {
            !b
        }
    }
    key(a).abs_diff(key(b))
}

/// A reservoir whose values (and the workloads below) are small
/// integers: every product and every partial sum is exactly
/// representable, so any association order gives the same bits.
fn integer_matrix(nrows: usize, ncols: usize, per_row: usize, seed: u64) -> TriMat {
    let mut m = TriMat::new(nrows, ncols);
    let mut used = std::collections::HashSet::new();
    let mut s = seed | 1;
    for _ in 0..nrows * per_row {
        s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
        let r = (s >> 33) as usize % nrows;
        let c = (s >> 13) as usize % ncols;
        if used.insert((r, c)) {
            m.push(r, c, ((s >> 7) % 8 + 1) as f64);
        }
    }
    m
}

fn integer_x(n: usize) -> Vec<f64> {
    (0..n).map(|i| ((i % 7) + 1) as f64).collect()
}

#[test]
fn rows_shorter_than_the_lane_count_are_bit_identical() {
    // band=1, fill=1.0: at most 3 nonzeros per row, so the wide loop
    // never runs and both paths reduce to the serial scalar tail.
    let m = gen::banded(40, 1, 1.0, 31);
    let a = Csr::from_tuples(&m);
    let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.37).sin() * 3.0).collect();
    let mut y0 = vec![0.0; 40];
    spmv::csr(&a, &x, &mut y0);
    for lanes in [4usize, 8] {
        let mut y = vec![-1.0; 40];
        simd::csr_spmv(&a, &x, &mut y, lanes);
        assert_eq!(y, y0, "lanes={lanes} must fall through to the exact serial tail");
    }
}

#[test]
fn integer_data_stays_within_2_ulp_on_adversarial_shapes() {
    // Skewed row lengths (powerlaw-like hub rows from the generator
    // below) exercise wide loops, tails, and empty rows together; on
    // exactly-representable data every association order is exact.
    for (mi, m) in [
        integer_matrix(64, 48, 9, 5),
        gen_integer_powerlaw(80, 17),
        integer_matrix(33, 71, 2, 11),
    ]
    .iter()
    .enumerate()
    {
        let a = Csr::from_tuples(m);
        let x = integer_x(m.ncols);
        let mut y0 = vec![0.0; m.nrows];
        spmv::csr(&a, &x, &mut y0);
        for lanes in [4usize, 8] {
            let mut y = vec![f64::NAN; m.nrows];
            simd::csr_spmv(&a, &x, &mut y, lanes);
            for (i, (g, w)) in y.iter().zip(&y0).enumerate() {
                assert!(ulps(*g, *w) <= 2, "matrix {mi} lanes {lanes} row {i}: {g} vs {w}");
            }
        }
        for order in [EllOrder::RowMajor, EllOrder::ColMajor] {
            let e = Ell::from_tuples(m, order);
            let mut y0 = vec![0.0; m.nrows];
            spmv::ell_rowwise(&e, &x, &mut y0);
            for lanes in [4usize, 8] {
                let mut y = vec![f64::NAN; m.nrows];
                simd::ell_spmv(&e, &x, &mut y, lanes);
                for (i, (g, w)) in y.iter().zip(&y0).enumerate() {
                    assert!(
                        ulps(*g, *w) <= 2,
                        "matrix {mi} {order:?} lanes {lanes} row {i}: {g} vs {w}"
                    );
                }
            }
        }
    }
}

/// Integer-valued powerlaw stand-in: row r gets roughly `80/(r+1)`
/// slots, giving a few very long rows and a long tail of short ones.
fn gen_integer_powerlaw(n: usize, seed: u64) -> TriMat {
    let mut m = TriMat::new(n, n);
    let mut used = std::collections::HashSet::new();
    let mut s = seed | 1;
    for r in 0..n {
        let want = (n / (r + 1)).max(1);
        for _ in 0..want {
            s = s.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let c = (s >> 33) as usize % n;
            if used.insert((r, c)) {
                m.push(r, c, ((s >> 9) % 5 + 1) as f64);
            }
        }
    }
    m
}

#[test]
fn sell_sigma_lane_kernels_are_bit_identical_everywhere() {
    // Bit-identity holds on *both* implementations (the AVX2 path only
    // vectorizes the exactly-rounded multiplies), on continuous
    // mixed-sign data — no integer crutch needed.
    for (s, sigma) in [(8usize, 16usize), (8, 32), (16, 64)] {
        let m = gen::powerlaw(90, 2.0, 30, 43);
        let a = SellSigma::from_tuples(&m, s, sigma);
        let x: Vec<f64> = (0..90).map(|i| (i as f64 * 0.21).cos() - 0.3).collect();
        let mut y0 = vec![0.0; 90];
        simd::sell_sigma_spmv(&a, &x, &mut y0, 1); // lanes=1 → serial kernel
        for lanes in [4usize, 8] {
            if s % lanes != 0 {
                continue; // lane_legal's own gate
            }
            let mut y = vec![f64::NAN; 90];
            simd::sell_sigma_spmv(&a, &x, &mut y, lanes);
            assert_eq!(y, y0, "s={s} sigma={sigma} lanes={lanes}");
        }
    }
}

#[test]
fn spmm_lane_micro_kernel_matches_serial() {
    let m = gen::uniform_random(45, 38, 500, 59);
    let a = Csr::from_tuples(&m);
    for k in [5usize, 8, 12] {
        let b: Vec<f64> = (0..38 * k).map(|i| (i as f64 * 0.043).sin() - 0.2).collect();
        let mut c0 = vec![0.0; 45 * k];
        spmm::csr(&a, &b, k, &mut c0);
        for lanes in [4usize, 8] {
            let mut c = vec![f64::NAN; 45 * k];
            simd::csr_spmm(&a, &b, k, &mut c, lanes);
            if simd::avx2_active() {
                for (g, w) in c.iter().zip(&c0) {
                    assert!((g - w).abs() <= 1e-12 * w.abs().max(1.0), "k={k}: {g} vs {w}");
                }
            } else {
                assert_eq!(c, c0, "element-wise axpy is bit-identical at k={k} lanes={lanes}");
            }
        }
    }
}

#[test]
fn prop_lane_kernels_match_serial_on_random_reservoirs() {
    forall("lane SpMV ≡ serial", 40, |g: &mut Gen| {
        let nrows = g.usize_in(3, 40 + g.size * 8);
        let ncols = g.usize_in(3, 40 + g.size * 8);
        let nnz = g.usize_in(1, (nrows * ncols).min(60 + g.size * 60));
        let m = gen::uniform_random(nrows, ncols, nnz, 1000 + g.size as u64);
        let a = Csr::from_tuples(&m);
        let x = g.vec_f64(ncols);
        let mut y0 = vec![0.0; nrows];
        spmv::csr(&a, &x, &mut y0);
        let lanes = *g.choose(&[4usize, 8]);
        let mut y = vec![f64::NAN; nrows];
        simd::csr_spmv(&a, &x, &mut y, lanes);
        for (i, (got, want)) in y.iter().zip(&y0).enumerate() {
            let tol = 1e-12 * want.abs().max(1.0);
            if (got - want).abs() > tol {
                return Err(format!("row {i} lanes {lanes}: {got} vs {want}"));
            }
        }
        Ok(())
    });
}

#[test]
fn engine_serves_wide_plans_end_to_end() {
    let m = gen::uniform_random(70, 70, 900, 77);
    let e = Engine::builder().arch(Arch::HostLarge).profile(false).archive(false).build();
    // The HostLarge pool carries the vector-width axis…
    let pool = e.plans(Kernel::Spmv);
    assert!(pool.iter().any(|p| p.id.ends_with(".v8")), "no wide plans in the pool");
    assert!(pool.iter().any(|p| p.exec.lanes == 4));
    // …and a pinned wide compile executes correctly through the lane
    // routing (serial and SELL-σ slice-plane alike).
    let x: Vec<f64> = (0..70).map(|i| (i as f64 * 0.13).sin() + 0.2).collect();
    let want = m.spmv_ref(&x);
    for id in ["csr.row.serial.v8", "sell32s256.slice.serial.v4"] {
        let exe = e.compile_pinned(Kernel::Spmv, &m, id).expect("wide plan pinnable");
        assert_eq!(exe.plan().id, id);
        assert!(exe.plan().exec.lanes > 1);
        let mut y = vec![0.0; 70];
        exe.spmv(&x, &mut y);
        forelem::util::prop::assert_close(&y, &want, 1e-10)
            .unwrap_or_else(|err| panic!("{id}: {err}"));
        // The inspectable artifact advertises the width.
        assert!(exe.codegen().contains("vectorize v"), "{id} codegen lacks the lane note");
    }
}
