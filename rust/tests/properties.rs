//! Property-based tests (util::prop) on the framework's invariants:
//! transformation chains preserve the iterated tuple multiset; storage
//! round-trips are lossless; generated routines are order-insensitive;
//! the coverage metric behaves monotonically.

use forelem::baselines::Kernel;
use forelem::concretize;
use forelem::matrix::TriMat;
use forelem::search::coverage::{self, Measurements};
use forelem::search::plan::PlanSpace;
use forelem::search::tree;
use forelem::util::prop::{assert_close, forall, Gen};

/// A random reservoir of tuples with no duplicate coordinates.
fn random_trimat(g: &mut Gen) -> TriMat {
    let nrows = g.usize_in(3, 12 + g.size * 4);
    let ncols = g.usize_in(3, 12 + g.size * 4);
    let nnz = g.usize_in(1, (nrows * ncols).min(10 + g.size * 20));
    let mut m = TriMat::new(nrows, ncols);
    let mut used = std::collections::HashSet::new();
    for _ in 0..nnz {
        let r = g.usize_in(0, nrows - 1);
        let c = g.usize_in(0, ncols - 1);
        if used.insert((r, c)) {
            let v = g.f64_in(0.1, 4.0) * if g.bool() { 1.0 } else { -1.0 };
            m.push(r, c, v);
        }
    }
    m
}

#[test]
fn prop_every_variant_preserves_spmv_semantics() {
    let t = tree::enumerate(Kernel::Spmv, &PlanSpace::serial_only());
    forall("variant ≡ oracle", 40, |g| {
        let m = random_trimat(g);
        let x = g.vec_f64(m.ncols);
        let want = m.spmv_ref(&x);
        // pick a random variant each case (all covered over the run)
        let v = g.choose(&t.plans);
        let p = concretize::prepare(v.exec, &m);
        let mut y = vec![0.0; m.nrows];
        p.spmv(&x, &mut y);
        assert_close(&y, &want, 1e-9).map_err(|e| format!("{}: {e}", v.id))
    });
}

#[test]
fn prop_storage_preserves_tuple_multiset() {
    // Rebuilding the dense expansion from every concretized storage must
    // equal the reservoir's dense expansion — i.e. no tuple is lost,
    // duplicated or reassigned by any generated layout.
    let t = tree::enumerate(Kernel::Spmv, &PlanSpace::serial_only());
    forall("storage lossless", 30, |g| {
        let m = random_trimat(g);
        let dense = m.to_dense();
        let v = g.choose(&t.plans);
        let p = concretize::prepare(v.exec, &m);
        // probe: SpMV against unit vectors reconstructs columns
        for j in 0..m.ncols.min(6) {
            let mut e = vec![0.0; m.ncols];
            e[j] = 1.0;
            let mut y = vec![0.0; m.nrows];
            p.spmv(&e, &mut y);
            for i in 0..m.nrows {
                let want = dense[i * m.ncols + j];
                if (y[i] - want).abs() > 1e-9 * want.abs().max(1.0) {
                    return Err(format!("{}: column {j} row {i}: {} vs {want}", v.id, y[i]));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_spmv_insensitive_to_reservoir_order() {
    let t = tree::enumerate(Kernel::Spmv, &PlanSpace::serial_only());
    forall("order-insensitive", 25, |g| {
        let mut m = random_trimat(g);
        let x = g.vec_f64(m.ncols);
        let v = g.choose(&t.plans);
        let p1 = concretize::prepare(v.exec, &m);
        let mut y1 = vec![0.0; m.nrows];
        p1.spmv(&x, &mut y1);
        // shuffle the reservoir (iteration order is explicitly undefined)
        let mut rng = forelem::util::rng::Rng::new(g.usize_in(0, 1 << 30) as u64);
        m.shuffle(&mut rng);
        let p2 = concretize::prepare(v.exec, &m);
        let mut y2 = vec![0.0; m.nrows];
        p2.spmv(&x, &mut y2);
        assert_close(&y1, &y2, 1e-9).map_err(|e| format!("{}: {e}", v.id))
    });
}

/// Adversarial shapes for the schedule axis: empty rows, 1×N, a single
/// dense row hogging all the nnz, and fewer rows than workers.
fn adversarial_shapes() -> Vec<(&'static str, TriMat)> {
    let mut empty_rows = TriMat::new(10, 10);
    empty_rows.push(0, 9, 2.0);
    empty_rows.push(9, 0, -3.0);

    let mut one_by_n = TriMat::new(1, 40);
    for j in (0..40).step_by(3) {
        one_by_n.push(0, j, j as f64 * 0.25 + 1.0);
    }

    let mut dense_row = TriMat::new(9, 25);
    for j in 0..25 {
        dense_row.push(4, j, (j as f64 - 12.0) * 0.3);
    }
    dense_row.push(0, 0, 1.0);
    dense_row.push(8, 24, -1.0);

    let mut tiny = TriMat::new(3, 5); // nrows < threads
    tiny.push(0, 1, 0.5);
    tiny.push(1, 4, 1.5);
    tiny.push(2, 0, -2.5);

    let all_empty = TriMat::new(6, 6); // zero nnz

    vec![
        ("empty-rows", empty_rows),
        ("1xN", one_by_n),
        ("dense-row-hog", dense_row),
        ("nrows<threads", tiny),
        ("all-empty", all_empty),
    ]
}

#[test]
fn prop_every_schedule_triple_matches_spmv_oracle() {
    // Every (layout, traversal, schedule) triple in the host pool must
    // match spmv_ref on the adversarial shapes. x_block is small so the
    // band path actually splits these column counts.
    let t = tree::enumerate(Kernel::Spmv, &PlanSpace::host(4, 8));
    assert!(t.plans.iter().any(|v| !v.exec.schedule.is_serial()));
    for (name, m) in adversarial_shapes() {
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.31).sin() + 0.6).collect();
        let want = m.spmv_ref(&x);
        for v in &t.plans {
            let p = concretize::prepare(v.exec, &m);
            let mut y = vec![0.0; m.nrows];
            p.spmv(&x, &mut y);
            assert_close(&y, &want, 1e-9)
                .unwrap_or_else(|e| panic!("{name}/{} ({}): {e}", v.id, v.name()));
        }
    }
}

#[test]
fn prop_every_schedule_triple_matches_spmm_oracle() {
    let t = tree::enumerate(Kernel::Spmm, &PlanSpace::host(4, 8));
    assert!(t.plans.iter().any(|v| !v.exec.schedule.is_serial()));
    let k = 5;
    for (name, m) in adversarial_shapes() {
        let b: Vec<f64> = (0..m.ncols * k).map(|i| ((i * 7 % 13) as f64 - 6.0) * 0.2).collect();
        let want = m.spmm_ref(&b, k);
        for v in &t.plans {
            let p = concretize::prepare(v.exec, &m);
            let mut c = vec![0.0; m.nrows * k];
            p.spmm(&b, k, &mut c);
            assert_close(&c, &want, 1e-9)
                .unwrap_or_else(|e| panic!("{name}/{} ({}): {e}", v.id, v.name()));
        }
    }
}

#[test]
fn prop_random_schedules_match_oracle() {
    // Random matrices × random schedule variants (threads beyond the
    // machine, tiny x_blocks) still agree with the oracle.
    let t = tree::enumerate(Kernel::Spmv, &PlanSpace::host(3, 16));
    forall("scheduled variant ≡ oracle", 40, |g| {
        let m = random_trimat(g);
        let x = g.vec_f64(m.ncols);
        let want = m.spmv_ref(&x);
        let v = g.choose(&t.plans);
        let p = concretize::prepare(v.exec, &m);
        let mut y = vec![0.0; m.nrows];
        p.spmv(&x, &mut y);
        assert_close(&y, &want, 1e-9).map_err(|e| format!("{} ({}): {e}", v.id, v.name()))
    });
}

#[test]
fn prop_trsv_solves_system() {
    let t = tree::enumerate(Kernel::Trsv, &PlanSpace::serial_only());
    forall("(I+L)x = b", 25, |g| {
        let n = g.usize_in(2, 30 + g.size * 3);
        let mut sq = TriMat::new(n, n);
        let mut used = std::collections::HashSet::new();
        for _ in 0..g.usize_in(0, n * 3) {
            let r = g.usize_in(1, n - 1);
            let c = g.usize_in(0, r - 1);
            if used.insert((r, c)) {
                sq.push(r, c, g.f64_in(-1.0, 1.0));
            }
        }
        let b = g.vec_f64(n);
        let v = g.choose(&t.plans);
        let p = concretize::prepare(v.exec, &sq);
        let mut x = vec![0.0; n];
        p.trsv(&b, &mut x);
        // verify (I + L) x == b
        let lx = sq.spmv_ref(&x);
        let back: Vec<f64> = (0..n).map(|i| x[i] + lx[i]).collect();
        assert_close(&back, &b, 1e-7).map_err(|e| format!("{}: {e}", v.id))
    });
}

/// A random strictly-lower triangular reservoir (unit diagonal implied).
fn random_lower(g: &mut Gen) -> TriMat {
    let n = g.usize_in(2, 30 + g.size * 3);
    let mut sq = TriMat::new(n, n);
    let mut used = std::collections::HashSet::new();
    for _ in 0..g.usize_in(0, n * 3) {
        let r = g.usize_in(1, n - 1);
        let c = g.usize_in(0, r - 1);
        if used.insert((r, c)) {
            sq.push(r, c, g.f64_in(-1.0, 1.0));
        }
    }
    sq
}

/// Adversarial triangular shapes for the level schedule: a dense row
/// depending on everything, a single serial dependency chain, a wide
/// independent level, and an empty (identity) system.
fn adversarial_triangles() -> Vec<(&'static str, TriMat)> {
    let mut dense_row = TriMat::new(16, 16);
    for j in 0..15 {
        dense_row.push(15, j, (j as f64 - 7.0) * 0.21);
    }
    dense_row.push(4, 2, 0.9);
    dense_row.push(9, 4, -0.6);

    let mut chain = TriMat::new(24, 24);
    for i in 1..24 {
        chain.push(i, i - 1, if i % 2 == 0 { 0.8 } else { -0.7 });
    }

    let mut wide = TriMat::new(20, 20);
    for i in 1..20 {
        wide.push(i, 0, i as f64 * 0.05);
    }

    vec![
        ("dense-row", dense_row),
        ("single-chain", chain),
        ("wide-level", wide),
        ("identity", TriMat::new(7, 7)),
    ]
}

#[test]
fn prop_level_trsv_equals_serial_on_adversarial_triangles() {
    // Every non-serial TrSv plan in the host pool must agree with its
    // serial counterpart on the adversarial shapes, for any thread
    // count.
    let t = tree::enumerate(Kernel::Trsv, &PlanSpace::host(4, 1024));
    let par_plans: Vec<_> = t.plans.iter().filter(|v| !v.exec.schedule.is_serial()).collect();
    assert_eq!(par_plans.len(), 2, "expected csr+csc level plans");
    for (name, l) in adversarial_triangles() {
        let b: Vec<f64> = (0..l.nrows).map(|i| (i as f64 * 0.29).cos() + 0.4).collect();
        let want = l.trsv_unit_lower_ref(&b);
        for v in &par_plans {
            let serial = concretize::prepare(
                forelem::concretize::Plan::serial(v.exec.layout, v.exec.traversal),
                &l,
            );
            let mut x_serial = vec![0.0; l.nrows];
            serial.trsv(&b, &mut x_serial);
            assert_close(&x_serial, &want, 1e-9).unwrap();

            let p = concretize::prepare(v.exec, &l);
            p.ensure_levels();
            let mut x = vec![0.0; l.nrows];
            p.trsv(&b, &mut x);
            assert_close(&x, &x_serial, 1e-9)
                .unwrap_or_else(|e| panic!("{name}/{}: level ≠ serial: {e}", v.id));
        }
    }
}

#[test]
fn prop_level_trsv_solves_random_triangles() {
    let t = tree::enumerate(Kernel::Trsv, &PlanSpace::host(3, 512));
    assert!(t.plans.iter().any(|v| !v.exec.schedule.is_serial()));
    forall("level TrSv ≡ oracle", 30, |g| {
        let sq = random_lower(g);
        let b = g.vec_f64(sq.nrows);
        let want = sq.trsv_unit_lower_ref(&b);
        let v = g.choose(&t.plans);
        let p = concretize::prepare(v.exec, &sq);
        let mut x = vec![0.0; sq.nrows];
        p.trsv(&b, &mut x);
        assert_close(&x, &want, 1e-7).map_err(|e| format!("{}: {e}", v.id))
    });
}

#[test]
fn prop_storage_cache_is_transparent() {
    // prepare_many Arc-shares one storage per distinct layout; the
    // shared executors must return bit-identical results to fresh
    // per-plan prepares for every (plan, kernel) in the pool.
    let t = tree::enumerate(Kernel::Spmv, &PlanSpace::host(3, 16));
    let execs: Vec<forelem::concretize::Plan> = t.plans.iter().map(|p| p.exec).collect();
    let m = {
        let mut g = Gen { rng: forelem::util::rng::Rng::new(0xCAFE), size: 3 };
        random_trimat(&mut g)
    };
    let (shared, builds) = concretize::prepare_many_counted(&execs, &m, 4);
    let distinct: std::collections::HashSet<String> =
        t.plans.iter().map(|p| format!("{:?}", p.exec.layout)).collect();
    assert_eq!(builds, distinct.len(), "cache built storages more than once");
    let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.41).sin() - 0.1).collect();
    for (exec, p) in execs.iter().zip(&shared) {
        let fresh = concretize::prepare(*exec, &m);
        let mut y_shared = vec![0.0; m.nrows];
        let mut y_fresh = vec![0.0; m.nrows];
        p.spmv(&x, &mut y_shared);
        fresh.spmv(&x, &mut y_fresh);
        assert_eq!(y_shared, y_fresh, "{exec:?}: cache changed SpMV bits");
    }
}

#[test]
fn prop_coverage_monotone_and_bounded() {
    forall("coverage monotone in t", 30, |g| {
        let nr = g.usize_in(2, 6);
        let nm = g.usize_in(2, 8);
        let mut meas = Measurements::new(
            (0..nr).map(|i| format!("r{i}")).collect(),
            (0..nm).map(|i| format!("m{i}")).collect(),
        );
        for r in 0..nr {
            for m in 0..nm {
                meas.set(r, m, g.f64_in(0.1, 10.0));
            }
        }
        let best = meas.best_per_matrix(None);
        let mut prev = 0.0;
        for t in [0.0, 5.0, 10.0, 25.0, 50.0, 100.0] {
            let c = coverage::coverage(&meas, &best, None, t);
            if c < prev - 1e-12 {
                return Err(format!("coverage decreased: {prev} -> {c} at t={t}"));
            }
            if !(0.0..=1.0).contains(&c) {
                return Err(format!("coverage out of range: {c}"));
            }
            prev = c;
        }
        // at t=0 someone is optimal on at least one matrix
        let c0 = coverage::coverage(&meas, &best, None, 0.0);
        if c0 <= 0.0 {
            return Err("no routine optimal anywhere".into());
        }
        Ok(())
    });
}

#[test]
fn prop_transform_chains_never_panic() {
    // Random step sequences either apply cleanly or report Illegal —
    // never panic, never corrupt the state.
    use forelem::forelem::ir::{ChainState, NStarMat, Orth};
    use forelem::transforms::{BlockStep, Step};
    let universe = [
        Step::Orthogonalize(Orth::Row),
        Step::Orthogonalize(Orth::Col),
        Step::Orthogonalize(Orth::RowCol),
        Step::Orthogonalize(Orth::Diag),
        Step::Localize,
        Step::Hisr,
        Step::Materialize,
        Step::Split,
        Step::NStar(NStarMat::Padded),
        Step::NStar(NStarMat::Exact),
        Step::NStarSort,
        Step::Interchange,
        Step::DimReduce,
        Step::Block(BlockStep::Tile2x2),
        Step::Block(BlockStep::FillCutoff),
    ];
    forall("random chains safe", 200, |g| {
        let kernel = *g.choose(&[Kernel::Spmv, Kernel::Spmm, Kernel::Trsv]);
        let mut s = ChainState::initial(kernel);
        let len = g.usize_in(0, 10);
        for _ in 0..len {
            let step = *g.choose(&universe);
            let _ = step.apply(&mut s); // Ok or Illegal, both fine
        }
        // state must remain internally consistent: history length ≥ flags set
        let flags = [s.split, s.sorted, s.interchanged, s.dim_reduced, s.hisr]
            .iter()
            .filter(|&&b| b)
            .count();
        if s.history.len() < flags {
            return Err(format!("history {} < flags {flags}", s.history.len()));
        }
        Ok(())
    });
}
