//! Integration suite for the request-batching path (`engine::batch`)
//! through the public API only: `Engine::batch_queue` +
//! `BatchQueue::submit`.
//!
//! The contract under test is the one DESIGN.md §"Request batching"
//! states: a submit answered through the queue — solo fast path,
//! deadline-sealed partial group, or full panel — is **bit-identical**
//! to running the queue's own solo SpMV plan on the same vector, and
//! the monotonic counters account for every request exactly once.
//! (The deterministic deadline-flush timing proof and the poisoning
//! drill live next to the implementation: the engine unit tests reach
//! the private queue state, and `forelem chaos` arms `batch.flush`.)

use std::sync::{Arc, Barrier};
use std::time::Duration;

use forelem::engine::batch::BatchQueue;
use forelem::engine::Engine;
use forelem::matrix::{gen, TriMat};
use forelem::{Arch, Kernel};

fn engine(arch: Arch, max_batch: usize, deadline_us: u64) -> Engine {
    Engine::builder()
        .arch(arch)
        .profile(false)
        .archive(false)
        .max_batch(max_batch)
        .flush_deadline(Duration::from_micros(deadline_us))
        .build()
}

/// Per-matrix reference outputs computed with the exact solo plan the
/// queue selected, then bit-compared against concurrent submits.
fn expected(e: &Engine, q: &BatchQueue, m: &TriMat, xs: &[Vec<f64>]) -> Vec<Vec<u64>> {
    let solo = e.compile_pinned(Kernel::Spmv, m, q.solo_plan_id()).expect("pin solo plan");
    let mut y = vec![0.0; m.nrows];
    xs.iter()
        .map(|x| {
            solo.spmv(x, &mut y);
            y.iter().map(|v| v.to_bits()).collect()
        })
        .collect()
}

fn vectors(ncols: usize, n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = forelem::util::rng::Rng::new(seed);
    (0..n).map(|_| (0..ncols).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect()).collect()
}

/// `threads` clients hammer one queue in barrier-aligned rounds;
/// every answer must carry the solo plan's exact bits.
fn hammer(q: &Arc<BatchQueue>, xs: &[Vec<f64>], want: &[Vec<u64>], threads: usize, rounds: usize) {
    let barrier = Barrier::new(threads);
    std::thread::scope(|s| {
        for t in 0..threads {
            let barrier = &barrier;
            let q = Arc::clone(q);
            s.spawn(move || {
                for r in 0..rounds {
                    barrier.wait();
                    let i = (t + r) % xs.len();
                    let y = q.submit(&xs[i]);
                    let got: Vec<u64> = y.iter().map(|v| v.to_bits()).collect();
                    assert_eq!(got, want[i], "thread {t} round {r}: bits diverged");
                }
            });
        }
    });
}

fn check_accounting(q: &BatchQueue, submitted: u64) {
    let st = q.stats();
    assert_eq!(st.submitted, submitted, "every submit counted");
    assert_eq!(st.batched + st.solo, st.submitted, "each request is batched xor solo");
    let by_hist: u64 = st.hist.iter().enumerate().map(|(k, &n)| k as u64 * n).sum();
    assert_eq!(by_hist, st.submitted, "histogram accounts every request");
    assert_eq!(st.deadline_flushes + st.full_flushes, st.flushes, "every flush has a seal cause");
    assert_eq!(st.poisoned_batches, 0, "no faults armed in this suite");
}

#[test]
fn concurrent_batched_serving_is_bit_identical_on_both_archs() {
    let mats = [
        gen::uniform_random(800, 700, 6_000, 41),
        gen::banded(600, 6, 0.9, 42),
        gen::powerlaw(500, 2.0, 32, 43),
    ];
    for arch in [Arch::HostSmall, Arch::HostLarge] {
        let e = engine(arch, 8, 150);
        for m in &mats {
            let q = e.batch_queue(m).expect("valid matrix");
            let xs = vectors(m.ncols, 4, 7 ^ m.fingerprint());
            let want = expected(&e, &q, m, &xs);
            let (threads, rounds) = (8, 20);
            hammer(&q, &xs, &want, threads, rounds);
            check_accounting(&q, (threads * rounds) as u64);
        }
    }
}

#[test]
fn max_batch_one_queue_always_falls_through_to_solo() {
    let e = engine(Arch::HostSmall, 1, 150);
    let m = gen::uniform_random(400, 350, 3_000, 44);
    let q = e.batch_queue(&m).expect("valid matrix");
    assert_eq!(q.min_k_pays(), None, "k=1 capacity can never pay for a panel");
    let xs = vectors(m.ncols, 3, 11);
    let want = expected(&e, &q, &m, &xs);
    let (threads, rounds) = (4, 25);
    hammer(&q, &xs, &want, threads, rounds);
    let st = q.stats();
    assert_eq!(st.solo, st.submitted, "every request served by the solo fast path");
    assert_eq!(st.batched, 0);
    assert_eq!(st.flushes, 0, "no groups ever form at capacity 1");
    assert_eq!(st.hist[1], st.submitted);
    check_accounting(&q, (threads * rounds) as u64);
}

#[test]
fn oversized_capacity_seals_partial_groups_by_deadline_only() {
    // 6 clients can never fill a 64-slot batch, so *every* flush that
    // occurs must have been sealed by the deadline — and its partial
    // panel must still return exact solo bits.
    let e = engine(Arch::HostSmall, 64, 300);
    let m = gen::banded(2_000, 14, 1.0, 45);
    let q = e.batch_queue(&m).expect("valid matrix");
    let xs = vectors(m.ncols, 4, 13);
    let want = expected(&e, &q, &m, &xs);
    let (threads, rounds) = (6, 30);
    hammer(&q, &xs, &want, threads, rounds);
    let st = q.stats();
    assert_eq!(st.full_flushes, 0, "a 6-client load cannot fill 64 slots");
    assert_eq!(st.deadline_flushes, st.flushes, "partial groups seal by deadline");
    check_accounting(&q, (threads * rounds) as u64);
}

#[test]
fn queue_registry_is_shared_per_fingerprint() {
    let e = engine(Arch::HostSmall, 8, 150);
    let a = gen::uniform_random(300, 300, 2_000, 46);
    let b = gen::uniform_random(300, 300, 2_000, 47);
    let qa1 = e.batch_queue(&a).expect("valid matrix");
    let qa2 = e.batch_queue(&a.clone()).expect("same fingerprint");
    let qb = e.batch_queue(&b).expect("valid matrix");
    assert!(Arc::ptr_eq(&qa1, &qa2), "one queue per (fingerprint, engine)");
    assert!(!Arc::ptr_eq(&qa1, &qb), "distinct matrices get distinct queues");
}
