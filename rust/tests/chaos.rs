//! The chaos suite: runs the full fault-injection drill —
//! every registered fault point armed with an io-error, a panic, and a
//! delay — and asserts the hardened serving path holds its contract at
//! each one: no deadlock, no abort, the compile lands on the expected
//! degradation-ladder rung, and the served SpMV stays bit-identical to
//! a direct prepare of the winning plan (the serial CSR reference on
//! the bottom rung). Only compiled under `--features chaos`; the
//! default build carries no injection points to drill.
//!
//! The drill mutates process-global state (`FORELEM_TUNING_DIR`, the
//! compile cache, the quarantine), so it lives alone in this
//! integration binary rather than inside the lib tests.
#![cfg(feature = "chaos")]

use forelem::chaos::{drill, POINTS};

#[test]
fn every_fault_point_degrades_instead_of_failing() {
    let outcomes = drill::run_all();
    // Three fault classes per registered point, none skipped.
    assert_eq!(
        outcomes.len(),
        POINTS.len() * 3,
        "drill must cover every point x {{io-error, panic, delay}}"
    );
    let failures: Vec<String> = outcomes
        .iter()
        .filter(|o| !o.ok)
        .map(|o| format!("{} x {}: {}", o.point, o.fault, o.detail))
        .collect();
    assert!(failures.is_empty(), "chaos drill failures:\n  {}", failures.join("\n  "));
}
