//! Integration contract of `forelem::engine` — the compile-and-serve
//! facade must be a *pure re-packaging* of the legacy pipeline: same
//! numerics bit-for-bit, same plan selection as the sweep's predicted
//! ranking, and a serving cache that shares storage instead of
//! rebuilding it.

use std::sync::Arc;

use forelem::concretize;
use forelem::coordinator::sweep::{self, SweepConfig};
use forelem::engine::{Arch, Autotune, Engine, Kernel};
use forelem::matrix::suite::{SuiteEntry, SUITE};

/// The quick-suite matrices (`SweepConfig::quick()`'s subset).
fn quick_entries() -> Vec<&'static SuiteEntry> {
    vec![&SUITE[0], &SUITE[2], &SUITE[7]]
}

fn hermetic(arch: Arch) -> Engine {
    Engine::builder().arch(arch).profile(false).archive(false).build()
}

/// The engine round-trip pin of the redesign: for every quick-suite
/// matrix and all three kernels, `Executable` output is bit-identical
/// to preparing the same plan through the legacy free-function path.
#[test]
fn executable_bit_identical_to_legacy_prepare_path() {
    let engine = hermetic(Arch::HostSmall);
    for e in quick_entries() {
        let built = e.build_scaled(1.0);
        for kernel in [Kernel::Spmv, Kernel::Spmm, Kernel::Trsv] {
            let m = if kernel == Kernel::Trsv { built.strictly_lower() } else { built.clone() };
            let exe = engine.compile(kernel, &m).expect("suite matrices are valid");
            let legacy = concretize::prepare(exe.plan().exec, &m);
            match kernel {
                Kernel::Spmv => {
                    let x: Vec<f64> =
                        (0..m.ncols).map(|i| (i as f64 * 0.017).sin() + 0.3).collect();
                    let mut ye = vec![0.0; m.nrows];
                    let mut yl = vec![0.0; m.nrows];
                    exe.spmv(&x, &mut ye);
                    legacy.spmv(&x, &mut yl);
                    assert_eq!(ye, yl, "{}: SpMV bits differ on {}", exe.plan().id, e.name);
                }
                Kernel::Spmm => {
                    let k = 8;
                    let b: Vec<f64> = (0..m.ncols * k).map(|i| i as f64 * 0.003 - 0.5).collect();
                    let mut ce = vec![0.0; m.nrows * k];
                    let mut cl = vec![0.0; m.nrows * k];
                    exe.spmm_k(&b, k, &mut ce);
                    legacy.spmm(&b, k, &mut cl);
                    assert_eq!(ce, cl, "{}: SpMM bits differ on {}", exe.plan().id, e.name);
                }
                Kernel::Trsv => {
                    let b: Vec<f64> = (0..m.nrows).map(|i| 1.0 - (i % 7) as f64 * 0.1).collect();
                    let mut xe = vec![0.0; m.nrows];
                    let mut xl = vec![0.0; m.nrows];
                    exe.trsv(&b, &mut xe);
                    legacy.trsv(&b, &mut xl);
                    assert_eq!(xe, xl, "{}: TrSv bits differ on {}", exe.plan().id, e.name);
                }
            }
        }
    }
}

/// The serving path: repeated compiles of the same reservoir return
/// `Arc::ptr_eq` storages (the plan + storage cache), across both the
/// same engine and a second engine with the same configuration.
#[test]
fn repeated_compiles_return_ptr_eq_storage() {
    let m = SUITE[2].build_scaled(1.0);
    let engine = hermetic(Arch::HostSmall);
    let first = engine.compile(Kernel::Spmv, &m).expect("suite matrices are valid");
    let second = engine.compile(Kernel::Spmv, &m).expect("suite matrices are valid");
    assert!(
        Arc::ptr_eq(&first.storage(), &second.storage()),
        "same engine must serve the cached storage"
    );
    assert_eq!(first.plan().id, second.plan().id);
    assert_eq!(first.bytes(), second.bytes());
    // The cache is process-wide: a second engine with an identical
    // configuration hits the same entry.
    let other = hermetic(Arch::HostSmall);
    let third = other.compile(Kernel::Spmv, &m).expect("suite matrices are valid");
    assert!(
        Arc::ptr_eq(&first.storage(), &third.storage()),
        "identically-configured engines must share the process-wide cache"
    );
    // A different kernel on the same matrix is its own entry (the
    // winning plan may coincide; the compile must still be cached
    // separately and stay correct).
    let spmm = engine.compile(Kernel::Spmm, &m).expect("suite matrices are valid");
    let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.05).cos()).collect();
    let mut y = vec![0.0; m.nrows];
    first.spmv(&x, &mut y);
    forelem::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
    let k = 4;
    let b: Vec<f64> = (0..m.ncols * k).map(|i| i as f64 * 0.01).collect();
    let mut c = vec![0.0; m.nrows * k];
    spmm.spmm_k(&b, k, &mut c);
    forelem::util::prop::assert_close(&c, &m.spmm_ref(&b, k), 1e-10).unwrap();
}

/// `Autotune::TopK(0)` (predict-only) must pick exactly the plan the
/// sweep's predicted ranking puts first — the engine and the paper
/// pipeline share one planner.
#[test]
fn predict_only_engine_matches_sweep_predicted_best() {
    let cfg = SweepConfig::quick();
    let r = sweep::run(Kernel::Spmv, Arch::HostSmall, &cfg, None);
    let engine = Engine::builder()
        .arch(Arch::HostSmall)
        .profile(false)
        .archive(false)
        .autotune(Autotune::TopK(0))
        .spmm_k(cfg.spmm_k)
        .build();
    for (mi, entry) in quick_entries().into_iter().enumerate() {
        assert_eq!(entry.name, r.gens.matrices[mi], "suite subset drifted");
        let m = entry.build_scaled(1.0);
        let exe = engine.compile(Kernel::Spmv, &m).expect("suite matrices are valid");
        let best = r.predicted_best(mi);
        let pick = r
            .plans
            .iter()
            .position(|p| p.id == exe.plan().id)
            .expect("engine pick must come from the sweep's pool");
        if pick != best {
            // `predicted_best` (Iterator::min_by) resolves exact float
            // ties toward the last index, the engine (like the sweep's
            // shortlist ordering) toward the first — divergence is
            // only acceptable on an exact predicted tie.
            assert_eq!(
                r.predicted[pick][mi],
                r.predicted[best][mi],
                "engine pick {} diverged from SweepResult::predicted_best {} on {}",
                exe.plan().id,
                r.plans[best].id,
                entry.name
            );
        }
        assert!(exe.measured_secs().is_none(), "TopK(0) must not measure");
    }
}

/// The scheduled space works end to end through the engine too
/// (HostLarge adds the parallel/tiled plans; results stay correct
/// whichever schedule wins).
#[test]
fn scheduled_engine_compiles_and_serves_correctly() {
    let m = SUITE[0].build_scaled(1.0);
    let engine = hermetic(Arch::HostLarge);
    let exe = engine.compile(Kernel::Spmv, &m).expect("suite matrices are valid");
    let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.11).sin()).collect();
    let mut y = vec![0.0; m.nrows];
    exe.spmv(&x, &mut y);
    forelem::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
    // The explain surface stays coherent under scheduling.
    let ex = exe.explain();
    assert_eq!(ex.plan_id, exe.plan().id);
    assert!(ex.predicted_secs > 0.0);
    assert!(!ex.to_string().is_empty());
}
