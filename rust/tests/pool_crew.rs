//! Crew serving-path integration suite: the persistent worker crew
//! must serve the parallel kernels bit-identically across repeated
//! reuse, spawn zero threads once warm, and match the spawn-per-call
//! executor it replaced. The tiny-budget eviction test lives here —
//! in its own process — because the compile cache (and its
//! last-writer-wins budget) is process-global: churning it under a
//! 1-byte budget inside the lib tests would race their Arc-sharing
//! assertions.

use forelem::concretize::{self, prepare, Layout, Plan, Schedule, Traversal};
use forelem::engine::{Autotune, Engine};
use forelem::matrix::gen;
use forelem::storage::{CooOrder, EllOrder};
use forelem::util::pool;
use forelem::util::prop::assert_close;
use forelem::{Arch, Kernel};
use std::sync::Arc;

fn base_plans() -> Vec<Plan> {
    vec![
        Plan::serial(Layout::CooAos(CooOrder::Unsorted), Traversal::Flat),
        Plan::serial(Layout::CooSoa(CooOrder::RowMajor), Traversal::Flat),
        Plan::serial(Layout::Csr, Traversal::RowWise),
        Plan::serial(Layout::CsrAos, Traversal::RowWise),
        Plan::serial(Layout::Csc, Traversal::ColScatter),
        Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWise),
        Plan::serial(Layout::Ell(EllOrder::ColMajor), Traversal::PlaneWise),
        Plan::serial(Layout::Jds { permuted: true }, Traversal::DiagMajor),
        Plan::serial(Layout::Bcsr { br: 2, bc: 3 }, Traversal::Blocked),
        Plan::serial(Layout::SellSigma { s: 8, sigma: 64 }, Traversal::SlicePlane),
    ]
}

/// Every parallel SpMV plan, executed on the crew: repeated calls on
/// one `Prepared` and calls on a fresh `Prepared` of the same plan
/// must agree bit-for-bit (crew dispatch is deterministic — task `i`
/// always lands on worker `i % crew`), and the numbers must match the
/// serial reference.
#[test]
fn crew_parallel_spmv_is_bit_stable_across_reuse() {
    let m = gen::powerlaw(64, 2.0, 24, 81);
    let x: Vec<f64> = (0..64).map(|i| (i as f64 * 0.17).sin() + 0.4).collect();
    let want = m.spmv_ref(&x);
    let mut ran = 0;
    for base in base_plans() {
        let plan = base.with_schedule(Schedule::Parallel { threads: 3 });
        if !concretize::supports(&plan, Kernel::Spmv) {
            continue;
        }
        ran += 1;
        let p = prepare(plan, &m);
        let mut first = vec![0.0; 64];
        p.spmv(&x, &mut first);
        for rep in 0..4 {
            let mut y = vec![0.0; 64];
            p.spmv(&x, &mut y);
            assert_eq!(y, first, "{plan:?}: reuse #{rep} drifted on the crew");
        }
        let fresh = prepare(plan, &m);
        let mut y2 = vec![0.0; 64];
        fresh.spmv(&x, &mut y2);
        assert_eq!(y2, first, "{plan:?}: fresh prepare disagrees with reused one");
        assert_close(&first, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
    }
    assert!(ran >= 4, "too few parallel SpMV plans exercised: {ran}");
}

/// Parallel SpMM and the level-scheduled parallel TrSv under the same
/// reuse contract.
#[test]
fn crew_parallel_spmm_and_trsv_are_bit_stable() {
    let m = gen::uniform_random(48, 52, 420, 83);
    let k = 5;
    let b: Vec<f64> = (0..52 * k).map(|i| i as f64 * 0.04 - 1.1).collect();
    let want_c = m.spmm_ref(&b, k);
    let mut spmm_ran = 0;
    for base in base_plans() {
        let plan = base.with_schedule(Schedule::Parallel { threads: 3 });
        if !concretize::supports(&plan, Kernel::Spmm) {
            continue;
        }
        spmm_ran += 1;
        let p = prepare(plan, &m);
        let mut first = vec![0.0; 48 * k];
        p.spmm(&b, k, &mut first);
        let mut again = vec![0.0; 48 * k];
        p.spmm(&b, k, &mut again);
        assert_eq!(again, first, "{plan:?}: SpMM reuse drifted on the crew");
        assert_close(&first, &want_c, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
    }
    assert!(spmm_ran >= 2, "too few parallel SpMM plans exercised: {spmm_ran}");

    let l = gen::uniform_random(40, 40, 300, 84).strictly_lower();
    let rhs: Vec<f64> = (0..40).map(|i| 1.0 - i as f64 * 0.02).collect();
    let want_x = l.trsv_unit_lower_ref(&rhs);
    let mut trsv_ran = 0;
    for base in base_plans() {
        let plan = base.with_schedule(Schedule::Parallel { threads: 4 });
        if !concretize::supports(&plan, Kernel::Trsv) {
            continue;
        }
        trsv_ran += 1;
        let p = prepare(plan, &l);
        let mut first = vec![0.0; 40];
        p.trsv(&rhs, &mut first);
        let mut again = vec![0.0; 40];
        p.trsv(&rhs, &mut again);
        assert_eq!(again, first, "{plan:?}: TrSv reuse drifted on the crew");
        assert_close(&first, &want_x, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
    }
    assert_eq!(trsv_ran, 2, "expected the CSR and CSC level-scheduled TrSv plans");
}

/// The crew executor and the spawn-per-call executor it replaced must
/// produce bit-identical results for the same chunked computation —
/// the kernels only changed *who* runs a range, never what the range
/// computes.
#[test]
fn crew_matches_spawning_executor_bit_for_bit() {
    let n = 7 * 61;
    let data: Vec<f64> = (0..n).map(|i| ((i as f64) * 0.37).sin() * 1e3 + 0.123).collect();
    let run = |crew: bool| {
        let mut acc = vec![0.0f64; 7];
        let chunk = n / 7;
        let mut tasks = Vec::with_capacity(7);
        for (c, slot) in acc.iter_mut().enumerate() {
            let piece = &data[c * chunk..(c + 1) * chunk];
            tasks.push(move || *slot = piece.iter().fold(0.0, |a, v| a * 1.0000001 + v));
        }
        if crew {
            pool::scoped_run(tasks);
        } else {
            pool::scoped_run_spawning(tasks);
        }
        acc
    };
    for _ in 0..3 {
        assert_eq!(run(true), run(false), "crew drifted from the spawning baseline");
    }
}

/// Once every worker has lazily spawned, repeated parallel kernel
/// invocations must spawn nothing — the serving-path invariant the
/// bench-json `pool` section and the CI planner guard also pin.
#[test]
fn warm_crew_serves_kernels_with_zero_spawns() {
    let nworkers = pool::crew_size();
    // Warm the whole crew (one task per worker), so concurrent tests
    // in this binary cannot spawn anyone mid-measurement either.
    let warm = {
        let mut hit = vec![false; nworkers.max(1)];
        let mut tasks = Vec::with_capacity(hit.len());
        for slot in hit.iter_mut() {
            tasks.push(move || *slot = true);
        }
        pool::scoped_run(tasks);
        hit
    };
    assert!(warm.iter().all(|&h| h), "warm batch lost a task");
    let m = gen::powerlaw(64, 2.0, 24, 85);
    let x: Vec<f64> = (0..64).map(|i| (i as f64 * 0.09).cos()).collect();
    let par3 = Schedule::Parallel { threads: 3 };
    let p = prepare(Plan::serial(Layout::Csr, Traversal::RowWise).with_schedule(par3), &m);
    let before = pool::crew_spawns();
    for _ in 0..20 {
        let mut y = vec![0.0; 64];
        p.spmv(&x, &mut y);
    }
    assert_eq!(pool::crew_spawns(), before, "a warm crew spawned threads on the serving path");
    assert_eq!(pool::crew_respawns(), 0, "no worker should ever die outside a chaos drill");
}

/// Engine-level cache behavior under a starvation budget: evictions
/// are counted and the resident set stays bounded, while a generous
/// budget keeps serving the same `Arc`-shared storage. Runs here, in
/// its own process, because the budget is process-global
/// (last-writer-wins).
#[test]
fn tiny_cache_budget_evicts_and_bounds_the_cache() {
    Engine::clear_cache();
    let engine = Engine::builder()
        .arch(Arch::HostLarge)
        .autotune(Autotune::Off)
        .profile(false)
        .archive(false)
        .build();
    let m = gen::uniform_random(40, 40, 300, 90);
    let a = engine.compile(Kernel::Spmv, &m).expect("compile");
    let b = engine.compile(Kernel::Spmv, &m).expect("recompile");
    assert!(
        Arc::ptr_eq(&a.storage(), &b.storage()),
        "generous budget must keep serving the cached storage"
    );
    // Served numerics match a direct prepare of the winning plan.
    let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.05).sin() + 0.2).collect();
    let mut served = vec![0.0; 40];
    let mut direct = vec![0.0; 40];
    a.spmv(&x, &mut served);
    concretize::prepare(a.plan().exec, &m).spmv(&x, &mut direct);
    assert_eq!(served, direct, "engine serving drifted from a direct prepare");

    let ev0 = Engine::cache_evictions();
    let starved = Engine::builder()
        .arch(Arch::HostLarge)
        .autotune(Autotune::Off)
        .profile(false)
        .archive(false)
        .cache_budget(1)
        .build();
    for seed in 0..4u64 {
        let mi = gen::uniform_random(32, 32, 200, 100 + seed);
        starved.compile(Kernel::Spmv, &mi).expect("starved compile");
        assert!(
            Engine::cache_len() <= 1,
            "a 1-byte budget must keep at most the newest entry resident"
        );
    }
    assert!(
        Engine::cache_evictions() >= ev0 + 3,
        "evicting inserts must advance the monotonic eviction counter"
    );
}
