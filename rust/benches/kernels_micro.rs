//! Micro-benchmarks of the generated kernels per format on one matrix
//! class — the profiling entry point for the L3 §Perf pass (DESIGN §7).
use forelem::baselines::Kernel;
use forelem::bench::harness::{black_box, time_fn, BenchConfig};
use forelem::concretize;
use forelem::matrix::suite;
use forelem::search::tree;

fn main() {
    let cfg = if std::env::var("FORELEM_QUICK").is_ok() {
        BenchConfig::quick()
    } else {
        BenchConfig::from_env()
    };
    let names = ["Erdos971", "blckhole", "consph", "Raj1", "net150"];
    let t = tree::enumerate(Kernel::Spmv);
    for name in names {
        let m = suite::by_name(name).unwrap().build();
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.01).sin()).collect();
        println!(
            "## {name}: n={} nnz={} maxrow={}",
            m.nrows,
            m.nnz(),
            m.max_row_nnz()
        );
        let mut rows: Vec<(String, f64, usize)> = Vec::new();
        for v in &t.variants {
            let p = concretize::prepare(v.plan, &m);
            let mut y = vec![0.0; m.nrows];
            let s = time_fn(&cfg, || {
                p.spmv(&x, &mut y);
                black_box(&y);
            });
            rows.push((format!("{} {}", v.id, v.name()), s.median, p.storage.bytes()));
        }
        rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        for (name, median, bytes) in rows {
            let gflops = 2.0 * m.nnz() as f64 / median / 1e9;
            println!("  {name:<48} {:>10.2} µs  {gflops:>6.2} GF/s  {:>8} B", median * 1e6, bytes);
        }
    }
}
