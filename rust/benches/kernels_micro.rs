//! Micro-benchmarks of the generated kernels per format on one matrix
//! class — the profiling entry point for the L3 §Perf pass (DESIGN §7).
//!
//! The pool is swept through the engine: every (layout × traversal ×
//! schedule) plan in the host schedule pool is pinned
//! (`Engine::compile_pinned`) and timed, and the CSR serial-vs-parallel
//! SpMV speedup is reported explicitly (the headline number for the
//! `Schedule::Parallel` generated kernels — expect ≥2× on ≥4 cores).
use forelem::bench::harness::{black_box, time_fn, BenchConfig};
use forelem::concretize::{Layout, Schedule};
use forelem::engine::{Arch, Engine, Kernel};
use forelem::matrix::suite;

fn main() {
    let cfg = if std::env::var("FORELEM_QUICK").is_ok() {
        BenchConfig::quick()
    } else {
        BenchConfig::from_env()
    };
    let threads = forelem::util::pool::default_workers().clamp(2, 8);
    let engine = Engine::builder().arch(Arch::HostLarge).profile(false).build();
    let plans = engine.plans(Kernel::Spmv);
    let names = ["Erdos971", "blckhole", "consph", "Raj1", "net150"];
    println!("plan space: {} plans, {} worker threads", plans.len(), threads);
    for name in names {
        let m = suite::by_name(name).unwrap().build();
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.01).sin()).collect();
        println!("## {name}: n={} nnz={} maxrow={}", m.nrows, m.nnz(), m.max_row_nnz());
        let mut rows: Vec<(String, f64, usize)> = Vec::new();
        let mut csr_serial = None;
        let mut csr_parallel = None;
        for v in &plans {
            let exe = engine.compile_pinned(Kernel::Spmv, &m, &v.id).expect("pool plan");
            let mut y = vec![0.0; m.nrows];
            let s = time_fn(&cfg, || {
                exe.spmv(&x, &mut y);
                black_box(&y);
            });
            if v.exec.layout == Layout::Csr {
                match v.exec.schedule {
                    Schedule::Serial => csr_serial = Some(s.median),
                    Schedule::Parallel { .. } => csr_parallel = Some(s.median),
                    _ => {}
                }
            }
            rows.push((format!("{} {}", v.id, v.name()), s.median, exe.bytes()));
        }
        rows.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        for (name, median, bytes) in rows {
            let gflops = 2.0 * m.nnz() as f64 / median / 1e9;
            println!("  {name:<58} {:>10.2} µs  {gflops:>6.2} GF/s  {:>8} B", median * 1e6, bytes);
        }
        if let (Some(ser), Some(par)) = (csr_serial, csr_parallel) {
            println!(
                "  CSR SpMV serial/parallel({threads}): {:.2}x speedup  ({:.2} µs -> {:.2} µs)",
                ser / par,
                ser * 1e6,
                par * 1e6
            );
        }
    }
}
