//! Ablation (paper §6.2.4): data-distribution generation via different
//! partitionings of the blocked iteration domain — even row blocks vs
//! nonzero-balanced blocks vs a 2-D balanced grid — measuring load
//! imbalance and parallel SpMV time on skewed suite matrices.
use forelem::bench::harness::{black_box, time_fn, BenchConfig};
use forelem::distrib::{self, grid_2d, rows_balanced, rows_even, PartitionedSpmv};
use forelem::matrix::suite;

fn main() {
    let cfg = if std::env::var("FORELEM_QUICK").is_ok() {
        BenchConfig::quick()
    } else {
        BenchConfig::from_env()
    };
    println!("## Ablation — partitioning strategies for parallel SpMV (§6.2.4)");
    for name in ["Raj1", "net150", "consph", "or2010"] {
        let m = suite::by_name(name).unwrap().build();
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.002).cos()).collect();
        println!("\n{name}: n={} nnz={}", m.nrows, m.nnz());
        for parts in [2usize, 4, 8] {
            for (label, p) in
                [("even rows", rows_even(&m, parts)), ("balanced nnz", rows_balanced(&m, parts))]
            {
                let exec = PartitionedSpmv::new(&m, &p);
                let imb = distrib::imbalance(&exec.nnz_per_part());
                let mut y = vec![0.0; m.nrows];
                let t = time_fn(&cfg, || {
                    exec.spmv(&x, &mut y);
                    black_box(&y);
                });
                println!(
                    "  {parts} parts {label:<14} imbalance {imb:>5.2}  spmv {:>9.2} µs",
                    t.median * 1e6
                );
            }
        }
        // 2-D grid balance report (distribution quality, Vastenhouw–Bisseling-style)
        let g = grid_2d(&m, 2);
        let nnz = forelem::distrib::partition::grid_block_nnz(&m, &g);
        let imb = distrib::imbalance(&nnz);
        println!("  4x4 grid (2-D balanced splits)   block-nnz imbalance {imb:.2}");
    }
}
