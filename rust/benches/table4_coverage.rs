//! Regenerates paper Table 4: coverage of the library-routine collection
//! for increasing t%, across all three kernels and both architectures.
use forelem::baselines::Kernel;
use forelem::bench::tables;
use forelem::coordinator::sweep::{Arch, SweepConfig};

fn main() {
    let cfg = if std::env::var("FORELEM_QUICK").is_ok() {
        SweepConfig::quick()
    } else {
        SweepConfig::default()
    };
    let xla = tables::try_xla();
    let mut sweeps = Vec::new();
    for kernel in [Kernel::Spmv, Kernel::Spmm, Kernel::Trsv] {
        for arch in [Arch::HostSmall, Arch::HostLarge] {
            sweeps.push(tables::run_sweep(kernel, arch, &cfg, xla.as_ref()));
        }
    }
    let refs: Vec<&_> = sweeps.iter().collect();
    println!("{}", tables::table4(&refs));
}
