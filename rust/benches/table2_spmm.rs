//! Regenerates paper Table 2 (run: cargo bench --bench table2_*).
//! Honors FORELEM_BENCH_REPEATS / FORELEM_QUICK=1 for smoke runs.
use forelem::bench::tables;
use forelem::coordinator::sweep::SweepConfig;

fn main() {
    let cfg = if std::env::var("FORELEM_QUICK").is_ok() {
        SweepConfig::quick()
    } else {
        SweepConfig::default()
    };
    let xla = tables::try_xla();
    let (txt, ..) = tables::table2(&cfg, xla.as_ref());
    println!("{txt}");
}
