//! Regenerates paper Figure 11: coverage curves vs t% for the Blaze-only
//! and all-library collections against the combined optimum, plus the
//! generated collection, on both architectures (SpMV).
use forelem::baselines::Kernel;
use forelem::bench::tables;
use forelem::coordinator::sweep::{Arch, SweepConfig};

fn main() {
    let cfg = if std::env::var("FORELEM_QUICK").is_ok() {
        SweepConfig::quick()
    } else {
        SweepConfig::default()
    };
    let xla = tables::try_xla();
    for arch in [Arch::HostSmall, Arch::HostLarge] {
        let s = tables::run_sweep(Kernel::Spmv, arch, &cfg, xla.as_ref());
        println!("{}", tables::fig11(&s));
    }
}
