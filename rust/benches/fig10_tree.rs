//! Regenerates paper Figure 10: the transformation tree / search-space
//! report (chains, executables, distinct data structures per kernel),
//! plus one sample derivation per generated layout.
use forelem::baselines::Kernel;
use forelem::bench::tables;
use forelem::search::plan::PlanSpace;
use forelem::search::tree;

fn main() {
    println!("{}", tables::fig10());
    let t = tree::enumerate(Kernel::Spmv, &PlanSpace::serial_only());
    println!("## sample derivations (SpMV)");
    for v in &t.plans {
        println!("{} {:<45} {}", v.id, v.name(), v.derivation);
    }
}
