//! Hybrid ELL + COO storage — generated when *loop blocking partitions
//! the ℕ\* domain by row fill* (paper §6.2.3: "for each of these blocks a
//! different set of transformations could be carried out, leading to
//! different storage formats"): rows up to a width cutoff live in a
//! padded ELL plane; the overflow of long rows spills to coordinate
//! storage. This is the format that wins on power-law matrices where
//! plain ELL drowns in padding.

use crate::matrix::TriMat;
use crate::storage::coo::{CooOrder, CooSoa};
use crate::storage::ell::{Ell, EllOrder};

#[derive(Clone, Debug)]
pub struct HybridEllCoo {
    pub nrows: usize,
    pub ncols: usize,
    /// ELL part: holds min(row_len, cutoff) entries of every row.
    pub ell: Ell,
    /// COO part: overflow entries of rows longer than the cutoff.
    pub tail: CooSoa,
    pub cutoff: usize,
}

impl HybridEllCoo {
    /// `cutoff = None` picks the width that minimizes stored slots
    /// (a simple version of the ELL/COO split heuristic).
    pub fn from_tuples(m: &TriMat, cutoff: Option<usize>, order: EllOrder) -> Self {
        let counts = m.row_counts();
        let cutoff = cutoff.unwrap_or_else(|| best_cutoff(&counts));
        let mut head = TriMat::new(m.nrows, m.ncols);
        let mut tail = TriMat::new(m.nrows, m.ncols);
        let mut fill = vec![0usize; m.nrows];
        let mut sorted = m.clone();
        sorted.sort_row_major();
        for e in &sorted.entries {
            let i = e.row as usize;
            if fill[i] < cutoff {
                head.push(i, e.col as usize, e.val);
            } else {
                tail.push(i, e.col as usize, e.val);
            }
            fill[i] += 1;
        }
        HybridEllCoo {
            nrows: m.nrows,
            ncols: m.ncols,
            ell: Ell::from_tuples(&head, order),
            tail: CooSoa::from_tuples(&tail, CooOrder::RowMajor),
            cutoff,
        }
    }

    pub fn nnz(&self) -> usize {
        self.ell.nnz + self.tail.nnz()
    }

    pub fn bytes(&self) -> usize {
        self.ell.bytes() + self.tail.bytes()
    }
}

/// Choose the ELL width minimizing total stored slots:
/// `nrows * k + overflow(k)` over candidate cutoffs.
pub fn best_cutoff(counts: &[usize]) -> usize {
    let max = counts.iter().copied().max().unwrap_or(0);
    if max == 0 {
        return 0;
    }
    let mut best_k = max;
    let mut best_cost = usize::MAX;
    for k in 0..=max {
        let overflow: usize = counts.iter().map(|&c| c.saturating_sub(k)).sum();
        // COO overflow entries cost ~2x an ELL slot (row+col+val vs col+val).
        let cost = counts.len() * k + 2 * overflow;
        if cost < best_cost {
            best_cost = cost;
            best_k = k;
        }
    }
    best_k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    fn dense_of(h: &HybridEllCoo) -> Vec<f64> {
        let e = &h.ell;
        let mut d = vec![0.0; h.nrows * h.ncols];
        for i in 0..e.nrows {
            for p in 0..e.row_len[i] as usize {
                let ix = e.index(i, p);
                d[i * e.ncols + e.cols[ix] as usize] += e.vals[ix];
            }
        }
        for k in 0..h.tail.nnz() {
            d[h.tail.rows[k] as usize * h.ncols + h.tail.cols[k] as usize] += h.tail.vals[k];
        }
        d
    }

    #[test]
    fn roundtrip_auto_and_fixed_cutoff() {
        let m = gen::powerlaw(60, 1.9, 40, 24);
        for cutoff in [None, Some(2), Some(5), Some(1000)] {
            let h = HybridEllCoo::from_tuples(&m, cutoff, EllOrder::ColMajor);
            assert_eq!(dense_of(&h), m.to_dense(), "cutoff {cutoff:?}");
            assert_eq!(h.nnz(), m.nnz());
        }
    }

    #[test]
    fn hybrid_beats_plain_ell_on_skew() {
        let m = gen::powerlaw(200, 1.8, 150, 25);
        let plain = Ell::from_tuples(&m, EllOrder::ColMajor);
        let h = HybridEllCoo::from_tuples(&m, None, EllOrder::ColMajor);
        assert!(h.bytes() < plain.bytes(), "hybrid {} vs ell {}", h.bytes(), plain.bytes());
    }

    #[test]
    fn huge_cutoff_degenerates_to_ell() {
        let m = gen::banded(30, 2, 1.0, 26);
        let h = HybridEllCoo::from_tuples(&m, Some(100), EllOrder::RowMajor);
        assert_eq!(h.tail.nnz(), 0);
        assert_eq!(h.ell.nnz, m.nnz());
    }

    #[test]
    fn best_cutoff_sane() {
        assert_eq!(best_cutoff(&[]), 0);
        assert_eq!(best_cutoff(&[0, 0]), 0);
        // uniform rows: cutoff = the row length
        assert_eq!(best_cutoff(&[3, 3, 3, 3]), 3);
        // one huge row among short ones: cutoff stays near the short length
        let c = best_cutoff(&[2, 2, 2, 2, 2, 2, 2, 2, 100]);
        assert!(c <= 3, "cutoff {c}");
    }
}
