//! Diagonal storage — generated when *orthogonalization on the derived
//! field `col - row`* is chosen (the paper's framework permits
//! orthogonalization on any invertible address-function of the token
//! fields, §2.1): all tuples with equal offset `col - row` form one
//! group, concretized as dense diagonals. Profitable only for banded
//! matrices; the search space prunes it elsewhere via the fill ratio.

use crate::matrix::TriMat;

#[derive(Clone, Debug)]
pub struct Dia {
    pub nrows: usize,
    pub ncols: usize,
    /// Stored diagonal offsets (col - row), ascending.
    pub offsets: Vec<i32>,
    /// `vals[d * nrows + i]` = A[i, i + offsets[d]] (0 where out of range
    /// or structurally zero).
    pub vals: Vec<f64>,
    pub nnz: usize,
}

impl Dia {
    pub fn from_tuples(m: &TriMat) -> Self {
        let mut offs: Vec<i32> = m
            .entries
            .iter()
            .map(|e| e.col as i32 - e.row as i32)
            .collect();
        offs.sort_unstable();
        offs.dedup();
        let mut vals = vec![0.0; offs.len() * m.nrows];
        for e in &m.entries {
            let off = e.col as i32 - e.row as i32;
            let d = offs.binary_search(&off).unwrap();
            vals[d * m.nrows + e.row as usize] += e.val;
        }
        Dia { nrows: m.nrows, ncols: m.ncols, offsets: offs, vals, nnz: m.nnz() }
    }

    pub fn ndiags(&self) -> usize {
        self.offsets.len()
    }

    /// Stored slots / nonzeros.
    pub fn fill_ratio(&self) -> f64 {
        if self.nnz == 0 {
            return 1.0;
        }
        (self.ndiags() * self.nrows) as f64 / self.nnz as f64
    }

    pub fn bytes(&self) -> usize {
        self.offsets.len() * 4 + self.vals.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    fn dense_of(d: &Dia) -> Vec<f64> {
        let mut out = vec![0.0; d.nrows * d.ncols];
        for (k, &off) in d.offsets.iter().enumerate() {
            for i in 0..d.nrows {
                let j = i as i64 + off as i64;
                if j >= 0 && (j as usize) < d.ncols {
                    out[i * d.ncols + j as usize] += d.vals[k * d.nrows + i];
                }
            }
        }
        out
    }

    #[test]
    fn roundtrip_banded() {
        let m = gen::banded(30, 4, 0.8, 27);
        let d = Dia::from_tuples(&m);
        assert_eq!(dense_of(&d), m.to_dense());
        assert!(d.ndiags() <= 9);
    }

    #[test]
    fn roundtrip_random_rectangular() {
        let m = gen::uniform_random(12, 20, 50, 28);
        let d = Dia::from_tuples(&m);
        assert_eq!(dense_of(&d), m.to_dense());
    }

    #[test]
    fn fill_ratio_good_for_bands_bad_for_random() {
        let band = Dia::from_tuples(&gen::banded(100, 2, 1.0, 29));
        let rand = Dia::from_tuples(&gen::uniform_random(100, 100, 300, 29));
        assert!(band.fill_ratio() < 1.5);
        assert!(rand.fill_ratio() > 5.0);
    }
}
