//! Sliced ELLPACK (SELL) — the hybrid the paper's §6.2.3 machinery
//! generates when *loop blocking partitions the row dimension before
//! materialization* and each block is then padded independently
//! ("for each of these blocks a different set of transformations could
//! be carried out"): rows are processed in slices of `s`; each slice is
//! padded only to its *own* maximum width, stored column-major within
//! the slice (vector-friendly), eliminating most of plain ELL's global
//! padding.

use crate::matrix::TriMat;
use crate::storage::csr::Csr;

#[derive(Clone, Debug)]
pub struct Sell {
    pub nrows: usize,
    pub ncols: usize,
    /// Slice height (rows per block).
    pub s: usize,
    pub nslices: usize,
    /// Per-slice width (max row length within the slice).
    pub widths: Vec<u32>,
    /// Start of each slice's payload in `cols`/`vals`
    /// (slice payload = widths[b] * rows_in_slice, column-major).
    pub slice_ptr: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
    /// Exact per-row lengths.
    pub row_len: Vec<u32>,
    pub nnz: usize,
}

impl Sell {
    pub fn from_tuples(m: &TriMat, s: usize) -> Self {
        assert!(s > 0);
        let csr = Csr::from_tuples(m);
        let nslices = m.nrows.div_ceil(s);
        let row_len: Vec<u32> =
            (0..m.nrows).map(|i| csr.row_ptr[i + 1] - csr.row_ptr[i]).collect();
        let mut widths = Vec::with_capacity(nslices);
        let mut slice_ptr = vec![0u32; nslices + 1];
        for b in 0..nslices {
            let lo = b * s;
            let hi = ((b + 1) * s).min(m.nrows);
            let w = (lo..hi).map(|i| row_len[i]).max().unwrap_or(0);
            widths.push(w);
            let rows = (hi - lo) as u32;
            slice_ptr[b + 1] = slice_ptr[b] + w * rows;
        }
        let total = slice_ptr[nslices] as usize;
        let mut cols = vec![0u32; total];
        let mut vals = vec![0.0f64; total];
        for b in 0..nslices {
            let lo = b * s;
            let hi = ((b + 1) * s).min(m.nrows);
            let rows = hi - lo;
            let base = slice_ptr[b] as usize;
            let w = widths[b] as usize;
            for (ri, i) in (lo..hi).enumerate() {
                let (rs, re) = (csr.row_ptr[i] as usize, csr.row_ptr[i + 1] as usize);
                for (p, k) in (rs..re).enumerate() {
                    // column-major within the slice: slot p plane, row ri
                    let ix = base + p * rows + ri;
                    cols[ix] = csr.cols[k];
                    vals[ix] = csr.vals[k];
                }
                let _ = w;
            }
        }
        Sell {
            nrows: m.nrows,
            ncols: m.ncols,
            s,
            nslices,
            widths,
            slice_ptr,
            cols,
            vals,
            row_len,
            nnz: m.nnz(),
        }
    }

    /// Stored slots / nonzeros — must sit between CSR (1.0) and ELL.
    pub fn padding_ratio(&self) -> f64 {
        if self.nnz == 0 {
            return 1.0;
        }
        self.vals.len() as f64 / self.nnz as f64
    }

    pub fn bytes(&self) -> usize {
        self.widths.len() * 4
            + self.slice_ptr.len() * 4
            + self.cols.len() * 4
            + self.vals.len() * 8
            + self.row_len.len() * 4
    }
}

/// SELL SpMV: slice loop outer, slot plane loop, row-vector inner.
pub fn spmv(a: &Sell, x: &[f64], y: &mut [f64]) {
    for b in 0..a.nslices {
        let lo = b * a.s;
        let hi = ((b + 1) * a.s).min(a.nrows);
        let rows = hi - lo;
        let base = a.slice_ptr[b] as usize;
        let w = a.widths[b] as usize;
        y[lo..hi].fill(0.0);
        for p in 0..w {
            let plane = base + p * rows;
            for ri in 0..rows {
                let ix = plane + ri;
                y[lo + ri] += a.vals[ix] * x[a.cols[ix] as usize];
            }
        }
    }
}

/// SELL SpMM.
pub fn spmm(a: &Sell, bm: &[f64], k: usize, c: &mut [f64]) {
    for b in 0..a.nslices {
        let lo = b * a.s;
        let hi = ((b + 1) * a.s).min(a.nrows);
        let rows = hi - lo;
        let base = a.slice_ptr[b] as usize;
        let w = a.widths[b] as usize;
        c[lo * k..hi * k].fill(0.0);
        for p in 0..w {
            let plane = base + p * rows;
            for ri in 0..rows {
                let ix = plane + ri;
                let v = a.vals[ix];
                if v == 0.0 {
                    continue;
                }
                let col = a.cols[ix] as usize;
                let brow = &bm[col * k..col * k + k];
                let crow = &mut c[(lo + ri) * k..(lo + ri) * k + k];
                for j in 0..k {
                    crow[j] += v * brow[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::storage::ell::{Ell, EllOrder};
    use crate::util::prop::assert_close;

    #[test]
    fn roundtrip_various_slices() {
        let m = gen::powerlaw(70, 1.9, 35, 200);
        let x: Vec<f64> = (0..70).map(|i| (i as f64 * 0.17).sin() + 0.3).collect();
        let want = m.spmv_ref(&x);
        for s in [1, 4, 8, 32, 128] {
            let a = Sell::from_tuples(&m, s);
            let mut y = vec![0.0; 70];
            spmv(&a, &x, &mut y);
            assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("s={s}: {e}"));
        }
    }

    #[test]
    fn spmm_matches() {
        let m = gen::uniform_random(40, 45, 300, 201);
        let k = 5;
        let bm: Vec<f64> = (0..45 * k).map(|i| i as f64 * 0.01 - 0.2).collect();
        let want = m.spmm_ref(&bm, k);
        let a = Sell::from_tuples(&m, 8);
        let mut c = vec![0.0; 40 * k];
        spmm(&a, &bm, k, &mut c);
        assert_close(&c, &want, 1e-10).unwrap();
    }

    #[test]
    fn padding_between_csr_and_ell() {
        let m = gen::powerlaw(128, 1.8, 60, 202);
        let sell = Sell::from_tuples(&m, 16);
        let ell = Ell::from_tuples(&m, EllOrder::RowMajor);
        assert!(sell.padding_ratio() >= 1.0 - 1e-12);
        assert!(sell.padding_ratio() <= ell.padding_ratio() + 1e-12);
        // strictly better than ELL on a skewed matrix
        assert!(sell.padding_ratio() < ell.padding_ratio());
    }

    #[test]
    fn slice_of_one_equals_csr_density() {
        let m = gen::banded(30, 3, 0.5, 203);
        let sell = Sell::from_tuples(&m, 1);
        assert!((sell.padding_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ragged_tail_slice() {
        // nrows not divisible by s
        let m = gen::uniform_random(37, 29, 150, 204);
        let x: Vec<f64> = (0..29).map(|i| i as f64 * 0.1).collect();
        let a = Sell::from_tuples(&m, 8);
        assert_eq!(a.nslices, 5);
        let mut y = vec![0.0; 37];
        spmv(&a, &x, &mut y);
        assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
    }
}
