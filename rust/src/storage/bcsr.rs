//! Blocked CSR — the concretization of *loop blocking on both row and
//! column orthogonalization* (paper §5.3 / §6.2.3, Fig 9): the matrix is
//! processed as `br × bc` submatrices; nonempty blocks are stored densely
//! and indexed CSR-style at block granularity.

use crate::matrix::TriMat;

#[derive(Clone, Debug)]
pub struct Bcsr {
    pub nrows: usize,
    pub ncols: usize,
    pub br: usize,
    pub bc: usize,
    /// Block-rows = ceil(nrows / br).
    pub nblock_rows: usize,
    pub nblock_cols: usize,
    pub block_row_ptr: Vec<u32>,
    /// Block-column index of each stored block.
    pub block_cols: Vec<u32>,
    /// Dense `br*bc` payload per stored block, row-major within the block.
    pub blocks: Vec<f64>,
    pub nnz: usize,
}

impl Bcsr {
    pub fn from_tuples(m: &TriMat, br: usize, bc: usize) -> Self {
        assert!(br > 0 && bc > 0);
        let nbr = m.nrows.div_ceil(br);
        let nbc = m.ncols.div_ceil(bc);
        // Collect nonempty blocks.
        use std::collections::BTreeMap;
        let mut map: BTreeMap<(u32, u32), Vec<f64>> = BTreeMap::new();
        for e in &m.entries {
            let (bi, bj) = (e.row as usize / br, e.col as usize / bc);
            let payload = map
                .entry((bi as u32, bj as u32))
                .or_insert_with(|| vec![0.0; br * bc]);
            payload[(e.row as usize % br) * bc + e.col as usize % bc] += e.val;
        }
        let mut block_row_ptr = vec![0u32; nbr + 1];
        let mut block_cols = Vec::with_capacity(map.len());
        let mut blocks = Vec::with_capacity(map.len() * br * bc);
        for (&(bi, bj), payload) in &map {
            block_row_ptr[bi as usize + 1] += 1;
            block_cols.push(bj);
            blocks.extend_from_slice(payload);
        }
        for i in 0..nbr {
            block_row_ptr[i + 1] += block_row_ptr[i];
        }
        Bcsr {
            nrows: m.nrows,
            ncols: m.ncols,
            br,
            bc,
            nblock_rows: nbr,
            nblock_cols: nbc,
            block_row_ptr,
            block_cols,
            blocks,
            nnz: m.nnz(),
        }
    }

    pub fn nblocks(&self) -> usize {
        self.block_cols.len()
    }

    /// Stored slots / nonzeros (block fill-in overhead).
    pub fn fill_ratio(&self) -> f64 {
        if self.nnz == 0 {
            return 1.0;
        }
        (self.nblocks() * self.br * self.bc) as f64 / self.nnz as f64
    }

    pub fn bytes(&self) -> usize {
        self.block_row_ptr.len() * 4 + self.block_cols.len() * 4 + self.blocks.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    fn dense_of(b: &Bcsr) -> Vec<f64> {
        let mut d = vec![0.0; b.nrows * b.ncols];
        for bi in 0..b.nblock_rows {
            let (s, e) = (b.block_row_ptr[bi] as usize, b.block_row_ptr[bi + 1] as usize);
            for k in s..e {
                let bj = b.block_cols[k] as usize;
                let payload = &b.blocks[k * b.br * b.bc..(k + 1) * b.br * b.bc];
                for r in 0..b.br {
                    for c in 0..b.bc {
                        let (gi, gj) = (bi * b.br + r, bj * b.bc + c);
                        if gi < b.nrows && gj < b.ncols {
                            d[gi * b.ncols + gj] += payload[r * b.bc + c];
                        }
                    }
                }
            }
        }
        d
    }

    #[test]
    fn roundtrip_various_block_shapes() {
        let m = gen::fem_blocks(12, 3, 3, 20);
        for (br, bc) in [(1, 1), (2, 2), (3, 3), (4, 2), (3, 5)] {
            let b = Bcsr::from_tuples(&m, br, bc);
            assert_eq!(dense_of(&b), m.to_dense(), "block {br}x{bc}");
        }
    }

    #[test]
    fn block_aligned_fem_has_low_fill() {
        let m = gen::fem_blocks(16, 3, 4, 21);
        let aligned = Bcsr::from_tuples(&m, 3, 3);
        let misaligned = Bcsr::from_tuples(&m, 4, 4);
        assert!(aligned.fill_ratio() <= misaligned.fill_ratio() + 0.25);
        assert!(aligned.fill_ratio() < 2.0);
    }

    #[test]
    fn one_by_one_equals_csr_structure() {
        let m = gen::uniform_random(20, 20, 80, 22);
        let b = Bcsr::from_tuples(&m, 1, 1);
        assert_eq!(b.nblocks(), m.nnz());
        assert!((b.fill_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ragged_edge_handled() {
        // 7x5 with 3x2 blocks exercises the remainder logic.
        let m = gen::uniform_random(7, 5, 20, 23);
        let b = Bcsr::from_tuples(&m, 3, 2);
        assert_eq!(b.nblock_rows, 3);
        assert_eq!(b.nblock_cols, 3);
        assert_eq!(dense_of(&b), m.to_dense());
    }
}
