//! Coordinate storage — the direct concretization of a materialized
//! reservoir with *no* orthogonalization: `forelem (i; i ∈ ℕ*) … PA[i] …`
//! maps to a flat sequence of localized tuples `⟨row, col, val⟩`.
//!
//! Two physical layouts correspond to the presence/absence of the
//! *structure splitting* transformation (paper §4.3.2):
//! `CooAos` (sequence of structures) and `CooSoa` (structure of
//! sequences). The sequence order is whatever the chain imposed
//! (unsorted, row-major via orthogonalization-on-row + concretization,
//! or col-major).

use crate::matrix::TriMat;

/// Element order imposed by the transformation chain before
/// concretization.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CooOrder {
    /// Iteration order left fully undefined (input order).
    Unsorted,
    /// Orthogonalized on `row`, then materialized.
    RowMajor,
    /// Orthogonalized on `col`, then materialized.
    ColMajor,
}

/// Array-of-structures coordinate storage.
#[derive(Clone, Debug)]
pub struct CooAos {
    pub nrows: usize,
    pub ncols: usize,
    pub order: CooOrder,
    /// Localized tuples `⟨row, col, val⟩` (val inline with the token).
    pub tuples: Vec<(u32, u32, f64)>,
}

impl CooAos {
    pub fn from_tuples(m: &TriMat, order: CooOrder) -> Self {
        let mut t = m.clone();
        match order {
            CooOrder::Unsorted => {}
            CooOrder::RowMajor => t.sort_row_major(),
            CooOrder::ColMajor => t.sort_col_major(),
        }
        CooAos {
            nrows: m.nrows,
            ncols: m.ncols,
            order,
            tuples: t.entries.iter().map(|e| (e.row, e.col, e.val)).collect(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.tuples.len()
    }

    /// Bytes of physical storage (for DESIGN/EXPERIMENTS footprint notes).
    pub fn bytes(&self) -> usize {
        self.tuples.len() * std::mem::size_of::<(u32, u32, f64)>()
    }
}

/// Structure-of-arrays coordinate storage (after structure splitting).
#[derive(Clone, Debug)]
pub struct CooSoa {
    pub nrows: usize,
    pub ncols: usize,
    pub order: CooOrder,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl CooSoa {
    pub fn from_tuples(m: &TriMat, order: CooOrder) -> Self {
        let aos = CooAos::from_tuples(m, order);
        let mut rows = Vec::with_capacity(aos.nnz());
        let mut cols = Vec::with_capacity(aos.nnz());
        let mut vals = Vec::with_capacity(aos.nnz());
        for (r, c, v) in aos.tuples {
            rows.push(r);
            cols.push(c);
            vals.push(v);
        }
        CooSoa { nrows: m.nrows, ncols: m.ncols, order, rows, cols, vals }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn bytes(&self) -> usize {
        self.rows.len() * 4 + self.cols.len() * 4 + self.vals.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn row_major_is_sorted() {
        let m = gen::uniform_random(40, 40, 200, 1);
        let c = CooAos::from_tuples(&m, CooOrder::RowMajor);
        assert!(c.tuples.windows(2).all(|w| (w[0].0, w[0].1) <= (w[1].0, w[1].1)));
        assert_eq!(c.nnz(), m.nnz());
    }

    #[test]
    fn col_major_is_sorted() {
        let m = gen::uniform_random(40, 40, 200, 2);
        let c = CooAos::from_tuples(&m, CooOrder::ColMajor);
        assert!(c.tuples.windows(2).all(|w| (w[0].1, w[0].0) <= (w[1].1, w[1].0)));
    }

    #[test]
    fn soa_matches_aos() {
        let m = gen::uniform_random(30, 50, 150, 3);
        let a = CooAos::from_tuples(&m, CooOrder::RowMajor);
        let s = CooSoa::from_tuples(&m, CooOrder::RowMajor);
        assert_eq!(a.nnz(), s.nnz());
        for (i, &(r, c, v)) in a.tuples.iter().enumerate() {
            assert_eq!((s.rows[i], s.cols[i]), (r, c));
            assert_eq!(s.vals[i], v);
        }
        // splitting saves memory vs padded AoS tuple
        assert!(s.bytes() <= a.bytes());
    }
}
