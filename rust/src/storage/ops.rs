//! `SparseOps` — the format-agnostic execution interface every physical
//! storage layout implements, replacing the executor's old
//! schedule × storage × kernel `match` pyramids with trait dispatch.
//!
//! # How execution is wired
//!
//! A concretization plan is executed in two layers:
//!
//! 1. **Format layer (this trait).** Each of the 12 storage formats
//!    implements [`SparseOps`]: the serial kernels
//!    ([`spmv_serial`](SparseOps::spmv_serial) /
//!    [`spmm_serial`](SparseOps::spmm_serial) /
//!    [`trsv_serial`](SparseOps::trsv_serial), traversal-dispatched
//!    *inside* the format), the parallel partition interface
//!    ([`par_units`](SparseOps::par_units) +
//!    [`spmv_range`](SparseOps::spmv_range) /
//!    [`spmm_range`](SparseOps::spmm_range) over contiguous output
//!    units), the B-panel SpMM kernel
//!    ([`spmm_panel`](SparseOps::spmm_panel)), and builders for the
//!    auxiliary structures a schedule may need
//!    ([`build_bands`](SparseOps::build_bands) for cache-blocked SpMV,
//!    [`build_levels`](SparseOps::build_levels) for level-scheduled
//!    TrSv). Introspection (`bytes`, `slug`) lives here too, so the
//!    executor never re-derives storage sizes by hand.
//! 2. **Schedule layer (`concretize::exec`).** The registry
//!    (`exec::build_ops`) binds a `Layout` to its storage builder once;
//!    `Prepared` then drives the trait object through the plan's
//!    schedule: `Serial` calls the serial kernel, `Parallel` the
//!    partitioned driver, `Tiled`/`ParallelTiled` the band or panel
//!    sweeps.
//!
//! # Adding a format (or a kernel) in one place
//!
//! * **New format:** implement `SparseOps` below (the serial methods
//!   are the only mandatory ones — every schedule hook defaults to a
//!   safe fallback), add one arm to `exec::build_ops`, and teach
//!   `concretize::layout` how chains map to the new `Layout`. Nothing
//!   in the executor or the sweep changes.
//! * **New schedule capability:** formats opt in by overriding the
//!   matching hook (`par_units` + `*_range` for row partitioning,
//!   `supports_spmm_panel` + `spmm_panel` for B tiling, `build_levels`
//!   + `trsv_level` for dependence-level scheduling) and declaring
//!   legality in `layout::schedule_legal`.
//!
//! The default `spmv_parallel`/`spmm_parallel` drivers split the output
//! into nnz-balanced contiguous unit ranges (rows for CSR/ELL, slices
//! for SELL, block rows for BCSR) with each worker owning a disjoint
//! `&mut` chunk — no locks, no atomics. Formats whose parallel
//! decomposition is not a plain output split (permuted JDS accumulates
//! into the permuted vector and scatters once at the end) override the
//! drivers themselves.

use std::ops::Range;
use std::sync::Arc;

use crate::concretize::layout::{coo_order_slug, Traversal};
use crate::matrix::delta::DeltaEntry;
use crate::kernels::levels::LevelSets;
use crate::kernels::{levels, par, simd, spmm, spmv, trsv};
use crate::storage::{
    sell, sell_sigma, Bcsr, CooAos, CooOrder, CooSoa, Csc, CscAos, Csr, CsrAos, CsrBands, Dia,
    Ell, EllOrder, HybridEllCoo, Jds, JdsRows, Sell, SellSigma,
};
use crate::util::pool::scoped_run;

/// Format-agnostic execution interface of a physical storage layout.
/// See the module docs for the layering and the extension recipe.
pub trait SparseOps: Send + Sync {
    /// Stable format slug (matches `Layout::slug` for the same layout).
    fn slug(&self) -> String;
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    /// Total bytes of the stored structure (indices + values + any
    /// auxiliary lists the format itself owns).
    fn bytes(&self) -> usize;

    // ---- serial executors (traversal dispatched inside the format) --

    fn spmv_serial(&self, t: Traversal, x: &[f64], y: &mut [f64]);
    fn spmm_serial(&self, t: Traversal, b: &[f64], k: usize, c: &mut [f64]);
    fn trsv_serial(&self, _b: &[f64], _x: &mut [f64]) {
        panic!("TrSv not generated for {} (checked by supports())", self.slug());
    }

    /// Batched-SpMV panel entry point for `engine::batch`: `k`
    /// right-hand sides packed row-major (`b[col * k + j]` = request
    /// `j`'s `x[col]`), each output column required to be
    /// **bit-identical** to a solo `spmv_serial` on that column. The
    /// default delivers the contract by construction — it gathers each
    /// column and runs the format's own serial SpMV — so every format
    /// is batchable; formats with a panel kernel whose per-column
    /// reduction order provably matches SpMV override it to skip the
    /// k gather/scatter passes (CSR → `spmm::csr_rowdot_k`).
    fn spmv_batch(&self, t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        let (nr, nc) = (self.nrows(), self.ncols());
        let mut x = vec![0.0; nc];
        let mut y = vec![0.0; nr];
        for j in 0..k {
            for (col, xc) in x.iter_mut().enumerate() {
                *xc = b[col * k + j];
            }
            self.spmv_serial(t, &x, &mut y);
            for (i, &yi) in y.iter().enumerate() {
                c[i * k + j] = yi;
            }
        }
    }

    // ---- parallel partition interface ------------------------------

    /// Number of disjoint contiguous output partitions (rows, slices,
    /// block rows); 0 means the format has no lock-free output split.
    fn par_units(&self) -> usize {
        0
    }

    /// Output rows covered by one partition unit.
    fn rows_per_unit(&self) -> usize {
        1
    }

    /// Cumulative weight (nonzeros) of units `0..u` — the balance
    /// function handed to `par::balanced_ranges`.
    fn unit_weight_prefix(&self, u: usize) -> usize {
        u
    }

    /// Whether the contiguous range kernels
    /// ([`spmv_range`](SparseOps::spmv_range) /
    /// [`spmm_range`](SparseOps::spmm_range)) back this format's
    /// `par_units` split. Formats that expose units but own
    /// scatter-style parallel drivers (JDS, ELL) return `false`, so
    /// generic range-walking passes — the NUMA first-touch re-walk —
    /// skip them instead of hitting the panicking defaults.
    fn has_range_kernels(&self) -> bool {
        true
    }

    /// SpMV over units `[u0, u1)`, writing into the chunk of `y` that
    /// starts at row `u0 * rows_per_unit()`.
    fn spmv_range(&self, _t: Traversal, _x: &[f64], _y: &mut [f64], _u0: usize, _u1: usize) {
        panic!("{} has no partitioned SpMV (schedule_legal admits none)", self.slug());
    }

    /// SpMM over units `[u0, u1)` into the matching chunk of `c`.
    fn spmm_range(
        &self,
        _t: Traversal,
        _b: &[f64],
        _k: usize,
        _c: &mut [f64],
        _u0: usize,
        _u1: usize,
    ) {
        panic!("{} has no partitioned SpMM (schedule_legal admits none)", self.slug());
    }

    /// `Schedule::Parallel` SpMV driver: nnz-balanced unit ranges, one
    /// owned output chunk per worker. Falls back to the serial nest
    /// when the format exposes no partitions or one range suffices.
    fn spmv_parallel(&self, t: Traversal, x: &[f64], y: &mut [f64], threads: usize) {
        let ranges =
            par::balanced_ranges(self.par_units(), threads, |u| self.unit_weight_prefix(u));
        if ranges.len() <= 1 {
            return self.spmv_serial(t, x, y);
        }
        let chunks = par::chunks_for(y, &ranges, self.rows_per_unit());
        let mut tasks = Vec::with_capacity(ranges.len());
        for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
            tasks.push(move || self.spmv_range(t, x, chunk, lo, hi));
        }
        scoped_run(tasks);
    }

    /// `Schedule::Parallel` SpMM driver (same split over `c` rows).
    fn spmm_parallel(&self, t: Traversal, b: &[f64], k: usize, c: &mut [f64], threads: usize) {
        let ranges =
            par::balanced_ranges(self.par_units(), threads, |u| self.unit_weight_prefix(u));
        if ranges.len() <= 1 {
            return self.spmm_serial(t, b, k, c);
        }
        let chunks = par::chunks_for(c, &ranges, self.rows_per_unit() * k);
        let mut tasks = Vec::with_capacity(ranges.len());
        for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
            tasks.push(move || self.spmm_range(t, b, k, chunk, lo, hi));
        }
        scoped_run(tasks);
    }

    // ---- vector-lane executors (the plan's fourth axis) ------------

    /// SpMV at vector width `lanes` (4 or 8; `concretize::lane_legal`
    /// gates the callers). Defaults to the scalar serial nest so a
    /// format without wide micro-kernels stays correct; CSR / ELL /
    /// SELL-σ override with `kernels::simd`.
    fn spmv_serial_lanes(&self, t: Traversal, x: &[f64], y: &mut [f64], _lanes: usize) {
        self.spmv_serial(t, x, y);
    }

    /// Lane-width SpMV over units `[u0, u1)` (chunk convention of
    /// [`spmv_range`](SparseOps::spmv_range)).
    fn spmv_range_lanes(
        &self,
        t: Traversal,
        x: &[f64],
        y: &mut [f64],
        u0: usize,
        u1: usize,
        _lanes: usize,
    ) {
        self.spmv_range(t, x, y, u0, u1);
    }

    /// `Schedule::Parallel` SpMV at vector width `lanes`: the scalar
    /// driver's nnz-balanced split with the lane range kernel in each
    /// worker.
    fn spmv_parallel_lanes(
        &self,
        t: Traversal,
        x: &[f64],
        y: &mut [f64],
        threads: usize,
        lanes: usize,
    ) {
        let ranges =
            par::balanced_ranges(self.par_units(), threads, |u| self.unit_weight_prefix(u));
        if ranges.len() <= 1 {
            return self.spmv_serial_lanes(t, x, y, lanes);
        }
        let chunks = par::chunks_for(y, &ranges, self.rows_per_unit());
        let mut tasks = Vec::with_capacity(ranges.len());
        for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
            tasks.push(move || self.spmv_range_lanes(t, x, chunk, lo, hi, lanes));
        }
        scoped_run(tasks);
    }

    /// SpMM at vector width `lanes` (widened register-blocked
    /// micro-kernel; CSR overrides, everything else runs scalar).
    fn spmm_serial_lanes(&self, t: Traversal, b: &[f64], k: usize, c: &mut [f64], _lanes: usize) {
        self.spmm_serial(t, b, k, c);
    }

    /// Lane-width SpMM over units `[u0, u1)`.
    fn spmm_range_lanes(
        &self,
        t: Traversal,
        b: &[f64],
        k: usize,
        c: &mut [f64],
        u0: usize,
        u1: usize,
        _lanes: usize,
    ) {
        self.spmm_range(t, b, k, c, u0, u1);
    }

    /// `Schedule::Parallel` SpMM at vector width `lanes`.
    fn spmm_parallel_lanes(
        &self,
        t: Traversal,
        b: &[f64],
        k: usize,
        c: &mut [f64],
        threads: usize,
        lanes: usize,
    ) {
        let ranges =
            par::balanced_ranges(self.par_units(), threads, |u| self.unit_weight_prefix(u));
        if ranges.len() <= 1 {
            return self.spmm_serial_lanes(t, b, k, c, lanes);
        }
        let chunks = par::chunks_for(c, &ranges, self.rows_per_unit() * k);
        let mut tasks = Vec::with_capacity(ranges.len());
        for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
            tasks.push(move || self.spmm_range_lanes(t, b, k, chunk, lo, hi, lanes));
        }
        scoped_run(tasks);
    }

    // ---- SpMM B-panel tiling ---------------------------------------

    /// Whether `spmm_panel` is implemented (`Tiled`/`ParallelTiled`
    /// SpMM legality mirrors this in `layout::schedule_legal`).
    fn supports_spmm_panel(&self) -> bool {
        false
    }

    /// SpMM restricted to the B/C column panel `cols` over the unit
    /// range `units` (`c` is the chunk for those units, full row
    /// stride `k`). Every (row, panel) cell is written exactly once.
    fn spmm_panel(
        &self,
        _t: Traversal,
        _b: &[f64],
        _k: usize,
        _c: &mut [f64],
        _cols: Range<usize>,
        _units: Range<usize>,
    ) {
        panic!("{} has no B-panel SpMM (schedule_legal admits none)", self.slug());
    }

    // ---- cache-blocked SpMV auxiliaries ----------------------------

    /// Per-band row splits for `Schedule::Tiled` SpMV, built once at
    /// `prepare()` (CSR only; other formats return `None`).
    fn build_bands(&self, _x_block: usize) -> Option<CsrBands> {
        None
    }

    fn spmv_tiled(&self, _bands: &CsrBands, _x: &[f64], _y: &mut [f64]) {
        panic!("{} has no cache-blocked SpMV (schedule_legal admits none)", self.slug());
    }

    fn spmv_parallel_tiled(&self, bands: &CsrBands, x: &[f64], y: &mut [f64], _threads: usize) {
        self.spmv_tiled(bands, x, y);
    }

    // ---- level-scheduled TrSv --------------------------------------

    /// Dependence level sets for `Schedule::Parallel` TrSv, built once
    /// at `prepare()` (compressed SoA formats only).
    fn build_levels(&self) -> Option<LevelSets> {
        None
    }

    fn trsv_level(&self, _lv: &LevelSets, b: &[f64], x: &mut [f64], _threads: usize) {
        self.trsv_serial(b, x);
    }

    // ---- versioned-matrix delta repair -----------------------------

    /// In-place structural repair for `Engine::apply_delta`: given a
    /// resolved, `(row, col)`-sorted delta already validated against
    /// the matrix this storage was built from, derive a **new** storage
    /// bit-identical to a fresh build on the post-delta matrix (the old
    /// one keeps serving in-flight traffic until the generation swap).
    /// `None` means this format — or this particular batch — cannot be
    /// repaired and the caller must rebuild from tuples. Default: no
    /// repair capability.
    fn repair(&self, _delta: &[DeltaEntry]) -> Option<Arc<dyn SparseOps>> {
        None
    }
}

// ------------------------------------------------------------- COO --

impl SparseOps for CooAos {
    fn slug(&self) -> String {
        format!("coo-aos-{}", coo_order_slug(self.order))
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        CooAos::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        spmv::coo_aos(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        spmm::coo_aos(self, b, k, c);
    }
    fn trsv_serial(&self, b: &[f64], x: &mut [f64]) {
        trsv::coo_rowmajor(self, b, x);
    }
}

impl SparseOps for CooSoa {
    fn slug(&self) -> String {
        format!("coo-soa-{}", coo_order_slug(self.order))
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        CooSoa::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        spmv::coo_soa(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        spmm::coo_soa(self, b, k, c);
    }
}

// ------------------------------------------------------------- CSR --

impl SparseOps for Csr {
    fn slug(&self) -> String {
        "csr".into()
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        Csr::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        spmv::csr(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        spmm::csr(self, b, k, c);
    }
    fn spmv_batch(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        // Row-dot panel: per column the literal SpMV reduction order,
        // without the default's k gather/scatter passes.
        spmm::csr_rowdot_k(self, b, k, c);
    }
    fn trsv_serial(&self, b: &[f64], x: &mut [f64]) {
        trsv::csr(self, b, x);
    }
    fn par_units(&self) -> usize {
        self.nrows
    }
    fn unit_weight_prefix(&self, u: usize) -> usize {
        self.row_ptr[u] as usize
    }
    fn spmv_range(&self, _t: Traversal, x: &[f64], y: &mut [f64], u0: usize, _u1: usize) {
        par::csr_rows(self, x, y, u0);
    }
    fn spmm_range(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64], u0: usize, _u1: usize) {
        par::csr_rows_mm(self, b, k, c, u0);
    }
    fn spmv_serial_lanes(&self, _t: Traversal, x: &[f64], y: &mut [f64], lanes: usize) {
        simd::csr_spmv(self, x, y, lanes);
    }
    fn spmv_range_lanes(
        &self,
        _t: Traversal,
        x: &[f64],
        y: &mut [f64],
        u0: usize,
        _u1: usize,
        lanes: usize,
    ) {
        simd::csr_spmv_rows(self, x, y, u0, lanes);
    }
    fn spmm_serial_lanes(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64], lanes: usize) {
        simd::csr_spmm(self, b, k, c, lanes);
    }
    fn spmm_range_lanes(
        &self,
        _t: Traversal,
        b: &[f64],
        k: usize,
        c: &mut [f64],
        u0: usize,
        _u1: usize,
        lanes: usize,
    ) {
        simd::csr_spmm_rows(self, b, k, c, u0, lanes);
    }
    fn supports_spmm_panel(&self) -> bool {
        true
    }
    fn spmm_panel(
        &self,
        _t: Traversal,
        b: &[f64],
        k: usize,
        c: &mut [f64],
        cols: Range<usize>,
        units: Range<usize>,
    ) {
        spmm::csr_panel(self, b, k, c, cols, units.start);
    }
    fn build_bands(&self, x_block: usize) -> Option<CsrBands> {
        Some(CsrBands::build(self, x_block))
    }
    fn spmv_tiled(&self, bands: &CsrBands, x: &[f64], y: &mut [f64]) {
        par::csr_spmv_tiled(self, bands, x, y);
    }
    fn spmv_parallel_tiled(&self, bands: &CsrBands, x: &[f64], y: &mut [f64], threads: usize) {
        par::csr_spmv_parallel_tiled(self, bands, x, y, threads);
    }
    fn build_levels(&self) -> Option<LevelSets> {
        Some(LevelSets::from_csr(self))
    }
    fn trsv_level(&self, lv: &LevelSets, b: &[f64], x: &mut [f64], threads: usize) {
        levels::csr_trsv_level(self, lv, b, x, threads);
    }
    fn repair(&self, delta: &[DeltaEntry]) -> Option<Arc<dyn SparseOps>> {
        // Row splicing handles any delta; level sets / bands are
        // rebuilt lazily by the fresh `Prepared`'s OnceLocks.
        Some(Arc::new(Csr::repaired(self, delta)))
    }
}

impl SparseOps for CsrAos {
    fn slug(&self) -> String {
        "csr-aos".into()
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        CsrAos::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        spmv::csr_aos(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        spmm::csr_aos(self, b, k, c);
    }
    fn trsv_serial(&self, b: &[f64], x: &mut [f64]) {
        trsv::csr_aos(self, b, x);
    }
}

// ------------------------------------------------------------- CSC --

impl SparseOps for Csc {
    fn slug(&self) -> String {
        "csc".into()
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        Csc::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        spmv::csc(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        spmm::csc(self, b, k, c);
    }
    fn trsv_serial(&self, b: &[f64], x: &mut [f64]) {
        trsv::csc(self, b, x);
    }
    fn build_levels(&self) -> Option<LevelSets> {
        Some(LevelSets::from_csc(self))
    }
    fn trsv_level(&self, lv: &LevelSets, b: &[f64], x: &mut [f64], threads: usize) {
        levels::csc_trsv_level(self, lv, b, x, threads);
    }
}

impl SparseOps for CscAos {
    fn slug(&self) -> String {
        "csc-aos".into()
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        CscAos::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        spmv::csc_aos(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        spmm::csc_aos(self, b, k, c);
    }
    fn trsv_serial(&self, b: &[f64], x: &mut [f64]) {
        trsv::csc_aos(self, b, x);
    }
}

// ------------------------------------------------------------- ELL --

impl SparseOps for Ell {
    fn slug(&self) -> String {
        match self.order {
            EllOrder::RowMajor => "ell-rm".into(),
            EllOrder::ColMajor => "ell-cm".into(),
        }
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        Ell::bytes(self)
    }
    fn spmv_serial(&self, t: Traversal, x: &[f64], y: &mut [f64]) {
        match t {
            Traversal::RowWisePadded => spmv::ell_rowwise_padded(self, x, y),
            Traversal::PlaneWise => spmv::ell_planewise(self, x, y),
            _ => spmv::ell_rowwise(self, x, y),
        }
    }
    fn spmm_serial(&self, t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        match t {
            Traversal::PlaneWise => spmm::ell_planewise(self, b, k, c),
            _ => spmm::ell_rowwise(self, b, k, c),
        }
    }
    fn trsv_serial(&self, b: &[f64], x: &mut [f64]) {
        trsv::ell_rowwise(self, b, x);
    }
    fn par_units(&self) -> usize {
        self.nrows
    }
    fn has_range_kernels(&self) -> bool {
        false // the dedicated prefix-building drivers own the split
    }
    // The row-length prefix is O(nrows) to recompute; the dedicated
    // driver builds it once per call instead of per balance probe.
    fn spmv_parallel(&self, t: Traversal, x: &[f64], y: &mut [f64], threads: usize) {
        if threads <= 1 {
            return self.spmv_serial(t, x, y);
        }
        par::ell_spmv(self, x, y, threads);
    }
    fn spmm_parallel(&self, t: Traversal, b: &[f64], k: usize, c: &mut [f64], threads: usize) {
        if threads <= 1 {
            return self.spmm_serial(t, b, k, c);
        }
        par::ell_spmm(self, b, k, c, threads);
    }
    // `lane_legal` admits ELL lanes only row-wise; the lane driver uses
    // the generic row split (uniform weights) with the wide row kernel.
    fn spmv_serial_lanes(&self, _t: Traversal, x: &[f64], y: &mut [f64], lanes: usize) {
        simd::ell_spmv(self, x, y, lanes);
    }
    fn spmv_range_lanes(
        &self,
        _t: Traversal,
        x: &[f64],
        y: &mut [f64],
        u0: usize,
        _u1: usize,
        lanes: usize,
    ) {
        simd::ell_spmv_rows(self, x, y, u0, lanes);
    }
    fn repair(&self, delta: &[DeltaEntry]) -> Option<Arc<dyn SparseOps>> {
        // Slot rewrites within the padding; `None` when the plane
        // width would change (caller rebuilds).
        Ell::repaired(self, delta).map(|e| Arc::new(e) as Arc<dyn SparseOps>)
    }
}

// ------------------------------------------------------------- JDS --

/// Jagged-diagonal storage + the per-diagonal row lists its unpermuted
/// traversal needs — bound together so the executor sees one format.
pub struct JdsOps {
    pub jds: Jds,
    pub rows: JdsRows,
}

impl SparseOps for JdsOps {
    fn slug(&self) -> String {
        if self.jds.permuted {
            "jds".into()
        } else {
            "jds-unperm".into()
        }
    }
    fn nrows(&self) -> usize {
        self.jds.nrows
    }
    fn ncols(&self) -> usize {
        self.jds.ncols
    }
    fn bytes(&self) -> usize {
        self.jds.bytes() + self.rows.bytes()
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        if self.jds.permuted {
            spmv::jds_permuted(&self.jds, x, y);
        } else {
            spmv::jds(&self.jds, &self.rows, x, y);
        }
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        spmm::jds(&self.jds, &self.rows, b, k, c);
    }
    fn par_units(&self) -> usize {
        if self.jds.permuted {
            self.jds.nrows
        } else {
            0
        }
    }
    fn has_range_kernels(&self) -> bool {
        false // the scatter drivers below own the split
    }
    // Permuted JDS accumulates into the permuted output and scatters
    // through `perm` once at the end — not a plain output split, so the
    // format owns its parallel drivers.
    fn spmv_parallel(&self, t: Traversal, x: &[f64], y: &mut [f64], threads: usize) {
        if !self.jds.permuted || threads <= 1 {
            return self.spmv_serial(t, x, y);
        }
        par::jds_spmv(&self.jds, x, y, threads);
    }
    fn spmm_parallel(&self, t: Traversal, b: &[f64], k: usize, c: &mut [f64], threads: usize) {
        if !self.jds.permuted || threads <= 1 {
            return self.spmm_serial(t, b, k, c);
        }
        par::jds_spmm(&self.jds, b, k, c, threads);
    }
    // JDS exposes units but no range kernels (the scatter drivers own
    // the split); keep hypothetical lane calls on the scalar drivers.
    fn spmv_parallel_lanes(
        &self,
        t: Traversal,
        x: &[f64],
        y: &mut [f64],
        threads: usize,
        _lanes: usize,
    ) {
        self.spmv_parallel(t, x, y, threads);
    }
    fn spmm_parallel_lanes(
        &self,
        t: Traversal,
        b: &[f64],
        k: usize,
        c: &mut [f64],
        threads: usize,
        _lanes: usize,
    ) {
        self.spmm_parallel(t, b, k, c, threads);
    }
}

// ------------------------------------------------------------ BCSR --

impl SparseOps for Bcsr {
    fn slug(&self) -> String {
        format!("bcsr{}x{}", self.br, self.bc)
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        Bcsr::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        spmv::bcsr(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        spmm::bcsr(self, b, k, c);
    }
    fn par_units(&self) -> usize {
        self.nblock_rows
    }
    fn rows_per_unit(&self) -> usize {
        self.br
    }
    fn unit_weight_prefix(&self, u: usize) -> usize {
        self.block_row_ptr[u] as usize
    }
    fn spmv_range(&self, _t: Traversal, x: &[f64], y: &mut [f64], u0: usize, u1: usize) {
        par::bcsr_block_rows(self, x, y, u0, u1, u0 * self.br);
    }
    fn spmm_range(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64], u0: usize, u1: usize) {
        par::bcsr_block_rows_mm(self, b, k, c, u0, u1, u0 * self.br);
    }
    fn supports_spmm_panel(&self) -> bool {
        true
    }
    fn spmm_panel(
        &self,
        _t: Traversal,
        b: &[f64],
        k: usize,
        c: &mut [f64],
        cols: Range<usize>,
        units: Range<usize>,
    ) {
        spmm::bcsr_panel(self, b, k, c, cols, units.start, units.end);
    }
}

// ---------------------------------------------------------- hybrid --

impl SparseOps for HybridEllCoo {
    fn slug(&self) -> String {
        "hyb".into()
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        HybridEllCoo::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        spmv::hybrid(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        spmm::hybrid(self, b, k, c);
    }
    fn trsv_serial(&self, b: &[f64], x: &mut [f64]) {
        trsv::hybrid(self, b, x);
    }
}

// ------------------------------------------------------------ SELL --

impl SparseOps for Sell {
    fn slug(&self) -> String {
        format!("sell{}", self.s)
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        Sell::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        sell::spmv(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        sell::spmm(self, b, k, c);
    }
    fn par_units(&self) -> usize {
        self.nslices
    }
    fn rows_per_unit(&self) -> usize {
        self.s
    }
    fn unit_weight_prefix(&self, u: usize) -> usize {
        self.slice_ptr[u] as usize
    }
    fn spmv_range(&self, _t: Traversal, x: &[f64], y: &mut [f64], u0: usize, u1: usize) {
        par::sell_slices(self, x, y, u0, u1, u0 * self.s);
    }
    fn spmm_range(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64], u0: usize, u1: usize) {
        par::sell_slices_mm(self, b, k, c, u0, u1, u0 * self.s);
    }
}

// ---------------------------------------------------------- SELL-σ --

// The extension-recipe litmus: one trait impl + one registry arm. The
// window permutation bounds the output scatter to its σ window, so
// slice-aligned windows (`σ % s == 0` — the chain mapping's σ = 8·s
// always is) are legal lock-free partition units and the litmus format
// joins the scheduled pool; unaligned constructions expose no units
// and stay serial.
impl SparseOps for SellSigma {
    fn slug(&self) -> String {
        format!("sell{}s{}", self.s, self.sigma)
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        SellSigma::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        sell_sigma::spmv(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64]) {
        sell_sigma::spmm(self, b, k, c);
    }
    fn par_units(&self) -> usize {
        if self.slices_per_window().is_some() {
            self.nwindows()
        } else {
            0
        }
    }
    fn rows_per_unit(&self) -> usize {
        self.sigma
    }
    fn unit_weight_prefix(&self, u: usize) -> usize {
        let spw = self.slices_per_window().expect("no units without alignment");
        self.slice_ptr[(u * spw).min(self.nslices)] as usize
    }
    fn spmv_range(&self, _t: Traversal, x: &[f64], y: &mut [f64], u0: usize, u1: usize) {
        sell_sigma::spmv_range(self, x, y, u0, u1, u0 * self.sigma);
    }
    fn spmm_range(&self, _t: Traversal, b: &[f64], k: usize, c: &mut [f64], u0: usize, u1: usize) {
        sell_sigma::spmm_range(self, b, k, c, u0, u1, u0 * self.sigma);
    }
    fn spmv_serial_lanes(&self, _t: Traversal, x: &[f64], y: &mut [f64], lanes: usize) {
        simd::sell_sigma_spmv(self, x, y, lanes);
    }
    fn spmv_range_lanes(
        &self,
        _t: Traversal,
        x: &[f64],
        y: &mut [f64],
        u0: usize,
        u1: usize,
        lanes: usize,
    ) {
        simd::sell_sigma_spmv_range(self, x, y, u0, u1, u0 * self.sigma, lanes);
    }
    fn repair(&self, delta: &[DeltaEntry]) -> Option<Arc<dyn SparseOps>> {
        // Update-only value patches; structural deltas rebuild.
        SellSigma::repaired(self, delta).map(|s| Arc::new(s) as Arc<dyn SparseOps>)
    }
}

// ------------------------------------------------------------- DIA --

impl SparseOps for Dia {
    fn slug(&self) -> String {
        "dia".into()
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn bytes(&self) -> usize {
        Dia::bytes(self)
    }
    fn spmv_serial(&self, _t: Traversal, x: &[f64], y: &mut [f64]) {
        spmv::dia(self, x, y);
    }
    fn spmm_serial(&self, _t: Traversal, _b: &[f64], _k: usize, _c: &mut [f64]) {
        panic!("SpMM over DIA pruned by the tree");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::TriMat;

    /// The fixed 8×8 reservoir the byte pins are computed against:
    /// row lengths [2,1,3,1,2,1,3,1], nnz = 14, row_max = 3,
    /// 5 distinct diagonals {-6,-3,-2,0,4}.
    fn fixed8() -> TriMat {
        let mut m = TriMat::new(8, 8);
        for (r, c, v) in [
            (0, 0, 1.0),
            (0, 4, 2.0),
            (1, 1, 3.0),
            (2, 0, 4.0),
            (2, 2, 5.0),
            (2, 6, 6.0),
            (3, 3, 7.0),
            (4, 4, 8.0),
            (4, 1, 9.0),
            (5, 5, 10.0),
            (6, 6, 11.0),
            (6, 0, 12.0),
            (6, 3, 13.0),
            (7, 7, 14.0),
        ] {
            m.push(r, c, v);
        }
        m
    }

    /// The dedupe satellite: `bytes()` now lives on the trait, so pin
    /// the exact per-format sizes once — any accidental re-derivation
    /// (like the executor's old hand-rolled JDS arm) shows up here.
    #[test]
    fn bytes_pinned_per_format_on_fixed_8x8() {
        let m = fixed8();
        let cases: Vec<(Box<dyn SparseOps>, usize)> = vec![
            // 14 tuples × 16B (padded ⟨u32,u32,f64⟩)
            (Box::new(CooAos::from_tuples(&m, CooOrder::RowMajor)), 224),
            // 14 × (4 + 4 + 8)
            (Box::new(CooSoa::from_tuples(&m, CooOrder::Unsorted)), 224),
            // row_ptr 9×4 + cols 14×4 + vals 14×8
            (Box::new(Csr::from_tuples(&m)), 204),
            // row_ptr 9×4 + pairs 14×16 (padded ⟨u32,f64⟩)
            (Box::new(CsrAos::from_tuples(&m)), 260),
            (Box::new(Csc::from_tuples(&m)), 204),
            (Box::new(CscAos::from_tuples(&m)), 260),
            // 8×3 padded slots ×12 + row_len 8×4 (both element orders)
            (Box::new(Ell::from_tuples(&m, EllOrder::RowMajor)), 320),
            (Box::new(Ell::from_tuples(&m, EllOrder::ColMajor)), 320),
            // Jds 216 (perm 32 + jd_ptr 16 + cols 56 + vals 112 +
            // diag_len 12) + JdsRows (8+4+2)×4 = 56
            (Box::new(build_jds(&m, true)), 272),
            (Box::new(build_jds(&m, false)), 272),
            // 10 2×2 blocks ×32 + block_cols 10×4 + block_row_ptr 5×4
            (Box::new(Bcsr::from_tuples(&m, 2, 2)), 380),
            // best cutoff 1: ELL head 8 slots (128B) + 6-entry COO tail
            (Box::new(HybridEllCoo::from_tuples(&m, None, EllOrder::ColMajor)), 224),
            // 2 slices of width 3: 24 slots ×12 + widths 2×4 +
            // slice_ptr 3×4 + row_len 8×4
            (Box::new(Sell::from_tuples(&m, 4)), 340),
            // full-window sort groups lengths [3,3,2,2|1,1,1,1]:
            // slices of width 3 and 1 → 16 slots ×12 + widths 2×4 +
            // slice_ptr 3×4 + row_len 8×4 + perm 8×4
            (Box::new(SellSigma::from_tuples(&m, 4, 8)), 276),
            // 5 diagonals: offsets 5×4 + planes 5×8 ×8
            (Box::new(Dia::from_tuples(&m)), 340),
        ];
        for (ops, want) in &cases {
            assert_eq!(ops.bytes(), *want, "{} bytes drifted", ops.slug());
            assert_eq!(ops.nrows(), 8);
            assert_eq!(ops.ncols(), 8);
        }
    }

    fn build_jds(m: &TriMat, permuted: bool) -> JdsOps {
        let jds = Jds::from_tuples(m, permuted);
        let rows = JdsRows::build(&jds, m);
        JdsOps { jds, rows }
    }

    /// Every layout variant the registry can build: the trait slug must
    /// never drift from `Layout::slug`.
    #[test]
    fn slugs_match_layout_slugs() {
        let m = fixed8();
        use crate::concretize::Layout;
        let pairs: Vec<(Box<dyn SparseOps>, Layout)> = vec![
            (Box::new(CooAos::from_tuples(&m, CooOrder::Unsorted)), {
                Layout::CooAos(CooOrder::Unsorted)
            }),
            (Box::new(CooAos::from_tuples(&m, CooOrder::RowMajor)), {
                Layout::CooAos(CooOrder::RowMajor)
            }),
            (Box::new(CooSoa::from_tuples(&m, CooOrder::ColMajor)), {
                Layout::CooSoa(CooOrder::ColMajor)
            }),
            (Box::new(Csr::from_tuples(&m)), Layout::Csr),
            (Box::new(CsrAos::from_tuples(&m)), Layout::CsrAos),
            (Box::new(Csc::from_tuples(&m)), Layout::Csc),
            (Box::new(CscAos::from_tuples(&m)), Layout::CscAos),
            (Box::new(Ell::from_tuples(&m, EllOrder::RowMajor)), Layout::Ell(EllOrder::RowMajor)),
            (Box::new(Ell::from_tuples(&m, EllOrder::ColMajor)), Layout::Ell(EllOrder::ColMajor)),
            (Box::new(build_jds(&m, true)), Layout::Jds { permuted: true }),
            (Box::new(build_jds(&m, false)), Layout::Jds { permuted: false }),
            (Box::new(Bcsr::from_tuples(&m, 2, 3)), Layout::Bcsr { br: 2, bc: 3 }),
            (Box::new(HybridEllCoo::from_tuples(&m, None, EllOrder::ColMajor)), {
                Layout::HybridEllCoo
            }),
            (Box::new(Sell::from_tuples(&m, 4)), Layout::Sell { s: 4 }),
            (Box::new(SellSigma::from_tuples(&m, 4, 32)), Layout::SellSigma { s: 4, sigma: 32 }),
            (Box::new(Dia::from_tuples(&m)), Layout::Dia),
        ];
        for (ops, layout) in &pairs {
            assert_eq!(ops.slug(), layout.slug());
        }
    }

    /// `spmv_batch` bit-identity across formats: every output column
    /// must carry exactly the bits of a solo `spmv_serial` on that
    /// column — for the gather/scatter default AND the CSR row-dot
    /// override. `==` on raw f64s, no tolerance.
    #[test]
    fn spmv_batch_columns_bitwise_equal_solo_spmv() {
        let m = fixed8();
        let k = 5;
        let b: Vec<f64> = (0..8 * k).map(|i| ((i * 11 % 17) as f64 - 8.0) * 0.3).collect();
        let formats: Vec<(Box<dyn SparseOps>, Traversal)> = vec![
            (Box::new(Csr::from_tuples(&m)), Traversal::RowWise),
            (Box::new(CsrAos::from_tuples(&m)), Traversal::RowWise),
            (Box::new(Ell::from_tuples(&m, EllOrder::RowMajor)), Traversal::RowWise),
            (Box::new(Csc::from_tuples(&m)), Traversal::ColScatter),
        ];
        for (ops, t) in &formats {
            let mut c = vec![f64::NAN; 8 * k];
            ops.spmv_batch(*t, &b, k, &mut c);
            for j in 0..k {
                let x: Vec<f64> = (0..8).map(|col| b[col * k + j]).collect();
                let mut y = vec![f64::NAN; 8];
                ops.spmv_serial(*t, &x, &mut y);
                let col: Vec<f64> = (0..8).map(|i| c[i * k + j]).collect();
                assert_eq!(col, y, "{} column {j} bits", ops.slug());
            }
        }
    }

    #[test]
    fn default_parallel_driver_splits_and_matches() {
        let m = fixed8();
        let x: Vec<f64> = (0..8).map(|i| i as f64 * 0.3 - 1.0).collect();
        let mut want = vec![0.0; 8];
        let csr = Csr::from_tuples(&m);
        csr.spmv_serial(Traversal::RowWise, &x, &mut want);
        for formats in [
            Box::new(Csr::from_tuples(&m)) as Box<dyn SparseOps>,
            Box::new(Sell::from_tuples(&m, 4)),
            Box::new(Bcsr::from_tuples(&m, 2, 2)),
        ] {
            for t in [1, 2, 3, 8] {
                let mut y = vec![0.0; 8];
                formats.spmv_parallel(Traversal::RowWise, &x, &mut y, t);
                crate::util::prop::assert_close(&y, &want, 1e-12)
                    .unwrap_or_else(|e| panic!("{} t={t}: {e}", formats.slug()));
            }
        }
    }
}
