//! Compressed Row Storage — the concretization of the chain
//! *orthogonalize(row) → loop-dependent materialization → structure
//! splitting → exact ℕ\* materialization → dimensionality reduction*
//! (paper Fig 8, gray path): nested sequences `PA[i][k]` flattened back
//! to back with a `PA_ptr` array.
//!
//! `CsrAos` is the same chain *without* structure splitting: the flat
//! sequence stores localized `⟨col, val⟩` pairs.

use crate::matrix::delta::{DeltaEntry, DeltaOp};
use crate::matrix::TriMat;

/// Split (SoA) CSR: `row_ptr`, `cols`, `vals`.
#[derive(Clone, Debug)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub row_ptr: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Csr {
    pub fn from_tuples(m: &TriMat) -> Self {
        let mut counts = vec![0u32; m.nrows + 1];
        for e in &m.entries {
            counts[e.row as usize + 1] += 1;
        }
        for i in 0..m.nrows {
            counts[i + 1] += counts[i];
        }
        let row_ptr = counts.clone();
        let nnz = m.nnz();
        let mut cols = vec![0u32; nnz];
        let mut vals = vec![0.0f64; nnz];
        let mut next = row_ptr.clone();
        // Fill per row; sort within row afterwards for deterministic layout.
        for e in &m.entries {
            let p = next[e.row as usize] as usize;
            cols[p] = e.col;
            vals[p] = e.val;
            next[e.row as usize] += 1;
        }
        // In-row sort by column (paper: inner order undefined; we pick
        // ascending for cache friendliness and reproducibility).
        let mut out = Csr { nrows: m.nrows, ncols: m.ncols, row_ptr, cols, vals };
        out.sort_rows();
        out
    }

    fn sort_rows(&mut self) {
        for i in 0..self.nrows {
            let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
            let mut idx: Vec<usize> = (s..e).collect();
            idx.sort_unstable_by_key(|&k| self.cols[k]);
            let c: Vec<u32> = idx.iter().map(|&k| self.cols[k]).collect();
            let v: Vec<f64> = idx.iter().map(|&k| self.vals[k]).collect();
            self.cols[s..e].copy_from_slice(&c);
            self.vals[s..e].copy_from_slice(&v);
        }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.row_ptr[i] as usize, self.row_ptr[i + 1] as usize);
        (&self.cols[s..e], &self.vals[s..e])
    }

    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.cols.len() * 4 + self.vals.len() * 8
    }

    /// Row splicing — the in-place-repair path of the versioned-matrix
    /// subsystem. `delta` must be resolved and `(row, col)`-sorted
    /// ([`crate::matrix::delta::DeltaBatch::resolved`]) and already
    /// validated against the source matrix. Each touched row is merged
    /// with its ops (both sides ascending by column) and spliced into
    /// fresh arrays; untouched rows are copied verbatim.
    ///
    /// Contract (pinned by `tests/delta.rs`): the result is
    /// **bit-identical** to `Csr::from_tuples` on the post-delta
    /// reservoir — both produce ascending-column rows carrying the
    /// exact value bits, so repair vs rebuild is unobservable
    /// downstream.
    pub fn repaired(&self, delta: &[DeltaEntry]) -> Csr {
        let grow = delta.iter().filter(|d| d.op == DeltaOp::Insert).count();
        let mut row_ptr = Vec::with_capacity(self.nrows + 1);
        let mut cols = Vec::with_capacity(self.cols.len() + grow);
        let mut vals = Vec::with_capacity(self.vals.len() + grow);
        row_ptr.push(0u32);
        let mut d = 0usize;
        for i in 0..self.nrows {
            let (rc, rv) = self.row(i);
            let d0 = d;
            while d < delta.len() && delta[d].row as usize == i {
                d += 1;
            }
            let ops = &delta[d0..d];
            if ops.is_empty() {
                cols.extend_from_slice(rc);
                vals.extend_from_slice(rv);
            } else {
                let (mut a, mut b) = (0usize, 0usize);
                while a < rc.len() || b < ops.len() {
                    if b >= ops.len() || (a < rc.len() && rc[a] < ops[b].col) {
                        cols.push(rc[a]);
                        vals.push(rv[a]);
                        a += 1;
                    } else if a >= rc.len() || ops[b].col < rc[a] {
                        // Absent column: a validated delta here is an
                        // insert.
                        cols.push(ops[b].col);
                        vals.push(ops[b].val);
                        b += 1;
                    } else {
                        // Present column: update replaces the value,
                        // delete drops the slot.
                        if ops[b].op != DeltaOp::Delete {
                            cols.push(rc[a]);
                            vals.push(ops[b].val);
                        }
                        a += 1;
                        b += 1;
                    }
                }
            }
            row_ptr.push(cols.len() as u32);
        }
        Csr { nrows: self.nrows, ncols: self.ncols, row_ptr, cols, vals }
    }
}

/// Per-band row splits for cache-blocked (`Schedule::Tiled`) CSR SpMV:
/// columns are partitioned into `x_block`-wide bands and, for each row,
/// the in-row position where each band starts is recorded (rows are
/// column-sorted, so a band's entries are contiguous within the row).
/// Built once at `prepare()` time; the two-pass kernel then walks one
/// band at a time so the `x` gather stays L2-resident (CSB-style).
#[derive(Clone, Debug)]
pub struct CsrBands {
    pub x_block: usize,
    pub nbands: usize,
    /// `(nbands + 1) × nrows`: `split[b * nrows + i]` is the global
    /// index (into `cols`/`vals`) of the first entry of row `i` whose
    /// column is ≥ `b * x_block`. Band `b` of row `i` spans
    /// `split[b * nrows + i] .. split[(b + 1) * nrows + i]`.
    pub split: Vec<u32>,
}

impl CsrBands {
    pub fn build(a: &Csr, x_block: usize) -> Self {
        assert!(x_block > 0);
        let nbands = a.ncols.div_ceil(x_block).max(1);
        let nrows = a.nrows;
        let mut split = vec![0u32; (nbands + 1) * nrows];
        for i in 0..nrows {
            let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
            let row_cols = &a.cols[s..e];
            split[i] = s as u32;
            for b in 1..=nbands {
                let bound = (b * x_block).min(u32::MAX as usize) as u32;
                let off = row_cols.partition_point(|&c| c < bound);
                split[b * nrows + i] = (s + off) as u32;
            }
        }
        CsrBands { x_block, nbands, split }
    }

    pub fn bytes(&self) -> usize {
        self.split.len() * 4
    }
}

/// Unsplit (AoS) CSR: flat sequence of `⟨col, val⟩` pairs + `row_ptr`.
#[derive(Clone, Debug)]
pub struct CsrAos {
    pub nrows: usize,
    pub ncols: usize,
    pub row_ptr: Vec<u32>,
    pub pairs: Vec<(u32, f64)>,
}

impl CsrAos {
    pub fn from_tuples(m: &TriMat) -> Self {
        let c = Csr::from_tuples(m);
        CsrAos {
            nrows: c.nrows,
            ncols: c.ncols,
            row_ptr: c.row_ptr.clone(),
            pairs: c.cols.iter().zip(c.vals.iter()).map(|(&a, &b)| (a, b)).collect(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.pairs.len()
    }

    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.pairs.len() * std::mem::size_of::<(u32, f64)>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn csr_roundtrip_dense() {
        let m = gen::uniform_random(25, 31, 180, 4);
        let c = Csr::from_tuples(&m);
        let mut d = vec![0.0; m.nrows * m.ncols];
        for i in 0..c.nrows {
            let (cols, vals) = c.row(i);
            for (j, v) in cols.iter().zip(vals.iter()) {
                d[i * c.ncols + *j as usize] += v;
            }
        }
        assert_eq!(d, m.to_dense());
    }

    #[test]
    fn row_ptr_monotone_and_total() {
        let m = gen::powerlaw(60, 2.0, 30, 5);
        let c = Csr::from_tuples(&m);
        assert_eq!(c.row_ptr.len(), m.nrows + 1);
        assert!(c.row_ptr.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(c.row_ptr[m.nrows] as usize, m.nnz());
    }

    #[test]
    fn rows_sorted_by_col() {
        let m = gen::uniform_random(20, 20, 120, 6);
        let c = Csr::from_tuples(&m);
        for i in 0..c.nrows {
            let (cols, _) = c.row(i);
            assert!(cols.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn aos_matches_soa() {
        let m = gen::uniform_random(20, 20, 100, 7);
        let s = Csr::from_tuples(&m);
        let a = CsrAos::from_tuples(&m);
        assert_eq!(a.row_ptr, s.row_ptr);
        for (i, &(c, v)) in a.pairs.iter().enumerate() {
            assert_eq!(c, s.cols[i]);
            assert_eq!(v, s.vals[i]);
        }
    }

    #[test]
    fn bands_partition_every_row() {
        let m = gen::uniform_random(30, 50, 400, 8);
        let c = Csr::from_tuples(&m);
        for xb in [1, 7, 16, 64, 1000] {
            let bands = CsrBands::build(&c, xb);
            assert_eq!(bands.nbands, c.ncols.div_ceil(xb).max(1));
            for i in 0..c.nrows {
                // Band starts are monotone and bracket the row exactly.
                assert_eq!(bands.split[i], c.row_ptr[i]);
                assert_eq!(bands.split[bands.nbands * c.nrows + i], c.row_ptr[i + 1]);
                for b in 0..bands.nbands {
                    let s = bands.split[b * c.nrows + i] as usize;
                    let e = bands.split[(b + 1) * c.nrows + i] as usize;
                    assert!(s <= e);
                    for k in s..e {
                        let col = c.cols[k] as usize;
                        assert!(col >= b * xb && col < (b + 1) * xb, "xb={xb} b={b}");
                    }
                }
            }
        }
    }

    #[test]
    fn empty_rows_ok() {
        let mut m = TriMat::new(5, 5);
        m.push(4, 0, 1.0);
        let c = Csr::from_tuples(&m);
        assert_eq!(c.row(0).0.len(), 0);
        assert_eq!(c.row(4).0, &[0]);
    }
}
