//! ELLPACK / ITPACK storage — the concretization of *orthogonalize(row) →
//! loop-dependent materialization → structure splitting → padded ℕ\*
//! materialization* (paper Fig 8 main path): every row padded to the
//! maximum row length K; `PA_len[q] = max(len(PA[q]))` so a single
//! rectangular (nrows × K) plane is allocated for values and one for
//! column indices.
//!
//! Two physical element orders correspond to applying *loop interchange*
//! after materialization or not (paper §5.2 / §6.2.2):
//! row-major (`EllOrder::RowMajor`) and column-major (`EllOrder::ColMajor`
//! — the classic ITPACK layout, and the MXU/VPU-friendly layout used by
//! the Pallas kernels in `python/compile/kernels/`).

use crate::matrix::delta::{DeltaEntry, DeltaOp};
use crate::matrix::TriMat;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EllOrder {
    /// `plane[i * k + p]` — row slots contiguous.
    RowMajor,
    /// `plane[p * nrows + i]` — slot-planes contiguous (ITPACK).
    ColMajor,
}

/// Padded rectangular storage. Padding slots carry `col = pad_col` (a
/// valid in-bounds column — conventionally 0 — paired with `val = 0.0`,
/// so kernels may process padding unconditionally without branching).
#[derive(Clone, Debug)]
pub struct Ell {
    pub nrows: usize,
    pub ncols: usize,
    pub k: usize,
    pub order: EllOrder,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
    /// Actual per-row lengths (the exact ℕ* sets, kept so kernels can
    /// also iterate without touching padding).
    pub row_len: Vec<u32>,
    /// Number of stored nonzeros (excludes padding).
    pub nnz: usize,
}

impl Ell {
    pub fn from_tuples(m: &TriMat, order: EllOrder) -> Self {
        let counts = m.row_counts();
        let k = counts.iter().copied().max().unwrap_or(0);
        let size = m.nrows * k;
        let mut cols = vec![0u32; size];
        let mut vals = vec![0.0f64; size];
        let mut fill = vec![0usize; m.nrows];
        // Deterministic slot order: sort row-major first.
        let mut t = m.clone();
        t.sort_row_major();
        let idx = |i: usize, p: usize| match order {
            EllOrder::RowMajor => i * k + p,
            EllOrder::ColMajor => p * m.nrows + i,
        };
        for e in &t.entries {
            let i = e.row as usize;
            let p = fill[i];
            cols[idx(i, p)] = e.col;
            vals[idx(i, p)] = e.val;
            fill[i] += 1;
        }
        Ell {
            nrows: m.nrows,
            ncols: m.ncols,
            k,
            order,
            cols,
            vals,
            row_len: counts.iter().map(|&c| c as u32).collect(),
            nnz: m.nnz(),
        }
    }

    #[inline]
    pub fn index(&self, i: usize, p: usize) -> usize {
        match self.order {
            EllOrder::RowMajor => i * self.k + p,
            EllOrder::ColMajor => p * self.nrows + i,
        }
    }

    /// Padding overhead ratio: stored slots / nonzeros.
    pub fn padding_ratio(&self) -> f64 {
        if self.nnz == 0 {
            return 1.0;
        }
        (self.nrows * self.k) as f64 / self.nnz as f64
    }

    pub fn bytes(&self) -> usize {
        self.cols.len() * 4 + self.vals.len() * 8 + self.row_len.len() * 4
    }

    /// Slot rewrites within the padding — the in-place-repair path of
    /// the versioned-matrix subsystem. `delta` must be resolved,
    /// `(row, col)`-sorted, and validated against the source matrix.
    ///
    /// Returns `None` when the post-delta **global** maximum row length
    /// differs from `self.k`: a fresh `from_tuples` would then choose a
    /// different plane width, so no in-padding rewrite can be
    /// bit-identical to it and the caller must rebuild. Otherwise each
    /// touched row's slots are rewritten with the merged
    /// ascending-column list, trailing stale slots re-zeroed to the
    /// padding convention (`col = 0`, `val = 0.0`), and the result is
    /// bit-identical to `from_tuples` on the post-delta reservoir.
    pub fn repaired(&self, delta: &[DeltaEntry]) -> Option<Ell> {
        // New per-row lengths first: the plane width must survive.
        let mut row_len = self.row_len.clone();
        let mut d = 0usize;
        while d < delta.len() {
            let i = delta[d].row as usize;
            match delta[d].op {
                DeltaOp::Insert => row_len[i] += 1,
                DeltaOp::Delete => row_len[i] -= 1,
                DeltaOp::Update => {}
            }
            d += 1;
        }
        let new_k = row_len.iter().copied().max().unwrap_or(0) as usize;
        if new_k != self.k {
            return None;
        }
        let mut out = Ell {
            nrows: self.nrows,
            ncols: self.ncols,
            k: self.k,
            order: self.order,
            cols: self.cols.clone(),
            vals: self.vals.clone(),
            row_len,
            nnz: self.nnz,
        };
        let mut d = 0usize;
        while d < delta.len() {
            let i = delta[d].row as usize;
            let d0 = d;
            while d < delta.len() && delta[d].row as usize == i {
                match delta[d].op {
                    DeltaOp::Insert => out.nnz += 1,
                    DeltaOp::Delete => out.nnz -= 1,
                    DeltaOp::Update => {}
                }
                d += 1;
            }
            let ops = &delta[d0..d];
            // Merge the old row (slots ascending by column) with its
            // ops into the rewritten slot list.
            let old_len = self.row_len[i] as usize;
            let mut merged: Vec<(u32, f64)> = Vec::with_capacity(self.k);
            let (mut a, mut b) = (0usize, 0usize);
            while a < old_len || b < ops.len() {
                let ac = (a < old_len).then(|| self.cols[self.index(i, a)]);
                if b >= ops.len() || ac.is_some_and(|c| c < ops[b].col) {
                    merged.push((self.cols[self.index(i, a)], self.vals[self.index(i, a)]));
                    a += 1;
                } else if ac.is_none() || ops[b].col < ac.unwrap_or(u32::MAX) {
                    merged.push((ops[b].col, ops[b].val));
                    b += 1;
                } else {
                    if ops[b].op != DeltaOp::Delete {
                        merged.push((ops[b].col, ops[b].val));
                    }
                    a += 1;
                    b += 1;
                }
            }
            for p in 0..self.k {
                let ix = out.index(i, p);
                match merged.get(p) {
                    Some(&(c, v)) => {
                        out.cols[ix] = c;
                        out.vals[ix] = v;
                    }
                    None => {
                        out.cols[ix] = 0;
                        out.vals[ix] = 0.0;
                    }
                }
            }
            out.row_len[i] = merged.len() as u32;
        }
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    fn dense_of(e: &Ell) -> Vec<f64> {
        let mut d = vec![0.0; e.nrows * e.ncols];
        for i in 0..e.nrows {
            for p in 0..e.row_len[i] as usize {
                let ix = e.index(i, p);
                d[i * e.ncols + e.cols[ix] as usize] += e.vals[ix];
            }
        }
        d
    }

    #[test]
    fn roundtrip_both_orders() {
        let m = gen::powerlaw(40, 2.0, 20, 12);
        for order in [EllOrder::RowMajor, EllOrder::ColMajor] {
            let e = Ell::from_tuples(&m, order);
            assert_eq!(dense_of(&e), m.to_dense());
            assert_eq!(e.k, m.max_row_nnz());
            assert_eq!(e.nnz, m.nnz());
        }
    }

    #[test]
    fn padding_is_zero_valued() {
        let m = gen::powerlaw(30, 2.2, 15, 13);
        let e = Ell::from_tuples(&m, EllOrder::ColMajor);
        for i in 0..e.nrows {
            for p in e.row_len[i] as usize..e.k {
                let ix = e.index(i, p);
                assert_eq!(e.vals[ix], 0.0);
                assert_eq!(e.cols[ix], 0);
            }
        }
    }

    #[test]
    fn padding_ratio_reflects_skew() {
        let skewed = gen::powerlaw(100, 1.8, 60, 14);
        let flat = gen::banded(100, 3, 1.0, 14);
        let es = Ell::from_tuples(&skewed, EllOrder::RowMajor);
        let ef = Ell::from_tuples(&flat, EllOrder::RowMajor);
        assert!(es.padding_ratio() > ef.padding_ratio());
    }

    #[test]
    fn empty_matrix() {
        let m = TriMat::new(4, 4);
        let e = Ell::from_tuples(&m, EllOrder::RowMajor);
        assert_eq!(e.k, 0);
        assert_eq!(e.padding_ratio(), 1.0);
    }
}
