//! Compressed Column Storage — the same chain as CSR but starting from
//! *orthogonalization on `col`* (paper §6.2.2: "a transformation sequence
//! that continues from orthogonalization on column … results in CCS").

use crate::matrix::TriMat;
use crate::storage::csr::Csr;

/// Split (SoA) CSC: `col_ptr`, `rows`, `vals`.
#[derive(Clone, Debug)]
pub struct Csc {
    pub nrows: usize,
    pub ncols: usize,
    pub col_ptr: Vec<u32>,
    pub rows: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Csc {
    pub fn from_tuples(m: &TriMat) -> Self {
        // CSC(A) is CSR(Aᵀ) with the index roles swapped.
        let t = m.transpose();
        let c = Csr::from_tuples(&t);
        Csc { nrows: m.nrows, ncols: m.ncols, col_ptr: c.row_ptr, rows: c.cols, vals: c.vals }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn col(&self, j: usize) -> (&[u32], &[f64]) {
        let (s, e) = (self.col_ptr[j] as usize, self.col_ptr[j + 1] as usize);
        (&self.rows[s..e], &self.vals[s..e])
    }

    pub fn bytes(&self) -> usize {
        self.col_ptr.len() * 4 + self.rows.len() * 4 + self.vals.len() * 8
    }
}

/// Unsplit (AoS) CSC.
#[derive(Clone, Debug)]
pub struct CscAos {
    pub nrows: usize,
    pub ncols: usize,
    pub col_ptr: Vec<u32>,
    pub pairs: Vec<(u32, f64)>,
}

impl CscAos {
    pub fn from_tuples(m: &TriMat) -> Self {
        let c = Csc::from_tuples(m);
        CscAos {
            nrows: c.nrows,
            ncols: c.ncols,
            col_ptr: c.col_ptr.clone(),
            pairs: c.rows.iter().zip(c.vals.iter()).map(|(&a, &b)| (a, b)).collect(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.pairs.len()
    }

    pub fn bytes(&self) -> usize {
        self.col_ptr.len() * 4 + self.pairs.len() * std::mem::size_of::<(u32, f64)>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    #[test]
    fn csc_roundtrip_dense() {
        let m = gen::uniform_random(17, 23, 120, 8);
        let c = Csc::from_tuples(&m);
        let mut d = vec![0.0; m.nrows * m.ncols];
        for j in 0..c.ncols {
            let (rows, vals) = c.col(j);
            for (i, v) in rows.iter().zip(vals.iter()) {
                d[*i as usize * c.ncols + j] += v;
            }
        }
        assert_eq!(d, m.to_dense());
    }

    #[test]
    fn col_ptr_total() {
        let m = gen::banded(40, 4, 0.5, 9);
        let c = Csc::from_tuples(&m);
        assert_eq!(c.col_ptr[m.ncols] as usize, m.nnz());
        assert!(c.col_ptr.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn cols_sorted_by_row() {
        let m = gen::uniform_random(30, 30, 200, 10);
        let c = Csc::from_tuples(&m);
        for j in 0..c.ncols {
            let (rows, _) = c.col(j);
            assert!(rows.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn aos_matches() {
        let m = gen::uniform_random(12, 12, 60, 11);
        let s = Csc::from_tuples(&m);
        let a = CscAos::from_tuples(&m);
        assert_eq!(a.col_ptr, s.col_ptr);
        assert_eq!(a.pairs.len(), s.nnz());
    }
}
