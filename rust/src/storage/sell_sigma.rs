//! Row-sigma-sorted Sliced ELLPACK (SELL-σ) — the format litmus test
//! for the `SparseOps` extension recipe: rows are sorted by descending
//! length *within windows of σ rows* (a bounded permutation, so the
//! output scatter stays cache-local), then sliced by `s` with each
//! slice padded to its own width, column-major within the slice. With
//! the slice widths tracking the locally-sorted maxima, the padding of
//! plain SELL collapses almost entirely on skewed matrices.
//!
//! Derivation: the paper's §6.2.3 blocking machinery with ℕ* sorting
//! applied to the sliced nest — `orthogonalize(row) → block(slice) →
//! materialize → nstar_sort` (`concretize::layout` maps the sorted +
//! row-sliced chain state here, with σ = 8·s).

use crate::matrix::delta::{DeltaEntry, DeltaOp};
use crate::matrix::TriMat;
use crate::storage::csr::Csr;

#[derive(Clone, Debug)]
pub struct SellSigma {
    pub nrows: usize,
    pub ncols: usize,
    /// Slice height (rows per block).
    pub s: usize,
    /// Sort-window height (rows sorted by length within each window).
    pub sigma: usize,
    pub nslices: usize,
    /// `perm[q]` = original row stored at sorted position `q`.
    pub perm: Vec<u32>,
    /// Per-slice width (max row length within the slice).
    pub widths: Vec<u32>,
    /// Start of each slice's payload in `cols`/`vals`
    /// (slice payload = widths[b] * rows_in_slice, column-major).
    pub slice_ptr: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
    /// Exact row lengths, indexed by *sorted position*.
    pub row_len: Vec<u32>,
    pub nnz: usize,
}

impl SellSigma {
    pub fn from_tuples(m: &TriMat, s: usize, sigma: usize) -> Self {
        assert!(s > 0 && sigma > 0);
        let csr = Csr::from_tuples(m);
        let lens: Vec<u32> =
            (0..m.nrows).map(|i| csr.row_ptr[i + 1] - csr.row_ptr[i]).collect();

        // Window-sort: rows within each σ window ordered by descending
        // length, ties by ascending row index (stable, deterministic).
        let mut perm: Vec<u32> = (0..m.nrows as u32).collect();
        for window in perm.chunks_mut(sigma) {
            window.sort_by(|&a, &b| {
                lens[b as usize].cmp(&lens[a as usize]).then(a.cmp(&b))
            });
        }
        let row_len: Vec<u32> = perm.iter().map(|&r| lens[r as usize]).collect();

        let nslices = m.nrows.div_ceil(s);
        let mut widths = Vec::with_capacity(nslices);
        let mut slice_ptr = vec![0u32; nslices + 1];
        for b in 0..nslices {
            let lo = b * s;
            let hi = ((b + 1) * s).min(m.nrows);
            let w = row_len[lo..hi].iter().copied().max().unwrap_or(0);
            widths.push(w);
            slice_ptr[b + 1] = slice_ptr[b] + w * (hi - lo) as u32;
        }
        let total = slice_ptr[nslices] as usize;
        let mut cols = vec![0u32; total];
        let mut vals = vec![0.0f64; total];
        for b in 0..nslices {
            let lo = b * s;
            let hi = ((b + 1) * s).min(m.nrows);
            let rows = hi - lo;
            let base = slice_ptr[b] as usize;
            for (ri, q) in (lo..hi).enumerate() {
                let orig = perm[q] as usize;
                let (rs, re) = (csr.row_ptr[orig] as usize, csr.row_ptr[orig + 1] as usize);
                for (p, k) in (rs..re).enumerate() {
                    // column-major within the slice: slot plane p, row ri
                    let ix = base + p * rows + ri;
                    cols[ix] = csr.cols[k];
                    vals[ix] = csr.vals[k];
                }
            }
        }
        SellSigma {
            nrows: m.nrows,
            ncols: m.ncols,
            s,
            sigma,
            nslices,
            perm,
            widths,
            slice_ptr,
            cols,
            vals,
            row_len,
            nnz: m.nnz(),
        }
    }

    /// Stored slots / nonzeros — must sit between CSR (1.0) and plain
    /// SELL with the same slice height.
    pub fn padding_ratio(&self) -> f64 {
        if self.nnz == 0 {
            return 1.0;
        }
        self.vals.len() as f64 / self.nnz as f64
    }

    pub fn bytes(&self) -> usize {
        self.perm.len() * 4
            + self.widths.len() * 4
            + self.slice_ptr.len() * 4
            + self.cols.len() * 4
            + self.row_len.len() * 4
            + self.vals.len() * 8
    }

    /// Slices per σ window when windows are slice-aligned (`σ % s == 0`
    /// — always true for the chain mapping's σ = 8·s), else `None`.
    /// Alignment is what makes the window a legal parallel unit: the
    /// sort permutation never crosses a window, so a whole-window range
    /// writes exactly its own contiguous σ rows of the output.
    pub fn slices_per_window(&self) -> Option<usize> {
        if self.sigma % self.s == 0 {
            Some(self.sigma / self.s)
        } else {
            None
        }
    }

    /// Number of σ windows (the parallel partition units).
    pub fn nwindows(&self) -> usize {
        self.nrows.div_ceil(self.sigma)
    }

    /// Value-slot rewrites — the in-place-repair path of the
    /// versioned-matrix subsystem, for **update-only** batches. `delta`
    /// must be resolved, `(row, col)`-sorted, and validated against the
    /// source matrix.
    ///
    /// Returns `None` if the batch contains any insert or delete: those
    /// change row lengths, which feed the window sort, the permutation,
    /// the slice widths and the payload offsets — a fresh `from_tuples`
    /// could lay the whole structure out differently, so only a rebuild
    /// is bit-identical. Updates keep every length fixed, so the sorted
    /// structure is provably unchanged and patching `vals` in place
    /// reproduces the fresh build exactly.
    pub fn repaired(&self, delta: &[DeltaEntry]) -> Option<SellSigma> {
        if delta.iter().any(|d| d.op != DeltaOp::Update) {
            return None;
        }
        // Invert the permutation: original row -> sorted position.
        let mut inv = vec![0u32; self.nrows];
        for (q, &orig) in self.perm.iter().enumerate() {
            inv[orig as usize] = q as u32;
        }
        let mut out = self.clone();
        for d in delta {
            let q = inv[d.row as usize] as usize;
            let b = q / self.s;
            let lo = b * self.s;
            let rows = ((b + 1) * self.s).min(self.nrows) - lo;
            let base = self.slice_ptr[b] as usize;
            for p in 0..self.row_len[q] as usize {
                let ix = base + p * rows + (q - lo);
                if self.cols[ix] == d.col {
                    out.vals[ix] = d.val;
                    break;
                }
            }
        }
        Some(out)
    }
}

/// SELL-σ SpMV: slice loop outer, slot plane loop, row-vector inner;
/// output scattered through `perm` (bounded by the σ window).
pub fn spmv(a: &SellSigma, x: &[f64], y: &mut [f64]) {
    for b in 0..a.nslices {
        let lo = b * a.s;
        let hi = ((b + 1) * a.s).min(a.nrows);
        let rows = hi - lo;
        let base = a.slice_ptr[b] as usize;
        let w = a.widths[b] as usize;
        for q in lo..hi {
            y[a.perm[q] as usize] = 0.0;
        }
        for p in 0..w {
            let plane = base + p * rows;
            for ri in 0..rows {
                if (p as u32) < a.row_len[lo + ri] {
                    let ix = plane + ri;
                    y[a.perm[lo + ri] as usize] += a.vals[ix] * x[a.cols[ix] as usize];
                }
            }
        }
    }
}

/// SELL-σ SpMV over the σ windows `[w0, w1)`: the slices of those
/// windows, scattering into the `y` chunk that starts at original row
/// `row0 = w0·σ`. Callers guarantee slice-aligned windows
/// (`slices_per_window().is_some()`, checked by `par_units`), so the
/// window-bounded permutation keeps every write inside the chunk.
pub fn spmv_range(a: &SellSigma, x: &[f64], y: &mut [f64], w0: usize, w1: usize, row0: usize) {
    let spw = a.slices_per_window().expect("window not slice-aligned");
    let sb1 = (w1 * spw).min(a.nslices);
    for sb in w0 * spw..sb1 {
        let lo = sb * a.s;
        let hi = ((sb + 1) * a.s).min(a.nrows);
        let rows = hi - lo;
        let base = a.slice_ptr[sb] as usize;
        let w = a.widths[sb] as usize;
        for q in lo..hi {
            y[a.perm[q] as usize - row0] = 0.0;
        }
        for p in 0..w {
            let plane = base + p * rows;
            for ri in 0..rows {
                if (p as u32) < a.row_len[lo + ri] {
                    let ix = plane + ri;
                    y[a.perm[lo + ri] as usize - row0] += a.vals[ix] * x[a.cols[ix] as usize];
                }
            }
        }
    }
}

/// SELL-σ SpMM over the σ windows `[w0, w1)` (see [`spmv_range`]).
pub fn spmm_range(
    a: &SellSigma,
    bm: &[f64],
    k: usize,
    c: &mut [f64],
    w0: usize,
    w1: usize,
    row0: usize,
) {
    let spw = a.slices_per_window().expect("window not slice-aligned");
    let sb1 = (w1 * spw).min(a.nslices);
    for sb in w0 * spw..sb1 {
        let lo = sb * a.s;
        let hi = ((sb + 1) * a.s).min(a.nrows);
        let rows = hi - lo;
        let base = a.slice_ptr[sb] as usize;
        let w = a.widths[sb] as usize;
        for q in lo..hi {
            let orig = a.perm[q] as usize - row0;
            c[orig * k..orig * k + k].fill(0.0);
        }
        for p in 0..w {
            let plane = base + p * rows;
            for ri in 0..rows {
                if (p as u32) >= a.row_len[lo + ri] {
                    continue;
                }
                let ix = plane + ri;
                let v = a.vals[ix];
                let col = a.cols[ix] as usize;
                let orig = a.perm[lo + ri] as usize - row0;
                let brow = &bm[col * k..col * k + k];
                let crow = &mut c[orig * k..orig * k + k];
                for j in 0..k {
                    crow[j] += v * brow[j];
                }
            }
        }
    }
}

/// SELL-σ SpMM.
pub fn spmm(a: &SellSigma, bm: &[f64], k: usize, c: &mut [f64]) {
    for b in 0..a.nslices {
        let lo = b * a.s;
        let hi = ((b + 1) * a.s).min(a.nrows);
        let rows = hi - lo;
        let base = a.slice_ptr[b] as usize;
        let w = a.widths[b] as usize;
        for q in lo..hi {
            let orig = a.perm[q] as usize;
            c[orig * k..orig * k + k].fill(0.0);
        }
        for p in 0..w {
            let plane = base + p * rows;
            for ri in 0..rows {
                if (p as u32) >= a.row_len[lo + ri] {
                    continue;
                }
                let ix = plane + ri;
                let v = a.vals[ix];
                let col = a.cols[ix] as usize;
                let orig = a.perm[lo + ri] as usize;
                let brow = &bm[col * k..col * k + k];
                let crow = &mut c[orig * k..orig * k + k];
                for j in 0..k {
                    crow[j] += v * brow[j];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::storage::sell::Sell;
    use crate::util::prop::assert_close;

    #[test]
    fn roundtrip_various_slices_and_windows() {
        let m = gen::powerlaw(70, 1.9, 35, 200);
        let x: Vec<f64> = (0..70).map(|i| (i as f64 * 0.17).sin() + 0.3).collect();
        let want = m.spmv_ref(&x);
        for s in [1, 4, 8, 32] {
            for sigma in [1, 8, 64, 256] {
                let a = SellSigma::from_tuples(&m, s, sigma);
                let mut y = vec![0.0; 70];
                spmv(&a, &x, &mut y);
                assert_close(&y, &want, 1e-10)
                    .unwrap_or_else(|e| panic!("s={s} sigma={sigma}: {e}"));
            }
        }
    }

    #[test]
    fn spmm_matches() {
        let m = gen::uniform_random(40, 45, 300, 201);
        let k = 5;
        let bm: Vec<f64> = (0..45 * k).map(|i| i as f64 * 0.01 - 0.2).collect();
        let want = m.spmm_ref(&bm, k);
        let a = SellSigma::from_tuples(&m, 8, 64);
        let mut c = vec![0.0; 40 * k];
        spmm(&a, &bm, k, &mut c);
        assert_close(&c, &want, 1e-10).unwrap();
    }

    #[test]
    fn sorting_beats_plain_sell_padding_on_skewed_rows() {
        let m = gen::powerlaw(128, 1.8, 60, 202);
        let sigma = SellSigma::from_tuples(&m, 16, 128);
        let plain = Sell::from_tuples(&m, 16);
        assert!(sigma.padding_ratio() >= 1.0 - 1e-12);
        assert!(
            sigma.padding_ratio() < plain.padding_ratio(),
            "sorted {} vs plain {}",
            sigma.padding_ratio(),
            plain.padding_ratio()
        );
    }

    #[test]
    fn sigma_one_equals_plain_sell_padding() {
        // A 1-row sort window is the identity permutation.
        let m = gen::powerlaw(60, 1.9, 30, 205);
        let sigma = SellSigma::from_tuples(&m, 8, 1);
        let plain = Sell::from_tuples(&m, 8);
        assert_eq!(sigma.perm, (0..60).collect::<Vec<u32>>());
        assert!((sigma.padding_ratio() - plain.padding_ratio()).abs() < 1e-12);
    }

    #[test]
    fn perm_is_a_permutation_and_window_bounded() {
        let m = gen::powerlaw(50, 2.0, 25, 203);
        let sigma = 16;
        let a = SellSigma::from_tuples(&m, 4, sigma);
        let mut seen = a.perm.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..50).collect::<Vec<u32>>());
        for (q, &orig) in a.perm.iter().enumerate() {
            assert_eq!(q / sigma, orig as usize / sigma, "row escaped its window");
        }
    }

    /// The parallel-promotion satellite: σ-aligned window ranges are a
    /// legal lock-free output split, and the generic parallel drivers
    /// must reproduce the serial result bit-for-bit shape-for-shape.
    #[test]
    fn window_ranges_match_serial_spmv_and_spmm() {
        use crate::concretize::Traversal;
        use crate::storage::SparseOps;
        let m = gen::powerlaw(90, 1.8, 45, 206);
        let x: Vec<f64> = (0..90).map(|i| (i as f64 * 0.13).sin() - 0.1).collect();
        let k = 3;
        let bm: Vec<f64> = (0..90 * k).map(|i| i as f64 * 0.02 - 0.8).collect();
        for (s, sigma) in [(4, 8), (8, 8), (8, 64), (16, 32)] {
            let a = SellSigma::from_tuples(&m, s, sigma);
            assert_eq!(a.slices_per_window(), Some(sigma / s));
            assert!(a.par_units() > 0, "aligned windows must partition");
            let mut want = vec![0.0; 90];
            spmv(&a, &x, &mut want);
            let mut want_c = vec![0.0; 90 * k];
            spmm(&a, &bm, k, &mut want_c);
            for t in [1, 2, 3, 7] {
                let mut y = vec![0.0; 90];
                a.spmv_parallel(Traversal::SlicePlane, &x, &mut y, t);
                assert_eq!(y, want, "s={s} sigma={sigma} t={t}: spmv bits differ");
                let mut c = vec![0.0; 90 * k];
                a.spmm_parallel(Traversal::SlicePlane, &bm, k, &mut c, t);
                assert_eq!(c, want_c, "s={s} sigma={sigma} t={t}: spmm bits differ");
            }
            // The weight prefix is the stored-slot prefix over windows.
            let nw = a.nwindows();
            assert_eq!(a.unit_weight_prefix(0), 0);
            assert_eq!(a.unit_weight_prefix(nw), a.vals.len());
        }
    }

    #[test]
    fn unaligned_windows_stay_serial() {
        use crate::concretize::Traversal;
        use crate::storage::SparseOps;
        // σ = 12 is not a multiple of s = 8: a window boundary cuts a
        // slice, so no lock-free output split exists.
        let m = gen::powerlaw(64, 2.0, 32, 207);
        let a = SellSigma::from_tuples(&m, 8, 12);
        assert_eq!(a.slices_per_window(), None);
        assert_eq!(a.par_units(), 0);
        let x: Vec<f64> = (0..64).map(|i| i as f64 * 0.05).collect();
        let mut want = vec![0.0; 64];
        spmv(&a, &x, &mut want);
        // The generic driver falls back to the serial nest.
        let mut y = vec![0.0; 64];
        a.spmv_parallel(Traversal::SlicePlane, &x, &mut y, 4);
        assert_eq!(y, want);
    }

    #[test]
    fn ragged_tail_slice() {
        let m = gen::uniform_random(37, 29, 150, 204);
        let x: Vec<f64> = (0..29).map(|i| i as f64 * 0.1).collect();
        let a = SellSigma::from_tuples(&m, 8, 32);
        assert_eq!(a.nslices, 5);
        assert!(a.bytes() > 0);
        let mut y = vec![0.0; 37];
        spmv(&a, &x, &mut y);
        assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
    }
}
