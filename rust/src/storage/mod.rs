//! Physical storage formats — every layout the transformation chains of
//! `transforms/` + `concretize/` can generate, (re)assembled from the
//! tuple reservoir (`matrix::TriMat`). Each submodule's doc comment names
//! the paper chain that derives it.

pub mod bcsr;
pub mod coo;
pub mod csc;
pub mod csr;
pub mod dia;
pub mod ell;
pub mod hybrid;
pub mod jds;
pub mod ops;
pub mod sell;
pub mod sell_sigma;

pub use bcsr::Bcsr;
pub use coo::{CooAos, CooOrder, CooSoa};
pub use csc::{Csc, CscAos};
pub use csr::{Csr, CsrAos, CsrBands};
pub use dia::Dia;
pub use ell::{Ell, EllOrder};
pub use hybrid::HybridEllCoo;
pub use jds::{Jds, JdsRows};
pub use ops::{JdsOps, SparseOps};
pub use sell::Sell;
pub use sell_sigma::SellSigma;
