//! Runtime artifacts: the shape-bucketed AOT executables `aot.py`
//! emitted (plain-text manifest, `file kernel nrows k ncols kcols` per
//! line — no JSON dependency offline) and the fitted cost-model
//! tuning profiles `forelem calibrate` persists
//! (`target/tuning/<arch>.profile`, auto-loaded by the CLI sweeps).

use std::path::{Path, PathBuf};

use crate::baselines::Kernel;
use crate::search::calibrate::Profile;

#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub file: String,
    pub kernel: Kernel,
    pub nrows: usize,
    pub k: usize,
    pub ncols: usize,
    pub kcols: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`. Returns an empty manifest if absent
    /// (the coordinator then runs native-only).
    pub fn load(dir: &Path) -> std::io::Result<Manifest> {
        let mpath = dir.join("manifest.txt");
        if !mpath.exists() {
            return Ok(Manifest { dir: dir.to_path_buf(), entries: Vec::new() });
        }
        let text = std::fs::read_to_string(&mpath)?;
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 6 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad manifest line: '{line}'"),
                ));
            }
            let kernel = match f[1] {
                "spmv" => Kernel::Spmv,
                "spmm" => Kernel::Spmm,
                other => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("unknown kernel '{other}'"),
                    ))
                }
            };
            let parse = |s: &str| -> std::io::Result<usize> {
                s.parse().map_err(|_| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad int '{s}'"))
                })
            };
            entries.push(ManifestEntry {
                file: f[0].to_string(),
                kernel,
                nrows: parse(f[2])?,
                k: parse(f[3])?,
                ncols: parse(f[4])?,
                kcols: parse(f[5])?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Default artifact dir: `$FORELEM_ARTIFACT_DIR` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FORELEM_ARTIFACT_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest bucket that fits (nrows, k, kcols) for `kernel`, if any.
    pub fn find_bucket(&self, kernel: Kernel, nrows: usize, k: usize, kcols: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kernel == kernel
                    && e.nrows >= nrows
                    && e.ncols >= nrows.max(1) // square buckets; operand len = ncols
                    && e.k >= k
                    && (kernel != Kernel::Spmm || e.kcols == kcols)
            })
            .min_by_key(|e| (e.nrows, e.k))
    }

    pub fn path_of(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

// ---------------------------------------------- tuning profiles -----

/// Directory the fitted cost-model profiles live in:
/// `$FORELEM_TUNING_DIR` or `target/tuning`.
pub fn tuning_dir() -> PathBuf {
    std::env::var("FORELEM_TUNING_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/tuning"))
}

/// Path a profile for `arch_slug` is persisted at, inside `dir`.
pub fn profile_path_in(dir: &Path, arch_slug: &str) -> PathBuf {
    dir.join(format!("{arch_slug}.profile"))
}

/// FNV-1a over the rendered profile body — the integrity check behind
/// the `checksum` trailer line.
fn profile_checksum(body: &str) -> u64 {
    let mut h = crate::util::fnv::Fnv1a::new();
    h.eat_bytes(body.as_bytes());
    h.finish()
}

/// Split a profile file into its body and the optional trailing
/// `checksum <hex>` line. The trailer must be the final line; profiles
/// written before the checksum era have none (and are accepted as-is,
/// the legacy contract).
fn split_checksum(text: &str) -> (&str, Option<&str>) {
    let t = text.strip_suffix('\n').unwrap_or(text);
    match t.rfind('\n') {
        Some(i) if t[i + 1..].starts_with("checksum ") => {
            (&text[..i + 1], Some(t[i + 1 + "checksum ".len()..].trim()))
        }
        None if t.starts_with("checksum ") => ("", Some(t["checksum ".len()..].trim())),
        _ => (text, None),
    }
}

/// Persist a fitted profile at an explicit `path` (parent created if
/// needed), with the FNV-1a `checksum` trailer [`load_profile_in`]
/// verifies.
pub fn save_profile_at(path: &Path, profile: &Profile) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let body = profile.render();
    std::fs::write(path, format!("{body}checksum {:016x}\n", profile_checksum(&body)))
}

/// Persist a fitted profile into `dir` (created if needed); returns
/// the written path.
pub fn save_profile_in(dir: &Path, profile: &Profile) -> std::io::Result<PathBuf> {
    let path = profile_path_in(dir, &profile.arch_slug);
    save_profile_at(&path, profile)?;
    Ok(path)
}

/// Persist a fitted profile into the default [`tuning_dir`].
pub fn save_profile(profile: &Profile) -> std::io::Result<PathBuf> {
    save_profile_in(&tuning_dir(), profile)
}

/// Load the profile for `arch_slug` from `dir`, if present and
/// parseable. A corrupt file — unparseable body *or* a `checksum`
/// trailer that doesn't match it — is reported on stderr and ignored
/// (the sweep then runs on the seed parameters; the engine records
/// `Health::SeedWeights`).
pub fn load_profile_in(dir: &Path, arch_slug: &str) -> Option<Profile> {
    let path = profile_path_in(dir, arch_slug);
    if let Err(e) = crate::faultpoint_io!("artifacts.load_profile") {
        eprintln!("ignoring tuning profile {}: {e}", path.display());
        return None;
    }
    let text = std::fs::read_to_string(&path).ok()?;
    let (body, trailer) = split_checksum(&text);
    if let Some(stored) = trailer {
        let computed = profile_checksum(body);
        if u64::from_str_radix(stored, 16) != Ok(computed) {
            eprintln!(
                "ignoring corrupt tuning profile {}: checksum mismatch (stored '{stored}', \
                 body hashes to {computed:016x})",
                path.display()
            );
            return None;
        }
    }
    match Profile::parse(body) {
        // A profile copied/renamed across architectures carries the
        // wrong structural shape (l2_bytes) — refuse it rather than
        // silently mis-ranking every gather-heavy plan.
        Ok(p) if p.arch_slug != arch_slug => {
            eprintln!(
                "ignoring tuning profile {}: fitted for '{}', requested '{arch_slug}'",
                path.display(),
                p.arch_slug
            );
            None
        }
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("ignoring corrupt tuning profile {}: {e}", path.display());
            None
        }
    }
}

/// Load the profile for `arch_slug` from the default [`tuning_dir`].
pub fn load_profile(arch_slug: &str) -> Option<Profile> {
    load_profile_in(&tuning_dir(), arch_slug)
}

// ------------------------------------------- autotune sample archive --

use crate::search::calibrate::{sample_to_json, Sample};

/// Path of the rolling autotune sample archive for `arch_slug` inside
/// `dir` — one `calibrate::sample_to_json` line per measured cell, the
/// same line format `forelem calibrate` (and `samples_from_json`)
/// consumes, so the serving-path archive feeds the refit loop directly.
pub fn samples_path_in(dir: &Path, arch_slug: &str) -> PathBuf {
    dir.join(format!("{arch_slug}.samples.jsonl"))
}

/// Path the corrupt lines of an archive are quarantined at by
/// [`load_samples_counted_in`] — kept next to the archive for
/// post-mortem inspection rather than silently discarded.
pub fn quarantine_path_in(dir: &Path, arch_slug: &str) -> PathBuf {
    dir.join(format!("{arch_slug}.samples.quarantine.jsonl"))
}

/// Append autotune measurements to the archive in `dir` (created if
/// needed); returns the archive path. The engine calls this after
/// every measured compile so serving traffic keeps accumulating
/// refit material.
///
/// The whole batch is rendered first and lands in one `O_APPEND`
/// `write_all`, so concurrent writers interleave at batch — not line —
/// granularity and a crash mid-call cannot leave a torn line for every
/// later load to trip over.
pub fn append_samples_in(
    dir: &Path,
    arch_slug: &str,
    samples: &[Sample],
) -> std::io::Result<PathBuf> {
    use std::io::Write;
    crate::faultpoint_io!("artifacts.append_samples")?;
    std::fs::create_dir_all(dir)?;
    let path = samples_path_in(dir, arch_slug);
    let mut batch = String::with_capacity(samples.len() * 160);
    for s in samples {
        batch.push_str(&sample_to_json(s));
        batch.push('\n');
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
    f.write_all(batch.as_bytes())?;
    Ok(path)
}

/// Append to the default [`tuning_dir`] archive.
pub fn append_samples(arch_slug: &str, samples: &[Sample]) -> std::io::Result<PathBuf> {
    append_samples_in(&tuning_dir(), arch_slug, samples)
}

/// A loaded sample archive plus its corruption tally.
#[derive(Clone, Debug, Default)]
pub struct SampleArchive {
    pub samples: Vec<Sample>,
    /// Non-empty archive lines that failed to parse as samples. They
    /// are copied to [`quarantine_path_in`] and surfaced by
    /// `forelem calibrate` — a nonzero count means refit material is
    /// being lost to corruption, which used to disappear into
    /// `unwrap_or_default()`.
    pub corrupt_lines: usize,
}

/// Load the archive for `arch_slug` in `dir` with strict per-line
/// accounting: every non-empty line must parse as a sample, failures
/// are counted and quarantined. Absent archive → empty; an IO error or
/// a parser panic is reported on stderr and treated as absent — this
/// sits on the calibrate path and must never take the process down.
pub fn load_samples_counted_in(dir: &Path, arch_slug: &str) -> SampleArchive {
    use crate::search::calibrate::sample_from_json_line;
    let path = samples_path_in(dir, arch_slug);
    let loaded = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| -> SampleArchive {
        if let Err(e) = crate::faultpoint_io!("artifacts.load_samples") {
            eprintln!("ignoring sample archive {}: {e}", path.display());
            return SampleArchive::default();
        }
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return SampleArchive::default(),
            Err(e) => {
                eprintln!("ignoring sample archive {}: {e}", path.display());
                return SampleArchive::default();
            }
        };
        let mut archive = SampleArchive::default();
        let mut corrupt: Vec<&str> = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match sample_from_json_line(line) {
                Some(s) => archive.samples.push(s),
                None => corrupt.push(line),
            }
        }
        archive.corrupt_lines = corrupt.len();
        if !corrupt.is_empty() {
            // Best effort — quarantine failing must not fail the load.
            let mut body = corrupt.join("\n");
            body.push('\n');
            let _ = std::fs::write(quarantine_path_in(dir, arch_slug), body);
        }
        archive
    }));
    loaded.unwrap_or_else(|_| {
        eprintln!("sample archive loader panicked; treating {} as absent", path.display());
        SampleArchive::default()
    })
}

/// Load every sample archived for `arch_slug` in `dir` (empty if the
/// archive does not exist; corrupt lines are quarantined — use
/// [`load_samples_counted_in`] to observe the count).
pub fn load_samples_in(dir: &Path, arch_slug: &str) -> Vec<Sample> {
    load_samples_counted_in(dir, arch_slug).samples
}

/// Load the default [`tuning_dir`] archive for `arch_slug`.
pub fn load_samples(arch_slug: &str) -> Vec<Sample> {
    load_samples_in(&tuning_dir(), arch_slug)
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    #[test]
    fn parses_and_finds_buckets() {
        let dir = std::env::temp_dir().join("forelem_manifest_test");
        write_manifest(
            &dir,
            "# comment\n\
             ell_spmv_n2048_k8.hlo.txt spmv 2048 8 2048 1\n\
             ell_spmv_n8192_k8.hlo.txt spmv 8192 8 8192 1\n\
             ell_spmv_n2048_k32.hlo.txt spmv 2048 32 2048 1\n\
             ell_spmm_n2048_k8_c100.hlo.txt spmm 2048 8 2048 100\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 4);
        // exact fit
        let b = m.find_bucket(Kernel::Spmv, 2048, 8, 1).unwrap();
        assert_eq!(b.nrows, 2048);
        // needs bigger k
        let b = m.find_bucket(Kernel::Spmv, 1000, 20, 1).unwrap();
        assert_eq!((b.nrows, b.k), (2048, 32));
        // too big
        assert!(m.find_bucket(Kernel::Spmv, 100_000, 8, 1).is_none());
        // spmm kcols must match
        assert!(m.find_bucket(Kernel::Spmm, 1000, 8, 100).is_some());
        assert!(m.find_bucket(Kernel::Spmm, 1000, 8, 50).is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_manifest_is_empty() {
        let dir = std::env::temp_dir().join("forelem_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.is_empty());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("forelem_manifest_bad");
        write_manifest(&dir, "only three fields\n");
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    /// The ISSUE's round-trip property: a fitted profile persisted
    /// through the artifact store reloads bit-for-bit — including
    /// weights with no short decimal representation.
    #[test]
    fn profile_roundtrip_through_disk_is_lossless() {
        use crate::search::cost::CostParams;
        let dir = std::env::temp_dir().join("forelem_tuning_roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let mut params = CostParams::host_large(8);
        // Perturb to awkward bit patterns (1/3, subnormal-ish tails).
        for (i, w) in params.weights.iter_mut().enumerate() {
            *w = (*w + 1e-13) / 3.0 + i as f64 * 1.7e-17;
        }
        let p = Profile::from_params("host-large", &params, 99);
        let path = save_profile_in(&dir, &p).expect("save");
        assert!(path.ends_with("host-large.profile"));
        let q = load_profile_in(&dir, "host-large").expect("load");
        assert_eq!(p, q);
        assert_eq!(q.params_for(8).weights, params.weights);
        // Absent and corrupt profiles both come back as None.
        assert!(load_profile_in(&dir, "host-small").is_none());
        std::fs::write(dir.join("host-small.profile"), "arch host-small\n").unwrap();
        assert!(load_profile_in(&dir, "host-small").is_none());
        // A profile renamed across architectures is refused: its
        // structural l2_bytes belongs to the other machine.
        std::fs::copy(dir.join("host-large.profile"), dir.join("host-small.profile")).unwrap();
        assert!(load_profile_in(&dir, "host-small").is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    /// The checksum trailer: tampering with a persisted profile is
    /// detected and the load falls back to None (→ seed weights),
    /// while trailer-less legacy profiles stay loadable.
    #[test]
    fn profile_checksum_rejects_tampering_accepts_legacy() {
        use crate::search::cost::CostParams;
        let dir = std::env::temp_dir().join("forelem_tuning_checksum");
        let _ = std::fs::remove_dir_all(&dir);
        let p = Profile::from_params("host-small", &CostParams::host_small(), 7);
        let path = save_profile_in(&dir, &p).expect("save");
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.lines().last().unwrap().starts_with("checksum "), "trailer present");
        assert_eq!(load_profile_in(&dir, "host-small"), Some(p.clone()));
        // Flip one byte of the body: the trailer no longer matches.
        let tampered = text.replacen("samples 7", "samples 8", 1);
        assert_ne!(tampered, text, "tamper target must exist");
        std::fs::write(&path, tampered).unwrap();
        assert!(load_profile_in(&dir, "host-small").is_none(), "bad checksum refused");
        // A legacy profile (no trailer) still loads.
        std::fs::write(&path, p.render()).unwrap();
        assert_eq!(load_profile_in(&dir, "host-small"), Some(p));
        let _ = std::fs::remove_dir_all(dir);
    }

    /// Strict archive accounting: corrupt lines are counted and
    /// quarantined next to the archive instead of silently dropping
    /// (or worse, dropping the whole archive).
    #[test]
    fn counted_load_quarantines_corrupt_lines() {
        use crate::search::cost::N_FEATURES;
        use std::io::Write;
        // This test crosses live fault points; in a chaos build, keep
        // it out of another test's armed window.
        #[cfg(feature = "chaos")]
        let _guard = crate::chaos::test_arming_guard();
        let dir = std::env::temp_dir().join("forelem_sample_quarantine");
        let _ = std::fs::remove_dir_all(&dir);
        let mk = |i: usize| Sample {
            matrix: format!("m{i}"),
            plan_id: "csr.row.serial".into(),
            features: [2.0e6; N_FEATURES],
            measured_secs: 1e-4,
            predicted_secs: 2e-4,
        };
        let path = append_samples_in(&dir, "host-small", &[mk(0), mk(1)]).expect("append");
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        writeln!(f, "{{\"matrix\": \"torn").unwrap();
        writeln!(f, "not json at all").unwrap();
        drop(f);
        let archive = load_samples_counted_in(&dir, "host-small");
        assert_eq!(archive.samples.len(), 2, "good lines survive the corruption");
        assert_eq!(archive.corrupt_lines, 2);
        let q = std::fs::read_to_string(quarantine_path_in(&dir, "host-small")).unwrap();
        assert_eq!(q.lines().count(), 2, "corrupt lines preserved for inspection");
        // The plain loader agrees, and an absent archive is clean.
        assert_eq!(load_samples_in(&dir, "host-small").len(), 2);
        let absent = load_samples_counted_in(&dir, "no-such-arch");
        assert!(absent.samples.is_empty());
        assert_eq!(absent.corrupt_lines, 0);
        let _ = std::fs::remove_dir_all(dir);
    }

    /// The serving-path archive: appended autotune samples round-trip
    /// through the line format and accumulate across appends.
    #[test]
    fn sample_archive_appends_and_reloads() {
        use crate::search::cost::N_FEATURES;
        // This test crosses live fault points; in a chaos build, keep
        // it out of another test's armed window.
        #[cfg(feature = "chaos")]
        let _guard = crate::chaos::test_arming_guard();
        let dir = std::env::temp_dir().join("forelem_sample_archive_test");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(load_samples_in(&dir, "host-large").is_empty());
        let mk = |i: usize| Sample {
            matrix: format!("m{i}"),
            plan_id: "csr.row.par4".into(),
            features: [1.5e6 + i as f64; N_FEATURES],
            measured_secs: 1e-4 * (i + 1) as f64,
            predicted_secs: 2e-4,
        };
        let p1 = append_samples_in(&dir, "host-large", &[mk(0), mk(1)]).expect("append");
        assert!(p1.ends_with("host-large.samples.jsonl"));
        append_samples_in(&dir, "host-large", &[mk(2)]).expect("append again");
        let got = load_samples_in(&dir, "host-large");
        assert_eq!(got.len(), 3);
        assert_eq!(got[2], mk(2), "samples must round-trip bit-exactly");
        // Per-arch isolation.
        assert!(load_samples_in(&dir, "host-small").is_empty());
        let _ = std::fs::remove_dir_all(dir);
    }
}
