//! Artifact manifest: the shape-bucketed executables `aot.py` emitted.
//! Plain-text manifest (`file kernel nrows k ncols kcols` per line) —
//! no JSON dependency offline.

use std::path::{Path, PathBuf};

use crate::baselines::Kernel;

#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub file: String,
    pub kernel: Kernel,
    pub nrows: usize,
    pub k: usize,
    pub ncols: usize,
    pub kcols: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`. Returns an empty manifest if absent
    /// (the coordinator then runs native-only).
    pub fn load(dir: &Path) -> std::io::Result<Manifest> {
        let mpath = dir.join("manifest.txt");
        if !mpath.exists() {
            return Ok(Manifest { dir: dir.to_path_buf(), entries: Vec::new() });
        }
        let text = std::fs::read_to_string(&mpath)?;
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 6 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad manifest line: '{line}'"),
                ));
            }
            let kernel = match f[1] {
                "spmv" => Kernel::Spmv,
                "spmm" => Kernel::Spmm,
                other => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("unknown kernel '{other}'"),
                    ))
                }
            };
            let parse = |s: &str| -> std::io::Result<usize> {
                s.parse().map_err(|_| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad int '{s}'"))
                })
            };
            entries.push(ManifestEntry {
                file: f[0].to_string(),
                kernel,
                nrows: parse(f[2])?,
                k: parse(f[3])?,
                ncols: parse(f[4])?,
                kcols: parse(f[5])?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Default artifact dir: `$FORELEM_ARTIFACT_DIR` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FORELEM_ARTIFACT_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest bucket that fits (nrows, k, kcols) for `kernel`, if any.
    pub fn find_bucket(&self, kernel: Kernel, nrows: usize, k: usize, kcols: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kernel == kernel
                    && e.nrows >= nrows
                    && e.ncols >= nrows.max(1) // square buckets; operand len = ncols
                    && e.k >= k
                    && (kernel != Kernel::Spmm || e.kcols == kcols)
            })
            .min_by_key(|e| (e.nrows, e.k))
    }

    pub fn path_of(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    #[test]
    fn parses_and_finds_buckets() {
        let dir = std::env::temp_dir().join("forelem_manifest_test");
        write_manifest(
            &dir,
            "# comment\n\
             ell_spmv_n2048_k8.hlo.txt spmv 2048 8 2048 1\n\
             ell_spmv_n8192_k8.hlo.txt spmv 8192 8 8192 1\n\
             ell_spmv_n2048_k32.hlo.txt spmv 2048 32 2048 1\n\
             ell_spmm_n2048_k8_c100.hlo.txt spmm 2048 8 2048 100\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 4);
        // exact fit
        let b = m.find_bucket(Kernel::Spmv, 2048, 8, 1).unwrap();
        assert_eq!(b.nrows, 2048);
        // needs bigger k
        let b = m.find_bucket(Kernel::Spmv, 1000, 20, 1).unwrap();
        assert_eq!((b.nrows, b.k), (2048, 32));
        // too big
        assert!(m.find_bucket(Kernel::Spmv, 100_000, 8, 1).is_none());
        // spmm kcols must match
        assert!(m.find_bucket(Kernel::Spmm, 1000, 8, 100).is_some());
        assert!(m.find_bucket(Kernel::Spmm, 1000, 8, 50).is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_manifest_is_empty() {
        let dir = std::env::temp_dir().join("forelem_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.is_empty());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("forelem_manifest_bad");
        write_manifest(&dir, "only three fields\n");
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
