//! Runtime artifacts: the shape-bucketed AOT executables `aot.py`
//! emitted (plain-text manifest, `file kernel nrows k ncols kcols` per
//! line — no JSON dependency offline) and the fitted cost-model
//! tuning profiles `forelem calibrate` persists
//! (`target/tuning/<arch>.profile`, auto-loaded by the CLI sweeps).

use std::path::{Path, PathBuf};

use crate::baselines::Kernel;
use crate::search::calibrate::Profile;

#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub file: String,
    pub kernel: Kernel,
    pub nrows: usize,
    pub k: usize,
    pub ncols: usize,
    pub kcols: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Parse `<dir>/manifest.txt`. Returns an empty manifest if absent
    /// (the coordinator then runs native-only).
    pub fn load(dir: &Path) -> std::io::Result<Manifest> {
        let mpath = dir.join("manifest.txt");
        if !mpath.exists() {
            return Ok(Manifest { dir: dir.to_path_buf(), entries: Vec::new() });
        }
        let text = std::fs::read_to_string(&mpath)?;
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 6 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad manifest line: '{line}'"),
                ));
            }
            let kernel = match f[1] {
                "spmv" => Kernel::Spmv,
                "spmm" => Kernel::Spmm,
                other => {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("unknown kernel '{other}'"),
                    ))
                }
            };
            let parse = |s: &str| -> std::io::Result<usize> {
                s.parse().map_err(|_| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad int '{s}'"))
                })
            };
            entries.push(ManifestEntry {
                file: f[0].to_string(),
                kernel,
                nrows: parse(f[2])?,
                k: parse(f[3])?,
                ncols: parse(f[4])?,
                kcols: parse(f[5])?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// Default artifact dir: `$FORELEM_ARTIFACT_DIR` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FORELEM_ARTIFACT_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Smallest bucket that fits (nrows, k, kcols) for `kernel`, if any.
    pub fn find_bucket(&self, kernel: Kernel, nrows: usize, k: usize, kcols: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| {
                e.kernel == kernel
                    && e.nrows >= nrows
                    && e.ncols >= nrows.max(1) // square buckets; operand len = ncols
                    && e.k >= k
                    && (kernel != Kernel::Spmm || e.kcols == kcols)
            })
            .min_by_key(|e| (e.nrows, e.k))
    }

    pub fn path_of(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

// ---------------------------------------------- tuning profiles -----

/// Directory the fitted cost-model profiles live in:
/// `$FORELEM_TUNING_DIR` or `target/tuning`.
pub fn tuning_dir() -> PathBuf {
    std::env::var("FORELEM_TUNING_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("target/tuning"))
}

/// Path a profile for `arch_slug` is persisted at, inside `dir`.
pub fn profile_path_in(dir: &Path, arch_slug: &str) -> PathBuf {
    dir.join(format!("{arch_slug}.profile"))
}

/// Persist a fitted profile into `dir` (created if needed); returns
/// the written path.
pub fn save_profile_in(dir: &Path, profile: &Profile) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = profile_path_in(dir, &profile.arch_slug);
    std::fs::write(&path, profile.render())?;
    Ok(path)
}

/// Persist a fitted profile into the default [`tuning_dir`].
pub fn save_profile(profile: &Profile) -> std::io::Result<PathBuf> {
    save_profile_in(&tuning_dir(), profile)
}

/// Load the profile for `arch_slug` from `dir`, if present and
/// parseable. A corrupt file is reported on stderr and ignored (the
/// sweep then runs on the seed parameters).
pub fn load_profile_in(dir: &Path, arch_slug: &str) -> Option<Profile> {
    let path = profile_path_in(dir, arch_slug);
    let text = std::fs::read_to_string(&path).ok()?;
    match Profile::parse(&text) {
        // A profile copied/renamed across architectures carries the
        // wrong structural shape (l2_bytes) — refuse it rather than
        // silently mis-ranking every gather-heavy plan.
        Ok(p) if p.arch_slug != arch_slug => {
            eprintln!(
                "ignoring tuning profile {}: fitted for '{}', requested '{arch_slug}'",
                path.display(),
                p.arch_slug
            );
            None
        }
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("ignoring corrupt tuning profile {}: {e}", path.display());
            None
        }
    }
}

/// Load the profile for `arch_slug` from the default [`tuning_dir`].
pub fn load_profile(arch_slug: &str) -> Option<Profile> {
    load_profile_in(&tuning_dir(), arch_slug)
}

// ------------------------------------------- autotune sample archive --

use crate::search::calibrate::{sample_to_json, samples_from_json, Sample};

/// Path of the rolling autotune sample archive for `arch_slug` inside
/// `dir` — one `calibrate::sample_to_json` line per measured cell, the
/// same line format `forelem calibrate` (and `samples_from_json`)
/// consumes, so the serving-path archive feeds the refit loop directly.
pub fn samples_path_in(dir: &Path, arch_slug: &str) -> PathBuf {
    dir.join(format!("{arch_slug}.samples.jsonl"))
}

/// Append autotune measurements to the archive in `dir` (created if
/// needed); returns the archive path. The engine calls this after
/// every measured compile so serving traffic keeps accumulating
/// refit material.
pub fn append_samples_in(
    dir: &Path,
    arch_slug: &str,
    samples: &[Sample],
) -> std::io::Result<PathBuf> {
    use std::io::Write;
    std::fs::create_dir_all(dir)?;
    let path = samples_path_in(dir, arch_slug);
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
    for s in samples {
        writeln!(f, "{}", sample_to_json(s))?;
    }
    Ok(path)
}

/// Append to the default [`tuning_dir`] archive.
pub fn append_samples(arch_slug: &str, samples: &[Sample]) -> std::io::Result<PathBuf> {
    append_samples_in(&tuning_dir(), arch_slug, samples)
}

/// Load every sample archived for `arch_slug` in `dir` (empty if the
/// archive does not exist — the parser skips malformed lines).
pub fn load_samples_in(dir: &Path, arch_slug: &str) -> Vec<Sample> {
    std::fs::read_to_string(samples_path_in(dir, arch_slug))
        .map(|t| samples_from_json(&t))
        .unwrap_or_default()
}

/// Load the default [`tuning_dir`] archive for `arch_slug`.
pub fn load_samples(arch_slug: &str) -> Vec<Sample> {
    load_samples_in(&tuning_dir(), arch_slug)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), body).unwrap();
    }

    #[test]
    fn parses_and_finds_buckets() {
        let dir = std::env::temp_dir().join("forelem_manifest_test");
        write_manifest(
            &dir,
            "# comment\n\
             ell_spmv_n2048_k8.hlo.txt spmv 2048 8 2048 1\n\
             ell_spmv_n8192_k8.hlo.txt spmv 8192 8 8192 1\n\
             ell_spmv_n2048_k32.hlo.txt spmv 2048 32 2048 1\n\
             ell_spmm_n2048_k8_c100.hlo.txt spmm 2048 8 2048 100\n",
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 4);
        // exact fit
        let b = m.find_bucket(Kernel::Spmv, 2048, 8, 1).unwrap();
        assert_eq!(b.nrows, 2048);
        // needs bigger k
        let b = m.find_bucket(Kernel::Spmv, 1000, 20, 1).unwrap();
        assert_eq!((b.nrows, b.k), (2048, 32));
        // too big
        assert!(m.find_bucket(Kernel::Spmv, 100_000, 8, 1).is_none());
        // spmm kcols must match
        assert!(m.find_bucket(Kernel::Spmm, 1000, 8, 100).is_some());
        assert!(m.find_bucket(Kernel::Spmm, 1000, 8, 50).is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_manifest_is_empty() {
        let dir = std::env::temp_dir().join("forelem_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.is_empty());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn rejects_malformed_lines() {
        let dir = std::env::temp_dir().join("forelem_manifest_bad");
        write_manifest(&dir, "only three fields\n");
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(dir);
    }

    /// The ISSUE's round-trip property: a fitted profile persisted
    /// through the artifact store reloads bit-for-bit — including
    /// weights with no short decimal representation.
    #[test]
    fn profile_roundtrip_through_disk_is_lossless() {
        use crate::search::cost::CostParams;
        let dir = std::env::temp_dir().join("forelem_tuning_roundtrip");
        let _ = std::fs::remove_dir_all(&dir);
        let mut params = CostParams::host_large(8);
        // Perturb to awkward bit patterns (1/3, subnormal-ish tails).
        for (i, w) in params.weights.iter_mut().enumerate() {
            *w = (*w + 1e-13) / 3.0 + i as f64 * 1.7e-17;
        }
        let p = Profile::from_params("host-large", &params, 99);
        let path = save_profile_in(&dir, &p).expect("save");
        assert!(path.ends_with("host-large.profile"));
        let q = load_profile_in(&dir, "host-large").expect("load");
        assert_eq!(p, q);
        assert_eq!(q.params_for(8).weights, params.weights);
        // Absent and corrupt profiles both come back as None.
        assert!(load_profile_in(&dir, "host-small").is_none());
        std::fs::write(dir.join("host-small.profile"), "arch host-small\n").unwrap();
        assert!(load_profile_in(&dir, "host-small").is_none());
        // A profile renamed across architectures is refused: its
        // structural l2_bytes belongs to the other machine.
        std::fs::copy(dir.join("host-large.profile"), dir.join("host-small.profile")).unwrap();
        assert!(load_profile_in(&dir, "host-small").is_none());
        let _ = std::fs::remove_dir_all(dir);
    }

    /// The serving-path archive: appended autotune samples round-trip
    /// through the line format and accumulate across appends.
    #[test]
    fn sample_archive_appends_and_reloads() {
        use crate::search::cost::N_FEATURES;
        let dir = std::env::temp_dir().join("forelem_sample_archive_test");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(load_samples_in(&dir, "host-large").is_empty());
        let mk = |i: usize| Sample {
            matrix: format!("m{i}"),
            plan_id: "csr.row.par4".into(),
            features: [1.5e6 + i as f64; N_FEATURES],
            measured_secs: 1e-4 * (i + 1) as f64,
            predicted_secs: 2e-4,
        };
        let p1 = append_samples_in(&dir, "host-large", &[mk(0), mk(1)]).expect("append");
        assert!(p1.ends_with("host-large.samples.jsonl"));
        append_samples_in(&dir, "host-large", &[mk(2)]).expect("append again");
        let got = load_samples_in(&dir, "host-large");
        assert_eq!(got.len(), 3);
        assert_eq!(got[2], mk(2), "samples must round-trip bit-exactly");
        // Per-arch isolation.
        assert!(load_samples_in(&dir, "host-small").is_empty());
        let _ = std::fs::remove_dir_all(dir);
    }
}
