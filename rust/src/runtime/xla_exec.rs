//! The XLA execution backend: compiles bucketed HLO-text artifacts on
//! the PJRT CPU client (once, cached) and runs generated padded-ELL
//! SpMV/SpMM through them.
//!
//! This is the second "architecture" of the evaluation (DESIGN.md §5):
//! a genuinely different execution pipeline — AOT-compiled XLA vs
//! natively compiled Rust — over the same generated data structures.
//! Kernels compute in f32 (the MXU-realistic dtype); the backend
//! downcasts f64 inputs and upcasts results, so callers compare against
//! the native f64 path with a relative tolerance (~1e-4).

// PjRtLoadedExecutable is neither Send nor Sync; the Arc is used only for
// cheap intra-thread cache sharing (measurement is single-threaded).
#![allow(clippy::arc_with_non_send_sync)]

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::baselines::Kernel;
use crate::runtime::artifacts::{Manifest, ManifestEntry};
use crate::storage::Ell;

pub struct XlaBackend {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl XlaBackend {
    /// Create the backend; errors if PJRT cannot initialize. An empty
    /// manifest is allowed (every call will report no-bucket).
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(XlaBackend { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Load from the default artifact dir.
    pub fn from_default_dir() -> Result<Self> {
        let dir = Manifest::default_dir();
        let manifest = Manifest::load(&dir).context("loading manifest")?;
        Self::new(manifest)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn executable(&self, entry: &ManifestEntry) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let mut cache = self.cache.lock().unwrap_or_else(|p| p.into_inner());
        if let Some(e) = cache.get(&entry.file) {
            return Ok(e.clone());
        }
        let path = self.manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 artifact path"))?,
        )
        .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {}", entry.file))?;
        let exe = std::sync::Arc::new(exe);
        cache.insert(entry.file.clone(), exe.clone());
        Ok(exe)
    }

    /// Can this backend run `kernel` for an ELL structure of this shape?
    pub fn bucket_for(&self, kernel: Kernel, nrows: usize, k: usize, kcols: usize) -> Option<&ManifestEntry> {
        if k == 0 {
            return None;
        }
        self.manifest.find_bucket(kernel, nrows, k, kcols)
    }

    /// Pad ELL planes to the bucket's (nrows × k), row-major f32.
    fn pad_planes(ell: &Ell, b_rows: usize, b_k: usize) -> (Vec<f32>, Vec<i32>) {
        let mut vals = vec![0.0f32; b_rows * b_k];
        let mut cols = vec![0i32; b_rows * b_k];
        for i in 0..ell.nrows {
            for p in 0..ell.row_len[i] as usize {
                let src = ell.index(i, p);
                let dst = i * b_k + p;
                vals[dst] = ell.vals[src] as f32;
                cols[dst] = ell.cols[src] as i32;
            }
        }
        (vals, cols)
    }

    /// SpMV via the AOT executable. `x.len() == ell.ncols`; returns
    /// `ell.nrows` outputs. Fails if no bucket fits.
    pub fn spmv(&self, ell: &Ell, x: &[f64]) -> Result<Vec<f64>> {
        let entry = self
            .bucket_for(Kernel::Spmv, ell.nrows.max(ell.ncols), ell.k, 1)
            .ok_or_else(|| anyhow!("no spmv bucket for n={} k={}", ell.nrows.max(ell.ncols), ell.k))?
            .clone();
        let exe = self.executable(&entry)?;
        let (vals, cols) = Self::pad_planes(ell, entry.nrows, entry.k);
        let mut xpad = vec![0.0f32; entry.ncols];
        for (i, &v) in x.iter().enumerate() {
            xpad[i] = v as f32;
        }
        let lv = xla::Literal::vec1(&vals).reshape(&[entry.nrows as i64, entry.k as i64])?;
        let lc = xla::Literal::vec1(&cols).reshape(&[entry.nrows as i64, entry.k as i64])?;
        let lx = xla::Literal::vec1(&xpad);
        let result = exe.execute::<xla::Literal>(&[lv, lc, lx])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let y32 = out.to_vec::<f32>()?;
        Ok(y32[..ell.nrows].iter().map(|&v| v as f64).collect())
    }

    /// SpMM via the AOT executable; `b` is (ncols × kcols) row-major.
    pub fn spmm(&self, ell: &Ell, b: &[f64], kcols: usize) -> Result<Vec<f64>> {
        let entry = self
            .bucket_for(Kernel::Spmm, ell.nrows.max(ell.ncols), ell.k, kcols)
            .ok_or_else(|| anyhow!("no spmm bucket for n={} k={} c={kcols}", ell.nrows.max(ell.ncols), ell.k))?
            .clone();
        let exe = self.executable(&entry)?;
        let (vals, cols) = Self::pad_planes(ell, entry.nrows, entry.k);
        let mut bpad = vec![0.0f32; entry.ncols * kcols];
        for r in 0..ell.ncols {
            for c in 0..kcols {
                bpad[r * kcols + c] = b[r * kcols + c] as f32;
            }
        }
        let lv = xla::Literal::vec1(&vals).reshape(&[entry.nrows as i64, entry.k as i64])?;
        let lc = xla::Literal::vec1(&cols).reshape(&[entry.nrows as i64, entry.k as i64])?;
        let lb = xla::Literal::vec1(&bpad).reshape(&[entry.ncols as i64, kcols as i64])?;
        let result = exe.execute::<xla::Literal>(&[lv, lc, lb])?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        let c32 = out.to_vec::<f32>()?;
        Ok(c32[..ell.nrows * kcols].iter().map(|&v| v as f64).collect())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::storage::EllOrder;

    fn backend() -> Option<XlaBackend> {
        // Tests run from the workspace root; artifacts may not be built
        // in minimal environments — skip gracefully then.
        let b = XlaBackend::from_default_dir().ok()?;
        if b.manifest.entries.is_empty() {
            return None;
        }
        Some(b)
    }

    #[test]
    fn xla_spmv_matches_native() {
        let Some(b) = backend() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let m = gen::powerlaw(500, 2.0, 30, 70);
        let ell = Ell::from_tuples(&m, EllOrder::ColMajor);
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.01).sin()).collect();
        let want = m.spmv_ref(&x);
        let got = b.spmv(&ell, &x).unwrap();
        for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
            let scale = w.abs().max(1.0);
            assert!((g - w).abs() < 2e-4 * scale, "row {i}: {g} vs {w}");
        }
    }

    #[test]
    fn xla_spmm_matches_native() {
        let Some(b) = backend() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let m = gen::banded(300, 4, 0.7, 71);
        let ell = Ell::from_tuples(&m, EllOrder::ColMajor);
        let kcols = 100;
        let bmat: Vec<f64> = (0..m.ncols * kcols).map(|i| ((i % 37) as f64 - 18.0) * 0.05).collect();
        let want = m.spmm_ref(&bmat, kcols);
        let got = b.spmm(&ell, &bmat, kcols).unwrap();
        for i in 0..want.len() {
            let scale = want[i].abs().max(1.0);
            assert!((got[i] - want[i]).abs() < 5e-4 * scale, "elem {i}: {} vs {}", got[i], want[i]);
        }
    }

    #[test]
    fn no_bucket_for_huge_k() {
        let Some(b) = backend() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        assert!(b.bucket_for(Kernel::Spmv, 1000, 1000, 1).is_none());
    }
}
