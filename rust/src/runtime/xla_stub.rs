//! Offline stand-in for the PJRT/XLA backend (`xla_exec.rs`), compiled
//! when the `xla` cargo feature is disabled. The real backend binds the
//! external `xla` crate, which cannot be resolved in offline builds;
//! this stub exposes the same API surface but always reports the
//! backend as unavailable, so `tables::try_xla()` returns `None` and
//! every sweep degrades to native-only — exactly the path all callers
//! already handle when artifacts are absent.

use crate::baselines::Kernel;
use crate::runtime::artifacts::{Manifest, ManifestEntry};
use crate::storage::Ell;

/// Error carried by every stub operation.
#[derive(Debug)]
pub struct XlaUnavailable;

impl std::fmt::Display for XlaUnavailable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "XLA backend unavailable: built without the `xla` cargo feature")
    }
}

impl std::error::Error for XlaUnavailable {}

type Result<T> = std::result::Result<T, XlaUnavailable>;

/// API-compatible stub for `xla_exec::XlaBackend`; unconstructible in
/// practice because both constructors fail.
pub struct XlaBackend {
    pub manifest: Manifest,
}

impl XlaBackend {
    pub fn new(_manifest: Manifest) -> Result<Self> {
        Err(XlaUnavailable)
    }

    pub fn from_default_dir() -> Result<Self> {
        Err(XlaUnavailable)
    }

    pub fn platform(&self) -> String {
        "stub (xla feature disabled)".to_string()
    }

    pub fn bucket_for(
        &self,
        _kernel: Kernel,
        _nrows: usize,
        _k: usize,
        _kcols: usize,
    ) -> Option<&ManifestEntry> {
        None
    }

    pub fn spmv(&self, _ell: &Ell, _x: &[f64]) -> Result<Vec<f64>> {
        Err(XlaUnavailable)
    }

    pub fn spmm(&self, _ell: &Ell, _b: &[f64], _kcols: usize) -> Result<Vec<f64>> {
        Err(XlaUnavailable)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(XlaBackend::from_default_dir().is_err());
        let err = XlaBackend::from_default_dir().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
