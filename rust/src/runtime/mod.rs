//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the `xla` crate's CPU
//! client. Python never runs here — the artifacts are self-contained.

pub mod artifacts;
pub mod xla_exec;

pub use artifacts::{Manifest, ManifestEntry};
pub use xla_exec::XlaBackend;
