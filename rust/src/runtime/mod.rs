//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the `xla` crate's CPU
//! client. Python never runs here — the artifacts are self-contained.
//!
//! The real backend requires the external `xla` (and `anyhow`) crates,
//! which offline builds cannot resolve; without the `xla` cargo feature
//! an API-compatible stub is compiled instead and the backend simply
//! reports itself absent (sweeps degrade to native-only).

// Runtime artifact IO sits on the serving path: every failure must be
// a typed Result or a logged degradation, never a panic (ISSUE 6).
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod artifacts;
pub mod topology;

#[cfg(feature = "xla")]
pub mod xla_exec;
#[cfg(not(feature = "xla"))]
#[path = "xla_stub.rs"]
pub mod xla_exec;

pub use artifacts::{Manifest, ManifestEntry};
pub use xla_exec::XlaBackend;
