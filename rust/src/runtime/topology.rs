//! Machine topology for the worker crew: NUMA node discovery and
//! worker→CPU pinning.
//!
//! Discovery parses `/sys/devices/system/node/node*/cpulist` (Linux);
//! on any other platform — or when sysfs is unreadable — it degrades
//! to a single node spanning the machine's available parallelism, so
//! every consumer sees a well-formed topology. Detection runs once per
//! process and is *always* compiled: the planner's structural
//! `sockets` knob (`search::cost::CostParams`) reads the detected node
//! count regardless of build flavor, because cross-socket traffic is a
//! property of the machine, not of whether pinning is enabled.
//!
//! Pinning is the `numa` cargo feature (same zero-dependency precedent
//! as `simd`): on Linux it issues a raw `sched_setaffinity` syscall
//! binding crew worker `i` to CPU `cpus[i % cpus.len()]` of the
//! node-major CPU list. Without the feature (or off Linux)
//! [`pin_worker`] is a no-op returning `false`. The worker→CPU map is
//! deterministic, which is what lets the first-touch pass
//! (`concretize::exec::Prepared::first_touch`) guarantee the worker
//! that touches a partition range is the worker that serves it.

use std::sync::OnceLock;

/// The detected machine topology (one instance per process).
#[derive(Clone, Debug)]
pub struct Topology {
    /// NUMA nodes with at least one CPU; 1 on single-node machines and
    /// wherever sysfs is unavailable.
    pub sockets: usize,
    /// Online CPU ids in node-major order: node 0's CPUs first, then
    /// node 1's, … — crew worker `i` maps to `cpus[i % cpus.len()]`.
    pub cpus: Vec<usize>,
}

/// Detect (once) and return the machine topology.
pub fn detect() -> &'static Topology {
    static TOPO: OnceLock<Topology> = OnceLock::new();
    TOPO.get_or_init(|| from_nodes(read_sysfs_nodes()))
}

/// Detected NUMA node count (≥ 1).
pub fn sockets() -> usize {
    detect().sockets
}

/// Whether this build pins crew workers (`numa` feature on Linux).
pub fn pinning_active() -> bool {
    cfg!(all(feature = "numa", target_os = "linux"))
}

/// Whether the NUMA placement layer is live: pinning compiled in *and*
/// more than one node detected. Gates the engine's first-touch pass —
/// on a single-node box the pass would only add prepare latency.
pub fn numa_active() -> bool {
    pinning_active() && sockets() > 1
}

/// CPU assigned to crew worker `idx` (deterministic round-robin over
/// the node-major CPU list).
pub fn cpu_for_worker(idx: usize) -> Option<usize> {
    let t = detect();
    if t.cpus.is_empty() {
        None
    } else {
        Some(t.cpus[idx % t.cpus.len()])
    }
}

/// Pin the calling thread to crew worker `idx`'s CPU. Returns whether
/// a pin was applied — always `false` without the `numa` feature or
/// off Linux, and best-effort on it (a failed syscall leaves the
/// thread unpinned rather than failing the caller).
pub fn pin_worker(idx: usize) -> bool {
    #[cfg(all(feature = "numa", target_os = "linux"))]
    {
        match cpu_for_worker(idx) {
            Some(cpu) => affinity::pin(cpu),
            None => false,
        }
    }
    #[cfg(not(all(feature = "numa", target_os = "linux")))]
    {
        let _ = idx;
        false
    }
}

/// Raw `sched_setaffinity` binding — declared directly (libc is always
/// linked; the crate stays dependency-free).
#[cfg(all(feature = "numa", target_os = "linux"))]
mod affinity {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }

    /// Bind the calling thread (pid 0) to a single CPU. The fixed
    /// 1024-bit mask matches glibc's `cpu_set_t`.
    pub fn pin(cpu: usize) -> bool {
        const WORDS: usize = 16;
        if cpu >= WORDS * 64 {
            return false;
        }
        let mut mask = [0u64; WORDS];
        mask[cpu / 64] |= 1u64 << (cpu % 64);
        unsafe { sched_setaffinity(0, std::mem::size_of::<[u64; WORDS]>(), mask.as_ptr()) == 0 }
    }
}

/// Build a topology from parsed sysfs nodes, falling back to a single
/// node over the machine's available parallelism.
fn from_nodes(nodes: Option<Vec<Vec<usize>>>) -> Topology {
    match nodes {
        Some(nodes) if !nodes.is_empty() => Topology {
            sockets: nodes.len(),
            cpus: nodes.into_iter().flatten().collect(),
        },
        _ => {
            let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
            Topology { sockets: 1, cpus: (0..n).collect() }
        }
    }
}

/// Per-node CPU lists from `/sys/devices/system/node`, `None` when the
/// directory or any node's `cpulist` is unreadable (non-Linux, sysfs
/// masked in a container, …).
fn read_sysfs_nodes() -> Option<Vec<Vec<usize>>> {
    let dir = std::fs::read_dir("/sys/devices/system/node").ok()?;
    let mut ids: Vec<usize> = dir
        .flatten()
        .filter_map(|e| {
            let name = e.file_name().into_string().ok()?;
            name.strip_prefix("node")?.parse::<usize>().ok()
        })
        .collect();
    ids.sort_unstable();
    let mut nodes = Vec::new();
    for id in ids {
        let path = format!("/sys/devices/system/node/node{id}/cpulist");
        let list = std::fs::read_to_string(path).ok()?;
        let cpus = parse_cpulist(list.trim());
        if !cpus.is_empty() {
            nodes.push(cpus);
        }
    }
    Some(nodes)
}

/// Parse a kernel cpulist (`"0-3,8,10-11"`) into CPU ids. Malformed
/// fragments are skipped; ranges are bounded so a corrupt file cannot
/// allocate unboundedly.
fn parse_cpulist(s: &str) -> Vec<usize> {
    const MAX_RANGE: usize = 4096;
    let mut cpus = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<usize>(), b.trim().parse::<usize>()) {
                if a <= b && b - a < MAX_RANGE {
                    cpus.extend(a..=b);
                }
            }
        } else if let Ok(v) = part.parse::<usize>() {
            cpus.push(v);
        }
    }
    cpus
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn parses_kernel_cpulists() {
        assert_eq!(parse_cpulist("0-3"), vec![0, 1, 2, 3]);
        assert_eq!(parse_cpulist("0-1,4,6-7"), vec![0, 1, 4, 6, 7]);
        assert_eq!(parse_cpulist("5"), vec![5]);
        assert_eq!(parse_cpulist(""), Vec::<usize>::new());
        assert_eq!(parse_cpulist(" 2 , 9 - 10 "), vec![2, 9, 10]);
        // Malformed fragments are skipped, not fatal.
        assert_eq!(parse_cpulist("x,3,4-z"), vec![3]);
        // Inverted and absurd ranges are rejected.
        assert_eq!(parse_cpulist("7-3"), Vec::<usize>::new());
        assert_eq!(parse_cpulist("0-99999999"), Vec::<usize>::new());
    }

    #[test]
    fn multi_node_topology_is_node_major() {
        let t = from_nodes(Some(vec![vec![0, 1, 2, 3], vec![8, 9, 10, 11]]));
        assert_eq!(t.sockets, 2);
        assert_eq!(t.cpus, vec![0, 1, 2, 3, 8, 9, 10, 11]);
    }

    #[test]
    fn fallback_is_single_node() {
        for nodes in [None, Some(Vec::new())] {
            let t = from_nodes(nodes);
            assert_eq!(t.sockets, 1);
            assert!(!t.cpus.is_empty());
            assert_eq!(t.cpus[0], 0);
        }
    }

    #[test]
    fn detected_topology_is_well_formed() {
        // Whatever the host looks like: at least one node, at least one
        // CPU, and a total worker mapping.
        let t = detect();
        assert!(t.sockets >= 1);
        assert!(!t.cpus.is_empty());
        assert!(sockets() >= 1);
        for idx in [0usize, 1, 7, 63] {
            assert!(cpu_for_worker(idx).is_some());
        }
    }

    #[test]
    fn numa_active_implies_pinning_and_nodes() {
        if numa_active() {
            assert!(pinning_active());
            assert!(sockets() > 1);
        }
        // pin_worker never panics, whatever the build flavor.
        let _ = pin_worker(0);
    }
}
