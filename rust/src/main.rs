//! forelem CLI — the L3 entrypoint.
//!
//! ```text
//! forelem enumerate [--kernel spmv|spmm|trsv]     Fig 10 tree report
//! forelem derive                                  Fig 8 derivation chains (IR at each step)
//! forelem codegen --variant ID [--kernel spmv]    generated C-like code for a plan
//!                                                 (stable id like csr.row.serial, or vNNN rank)
//! forelem table1|table2|table3 [--quick]          paper reduction tables (both archs)
//! forelem table4|table5|fig11  [--quick]          coverage / selection analyses
//! forelem bench-all [--quick] [--out FILE]        everything, appended to FILE
//! forelem bench-json [--shortlist K]              BENCH_spmv.json + planner audit + samples
//! forelem calibrate [FILES…] [--arch A] [--check] fit a tuning profile from BENCH_*.json
//! forelem suite                                   print the 20-matrix suite statistics
//! ```

use forelem::baselines::Kernel;
use forelem::bench::tables;
use forelem::coordinator::sweep::{Arch, SweepConfig, DEFAULT_X_BLOCK};
use forelem::search::plan::PlanSpace;
use forelem::util::cli::Args;

fn kernel_of(args: &Args) -> Kernel {
    match args.get_or("kernel", "spmv") {
        "spmv" => Kernel::Spmv,
        "spmm" => Kernel::Spmm,
        "trsv" => Kernel::Trsv,
        other => {
            eprintln!("unknown kernel '{other}' (spmv|spmm|trsv)");
            std::process::exit(2);
        }
    }
}

fn sweep_cfg(args: &Args) -> SweepConfig {
    let mut cfg = if args.flag("quick") { SweepConfig::quick() } else { SweepConfig::default() };
    if let Some(k) = args.get("spmm-k") {
        cfg.spmm_k = k.parse().expect("--spmm-k integer");
    }
    if let Some(n) = args.get("matrices") {
        let n: usize = n.parse().expect("--matrices integer");
        cfg.matrices = Some((0..n.min(20)).collect());
    }
    // Opt into the schedule axis (parallel / cache-blocked generated
    // kernels on the HostLarge arch; HostSmall stays single-core).
    cfg.use_schedules = args.flag("schedules");
    // Predict→measure shortlist: time only the top-K cost-ranked plans
    // per matrix. 0 (default) = exhaustive, paper protocol.
    cfg.shortlist = args.get_usize("shortlist", 0);
    // CLI sweeps auto-load the fitted tuning profile when one exists
    // (target/tuning/<arch>.profile, written by `forelem calibrate`);
    // --no-profile ranks on the seed parameters instead (capture-aware
    // so `--no-profile ARG` orderings can't silently re-enable it).
    let (no_profile, swallowed) = args.flag_with_capture("no-profile");
    if let Some(tok) = swallowed {
        eprintln!("warning: '--no-profile {tok}' — '{tok}' was not used (sweeps take no positional args)");
    }
    cfg.use_profile = !no_profile;
    cfg
}

fn emit(args: &Args, text: &str) {
    println!("{text}");
    if let Some(path) = args.get("out") {
        tables::record(path, text).expect("writing --out file");
    }
}

fn cmd_derive() -> String {
    use forelem::forelem::ir::{NStarMat, Orth};
    use forelem::forelem::{build, pretty};
    use forelem::transforms::{apply_chain, Step};
    let chains: Vec<(&str, Vec<Step>)> = vec![
        (
            "Fig 8 main path → ITPACK (ELL column-major)",
            vec![
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::Split,
                Step::NStar(NStarMat::Padded),
                Step::Interchange,
            ],
        ),
        (
            "Fig 8 gray path → CSR",
            vec![
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::Split,
                Step::NStar(NStarMat::Exact),
                Step::DimReduce,
            ],
        ),
        (
            "column start → CCS",
            vec![
                Step::Orthogonalize(Orth::Col),
                Step::Materialize,
                Step::Split,
                Step::NStar(NStarMat::Exact),
                Step::DimReduce,
            ],
        ),
        (
            "ℕ*-sorted + interchange → JDS",
            vec![
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::Split,
                Step::NStarSort,
                Step::NStar(NStarMat::Exact),
                Step::Interchange,
                Step::DimReduce,
            ],
        ),
    ];
    let mut out = String::from("## Fig 5/6/7 — the paper-faithful kernel specifications\n");
    out.push_str(&pretty::render(&forelem::forelem::specs::spmv_fig5()));
    for p in forelem::forelem::specs::trsv_fig6() {
        out.push('\n');
        out.push_str(&pretty::render(&p));
    }
    for p in forelem::forelem::specs::lu_fig7() {
        out.push('\n');
        out.push_str(&pretty::render(&p));
    }
    out.push_str("\n## Fig 8 — derivation chains (IR after each step)\n");
    for (name, steps) in chains {
        out.push_str(&format!("\n==== {name} ====\n"));
        let mut prefix: Vec<Step> = Vec::new();
        // initial form
        let s0 = apply_chain(Kernel::Spmv, &[]).unwrap();
        out.push_str(&pretty::render(&build::program(&s0)));
        for st in steps {
            prefix.push(st);
            let s = apply_chain(Kernel::Spmv, &prefix).unwrap();
            out.push('\n');
            out.push_str(&pretty::render(&build::program(&s)));
        }
        let s = apply_chain(Kernel::Spmv, &prefix).unwrap();
        let plans = forelem::concretize::plans(&s).unwrap();
        for p in plans {
            out.push_str(&format!("\n→ concretizes to: {}\n", p.layout.literature_name()));
            out.push_str(&forelem::concretize::codegen::emit(Kernel::Spmv, &p));
        }
    }
    out
}

fn cmd_codegen(args: &Args) -> String {
    let kernel = kernel_of(args);
    let space = if args.flag("schedules") {
        PlanSpace::host(forelem::util::pool::default_workers().clamp(2, 8), DEFAULT_X_BLOCK)
    } else {
        PlanSpace::serial_only()
    };
    let tree = forelem::search::enumerate(kernel, &space);
    // Accept a stable id ("csr.row.serial"), a cost-rank ordinal
    // ("v003" = third-cheapest plan), or default to the top-ranked one.
    let sel = args.get_or("variant", "v001");
    let plan = if let Some(ord) = sel
        .strip_prefix('v')
        .and_then(|n| n.parse::<usize>().ok())
        .filter(|&n| n >= 1 && n <= tree.plans.len())
    {
        Some(&tree.plans[ord - 1])
    } else {
        tree.plans.iter().find(|p| p.id == sel)
    };
    let Some(p) = plan else {
        let ids: Vec<&str> = tree.plans.iter().map(|p| p.id.as_str()).collect();
        return format!(
            "no plan '{sel}' (use v1..v{} by predicted rank, or one of: {})",
            tree.plans.len(),
            ids.join(", ")
        );
    };
    format!(
        "plan {} — {}\nderivation: {}\n\n{}",
        p.id,
        p.exec.layout.literature_name(),
        p.derivation,
        forelem::concretize::codegen::emit_with_cost(
            kernel,
            &p.exec,
            space.dense_k,
            &space.ranking_stats(),
            &space.params,
        )
    )
}

/// `forelem calibrate [FILES…] [--arch host-small|host-large]
/// [--out PATH] [--check]` — fit the cost-model weights from the
/// calibration samples one or more `bench-json` records archived,
/// persist the profile (default `target/tuning/<arch>.profile`), and
/// report predicted-vs-measured top-1 agreement under the recording
/// planner (the archived predictions) and under the fitted weights. A
/// fit that regresses agreement is never persisted; `--check`
/// additionally exits nonzero on regression — the CI planner-guard's
/// refit gate — and an existing on-disk profile that outscores the new
/// fit is kept.
fn cmd_calibrate(args: &Args) {
    use forelem::runtime::artifacts;
    use forelem::search::calibrate::{self, Profile};
    let arch = match args.get_or("arch", "host-large") {
        "host-small" => Arch::HostSmall,
        "host-large" => Arch::HostLarge,
        other => {
            eprintln!("unknown arch '{other}' (host-small|host-large)");
            std::process::exit(2);
        }
    };
    // `--check BENCH.json` orderings: the parser swallows the file as
    // the flag's value — recover it into the file list so the gate
    // can't be silently disabled by argument order.
    let (check, swallowed) = args.flag_with_capture("check");
    let mut files: Vec<String> = args.positional.clone();
    files.extend(swallowed.map(str::to_string));
    if files.is_empty() {
        files.push("BENCH_spmv.json".to_string());
    }
    let mut samples = Vec::new();
    for f in &files {
        let text = std::fs::read_to_string(f)
            .unwrap_or_else(|e| panic!("reading bench record {f}: {e}"));
        let n0 = samples.len();
        samples.extend(calibrate::samples_from_json(&text));
        println!("{f}: {} samples", samples.len() - n0);
    }
    if samples.is_empty() {
        eprintln!("no calibration samples found (re-run `forelem bench-json` first)");
        std::process::exit(2);
    }
    let seed = arch.cost_params();
    let fitted = calibrate::fit(&samples, &seed);
    // Baseline = the planner that *ranked the record* (its archived
    // predictions), not a re-dot with seed weights — records produced
    // under an already-loaded profile would otherwise be mis-scored.
    let (rm, total) = calibrate::top1_agreement_recorded(&samples);
    let (fm, _) = calibrate::top1_agreement(&samples, &fitted.weights);
    println!("fitted {} weights from {} samples over {} matrices:", arch.slug(), samples.len(), total);
    for (name, (s, f)) in forelem::search::cost::FEATURE_NAMES
        .iter()
        .zip(seed.weights.iter().zip(&fitted.weights))
    {
        println!("  {name:<16} seed {s:>12.4e}  fitted {f:>12.4e}");
    }
    println!("recorded_top1_agreement: {:.4}", rm as f64 / total.max(1) as f64);
    println!("fitted_top1_agreement: {:.4}", fm as f64 / total.max(1) as f64);
    // A fit that loses to the planner that produced the record never
    // lands in target/tuning (where the next sweep would auto-load
    // it) — with or without --check; --check additionally fails the
    // build for CI.
    if fm < rm {
        eprintln!(
            "refit regressed top-1 agreement: fitted {fm}/{total} < recorded {rm}/{total}; \
             profile NOT written"
        );
        std::process::exit(if check { 1 } else { 0 });
    }
    // Ratchet: never overwrite an existing profile that outscores the
    // new fit on this same sample set.
    if args.get("out").is_none() {
        if let Some(old) = artifacts::load_profile(arch.slug()) {
            let (om, _) = calibrate::top1_agreement(&samples, &old.weights);
            if om > fm {
                println!(
                    "existing profile scores {om}/{total} > fitted {fm}/{total}; keeping it"
                );
                return;
            }
        }
    }
    let profile = Profile::from_params(arch.slug(), &fitted, samples.len());
    let path = match args.get("out") {
        Some(p) => {
            if let Some(dir) = std::path::Path::new(p).parent() {
                std::fs::create_dir_all(dir).expect("creating --out directory");
            }
            std::fs::write(p, profile.render()).expect("writing --out profile");
            std::path::PathBuf::from(p)
        }
        None => artifacts::save_profile(&profile).expect("writing tuning profile"),
    };
    println!("wrote {} ({} sweeps will auto-load it)", path.display(), arch.slug());
}

fn cmd_suite() -> String {
    let mut out = String::from("## 20-matrix suite (synthetic stand-ins; DESIGN.md §5)\n");
    out.push_str(&format!(
        "{:<12} {:>8} {:>10} {:>8} {:>10} {:>8} {:>10} {:>10}\n",
        "name", "n", "nnz", "maxrow", "nnz/row", "row-cv", "bandwidth", "ell-fill"
    ));
    for e in &forelem::matrix::suite::SUITE {
        // Memoized MatrixStats — the same values the planner ranks on.
        let s = e.stats();
        out.push_str(&format!(
            "{:<12} {:>8} {:>10} {:>8} {:>10.1} {:>8.2} {:>10} {:>10.2}\n",
            e.name,
            s.nrows,
            s.nnz,
            s.row_max,
            s.row_mean,
            s.row_cv(),
            s.bandwidth,
            s.ell_fill()
        ));
    }
    out
}

fn main() {
    let args = Args::parse();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "enumerate" | "fig10" => emit(&args, &tables::fig10()),
        "derive" => emit(&args, &cmd_derive()),
        "codegen" => emit(&args, &cmd_codegen(&args)),
        "suite" => emit(&args, &cmd_suite()),
        "table1" | "table2" | "table3" => {
            let cfg = sweep_cfg(&args);
            let xla = tables::try_xla();
            let (txt, ..) = match sub.as_str() {
                "table1" => tables::table1(&cfg, xla.as_ref()),
                "table2" => tables::table2(&cfg, xla.as_ref()),
                _ => tables::table3(&cfg, xla.as_ref()),
            };
            emit(&args, &txt);
        }
        "table4" | "table5" | "fig11" => {
            let cfg = sweep_cfg(&args);
            let xla = tables::try_xla();
            let a = tables::run_sweep(Kernel::Spmv, Arch::HostSmall, &cfg, xla.as_ref());
            let b = tables::run_sweep(Kernel::Spmv, Arch::HostLarge, &cfg, xla.as_ref());
            let txt = match sub.as_str() {
                "table4" => tables::table4(&[&a, &b]),
                "table5" => tables::table5(&[&a, &b], args.get_usize("seed", 2022) as u64),
                _ => format!("{}\n{}", tables::fig11(&a), tables::fig11(&b)),
            };
            emit(&args, &txt);
        }
        "bench-json" => {
            let cfg = sweep_cfg(&args);
            let xla = tables::try_xla();
            let path = args.get_or("out", "BENCH_spmv.json").to_string();
            forelem::coordinator::sweep::write_bench_json(
                &path,
                Arch::HostLarge,
                &cfg,
                xla.as_ref(),
            )
            .expect("writing bench json");
            println!(
                "wrote {path} (serial vs best-schedule SpMV medians + predicted-vs-measured \
                 audit + calibration samples)"
            );
        }
        "calibrate" => cmd_calibrate(&args),
        "bench-all" => {
            let cfg = sweep_cfg(&args);
            let xla = tables::try_xla();
            eprintln!(
                "xla backend: {}",
                xla.as_ref().map(|b| b.platform()).unwrap_or_else(|| "absent".into())
            );
            emit(&args, &tables::fig10());
            let (t1, a1, b1) = tables::table1(&cfg, xla.as_ref());
            emit(&args, &t1);
            let (t2, a2, b2) = tables::table2(&cfg, xla.as_ref());
            emit(&args, &t2);
            let (t3, a3, b3) = tables::table3(&cfg, xla.as_ref());
            emit(&args, &t3);
            let sweeps = [&a1, &b1, &a2, &b2, &a3, &b3];
            emit(&args, &tables::table4(&sweeps));
            emit(&args, &tables::table5(&sweeps, args.get_usize("seed", 2022) as u64));
            emit(&args, &tables::fig11(&a1));
            emit(&args, &tables::fig11(&b1));
            emit(&args, &tables::best_triples_report(&a1));
            emit(&args, &tables::best_triples_report(&b1));
        }
        _ => {
            println!(
                "forelem — automatic compiler-based data structure generation\n\
                 subcommands: enumerate derive codegen suite table1 table2 table3\n\
                 \x20            table4 table5 fig11 bench-all bench-json calibrate\n\
                 flags: --quick --kernel K --variant ID --spmm-k N --matrices N --out FILE\n\
                 \x20      --schedules (add the parallel/tiled schedule axis on host-large)\n\
                 \x20      --shortlist K (measure only the top-K cost-ranked plans per\n\
                 \x20                     matrix; 0 = exhaustive, the paper protocol)\n\
                 \x20      --no-profile (rank on the seed cost parameters even when a\n\
                 \x20                    fitted target/tuning/<arch>.profile exists)\n\
                 calibrate: forelem calibrate [BENCH_*.json…] [--arch host-large]\n\
                 \x20          [--out PATH] [--check (fail if fitted agreement < the\n\
                 \x20          record's own planner; regressed fits are never persisted)]"
            );
        }
    }
}
