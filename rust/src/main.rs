//! forelem CLI — the L3 entrypoint.
//!
//! ```text
//! forelem run [--kernel K] [--matrix NAME]        compile-and-serve demo: Engine::compile
//!             [--arch A] [--autotune K]           + explain() + one timed serve
//! forelem enumerate [--kernel spmv|spmm|trsv]     Fig 10 tree report
//! forelem derive                                  Fig 8 derivation chains (IR at each step)
//! forelem codegen --variant ID [--kernel spmv]    generated C-like code for a plan
//!                                                 (stable id like csr.row.serial, or vNNN rank)
//! forelem table1|table2|table3 [--quick]          paper reduction tables (both archs)
//! forelem table4|table5|fig11  [--quick]          coverage / selection analyses
//! forelem bench-all [--quick] [--out FILE]        everything, appended to FILE
//! forelem bench-json [--shortlist K]              BENCH_spmv.json + planner audit + samples
//! forelem serve-bench [--quick] [--clients N]      closed-loop batched-serving benchmark
//! forelem delta-bench [--quick] [--rounds N]       dynamic-matrix delta stream: repair vs
//!                                                  rebuild latency + swap stall, BENCH_delta.json
//! forelem calibrate [FILES…] [--arch A] [--check] fit a tuning profile from BENCH_*.json
//! forelem chaos                                   fault-injection drill (--features chaos)
//! forelem suite                                   print the 20-matrix suite statistics
//! ```

use forelem::bench::tables;
use forelem::coordinator::sweep::SweepConfig;
use forelem::engine::{Autotune, Engine};
use forelem::util::cli::Args;
use forelem::{Arch, Kernel};

fn kernel_of(args: &Args) -> Kernel {
    match args.get_or("kernel", "spmv") {
        "spmv" => Kernel::Spmv,
        "spmm" => Kernel::Spmm,
        "trsv" => Kernel::Trsv,
        other => {
            eprintln!("unknown kernel '{other}' (spmv|spmm|trsv)");
            std::process::exit(2);
        }
    }
}

fn arch_of(args: &Args, default: &str) -> Arch {
    match args.get_or("arch", default) {
        "host-small" => Arch::HostSmall,
        "host-large" => Arch::HostLarge,
        other => {
            eprintln!("unknown arch '{other}' (host-small|host-large)");
            std::process::exit(2);
        }
    }
}

/// The shared boolean-flag set of every sweep-style subcommand,
/// validated uniformly: stray positional tokens — bare or swallowed by
/// a boolean flag (`--quick 3`) — are rejected instead of silently
/// changing behavior. Returns `(quick, schedules, no_profile)`.
fn sweep_flags(args: &Args) -> (bool, bool, bool) {
    match args.strict_bool_flags(&["quick", "schedules", "no-profile"]) {
        Ok(v) => (v[0], v[1], v[2]),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
}

fn sweep_cfg(args: &Args) -> SweepConfig {
    let (quick, schedules, no_profile) = sweep_flags(args);
    let mut cfg = if quick { SweepConfig::quick() } else { SweepConfig::default() };
    if let Some(k) = args.get("spmm-k") {
        cfg.spmm_k = k.parse().expect("--spmm-k integer");
    }
    if let Some(n) = args.get("matrices") {
        let n: usize = n.parse().expect("--matrices integer");
        cfg.matrices = Some((0..n.min(20)).collect());
    }
    // Opt into the schedule axis (parallel / cache-blocked generated
    // kernels on the HostLarge arch; HostSmall stays single-core).
    cfg.use_schedules = schedules;
    // Predict→measure shortlist: time only the top-K cost-ranked plans
    // per matrix. Default 8 on the large suite now that fitted top-1
    // agreement is ratcheted in CI; `--shortlist 0` is the explicit
    // exhaustive opt-in (the paper protocol). Quick sweeps stay
    // exhaustive — their pruned pool is already small.
    cfg.shortlist = args.get_usize("shortlist", if quick { 0 } else { 8 });
    // CLI sweeps auto-load the fitted tuning profile when one exists
    // (target/tuning/<arch>.profile, written by `forelem calibrate`);
    // --no-profile ranks on the seed parameters instead.
    cfg.use_profile = !no_profile;
    cfg
}

fn emit(args: &Args, text: &str) {
    println!("{text}");
    if let Some(path) = args.get("out") {
        tables::record(path, text).expect("writing --out file");
    }
}

fn cmd_derive() -> String {
    use forelem::forelem::ir::{NStarMat, Orth};
    use forelem::forelem::{build, pretty};
    use forelem::transforms::{apply_chain, Step};
    let chains: Vec<(&str, Vec<Step>)> = vec![
        (
            "Fig 8 main path → ITPACK (ELL column-major)",
            vec![
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::Split,
                Step::NStar(NStarMat::Padded),
                Step::Interchange,
            ],
        ),
        (
            "Fig 8 gray path → CSR",
            vec![
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::Split,
                Step::NStar(NStarMat::Exact),
                Step::DimReduce,
            ],
        ),
        (
            "column start → CCS",
            vec![
                Step::Orthogonalize(Orth::Col),
                Step::Materialize,
                Step::Split,
                Step::NStar(NStarMat::Exact),
                Step::DimReduce,
            ],
        ),
        (
            "ℕ*-sorted + interchange → JDS",
            vec![
                Step::Orthogonalize(Orth::Row),
                Step::Materialize,
                Step::Split,
                Step::NStarSort,
                Step::NStar(NStarMat::Exact),
                Step::Interchange,
                Step::DimReduce,
            ],
        ),
    ];
    let mut out = String::from("## Fig 5/6/7 — the paper-faithful kernel specifications\n");
    out.push_str(&pretty::render(&forelem::forelem::specs::spmv_fig5()));
    for p in forelem::forelem::specs::trsv_fig6() {
        out.push('\n');
        out.push_str(&pretty::render(&p));
    }
    for p in forelem::forelem::specs::lu_fig7() {
        out.push('\n');
        out.push_str(&pretty::render(&p));
    }
    out.push_str("\n## Fig 8 — derivation chains (IR after each step)\n");
    for (name, steps) in chains {
        out.push_str(&format!("\n==== {name} ====\n"));
        let mut prefix: Vec<Step> = Vec::new();
        // initial form
        let s0 = apply_chain(Kernel::Spmv, &[]).unwrap();
        out.push_str(&pretty::render(&build::program(&s0)));
        for st in steps {
            prefix.push(st);
            let s = apply_chain(Kernel::Spmv, &prefix).unwrap();
            out.push('\n');
            out.push_str(&pretty::render(&build::program(&s)));
        }
        let s = apply_chain(Kernel::Spmv, &prefix).unwrap();
        let plans = forelem::concretize::plans(&s).unwrap();
        for p in plans {
            out.push_str(&format!("\n→ concretizes to: {}\n", p.layout.literature_name()));
            out.push_str(&forelem::concretize::codegen::emit(Kernel::Spmv, &p));
        }
    }
    out
}

fn cmd_codegen(args: &Args) -> String {
    let kernel = kernel_of(args);
    let (_, schedules, no_profile) = sweep_flags(args);
    // The pipeline runs through the engine: `--schedules` selects the
    // scheduled host-large space, otherwise the paper's serial tree.
    let arch = arch_of(args, if schedules { "host-large" } else { "host-small" });
    let engine = Engine::builder().arch(arch).schedules(schedules).profile(!no_profile).build();
    let plans = engine.plans(kernel);
    // Accept a stable id ("csr.row.serial"), a cost-rank ordinal
    // ("v003" = third-cheapest plan), or default to the top-ranked one.
    let sel = args.get_or("variant", "v001");
    let plan = if let Some(ord) = sel
        .strip_prefix('v')
        .and_then(|n| n.parse::<usize>().ok())
        .filter(|&n| n >= 1 && n <= plans.len())
    {
        Some(&plans[ord - 1])
    } else {
        plans.iter().find(|p| p.id == sel)
    };
    let Some(p) = plan else {
        let ids: Vec<&str> = plans.iter().map(|p| p.id.as_str()).collect();
        return format!(
            "no plan '{sel}' (use v1..v{} by predicted rank, or one of: {})",
            plans.len(),
            ids.join(", ")
        );
    };
    format!(
        "plan {} — {}\nderivation: {}\n\n{}",
        p.id,
        p.exec.layout.literature_name(),
        p.derivation,
        engine.emit(kernel, p)
    )
}

/// `forelem run` — the compile-and-serve demo: one suite matrix
/// through `Engine::compile` (optionally autotuned), the `explain()`
/// cost breakdown, an oracle-checked timed serve, and a recompile to
/// show the process-wide cache hit.
fn cmd_run(args: &Args) {
    use forelem::bench::harness::{black_box, time_fn, BenchConfig};
    use forelem::util::prop::assert_close;
    let (quick, schedules, no_profile) = sweep_flags(args);
    let kernel = kernel_of(args);
    let arch = arch_of(args, "host-large");
    let name = args.get_or("matrix", "Raj1");
    let entry = forelem::matrix::suite::by_name(name).unwrap_or_else(|| {
        eprintln!("unknown matrix '{name}'; available:");
        for e in &forelem::matrix::suite::SUITE {
            eprintln!("  {}", e.name);
        }
        std::process::exit(2);
    });
    let built = entry.build_scaled(arch.scale());
    let m = if kernel == Kernel::Trsv { built.strictly_lower() } else { built };
    let k_dense = args.get_usize("spmm-k", if quick { 16 } else { 100 });
    let autotune = args.get_usize("autotune", 0);
    let bench = if quick { BenchConfig::quick() } else { BenchConfig::from_env() };
    // Like the sweep subcommands, the schedule axis is an explicit
    // opt-in: without --schedules the engine ranks the serial tree
    // (the paper protocol) even on host-large.
    let engine = Engine::builder()
        .arch(arch)
        .schedules(schedules)
        .spmm_k(k_dense)
        .autotune(if autotune >= 2 { Autotune::TopK(autotune) } else { Autotune::Off })
        .profile(!no_profile)
        .bench(bench)
        .build();

    let t0 = std::time::Instant::now();
    let exe = match engine.compile(kernel, &m) {
        Ok(exe) => exe,
        Err(e) => {
            eprintln!("error [{}]: {e}", e.class());
            std::process::exit(2);
        }
    };
    println!(
        "compiled {} for {} on {} in {:.1} ms ({} plans ranked{})",
        kernel.label(),
        name,
        arch.slug(),
        t0.elapsed().as_secs_f64() * 1e3,
        engine.plans(kernel).len(),
        if autotune >= 2 { format!(", top-{autotune} measured") } else { String::new() }
    );
    println!("{}", exe.explain());

    match kernel {
        Kernel::Spmv => {
            let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.013).sin()).collect();
            let mut y = vec![0.0; m.nrows];
            exe.spmv(&x, &mut y);
            assert_close(&y, &m.spmv_ref(&x), 1e-9).expect("generated SpMV vs oracle");
            let s = time_fn(&bench, || {
                exe.spmv(&x, &mut y);
                black_box(&y);
            });
            println!("serve: {:.2} us/SpMV (oracle-checked)", s.median * 1e6);
        }
        Kernel::Spmm => {
            let b: Vec<f64> = (0..m.ncols * k_dense).map(|i| (i as f64 * 0.007).cos()).collect();
            let mut c = vec![0.0; m.nrows * k_dense];
            exe.spmm(&b, &mut c);
            assert_close(&c, &m.spmm_ref(&b, k_dense), 1e-9).expect("generated SpMM vs oracle");
            let s = time_fn(&bench, || {
                exe.spmm(&b, &mut c);
                black_box(&c);
            });
            println!("serve: {:.2} us/SpMM k={k_dense} (oracle-checked)", s.median * 1e6);
        }
        Kernel::Trsv => {
            let b: Vec<f64> = (0..m.nrows).map(|i| 1.0 - (i % 9) as f64 * 0.2).collect();
            let mut x = vec![0.0; m.nrows];
            exe.trsv(&b, &mut x);
            assert_close(&x, &m.trsv_unit_lower_ref(&b), 1e-8).expect("generated TrSv vs oracle");
            let s = time_fn(&bench, || {
                exe.trsv(&b, &mut x);
                black_box(&x);
            });
            println!("serve: {:.2} us/TrSv (oracle-checked)", s.median * 1e6);
        }
    }

    // The serving path: a second compile of the same reservoir is a
    // cache hit sharing the same assembled storage.
    let t1 = std::time::Instant::now();
    let again = engine.compile(kernel, &m).expect("recompile of a validated matrix");
    let hit = std::sync::Arc::ptr_eq(&exe.storage(), &again.storage());
    println!(
        "recompile: {:.2} us — cache {}",
        t1.elapsed().as_secs_f64() * 1e6,
        if hit { "hit (storage Arc-shared)" } else { "miss (unexpected)" }
    );
}

/// `forelem calibrate [FILES…] [--arch host-small|host-large]
/// [--out PATH] [--check]` — fit the cost-model weights from the
/// calibration samples one or more `bench-json` records archived,
/// persist the profile (default `target/tuning/<arch>.profile`), and
/// report predicted-vs-measured top-1 agreement under the recording
/// planner (the archived predictions) and under the fitted weights. A
/// fit that regresses agreement is never persisted; `--check`
/// additionally exits nonzero on regression — the CI planner-guard's
/// refit gate — and an existing on-disk profile that outscores the new
/// fit is kept.
fn cmd_calibrate(args: &Args) {
    use forelem::runtime::artifacts;
    use forelem::search::calibrate::{self, Profile};
    let arch = arch_of(args, "host-large");
    // `--check BENCH.json` orderings: the parser swallows the file as
    // the flag's value — recover it into the file list so the gate
    // can't be silently disabled by argument order.
    let (check, swallowed) = args.flag_with_capture("check");
    let mut files: Vec<String> = args.positional.clone();
    files.extend(swallowed.map(str::to_string));
    if files.is_empty() {
        // Default material: the last bench record, plus the engine's
        // rolling autotune archive when serving traffic has left one —
        // the online half of the refit loop.
        let bench = std::path::Path::new("BENCH_spmv.json");
        if bench.exists() {
            files.push("BENCH_spmv.json".to_string());
        }
        let archive = artifacts::samples_path_in(&artifacts::tuning_dir(), arch.slug());
        if archive.exists() {
            files.push(archive.display().to_string());
        }
        if files.is_empty() {
            files.push("BENCH_spmv.json".to_string()); // keep the old error path
        }
    }
    let mut samples = Vec::new();
    let mut corrupt_total = 0usize;
    for f in &files {
        let text = std::fs::read_to_string(f)
            .unwrap_or_else(|e| panic!("reading bench record {f}: {e}"));
        let n0 = samples.len();
        if f.ends_with(".jsonl") {
            // Archive files get strict per-line accounting: corrupt
            // lines are counted and quarantined next to the archive
            // (same naming as `artifacts::quarantine_path_in`) instead
            // of silently shrinking the refit material.
            let mut corrupt: Vec<&str> = Vec::new();
            for line in text.lines() {
                if line.trim().is_empty() {
                    continue;
                }
                match calibrate::sample_from_json_line(line) {
                    Some(s) => samples.push(s),
                    None => corrupt.push(line),
                }
            }
            if corrupt.is_empty() {
                println!("{f}: {} samples", samples.len() - n0);
            } else {
                corrupt_total += corrupt.len();
                let qpath = format!("{}.quarantine.jsonl", f.strip_suffix(".jsonl").unwrap_or(f));
                let mut body = corrupt.join("\n");
                body.push('\n');
                match std::fs::write(&qpath, body) {
                    Ok(()) => println!(
                        "{f}: {} samples, {} corrupt lines quarantined to {qpath}",
                        samples.len() - n0,
                        corrupt.len()
                    ),
                    Err(e) => println!(
                        "{f}: {} samples, {} corrupt lines skipped (quarantine failed: {e})",
                        samples.len() - n0,
                        corrupt.len()
                    ),
                }
            }
        } else {
            samples.extend(calibrate::samples_from_json(&text));
            println!("{f}: {} samples", samples.len() - n0);
        }
    }
    if corrupt_total > 0 {
        eprintln!("warning: {corrupt_total} corrupt archive lines excluded from the fit");
    }
    if samples.is_empty() {
        eprintln!("no calibration samples found (re-run `forelem bench-json` first)");
        std::process::exit(2);
    }
    let seed = arch.cost_params();
    let fitted = calibrate::fit(&samples, &seed);
    // Baseline = the planner that *ranked the record* (its archived
    // predictions), not a re-dot with seed weights — records produced
    // under an already-loaded profile would otherwise be mis-scored.
    let (rm, total) = calibrate::top1_agreement_recorded(&samples);
    let (fm, _) = calibrate::top1_agreement(&samples, &fitted.weights);
    println!("fitted {} weights from {} samples over {} matrices:", arch.slug(), samples.len(), total);
    for (name, (s, f)) in forelem::search::cost::FEATURE_NAMES
        .iter()
        .zip(seed.weights.iter().zip(&fitted.weights))
    {
        println!("  {name:<16} seed {s:>12.4e}  fitted {f:>12.4e}");
    }
    println!("recorded_top1_agreement: {:.4}", rm as f64 / total.max(1) as f64);
    println!("fitted_top1_agreement: {:.4}", fm as f64 / total.max(1) as f64);
    // A fit that loses to the planner that produced the record never
    // lands in target/tuning (where the next sweep would auto-load
    // it) — with or without --check; --check additionally fails the
    // build for CI.
    if fm < rm {
        eprintln!(
            "refit regressed top-1 agreement: fitted {fm}/{total} < recorded {rm}/{total}; \
             profile NOT written"
        );
        std::process::exit(if check { 1 } else { 0 });
    }
    // Ratchet: never overwrite an existing profile that outscores the
    // new fit on this same sample set.
    if args.get("out").is_none() {
        if let Some(old) = artifacts::load_profile(arch.slug()) {
            let (om, _) = calibrate::top1_agreement(&samples, &old.weights);
            if om > fm {
                println!(
                    "existing profile scores {om}/{total} > fitted {fm}/{total}; keeping it"
                );
                return;
            }
        }
    }
    let profile = Profile::from_params(arch.slug(), &fitted, samples.len());
    let path = match args.get("out") {
        Some(p) => {
            // Through the artifact store, so --out profiles carry the
            // same checksum trailer the loader verifies.
            let path = std::path::PathBuf::from(p);
            artifacts::save_profile_at(&path, &profile).expect("writing --out profile");
            path
        }
        None => artifacts::save_profile(&profile).expect("writing tuning profile"),
    };
    println!("wrote {} ({} sweeps will auto-load it)", path.display(), arch.slug());
    // A fresh profile resets the quarantine evidence: entries record
    // measurement faults under the *old* calibration regime, and one
    // transient glitch must not exclude a plan from this process
    // forever once the planner has been refit.
    Engine::clear_quarantine();
    if Engine::quarantine_len() == 0 {
        eprintln!("quarantine cleared (recalibration resets fault evidence)");
    }
}

fn cmd_suite() -> String {
    let mut out = String::from("## 20-matrix suite (synthetic stand-ins; DESIGN.md §5)\n");
    out.push_str(&format!(
        "{:<12} {:>8} {:>10} {:>8} {:>10} {:>8} {:>10} {:>10}\n",
        "name", "n", "nnz", "maxrow", "nnz/row", "row-cv", "bandwidth", "ell-fill"
    ));
    for e in &forelem::matrix::suite::SUITE {
        // Memoized MatrixStats — the same values the planner ranks on.
        let s = e.stats();
        out.push_str(&format!(
            "{:<12} {:>8} {:>10} {:>8} {:>10.1} {:>8.2} {:>10} {:>10.2}\n",
            e.name,
            s.nrows,
            s.nnz,
            s.row_max,
            s.row_mean,
            s.row_cv(),
            s.bandwidth,
            s.ell_fill()
        ));
    }
    out
}

fn cmd_serve_bench(args: &Args) {
    use forelem::coordinator::serve;
    let (quick, no_profile) = match args.strict_bool_flags(&["quick", "no-profile"]) {
        Ok(v) => (v[0], v[1]),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let mut cfg = serve::ServeConfig::quick();
    cfg.arch = arch_of(args, "host-large");
    if !quick {
        // The full run covers more of the suite with a longer closed
        // loop; --quick keeps the CI-sized three-matrix workload.
        cfg.matrices = (0..8).collect();
        cfg.requests_per_client = 800;
    }
    cfg.use_profile = !no_profile;
    cfg.clients = args.get_usize("clients", cfg.clients).max(1);
    cfg.requests_per_client = args.get_usize("requests", cfg.requests_per_client).max(1);
    cfg.lambda_hz = args.get_f64("lambda", cfg.lambda_hz);
    cfg.max_batch = args.get_usize("max-batch", cfg.max_batch).max(1);
    cfg.flush_deadline =
        std::time::Duration::from_micros(args.get_usize("deadline-us", 150) as u64);
    if let Some(n) = args.get("matrices") {
        let n: usize = n.parse().expect("--matrices expects an integer");
        cfg.matrices = (0..n.clamp(1, 20)).collect();
    }
    cfg.seed = args.get_usize("seed", cfg.seed as usize) as u64;
    let report = match serve::run(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("serve-bench failed: {e}");
            std::process::exit(1);
        }
    };
    print!("{}", serve::report_text(&report));
    let path = args.get_or("out", "BENCH_serve.json");
    std::fs::write(path, serve::to_json(&report)).expect("writing serve json");
    println!("wrote {path} (closed-loop serving: throughput, latency percentiles, batch histogram)");
    if !report.bit_identical {
        eprintln!("serve-bench: batched results were NOT bit-identical to the solo plan");
        std::process::exit(1);
    }
}

/// `forelem delta-bench` — the dynamic-matrix benchmark: versioned
/// matrices absorbing update streams under concurrent serves, timing
/// in-place repair vs from-scratch rebuild and the serve-side swap
/// stall. Writes `BENCH_delta.json`; exits non-zero when any final
/// generation fails the bitwise-identity check against a fresh prepare.
fn cmd_delta_bench(args: &Args) {
    use forelem::coordinator::delta_bench;
    let (quick, no_profile) = match args.strict_bool_flags(&["quick", "no-profile"]) {
        Ok(v) => (v[0], v[1]),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let mut cfg = delta_bench::DeltaBenchConfig::quick();
    cfg.arch = arch_of(args, "host-small");
    if !quick {
        // The full run streams deltas over more of the suite with a
        // longer window; --quick keeps the CI-sized two-matrix stream.
        cfg.matrices = (0..6).collect();
        cfg.rounds = 64;
    }
    cfg.use_profile = !no_profile;
    if let Some(n) = args.get("matrices") {
        let n: usize = n.parse().expect("--matrices expects an integer");
        cfg.matrices = (0..n.clamp(1, 20)).collect();
    }
    cfg.rounds = args.get_usize("rounds", cfg.rounds).max(1);
    cfg.ops_per_batch = args.get_usize("ops", cfg.ops_per_batch).max(1);
    cfg.serve_clients = args.get_usize("clients", cfg.serve_clients).max(1);
    cfg.seed = args.get_usize("seed", cfg.seed as usize) as u64;
    let report = match delta_bench::run(&cfg) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("delta-bench failed [{}]: {e}", e.class());
            std::process::exit(1);
        }
    };
    print!("{}", delta_bench::report_text(&report));
    let path = args.get_or("out", "BENCH_delta.json");
    std::fs::write(path, delta_bench::to_json(&report)).expect("writing delta json");
    println!("wrote {path} (repair vs rebuild latency, swap-stall percentiles, route counts)");
    if !report.bit_identical {
        eprintln!("delta-bench: a live generation did NOT serve a fresh prepare's exact bits");
        std::process::exit(1);
    }
}

fn main() {
    let args = Args::parse();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    match sub.as_str() {
        "run" => cmd_run(&args),
        "enumerate" | "fig10" => emit(&args, &tables::fig10()),
        "derive" => emit(&args, &cmd_derive()),
        "codegen" => emit(&args, &cmd_codegen(&args)),
        "suite" => emit(&args, &cmd_suite()),
        "table1" | "table2" | "table3" => {
            let cfg = sweep_cfg(&args);
            let xla = tables::try_xla();
            let (txt, ..) = match sub.as_str() {
                "table1" => tables::table1(&cfg, xla.as_ref()),
                "table2" => tables::table2(&cfg, xla.as_ref()),
                _ => tables::table3(&cfg, xla.as_ref()),
            };
            emit(&args, &txt);
        }
        "table4" | "table5" | "fig11" => {
            let cfg = sweep_cfg(&args);
            let xla = tables::try_xla();
            let a = tables::run_sweep(Kernel::Spmv, Arch::HostSmall, &cfg, xla.as_ref());
            let b = tables::run_sweep(Kernel::Spmv, Arch::HostLarge, &cfg, xla.as_ref());
            let txt = match sub.as_str() {
                "table4" => tables::table4(&[&a, &b]),
                "table5" => tables::table5(&[&a, &b], args.get_usize("seed", 2022) as u64),
                _ => format!("{}\n{}", tables::fig11(&a), tables::fig11(&b)),
            };
            emit(&args, &txt);
        }
        "bench-json" => {
            let cfg = sweep_cfg(&args);
            let xla = tables::try_xla();
            let path = args.get_or("out", "BENCH_spmv.json").to_string();
            forelem::coordinator::sweep::write_bench_json(
                &path,
                Arch::HostLarge,
                &cfg,
                xla.as_ref(),
            )
            .expect("writing bench json");
            println!(
                "wrote {path} (serial vs best-schedule SpMV medians + predicted-vs-measured \
                 audit + calibration samples)"
            );
        }
        "serve-bench" => cmd_serve_bench(&args),
        "delta-bench" => cmd_delta_bench(&args),
        "calibrate" => cmd_calibrate(&args),
        "chaos" => {
            #[cfg(feature = "chaos")]
            {
                let ok = forelem::chaos::drill::run_and_report();
                std::process::exit(if ok { 0 } else { 1 });
            }
            #[cfg(not(feature = "chaos"))]
            {
                eprintln!(
                    "the chaos drill needs the fault-injection points compiled in:\n\
                     \x20   cargo run --release --features chaos -- chaos"
                );
                std::process::exit(2);
            }
        }
        "bench-all" => {
            let cfg = sweep_cfg(&args);
            let xla = tables::try_xla();
            eprintln!(
                "xla backend: {}",
                xla.as_ref().map(|b| b.platform()).unwrap_or_else(|| "absent".into())
            );
            emit(&args, &tables::fig10());
            let (t1, a1, b1) = tables::table1(&cfg, xla.as_ref());
            emit(&args, &t1);
            let (t2, a2, b2) = tables::table2(&cfg, xla.as_ref());
            emit(&args, &t2);
            let (t3, a3, b3) = tables::table3(&cfg, xla.as_ref());
            emit(&args, &t3);
            let sweeps = [&a1, &b1, &a2, &b2, &a3, &b3];
            emit(&args, &tables::table4(&sweeps));
            emit(&args, &tables::table5(&sweeps, args.get_usize("seed", 2022) as u64));
            emit(&args, &tables::fig11(&a1));
            emit(&args, &tables::fig11(&b1));
            emit(&args, &tables::best_triples_report(&a1));
            emit(&args, &tables::best_triples_report(&b1));
        }
        _ => {
            println!(
                "forelem — automatic compiler-based data structure generation\n\
                 subcommands: run enumerate derive codegen suite table1 table2 table3\n\
                 \x20            table4 table5 fig11 bench-all bench-json serve-bench\n\
                 \x20            delta-bench calibrate chaos\n\
                 flags: --quick --kernel K --variant ID --spmm-k N --matrices N --out FILE\n\
                 \x20      --schedules (add the parallel/tiled schedule axis on host-large)\n\
                 \x20      --shortlist K (measure only the top-K cost-ranked plans per\n\
                 \x20                     matrix; default 8 on the full suite, quick sweeps\n\
                 \x20                     exhaustive; 0 = exhaustive, the paper protocol)\n\
                 \x20      --no-profile (rank on the seed cost parameters even when a\n\
                 \x20                    fitted target/tuning/<arch>.profile exists)\n\
                 run: forelem run [--kernel spmv|spmm|trsv] [--matrix NAME]\n\
                 \x20     [--arch host-large] [--autotune K (measure the top-K predicted\n\
                 \x20     plans, archive the samples)] — Engine::compile + explain + serve\n\
                 calibrate: forelem calibrate [FILES… (BENCH_*.json and/or the engine's\n\
                 \x20          target/tuning/<arch>.samples.jsonl archive)] [--arch host-large]\n\
                 \x20          [--out PATH] [--check (fail if fitted agreement < the\n\
                 \x20          record's own planner; regressed fits are never persisted)]\n\
                 serve-bench: forelem serve-bench [--quick] [--clients N] [--requests N]\n\
                 \x20            [--lambda HZ (Poisson arrival rate per client)]\n\
                 \x20            [--max-batch K] [--deadline-us D] [--matrices N]\n\
                 \x20            [--out BENCH_serve.json] — closed-loop serving benchmark\n\
                 \x20            of the request-batching path: batched vs unbatched\n\
                 \x20            throughput, p50/p95/p99 latency, batch-size histogram;\n\
                 \x20            exits non-zero on any bitwise mismatch\n\
                 delta-bench: forelem delta-bench [--quick] [--rounds N] [--ops N]\n\
                 \x20            [--clients N] [--matrices N] [--arch host-small]\n\
                 \x20            [--out BENCH_delta.json] — stream update batches through\n\
                 \x20            versioned matrices under concurrent serves: in-place repair\n\
                 \x20            vs from-scratch rebuild latency, serve-side swap-stall\n\
                 \x20            percentiles, repair/rebuild/replan route counts; exits\n\
                 \x20            non-zero if a live generation drifts from a fresh prepare\n\
                 chaos: forelem chaos — run the fault-injection drill at every fault\n\
                 \x20      point (requires a --features chaos build); exits non-zero if\n\
                 \x20      any fault deadlocks, aborts, or lands on the wrong health rung"
            );
        }
    }
}
