//! `forelem::engine` — the production-facing compile-and-serve facade.
//!
//! The paper's promise is "specification in, tuned executable out":
//! the user writes a data-structure-free forelem program and the
//! compiler derives the loop nest *and* the physical data structure.
//! This module is the single front door that delivers that contract as
//! an embedding API, wrapping the whole planner pipeline behind one
//! call:
//!
//! ```text
//! Engine::compile(kernel, &matrix)
//!   = enumerate (search::tree, the transformation-tree walk)
//!   → calibrated predict (search::cost under the fitted profile)
//!   → optional measure loop (Autotune::TopK(k) times the shortlist)
//!   → prepare (concretize — storage assembly + schedule auxiliaries)
//!   → Executable (spmv / spmm / trsv + plan() + bytes() + explain())
//! ```
//!
//! # Serving path
//!
//! Compiles are memoized in a **process-wide cache** keyed by
//! `(kernel, arch, matrix fingerprint, config digest)`: the second
//! `compile` of the same reservoir returns the same `Arc`-shared
//! storage without touching the planner — the repeated-traffic serving
//! path. Within a single compile, the autotune shortlist is prepared
//! through `concretize::prepare_many`'s plan-keyed storage cache, so
//! schedule/traversal variants of one layout share one assembly.
//!
//! # Online calibration
//!
//! Every autotune measurement is archived as a
//! [`search::calibrate::Sample`](crate::search::calibrate::Sample)
//! (`target/tuning/<arch>.samples.jsonl` — the same line format
//! `forelem calibrate` consumes), so serving traffic keeps feeding the
//! predict→measure→refit loop. The builder auto-loads the fitted
//! `target/tuning/<arch>.profile` like the CLI sweeps do; call
//! [`EngineBuilder::profile`]`(false)` to rank on the seed model
//! (library tests do, for hermeticity).
//!
//! # Example
//!
//! ```
//! use forelem::engine::{Engine, Kernel};
//! use forelem::matrix::TriMat;
//!
//! let mut a = TriMat::new(2, 2);
//! a.push(0, 0, 2.0);
//! a.push(1, 0, 1.0);
//! a.push(1, 1, 3.0);
//! let engine = Engine::builder().profile(false).build();
//! let exe = engine.compile(Kernel::Spmv, &a);
//! let mut y = [0.0; 2];
//! exe.spmv(&[1.0, 2.0], &mut y);
//! assert_eq!(y, [2.0, 7.0]);
//! ```

mod cache;
mod executable;

pub use executable::{CostBreakdown, CostTerm, Executable};

pub use crate::baselines::Kernel;
pub use crate::coordinator::sweep::Arch;

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::bench::harness::{black_box, time_fn, BenchConfig};
use crate::concretize::{self, Schedule};
use crate::matrix::{MatrixStats, TriMat};
use crate::runtime::artifacts;
use crate::search::calibrate::Sample;
use crate::search::cost::{self, FeatureVec};
use crate::search::plan::{Plan, PlanSpace};
use crate::search::tree;

use executable::Compiled;

/// How much measuring `compile` may do on top of the calibrated
/// prediction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Autotune {
    /// Predict-only: trust the (calibrated) cost model's first pick.
    Off,
    /// Measure the top-`k` predicted plans and keep the fastest;
    /// `TopK(0)` and `TopK(1)` degenerate to predict-only. Each
    /// measurement is archived as a calibration sample.
    TopK(usize),
}

impl Autotune {
    fn k(&self) -> usize {
        match self {
            Autotune::Off => 0,
            Autotune::TopK(k) => *k,
        }
    }
}

/// Builder for [`Engine`] — the knobs of the compile pipeline.
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    arch: Arch,
    schedules: bool,
    spmm_k: usize,
    autotune: Autotune,
    profile: bool,
    archive: bool,
    bench: BenchConfig,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            arch: Arch::HostSmall,
            schedules: true,
            spmm_k: 100,
            autotune: Autotune::Off,
            profile: true,
            archive: true,
            bench: BenchConfig::quick(),
        }
    }
}

impl EngineBuilder {
    /// Target architecture: selects the plan space (`HostSmall` stays
    /// serial-only, `HostLarge` adds the parallel/tiled schedules) and
    /// the cost-model seed parameters / tuning-profile slug.
    pub fn arch(mut self, arch: Arch) -> Self {
        self.arch = arch;
        self
    }

    /// Force the serial-only plan space even on a scheduled
    /// architecture (`false`); `true` (default) uses the architecture's
    /// full space.
    pub fn schedules(mut self, on: bool) -> Self {
        self.schedules = on;
        self
    }

    /// Dense-operand column count SpMM plans are ranked for and
    /// [`Executable::spmm`] executes with (default 100, the paper's k).
    pub fn spmm_k(mut self, k: usize) -> Self {
        self.spmm_k = k.max(1);
        self
    }

    /// Measure-based autotuning policy (default [`Autotune::Off`]).
    pub fn autotune(mut self, autotune: Autotune) -> Self {
        self.autotune = autotune;
        self
    }

    /// Auto-load the fitted `target/tuning/<arch>.profile` written by
    /// `forelem calibrate` (default `true`, like the CLI sweeps; pass
    /// `false` to rank on the seed cost model — tests do, for
    /// hermeticity).
    pub fn profile(mut self, on: bool) -> Self {
        self.profile = on;
        self
    }

    /// Append every autotune measurement to the per-arch calibration
    /// archive (`target/tuning/<arch>.samples.jsonl`, default `true`)
    /// so serving traffic keeps feeding the refit loop.
    pub fn archive(mut self, on: bool) -> Self {
        self.archive = on;
        self
    }

    /// Measurement protocol of the autotune loop (default
    /// `BenchConfig::quick()` — serving compiles should be cheap).
    pub fn bench(mut self, bench: BenchConfig) -> Self {
        self.bench = bench;
        self
    }

    pub fn build(self) -> Engine {
        Engine { cfg: self, pools: Mutex::new(HashMap::new()) }
    }
}

/// The engine-internal per-kernel planner state: the schedule-crossed
/// plan space (profile-resolved parameters) and the enumerated,
/// cost-ranked plan pool. `coordinator::sweep` drives the same seam
/// ([`planned_pool`]) for its exhaustive paper-table path instead of
/// duplicating the profile-loading + enumeration logic.
pub(crate) struct PlannedPool {
    pub space: PlanSpace,
    pub plans: Vec<Plan>,
    /// Whether `space.params` came from a fitted on-disk profile.
    pub profile_loaded: bool,
}

/// Build the plan space + enumerated pool for one kernel — stage 1 of
/// every pipeline (engine compiles and sweeps alike). A fitted tuning
/// profile, when opted in and present, replaces the seed weights (the
/// thread count stays the running machine's). `announce` prints the
/// profile note to stderr — the sweep passes `true` so fitted rankings
/// never silently replace the seed model in paper-table output; the
/// engine stays quiet (embedding hosts read
/// `CostBreakdown::profile_loaded` instead of scraping logs).
pub(crate) fn planned_pool(
    kernel: Kernel,
    arch: Arch,
    use_schedules: bool,
    dense_k: usize,
    use_profile: bool,
    announce: bool,
) -> PlannedPool {
    let mut space = arch.plan_space();
    if !use_schedules {
        space.schedules = vec![Schedule::Serial];
    }
    space.dense_k = dense_k;
    let mut profile_loaded = false;
    if use_profile {
        if let Some(prof) = artifacts::load_profile(arch.slug()) {
            space.params = prof.params_for(space.params.threads);
            profile_loaded = true;
            if announce {
                eprintln!(
                    "note: {} ranking under fitted profile {} (--no-profile for the seed model)",
                    arch.slug(),
                    artifacts::profile_path_in(&artifacts::tuning_dir(), arch.slug()).display()
                );
            }
        }
    }
    let tree = tree::enumerate(kernel, &space);
    PlannedPool { space, plans: tree.plans, profile_loaded }
}

/// The compile-and-serve facade. Construct once per process (or per
/// configuration) via [`Engine::builder`], then [`compile`](Engine::compile)
/// per (kernel, matrix); repeated compiles of the same matrix are
/// served from the process-wide cache.
pub struct Engine {
    cfg: EngineBuilder,
    pools: Mutex<HashMap<Kernel, Arc<PlannedPool>>>,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// The architecture this engine compiles for.
    pub fn arch(&self) -> Arch {
        self.cfg.arch
    }

    /// The enumerated, cost-ranked plan pool for `kernel` (ranked
    /// against the space's nominal statistics; `compile` re-ranks per
    /// matrix).
    pub fn plans(&self, kernel: Kernel) -> Vec<Plan> {
        self.pool(kernel).plans.clone()
    }

    /// The generated C-like code of one plan, prefixed with its
    /// predicted resource footprint under this engine's (possibly
    /// fitted) parameters — the inspectable artifact.
    pub fn emit(&self, kernel: Kernel, plan: &Plan) -> String {
        let pool = self.pool(kernel);
        concretize::codegen::emit_with_cost(
            kernel,
            &plan.exec,
            pool.space.dense_k,
            &pool.space.ranking_stats(),
            &pool.space.params,
        )
    }

    /// Compile `kernel` against a tuple reservoir: rank the enumerated
    /// pool on the matrix's statistics under the calibrated parameters,
    /// optionally measure the shortlist ([`Autotune::TopK`]), assemble
    /// the winning storage, and return the bound [`Executable`].
    ///
    /// For TrSv the reservoir must hold the strictly-lower triangle
    /// (as everywhere else in the crate).
    pub fn compile(&self, kernel: Kernel, m: &TriMat) -> Executable {
        self.compile_inner(kernel, m, None)
    }

    /// [`compile`](Engine::compile) pinned to one plan by stable id
    /// (e.g. `"csr.row.serial"`), bypassing selection — for harnesses
    /// that sweep the whole pool and for serving setups that fix a
    /// plan out-of-band. Returns `None` if the pool has no such plan.
    pub fn compile_pinned(&self, kernel: Kernel, m: &TriMat, plan_id: &str) -> Option<Executable> {
        if !self.pool(kernel).plans.iter().any(|p| p.id == plan_id) {
            return None;
        }
        Some(self.compile_inner(kernel, m, Some(plan_id)))
    }

    /// Drop every cached compile in the process (all engines). Mostly
    /// for long-running hosts that want to bound memory and for
    /// benchmarks that need cold compiles.
    pub fn clear_cache() {
        cache::clear();
    }

    /// Number of compiles currently cached process-wide.
    pub fn cache_len() -> usize {
        cache::len()
    }

    fn pool(&self, kernel: Kernel) -> Arc<PlannedPool> {
        let mut pools = self.pools.lock().unwrap();
        pools
            .entry(kernel)
            .or_insert_with(|| {
                Arc::new(planned_pool(
                    kernel,
                    self.cfg.arch,
                    self.cfg.schedules,
                    self.cfg.spmm_k,
                    self.cfg.profile,
                    false,
                ))
            })
            .clone()
    }

    fn compile_inner(&self, kernel: Kernel, m: &TriMat, pinned: Option<&str>) -> Executable {
        let pool = self.pool(kernel);
        let fingerprint = m.fingerprint();
        let key = cache::Key::new(
            kernel,
            self.cfg.arch.slug(),
            fingerprint,
            cache::config_digest(
                &pool.space.params,
                self.cfg.schedules,
                self.cfg.spmm_k,
                self.cfg.autotune.k(),
                pinned,
            ),
        );
        if let Some(hit) = cache::lookup(&key) {
            return Executable::new(kernel, self.cfg.spmm_k, hit);
        }

        let stats = MatrixStats::of(m);
        // Shortlist selection: `cost::rank_execs` is the one
        // implementation of the predicted-ascending, index-tie
        // ordering contract (shared with the sweep's shortlist). A
        // pinned compile skips ranking the pool entirely (pool sweeps
        // like `kernels_micro` would otherwise pay O(pool²)).
        let shortlist: Vec<usize> = match pinned {
            Some(id) => {
                vec![pool.plans.iter().position(|p| p.id == id).expect("checked by caller")]
            }
            None => {
                assert!(!pool.plans.is_empty(), "empty plan pool for {kernel:?}");
                let execs: Vec<concretize::Plan> = pool.plans.iter().map(|p| p.exec).collect();
                let order =
                    cost::rank_execs(kernel, self.cfg.spmm_k, &execs, &stats, &pool.space.params);
                let k = self.cfg.autotune.k().clamp(1, pool.plans.len());
                order[..k].to_vec()
            }
        };
        // Features/predictions for the shortlist only — what the
        // measure loop archives and the winner's explain() reports.
        // `rank_execs` scored with the same dot product, so the
        // re-extraction is bit-identical to the ranking pass above.
        let short_fvs: Vec<FeatureVec> = shortlist
            .iter()
            .map(|&pi| pool.plans[pi].features(kernel, self.cfg.spmm_k, &stats, &pool.space.params))
            .collect();
        let short_pred: Vec<f64> =
            short_fvs.iter().map(|f| f.dot(&pool.space.params.weights).max(1e-12)).collect();
        let (win_si, prepared, measured, mut samples) =
            self.select(kernel, m, &pool, &shortlist, &short_fvs, &short_pred);

        // The online-calibration hook: archive what the clock said so
        // `forelem calibrate` can refit the serving profile. The label
        // reuses the fingerprint already computed for the cache key;
        // archive failures must never fail a compile.
        if self.cfg.archive && !samples.is_empty() {
            let label = format!("fp{fingerprint:016x}");
            for s in &mut samples {
                s.matrix = label.clone();
            }
            if let Err(e) = artifacts::append_samples(self.cfg.arch.slug(), &samples) {
                eprintln!("warning: could not archive autotune samples: {e}");
            }
        }

        let compiled = Arc::new(Compiled {
            plan: pool.plans[shortlist[win_si]].clone(),
            prepared,
            stats,
            params: pool.space.params,
            features: short_fvs[win_si],
            predicted_secs: short_pred[win_si],
            measured_secs: measured,
            profile_loaded: pool.profile_loaded,
        });
        cache::insert(key, Arc::clone(&compiled));
        Executable::new(kernel, self.cfg.spmm_k, compiled)
    }

    /// Prepare the shortlist (plan-keyed storage cache) and, when it
    /// has more than one entry, run the measure loop: time each
    /// candidate under the quick protocol and keep the fastest.
    /// `fvs`/`predicted` are aligned with `shortlist` (which holds
    /// pool indices). Returns `(winning shortlist index, its storage,
    /// its measured seconds, one calibration sample per measurement)`
    /// — samples come back with an empty `matrix` label; the caller
    /// stamps the fingerprint and archives them.
    fn select(
        &self,
        kernel: Kernel,
        m: &TriMat,
        pool: &PlannedPool,
        shortlist: &[usize],
        fvs: &[FeatureVec],
        predicted: &[f64],
    ) -> (usize, Arc<concretize::Prepared>, Option<f64>, Vec<Sample>) {
        let execs: Vec<concretize::Plan> =
            shortlist.iter().map(|&pi| pool.plans[pi].exec).collect();
        let prepared = concretize::prepare_many(&execs, m, crate::util::pool::default_workers());
        // Schedule auxiliaries (band splits, TrSv level sets) are part
        // of the generated data structure — built at compile time, not
        // on the first serve (and never inside a timed region).
        for p in &prepared {
            match kernel {
                Kernel::Spmv => p.ensure_bands(),
                Kernel::Trsv => p.ensure_levels(),
                Kernel::Spmm => {}
            }
        }
        let mut prepared: Vec<Arc<concretize::Prepared>> =
            prepared.into_iter().map(Arc::new).collect();
        if shortlist.len() <= 1 {
            return (0, prepared.remove(0), None, Vec::new());
        }

        let x = workload(m.ncols.max(m.nrows), 0xC0FFEE);
        let b = if kernel == Kernel::Spmm {
            workload(m.ncols * self.cfg.spmm_k, 0xBEEF)
        } else {
            Vec::new()
        };
        let mut samples: Vec<Sample> = Vec::with_capacity(shortlist.len());
        let mut best: Option<(usize, f64)> = None;
        for (si, &pi) in shortlist.iter().enumerate() {
            let p = &prepared[si];
            let t = match kernel {
                Kernel::Spmv => {
                    let mut y = vec![0.0; m.nrows];
                    time_fn(&self.cfg.bench, || {
                        p.spmv(&x[..m.ncols], &mut y);
                        black_box(&y);
                    })
                }
                Kernel::Spmm => {
                    let mut c = vec![0.0; m.nrows * self.cfg.spmm_k];
                    time_fn(&self.cfg.bench, || {
                        p.spmm(&b, self.cfg.spmm_k, &mut c);
                        black_box(&c);
                    })
                }
                Kernel::Trsv => {
                    let mut xs = vec![0.0; m.nrows];
                    time_fn(&self.cfg.bench, || {
                        p.trsv(&x[..m.nrows], &mut xs);
                        black_box(&xs);
                    })
                }
            };
            samples.push(Sample {
                matrix: String::new(), // stamped by the caller
                plan_id: pool.plans[pi].id.clone(),
                features: fvs[si].0,
                measured_secs: t.median,
                predicted_secs: predicted[si],
            });
            if best.map(|(_, bt)| t.median < bt).unwrap_or(true) {
                best = Some((si, t.median));
            }
        }
        let (si, secs) = best.expect("non-empty shortlist");
        (si, prepared.swap_remove(si), Some(secs), samples)
    }
}

/// Deterministic measurement workload (same generator family as the
/// sweep's, so engine measurements are comparable across processes).
fn workload(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    fn engine_small() -> Engine {
        Engine::builder().arch(Arch::HostSmall).profile(false).archive(false).build()
    }

    #[test]
    fn compile_executes_all_three_kernels_correctly() {
        let m = gen::uniform_random(40, 40, 280, 900);
        let e = engine_small();

        let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.11).sin() + 0.4).collect();
        let exe = e.compile(Kernel::Spmv, &m);
        let mut y = vec![0.0; 40];
        exe.spmv(&x, &mut y);
        crate::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
        assert!(exe.bytes() > 0);
        assert!(exe.predicted_secs() > 0.0);

        let k = 5;
        let b: Vec<f64> = (0..40 * k).map(|i| i as f64 * 0.03 - 0.5).collect();
        let exe = e.compile(Kernel::Spmm, &m);
        let mut c = vec![0.0; 40 * k];
        exe.spmm_k(&b, k, &mut c);
        crate::util::prop::assert_close(&c, &m.spmm_ref(&b, k), 1e-10).unwrap();

        let l = m.strictly_lower();
        let exe = e.compile(Kernel::Trsv, &l);
        let mut xs = vec![0.0; 40];
        exe.trsv(&x, &mut xs);
        crate::util::prop::assert_close(&xs, &l.trsv_unit_lower_ref(&x), 1e-9).unwrap();
    }

    #[test]
    fn repeated_compiles_share_the_cached_storage() {
        let m = gen::powerlaw(36, 2.0, 18, 901);
        let e = engine_small();
        let a = e.compile(Kernel::Spmv, &m);
        let b = e.compile(Kernel::Spmv, &m);
        assert!(Arc::ptr_eq(&a.storage(), &b.storage()), "cache must Arc-share storage");
        assert_eq!(a.plan().id, b.plan().id);
        // A different matrix is a different key.
        let m2 = gen::powerlaw(36, 2.0, 18, 902);
        let c = e.compile(Kernel::Spmv, &m2);
        assert!(!Arc::ptr_eq(&a.storage(), &c.storage()));
        // A different config digest (spmm_k affects SpMM ranking) does
        // not collide either — via a second engine.
        let e2 = Engine::builder()
            .arch(Arch::HostSmall)
            .profile(false)
            .archive(false)
            .spmm_k(7)
            .build();
        let d = e2.compile(Kernel::Spmm, &m);
        assert!(!Arc::ptr_eq(&a.storage(), &d.storage()) || a.plan().id != d.plan().id);
    }

    #[test]
    fn autotune_topk_measures_and_picks_a_shortlisted_plan() {
        let m = gen::uniform_random(50, 50, 400, 903);
        let e = Engine::builder()
            .arch(Arch::HostSmall)
            .profile(false)
            .archive(false)
            .autotune(Autotune::TopK(3))
            .build();
        let exe = e.compile(Kernel::Spmv, &m);
        let secs = exe.measured_secs().expect("TopK(3) must measure");
        assert!(secs > 0.0 && secs.is_finite());
        // The winner is one of the top-3 predicted plans.
        let pool = e.plans(Kernel::Spmv);
        let stats = MatrixStats::of(&m);
        let params = crate::coordinator::sweep::Arch::HostSmall.cost_params();
        let execs: Vec<concretize::Plan> = pool.iter().map(|p| p.exec).collect();
        let order = crate::search::cost::rank_execs(Kernel::Spmv, 100, &execs, &stats, &params);
        let top3: Vec<&str> = order[..3].iter().map(|&i| pool[i].id.as_str()).collect();
        assert!(top3.contains(&exe.plan().id.as_str()), "{} not in {top3:?}", exe.plan().id);
        // Correctness is untouched by autotuning.
        let x: Vec<f64> = (0..50).map(|i| (i as f64 * 0.2).cos()).collect();
        let mut y = vec![0.0; 50];
        exe.spmv(&x, &mut y);
        crate::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
    }

    #[test]
    fn compile_pinned_respects_the_plan_id() {
        let m = gen::banded(30, 4, 0.7, 904);
        let e = engine_small();
        let exe = e.compile_pinned(Kernel::Spmv, &m, "csr.row.serial").expect("csr exists");
        assert_eq!(exe.plan().id, "csr.row.serial");
        assert!(e.compile_pinned(Kernel::Spmv, &m, "no.such.plan").is_none());
        let x: Vec<f64> = (0..30).map(|i| i as f64 * 0.1).collect();
        let mut y = vec![0.0; 30];
        exe.spmv(&x, &mut y);
        crate::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
    }

    #[test]
    fn explain_breaks_the_prediction_down() {
        let m = gen::uniform_random(25, 25, 120, 905);
        let e = engine_small();
        let exe = e.compile(Kernel::Spmv, &m);
        let ex = exe.explain();
        assert_eq!(ex.plan_id, exe.plan().id);
        assert_eq!(ex.terms.len(), crate::search::cost::N_FEATURES);
        let sum: f64 = ex.terms.iter().map(|t| t.seconds).sum();
        assert!((sum.max(1e-12) - ex.predicted_secs).abs() <= 1e-18 + 1e-12 * ex.predicted_secs);
        let text = ex.to_string();
        for name in crate::search::cost::FEATURE_NAMES {
            assert!(text.contains(name), "explain text missing {name}");
        }
        assert!(text.contains(&ex.plan_id));
        assert!(text.contains("bytes"));
    }

    #[test]
    fn engine_pool_matches_direct_enumeration() {
        let e = engine_small();
        let pool = e.plans(Kernel::Spmv);
        let direct = tree::enumerate(Kernel::Spmv, &PlanSpace::serial_only());
        let a: Vec<&String> = pool.iter().map(|p| &p.id).collect();
        let b: Vec<&String> = direct.plans.iter().map(|p| &p.id).collect();
        assert_eq!(a, b, "HostSmall engine pool must be the serial-only tree");
        // And the emitted artifact carries the cost header.
        let txt = e.emit(Kernel::Spmv, &pool[0]);
        assert!(txt.contains("/* predicted on"));
        assert!(txt.contains("/* generated:"));
    }
}
