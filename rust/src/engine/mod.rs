//! `forelem::engine` — the production-facing compile-and-serve facade.
//!
//! The paper's promise is "specification in, tuned executable out":
//! the user writes a data-structure-free forelem program and the
//! compiler derives the loop nest *and* the physical data structure.
//! This module is the single front door that delivers that contract as
//! an embedding API, wrapping the whole planner pipeline behind one
//! call:
//!
//! ```text
//! Engine::compile(kernel, &matrix)
//!   = validate (TriMat::validate — the one hard error)
//!   → enumerate (search::tree, the transformation-tree walk)
//!   → calibrated predict (search::cost under the fitted profile)
//!   → optional measure loop (Autotune::TopK(k) times the shortlist)
//!   → prepare (concretize — storage assembly + schedule auxiliaries)
//!   → Executable (spmv / spmm / trsv + plan() + bytes() + explain())
//! ```
//!
//! # Serving path
//!
//! Compiles are memoized in a **process-wide cache** keyed by
//! `(kernel, arch, matrix fingerprint, config digest)`: the second
//! `compile` of the same reservoir returns the same `Arc`-shared
//! storage without touching the planner — the repeated-traffic serving
//! path. The cache is bounded by a byte budget
//! ([`EngineBuilder::cache_budget`], default 1 GiB) with LRU eviction,
//! so a host compiling an unbounded stream of matrices stays bounded.
//! Within a single compile, the autotune shortlist is prepared
//! through `concretize::prepare_many`'s plan-keyed storage cache, so
//! schedule/traversal variants of one layout share one assembly.
//! Parallel execution — both the prepare fan-out and every parallel
//! kernel — runs on the process-wide persistent worker crew
//! (`util::pool`): workers are spawned once and parked between calls,
//! so the warm serving path performs zero thread spawns.
//!
//! # Degradation ladder
//!
//! [`Engine::compile`] returns `Err` only for an invalid reservoir
//! ([`crate::error::ForelemError::InvalidMatrix`]). Every other fault
//! — a missing/corrupt tuning profile, a panicking storage assembly, a
//! measurement that panics or hangs past the
//! [`EngineBuilder::measure_timeout`] watchdog — lands a rung down the
//! [`Health`] ladder recorded on the [`Executable`] instead of
//! surfacing. Candidates whose preparation or measurement faulted are
//! quarantined process-wide per `(matrix fingerprint, plan id)`, so
//! later compiles of the same matrix fall through to the next-ranked
//! plan without re-running a measurement already known to take the
//! process down.
//!
//! # Online calibration
//!
//! Every autotune measurement is archived as a
//! [`search::calibrate::Sample`](crate::search::calibrate::Sample)
//! (`target/tuning/<arch>.samples.jsonl` — the same line format
//! `forelem calibrate` consumes), so serving traffic keeps feeding the
//! predict→measure→refit loop. The builder auto-loads the fitted
//! `target/tuning/<arch>.profile` like the CLI sweeps do; call
//! [`EngineBuilder::profile`]`(false)` to rank on the seed model
//! (library tests do, for hermeticity).
//!
//! # Example
//!
//! ```
//! use forelem::engine::{Engine, Kernel};
//! use forelem::matrix::TriMat;
//!
//! let mut a = TriMat::new(2, 2);
//! a.push(0, 0, 2.0);
//! a.push(1, 0, 1.0);
//! a.push(1, 1, 3.0);
//! let engine = Engine::builder().profile(false).build();
//! // Errs only on an invalid reservoir; runtime faults degrade the
//! // Health rung instead.
//! let exe = engine.compile(Kernel::Spmv, &a).unwrap();
//! let mut y = [0.0; 2];
//! exe.spmv(&[1.0, 2.0], &mut y);
//! assert_eq!(y, [2.0, 7.0]);
//! ```

// The serving path must never take the host down on a recoverable
// fault; panicking escape hatches are opted into per expression, not
// reached for by habit.
#![deny(clippy::unwrap_used, clippy::expect_used)]

pub mod batch;
mod cache;
mod executable;
mod quarantine;
pub mod version;

pub use executable::{CostBreakdown, CostTerm, Executable, Health};
pub use version::{DeltaOutcome, DeltaReport, Fingerprint, Transition, VersionedMatrix};

pub use crate::baselines::Kernel;
pub use crate::coordinator::sweep::Arch;

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::bench::harness::{black_box, time_fn, BenchConfig};
use crate::concretize::{self, Layout, Schedule, Traversal};
use crate::error::ForelemError;
use crate::matrix::{MatrixStats, TriMat};
use crate::runtime::artifacts;
use crate::search::calibrate::Sample;
use crate::search::cost::{self, FeatureVec};
use crate::search::plan::{Plan, PlanSpace};
use crate::search::tree;

use executable::Compiled;

/// How much measuring `compile` may do on top of the calibrated
/// prediction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Autotune {
    /// Predict-only: trust the (calibrated) cost model's first pick.
    Off,
    /// Measure the top-`k` predicted plans and keep the fastest;
    /// `TopK(0)` and `TopK(1)` degenerate to predict-only. Each
    /// measurement is archived as a calibration sample.
    TopK(usize),
}

impl Autotune {
    fn k(&self) -> usize {
        match self {
            Autotune::Off => 0,
            Autotune::TopK(k) => *k,
        }
    }
}

/// Builder for [`Engine`] — the knobs of the compile pipeline.
#[derive(Clone, Debug)]
pub struct EngineBuilder {
    arch: Arch,
    schedules: bool,
    spmm_k: usize,
    autotune: Autotune,
    profile: bool,
    archive: bool,
    bench: BenchConfig,
    measure_timeout: Duration,
    cache_budget: usize,
    max_batch: usize,
    flush_deadline: Duration,
}

impl Default for EngineBuilder {
    fn default() -> Self {
        EngineBuilder {
            arch: Arch::HostSmall,
            schedules: true,
            spmm_k: 100,
            autotune: Autotune::Off,
            profile: true,
            archive: true,
            bench: BenchConfig::quick(),
            measure_timeout: Duration::from_secs(5),
            cache_budget: cache::DEFAULT_BUDGET,
            max_batch: 16,
            flush_deadline: Duration::from_micros(150),
        }
    }
}

impl EngineBuilder {
    /// Target architecture: selects the plan space (`HostSmall` stays
    /// serial-only, `HostLarge` adds the parallel/tiled schedules) and
    /// the cost-model seed parameters / tuning-profile slug.
    pub fn arch(mut self, arch: Arch) -> Self {
        self.arch = arch;
        self
    }

    /// Force the serial-only plan space even on a scheduled
    /// architecture (`false`); `true` (default) uses the architecture's
    /// full space.
    pub fn schedules(mut self, on: bool) -> Self {
        self.schedules = on;
        self
    }

    /// Dense-operand column count SpMM plans are ranked for and
    /// [`Executable::spmm`] executes with (default 100, the paper's k).
    pub fn spmm_k(mut self, k: usize) -> Self {
        self.spmm_k = k.max(1);
        self
    }

    /// Measure-based autotuning policy (default [`Autotune::Off`]).
    pub fn autotune(mut self, autotune: Autotune) -> Self {
        self.autotune = autotune;
        self
    }

    /// Auto-load the fitted `target/tuning/<arch>.profile` written by
    /// `forelem calibrate` (default `true`, like the CLI sweeps; pass
    /// `false` to rank on the seed cost model — tests do, for
    /// hermeticity).
    pub fn profile(mut self, on: bool) -> Self {
        self.profile = on;
        self
    }

    /// Append every autotune measurement to the per-arch calibration
    /// archive (`target/tuning/<arch>.samples.jsonl`, default `true`)
    /// so serving traffic keeps feeding the refit loop.
    pub fn archive(mut self, on: bool) -> Self {
        self.archive = on;
        self
    }

    /// Measurement protocol of the autotune loop (default
    /// `BenchConfig::quick()` — serving compiles should be cheap).
    pub fn bench(mut self, bench: BenchConfig) -> Self {
        self.bench = bench;
        self
    }

    /// Wall-clock watchdog on each autotune candidate measurement
    /// (default 5 s). A candidate that has not reported by then is
    /// quarantined and its measurement thread abandoned; the compile
    /// falls through to the remaining candidates. Not part of the
    /// cache digest — the watchdog guards liveness, it does not define
    /// the plan space.
    pub fn measure_timeout(mut self, timeout: Duration) -> Self {
        self.measure_timeout = timeout;
        self
    }

    /// Byte budget of the process-wide compile cache (default 1 GiB):
    /// each cached compile is charged its generated data structure's
    /// footprint, and inserting past the budget evicts
    /// least-recently-used entries (counted — see
    /// [`Engine::cache_evictions`]). Like the measurement watchdog this
    /// is a liveness bound, not a plan input, so it is *not* part of
    /// the cache digest: two engines differing only in budget share
    /// entries.
    pub fn cache_budget(mut self, bytes: usize) -> Self {
        self.cache_budget = bytes.max(1);
        self
    }

    /// Most requests a [`batch::BatchQueue`] coalesces into one SpMM
    /// panel (default 16). Also bounds the batch-size histogram.
    pub fn max_batch(mut self, k: usize) -> Self {
        self.max_batch = k.max(1);
        self
    }

    /// How long a batch leader holds an open batch for joiners before
    /// flushing it partial (default 150 µs — about one small-matrix
    /// SpMV, so a second concurrent request usually lands in time
    /// without adding visible latency under load).
    pub fn flush_deadline(mut self, d: Duration) -> Self {
        self.flush_deadline = d;
        self
    }

    pub fn build(self) -> Engine {
        Engine { cfg: self, pools: Mutex::new(HashMap::new()), batches: Mutex::new(HashMap::new()) }
    }
}

/// The engine-internal per-kernel planner state: the schedule-crossed
/// plan space (profile-resolved parameters) and the enumerated,
/// cost-ranked plan pool. `coordinator::sweep` drives the same seam
/// ([`planned_pool`]) for its exhaustive paper-table path instead of
/// duplicating the profile-loading + enumeration logic.
pub(crate) struct PlannedPool {
    pub space: PlanSpace,
    pub plans: Vec<Plan>,
    /// Whether `space.params` came from a fitted on-disk profile.
    pub profile_loaded: bool,
}

/// Build the plan space + enumerated pool for one kernel — stage 1 of
/// every pipeline (engine compiles and sweeps alike). A fitted tuning
/// profile, when opted in and present, replaces the seed weights (the
/// thread count stays the running machine's). `announce` prints the
/// profile note to stderr — the sweep passes `true` so fitted rankings
/// never silently replace the seed model in paper-table output; the
/// engine stays quiet (embedding hosts read
/// `CostBreakdown::profile_loaded` instead of scraping logs).
pub(crate) fn planned_pool(
    kernel: Kernel,
    arch: Arch,
    use_schedules: bool,
    dense_k: usize,
    use_profile: bool,
    announce: bool,
) -> PlannedPool {
    let mut space = arch.plan_space();
    if !use_schedules {
        // "Serial-only" means the paper's scalar serial tree: dropping
        // the schedule axis drops the vector-width axis with it.
        space.schedules = vec![Schedule::Serial];
        space.lanes = vec![1];
    }
    space.dense_k = dense_k;
    let mut profile_loaded = false;
    if use_profile {
        // Panic shield: a corrupt or adversarial profile costs at most
        // the fitted weights (Health::SeedWeights), never the compile.
        let loaded = catch_unwind(|| artifacts::load_profile(arch.slug())).unwrap_or_else(|_| {
            eprintln!("warning: tuning profile loader panicked; {} uses seed weights", arch.slug());
            None
        });
        if let Some(prof) = loaded {
            space.params = prof.params_for(space.params.threads);
            profile_loaded = true;
            if announce {
                eprintln!(
                    "note: {} ranking under fitted profile {} (--no-profile for the seed model)",
                    arch.slug(),
                    artifacts::profile_path_in(&artifacts::tuning_dir(), arch.slug()).display()
                );
            }
        }
    }
    let tree = tree::enumerate(kernel, &space);
    PlannedPool { space, plans: tree.plans, profile_loaded }
}

/// One shortlisted plan flowing through the fault-isolated pipeline:
/// pool index, stable id, execution triple, and the prediction that
/// ranked it.
struct Candidate {
    pi: usize,
    id: String,
    exec: concretize::Plan,
    fv: FeatureVec,
    predicted: f64,
}

/// The compile-and-serve facade. Construct once per process (or per
/// configuration) via [`Engine::builder`], then [`compile`](Engine::compile)
/// per (kernel, matrix); repeated compiles of the same matrix are
/// served from the process-wide cache.
pub struct Engine {
    cfg: EngineBuilder,
    pools: Mutex<HashMap<Kernel, Arc<PlannedPool>>>,
    /// Per-fingerprint request-batching queues ([`Engine::batch_queue`]).
    batches: Mutex<HashMap<u64, Arc<batch::BatchQueue>>>,
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// The architecture this engine compiles for.
    pub fn arch(&self) -> Arch {
        self.cfg.arch
    }

    /// The enumerated, cost-ranked plan pool for `kernel` (ranked
    /// against the space's nominal statistics; `compile` re-ranks per
    /// matrix).
    pub fn plans(&self, kernel: Kernel) -> Vec<Plan> {
        self.pool(kernel).plans.clone()
    }

    /// The generated C-like code of one plan, prefixed with its
    /// predicted resource footprint under this engine's (possibly
    /// fitted) parameters — the inspectable artifact.
    pub fn emit(&self, kernel: Kernel, plan: &Plan) -> String {
        let pool = self.pool(kernel);
        concretize::codegen::emit_with_cost(
            kernel,
            &plan.exec,
            pool.space.dense_k,
            &pool.space.ranking_stats(),
            &pool.space.params,
        )
    }

    /// Compile `kernel` against a tuple reservoir: rank the enumerated
    /// pool on the matrix's statistics under the calibrated parameters,
    /// optionally measure the shortlist ([`Autotune::TopK`]), assemble
    /// the winning storage, and return the bound [`Executable`].
    ///
    /// # Errors
    ///
    /// Only [`ForelemError::InvalidMatrix`] — the reservoir violates
    /// its invariants ([`TriMat::validate`]). Every runtime fault past
    /// that point degrades the [`Executable::health`] rung instead of
    /// erroring (see the module docs).
    ///
    /// For TrSv the reservoir must hold the strictly-lower triangle
    /// (as everywhere else in the crate).
    pub fn compile(&self, kernel: Kernel, m: &TriMat) -> Result<Executable, ForelemError> {
        m.validate()?;
        Ok(self.compile_inner(kernel, m, None))
    }

    /// [`compile`](Engine::compile) pinned to one plan by stable id
    /// (e.g. `"csr.row.serial"`), bypassing selection *and* the
    /// quarantine denylist — for harnesses that sweep the whole pool
    /// and for serving setups that fix a plan out-of-band.
    ///
    /// # Errors
    ///
    /// [`ForelemError::InvalidMatrix`] for a bad reservoir,
    /// [`ForelemError::UnsupportedPlan`] when the pool has no plan
    /// with this id.
    pub fn compile_pinned(
        &self,
        kernel: Kernel,
        m: &TriMat,
        plan_id: &str,
    ) -> Result<Executable, ForelemError> {
        m.validate()?;
        if !self.pool(kernel).plans.iter().any(|p| p.id == plan_id) {
            return Err(ForelemError::UnsupportedPlan {
                plan_id: plan_id.to_string(),
                reason: format!("not in this engine's {kernel:?} pool"),
            });
        }
        Ok(self.compile_inner(kernel, m, Some(plan_id)))
    }

    /// Drop every cached compile in the process (all engines). Mostly
    /// for long-running hosts that want to bound memory and for
    /// benchmarks that need cold compiles.
    pub fn clear_cache() {
        cache::clear();
    }

    /// Number of compiles currently cached process-wide.
    pub fn cache_len() -> usize {
        cache::len()
    }

    /// Total bytes of generated data structures currently cached
    /// process-wide (the quantity [`EngineBuilder::cache_budget`]
    /// bounds).
    pub fn cache_bytes() -> usize {
        cache::bytes()
    }

    /// Process-wide count of compile-cache budget evictions since
    /// process start (monotonic — long-running hosts watch the delta).
    pub fn cache_evictions() -> u64 {
        cache::evictions()
    }

    /// Number of `(matrix fingerprint, plan id)` pairs quarantined
    /// process-wide after a panicking or hung preparation/measurement.
    pub fn quarantine_len() -> usize {
        quarantine::len()
    }

    /// Drop every quarantine entry (tests and the chaos drill; a
    /// serving host might call it after a deploy that fixed a kernel).
    pub fn clear_quarantine() {
        quarantine::clear();
    }

    fn pool(&self, kernel: Kernel) -> Arc<PlannedPool> {
        let mut pools = self.pools.lock().unwrap_or_else(|p| p.into_inner());
        pools
            .entry(kernel)
            .or_insert_with(|| {
                Arc::new(planned_pool(
                    kernel,
                    self.cfg.arch,
                    self.cfg.schedules,
                    self.cfg.spmm_k,
                    self.cfg.profile,
                    false,
                ))
            })
            .clone()
    }

    fn compile_inner(&self, kernel: Kernel, m: &TriMat, pinned: Option<&str>) -> Executable {
        let pool = self.pool(kernel);
        let fingerprint = m.fingerprint();
        let key = cache::Key::new(
            kernel,
            self.cfg.arch.slug(),
            fingerprint,
            cache::config_digest(
                &pool.space.params,
                self.cfg.schedules,
                self.cfg.spmm_k,
                self.cfg.autotune.k(),
                pinned,
            ),
        );
        if let Some(hit) = cache::lookup(&key) {
            return Executable::new(kernel, self.cfg.spmm_k, hit);
        }

        let stats = MatrixStats::of(m);
        // Rung 0 or 1 before anything else runs: a requested profile
        // that did not load (missing, corrupt, bad checksum, loader
        // panic) means every prediction below ran on seed weights.
        let base = if self.cfg.profile && !pool.profile_loaded {
            Health::SeedWeights
        } else {
            Health::Calibrated
        };

        // Shortlist selection: `cost::rank_execs` is the one
        // implementation of the predicted-ascending, index-tie
        // ordering contract (shared with the sweep's shortlist),
        // thinned by the quarantine denylist so a compile falls
        // through to the next-ranked plan instead of re-running a
        // known-bad candidate. A pinned compile skips ranking the pool
        // entirely (pool sweeps like `kernels_micro` would otherwise
        // pay O(pool²)) and overrides the denylist.
        let shortlist: Vec<usize> = match pinned {
            Some(id) => pool.plans.iter().position(|p| p.id == id).into_iter().collect(),
            None => {
                assert!(!pool.plans.is_empty(), "empty plan pool for {kernel:?}");
                let execs: Vec<concretize::Plan> = pool.plans.iter().map(|p| p.exec).collect();
                let order =
                    cost::rank_execs(kernel, self.cfg.spmm_k, &execs, &stats, &pool.space.params);
                let k = self.cfg.autotune.k().clamp(1, pool.plans.len());
                let picked: Vec<usize> = order
                    .iter()
                    .copied()
                    .filter(|&pi| !quarantine::is_denied(fingerprint, &pool.plans[pi].id))
                    .take(k)
                    .collect();
                if picked.is_empty() {
                    // Every plan quarantined for this matrix: serve
                    // the reference rung rather than re-run a
                    // candidate already known to fault.
                    return self.reference_fallback(kernel, m, &pool, stats);
                }
                picked
            }
        };
        // Features/predictions for the shortlist only — what the
        // measure loop archives and the winner's explain() reports.
        // `rank_execs` scored with the same dot product, so the
        // re-extraction is bit-identical to the ranking pass above.
        let cands: Vec<Candidate> = shortlist
            .iter()
            .map(|&pi| {
                let p = &pool.plans[pi];
                let fv = p.features(kernel, self.cfg.spmm_k, &stats, &pool.space.params);
                Candidate {
                    pi,
                    id: p.id.clone(),
                    exec: p.exec,
                    fv,
                    predicted: fv.dot(&pool.space.params.weights).max(1e-12),
                }
            })
            .collect();

        let mut survivors = self.prepare_candidates(kernel, m, cands, fingerprint);
        if survivors.is_empty() {
            return self.reference_fallback(kernel, m, &pool, stats);
        }
        let (win, measured, mut samples, unmeasured) =
            self.measure_candidates(kernel, m, &survivors, fingerprint);
        let health = if unmeasured { base.max(Health::PredictedOnly) } else { base };

        // The online-calibration hook: archive what the clock said so
        // `forelem calibrate` can refit the serving profile. The label
        // reuses the fingerprint already computed for the cache key;
        // archive failures (including a panicking writer) must never
        // fail a compile.
        if self.cfg.archive && !samples.is_empty() {
            let label = format!("fp{fingerprint:016x}");
            for s in &mut samples {
                s.matrix = label.clone();
            }
            let slug = self.cfg.arch.slug();
            match catch_unwind(AssertUnwindSafe(|| artifacts::append_samples(slug, &samples))) {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => eprintln!("warning: could not archive autotune samples: {e}"),
                Err(_) => eprintln!("warning: sample archiver panicked; samples not archived"),
            }
        }

        let (c, prepared) = survivors.swap_remove(win);
        let compiled = Arc::new(Compiled {
            plan: pool.plans[c.pi].clone(),
            prepared,
            stats,
            params: pool.space.params,
            features: c.fv,
            predicted_secs: c.predicted,
            measured_secs: measured,
            profile_loaded: pool.profile_loaded,
            health,
            fingerprint,
        });
        // Degraded compiles (PredictedOnly / ReferenceSerial) are NOT
        // cached: with the faulty candidates quarantined, the next
        // compile of this matrix can climb back up the ladder.
        if health <= Health::SeedWeights {
            cache::insert(key, Arc::clone(&compiled), self.cfg.cache_budget);
        }
        Executable::new(kernel, self.cfg.spmm_k, compiled)
    }

    /// Assemble storage + schedule auxiliaries for every shortlisted
    /// candidate, fault-isolated. The fast path is one batch through
    /// `prepare_many`'s plan-keyed storage cache; if the batch panics,
    /// each candidate is retried alone and the ones that still panic
    /// are quarantined — returning only the survivors (possibly none;
    /// the caller then serves the reference rung).
    fn prepare_candidates(
        &self,
        kernel: Kernel,
        m: &TriMat,
        cands: Vec<Candidate>,
        fingerprint: u64,
    ) -> Vec<(Candidate, Arc<concretize::Prepared>)> {
        // Schedule auxiliaries (band splits, TrSv level sets) are part
        // of the generated data structure — built at compile time, not
        // on the first serve (and never inside a timed region).
        let ensure = |p: &concretize::Prepared| {
            match kernel {
                Kernel::Spmv => p.ensure_bands(),
                Kernel::Trsv => p.ensure_levels(),
                Kernel::Spmm => {}
            }
            // On a NUMA machine with pinning live, walk each parallel
            // partition range on the crew worker that will serve it, so
            // the kernel-visible pages are first-touch-placed on that
            // worker's node. A no-op everywhere else.
            if crate::runtime::topology::numa_active() {
                p.first_touch();
            }
        };
        let batch = catch_unwind(AssertUnwindSafe(|| {
            crate::faultpoint!("engine.prepare");
            let execs: Vec<concretize::Plan> = cands.iter().map(|c| c.exec).collect();
            let workers = crate::util::pool::default_workers();
            let prepared = concretize::prepare_many(&execs, m, workers);
            for p in &prepared {
                ensure(p);
            }
            prepared.into_iter().map(Arc::new).collect::<Vec<_>>()
        }));
        match batch {
            Ok(prepared) => cands.into_iter().zip(prepared).collect(),
            Err(_) => {
                eprintln!("warning: batch candidate preparation panicked; retrying per candidate");
                let mut out = Vec::new();
                for c in cands {
                    let one = catch_unwind(AssertUnwindSafe(|| {
                        crate::faultpoint!("engine.prepare");
                        let p = concretize::prepare(c.exec, m);
                        ensure(&p);
                        Arc::new(p)
                    }));
                    match one {
                        Ok(p) => out.push((c, p)),
                        Err(_) => {
                            quarantine::deny(fingerprint, &c.id, "storage preparation panicked")
                        }
                    }
                }
                out
            }
        }
    }

    /// The fault-isolated measure loop: when more than one candidate
    /// survived preparation, time each on its own watchdogged thread
    /// and keep the fastest. A candidate that panics or outlives
    /// [`EngineBuilder::measure_timeout`] is quarantined (its thread
    /// abandoned — the price of never deadlocking the compile) and the
    /// loop falls through. Returns `(winning survivor index, measured
    /// seconds, one calibration sample per successful measurement,
    /// every-measurement-failed)`; samples come back with an empty
    /// `matrix` label — the caller stamps the fingerprint.
    fn measure_candidates(
        &self,
        kernel: Kernel,
        m: &TriMat,
        cands: &[(Candidate, Arc<concretize::Prepared>)],
        fingerprint: u64,
    ) -> (usize, Option<f64>, Vec<Sample>, bool) {
        if cands.len() <= 1 {
            return (0, None, Vec::new(), false);
        }
        let x = Arc::new(workload(m.ncols.max(m.nrows), 0xC0FFEE));
        let b = Arc::new(if kernel == Kernel::Spmm {
            workload(m.ncols * self.cfg.spmm_k, 0xBEEF)
        } else {
            Vec::new()
        });
        let (nrows, ncols, dense_k) = (m.nrows, m.ncols, self.cfg.spmm_k);
        let bench = self.cfg.bench;
        let mut samples: Vec<Sample> = Vec::with_capacity(cands.len());
        let mut best: Option<(usize, f64)> = None;
        for (ci, (c, p)) in cands.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            let (p, x, b) = (Arc::clone(p), Arc::clone(&x), Arc::clone(&b));
            let spawned = std::thread::Builder::new()
                .name(format!("forelem-measure-{}", c.id))
                .spawn(move || {
                    let timed = catch_unwind(AssertUnwindSafe(|| {
                        crate::faultpoint!("engine.measure");
                        let t = match kernel {
                            Kernel::Spmv => {
                                let mut y = vec![0.0; nrows];
                                time_fn(&bench, || {
                                    p.spmv(&x[..ncols], &mut y);
                                    black_box(&y);
                                })
                            }
                            Kernel::Spmm => {
                                let mut cbuf = vec![0.0; nrows * dense_k];
                                time_fn(&bench, || {
                                    p.spmm(&b, dense_k, &mut cbuf);
                                    black_box(&cbuf);
                                })
                            }
                            Kernel::Trsv => {
                                let mut xs = vec![0.0; nrows];
                                time_fn(&bench, || {
                                    p.trsv(&x[..nrows], &mut xs);
                                    black_box(&xs);
                                })
                            }
                        };
                        t.median
                    }));
                    // The receiver may have given up on us (watchdog
                    // fired); a dead channel is not our problem.
                    let _ = tx.send(timed.map_err(|_| ()));
                });
            let outcome: Result<f64, String> = match spawned {
                Err(e) => Err(format!("measurement thread failed to spawn: {e}")),
                Ok(_detached) => match rx.recv_timeout(self.cfg.measure_timeout) {
                    Ok(Ok(secs)) => Ok(secs),
                    Ok(Err(())) => Err("measurement panicked".to_string()),
                    Err(mpsc::RecvTimeoutError::Timeout) => Err(format!(
                        "measurement exceeded the {} ms watchdog (thread abandoned)",
                        self.cfg.measure_timeout.as_millis()
                    )),
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        Err("measurement thread died without reporting".to_string())
                    }
                },
            };
            match outcome {
                Ok(secs) => {
                    samples.push(Sample {
                        matrix: String::new(), // stamped by the caller
                        plan_id: c.id.clone(),
                        features: c.fv.0,
                        measured_secs: secs,
                        predicted_secs: c.predicted,
                    });
                    if best.map(|(_, bt)| secs < bt).unwrap_or(true) {
                        best = Some((ci, secs));
                    }
                }
                Err(reason) => quarantine::deny(fingerprint, &c.id, &reason),
            }
        }
        match best {
            Some((ci, secs)) => (ci, Some(secs), samples, false),
            // Every measurement failed: serve the predicted best
            // (survivors are predicted-ascending) unmeasured.
            None => (0, None, samples, true),
        }
    }

    /// The ladder's bottom rung: candidate selection/preparation could
    /// not produce a single runnable plan, so serve the reference
    /// serial CSR execution — the one plan whose assembly and loop
    /// nest are always valid. Never cached, so a later compile retries
    /// the full pipeline.
    fn reference_fallback(
        &self,
        kernel: Kernel,
        m: &TriMat,
        pool: &PlannedPool,
        stats: MatrixStats,
    ) -> Executable {
        let pi = pool
            .plans
            .iter()
            .position(|p| {
                p.exec.layout == Layout::Csr
                    && p.exec.traversal == Traversal::RowWise
                    && p.exec.schedule == Schedule::Serial
                    && p.exec.lanes == 1
            })
            .unwrap_or(0);
        let plan = pool.plans[pi].clone();
        eprintln!("warning: {kernel:?} compile degraded to the reference serial plan {}", plan.id);
        let prepared = concretize::prepare(plan.exec, m);
        match kernel {
            Kernel::Spmv => prepared.ensure_bands(),
            Kernel::Trsv => prepared.ensure_levels(),
            Kernel::Spmm => {}
        }
        let fv = plan.features(kernel, self.cfg.spmm_k, &stats, &pool.space.params);
        let predicted = fv.dot(&pool.space.params.weights).max(1e-12);
        let compiled = Arc::new(Compiled {
            plan,
            prepared: Arc::new(prepared),
            stats,
            params: pool.space.params,
            features: fv,
            predicted_secs: predicted,
            measured_secs: None,
            profile_loaded: pool.profile_loaded,
            health: Health::ReferenceSerial,
            fingerprint: m.fingerprint(),
        });
        Executable::new(kernel, self.cfg.spmm_k, compiled)
    }
}

/// Deterministic measurement workload (same generator family as the
/// sweep's, so engine measurements are comparable across processes).
fn workload(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = crate::util::rng::Rng::new(seed);
    (0..n).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::matrix::gen;

    fn engine_small() -> Engine {
        Engine::builder().arch(Arch::HostSmall).profile(false).archive(false).build()
    }

    #[test]
    fn compile_executes_all_three_kernels_correctly() {
        let m = gen::uniform_random(40, 40, 280, 900);
        let e = engine_small();

        let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.11).sin() + 0.4).collect();
        let exe = e.compile(Kernel::Spmv, &m).expect("valid matrix");
        let mut y = vec![0.0; 40];
        exe.spmv(&x, &mut y);
        crate::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
        assert!(exe.bytes() > 0);
        assert!(exe.predicted_secs() > 0.0);

        let k = 5;
        let b: Vec<f64> = (0..40 * k).map(|i| i as f64 * 0.03 - 0.5).collect();
        let exe = e.compile(Kernel::Spmm, &m).expect("valid matrix");
        let mut c = vec![0.0; 40 * k];
        exe.spmm_k(&b, k, &mut c);
        crate::util::prop::assert_close(&c, &m.spmm_ref(&b, k), 1e-10).unwrap();

        let l = m.strictly_lower();
        let exe = e.compile(Kernel::Trsv, &l).expect("valid matrix");
        let mut xs = vec![0.0; 40];
        exe.trsv(&x, &mut xs);
        crate::util::prop::assert_close(&xs, &l.trsv_unit_lower_ref(&x), 1e-9).unwrap();
    }

    #[test]
    fn repeated_compiles_share_the_cached_storage() {
        let m = gen::powerlaw(36, 2.0, 18, 901);
        let e = engine_small();
        let a = e.compile(Kernel::Spmv, &m).expect("valid matrix");
        let b = e.compile(Kernel::Spmv, &m).expect("valid matrix");
        assert!(Arc::ptr_eq(&a.storage(), &b.storage()), "cache must Arc-share storage");
        assert_eq!(a.plan().id, b.plan().id);
        // A different matrix is a different key.
        let m2 = gen::powerlaw(36, 2.0, 18, 902);
        let c = e.compile(Kernel::Spmv, &m2).expect("valid matrix");
        assert!(!Arc::ptr_eq(&a.storage(), &c.storage()));
        // A different config digest (spmm_k affects SpMM ranking) does
        // not collide either — via a second engine.
        let e2 = Engine::builder()
            .arch(Arch::HostSmall)
            .profile(false)
            .archive(false)
            .spmm_k(7)
            .build();
        let d = e2.compile(Kernel::Spmm, &m).expect("valid matrix");
        assert!(!Arc::ptr_eq(&a.storage(), &d.storage()) || a.plan().id != d.plan().id);
    }

    #[test]
    fn autotune_topk_measures_and_picks_a_shortlisted_plan() {
        let m = gen::uniform_random(50, 50, 400, 903);
        let e = Engine::builder()
            .arch(Arch::HostSmall)
            .profile(false)
            .archive(false)
            .autotune(Autotune::TopK(3))
            .build();
        let exe = e.compile(Kernel::Spmv, &m).expect("valid matrix");
        let secs = exe.measured_secs().expect("TopK(3) must measure");
        assert!(secs > 0.0 && secs.is_finite());
        assert_eq!(exe.health(), Health::Calibrated, "clean autotune stays on the top rung");
        // The winner is one of the top-3 predicted plans.
        let pool = e.plans(Kernel::Spmv);
        let stats = MatrixStats::of(&m);
        let params = crate::coordinator::sweep::Arch::HostSmall.cost_params();
        let execs: Vec<concretize::Plan> = pool.iter().map(|p| p.exec).collect();
        let order = crate::search::cost::rank_execs(Kernel::Spmv, 100, &execs, &stats, &params);
        let top3: Vec<&str> = order[..3].iter().map(|&i| pool[i].id.as_str()).collect();
        assert!(top3.contains(&exe.plan().id.as_str()), "{} not in {top3:?}", exe.plan().id);
        // Correctness is untouched by autotuning.
        let x: Vec<f64> = (0..50).map(|i| (i as f64 * 0.2).cos()).collect();
        let mut y = vec![0.0; 50];
        exe.spmv(&x, &mut y);
        crate::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
    }

    #[test]
    fn compile_pinned_respects_the_plan_id() {
        let m = gen::banded(30, 4, 0.7, 904);
        let e = engine_small();
        let exe = e.compile_pinned(Kernel::Spmv, &m, "csr.row.serial").expect("csr exists");
        assert_eq!(exe.plan().id, "csr.row.serial");
        let err = e.compile_pinned(Kernel::Spmv, &m, "no.such.plan").unwrap_err();
        assert_eq!(err.class(), "unsupported-plan");
        let x: Vec<f64> = (0..30).map(|i| i as f64 * 0.1).collect();
        let mut y = vec![0.0; 30];
        exe.spmv(&x, &mut y);
        crate::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
    }

    #[test]
    fn invalid_matrices_are_the_one_hard_error() {
        let e = engine_small();
        let empty = TriMat::new(0, 4);
        let err = e.compile(Kernel::Spmv, &empty).unwrap_err();
        assert_eq!(err.class(), "invalid-matrix");
        let err = e.compile_pinned(Kernel::Spmv, &empty, "csr.row.serial").unwrap_err();
        assert_eq!(err.class(), "invalid-matrix", "pinned path validates too");
        // A healthy compile sits on the top rung and reports so.
        let m = gen::uniform_random(20, 20, 80, 907);
        let exe = e.compile(Kernel::Spmv, &m).expect("valid matrix");
        assert_eq!(exe.health(), Health::Calibrated);
        assert!(!exe.health().degraded());
        assert_eq!(exe.explain().health, Health::Calibrated);
        // The ladder's order backs `degraded()` and alarm thresholds.
        assert!(Health::Calibrated < Health::SeedWeights);
        assert!(Health::SeedWeights < Health::PredictedOnly);
        assert!(Health::PredictedOnly < Health::ReferenceSerial);
    }

    #[test]
    fn quarantined_plans_fall_through_to_the_next_ranked() {
        let m = gen::uniform_random(30, 30, 200, 906);
        let e = engine_small();
        // Rank the pool exactly as compile_inner will, then deny the
        // predicted best for this matrix before the first compile.
        let pool = e.plans(Kernel::Spmv);
        let stats = MatrixStats::of(&m);
        let params = Arch::HostSmall.cost_params();
        let execs: Vec<concretize::Plan> = pool.iter().map(|p| p.exec).collect();
        let order = cost::rank_execs(Kernel::Spmv, 100, &execs, &stats, &params);
        let top = pool[order[0]].id.clone();
        let next = pool[order[1]].id.clone();
        quarantine::deny(m.fingerprint(), &top, "test quarantine");
        assert!(Engine::quarantine_len() >= 1);
        let exe = e.compile(Kernel::Spmv, &m).expect("valid matrix");
        assert_eq!(exe.plan().id, next, "selection must fall through past the denylist");
        // The pinned API overrides the denylist (explicit request).
        let pinned = e.compile_pinned(Kernel::Spmv, &m, &top).expect("pin overrides quarantine");
        assert_eq!(pinned.plan().id, top);
        // Numerics stay correct on the fallback plan.
        let x: Vec<f64> = (0..30).map(|i| (i as f64 * 0.07).sin()).collect();
        let mut y = vec![0.0; 30];
        exe.spmv(&x, &mut y);
        crate::util::prop::assert_close(&y, &m.spmv_ref(&x), 1e-10).unwrap();
    }

    #[test]
    fn explain_breaks_the_prediction_down() {
        let m = gen::uniform_random(25, 25, 120, 905);
        let e = engine_small();
        let exe = e.compile(Kernel::Spmv, &m).expect("valid matrix");
        let ex = exe.explain();
        assert_eq!(ex.plan_id, exe.plan().id);
        assert_eq!(ex.terms.len(), crate::search::cost::N_FEATURES);
        let sum: f64 = ex.terms.iter().map(|t| t.seconds).sum();
        assert!((sum.max(1e-12) - ex.predicted_secs).abs() <= 1e-18 + 1e-12 * ex.predicted_secs);
        let text = ex.to_string();
        for name in crate::search::cost::FEATURE_NAMES {
            assert!(text.contains(name), "explain text missing {name}");
        }
        assert!(text.contains(&ex.plan_id));
        assert!(text.contains("bytes"));
    }

    #[test]
    fn engine_pool_matches_direct_enumeration() {
        let e = engine_small();
        let pool = e.plans(Kernel::Spmv);
        let direct = tree::enumerate(Kernel::Spmv, &PlanSpace::serial_only());
        let a: Vec<&String> = pool.iter().map(|p| &p.id).collect();
        let b: Vec<&String> = direct.plans.iter().map(|p| &p.id).collect();
        assert_eq!(a, b, "HostSmall engine pool must be the serial-only tree");
        // And the emitted artifact carries the cost header.
        let txt = e.emit(Kernel::Spmv, &pool[0]);
        assert!(txt.contains("/* predicted on"));
        assert!(txt.contains("/* generated:"));
    }
}
