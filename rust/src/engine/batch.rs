//! Request batching — coalesce concurrent same-matrix SpMV calls into
//! one planned SpMM panel (the serving half of the paper's thesis: the
//! *workload* picks the routine, and a workload of k concurrent SpMVs
//! on one fingerprint IS an SpMM(k)).
//!
//! # Queue lifecycle
//!
//! A [`BatchQueue`] is built per `(matrix fingerprint, arch)` via
//! [`Engine::batch_queue`]. [`BatchQueue::submit`] is the only entry
//! point; it is leader/follower group commit:
//!
//! * **fast path** — if batching can never pay on this matrix
//!   (`pass_through`) or no other submission is in flight, the request
//!   runs the planned solo SpMV immediately: one branch and two relaxed
//!   counter bumps on top of the bare `Executable::spmv`, no lock, no
//!   deadline wait. k = 1 never queues.
//! * **join** — with the queue's `state` lock held, a submitter either
//!   joins the currently open batch (pushing its `x` under the slot
//!   lock, so its result index is race-free) or opens a new one and
//!   becomes that batch's *leader*. A join that fills the batch to
//!   `max_batch` seals it on the spot and wakes the leader.
//! * **flush** — the leader waits on the slot condvar until the batch
//!   seals or `flush_deadline` expires (partial batches flush on the
//!   deadline: the leader clears `state` first, then seals, so late
//!   submitters open a fresh batch instead of joining a sealed one),
//!   then executes the whole group and distributes per-waiter results.
//!
//! Lock order is strictly `state → slot.m`, on every path including the
//! deadline re-seal; the condvar waits hold only `slot.m`.
//!
//! # Cost-model batch decision
//!
//! Whether a sealed group of k requests runs as one SpMM(k) panel or as
//! k planned SpMVs is decided by [`cost::batch_decision`] under the
//! same (possibly fitted) parameters that rank every compile: at
//! construction the queue finds `min_k_pays`, the smallest k whose
//! predicted panel time (including pack/scatter traffic) beats k solo
//! serves. Groups below the threshold loop the solo executable; groups
//! at or above it run `Executable::spmm_k(k)` on the plan the model
//! ranks best *for that k* (compiled once per distinct k, memoized —
//! the process-wide compile cache dedups the storage underneath).
//!
//! # Bit-identity contract
//!
//! Batched answers must be bit-identical to the solo SpMV the caller
//! would have gotten, so batching is a pure throughput knob — never a
//! numerics change. Both sides of the decision are therefore restricted
//! to the *canonical* plan sets: row-wise CSR/CSR-AoS at `lanes == 1`,
//! whose per-slot reduction folds from 0.0 in `p`-ascending order for
//! SpMV (serial and row-partitioned parallel alike) and per panel
//! column for SpMM (`kernels::spmm::csr_rowdot_k` is the structural
//! witness; `axpy_k4` accumulates each slot in the same order). Tiled
//! SpMV (band-split accumulation reassociates) and wide lanes (the
//! AVX2 path is machine-dependent) are excluded from both sides.
//!
//! # Fault isolation
//!
//! The flush body runs under `catch_unwind` with the `batch.flush`
//! chaos point at its head: a panicking flush marks that batch
//! *poisoned*, wakes its waiters — followers panic with a clear
//! message, the leader re-raises the original payload — and leaves the
//! queue itself healthy for the next batch. One bad group never takes
//! the queue down.

use std::collections::HashMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::baselines::Kernel;
use crate::concretize::{Layout, Plan as ExecPlan, Schedule, Traversal};
use crate::error::ForelemError;
use crate::matrix::{MatrixStats, TriMat};
use crate::search::cost::{self, CostParams};

use super::{Engine, EngineBuilder, Executable};

/// Is this execution triple in the canonical SpMV set — serial or
/// row-partitioned row-wise CSR/CSR-AoS, scalar lanes — whose
/// reduction order defines the bit-identity contract?
fn canonical_spmv(e: &ExecPlan) -> bool {
    matches!(e.layout, Layout::Csr | Layout::CsrAos)
        && e.traversal == Traversal::RowWise
        && matches!(e.schedule, Schedule::Serial | Schedule::Parallel { .. })
        && e.lanes == 1
}

/// Canonical SpMM set: same layouts/traversal/lanes; any schedule is
/// admissible because parallel splits rows and tiled splits the dense
/// `k` axis into panels — neither reassociates a per-column reduction.
fn canonical_spmm(e: &ExecPlan) -> bool {
    matches!(e.layout, Layout::Csr | Layout::CsrAos)
        && e.traversal == Traversal::RowWise
        && e.lanes == 1
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// One in-flight batch: requests pack into `xs` until sealed, the
/// leader's flush fills `results` (indexed like `xs`) and flips `done`
/// — or `poisoned` when the flush panicked.
struct Flight {
    xs: Vec<Vec<f64>>,
    results: Vec<Vec<f64>>,
    sealed: bool,
    /// Sealed by filling to `max_batch` (vs the leader's deadline).
    sealed_full: bool,
    done: bool,
    poisoned: bool,
}

struct BatchSlot {
    m: Mutex<Flight>,
    cv: Condvar,
}

impl BatchSlot {
    fn new(x: &[f64]) -> Self {
        BatchSlot {
            m: Mutex::new(Flight {
                xs: vec![x.to_vec()],
                results: vec![Vec::new()],
                sealed: false,
                sealed_full: false,
                done: false,
                poisoned: false,
            }),
            cv: Condvar::new(),
        }
    }
}

/// Decrement-on-drop guard so a poisoned waiter's panic still releases
/// its in-flight slot (otherwise the fast-path invariant would rot).
struct InflightGuard<'a>(&'a AtomicUsize);

impl Drop for InflightGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Monotonic counters of one queue — read with [`BatchQueue::stats`].
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct BatchStats {
    /// Total `submit` calls.
    pub submitted: u64,
    /// Requests answered from a coalesced SpMM panel.
    pub batched: u64,
    /// Requests answered by the solo SpMV plan (fast path, k = 1
    /// flushes, and sub-threshold groups).
    pub solo: u64,
    /// Queue flushes executed (one per sealed batch).
    pub flushes: u64,
    /// Flushes sealed by the deadline with a partial batch.
    pub deadline_flushes: u64,
    /// Flushes sealed by reaching `max_batch`.
    pub full_flushes: u64,
    /// Batches whose flush panicked (their waiters were poisoned).
    pub poisoned_batches: u64,
    /// `hist[k]` = groups served at size k (`hist[1]` counts the solo
    /// fast path too); length `max_batch + 1`.
    pub hist: Vec<u64>,
}

struct Counters {
    submitted: AtomicU64,
    batched: AtomicU64,
    solo: AtomicU64,
    flushes: AtomicU64,
    deadline_flushes: AtomicU64,
    full_flushes: AtomicU64,
    poisoned_batches: AtomicU64,
    hist: Vec<AtomicU64>,
}

impl Counters {
    fn new(max_batch: usize) -> Self {
        Counters {
            submitted: AtomicU64::new(0),
            batched: AtomicU64::new(0),
            solo: AtomicU64::new(0),
            flushes: AtomicU64::new(0),
            deadline_flushes: AtomicU64::new(0),
            full_flushes: AtomicU64::new(0),
            poisoned_batches: AtomicU64::new(0),
            hist: (0..=max_batch).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn bump_hist(&self, k: usize) {
        if let Some(slot) = self.hist.get(k) {
            slot.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// The per-`(fingerprint, arch)` coalescing queue. See the module docs
/// for lifecycle, decision and contract; construct via
/// [`Engine::batch_queue`].
pub struct BatchQueue {
    engine: Engine,
    m: TriMat,
    nrows: usize,
    ncols: usize,
    max_batch: usize,
    flush_deadline: Duration,
    /// The best-ranked canonical solo SpMV executable.
    solo: Executable,
    solo_id: String,
    /// Canonical SpMM candidates `(plan id, triple)` the per-k ranking
    /// chooses from.
    spmm_plans: Vec<(String, ExecPlan)>,
    /// Canonical SpMV triples — the solo side of `batch_decision`.
    spmv_execs: Vec<ExecPlan>,
    stats_m: MatrixStats,
    params: CostParams,
    /// Smallest group size whose predicted panel beats k solo serves;
    /// `usize::MAX` when batching never pays on this matrix.
    min_k_pays: usize,
    per_k: Mutex<HashMap<usize, Executable>>,
    state: Mutex<Option<Arc<BatchSlot>>>,
    inflight: AtomicUsize,
    counters: Counters,
}

impl BatchQueue {
    pub(super) fn new(cfg: &EngineBuilder, m: &TriMat) -> Result<BatchQueue, ForelemError> {
        m.validate()?;
        let engine = cfg.clone().build();
        let stats_m = MatrixStats::of(m);
        let spmv_pool = engine.pool(Kernel::Spmv);
        let spmm_pool = engine.pool(Kernel::Spmm);
        let params = spmv_pool.space.params;
        let spmv_canon: Vec<(String, ExecPlan)> = spmv_pool
            .plans
            .iter()
            .filter(|p| canonical_spmv(&p.exec))
            .map(|p| (p.id.clone(), p.exec))
            .collect();
        let spmm_plans: Vec<(String, ExecPlan)> = spmm_pool
            .plans
            .iter()
            .filter(|p| canonical_spmm(&p.exec))
            .map(|p| (p.id.clone(), p.exec))
            .collect();
        let spmv_execs: Vec<ExecPlan> = spmv_canon.iter().map(|(_, e)| *e).collect();
        let Some(&best) =
            cost::rank_execs(Kernel::Spmv, 1, &spmv_execs, &stats_m, &params).first()
        else {
            return Err(ForelemError::UnsupportedPlan {
                plan_id: "<canonical spmv>".into(),
                reason: "plan pool has no bit-identity-canonical SpMV plan".into(),
            });
        };
        let solo_id = spmv_canon[best].0.clone();
        let solo = engine.compile_pinned(Kernel::Spmv, m, &solo_id)?;
        let max_batch = cfg.max_batch.max(1);
        let spmm_execs: Vec<ExecPlan> = spmm_plans.iter().map(|(_, e)| *e).collect();
        let mut min_k_pays = usize::MAX;
        for k in 2..=max_batch {
            match cost::batch_decision(k, &spmv_execs, &spmm_execs, &stats_m, &params) {
                Some(d) if d.batch_pays() => {
                    min_k_pays = k;
                    break;
                }
                Some(_) => {}
                None => break,
            }
        }
        Ok(BatchQueue {
            engine,
            m: m.clone(),
            nrows: m.nrows,
            ncols: m.ncols,
            max_batch,
            flush_deadline: cfg.flush_deadline,
            solo,
            solo_id,
            spmm_plans,
            spmv_execs,
            stats_m,
            params,
            min_k_pays,
            per_k: Mutex::new(HashMap::new()),
            state: Mutex::new(None),
            inflight: AtomicUsize::new(0),
            counters: Counters::new(max_batch),
        })
    }

    /// Serve one SpMV request, possibly coalesced with concurrent
    /// submitters on other threads. Returns `y = A x`, bit-identical
    /// to [`Executable::spmv`] on the queue's solo plan regardless of
    /// how the request was grouped.
    ///
    /// # Panics
    ///
    /// If `x.len() != ncols`, or if this request's batch flush
    /// panicked (every waiter of a poisoned batch panics; the queue
    /// stays healthy for subsequent batches).
    pub fn submit(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.ncols, "submit: x length vs matrix ncols");
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        let prior = self.inflight.fetch_add(1, Ordering::AcqRel);
        let _guard = InflightGuard(&self.inflight);
        // Fast path: an open batch implies its leader is inside
        // `submit` and still counted in `inflight`, so `prior == 0`
        // proves there is nothing to coalesce with — serve solo, no
        // lock, no deadline. Also the standing mode when the cost
        // model says batching never pays here.
        if self.min_k_pays == usize::MAX || prior == 0 {
            let mut y = vec![0.0; self.nrows];
            self.solo.spmv(x, &mut y);
            self.counters.solo.fetch_add(1, Ordering::Relaxed);
            self.counters.bump_hist(1);
            return y;
        }
        enum Role {
            Leader(Arc<BatchSlot>),
            Follower(Arc<BatchSlot>, usize),
        }
        let role = {
            let mut st = lock(&self.state);
            match st.as_ref() {
                Some(open) => {
                    let slot = Arc::clone(open);
                    let mut g = lock(&slot.m);
                    let idx = g.xs.len();
                    g.xs.push(x.to_vec());
                    g.results.push(Vec::new());
                    if g.xs.len() >= self.max_batch {
                        g.sealed = true;
                        g.sealed_full = true;
                        slot.cv.notify_all();
                        *st = None;
                    }
                    drop(g);
                    Role::Follower(slot, idx)
                }
                None => {
                    let slot = Arc::new(BatchSlot::new(x));
                    *st = Some(Arc::clone(&slot));
                    Role::Leader(slot)
                }
            }
        };
        match role {
            Role::Leader(slot) => {
                let start = Instant::now();
                let mut g = lock(&slot.m);
                while !g.sealed {
                    let elapsed = start.elapsed();
                    if elapsed >= self.flush_deadline {
                        // Deadline: close the batch to new joiners
                        // *first* (state lock), then seal — strict
                        // state → slot.m order, so we must let go of
                        // the slot in between.
                        drop(g);
                        let mut st = lock(&self.state);
                        if st.as_ref().is_some_and(|cur| Arc::ptr_eq(cur, &slot)) {
                            *st = None;
                        }
                        drop(st);
                        g = lock(&slot.m);
                        g.sealed = true;
                        break;
                    }
                    g = slot
                        .cv
                        .wait_timeout(g, self.flush_deadline - elapsed)
                        .map(|(g, _)| g)
                        .unwrap_or_else(|p| p.into_inner().0);
                }
                let full = g.sealed_full;
                drop(g);
                self.counters.flushes.fetch_add(1, Ordering::Relaxed);
                if full {
                    self.counters.full_flushes.fetch_add(1, Ordering::Relaxed);
                } else {
                    self.counters.deadline_flushes.fetch_add(1, Ordering::Relaxed);
                }
                self.flush(&slot);
                let mut g = lock(&slot.m);
                std::mem::take(&mut g.results[0])
            }
            Role::Follower(slot, idx) => {
                let mut g = lock(&slot.m);
                while !g.done && !g.poisoned {
                    g = slot.cv.wait(g).unwrap_or_else(|p| p.into_inner());
                }
                assert!(
                    !g.poisoned,
                    "batch flush panicked; this batch's waiters are poisoned \
                     (the queue itself stays serviceable)"
                );
                std::mem::take(&mut g.results[idx])
            }
        }
    }

    /// Execute one sealed batch and distribute results. Panics inside
    /// the execution body poison exactly this batch: waiters are woken
    /// with `poisoned` set and the leader re-raises the payload.
    fn flush(&self, slot: &Arc<BatchSlot>) {
        let xs = {
            let mut g = lock(&slot.m);
            std::mem::take(&mut g.xs)
        };
        let outcome = catch_unwind(AssertUnwindSafe(|| self.run_group(&xs)));
        let mut g = lock(&slot.m);
        match outcome {
            Ok(results) => {
                g.results = results;
                g.done = true;
                slot.cv.notify_all();
            }
            Err(payload) => {
                self.counters.poisoned_batches.fetch_add(1, Ordering::Relaxed);
                g.poisoned = true;
                slot.cv.notify_all();
                drop(g);
                resume_unwind(payload);
            }
        }
    }

    /// The batch execution body (the unit `catch_unwind` isolates):
    /// panel when the model says k pays, k planned solo serves
    /// otherwise.
    fn run_group(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        crate::faultpoint!("batch.flush");
        let k = xs.len();
        self.counters.bump_hist(k);
        if k >= self.min_k_pays {
            if let Some(exec) = self.exec_for_k(k) {
                let mut b = vec![0.0; self.ncols * k];
                for (j, x) in xs.iter().enumerate() {
                    for (col, &v) in x.iter().enumerate() {
                        b[col * k + j] = v;
                    }
                }
                let mut c = vec![0.0; self.nrows * k];
                exec.spmm_k(&b, k, &mut c);
                self.counters.batched.fetch_add(k as u64, Ordering::Relaxed);
                return (0..k)
                    .map(|j| (0..self.nrows).map(|i| c[i * k + j]).collect())
                    .collect();
            }
        }
        // Below the crossover (or the per-k compile degraded away):
        // exactly the k × SpMV the model predicted for this side.
        self.counters.solo.fetch_add(k as u64, Ordering::Relaxed);
        xs.iter()
            .map(|x| {
                let mut y = vec![0.0; self.nrows];
                self.solo.spmv(x, &mut y);
                y
            })
            .collect()
    }

    /// The canonical SpMM executable ranked best *at this k*, compiled
    /// once and memoized (the process-wide compile cache shares the
    /// assembled storage with every other compile of this matrix).
    /// `None` if the pinned compile failed — the caller falls back to
    /// solo serves rather than erroring the batch.
    fn exec_for_k(&self, k: usize) -> Option<Executable> {
        if let Some(e) = lock(&self.per_k).get(&k) {
            return Some(e.clone());
        }
        let execs: Vec<ExecPlan> = self.spmm_plans.iter().map(|(_, e)| *e).collect();
        let best =
            *cost::rank_execs(Kernel::Spmm, k, &execs, &self.stats_m, &self.params).first()?;
        let id = &self.spmm_plans[best].0;
        let exe = self.engine.compile_pinned(Kernel::Spmm, &self.m, id).ok()?;
        lock(&self.per_k).insert(k, exe.clone());
        Some(exe)
    }

    /// Stable id of the solo SpMV plan every answer is bit-identical to.
    pub fn solo_plan_id(&self) -> &str {
        &self.solo_id
    }

    /// Smallest group size the cost model batches at (`None`: batching
    /// never pays on this matrix and every submit passes through).
    pub fn min_k_pays(&self) -> Option<usize> {
        (self.min_k_pays != usize::MAX).then_some(self.min_k_pays)
    }

    /// The predicted batch-vs-loop verdict at one k, under this
    /// queue's canonical plan sets and (possibly fitted) parameters.
    pub fn decision_at(&self, k: usize) -> Option<cost::BatchDecision> {
        let spmm_execs: Vec<ExecPlan> = self.spmm_plans.iter().map(|(_, e)| *e).collect();
        cost::batch_decision(k, &self.spmv_execs, &spmm_execs, &self.stats_m, &self.params)
    }

    /// Snapshot of the queue counters.
    pub fn stats(&self) -> BatchStats {
        BatchStats {
            submitted: self.counters.submitted.load(Ordering::Relaxed),
            batched: self.counters.batched.load(Ordering::Relaxed),
            solo: self.counters.solo.load(Ordering::Relaxed),
            flushes: self.counters.flushes.load(Ordering::Relaxed),
            deadline_flushes: self.counters.deadline_flushes.load(Ordering::Relaxed),
            full_flushes: self.counters.full_flushes.load(Ordering::Relaxed),
            poisoned_batches: self.counters.poisoned_batches.load(Ordering::Relaxed),
            hist: self.counters.hist.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
        }
    }
}

impl Engine {
    /// The batching queue for one tuple reservoir on this engine's
    /// arch — created on first request per fingerprint, shared (and
    /// counter-accumulating) afterwards. The queue compiles through
    /// the same builder configuration as this engine, so plan ranking,
    /// profile use and autotune policy follow the engine's knobs.
    ///
    /// # Errors
    ///
    /// [`ForelemError::InvalidMatrix`] for a bad reservoir;
    /// [`ForelemError::UnsupportedPlan`] if the plan pool somehow has
    /// no bit-identity-canonical plan (not reachable with the shipped
    /// enumeration).
    pub fn batch_queue(&self, m: &TriMat) -> Result<Arc<BatchQueue>, ForelemError> {
        m.validate()?;
        let fp = m.fingerprint();
        if let Some(q) = lock(&self.batches).get(&fp) {
            return Ok(Arc::clone(q));
        }
        let q = Arc::new(BatchQueue::new(&self.cfg, m)?);
        let mut reg = lock(&self.batches);
        Ok(Arc::clone(reg.entry(fp).or_insert(q)))
    }

    /// Drop this engine's batching queue for matrix `fingerprint` —
    /// generation retirement (`engine::version`): the queue's solo and
    /// per-k executables were compiled against the superseded bits, so
    /// the registry entry must age out with the generation. In-flight
    /// `submit` calls hold their own `Arc` and drain safely on the old
    /// queue; the *next* `batch_queue` call builds a fresh queue
    /// against the post-delta reservoir. Returns whether an entry was
    /// actually registered.
    pub(crate) fn retire_batch_queue(&self, fingerprint: u64) -> bool {
        lock(&self.batches).remove(&fingerprint).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::Arch;

    fn test_engine() -> Engine {
        Engine::builder()
            .arch(Arch::HostSmall)
            .profile(false)
            .archive(false)
            .max_batch(4)
            .flush_deadline(Duration::from_micros(200))
            .build()
    }

    #[test]
    fn k1_submit_matches_solo_spmv_bitwise() {
        let m = gen::uniform_random(40, 40, 300, 91);
        let engine = test_engine();
        let q = engine.batch_queue(&m).unwrap_or_else(|e| panic!("{e}"));
        let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.7).sin()).collect();
        let y = q.submit(&x);
        let solo = engine
            .compile_pinned(Kernel::Spmv, &m, q.solo_plan_id())
            .unwrap_or_else(|e| panic!("{e}"));
        let mut want = vec![0.0; 40];
        solo.spmv(&x, &mut want);
        assert_eq!(y, want, "uncontended submit must be the solo plan's bits");
        let s = q.stats();
        assert_eq!(s.submitted, 1);
        assert_eq!(s.solo, 1);
        assert_eq!(s.flushes, 0, "k=1 must never reach the queue");
    }

    #[test]
    fn queue_is_shared_per_fingerprint() {
        let m = gen::banded(30, 2, 0.8, 92);
        let engine = test_engine();
        let a = engine.batch_queue(&m).unwrap_or_else(|e| panic!("{e}"));
        let b = engine.batch_queue(&m).unwrap_or_else(|e| panic!("{e}"));
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn decision_threshold_is_consistent_with_decision_at() {
        let m = gen::uniform_random(60, 60, 600, 93);
        let engine = test_engine();
        let q = engine.batch_queue(&m).unwrap_or_else(|e| panic!("{e}"));
        if let Some(kmin) = q.min_k_pays() {
            let d = q.decision_at(kmin).unwrap_or_else(|| panic!("no decision at {kmin}"));
            assert!(d.batch_pays(), "threshold k={kmin} must itself pay");
            for k in 2..kmin {
                let d = q.decision_at(k).unwrap_or_else(|| panic!("no decision at {k}"));
                assert!(!d.batch_pays(), "k={k} below threshold must not pay");
            }
        }
    }

    /// Concurrent submitters against a deliberately long deadline:
    /// every result bit-identical to the solo plan, and the counters
    /// account for every request exactly once.
    #[test]
    fn concurrent_submits_are_bitwise_solo_and_fully_accounted() {
        let m = gen::uniform_random(50, 50, 500, 94);
        let engine = Engine::builder()
            .arch(Arch::HostSmall)
            .profile(false)
            .archive(false)
            .max_batch(4)
            .flush_deadline(Duration::from_millis(20))
            .build();
        let q = engine.batch_queue(&m).unwrap_or_else(|e| panic!("{e}"));
        let solo = engine
            .compile_pinned(Kernel::Spmv, &m, q.solo_plan_id())
            .unwrap_or_else(|e| panic!("{e}"));
        let n_threads = 8;
        let rounds = 10;
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let q = &q;
                let solo = &solo;
                s.spawn(move || {
                    for r in 0..rounds {
                        let x: Vec<f64> =
                            (0..50).map(|i| ((i + t * 7 + r * 13) as f64 * 0.31).cos()).collect();
                        let y = q.submit(&x);
                        let mut want = vec![0.0; 50];
                        solo.spmv(&x, &mut want);
                        assert_eq!(y, want, "thread {t} round {r}");
                    }
                });
            }
        });
        let s = q.stats();
        assert_eq!(s.submitted, (n_threads * rounds) as u64);
        assert_eq!(
            s.batched + s.solo,
            s.submitted,
            "every request is served exactly once: {s:?}"
        );
        let hist_total: u64 =
            s.hist.iter().enumerate().map(|(k, &c)| k as u64 * c).sum();
        assert_eq!(hist_total, s.submitted, "histogram covers every request: {s:?}");
    }

    /// A leader with no joiners must flush its partial batch at the
    /// deadline rather than hang — and a partial group of 1 serves
    /// solo even above a paying threshold.
    #[test]
    fn deadline_flushes_partial_batch() {
        let m = gen::uniform_random(30, 30, 200, 95);
        let engine = test_engine();
        let q = engine.batch_queue(&m).unwrap_or_else(|e| panic!("{e}"));
        // Force the queue path by simulating one in-flight peer.
        q.inflight.fetch_add(1, Ordering::AcqRel);
        let x: Vec<f64> = (0..30).map(|i| i as f64 * 0.05 - 0.7).collect();
        let t0 = Instant::now();
        let y = q.submit(&x);
        q.inflight.fetch_sub(1, Ordering::AcqRel);
        assert!(
            t0.elapsed() >= Duration::from_micros(200),
            "partial batch must wait out the deadline"
        );
        let mut want = vec![0.0; 30];
        q.solo.spmv(&x, &mut want);
        assert_eq!(y, want);
        let s = q.stats();
        assert_eq!(s.flushes, 1);
        assert_eq!(s.deadline_flushes, 1);
        assert_eq!(s.hist[1], 1);
    }
}
