//! The autotune denylist: candidates whose measurement panicked or
//! hung are quarantined per `(matrix fingerprint, plan id)` so no
//! later compile of the same matrix re-runs a measurement already
//! known to take the process down (or stall it against the watchdog).
//! Process-wide, like the compile cache it complements.
//!
//! Entries are **bounded** ([`MAX_ENTRIES`], insertion-order FIFO
//! eviction) and **clearable** (`Engine::clear_quarantine`, which
//! `forelem calibrate` invokes after persisting a fresh profile): a
//! quarantine records *evidence of a fault*, not a verdict, so one
//! transient measurement glitch must never exclude a plan from a
//! long-running host forever. Re-denying an existing key refreshes
//! its reason without re-queueing it for eviction.

use std::collections::{HashMap, VecDeque};
use std::sync::{Mutex, OnceLock};

/// Process-wide cap on quarantined `(matrix, plan)` pairs. Far above
/// what a healthy host accumulates (entries appear only when a
/// measurement panics or hangs); when a pathological environment
/// floods the list, the *oldest* evidence is dropped first — precisely
/// the entries most likely to have been transient.
pub(crate) const MAX_ENTRIES: usize = 256;

type Key = (u64, String);

#[derive(Default)]
struct DenyList {
    map: HashMap<Key, String>,
    /// Insertion order of `map`'s keys — the FIFO eviction queue.
    order: VecDeque<Key>,
}

fn deny_list() -> &'static Mutex<DenyList> {
    static DENY: OnceLock<Mutex<DenyList>> = OnceLock::new();
    DENY.get_or_init(|| Mutex::new(DenyList::default()))
}

fn locked() -> std::sync::MutexGuard<'static, DenyList> {
    // A panic while holding this lock poisons it; the list itself is
    // always in a consistent state (single-call updates), so recover
    // the inner value instead of propagating the poison forever.
    deny_list().lock().unwrap_or_else(|p| p.into_inner())
}

/// Quarantine `plan_id` for the matrix with `fingerprint`, recording
/// why. Logs on first insertion only; evicts the oldest entry past
/// [`MAX_ENTRIES`].
pub(crate) fn deny(fingerprint: u64, plan_id: &str, reason: &str) {
    let key: Key = (fingerprint, plan_id.to_string());
    let mut list = locked();
    let prev = list.map.insert(key.clone(), reason.to_string());
    if prev.is_none() {
        list.order.push_back(key);
        while list.map.len() > MAX_ENTRIES {
            match list.order.pop_front() {
                Some(oldest) => {
                    list.map.remove(&oldest);
                }
                None => break, // unreachable: order tracks map 1:1
            }
        }
        eprintln!("quarantined plan {plan_id} on matrix fp{fingerprint:016x}: {reason}");
    }
}

/// Is `plan_id` quarantined for this matrix?
pub(crate) fn is_denied(fingerprint: u64, plan_id: &str) -> bool {
    locked().map.contains_key(&(fingerprint, plan_id.to_string()))
}

/// Number of quarantined `(matrix, plan)` pairs process-wide.
pub(crate) fn len() -> usize {
    locked().map.len()
}

/// Drop every quarantine entry (tests, the chaos drill, and the
/// recalibrate path — a fresh profile resets the evidence).
pub(crate) fn clear() {
    let mut list = locked();
    list.map.clear();
    list.order.clear();
}

/// Drop every entry recorded against matrix `fingerprint` — generation
/// retirement (`engine::version`): evidence gathered on superseded bits
/// says nothing about the post-delta matrix, so it must not veto
/// candidates for the new generation. Returns the number dropped.
pub(crate) fn evict_fingerprint(fingerprint: u64) -> usize {
    let mut list = locked();
    let before = list.map.len();
    list.map.retain(|k, _| k.0 != fingerprint);
    list.order.retain(|k| k.0 != fingerprint);
    before - list.map.len()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn deny_is_keyed_by_matrix_and_plan() {
        clear();
        assert!(!is_denied(1, "csr.row.serial"));
        deny(1, "csr.row.serial", "panicked");
        deny(1, "csr.row.serial", "panicked again"); // logs once, updates reason
        assert!(is_denied(1, "csr.row.serial"));
        assert!(!is_denied(2, "csr.row.serial"), "other matrices unaffected");
        assert!(!is_denied(1, "csc.col.serial"), "other plans unaffected");
        // The vector-width component is part of the stable id, so a
        // faulting wide variant never shadows its scalar sibling (or
        // vice versa).
        assert!(!is_denied(1, "csr.row.serial.v8"), "wide variant is its own key");
        deny(1, "csr.row.serial.v8", "gather panicked");
        assert!(is_denied(1, "csr.row.serial.v8"));
        assert!(is_denied(1, "csr.row.serial"), "scalar entry untouched");
        assert_eq!(len(), 2);
        clear();
        assert_eq!(len(), 0);
    }

    #[test]
    fn cap_evicts_oldest_first_and_re_deny_does_not_requeue() {
        clear();
        // Re-denying key 0 later must NOT refresh its eviction slot:
        // it stays the oldest and is the first to go at the cap.
        deny(0, "p", "first");
        for fp in 1..MAX_ENTRIES as u64 {
            deny(fp, "p", "fill");
        }
        assert_eq!(len(), MAX_ENTRIES);
        deny(0, "p", "transient fault seen again"); // existing key: reason refresh only
        assert_eq!(len(), MAX_ENTRIES);
        deny(MAX_ENTRIES as u64, "p", "one past the cap");
        assert_eq!(len(), MAX_ENTRIES, "cap holds");
        assert!(!is_denied(0, "p"), "oldest entry evicted despite the later re-deny");
        assert!(is_denied(1, "p"), "second-oldest survives");
        assert!(is_denied(MAX_ENTRIES as u64, "p"), "newest present");
        clear();
        assert_eq!(len(), 0);
    }

    /// Generation retirement: evidence recorded on superseded bits is
    /// dropped wholesale (every plan of the fingerprint), other
    /// matrices keep theirs, and the FIFO queue stays in sync with the
    /// map so the cap keeps working afterwards.
    #[test]
    fn evict_fingerprint_drops_stale_evidence() {
        clear();
        deny(41, "csr.row.serial", "panicked");
        deny(41, "ell-rm.row.serial", "hung");
        deny(42, "csr.row.serial", "panicked");
        assert_eq!(evict_fingerprint(41), 2);
        assert!(!is_denied(41, "csr.row.serial"));
        assert!(!is_denied(41, "ell-rm.row.serial"));
        assert!(is_denied(42, "csr.row.serial"), "other matrices keep their evidence");
        assert_eq!(evict_fingerprint(41), 0, "idempotent");
        assert_eq!(len(), 1);
        // The FIFO queue shrank in lockstep with the map, so the cap
        // bookkeeping stays 1:1 after a retirement.
        {
            let list = locked();
            assert_eq!(list.order.len(), list.map.len());
        }
        clear();
    }
}
