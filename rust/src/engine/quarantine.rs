//! The autotune denylist: candidates whose measurement panicked or
//! hung are quarantined per `(matrix fingerprint, plan id)` so no
//! later compile of the same matrix re-runs a measurement already
//! known to take the process down (or stall it against the watchdog).
//! Process-wide, like the compile cache it complements.

use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

type DenyMap = HashMap<(u64, String), String>;

fn deny_map() -> &'static Mutex<DenyMap> {
    static DENY: OnceLock<Mutex<DenyMap>> = OnceLock::new();
    DENY.get_or_init(|| Mutex::new(HashMap::new()))
}

fn locked() -> std::sync::MutexGuard<'static, DenyMap> {
    // A panic while holding this lock poisons it; the map itself is
    // always in a consistent state (single-call updates), so recover
    // the inner value instead of propagating the poison forever.
    deny_map().lock().unwrap_or_else(|p| p.into_inner())
}

/// Quarantine `plan_id` for the matrix with `fingerprint`, recording
/// why. Logs on first insertion only.
pub(crate) fn deny(fingerprint: u64, plan_id: &str, reason: &str) {
    let prev = locked().insert((fingerprint, plan_id.to_string()), reason.to_string());
    if prev.is_none() {
        eprintln!("quarantined plan {plan_id} on matrix fp{fingerprint:016x}: {reason}");
    }
}

/// Is `plan_id` quarantined for this matrix?
pub(crate) fn is_denied(fingerprint: u64, plan_id: &str) -> bool {
    locked().contains_key(&(fingerprint, plan_id.to_string()))
}

/// Number of quarantined `(matrix, plan)` pairs process-wide.
pub(crate) fn len() -> usize {
    locked().len()
}

/// Drop every quarantine entry (tests and the chaos drill).
pub(crate) fn clear() {
    locked().clear();
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn deny_is_keyed_by_matrix_and_plan() {
        clear();
        assert!(!is_denied(1, "csr.row.serial"));
        deny(1, "csr.row.serial", "panicked");
        deny(1, "csr.row.serial", "panicked again"); // logs once, updates reason
        assert!(is_denied(1, "csr.row.serial"));
        assert!(!is_denied(2, "csr.row.serial"), "other matrices unaffected");
        assert!(!is_denied(1, "csc.col.serial"), "other plans unaffected");
        // The vector-width component is part of the stable id, so a
        // faulting wide variant never shadows its scalar sibling (or
        // vice versa).
        assert!(!is_denied(1, "csr.row.serial.v8"), "wide variant is its own key");
        deny(1, "csr.row.serial.v8", "gather panicked");
        assert!(is_denied(1, "csr.row.serial.v8"));
        assert!(is_denied(1, "csr.row.serial"), "scalar entry untouched");
        assert_eq!(len(), 2);
        clear();
        assert_eq!(len(), 0);
    }
}
