//! Versioned matrices: storage generations, delta application, and
//! hot-swap re-planning — the dynamic-matrix half of the engine.
//!
//! The planner compiles a *snapshot* of a tuple reservoir into a tuned
//! data structure; real workloads mutate the reservoir. This module
//! models each mutation as a **storage-generation transition**: a
//! [`VersionedMatrix`] owns the current generation (matrix + one
//! [`Executable`] per requested kernel) behind an atomic swap, and
//! [`VersionedMatrix::apply_delta`] moves it to the next generation by
//! the cheapest safe route:
//!
//! ```text
//! apply_delta(batch)
//!   = resolve + validate   (matrix::delta — the one hard error)
//!   → attempt repair       (SparseOps::repair: CSR row splicing, ELL
//!                           slot rewrites, SELL value patches; None
//!                           when the format would lay out differently)
//!   → decide               (search::cost::delta_decision: repair vs
//!                           rebuild vs re-plan, per kernel)
//!   → build the next generation off to the side
//!   → swap                 (one Mutex store; serves in flight keep
//!                           their Arc'd generation and drain on it)
//!   → retire               (evict compile-cache / quarantine / batch-
//!                           queue entries keyed by the old fingerprint)
//! ```
//!
//! # Consistency contract
//!
//! Every serve (`spmv`/`spmm`/`trsv`) snapshots the generation `Arc`
//! once, runs entirely on that snapshot, and returns the
//! [`Fingerprint`] of the generation that answered — so a caller racing
//! `apply_delta` can assert its answer came from exactly one
//! generation, never a torn mix. The generation lineage is carried as a
//! chained [`Transition<Fingerprint>`] (genesis → current), extended on
//! every swap; `chain().to()` always equals the current fingerprint.
//!
//! # Bit-identity contract
//!
//! A repaired generation is **bit-identical** to compiling the
//! post-delta reservoir from scratch with the same plan: the per-format
//! `repair` implementations splice the exact value bits a fresh
//! `from_tuples` build would produce, and stale schedule auxiliaries
//! (band splits, TrSv level sets) are re-derived lazily from the
//! repaired structure rather than patched approximately
//! (`concretize::exec::prepared_from_ops`). `tests/delta.rs` pins this
//! across formats × kernels.
//!
//! # Fault containment
//!
//! A panicking repair (`delta.repair` chaos point) degrades to a
//! rebuild — never a torn structure. A fault at the swap itself
//! (`delta.swap`) surfaces as a typed
//! [`ForelemError::MeasurementFailure`] with the serving generation
//! unchanged.

use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::baselines::Kernel;
use crate::concretize;
use crate::error::ForelemError;
use crate::matrix::delta::{DeltaBatch, DeltaEntry};
use crate::matrix::{MatrixStats, TriMat};
use crate::search::cost::{self, DeltaAction};

use super::executable::Compiled;
use super::{cache, quarantine, Engine, Executable};

/// A storage-generation identity: the 64-bit content fingerprint of the
/// tuple reservoir a generation was compiled from
/// (`TriMat::fingerprint` — structure and value bits both). Formats as
/// the same `fp{:016x}` label the cache, quarantine, and calibration
/// archive key by.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint(pub u64);

impl fmt::Display for Fingerprint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "fp{:016x}", self.0)
    }
}

/// A directed state change `from → to`. The states are private: a
/// `Transition` is constructed whole and read whole, so an
/// inconsistent pair can never be assembled field by field.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Transition<T> {
    from_state: T,
    to_state: T,
}

impl<T> Transition<T> {
    pub fn new(from_state: T, to_state: T) -> Self {
        Transition { from_state, to_state }
    }

    /// The state this transition leaves.
    pub fn from(&self) -> &T {
        &self.from_state
    }

    /// The state this transition enters.
    pub fn to(&self) -> &T {
        &self.to_state
    }

    /// Decompose into `(from, to)`.
    pub fn into_states(self) -> (T, T) {
        (self.from_state, self.to_state)
    }
}

impl<T: PartialEq> Transition<T> {
    /// A transition that goes nowhere (genesis chains start as one).
    pub fn is_no_op(&self) -> bool {
        self.from_state == self.to_state
    }

    /// Compose `self` then `next` into one transition spanning both.
    ///
    /// # Errors
    ///
    /// [`TransitionChainError`] when `next` does not depart from the
    /// state `self` arrived at — the seam where a torn generation
    /// lineage would otherwise hide.
    pub fn chain(self, next: Self) -> Result<Self, TransitionChainError<T>> {
        if self.to_state == next.from_state {
            Ok(Transition::new(self.from_state, next.to_state))
        } else {
            Err(TransitionChainError { arrived: self.to_state, departed: next.from_state })
        }
    }
}

/// Two transitions that do not meet: the first arrived at `arrived`,
/// the second departed from `departed`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransitionChainError<T> {
    pub arrived: T,
    pub departed: T,
}

impl<T: fmt::Debug> fmt::Display for TransitionChainError<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "transition chain gap: arrived at {:?} but next departs from {:?}",
            self.arrived, self.departed
        )
    }
}

/// How `apply_delta` carried one kernel's executable to the next
/// generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeltaOutcome {
    /// The delta was spliced into the existing storage in place
    /// (`SparseOps::repair`) — no planner, no full rebuild.
    Repaired,
    /// The same plan's storage was rebuilt from the post-delta tuples
    /// (format could not absorb this batch, or a rebuild predicted
    /// cheaper, or a faulted repair degraded here).
    Rebuilt,
    /// The accumulated drift justified a full predict→measure compile;
    /// the new generation may serve a different plan.
    Replanned,
}

/// What one [`VersionedMatrix::apply_delta`] did, for callers and the
/// `forelem delta-bench` harness.
#[derive(Clone, Debug)]
pub struct DeltaReport {
    /// This application's step: old fingerprint → new fingerprint.
    pub transition: Transition<Fingerprint>,
    /// The full lineage after the swap (genesis → current).
    pub chain: Transition<Fingerprint>,
    /// Generation sequence number after the swap (genesis is 0).
    pub generation: u64,
    /// Resolved delta ops applied (post last-write-wins coalescing).
    pub ops: usize,
    /// Per-kernel route taken to the new generation.
    pub outcomes: Vec<(Kernel, DeltaOutcome)>,
    /// Compile-cache entries evicted at old-generation retirement.
    pub cache_evicted: u64,
    /// Quarantine entries evicted at old-generation retirement.
    pub quarantine_evicted: usize,
    /// Whether a request-batching queue was registered on the old
    /// fingerprint and retired with it.
    pub batch_queue_retired: bool,
}

/// One immutable storage generation. Serves hold an `Arc` to it for
/// their whole execution, so a swap never tears a serve.
struct GenState {
    matrix: TriMat,
    fingerprint: Fingerprint,
    seq: u64,
    chain: Transition<Fingerprint>,
    execs: Vec<(Kernel, Executable)>,
    /// Delta ops absorbed since the last full re-plan — decays the
    /// re-plan margin in `cost::delta_decision`.
    deltas_applied: u64,
}

/// A dynamic matrix served through the engine: the current generation
/// behind an atomic swap, mutated by [`apply_delta`]
/// (`VersionedMatrix::apply_delta`) and queried by serve methods that
/// name the generation that answered.
///
/// Shareable across threads (`&self` everywhere); serves are
/// wait-free with respect to delta application — they snapshot the
/// generation `Arc` under a short lock and run outside it.
pub struct VersionedMatrix {
    engine: Engine,
    state: Mutex<Arc<GenState>>,
    /// Serializes `apply_delta` end to end. Serves never take it.
    apply_lock: Mutex<()>,
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // Generation state is swapped whole (single Arc store), so a
    // poisoned lock still guards a consistent value — recover it.
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl VersionedMatrix {
    /// The current generation's fingerprint.
    pub fn fingerprint(&self) -> Fingerprint {
        self.current().fingerprint
    }

    /// The current generation's sequence number (genesis is 0).
    pub fn generation(&self) -> u64 {
        self.current().seq
    }

    /// The full lineage: genesis fingerprint → current fingerprint.
    /// `chain().to()` always names the generation serves answer from.
    pub fn chain(&self) -> Transition<Fingerprint> {
        self.current().chain.clone()
    }

    /// Delta ops absorbed since the last full re-plan.
    pub fn deltas_applied(&self) -> u64 {
        self.current().deltas_applied
    }

    /// A copy of the current generation's tuple reservoir (tests use it
    /// as the rebuild-from-scratch reference).
    pub fn snapshot(&self) -> TriMat {
        self.current().matrix.clone()
    }

    /// The current generation's executable for `kernel`, if that kernel
    /// was requested at construction. Cheap (`Arc`-backed clone).
    pub fn executable(&self, kernel: Kernel) -> Option<Executable> {
        let g = self.current();
        g.execs.iter().find(|(k, _)| *k == kernel).map(|(_, e)| e.clone())
    }

    /// Serve `y = A x` on the current generation; returns the
    /// fingerprint of the generation that answered.
    ///
    /// # Errors
    ///
    /// [`ForelemError::UnsupportedPlan`] when `Kernel::Spmv` was not
    /// requested at construction.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) -> Result<Fingerprint, ForelemError> {
        let g = self.current();
        Self::exec_for(&g, Kernel::Spmv)?.spmv(x, y);
        Ok(g.fingerprint)
    }

    /// Serve `C = A B` (engine-configured dense column count) on the
    /// current generation; returns the answering generation's
    /// fingerprint. Same error contract as [`spmv`](Self::spmv).
    pub fn spmm(&self, b: &[f64], c: &mut [f64]) -> Result<Fingerprint, ForelemError> {
        let g = self.current();
        Self::exec_for(&g, Kernel::Spmm)?.spmm(b, c);
        Ok(g.fingerprint)
    }

    /// Serve the unit-lower solve `L x = b` on the current generation;
    /// returns the answering generation's fingerprint. Same error
    /// contract as [`spmv`](Self::spmv).
    pub fn trsv(&self, b: &[f64], x: &mut [f64]) -> Result<Fingerprint, ForelemError> {
        let g = self.current();
        Self::exec_for(&g, Kernel::Trsv)?.trsv(b, x);
        Ok(g.fingerprint)
    }

    /// Apply a typed delta batch, moving this matrix to its next
    /// storage generation. Per kernel, takes the route
    /// [`cost::delta_decision`] picks: in-place **repair** when the
    /// format supports this batch and it predicts cheaper, a
    /// same-plan **rebuild** otherwise, or a full **re-plan** when the
    /// post-delta statistics have drifted far enough that a different
    /// plan should win. The next generation is built entirely off to
    /// the side and installed with one atomic swap; serves in flight
    /// drain on the old generation.
    ///
    /// # Errors
    ///
    /// [`ForelemError::InvalidMatrix`] when the batch fails resolution
    /// or validation against the current generation (conflicting
    /// insert+delete pair, insert of a present coordinate, …) — the
    /// generation is untouched. [`ForelemError::MeasurementFailure`]
    /// (`plan_id: "delta.swap"`) when the swap itself faults under the
    /// chaos harness — the generation is untouched then too.
    pub fn apply_delta(&self, batch: &DeltaBatch) -> Result<DeltaReport, ForelemError> {
        let _serialized = lock(&self.apply_lock);
        let old = self.current();
        let resolved = batch.resolved()?;
        let new_matrix = batch.apply(&old.matrix)?;
        let new_fp = Fingerprint(new_matrix.fingerprint());
        let step = Transition::new(old.fingerprint, new_fp);
        if resolved.is_empty() {
            return Ok(DeltaReport {
                transition: step,
                chain: old.chain.clone(),
                generation: old.seq,
                ops: 0,
                outcomes: Vec::new(),
                cache_evicted: 0,
                quarantine_evicted: 0,
                batch_queue_retired: false,
            });
        }

        let new_stats = MatrixStats::of(&new_matrix);
        let mut execs = Vec::with_capacity(old.execs.len());
        let mut outcomes = Vec::with_capacity(old.execs.len());
        let mut all_replanned = true;
        for (kernel, cur) in &old.execs {
            let (exe, outcome) = self.transition_exec(
                *kernel,
                cur,
                &resolved,
                &new_matrix,
                &new_stats,
                old.deltas_applied,
            )?;
            all_replanned &= outcome == DeltaOutcome::Replanned;
            execs.push((*kernel, exe));
            outcomes.push((*kernel, outcome));
        }

        // The swap seam: a fault here must leave the serving generation
        // untouched (the chaos drill pins this), so it fires before the
        // single store below and surfaces as a typed error.
        if catch_unwind(|| crate::faultpoint!("delta.swap")).is_err() {
            return Err(ForelemError::MeasurementFailure {
                plan_id: "delta.swap".to_string(),
                reason: "storage-generation swap faulted; the serving generation is unchanged"
                    .to_string(),
            });
        }

        let chain = match old.chain.clone().chain(step.clone()) {
            Ok(c) => c,
            // Unreachable by construction (step departs from chain.to),
            // but a lineage is better re-rooted than panicked over.
            Err(_) => Transition::new(*old.chain.from(), new_fp),
        };
        let deltas_applied =
            if all_replanned { 0 } else { old.deltas_applied + resolved.len() as u64 };
        let next = Arc::new(GenState {
            matrix: new_matrix,
            fingerprint: new_fp,
            seq: old.seq + 1,
            chain: chain.clone(),
            execs,
            deltas_applied,
        });
        *lock(&self.state) = next;

        // Old-generation retirement: evidence and artifacts keyed by
        // the superseded fingerprint age out now, not at some later
        // cache-budget squeeze. Skipped when the delta round-tripped to
        // the same bits (the entries still describe the live matrix).
        let (mut cache_evicted, mut quarantine_evicted, mut batch_queue_retired) = (0, 0, false);
        if !step.is_no_op() {
            cache_evicted = cache::evict_fingerprint(old.fingerprint.0);
            quarantine_evicted = quarantine::evict_fingerprint(old.fingerprint.0);
            batch_queue_retired = self.engine.retire_batch_queue(old.fingerprint.0);
        }
        Ok(DeltaReport {
            transition: step,
            chain,
            generation: old.seq + 1,
            ops: resolved.len(),
            outcomes,
            cache_evicted,
            quarantine_evicted,
            batch_queue_retired,
        })
    }

    fn current(&self) -> Arc<GenState> {
        Arc::clone(&lock(&self.state))
    }

    fn exec_for(g: &GenState, kernel: Kernel) -> Result<&Executable, ForelemError> {
        match g.execs.iter().find(|(k, _)| *k == kernel) {
            Some((_, e)) => Ok(e),
            None => Err(ForelemError::UnsupportedPlan {
                plan_id: format!("{kernel:?}"),
                reason: "kernel was not requested when this VersionedMatrix was built".to_string(),
            }),
        }
    }

    /// Carry one kernel's executable to the post-delta generation along
    /// the route `cost::delta_decision` picks. The repair attempt runs
    /// behind `catch_unwind`: a panicking format repair (the
    /// `delta.repair` chaos point stands in for one) degrades to a
    /// rebuild instead of tearing anything — the old generation keeps
    /// serving throughout either way, since repair is copy-on-write.
    fn transition_exec(
        &self,
        kernel: Kernel,
        cur: &Executable,
        resolved: &[DeltaEntry],
        new_matrix: &TriMat,
        new_stats: &MatrixStats,
        deltas_applied: u64,
    ) -> Result<(Executable, DeltaOutcome), ForelemError> {
        let pool = self.engine.pool(kernel);
        let params = pool.space.params;
        let dense_k = self.engine.cfg.spmm_k;

        let repaired = match catch_unwind(AssertUnwindSafe(|| {
            crate::faultpoint!("delta.repair");
            cur.storage().repair(resolved)
        })) {
            Ok(r) => r,
            Err(_) => {
                eprintln!("warning: {kernel:?} delta repair panicked; degrading to rebuild");
                None
            }
        };

        // Incumbent vs shortlist winner, both predicted on the
        // *post-delta* statistics — the drift signal the re-plan arm
        // of the decision consumes.
        let cur_fv = cur.plan().features(kernel, dense_k, new_stats, &params);
        let cur_pred = cur_fv.dot(&params.weights).max(1e-12);
        let pool_execs: Vec<concretize::Plan> = pool.plans.iter().map(|p| p.exec).collect();
        let order = cost::rank_execs(kernel, dense_k, &pool_execs, new_stats, &params);
        let best_pred = match order.first() {
            Some(&pi) => pool.plans[pi]
                .features(kernel, dense_k, new_stats, &params)
                .dot(&params.weights)
                .max(1e-12),
            None => cur_pred,
        };
        let decision = cost::delta_decision(
            new_stats,
            resolved.len(),
            repaired.is_some(),
            cur_pred,
            best_pred,
            deltas_applied,
            &params,
        );

        match (decision.action, repaired) {
            (DeltaAction::Replan, _) => {
                Ok((self.engine.compile(kernel, new_matrix)?, DeltaOutcome::Replanned))
            }
            (DeltaAction::Repair, Some(ops)) => {
                let prepared = concretize::exec::prepared_from_ops(
                    cur.plan().exec,
                    new_matrix.nrows,
                    new_matrix.ncols,
                    ops,
                );
                // Schedule auxiliaries are compile-time work here as in
                // `Engine::compile` — re-derived from the repaired
                // structure, never served stale from the old one.
                match kernel {
                    Kernel::Spmv => prepared.ensure_bands(),
                    Kernel::Trsv => prepared.ensure_levels(),
                    Kernel::Spmm => {}
                }
                if crate::runtime::topology::numa_active() {
                    prepared.first_touch();
                }
                let compiled = Arc::new(Compiled {
                    plan: cur.plan().clone(),
                    prepared: Arc::new(prepared),
                    stats: *new_stats,
                    params,
                    features: cur_fv,
                    predicted_secs: cur_pred,
                    measured_secs: None,
                    profile_loaded: pool.profile_loaded,
                    health: cur.health(),
                    fingerprint: new_matrix.fingerprint(),
                });
                Ok((Executable::new(kernel, dense_k, compiled), DeltaOutcome::Repaired))
            }
            // A repair verdict without a repaired structure only
            // happens when the attempt faulted above; rebuild.
            (DeltaAction::Repair, None) | (DeltaAction::Rebuild, _) => Ok((
                self.engine.compile_pinned(kernel, new_matrix, &cur.plan().id)?,
                DeltaOutcome::Rebuilt,
            )),
        }
    }
}

impl Engine {
    /// Promote a tuple reservoir to a [`VersionedMatrix`]: compile each
    /// requested kernel once (generation 0) and return the handle that
    /// serves and mutates it. The versioned matrix compiles through an
    /// engine built from this engine's configuration, so its compiles
    /// share the process-wide cache/quarantine with everyone else's.
    ///
    /// # Errors
    ///
    /// [`ForelemError::InvalidMatrix`] per [`Engine::compile`].
    pub fn versioned(
        &self,
        m: &TriMat,
        kernels: &[Kernel],
    ) -> Result<VersionedMatrix, ForelemError> {
        m.validate()?;
        let engine = self.cfg.clone().build();
        let mut execs = Vec::with_capacity(kernels.len());
        for &k in kernels {
            execs.push((k, engine.compile(k, m)?));
        }
        let fp = Fingerprint(m.fingerprint());
        let genesis = GenState {
            matrix: m.clone(),
            fingerprint: fp,
            seq: 0,
            chain: Transition::new(fp, fp),
            execs,
            deltas_applied: 0,
        };
        Ok(VersionedMatrix {
            engine,
            state: Mutex::new(Arc::new(genesis)),
            apply_lock: Mutex::new(()),
        })
    }

    /// One-shot delta application without a [`VersionedMatrix`]: apply
    /// `batch` to `m`, retire everything keyed by `m`'s fingerprint
    /// (compile-cache entries, quarantine evidence, the request-batching
    /// queue), and return the canonical post-delta reservoir — ready
    /// for the next [`Engine::compile`]. Callers that serve
    /// continuously should hold a `VersionedMatrix` instead; this is
    /// the batch-job shape (mutate, recompile, move on).
    ///
    /// # Errors
    ///
    /// [`ForelemError::InvalidMatrix`] on a bad reservoir or a batch
    /// that fails resolution/validation against it.
    pub fn apply_delta(&self, m: &TriMat, batch: &DeltaBatch) -> Result<TriMat, ForelemError> {
        m.validate()?;
        let out = batch.apply(m)?;
        let old_fp = m.fingerprint();
        if out.fingerprint() != old_fp {
            cache::evict_fingerprint(old_fp);
            quarantine::evict_fingerprint(old_fp);
            self.retire_batch_queue(old_fp);
        }
        Ok(out)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::sweep::Arch;
    use crate::matrix::gen;

    fn engine_small() -> Engine {
        Engine::builder().arch(Arch::HostSmall).profile(false).archive(false).build()
    }

    #[test]
    fn transition_chains_like_moho() {
        let a = Transition::new(1u64, 2u64);
        assert_eq!(*a.from(), 1);
        assert_eq!(*a.to(), 2);
        assert!(!a.is_no_op());
        assert!(Transition::new(7u64, 7u64).is_no_op());
        let ab = a.clone().chain(Transition::new(2u64, 3u64)).expect("contiguous");
        assert_eq!(ab.clone().into_states(), (1, 3));
        let gap = ab.chain(Transition::new(9u64, 10u64)).unwrap_err();
        assert_eq!(gap, TransitionChainError { arrived: 3, departed: 9 });
        assert!(gap.to_string().contains("chain gap"));
    }

    #[test]
    fn fingerprint_displays_like_the_archive_label() {
        assert_eq!(Fingerprint(0xABC).to_string(), "fp0000000000000abc");
    }

    #[test]
    fn apply_delta_swaps_generations_and_extends_the_chain() {
        let m = gen::uniform_random(40, 40, 300, 1100);
        let e = engine_small();
        let vm = e.versioned(&m, &[Kernel::Spmv]).expect("valid matrix");
        let g0 = vm.fingerprint();
        assert_eq!(vm.generation(), 0);
        assert!(vm.chain().is_no_op(), "genesis chain goes nowhere yet");

        // A pure value update keeps every format repairable.
        let probe = m.entries[0];
        let mut b = DeltaBatch::new(40, 40);
        b.update(probe.row as usize, probe.col as usize, probe.val + 1.5);
        let report = vm.apply_delta(&b).expect("clean batch");
        assert_eq!(report.ops, 1);
        assert_eq!(report.generation, 1);
        assert_eq!(*report.transition.from(), g0);
        assert_eq!(*report.transition.to(), vm.fingerprint());
        assert_ne!(g0, vm.fingerprint(), "value change must move the fingerprint");
        assert_eq!(*vm.chain().from(), g0, "chain stays rooted at genesis");
        assert_eq!(*vm.chain().to(), vm.fingerprint());

        // The served answer names the new generation and matches the
        // rebuilt-from-scratch reference exactly.
        let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.13).sin()).collect();
        let mut y = vec![0.0; 40];
        let served_by = vm.spmv(&x, &mut y).expect("spmv was requested");
        assert_eq!(served_by, vm.fingerprint());
        let reference = vm.snapshot();
        crate::util::prop::assert_close(&y, &reference.spmv_ref(&x), 1e-10).unwrap();
    }

    #[test]
    fn unrequested_kernels_are_a_typed_error() {
        let m = gen::uniform_random(20, 20, 80, 1101);
        let vm = engine_small().versioned(&m, &[Kernel::Spmv]).expect("valid matrix");
        let b_in = vec![0.0; 20 * 100];
        let mut c = vec![0.0; 20 * 100];
        let err = vm.spmm(&b_in, &mut c).unwrap_err();
        assert_eq!(err.class(), "unsupported-plan");
    }

    #[test]
    fn conflicting_batches_leave_the_generation_untouched() {
        let m = gen::uniform_random(20, 20, 80, 1102);
        let vm = engine_small().versioned(&m, &[Kernel::Spmv]).expect("valid matrix");
        let fp = vm.fingerprint();
        let mut b = DeltaBatch::new(20, 20);
        b.insert(0, 1, 1.0);
        b.delete(0, 1);
        let err = vm.apply_delta(&b).unwrap_err();
        assert_eq!(err.class(), "invalid-matrix");
        assert_eq!(vm.fingerprint(), fp, "failed delta must not move the generation");
        assert_eq!(vm.generation(), 0);
    }

    #[test]
    fn one_shot_apply_delta_retires_the_old_fingerprint() {
        let m = gen::uniform_random(24, 24, 120, 1103);
        let e = engine_small();
        let _warm = e.compile(Kernel::Spmv, &m).expect("valid matrix");
        let probe = m.entries[0];
        let mut b = DeltaBatch::new(24, 24);
        b.update(probe.row as usize, probe.col as usize, probe.val * 2.0);
        let m2 = e.apply_delta(&m, &b).expect("clean batch");
        assert_ne!(m2.fingerprint(), m.fingerprint());
        // The superseded generation's compile is no longer cached: a
        // fresh compile of the *old* bits is a different storage Arc.
        let again = e.compile(Kernel::Spmv, &m).expect("valid matrix");
        assert!(
            !Arc::ptr_eq(&_warm.storage(), &again.storage()),
            "old generation's cache entry must have been evicted"
        );
    }
}
