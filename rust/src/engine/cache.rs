//! The process-wide compile cache — the serving path.
//!
//! Keyed by `(kernel, arch, matrix fingerprint, config digest)`: the
//! fingerprint is `TriMat::fingerprint` (content + shape + order), the
//! digest folds in everything else that can change the winning plan or
//! its storage — the ranked weight vector (so loading a new tuning
//! profile cold-starts the cache instead of serving stale plans), the
//! vector register width (a wider unit can flip the winning lane
//! count), the structural socket count (a NUMA box prices plans
//! differently), the schedule axis, the SpMM dense width, the autotune
//! depth, and a pinned plan id if any. Entries hold the `Arc`-shared
//! `Compiled` (plan + storage), so a hit is a pointer clone: repeated
//! compiles of the same matrix are free. This layers *above*
//! `concretize::prepare_many`'s plan-keyed storage cache, which
//! de-duplicates storage *within* one compile's shortlist.
//!
//! # Eviction
//!
//! The cache is bounded by a byte budget (`EngineBuilder::cache_budget`,
//! default [`DEFAULT_BUDGET`]): each entry is charged its generated
//! data structure's footprint (`Prepared::bytes`), and inserting past
//! the budget evicts least-recently-used entries until the total fits
//! again. The newest entry is never evicted — a single matrix larger
//! than the budget still serves from cache rather than recompiling on
//! every call. Recency is a logical clock bumped on every hit, so the
//! hot working set survives a sweep over many cold matrices. Evictions
//! are counted process-wide ([`evictions`]) and surfaced through
//! `Executable::explain()` and the bench-json `pool` section. The
//! budget is a liveness knob, not a plan input, so it stays *out* of
//! the config digest (the `measure_timeout` precedent): two engines
//! differing only in budget share entries.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::baselines::Kernel;
use crate::search::cost::CostParams;

use super::executable::Compiled;

/// Default cache budget: generous enough that eviction never triggers
/// in ordinary serving (the sweeps' largest prepared structures are
/// tens of MB), small enough to bound a long-lived host that compiles
/// an unbounded stream of distinct matrices.
pub const DEFAULT_BUDGET: usize = 1 << 30; // 1 GiB

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) struct Key {
    kernel: Kernel,
    arch: &'static str,
    fingerprint: u64,
    digest: u64,
}

impl Key {
    pub(crate) fn new(kernel: Kernel, arch: &'static str, fingerprint: u64, digest: u64) -> Self {
        Key { kernel, arch, fingerprint, digest }
    }
}

/// FNV-1a fold (`util::fnv::Fnv1a`, the same primitive as
/// `TriMat::fingerprint`) of the engine-configuration facets that
/// affect compile results (see module docs for the list).
pub(crate) fn config_digest(
    params: &CostParams,
    schedules: bool,
    spmm_k: usize,
    autotune_k: usize,
    pinned: Option<&str>,
) -> u64 {
    let mut h = crate::util::fnv::Fnv1a::new();
    h.eat_u64(params.l2_bytes.to_bits());
    h.eat_u64(params.threads as u64);
    h.eat_u64(params.vector_bytes.to_bits());
    h.eat_u64(params.sockets as u64);
    for w in &params.weights {
        h.eat_u64(w.to_bits());
    }
    h.eat_u64(schedules as u64);
    h.eat_u64(spmm_k as u64);
    h.eat_u64(autotune_k as u64);
    if let Some(id) = pinned {
        h.eat_bytes(id.as_bytes());
    }
    h.finish()
}

struct Entry {
    compiled: Arc<Compiled>,
    bytes: usize,
    last_used: u64,
}

struct Store {
    map: HashMap<Key, Entry>,
    /// Logical recency clock — bumped on every lookup hit and insert.
    clock: u64,
    /// Sum of `Entry::bytes` currently held.
    bytes: usize,
    /// Byte budget applied by the most recent insert (engines configure
    /// it per-build; last writer wins, which is fine — the budget is a
    /// liveness bound, not a correctness input).
    budget: usize,
    /// Monotonic eviction count (survives `clear`).
    evictions: u64,
}

impl Store {
    fn new() -> Self {
        Store {
            map: HashMap::new(),
            clock: 0,
            bytes: 0,
            budget: DEFAULT_BUDGET,
            evictions: 0,
        }
    }

    fn lookup(&mut self, key: &Key) -> Option<Arc<Compiled>> {
        self.clock += 1;
        let clock = self.clock;
        let e = self.map.get_mut(key)?;
        e.last_used = clock;
        Some(Arc::clone(&e.compiled))
    }

    /// Insert `bytes`-sized entry under `budget`, evicting LRU entries
    /// (never the one just inserted) until the total footprint fits.
    fn insert(&mut self, key: Key, compiled: Arc<Compiled>, bytes: usize, budget: usize) {
        self.clock += 1;
        let clock = self.clock;
        self.budget = budget.max(1);
        let bytes = bytes.max(1);
        if let Some(old) = self.map.insert(key, Entry { compiled, bytes, last_used: clock }) {
            self.bytes -= old.bytes;
        }
        self.bytes += bytes;
        while self.bytes > self.budget {
            let victim = self
                .map
                .iter()
                .filter(|(k, _)| **k != key)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            match victim {
                Some(k) => {
                    if let Some(e) = self.map.remove(&k) {
                        self.bytes -= e.bytes;
                        self.evictions += 1;
                    }
                }
                None => break, // only the new entry remains — keep it
            }
        }
    }
}

fn store() -> &'static Mutex<Store> {
    static CACHE: OnceLock<Mutex<Store>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(Store::new()))
}

/// Lock the cache, recovering from poison: single-call map updates
/// leave it consistent even if a holder panicked, and the serving path
/// must not turn one past panic into a permanent compile failure.
fn locked() -> std::sync::MutexGuard<'static, Store> {
    store().lock().unwrap_or_else(|p| p.into_inner())
}

pub(crate) fn lookup(key: &Key) -> Option<Arc<Compiled>> {
    locked().lookup(key)
}

/// Insert under `budget` bytes (the entry is charged its generated
/// data structure's footprint), evicting LRU entries until it fits.
pub(crate) fn insert(key: Key, compiled: Arc<Compiled>, budget: usize) {
    let bytes = compiled.prepared.bytes();
    locked().insert(key, compiled, bytes, budget);
}

pub(crate) fn clear() {
    let mut s = locked();
    s.map.clear();
    s.bytes = 0;
}

/// Drop every entry compiled against matrix `fingerprint` — generation
/// retirement (`engine::version`): once a delta supersedes a
/// generation, plans compiled for the old bits must never serve again,
/// and their bytes should not sit in the budget until LRU pressure
/// finds them. Each removal counts as an eviction (surfaced through
/// `Engine::cache_evictions`). Returns the number of entries dropped.
pub(crate) fn evict_fingerprint(fingerprint: u64) -> u64 {
    let mut s = locked();
    let victims: Vec<Key> =
        s.map.keys().filter(|k| k.fingerprint == fingerprint).copied().collect();
    let mut dropped = 0u64;
    for k in victims {
        if let Some(e) = s.map.remove(&k) {
            s.bytes -= e.bytes;
            s.evictions += 1;
            dropped += 1;
        }
    }
    dropped
}

pub(crate) fn len() -> usize {
    locked().map.len()
}

/// Total bytes of generated data structures currently cached.
pub(crate) fn bytes() -> usize {
    locked().bytes
}

/// Process-wide monotonic count of budget evictions (monotonic across
/// `clear`, like the crew spawn counters — report deltas).
pub(crate) fn evictions() -> u64 {
    locked().evictions
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn digest_separates_configurations() {
        let seed = CostParams::host_small();
        let base = config_digest(&seed, true, 100, 0, None);
        assert_eq!(base, config_digest(&seed, true, 100, 0, None), "deterministic");
        assert_ne!(base, config_digest(&seed, false, 100, 0, None), "schedule axis");
        assert_ne!(base, config_digest(&seed, true, 16, 0, None), "spmm_k");
        assert_ne!(base, config_digest(&seed, true, 100, 4, None), "autotune depth");
        assert_ne!(base, config_digest(&seed, true, 100, 0, Some("csr.row.serial")), "pin");
        // A fitted profile (different weights) cold-starts the cache.
        let mut w = seed.weights;
        w[0] *= 1.5;
        let fitted = seed.with_weights(w);
        assert_ne!(base, config_digest(&fitted, true, 100, 0, None), "weights");
        // Structural shape participates too.
        let mut big = seed;
        big.l2_bytes *= 2.0;
        assert_ne!(base, config_digest(&big, true, 100, 0, None), "l2");
        // So does the register width: widening the vector unit can
        // flip which lane count wins, so it must cold-start the cache.
        let mut wide = seed;
        wide.vector_bytes = 64.0;
        assert_ne!(base, config_digest(&wide, true, 100, 0, None), "vector width");
        // And the socket count: a NUMA machine prices parallel plans
        // differently, so plans compiled single-node must not serve it.
        let numa = seed.with_sockets(2);
        assert_ne!(base, config_digest(&numa, true, 100, 0, None), "sockets");
    }

    #[test]
    fn keys_are_exact() {
        let d = config_digest(&CostParams::host_small(), true, 100, 0, None);
        let a = Key::new(Kernel::Spmv, "host-small", 1, d);
        assert_eq!(a, Key::new(Kernel::Spmv, "host-small", 1, d));
        assert_ne!(a, Key::new(Kernel::Spmm, "host-small", 1, d));
        assert_ne!(a, Key::new(Kernel::Spmv, "host-large", 1, d));
        assert_ne!(a, Key::new(Kernel::Spmv, "host-small", 2, d));
    }

    fn dummy_compiled() -> Arc<Compiled> {
        let mut m = crate::matrix::TriMat::new(2, 2);
        m.push(0, 0, 1.0);
        m.push(1, 1, 2.0);
        let space = crate::search::plan::PlanSpace::serial_only();
        let plan = crate::search::tree::enumerate(Kernel::Spmv, &space).plans[0].clone();
        let prepared = Arc::new(crate::concretize::prepare(plan.exec, &m));
        Arc::new(Compiled {
            plan,
            prepared,
            stats: crate::matrix::MatrixStats::of(&m),
            params: CostParams::host_small(),
            features: crate::search::cost::FeatureVec::zero(),
            predicted_secs: 1e-6,
            measured_secs: None,
            profile_loaded: false,
            health: crate::engine::Health::Calibrated,
            fingerprint: m.fingerprint(),
        })
    }

    /// LRU semantics on a *local* store (the global one is shared by
    /// every concurrently running test — exercising tiny budgets there
    /// would evict entries other tests assert Arc-sharing on).
    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let mut s = Store::new();
        let c = dummy_compiled();
        let key = |f: u64| Key::new(Kernel::Spmv, "test-arch", f, 0);
        s.insert(key(1), Arc::clone(&c), 100, 250);
        s.insert(key(2), Arc::clone(&c), 100, 250);
        assert_eq!(s.map.len(), 2);
        assert_eq!(s.bytes, 200);
        assert_eq!(s.evictions, 0);
        // Touch 1 so 2 becomes least-recently-used, then overflow.
        assert!(s.lookup(&key(1)).is_some());
        s.insert(key(3), Arc::clone(&c), 100, 250);
        assert_eq!(s.map.len(), 2);
        assert_eq!(s.evictions, 1);
        assert!(s.lookup(&key(2)).is_none(), "the LRU entry is the one evicted");
        assert!(s.lookup(&key(1)).is_some());
        assert!(s.lookup(&key(3)).is_some());
        // An entry larger than the whole budget still lands (the
        // newest entry is never evicted) but displaces everything else.
        s.insert(key(4), Arc::clone(&c), 10_000, 250);
        assert_eq!(s.map.len(), 1);
        assert_eq!(s.evictions, 3);
        assert!(s.lookup(&key(4)).is_some());
        // Replacing a key does not double-charge its bytes.
        s.insert(key(4), Arc::clone(&c), 10_000, 250);
        assert_eq!(s.bytes, 10_000);
        assert_eq!(s.map.len(), 1);
        assert_eq!(s.evictions, 3);
        // A zero-byte entry is still charged one byte (bookkeeping
        // stays consistent for empty prepared storage).
        s.insert(key(5), Arc::clone(&c), 0, usize::MAX);
        assert_eq!(s.bytes, 10_001);
    }

    /// Generation retirement: every entry of a superseded fingerprint
    /// goes at once (all kernels / digests), other fingerprints stay,
    /// and each removal counts as an eviction. Runs against the global
    /// store with fingerprints unique to this test (other tests only
    /// assert `>=` deltas on the counter).
    #[test]
    fn evict_fingerprint_drops_all_generations_entries() {
        let c = dummy_compiled();
        let fp_old = 0xDE17A_01Du64;
        let fp_new = 0xDE17A_07Eu64;
        insert(Key::new(Kernel::Spmv, "evict-test", fp_old, 1), Arc::clone(&c), usize::MAX);
        insert(Key::new(Kernel::Spmm, "evict-test", fp_old, 2), Arc::clone(&c), usize::MAX);
        insert(Key::new(Kernel::Spmv, "evict-test", fp_new, 1), Arc::clone(&c), usize::MAX);
        let ev0 = evictions();
        assert_eq!(evict_fingerprint(fp_old), 2);
        assert!(evictions() >= ev0 + 2, "each retirement drop counts as an eviction");
        assert!(lookup(&Key::new(Kernel::Spmv, "evict-test", fp_old, 1)).is_none());
        assert!(lookup(&Key::new(Kernel::Spmm, "evict-test", fp_old, 2)).is_none());
        assert!(lookup(&Key::new(Kernel::Spmv, "evict-test", fp_new, 1)).is_some());
        assert_eq!(evict_fingerprint(fp_old), 0, "idempotent");
        evict_fingerprint(fp_new); // leave the global store clean
    }
}
