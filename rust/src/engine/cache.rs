//! The process-wide compile cache — the serving path.
//!
//! Keyed by `(kernel, arch, matrix fingerprint, config digest)`: the
//! fingerprint is `TriMat::fingerprint` (content + shape + order), the
//! digest folds in everything else that can change the winning plan or
//! its storage — the ranked weight vector (so loading a new tuning
//! profile cold-starts the cache instead of serving stale plans), the
//! vector register width (a wider unit can flip the winning lane
//! count), the schedule axis, the SpMM dense width, the autotune
//! depth, and a pinned plan id if any. Entries hold the `Arc`-shared `Compiled`
//! (plan + storage), so a hit is a pointer clone: repeated compiles of
//! the same matrix are free. This layers *above*
//! `concretize::prepare_many`'s plan-keyed storage cache, which
//! de-duplicates storage *within* one compile's shortlist.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use crate::baselines::Kernel;
use crate::search::cost::CostParams;

use super::executable::Compiled;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub(crate) struct Key {
    kernel: Kernel,
    arch: &'static str,
    fingerprint: u64,
    digest: u64,
}

impl Key {
    pub(crate) fn new(kernel: Kernel, arch: &'static str, fingerprint: u64, digest: u64) -> Self {
        Key { kernel, arch, fingerprint, digest }
    }
}

/// FNV-1a fold (`util::fnv::Fnv1a`, the same primitive as
/// `TriMat::fingerprint`) of the engine-configuration facets that
/// affect compile results (see module docs for the list).
pub(crate) fn config_digest(
    params: &CostParams,
    schedules: bool,
    spmm_k: usize,
    autotune_k: usize,
    pinned: Option<&str>,
) -> u64 {
    let mut h = crate::util::fnv::Fnv1a::new();
    h.eat_u64(params.l2_bytes.to_bits());
    h.eat_u64(params.threads as u64);
    h.eat_u64(params.vector_bytes.to_bits());
    for w in &params.weights {
        h.eat_u64(w.to_bits());
    }
    h.eat_u64(schedules as u64);
    h.eat_u64(spmm_k as u64);
    h.eat_u64(autotune_k as u64);
    if let Some(id) = pinned {
        h.eat_bytes(id.as_bytes());
    }
    h.finish()
}

fn cache() -> &'static Mutex<HashMap<Key, Arc<Compiled>>> {
    static CACHE: OnceLock<Mutex<HashMap<Key, Arc<Compiled>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Lock the cache, recovering from poison: single-call map updates
/// leave it consistent even if a holder panicked, and the serving path
/// must not turn one past panic into a permanent compile failure.
fn locked() -> std::sync::MutexGuard<'static, HashMap<Key, Arc<Compiled>>> {
    cache().lock().unwrap_or_else(|p| p.into_inner())
}

pub(crate) fn lookup(key: &Key) -> Option<Arc<Compiled>> {
    locked().get(key).cloned()
}

pub(crate) fn insert(key: Key, compiled: Arc<Compiled>) {
    locked().insert(key, compiled);
}

pub(crate) fn clear() {
    locked().clear();
}

pub(crate) fn len() -> usize {
    locked().len()
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn digest_separates_configurations() {
        let seed = CostParams::host_small();
        let base = config_digest(&seed, true, 100, 0, None);
        assert_eq!(base, config_digest(&seed, true, 100, 0, None), "deterministic");
        assert_ne!(base, config_digest(&seed, false, 100, 0, None), "schedule axis");
        assert_ne!(base, config_digest(&seed, true, 16, 0, None), "spmm_k");
        assert_ne!(base, config_digest(&seed, true, 100, 4, None), "autotune depth");
        assert_ne!(base, config_digest(&seed, true, 100, 0, Some("csr.row.serial")), "pin");
        // A fitted profile (different weights) cold-starts the cache.
        let mut w = seed.weights;
        w[0] *= 1.5;
        let fitted = seed.with_weights(w);
        assert_ne!(base, config_digest(&fitted, true, 100, 0, None), "weights");
        // Structural shape participates too.
        let mut big = seed;
        big.l2_bytes *= 2.0;
        assert_ne!(base, config_digest(&big, true, 100, 0, None), "l2");
        // So does the register width: widening the vector unit can
        // flip which lane count wins, so it must cold-start the cache.
        let mut wide = seed;
        wide.vector_bytes = 64.0;
        assert_ne!(base, config_digest(&wide, true, 100, 0, None), "vector width");
    }

    #[test]
    fn keys_are_exact() {
        let d = config_digest(&CostParams::host_small(), true, 100, 0, None);
        let a = Key::new(Kernel::Spmv, "host-small", 1, d);
        assert_eq!(a, Key::new(Kernel::Spmv, "host-small", 1, d));
        assert_ne!(a, Key::new(Kernel::Spmm, "host-small", 1, d));
        assert_ne!(a, Key::new(Kernel::Spmv, "host-large", 1, d));
        assert_ne!(a, Key::new(Kernel::Spmv, "host-small", 2, d));
    }
}
