//! The engine's output artifact: a tuned routine bound to its
//! generated data structure, plus the observability surface
//! (`plan()`, `bytes()`, `explain()`).

use std::fmt;
use std::sync::Arc;

use crate::baselines::Kernel;
use crate::concretize::{self, Prepared};
use crate::matrix::MatrixStats;
use crate::search::cost::{CostParams, FeatureVec, FEATURE_NAMES, N_FEATURES};
use crate::search::plan::Plan;
use crate::storage::SparseOps;

/// Where on the degradation ladder a compile landed — queryable on the
/// [`Executable`] so a serving host can alarm on degraded compiles
/// without parsing logs. The variants are ordered top rung first;
/// `Ord` follows that order, so `health > Health::Calibrated` means
/// "degraded in some way".
///
/// ```text
/// Calibrated        profile loaded, autotune (if requested) succeeded
///   └─ SeedWeights      profile missing/corrupt → seed cost weights
///       └─ PredictedOnly    every measurement failed → predicted best,
///       │                   unmeasured (quarantined candidates skipped)
///       └─ ReferenceSerial  candidate preparation failed wholesale →
///                           the serial CSR reference plan, always valid
/// ```
///
/// `Engine::compile` only *errors* on an invalid matrix
/// ([`crate::error::ForelemError::InvalidMatrix`]); every other fault
/// lands a rung down this ladder instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Health {
    /// Top rung: the fitted tuning profile loaded and — when autotune
    /// was requested — at least one candidate measured successfully.
    Calibrated,
    /// The tuning profile was missing, corrupt, or failed its
    /// checksum: predictions ran on the seed weights.
    SeedWeights,
    /// Autotune was requested but every shortlisted measurement
    /// panicked, hung, or was already quarantined: the engine serves
    /// the predicted-best plan unmeasured.
    PredictedOnly,
    /// Last resort: candidate preparation itself failed (or a pinned
    /// plan disappeared), so the engine serves the reference serial
    /// CSR plan — the one execution that is always valid.
    ReferenceSerial,
}

impl Health {
    /// Stable lowercase label for logs and metrics keys.
    pub fn label(&self) -> &'static str {
        match self {
            Health::Calibrated => "calibrated",
            Health::SeedWeights => "seed-weights",
            Health::PredictedOnly => "predicted-only",
            Health::ReferenceSerial => "reference-serial",
        }
    }

    /// True for every rung below [`Health::Calibrated`].
    pub fn degraded(&self) -> bool {
        *self != Health::Calibrated
    }
}

/// The cached result of one `Engine::compile`: the winning plan, its
/// assembled storage, and everything `explain()` needs to say why.
pub(crate) struct Compiled {
    pub plan: Plan,
    pub prepared: Arc<Prepared>,
    pub stats: MatrixStats,
    pub params: CostParams,
    pub features: FeatureVec,
    pub predicted_secs: f64,
    pub measured_secs: Option<f64>,
    pub profile_loaded: bool,
    pub health: Health,
    /// `TriMat::fingerprint` of the matrix this compile answers for —
    /// the storage-generation identity `engine::version` chains
    /// `Transition`s over and retirement evicts by.
    pub fingerprint: u64,
}

/// A compiled routine + data structure, bound to one matrix — what
/// `Engine::compile` returns. Cloning is cheap (the storage is
/// `Arc`-shared, as it is across the engine's process-wide cache).
#[derive(Clone)]
pub struct Executable {
    kernel: Kernel,
    dense_k: usize,
    inner: Arc<Compiled>,
}

impl fmt::Debug for Executable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Executable")
            .field("kernel", &self.kernel)
            .field("plan", &self.inner.plan.id)
            .field("health", &self.inner.health)
            .finish_non_exhaustive()
    }
}

impl Executable {
    pub(crate) fn new(kernel: Kernel, dense_k: usize, inner: Arc<Compiled>) -> Self {
        Executable { kernel, dense_k, inner }
    }

    /// The kernel this executable was compiled (and tuned) for.
    pub fn kernel(&self) -> Kernel {
        self.kernel
    }

    /// The winning plan: stable id, derivation chain, execution triple.
    pub fn plan(&self) -> &Plan {
        &self.inner.plan
    }

    /// Total bytes of the generated data structure (storage + schedule
    /// auxiliaries built at compile time).
    pub fn bytes(&self) -> usize {
        self.inner.prepared.bytes()
    }

    /// The plan's predicted seconds per invocation on this matrix,
    /// under the engine's (possibly fitted) parameters.
    pub fn predicted_secs(&self) -> f64 {
        self.inner.predicted_secs
    }

    /// Median measured seconds from the autotune loop, if the engine
    /// measured this compile (`Autotune::TopK(k ≥ 2)`).
    pub fn measured_secs(&self) -> Option<f64> {
        self.inner.measured_secs
    }

    /// Which rung of the degradation ladder this compile landed on —
    /// [`Health::Calibrated`] when nothing went wrong. See [`Health`].
    pub fn health(&self) -> Health {
        self.inner.health
    }

    /// `TriMat::fingerprint` of the matrix this executable answers for
    /// — the storage-generation identity. A serve through
    /// `engine::version` asserts its answer against exactly this value.
    pub fn fingerprint(&self) -> u64 {
        self.inner.fingerprint
    }

    /// The `Arc`-shared storage behind the executable — exposed so
    /// callers (and the cache tests) can observe sharing across
    /// repeated compiles.
    pub fn storage(&self) -> Arc<dyn SparseOps> {
        Arc::clone(&self.inner.prepared.ops)
    }

    /// Run the generated SpMV: `y = A x`.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.check(Kernel::Spmv);
        self.inner.prepared.spmv(x, y);
    }

    /// Run the generated SpMM with the engine's configured dense
    /// column count (`EngineBuilder::spmm_k`, default 100): `C = A B`,
    /// `b` is `ncols × k` row-major.
    pub fn spmm(&self, b: &[f64], c: &mut [f64]) {
        self.spmm_k(b, self.dense_k, c);
    }

    /// Run the generated SpMM with an explicit dense column count.
    pub fn spmm_k(&self, b: &[f64], k: usize, c: &mut [f64]) {
        self.check(Kernel::Spmm);
        self.inner.prepared.spmm(b, k, c);
    }

    /// Run the generated unit-lower TrSv (the storage holds the
    /// strictly-lower triangle): solve `L x = b`.
    pub fn trsv(&self, b: &[f64], x: &mut [f64]) {
        self.check(Kernel::Trsv);
        self.inner.prepared.trsv(b, x);
    }

    /// The generated C-like code of the winning plan — the inspectable
    /// artifact, headed by the predicted footprint that ranked it.
    pub fn codegen(&self) -> String {
        concretize::codegen::emit_with_cost(
            self.kernel,
            &self.inner.plan.exec,
            self.dense_k,
            &self.inner.stats,
            &self.inner.params,
        )
    }

    /// The cost/feature breakdown of the winning plan on this matrix:
    /// one term per cost-model feature (value × fitted weight =
    /// seconds), the predicted and — when autotuned — measured time,
    /// and the storage footprint. The observability face of the
    /// planner: render with `Display` or consume the fields.
    pub fn explain(&self) -> CostBreakdown {
        let c = &*self.inner;
        let terms: Vec<CostTerm> = (0..N_FEATURES)
            .map(|i| CostTerm {
                name: FEATURE_NAMES[i],
                feature: c.features.0[i],
                weight: c.params.weights[i],
                seconds: c.features.0[i] * c.params.weights[i],
            })
            .collect();
        CostBreakdown {
            kernel: self.kernel,
            plan_id: c.plan.id.clone(),
            derivation: c.plan.derivation.clone(),
            predicted_secs: c.predicted_secs,
            measured_secs: c.measured_secs,
            bytes: self.bytes(),
            profile_loaded: c.profile_loaded,
            health: c.health,
            cache_evictions: super::cache::evictions(),
            terms,
        }
    }

    /// A kernel mismatch is a caller bug, not a degraded mode: an
    /// executable tuned for one kernel may not even generate a legal
    /// loop nest for another (e.g. a parallel SpMV plan has no TrSv).
    fn check(&self, called: Kernel) {
        if self.kernel == called {
            return;
        }
        assert!(
            concretize::supports(&self.inner.plan.exec, called),
            "executable was compiled for {:?} (plan {}); its generated nest does not \
             support {:?} — compile({:?}, ..) instead",
            self.kernel,
            self.inner.plan.id,
            called,
            called
        );
    }
}

/// One feature's contribution to a predicted time.
#[derive(Clone, Debug, PartialEq)]
pub struct CostTerm {
    /// Feature name (`search::cost::FEATURE_NAMES` order).
    pub name: &'static str,
    /// Extracted feature value on this matrix.
    pub feature: f64,
    /// The (seed or fitted) weight applied to it.
    pub weight: f64,
    /// `feature × weight` — this term's share of the prediction.
    pub seconds: f64,
}

/// The `explain()` report: why the engine picked this plan and what it
/// expects it to cost. `predicted_secs` is the dot product of the
/// terms (clamped positive), exactly what ranked the plan.
#[derive(Clone, Debug)]
pub struct CostBreakdown {
    pub kernel: Kernel,
    pub plan_id: String,
    pub derivation: String,
    pub predicted_secs: f64,
    /// Autotune median, when the engine measured this compile.
    pub measured_secs: Option<f64>,
    /// Bytes of the generated data structure.
    pub bytes: usize,
    /// Whether the weights came from a fitted tuning profile.
    pub profile_loaded: bool,
    /// The degradation-ladder rung the compile landed on.
    pub health: Health,
    /// Process-wide compile-cache budget evictions at explain time
    /// (monotonic since process start — hosts watch the delta to spot
    /// a cache churning under its `EngineBuilder::cache_budget`).
    pub cache_evictions: u64,
    pub terms: Vec<CostTerm>,
}

impl fmt::Display for CostBreakdown {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "{} plan {} ({} bytes, {} weights{})",
            self.kernel.label(),
            self.plan_id,
            self.bytes,
            if self.profile_loaded { "fitted" } else { "seed" },
            if self.health.degraded() {
                format!(", health: {}", self.health.label())
            } else {
                String::new()
            }
        )?;
        writeln!(f, "  derivation: {}", self.derivation)?;
        for t in &self.terms {
            writeln!(
                f,
                "  {:<16} {:>12.4e} x {:>10.3e} = {:>9.3} us",
                t.name,
                t.feature,
                t.weight,
                t.seconds * 1e6
            )?;
        }
        write!(f, "  predicted {:.3} us", self.predicted_secs * 1e6)?;
        if let Some(m) = self.measured_secs {
            write!(f, ", measured {:.3} us (autotuned)", m * 1e6)?;
        }
        if self.cache_evictions > 0 {
            write!(f, " [cache evictions: {}]", self.cache_evictions)?;
        }
        writeln!(f)
    }
}
