//! Fault injection for the hardened serving path — the `chaos` feature.
//!
//! The robustness layer's contract (`ISSUE 6`) is that a fault at any
//! seam of `Engine::compile → Executable::spmv` degrades down the
//! [`crate::engine::Health`] ladder instead of crashing or deadlocking
//! the process. This module makes that contract *testable*: named
//! **fault points** are compiled into the artifact-IO, prepare and
//! measurement seams, and the drill ([`drill`], `forelem chaos`,
//! `tests/chaos.rs`) arms each point with an IO error, a panic and a
//! delay in turn, asserting the expected ladder rung engages and the
//! served numerics stay correct.
//!
//! # Zero cost when off
//!
//! Without the `chaos` cargo feature, [`trigger`] is an inline empty
//! function returning `Ok(())` and [`trigger_unwrap`] inlines to
//! nothing — no registry, no lock, no branch survives optimization.
//! With the feature, every [`trigger`] consults a process-global
//! armed-faults table (`arm` / `disarm_all`).
//!
//! # Seams
//!
//! The registered points are listed in [`POINTS`]; a point is placed
//! with [`faultpoint!`] (panic-isolated seams — the injected IO error
//! also manifests as a panic, exercising the same isolation) or
//! [`faultpoint_io!`] (seams with a real `io::Result` path).
//!
//! [`faultpoint!`]: crate::faultpoint
//! [`faultpoint_io!`]: crate::faultpoint_io

/// Every registered fault point. The chaos drill iterates this list,
/// so adding a `faultpoint!` without registering it here leaves it
/// un-drilled (and `arm` rejects unknown names to catch typos).
pub const POINTS: &[&str] = &[
    "artifacts.load_profile",
    "artifacts.append_samples",
    "artifacts.load_samples",
    "engine.prepare",
    "engine.measure",
    "pool.worker",
    "batch.flush",
    "delta.repair",
    "delta.swap",
];

/// Fire the named fault point. With the `chaos` feature and an armed
/// fault this returns an injected `io::Error`, panics, or sleeps;
/// otherwise (and always without the feature) it is `Ok(())`.
#[cfg(feature = "chaos")]
pub fn trigger(name: &'static str) -> std::io::Result<()> {
    imp::trigger(name)
}

/// Fire the named fault point (no-op build: the `chaos` feature is
/// off, so this inlines away).
#[cfg(not(feature = "chaos"))]
#[inline(always)]
pub fn trigger(_name: &'static str) -> std::io::Result<()> {
    Ok(())
}

/// [`trigger`] for seams with no `io::Result` path: an injected IO
/// error is escalated to a panic so it exercises the same
/// `catch_unwind` isolation as an injected panic.
#[inline(always)]
pub fn trigger_unwrap(name: &'static str) {
    if let Err(e) = trigger(name) {
        panic!("chaos fault at {name}: {e}");
    }
}

/// Place a panic-isolated fault point: `faultpoint!("engine.measure")`.
#[macro_export]
macro_rules! faultpoint {
    ($name:expr) => {
        $crate::chaos::trigger_unwrap($name)
    };
}

/// Place an IO-seam fault point yielding `std::io::Result<()>`:
/// `faultpoint_io!("artifacts.append_samples")?`.
#[macro_export]
macro_rules! faultpoint_io {
    ($name:expr) => {
        $crate::chaos::trigger($name)
    };
}

/// A fault to arm at a point (chaos builds only).
#[cfg(feature = "chaos")]
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// The point reports an injected `std::io::Error`.
    IoError,
    /// The point panics.
    Panic,
    /// The point sleeps for the given duration, then proceeds.
    Delay(std::time::Duration),
}

#[cfg(feature = "chaos")]
pub use imp::{arm, disarm_all};

/// Serialize lib tests that *arm* faults against lib tests that merely
/// cross fault points in the same binary: an armed window must never
/// bleed into an unrelated concurrently-running test.
#[cfg(all(test, feature = "chaos"))]
pub(crate) fn test_arming_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::OnceLock<std::sync::Mutex<()>> = std::sync::OnceLock::new();
    LOCK.get_or_init(|| std::sync::Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

#[cfg(feature = "chaos")]
mod imp {
    use std::collections::HashMap;
    use std::io;
    use std::sync::{Mutex, OnceLock};

    use super::Fault;

    fn armed() -> &'static Mutex<HashMap<&'static str, Fault>> {
        static ARMED: OnceLock<Mutex<HashMap<&'static str, Fault>>> = OnceLock::new();
        ARMED.get_or_init(|| Mutex::new(HashMap::new()))
    }

    /// Arm `fault` at `point` (must be one of [`super::POINTS`]).
    pub fn arm(point: &'static str, fault: Fault) {
        assert!(super::POINTS.contains(&point), "unknown fault point '{point}'");
        armed().lock().unwrap_or_else(|p| p.into_inner()).insert(point, fault);
    }

    /// Disarm every fault.
    pub fn disarm_all() {
        armed().lock().unwrap_or_else(|p| p.into_inner()).clear();
    }

    pub fn trigger(name: &'static str) -> io::Result<()> {
        let fault = armed().lock().unwrap_or_else(|p| p.into_inner()).get(name).copied();
        match fault {
            None => Ok(()),
            Some(Fault::IoError) => {
                Err(io::Error::other(format!("chaos: injected io error at {name}")))
            }
            Some(Fault::Panic) => panic!("chaos: injected panic at {name}"),
            Some(Fault::Delay(d)) => {
                std::thread::sleep(d);
                Ok(())
            }
        }
    }
}

/// The drill: arm every registered point with every fault class and
/// assert the degradation ladder engages without a crash, deadlock or
/// wrong answer. Shared verbatim by `forelem chaos` and the
/// `tests/chaos.rs` integration suite so the CLI and CI exercise one
/// code path.
#[cfg(feature = "chaos")]
pub mod drill {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::time::Duration;

    use super::{arm, disarm_all, Fault};
    use crate::bench::harness::BenchConfig;
    use crate::concretize;
    use crate::coordinator::sweep::Arch;
    use crate::engine::{Autotune, Engine, Health, Kernel};
    use crate::matrix::gen;
    use crate::runtime::artifacts;
    use crate::search::calibrate::{Profile, Sample};
    use crate::search::cost::N_FEATURES;

    /// One (point × fault) drill result.
    #[derive(Clone, Debug)]
    pub struct Outcome {
        pub point: &'static str,
        pub fault: &'static str,
        /// Health of the compile, when the point sits on the compile
        /// path (`None` for the calibrate-path archive points).
        pub health: Option<Health>,
        pub ok: bool,
        pub detail: String,
    }

    const MEASURE_TIMEOUT: Duration = Duration::from_millis(150);

    fn faults_for(point: &str) -> [Fault; 3] {
        // The delay at the measurement seam must exceed the watchdog
        // timeout (that *is* the drill); elsewhere a short delay just
        // rides through.
        let delay = if point == "engine.measure" {
            Duration::from_millis(400)
        } else {
            Duration::from_millis(25)
        };
        [Fault::IoError, Fault::Panic, Fault::Delay(delay)]
    }

    fn fault_label(f: Fault) -> &'static str {
        match f {
            Fault::IoError => "io-error",
            Fault::Panic => "panic",
            Fault::Delay(_) => "delay",
        }
    }

    /// The expected ladder rung when `fault` is armed at `point` on an
    /// engine whose tuning profile is present and valid.
    fn expected_health(point: &str, fault: Fault) -> Health {
        match (point, fault) {
            // Profile unreadable / loader panicking: seed weights.
            ("artifacts.load_profile", Fault::IoError | Fault::Panic) => Health::SeedWeights,
            // Candidate preparation failing wholesale: last resort.
            ("engine.prepare", Fault::IoError | Fault::Panic) => Health::ReferenceSerial,
            // Every candidate measurement panics or hangs: serve the
            // predicted best unmeasured.
            ("engine.measure", _) => Health::PredictedOnly,
            // Archive-write failures and benign delays never degrade.
            _ => Health::Calibrated,
        }
    }

    /// Run the full drill. Never panics; failures come back as
    /// `ok == false` outcomes.
    pub fn run_all() -> Vec<Outcome> {
        let dir = std::env::temp_dir().join("forelem_chaos_drill");
        let _ = std::fs::remove_dir_all(&dir);
        if let Err(e) = std::fs::create_dir_all(&dir) {
            return vec![Outcome {
                point: "setup",
                fault: "none",
                health: None,
                ok: false,
                detail: format!("could not create drill dir: {e}"),
            }];
        }
        // Route the engine's artifact traffic at the drill directory
        // and seed a valid profile so the healthy baseline is the
        // ladder's top rung (Calibrated).
        std::env::set_var("FORELEM_TUNING_DIR", &dir);
        let profile = Profile::from_params("host-small", &Arch::HostSmall.cost_params(), 1);
        if let Err(e) = artifacts::save_profile_in(&dir, &profile) {
            return vec![Outcome {
                point: "setup",
                fault: "none",
                health: None,
                ok: false,
                detail: format!("could not seed drill profile: {e}"),
            }];
        }

        let mut out = Vec::new();
        for (pi, &point) in super::POINTS.iter().enumerate() {
            for fault in faults_for(point) {
                disarm_all();
                Engine::clear_cache();
                Engine::clear_quarantine();
                arm(point, fault);
                let o = if point == "artifacts.load_samples" {
                    drill_archive_load(&dir, point, fault)
                } else if point == "pool.worker" {
                    drill_crew(point, fault)
                } else if point == "batch.flush" {
                    drill_batch(point, fault)
                } else if point.starts_with("delta.") {
                    drill_delta(point, fault)
                } else {
                    drill_compile(point, fault, pi as u64)
                };
                disarm_all();
                out.push(o);
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
        out
    }

    /// Drill a compile-path point: the compile must succeed, land on
    /// the expected ladder rung, and serve numerics bit-identical to
    /// preparing the winning plan directly (and, on the bottom rung,
    /// to the serial CSR reference — which *is* the bottom rung's
    /// plan).
    fn drill_compile(point: &'static str, fault: Fault, seed: u64) -> Outcome {
        let fl = fault_label(fault);
        let m = gen::uniform_random(48, 48, 360, 0xC0A0 + seed);
        let engine = Engine::builder()
            .arch(Arch::HostSmall)
            .autotune(Autotune::TopK(3))
            .profile(true)
            .archive(true)
            .bench(BenchConfig::quick())
            .measure_timeout(MEASURE_TIMEOUT)
            .build();
        let compiled = catch_unwind(AssertUnwindSafe(|| engine.compile(Kernel::Spmv, &m)));
        let exe = match compiled {
            Err(_) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: "compile panicked through the isolation layer".into(),
                }
            }
            Ok(Err(e)) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: format!("compile errored instead of degrading: {e}"),
                }
            }
            Ok(Ok(exe)) => exe,
        };
        let health = exe.health();
        let want = expected_health(point, fault);
        if health != want {
            return Outcome {
                point,
                fault: fl,
                health: Some(health),
                ok: false,
                detail: format!("health {health:?}, expected {want:?}"),
            };
        }
        if point == "engine.measure" && Engine::quarantine_len() == 0 {
            return Outcome {
                point,
                fault: fl,
                health: Some(health),
                ok: false,
                detail: "measurement faults did not quarantine any candidate".into(),
            };
        }
        // Bit-identity: the served kernel against a direct prepare of
        // the same winning plan.
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.019).sin() + 0.25).collect();
        let mut served = vec![0.0; m.nrows];
        let mut reference = vec![0.0; m.nrows];
        exe.spmv(&x, &mut served);
        concretize::prepare(exe.plan().exec, &m).spmv(&x, &mut reference);
        if served != reference {
            return Outcome {
                point,
                fault: fl,
                health: Some(health),
                ok: false,
                detail: format!("served SpMV drifted from plan {}'s direct prepare", exe.plan().id),
            };
        }
        if health == Health::ReferenceSerial {
            // The bottom rung must literally be the serial CSR plan.
            let e = &exe.plan().exec;
            let is_ref = e.layout == concretize::Layout::Csr
                && e.traversal == concretize::Traversal::RowWise
                && e.schedule == concretize::Schedule::Serial;
            if !is_ref {
                return Outcome {
                    point,
                    fault: fl,
                    health: Some(health),
                    ok: false,
                    detail: format!("bottom rung served plan {}, not serial CSR", exe.plan().id),
                };
            }
        }
        Outcome { point, fault: fl, health: Some(health), ok: true, detail: "ok".into() }
    }

    /// Drill the crew's worker-death seam (`pool.worker` sits between
    /// dequeue and run in `util::pool::worker_loop`). Three contracts:
    ///
    /// 1. **No deadlock, no strand.** A lethal armed fault kills every
    ///    worker that dequeues, so a submitted batch must unwind on the
    ///    submitter with the drop-guard's poison payload — `scoped_run`
    ///    returns (via panic), it never parks forever on the condvar. A
    ///    benign delay rides through to the correct answer.
    /// 2. **Clean respawn.** After disarming, the next batch lazily
    ///    respawns the dead workers (`crew_respawns` grows) and
    ///    computes the exact expected result.
    /// 3. **Engine isolation.** An `Engine::compile` on the parallel
    ///    plan space under the still-armed fault must come back `Ok`
    ///    on *some* ladder rung — crew deaths on the measure path
    ///    quarantine candidates, they never crash or hang the compile
    ///    — and once disarmed the served kernel must match a direct
    ///    prepare of the winning plan bit-for-bit.
    fn drill_crew(point: &'static str, fault: Fault) -> Outcome {
        use crate::util::pool;
        let fl = fault_label(fault);
        let n = pool::crew_size();
        if n <= 1 {
            return Outcome {
                point,
                fault: fl,
                health: None,
                ok: true,
                detail: "skipped: a one-worker crew runs inline, the seam cannot fire".into(),
            };
        }
        let run_batch = || {
            let mut acc = vec![0.0f64; n];
            let mut tasks = Vec::with_capacity(n);
            for (i, slot) in acc.iter_mut().enumerate() {
                tasks.push(move || *slot = (i + 1) as f64);
            }
            pool::scoped_run(tasks);
            acc.iter().sum::<f64>()
        };
        let want = (n * (n + 1)) as f64 / 2.0;
        let lethal = !matches!(fault, Fault::Delay(_));
        let armed_result = catch_unwind(AssertUnwindSafe(|| run_batch()));
        match (&armed_result, lethal) {
            (Ok(_), true) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: "armed worker death did not poison the batch".into(),
                }
            }
            (Err(_), false) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: "a benign delay unwound the batch".into(),
                }
            }
            (Ok(&sum), false) if sum != want => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: format!("delayed batch computed {sum}, expected {want}"),
                }
            }
            _ => {}
        }
        let respawns_before = pool::crew_respawns();
        disarm_all();
        let healed = run_batch();
        if healed != want {
            return Outcome {
                point,
                fault: fl,
                health: None,
                ok: false,
                detail: format!("post-disarm batch computed {healed}, expected {want}"),
            };
        }
        if lethal && pool::crew_respawns() <= respawns_before {
            return Outcome {
                point,
                fault: fl,
                health: None,
                ok: false,
                detail: "dead workers were never respawned".into(),
            };
        }
        // Contract 3: a compile whose candidate pool includes parallel
        // plans (HostLarge) under the still-armed fault.
        arm(point, fault);
        let m = gen::uniform_random(48, 48, 360, 0xCE44);
        let engine = Engine::builder()
            .arch(Arch::HostLarge)
            .autotune(Autotune::TopK(3))
            .bench(BenchConfig::quick())
            .measure_timeout(MEASURE_TIMEOUT)
            .build();
        let compiled = catch_unwind(AssertUnwindSafe(|| engine.compile(Kernel::Spmv, &m)));
        disarm_all();
        let exe = match compiled {
            Err(_) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: "compile panicked through the crew isolation".into(),
                }
            }
            Ok(Err(e)) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: format!("compile errored instead of degrading: {e}"),
                }
            }
            Ok(Ok(exe)) => exe,
        };
        let health = exe.health();
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.023).cos() + 0.5).collect();
        let mut served = vec![0.0; m.nrows];
        let mut reference = vec![0.0; m.nrows];
        exe.spmv(&x, &mut served);
        concretize::prepare(exe.plan().exec, &m).spmv(&x, &mut reference);
        if served != reference {
            return Outcome {
                point,
                fault: fl,
                health: Some(health),
                ok: false,
                detail: format!(
                    "served SpMV drifted from plan {}'s direct prepare after crew faults",
                    exe.plan().id
                ),
            };
        }
        Outcome { point, fault: fl, health: Some(health), ok: true, detail: "ok".into() }
    }

    /// Drill the batching queue's flush seam (`batch.flush` sits at
    /// the head of the group execution body). Three contracts:
    ///
    /// 1. **The solo fast path never crosses the seam** — an
    ///    uncontended submit succeeds with the fault armed.
    /// 2. **Poisoning is per-batch.** Under a lethal fault, grouped
    ///    waiters unwind (the batch is poisoned) while any submit that
    ///    raced to the fast path still answers correctly; a benign
    ///    delay rides through to bit-correct answers for everyone.
    /// 3. **The queue survives its poisoned batches.** After
    ///    disarming, the same queue serves bit-identical to a direct
    ///    prepare of its solo plan.
    fn drill_batch(point: &'static str, fault: Fault) -> Outcome {
        let fl = fault_label(fault);
        let m = gen::uniform_random(48, 48, 360, 0xBA7C);
        let engine = Engine::builder()
            .arch(Arch::HostSmall)
            .profile(false)
            .archive(false)
            .max_batch(4)
            .flush_deadline(Duration::from_millis(25))
            .build();
        let q = match engine.batch_queue(&m) {
            Ok(q) => q,
            Err(e) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: format!("batch queue construction failed: {e}"),
                }
            }
        };
        let x: Vec<f64> = (0..48).map(|i| (i as f64 * 0.017).sin() + 0.4).collect();
        let mut want = vec![0.0; 48];
        match engine.compile_pinned(Kernel::Spmv, &m, q.solo_plan_id()) {
            Ok(solo) => solo.spmv(&x, &mut want),
            Err(e) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: format!("solo reference compile failed: {e}"),
                }
            }
        }
        // Contract 1: uncontended submit = fast path, no flush, no
        // fault crossing.
        let solo_armed = catch_unwind(AssertUnwindSafe(|| q.submit(&x)));
        match solo_armed {
            Err(_) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: "armed flush fault leaked into the solo fast path".into(),
                }
            }
            Ok(y) if y != want => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: "solo fast path drifted under an armed flush fault".into(),
                }
            }
            Ok(_) => {}
        }
        // Contract 2: aligned concurrent submitters force real
        // batches through the armed seam.
        let lethal = !matches!(fault, Fault::Delay(_));
        let n_threads = 8;
        let rounds = 5;
        let barrier = std::sync::Barrier::new(n_threads);
        let mut outcomes: Vec<Result<Vec<f64>, ()>> = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for _ in 0..n_threads {
                let q = &q;
                let x = &x;
                let barrier = &barrier;
                handles.push(s.spawn(move || {
                    barrier.wait();
                    // Several submits per thread so at least one pair
                    // overlaps into a real batch even under a fully
                    // serializing scheduler; each submit is isolated
                    // so one poisoned batch doesn't hide the rest.
                    (0..rounds)
                        .map(|_| catch_unwind(AssertUnwindSafe(|| q.submit(x))).map_err(|_| ()))
                        .collect::<Vec<Result<Vec<f64>, ()>>>()
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(v) => outcomes.extend(v),
                    Err(_) => outcomes.push(Err(())),
                }
            }
        });
        let poisoned = outcomes.iter().filter(|o| o.is_err()).count();
        if lethal && poisoned == 0 {
            return Outcome {
                point,
                fault: fl,
                health: None,
                ok: false,
                detail: "a lethal flush fault poisoned no batched waiter".into(),
            };
        }
        if !lethal && poisoned > 0 {
            return Outcome {
                point,
                fault: fl,
                health: None,
                ok: false,
                detail: format!("a benign delay poisoned {poisoned} waiters"),
            };
        }
        for o in outcomes.iter().flatten() {
            if o != &want {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: "a surviving submit drifted from the solo plan's bits".into(),
                };
            }
        }
        // Contract 3: the queue outlives its poisoned batches.
        disarm_all();
        let healed = catch_unwind(AssertUnwindSafe(|| q.submit(&x)));
        let ok = matches!(&healed, Ok(y) if y == &want);
        Outcome {
            point,
            fault: fl,
            health: None,
            ok,
            detail: if ok {
                "ok".into()
            } else {
                "queue did not recover after its poisoned batch".into()
            },
        }
    }

    /// Drill the versioned-matrix delta seams (`engine::version`).
    /// `delta.repair` sits inside the per-kernel in-place repair
    /// attempt: a lethal fault there must degrade that kernel's
    /// transition to a full rebuild — the apply still succeeds, the
    /// new generation's bits are exactly a from-scratch prepare's, and
    /// no torn structure ever serves. `delta.swap` sits just before
    /// the generation store: a lethal fault there must surface as a
    /// typed `MeasurementFailure { plan_id: "delta.swap" }` with the
    /// serving generation untouched. In both cases a benign delay
    /// rides through, and after disarming the next apply succeeds
    /// (healed re-check).
    fn drill_delta(point: &'static str, fault: Fault) -> Outcome {
        use crate::engine::{DeltaOutcome, VersionedMatrix};
        use crate::error::ForelemError;
        use crate::matrix::delta::DeltaBatch;
        let fl = fault_label(fault);
        let lethal = !matches!(fault, Fault::Delay(_));
        let fail = |detail: String| Outcome { point, fault: fl, health: None, ok: false, detail };
        let m = gen::uniform_random(48, 48, 360, 0xDE17);
        let engine =
            Engine::builder().arch(Arch::HostSmall).profile(false).archive(false).build();
        let vm = match engine.versioned(&m, &[Kernel::Spmv]) {
            Ok(v) => v,
            Err(e) => return fail(format!("versioned construction failed: {e}")),
        };
        let fp0 = vm.fingerprint();
        let probe = m.entries[0];
        let mut batch = DeltaBatch::new(48, 48);
        batch.update(probe.row as usize, probe.col as usize, probe.val + 2.5);
        let applied = catch_unwind(AssertUnwindSafe(|| vm.apply_delta(&batch)));

        // The contract both points share: whatever generation is live
        // right now serves bit-identical to a direct prepare of its
        // own reservoir, and names itself as the answerer.
        let x: Vec<f64> = (0..48).map(|i| (i as f64 * 0.021).sin() + 0.3).collect();
        let serve_matches = |vm: &VersionedMatrix| -> Result<(), String> {
            let exe = vm
                .executable(Kernel::Spmv)
                .ok_or_else(|| "spmv executable missing".to_string())?;
            let live = vm.snapshot();
            let mut served = vec![0.0; 48];
            let mut reference = vec![0.0; 48];
            let by = vm.spmv(&x, &mut served).map_err(|e| e.to_string())?;
            if by != vm.fingerprint() {
                return Err("serve named a generation other than the live one".into());
            }
            concretize::prepare(exe.plan().exec, &live).spmv(&x, &mut reference);
            if served != reference {
                return Err(format!(
                    "served SpMV drifted from plan {}'s direct prepare",
                    exe.plan().id
                ));
            }
            Ok(())
        };

        match (point, applied) {
            (_, Err(_)) => {
                return fail("a delta fault escaped the isolation layer as a panic".into())
            }
            ("delta.repair", Ok(Err(e))) => {
                return fail(format!("a repair fault must degrade to rebuild, not error: {e}"))
            }
            ("delta.repair", Ok(Ok(report))) => {
                if report.generation != 1 || vm.fingerprint() == fp0 {
                    return fail("the repair drill did not advance the generation".into());
                }
                let repaired =
                    report.outcomes.iter().any(|(_, o)| *o == DeltaOutcome::Repaired);
                if lethal && repaired {
                    return fail("a faulted repair still claimed the Repaired route".into());
                }
                if !lethal && !repaired {
                    return fail(
                        "a benign delay should ride through to an in-place repair".into(),
                    );
                }
            }
            ("delta.swap", Ok(res)) => {
                if lethal {
                    match res {
                        Err(ForelemError::MeasurementFailure { plan_id, .. })
                            if plan_id == "delta.swap" => {}
                        other => {
                            return fail(format!(
                                "a swap fault must be a typed delta.swap MeasurementFailure, \
                                 got {other:?}"
                            ))
                        }
                    }
                    if vm.fingerprint() != fp0 || vm.generation() != 0 {
                        return fail("an aborted swap moved the serving generation".into());
                    }
                } else {
                    match res {
                        Ok(r) if r.generation == 1 => {}
                        other => {
                            return fail(format!(
                                "a benign swap delay should ride through, got {other:?}"
                            ))
                        }
                    }
                }
            }
            _ => return fail("unregistered delta drill point".into()),
        }
        if let Err(d) = serve_matches(&vm) {
            return fail(d);
        }
        // Healed re-check: after disarming, the subsystem must be fully
        // live — the next delta applies and the new generation serves
        // its own bits.
        disarm_all();
        let live = vm.snapshot();
        let probe2 = live.entries[0];
        let mut heal = DeltaBatch::new(48, 48);
        heal.update(probe2.row as usize, probe2.col as usize, probe2.val - 1.25);
        match catch_unwind(AssertUnwindSafe(|| vm.apply_delta(&heal))) {
            Ok(Ok(_)) => {}
            Ok(Err(e)) => return fail(format!("post-disarm apply_delta errored: {e}")),
            Err(_) => return fail("post-disarm apply_delta panicked".into()),
        }
        if let Err(d) = serve_matches(&vm) {
            return fail(d);
        }
        Outcome { point, fault: fl, health: None, ok: true, detail: "ok".into() }
    }

    /// Drill the calibrate-path archive loader: a fault while loading
    /// must never escape as a panic, and the corrupt-line quarantine
    /// must keep counting when the fault rides through.
    fn drill_archive_load(dir: &std::path::Path, point: &'static str, fault: Fault) -> Outcome {
        let fl = fault_label(fault);
        let slug = "drill-arch";
        let mk = |i: usize| Sample {
            matrix: format!("m{i}"),
            plan_id: "csr.row.serial".into(),
            features: [1.0e6; N_FEATURES],
            measured_secs: 1e-4,
            predicted_secs: 1e-4,
        };
        // Two good lines + one corrupt line, written before arming.
        disarm_all();
        let seeded = artifacts::append_samples_in(dir, slug, &[mk(0), mk(1)]).and_then(|path| {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path)?;
            writeln!(f, "{{corrupt, not a sample}}")?;
            Ok(())
        });
        if let Err(e) = seeded {
            return Outcome {
                point,
                fault: fl,
                health: None,
                ok: false,
                detail: format!("could not seed drill archive: {e}"),
            };
        }
        arm(point, fault);
        let loaded = catch_unwind(AssertUnwindSafe(|| artifacts::load_samples_counted_in(dir, slug)));
        let _ = std::fs::remove_file(artifacts::samples_path_in(dir, slug));
        let archive = match loaded {
            Err(_) => {
                return Outcome {
                    point,
                    fault: fl,
                    health: None,
                    ok: false,
                    detail: "archive load panicked through the isolation layer".into(),
                }
            }
            Ok(a) => a,
        };
        let ok = match fault {
            // Unreadable / panicking loader: the archive is treated as
            // absent, never a crash.
            Fault::IoError | Fault::Panic => archive.samples.is_empty(),
            // A benign delay rides through: both good samples load and
            // the corrupt line is counted, not silently dropped.
            Fault::Delay(_) => archive.samples.len() == 2 && archive.corrupt_lines == 1,
        };
        Outcome {
            point,
            fault: fl,
            health: None,
            ok,
            detail: if ok {
                "ok".into()
            } else {
                format!(
                    "archive load under {fl}: {} samples, {} corrupt lines",
                    archive.samples.len(),
                    archive.corrupt_lines
                )
            },
        }
    }

    /// Run the drill and print a report; returns overall success.
    /// `forelem chaos` exits nonzero when this returns false.
    pub fn run_and_report() -> bool {
        let outcomes = run_all();
        println!("## chaos drill — every fault point x {{io-error, panic, delay}}");
        println!("{:<26} {:<9} {:<16} {}", "point", "fault", "health", "result");
        let mut all_ok = true;
        for o in &outcomes {
            let health = o.health.map(|h| format!("{h:?}")).unwrap_or_else(|| "-".into());
            println!(
                "{:<26} {:<9} {:<16} {}",
                o.point,
                o.fault,
                health,
                if o.ok { "ok".to_string() } else { format!("FAIL: {}", o.detail) }
            );
            all_ok &= o.ok;
        }
        println!(
            "{}/{} drills passed",
            outcomes.iter().filter(|o| o.ok).count(),
            outcomes.len()
        );
        all_ok
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn trigger_is_ok_when_nothing_armed() {
        // Holds both with and without the feature: an unarmed point is
        // a no-op.
        assert!(super::trigger("artifacts.load_profile").is_ok());
        super::trigger_unwrap("engine.measure");
    }

    #[cfg(feature = "chaos")]
    #[test]
    fn armed_faults_fire_and_disarm() {
        use super::{arm, disarm_all, Fault};
        // Hold the arming guard so the armed window cannot bleed into
        // an unrelated test crossing the same point concurrently.
        let _guard = super::test_arming_guard();
        arm("artifacts.append_samples", Fault::IoError);
        assert!(super::trigger("artifacts.append_samples").is_err());
        assert!(super::trigger("artifacts.load_profile").is_ok(), "other points stay clear");
        let p = std::panic::catch_unwind(|| {
            arm("artifacts.append_samples", Fault::Panic);
            super::trigger("artifacts.append_samples")
        });
        assert!(p.is_err(), "Panic fault must panic");
        disarm_all();
        assert!(super::trigger("artifacts.append_samples").is_ok());
    }
}
