//! `forelem serve-bench` — closed-loop serving benchmark for the
//! request-batching path (`engine::batch`).
//!
//! N client threads serve SpMV requests against M suite matrices with
//! Poisson-distributed think time between requests. The same workload
//! schedule runs twice:
//!
//!   * **unbatched** — every client executes the queue's own solo SpMV
//!     plan directly (`Engine::compile_pinned` on the same plan id the
//!     queue selected, so the two phases run identical kernels);
//!   * **batched**  — every client goes through
//!     [`BatchQueue::submit`], letting concurrent same-matrix requests
//!     coalesce into one SpMM panel.
//!
//! The report carries throughput, latency percentiles (batched latency
//! *includes* queueing/deadline wait — that is the price of the
//! throughput win), the observed batch-size histogram, and the
//! batched-vs-unbatched speedup. A bitwise identity pre-check runs
//! before either phase: for every matrix, `submit` must reproduce the
//! solo plan's output exactly, bit for bit, or the report is flagged
//! and the CLI exits non-zero. `BENCH_serve.json` is the machine
//! artifact CI archives and guards.

use std::sync::Barrier;
use std::time::{Duration, Instant};

use crate::coordinator::sweep::{json_escape, json_str_array, Arch};
use crate::engine::batch::BatchStats;
use crate::engine::Engine;
use crate::error::ForelemError;
use crate::matrix::suite::SUITE;
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;
use crate::Kernel;

/// Distinct request vectors pre-generated per matrix; requests cycle
/// through them so the workload is deterministic per seed.
const XS_PER_MATRIX: usize = 4;

/// Configuration of one serve-bench run.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub arch: Arch,
    /// Concurrent closed-loop client threads (the offered concurrency).
    pub clients: usize,
    /// Suite indices the clients cycle through.
    pub matrices: Vec<usize>,
    /// Requests each client issues per phase.
    pub requests_per_client: usize,
    /// Poisson arrival rate per client in Hz; `0` disables think time
    /// (back-to-back closed loop).
    pub lambda_hz: f64,
    /// Queue capacity — a flush seals at this group size.
    pub max_batch: usize,
    pub flush_deadline: Duration,
    /// Load the fitted tuning profile when one exists (the batch
    /// decision is cost-model-driven, so calibration shifts it).
    pub use_profile: bool,
    pub seed: u64,
}

impl ServeConfig {
    /// The CI-sized run: the quick-suite matrices, 8 clients, enough
    /// requests for the histogram to be meaningful in well under a
    /// second of serving. `host-large` so the canonical pools carry
    /// parallel schedules — both phases then draw on the same worker
    /// crew and the comparison is CPU work vs CPU work, not
    /// request-parallelism vs a serialized flusher. `max_batch` equals
    /// the client count: a full closed-loop wave seals the group
    /// immediately instead of idling out the flush deadline.
    pub fn quick() -> ServeConfig {
        ServeConfig {
            arch: Arch::HostLarge,
            clients: 8,
            // Same indices as `SweepConfig::quick()` — one graph, one
            // banded, one constraint matrix.
            matrices: vec![0, 2, 7],
            requests_per_client: 300,
            lambda_hz: 50_000.0,
            max_batch: 8,
            flush_deadline: Duration::from_micros(150),
            use_profile: true,
            seed: 2022,
        }
    }
}

/// Per-matrix outcome: which solo plan served the unbatched phase,
/// where the cost model put the batching threshold, and the queue's
/// counter deltas over the batched phase.
#[derive(Clone, Debug)]
pub struct MatrixServe {
    pub name: String,
    pub solo_plan_id: String,
    /// `None` when the cost model says batching never pays here.
    pub min_k_pays: Option<usize>,
    pub stats: BatchStats,
}

/// One latency distribution, seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct Latency {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Latency {
    fn of(latencies: &mut [f64]) -> Latency {
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Latency {
            p50: percentile_sorted(latencies, 50.0),
            p95: percentile_sorted(latencies, 95.0),
            p99: percentile_sorted(latencies, 99.0),
        }
    }
}

/// The serve-bench result — rendered by [`report_text`] and
/// [`to_json`].
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub arch: Arch,
    pub clients: usize,
    pub requests_per_phase: u64,
    /// Every matrix reproduced the solo plan's bits through `submit`.
    pub bit_identical: bool,
    pub unbatched_elapsed: f64,
    pub batched_elapsed: f64,
    /// Requests per second over the whole phase.
    pub unbatched_throughput: f64,
    pub batched_throughput: f64,
    /// `batched_throughput / unbatched_throughput`.
    pub speedup: f64,
    /// Requests served from coalesced panels over the batched phase,
    /// summed across matrices. `0` means the cost model declined to
    /// batch everywhere (pass-through queues) — the speedup is then
    /// noise around 1.0, not a batching measurement.
    pub batched_requests: u64,
    pub unbatched_latency: Latency,
    pub batched_latency: Latency,
    /// `hist[k]` = groups executed at size k over the batched phase,
    /// summed across matrices; index 0 unused.
    pub hist: Vec<u64>,
    pub per_matrix: Vec<MatrixServe>,
}

fn stats_delta(after: &BatchStats, before: &BatchStats) -> BatchStats {
    BatchStats {
        submitted: after.submitted - before.submitted,
        batched: after.batched - before.batched,
        solo: after.solo - before.solo,
        flushes: after.flushes - before.flushes,
        deadline_flushes: after.deadline_flushes - before.deadline_flushes,
        full_flushes: after.full_flushes - before.full_flushes,
        poisoned_batches: after.poisoned_batches - before.poisoned_batches,
        hist: after
            .hist
            .iter()
            .zip(before.hist.iter().chain(std::iter::repeat(&0)))
            .map(|(a, b)| a - b)
            .collect(),
    }
}

/// Run the benchmark. Phases share one deterministic workload
/// schedule (client c, request r → matrix `matrices[r % M]`, vector
/// `r % XS_PER_MATRIX`), so the two phases serve identical requests.
///
/// # Errors
///
/// Propagates [`ForelemError`] from queue construction or from
/// pinning the solo plan (invalid matrix, unknown plan id).
pub fn run(cfg: &ServeConfig) -> Result<ServeReport, ForelemError> {
    assert!(cfg.clients >= 1, "serve-bench needs at least one client");
    assert!(cfg.requests_per_client >= 1, "serve-bench needs at least one request per client");
    assert!(!cfg.matrices.is_empty(), "serve-bench needs at least one matrix");
    let engine = Engine::builder()
        .arch(cfg.arch)
        .profile(cfg.use_profile)
        .archive(false)
        .max_batch(cfg.max_batch)
        .flush_deadline(cfg.flush_deadline)
        .build();

    // Build matrices, queues, pinned solo executables and request
    // vectors up front — construction cost stays out of both phases.
    let mut names = Vec::new();
    let mut mats = Vec::new();
    let mut queues = Vec::new();
    let mut solos = Vec::new();
    let mut xs: Vec<Vec<Vec<f64>>> = Vec::new();
    for (slot, &si) in cfg.matrices.iter().enumerate() {
        let entry = &SUITE[si % SUITE.len()];
        let m = entry.build_scaled(cfg.arch.scale());
        let q = engine.batch_queue(&m)?;
        let solo = engine.compile_pinned(Kernel::Spmv, &m, q.solo_plan_id())?;
        let mut rng = Rng::new(cfg.seed ^ (0x5e7e * (slot as u64 + 1)));
        let mut vs = Vec::with_capacity(XS_PER_MATRIX);
        for _ in 0..XS_PER_MATRIX {
            vs.push((0..m.ncols).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect());
        }
        names.push(entry.name.to_string());
        mats.push(m);
        queues.push(q);
        solos.push(solo);
        xs.push(vs);
    }

    // Bitwise identity pre-check: submit (solo fast path, no
    // concurrency) must reproduce the pinned solo plan exactly.
    let mut bit_identical = true;
    for (mi, q) in queues.iter().enumerate() {
        let mut y = vec![0.0; mats[mi].nrows];
        for x in &xs[mi] {
            solos[mi].spmv(x, &mut y);
            let got = q.submit(x);
            if got.iter().map(|v| v.to_bits()).ne(y.iter().map(|v| v.to_bits())) {
                eprintln!("serve-bench: BIT MISMATCH on {} via the queue", names[mi]);
                bit_identical = false;
            }
        }
    }

    let nmat = mats.len();
    let total = (cfg.clients * cfg.requests_per_client) as u64;

    // Phase runner: every client walks the same schedule; `batched`
    // switches the serving path, nothing else.
    let run_phase = |batched: bool, phase_salt: u64| -> (f64, Vec<f64>) {
        let barrier = Barrier::new(cfg.clients + 1);
        let mut lats: Vec<f64> = Vec::with_capacity(total as usize);
        let mut elapsed = 0.0;
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(cfg.clients);
            for c in 0..cfg.clients {
                let barrier = &barrier;
                let queues = &queues;
                let solos = &solos;
                let mats = &mats;
                let xs = &xs;
                let mut rng = Rng::new(
                    cfg.seed ^ phase_salt ^ 0x9e3779b97f4a7c15u64.wrapping_mul(c as u64 + 1),
                );
                handles.push(s.spawn(move || {
                    let mut ys: Vec<Vec<f64>> =
                        mats.iter().map(|m| vec![0.0; m.nrows]).collect();
                    let mut local = Vec::with_capacity(cfg.requests_per_client);
                    barrier.wait();
                    for r in 0..cfg.requests_per_client {
                        if cfg.lambda_hz > 0.0 {
                            // Poisson arrivals: exponential think time,
                            // mean 1/λ. gen_f64 ∈ [0,1) so 1-u ∈ (0,1].
                            let dt = -(1.0 - rng.gen_f64()).ln() / cfg.lambda_hz;
                            std::thread::sleep(Duration::from_secs_f64(dt));
                        }
                        let mi = r % nmat;
                        let x = &xs[mi][r % XS_PER_MATRIX];
                        let t0 = Instant::now();
                        if batched {
                            let y = queues[mi].submit(x);
                            local.push(t0.elapsed().as_secs_f64());
                            std::hint::black_box(&y);
                        } else {
                            solos[mi].spmv(x, &mut ys[mi]);
                            local.push(t0.elapsed().as_secs_f64());
                            std::hint::black_box(&ys[mi]);
                        }
                    }
                    local
                }));
            }
            barrier.wait();
            let t0 = Instant::now();
            for h in handles {
                match h.join() {
                    Ok(local) => lats.extend(local),
                    Err(p) => std::panic::resume_unwind(p),
                }
            }
            elapsed = t0.elapsed().as_secs_f64();
        });
        (elapsed, lats)
    };

    let (unbatched_elapsed, mut unbatched_lats) = run_phase(false, 0x0101);
    let before: Vec<BatchStats> = queues.iter().map(|q| q.stats()).collect();
    let (batched_elapsed, mut batched_lats) = run_phase(true, 0x0202);
    let after: Vec<BatchStats> = queues.iter().map(|q| q.stats()).collect();

    let mut hist = vec![0u64; cfg.max_batch + 1];
    let mut per_matrix = Vec::with_capacity(nmat);
    for mi in 0..nmat {
        let d = stats_delta(&after[mi], &before[mi]);
        for (k, &n) in d.hist.iter().enumerate() {
            if k < hist.len() {
                hist[k] += n;
            }
        }
        per_matrix.push(MatrixServe {
            name: names[mi].clone(),
            solo_plan_id: queues[mi].solo_plan_id().to_string(),
            min_k_pays: queues[mi].min_k_pays(),
            stats: d,
        });
    }

    let unbatched_throughput = total as f64 / unbatched_elapsed.max(1e-12);
    let batched_throughput = total as f64 / batched_elapsed.max(1e-12);
    let batched_requests = per_matrix.iter().map(|p| p.stats.batched).sum();
    Ok(ServeReport {
        arch: cfg.arch,
        clients: cfg.clients,
        requests_per_phase: total,
        bit_identical,
        unbatched_elapsed,
        batched_elapsed,
        unbatched_throughput,
        batched_throughput,
        speedup: batched_throughput / unbatched_throughput.max(1e-12),
        batched_requests,
        unbatched_latency: Latency::of(&mut unbatched_lats),
        batched_latency: Latency::of(&mut batched_lats),
        hist,
        per_matrix,
    })
}

/// Human-readable report for stdout.
pub fn report_text(r: &ServeReport) -> String {
    let us = 1e6;
    let mut out = String::new();
    out.push_str(&format!(
        "serve-bench [{}] — {} clients, {} requests/phase, bit-identical: {}\n",
        r.arch.slug(),
        r.clients,
        r.requests_per_phase,
        if r.bit_identical { "yes" } else { "NO (MISMATCH)" },
    ));
    out.push_str(&format!(
        "  unbatched: {:>10.0} req/s   p50 {:>8.1}us  p95 {:>8.1}us  p99 {:>8.1}us\n",
        r.unbatched_throughput,
        r.unbatched_latency.p50 * us,
        r.unbatched_latency.p95 * us,
        r.unbatched_latency.p99 * us,
    ));
    out.push_str(&format!(
        "  batched:   {:>10.0} req/s   p50 {:>8.1}us  p95 {:>8.1}us  p99 {:>8.1}us\n",
        r.batched_throughput,
        r.batched_latency.p50 * us,
        r.batched_latency.p95 * us,
        r.batched_latency.p99 * us,
    ));
    out.push_str(&format!(
        "  speedup:   {:.3}x ({} of {} requests served from panels)\n",
        r.speedup, r.batched_requests, r.requests_per_phase
    ));
    let groups: Vec<String> = r
        .hist
        .iter()
        .enumerate()
        .filter(|&(k, &n)| k > 0 && n > 0)
        .map(|(k, &n)| format!("{k}:{n}"))
        .collect();
    out.push_str(&format!("  batch-size histogram (k:groups): {}\n", groups.join(" ")));
    for pm in &r.per_matrix {
        out.push_str(&format!(
            "  {:<12} solo {:<24} min-k-pays {:<4} submitted {:>5}  batched {:>5}  \
             flushes {:>4} (deadline {}, full {})\n",
            pm.name,
            pm.solo_plan_id,
            pm.min_k_pays.map(|k| k.to_string()).unwrap_or_else(|| "-".into()),
            pm.stats.submitted,
            pm.stats.batched,
            pm.stats.flushes,
            pm.stats.deadline_flushes,
            pm.stats.full_flushes,
        ));
    }
    out
}

/// Render the report as the `BENCH_serve.json` document (same
/// hand-rolled style as `BENCH_spmv.json` — no serde in the tree).
pub fn to_json(r: &ServeReport) -> String {
    let hist: Vec<String> = r.hist.iter().map(u64::to_string).collect();
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"forelem-serve-bench-v1\",\n");
    s.push_str(&format!("  \"arch\": \"{}\",\n", json_escape(r.arch.slug())));
    s.push_str(&format!("  \"clients\": {},\n", r.clients));
    s.push_str(&format!("  \"requests_per_phase\": {},\n", r.requests_per_phase));
    s.push_str(&format!("  \"bit_identical\": {},\n", r.bit_identical));
    s.push_str(&format!("  \"unbatched_elapsed_s\": {:e},\n", r.unbatched_elapsed));
    s.push_str(&format!("  \"batched_elapsed_s\": {:e},\n", r.batched_elapsed));
    s.push_str(&format!("  \"unbatched_rps\": {:e},\n", r.unbatched_throughput));
    s.push_str(&format!("  \"batched_rps\": {:e},\n", r.batched_throughput));
    s.push_str(&format!("  \"speedup\": {:e},\n", r.speedup));
    s.push_str(&format!("  \"batched_requests\": {},\n", r.batched_requests));
    s.push_str(&format!(
        "  \"unbatched_latency_s\": {{\"p50\": {:e}, \"p95\": {:e}, \"p99\": {:e}}},\n",
        r.unbatched_latency.p50, r.unbatched_latency.p95, r.unbatched_latency.p99
    ));
    s.push_str(&format!(
        "  \"batched_latency_s\": {{\"p50\": {:e}, \"p95\": {:e}, \"p99\": {:e}}},\n",
        r.batched_latency.p50, r.batched_latency.p95, r.batched_latency.p99
    ));
    s.push_str(&format!("  \"batch_hist\": [{}],\n", hist.join(", ")));
    let names: Vec<String> = r.per_matrix.iter().map(|p| p.name.clone()).collect();
    s.push_str(&format!("  \"matrices\": {},\n", json_str_array(&names)));
    s.push_str("  \"per_matrix\": [\n");
    for (i, pm) in r.per_matrix.iter().enumerate() {
        let h: Vec<String> = pm.stats.hist.iter().map(u64::to_string).collect();
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"solo_plan\": \"{}\", \"min_k_pays\": {}, \
             \"submitted\": {}, \"batched\": {}, \"solo\": {}, \"flushes\": {}, \
             \"deadline_flushes\": {}, \"full_flushes\": {}, \"poisoned\": {}, \
             \"hist\": [{}]}}{}\n",
            json_escape(&pm.name),
            json_escape(&pm.solo_plan_id),
            pm.min_k_pays.map(|k| k.to_string()).unwrap_or_else(|| "null".into()),
            pm.stats.submitted,
            pm.stats.batched,
            pm.stats.solo,
            pm.stats.flushes,
            pm.stats.deadline_flushes,
            pm.stats.full_flushes,
            pm.stats.poisoned_batches,
            h.join(", "),
            if i + 1 == r.per_matrix.len() { "" } else { "," },
        ));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn tiny() -> ServeConfig {
        ServeConfig {
            arch: Arch::HostSmall,
            clients: 4,
            matrices: vec![0, 2],
            requests_per_client: 24,
            lambda_hz: 0.0,
            max_batch: 4,
            flush_deadline: Duration::from_micros(150),
            use_profile: false,
            seed: 7,
        }
    }

    #[test]
    fn serve_bench_runs_bit_identical_and_accounts_every_request() {
        let cfg = tiny();
        let r = run(&cfg).expect("serve run");
        assert!(r.bit_identical, "queue output must match the pinned solo plan bitwise");
        assert_eq!(r.requests_per_phase, 4 * 24);
        let served: u64 = r.per_matrix.iter().map(|p| p.stats.submitted).sum();
        assert_eq!(served, r.requests_per_phase, "batched phase accounts every request");
        for pm in &r.per_matrix {
            assert_eq!(pm.stats.poisoned_batches, 0);
            let by_hist: u64 =
                pm.stats.hist.iter().enumerate().map(|(k, &n)| k as u64 * n).sum();
            assert_eq!(by_hist, pm.stats.submitted, "histogram accounts every request");
        }
        assert!(r.speedup > 0.0 && r.unbatched_throughput > 0.0);
    }

    #[test]
    fn serve_json_has_the_guarded_fields() {
        let cfg = tiny();
        let r = run(&cfg).expect("serve run");
        let j = to_json(&r);
        assert!(j.contains("\"speedup\": "));
        assert!(j.contains("\"batched_requests\": "));
        assert!(j.contains("\"bit_identical\": true"));
        assert!(j.contains("\"batch_hist\": ["));
        assert!(j.contains("forelem-serve-bench-v1"));
        let txt = report_text(&r);
        assert!(txt.contains("speedup"));
    }
}
