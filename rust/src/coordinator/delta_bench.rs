//! `forelem delta-bench` — the dynamic-matrix benchmark
//! (`engine::version`).
//!
//! For each suite matrix, a [`VersionedMatrix`] absorbs a deterministic
//! stream of update batches while serve threads hammer SpMV through the
//! hot swaps. Three costs come out:
//!
//!   * **repair latency** — the in-place splice (`SparseOps::repair`)
//!     on the live storage, timed directly;
//!   * **rebuild latency** — assembling the same plan's storage from
//!     the post-delta tuples from scratch (the route repair avoids);
//!   * **swap stall** — serve-side latency percentiles observed *while*
//!     generations swap under the serves; the p99 is the stall a
//!     request sees when it lands across a swap.
//!
//! A bitwise identity check runs per matrix after the stream: the live
//! generation must serve exactly the bits a from-scratch prepare of its
//! reservoir serves, or the report is flagged and the CLI exits
//! non-zero. `BENCH_delta.json` is the machine artifact CI archives
//! next to `BENCH_serve.json` as a planner-guard input.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::concretize;
use crate::coordinator::sweep::{json_escape, json_str_array, Arch};
use crate::engine::{DeltaOutcome, Engine, VersionedMatrix};
use crate::error::ForelemError;
use crate::matrix::delta::DeltaBatch;
use crate::matrix::suite::SUITE;
use crate::util::rng::Rng;
use crate::util::stats::percentile_sorted;
use crate::Kernel;

/// Configuration of one delta-bench run.
#[derive(Clone, Debug)]
pub struct DeltaBenchConfig {
    pub arch: Arch,
    /// Suite indices the stream runs over.
    pub matrices: Vec<usize>,
    /// Delta batches applied per matrix.
    pub rounds: usize,
    /// Update ops per batch (clamped to the matrix's nnz).
    pub ops_per_batch: usize,
    /// Serve threads hammering SpMV through the swaps.
    pub serve_clients: usize,
    /// Load the fitted tuning profile when one exists (the
    /// repair-vs-rebuild decision is cost-model-driven).
    pub use_profile: bool,
    pub seed: u64,
}

impl DeltaBenchConfig {
    /// The CI-sized run: two quick-suite matrices, enough rounds for
    /// stable percentiles in well under a second.
    pub fn quick() -> DeltaBenchConfig {
        DeltaBenchConfig {
            arch: Arch::HostSmall,
            matrices: vec![0, 2],
            rounds: 24,
            ops_per_batch: 8,
            serve_clients: 4,
            use_profile: true,
            seed: 2033,
        }
    }
}

/// One latency distribution, seconds.
#[derive(Clone, Copy, Debug, Default)]
pub struct Latency {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Latency {
    fn of(latencies: &mut [f64]) -> Latency {
        if latencies.is_empty() {
            // A plan whose layout has no repair path records no repair
            // samples; an all-zero row reads as "not exercised".
            return Latency::default();
        }
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        Latency {
            p50: percentile_sorted(latencies, 50.0),
            p95: percentile_sorted(latencies, 95.0),
            p99: percentile_sorted(latencies, 99.0),
        }
    }
}

/// Per-matrix outcome of the delta stream.
#[derive(Clone, Debug)]
pub struct MatrixDelta {
    pub name: String,
    /// Routes the per-round transitions took.
    pub repaired: u64,
    pub rebuilt: u64,
    pub replanned: u64,
    /// Direct in-place repair latency over the stream.
    pub repair: Latency,
    /// From-scratch storage assembly latency on the same post-delta
    /// reservoirs.
    pub rebuild: Latency,
    /// Whether the final generation served bit-identical to a fresh
    /// prepare of its own reservoir.
    pub bit_identical: bool,
}

/// The delta-bench result — rendered by [`report_text`] and
/// [`to_json`].
#[derive(Clone, Debug)]
pub struct DeltaBenchReport {
    pub arch: Arch,
    pub rounds: usize,
    pub ops_per_batch: usize,
    pub serve_clients: usize,
    /// Every matrix's final generation reproduced a fresh prepare's
    /// bits exactly.
    pub bit_identical: bool,
    /// Full `apply_delta` latency (resolve → decide → build → swap →
    /// retire), all matrices pooled.
    pub apply: Latency,
    /// In-place repair latency, all matrices pooled.
    pub repair: Latency,
    /// From-scratch rebuild latency, all matrices pooled.
    pub rebuild: Latency,
    /// `repair.p50 / rebuild.p50` — below 1.0 means the splice beats
    /// reassembly at the median (the subsystem's reason to exist).
    pub repair_over_rebuild_p50: f64,
    /// Serve latency observed concurrently with the delta stream; the
    /// p99 is the headline swap-stall number.
    pub swap_stall: Latency,
    /// Serves completed while the stream ran.
    pub serves: u64,
    pub per_matrix: Vec<MatrixDelta>,
}

/// Run the benchmark.
///
/// # Errors
///
/// Propagates [`ForelemError`] from versioned-matrix construction or a
/// delta application (both indicate a harness bug — the generated
/// batches are valid by construction).
pub fn run(cfg: &DeltaBenchConfig) -> Result<DeltaBenchReport, ForelemError> {
    assert!(cfg.rounds >= 1, "delta-bench needs at least one round");
    assert!(cfg.ops_per_batch >= 1, "delta-bench needs at least one op per batch");
    assert!(!cfg.matrices.is_empty(), "delta-bench needs at least one matrix");
    let engine = Engine::builder().arch(cfg.arch).profile(cfg.use_profile).archive(false).build();

    let mut per_matrix = Vec::with_capacity(cfg.matrices.len());
    let mut apply_lats: Vec<f64> = Vec::new();
    let mut repair_lats: Vec<f64> = Vec::new();
    let mut rebuild_lats: Vec<f64> = Vec::new();
    let mut stall_lats: Vec<f64> = Vec::new();
    let mut serves: u64 = 0;
    let mut bit_identical = true;

    for (slot, &si) in cfg.matrices.iter().enumerate() {
        let entry = &SUITE[si % SUITE.len()];
        let m = entry.build_scaled(cfg.arch.scale());
        let vm = engine.versioned(&m, &[Kernel::Spmv])?;
        let mut rng = Rng::new(cfg.seed ^ (0xDE17A * (slot as u64 + 1)));
        let x: Vec<f64> = (0..m.ncols).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect();

        let mut m_repair: Vec<f64> = Vec::with_capacity(cfg.rounds);
        let mut m_rebuild: Vec<f64> = Vec::with_capacity(cfg.rounds);
        let (mut repaired, mut rebuilt, mut replanned) = (0u64, 0u64, 0u64);

        let stop = AtomicBool::new(false);
        let shared_stalls: Mutex<Vec<f64>> = Mutex::new(Vec::new());
        let shared_serves = Mutex::new(0u64);
        std::thread::scope(|s| -> Result<(), ForelemError> {
            for _ in 0..cfg.serve_clients {
                let vm = &vm;
                let stop = &stop;
                let shared_stalls = &shared_stalls;
                let shared_serves = &shared_serves;
                let x = &x;
                let nrows = m.nrows;
                s.spawn(move || {
                    let mut y = vec![0.0; nrows];
                    let mut local = Vec::new();
                    let mut n = 0u64;
                    while !stop.load(Ordering::Relaxed) {
                        let t0 = Instant::now();
                        // The serve names its generation; a failure
                        // here would be a torn swap — surfaced by the
                        // bit-identity flag below going false.
                        if vm.spmv(x, &mut y).is_ok() {
                            local.push(t0.elapsed().as_secs_f64());
                            n += 1;
                        }
                    }
                    shared_stalls
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .extend(local);
                    *shared_serves.lock().unwrap_or_else(|p| p.into_inner()) += n;
                });
            }

            let result = (|| -> Result<(), ForelemError> {
                for _ in 0..cfg.rounds {
                    // Update a deterministic sample of live coordinates
                    // — update-only batches keep every format on the
                    // repair path, so repair and rebuild are timed on
                    // identical work.
                    let live = vm.snapshot();
                    let nnz = live.entries.len();
                    let k = cfg.ops_per_batch.min(nnz);
                    let mut batch = DeltaBatch::new(live.nrows, live.ncols);
                    let mut taken = std::collections::HashSet::new();
                    while taken.len() < k {
                        let i = (rng.gen_f64() * nnz as f64) as usize % nnz;
                        if taken.insert(i) {
                            let e = live.entries[i];
                            batch.update(
                                e.row as usize,
                                e.col as usize,
                                e.val + rng.gen_f64_range(0.25, 0.75),
                            );
                        }
                    }
                    let resolved = batch.resolved()?;
                    let post = batch.apply(&live)?;
                    // Direct repair and rebuild timings, outside the
                    // serving path (the live generation is untouched —
                    // repair is copy-on-write).
                    if let Some(exe) = vm.executable(Kernel::Spmv) {
                        let t0 = Instant::now();
                        let r = exe.storage().repair(&resolved);
                        if r.is_some() {
                            m_repair.push(t0.elapsed().as_secs_f64());
                        }
                        let t0 = Instant::now();
                        std::hint::black_box(concretize::prepare(exe.plan().exec, &post));
                        m_rebuild.push(t0.elapsed().as_secs_f64());
                    }
                    let t0 = Instant::now();
                    let report = vm.apply_delta(&batch)?;
                    apply_lats.push(t0.elapsed().as_secs_f64());
                    for (_, o) in &report.outcomes {
                        match o {
                            DeltaOutcome::Repaired => repaired += 1,
                            DeltaOutcome::Rebuilt => rebuilt += 1,
                            DeltaOutcome::Replanned => replanned += 1,
                        }
                    }
                }
                Ok(())
            })();
            stop.store(true, Ordering::Relaxed);
            result
        })?;
        stall_lats.extend(shared_stalls.lock().unwrap_or_else(|p| p.into_inner()).iter());
        serves += *shared_serves.lock().unwrap_or_else(|p| p.into_inner());

        let ok = final_generation_bit_identical(&vm, &x);
        bit_identical &= ok;
        repair_lats.extend(m_repair.iter());
        rebuild_lats.extend(m_rebuild.iter());
        per_matrix.push(MatrixDelta {
            name: entry.name.to_string(),
            repaired,
            rebuilt,
            replanned,
            repair: Latency::of(&mut m_repair),
            rebuild: Latency::of(&mut m_rebuild),
            bit_identical: ok,
        });
    }

    let repair = Latency::of(&mut repair_lats);
    let rebuild = Latency::of(&mut rebuild_lats);
    Ok(DeltaBenchReport {
        arch: cfg.arch,
        rounds: cfg.rounds,
        ops_per_batch: cfg.ops_per_batch,
        serve_clients: cfg.serve_clients,
        bit_identical,
        apply: Latency::of(&mut apply_lats),
        repair,
        rebuild,
        repair_over_rebuild_p50: repair.p50 / rebuild.p50.max(1e-12),
        swap_stall: Latency::of(&mut stall_lats),
        serves,
        per_matrix,
    })
}

/// The bit-identity post-check: the live generation must serve exactly
/// what a from-scratch prepare of its own reservoir serves.
fn final_generation_bit_identical(vm: &VersionedMatrix, x: &[f64]) -> bool {
    let exe = match vm.executable(Kernel::Spmv) {
        Some(e) => e,
        None => return false,
    };
    let live = vm.snapshot();
    let mut served = vec![0.0; live.nrows];
    let mut reference = vec![0.0; live.nrows];
    if vm.spmv(x, &mut served).is_err() {
        return false;
    }
    concretize::prepare(exe.plan().exec, &live).spmv(x, &mut reference);
    let same =
        served.iter().map(|v| v.to_bits()).eq(reference.iter().map(|v| v.to_bits()));
    if !same {
        eprintln!("delta-bench: BIT MISMATCH between the live generation and a fresh prepare");
    }
    same
}

/// Human-readable report for stdout.
pub fn report_text(r: &DeltaBenchReport) -> String {
    let us = 1e6;
    let mut out = String::new();
    out.push_str(&format!(
        "delta-bench [{}] — {} rounds x {} ops, {} serve clients, bit-identical: {}\n",
        r.arch.slug(),
        r.rounds,
        r.ops_per_batch,
        r.serve_clients,
        if r.bit_identical { "yes" } else { "NO (MISMATCH)" },
    ));
    out.push_str(&format!(
        "  repair:    p50 {:>8.1}us  p95 {:>8.1}us  p99 {:>8.1}us\n",
        r.repair.p50 * us,
        r.repair.p95 * us,
        r.repair.p99 * us,
    ));
    out.push_str(&format!(
        "  rebuild:   p50 {:>8.1}us  p95 {:>8.1}us  p99 {:>8.1}us   (repair/rebuild p50: {:.3})\n",
        r.rebuild.p50 * us,
        r.rebuild.p95 * us,
        r.rebuild.p99 * us,
        r.repair_over_rebuild_p50,
    ));
    out.push_str(&format!(
        "  apply:     p50 {:>8.1}us  p95 {:>8.1}us  p99 {:>8.1}us\n",
        r.apply.p50 * us,
        r.apply.p95 * us,
        r.apply.p99 * us,
    ));
    out.push_str(&format!(
        "  swap stall (serve-side, {} serves): p50 {:>8.1}us  p95 {:>8.1}us  p99 {:>8.1}us\n",
        r.serves,
        r.swap_stall.p50 * us,
        r.swap_stall.p95 * us,
        r.swap_stall.p99 * us,
    ));
    for pm in &r.per_matrix {
        out.push_str(&format!(
            "  {:<12} repaired {:>4}  rebuilt {:>4}  replanned {:>4}  repair-p50 {:>8.1}us  \
             rebuild-p50 {:>8.1}us  bit-identical: {}\n",
            pm.name,
            pm.repaired,
            pm.rebuilt,
            pm.replanned,
            pm.repair.p50 * us,
            pm.rebuild.p50 * us,
            if pm.bit_identical { "yes" } else { "NO" },
        ));
    }
    out
}

/// Render the report as the `BENCH_delta.json` document (same
/// hand-rolled style as the other bench artifacts — no serde in the
/// tree).
pub fn to_json(r: &DeltaBenchReport) -> String {
    let lat = |l: &Latency| {
        format!("{{\"p50\": {:e}, \"p95\": {:e}, \"p99\": {:e}}}", l.p50, l.p95, l.p99)
    };
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"forelem-delta-bench-v1\",\n");
    s.push_str(&format!("  \"arch\": \"{}\",\n", json_escape(r.arch.slug())));
    s.push_str(&format!("  \"rounds\": {},\n", r.rounds));
    s.push_str(&format!("  \"ops_per_batch\": {},\n", r.ops_per_batch));
    s.push_str(&format!("  \"serve_clients\": {},\n", r.serve_clients));
    s.push_str(&format!("  \"bit_identical\": {},\n", r.bit_identical));
    s.push_str(&format!("  \"apply_latency_s\": {},\n", lat(&r.apply)));
    s.push_str(&format!("  \"repair_latency_s\": {},\n", lat(&r.repair)));
    s.push_str(&format!("  \"rebuild_latency_s\": {},\n", lat(&r.rebuild)));
    s.push_str(&format!("  \"repair_over_rebuild_p50\": {:e},\n", r.repair_over_rebuild_p50));
    s.push_str(&format!("  \"swap_stall_s\": {},\n", lat(&r.swap_stall)));
    s.push_str(&format!("  \"serves\": {},\n", r.serves));
    let names: Vec<String> = r.per_matrix.iter().map(|p| p.name.clone()).collect();
    s.push_str(&format!("  \"matrices\": {},\n", json_str_array(&names)));
    s.push_str("  \"per_matrix\": [\n");
    for (i, pm) in r.per_matrix.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": \"{}\", \"repaired\": {}, \"rebuilt\": {}, \"replanned\": {}, \
             \"repair_s\": {}, \"rebuild_s\": {}, \"bit_identical\": {}}}{}\n",
            json_escape(&pm.name),
            pm.repaired,
            pm.rebuilt,
            pm.replanned,
            lat(&pm.repair),
            lat(&pm.rebuild),
            pm.bit_identical,
            if i + 1 == r.per_matrix.len() { "" } else { "," },
        ));
    }
    s.push_str("  ]\n");
    s.push_str("}\n");
    s
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn tiny() -> DeltaBenchConfig {
        DeltaBenchConfig {
            arch: Arch::HostSmall,
            matrices: vec![0, 2],
            rounds: 6,
            ops_per_batch: 4,
            serve_clients: 2,
            use_profile: false,
            seed: 11,
        }
    }

    #[test]
    fn delta_bench_runs_bit_identical_and_counts_every_round() {
        let cfg = tiny();
        let r = run(&cfg).expect("delta-bench run");
        assert!(r.bit_identical, "final generations must serve a fresh prepare's exact bits");
        for pm in &r.per_matrix {
            assert_eq!(
                pm.repaired + pm.rebuilt + pm.replanned,
                cfg.rounds as u64,
                "{}: every round takes exactly one route",
                pm.name
            );
            assert!(pm.bit_identical);
        }
        assert!(r.serves > 0, "serve threads must have gotten through the swaps");
        assert!(r.rebuild.p50 >= 0.0 && r.apply.p50 >= 0.0);
    }

    #[test]
    fn delta_json_has_the_guarded_fields() {
        let cfg = tiny();
        let r = run(&cfg).expect("delta-bench run");
        let j = to_json(&r);
        assert!(j.contains("forelem-delta-bench-v1"));
        assert!(j.contains("\"bit_identical\": true"));
        assert!(j.contains("\"repair_latency_s\": "));
        assert!(j.contains("\"rebuild_latency_s\": "));
        assert!(j.contains("\"swap_stall_s\": "));
        assert!(j.contains("\"repair_over_rebuild_p50\": "));
        let txt = report_text(&r);
        assert!(txt.contains("swap stall"));
        assert!(txt.contains("repair"));
    }
}
