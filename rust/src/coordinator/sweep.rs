//! The benchmark sweep: produces `Measurements` tables for library
//! routines and generated variants over the 20-matrix suite.

use crate::baselines::{Kernel, LibRoutine, ALL_ROUTINES};
use crate::bench::harness::{black_box, time_fn, BenchConfig};
use crate::concretize;
use crate::matrix::suite::{SuiteEntry, SUITE};
use crate::matrix::TriMat;
use crate::runtime::XlaBackend;
use crate::search::coverage::Measurements;
use crate::search::tree;
use crate::storage::{Ell, EllOrder};
use crate::util::rng::Rng;

/// An evaluation "architecture" (DESIGN.md §5 substitution for the
/// paper's Xeon 5150 / Xeon E5 pair).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    /// Suite at scale 1.0, native backend only (paper: Xeon 5150).
    HostSmall,
    /// Suite at scale 2.0 (larger working set) + the XLA-PJRT AOT
    /// backend in the generated pool (paper: Xeon E5).
    HostLarge,
}

impl Arch {
    pub fn name(&self) -> &'static str {
        match self {
            Arch::HostSmall => "host-small (Xeon 5150 stand-in)",
            Arch::HostLarge => "host-large (Xeon E5 stand-in)",
        }
    }

    pub fn scale(&self) -> f64 {
        match self {
            Arch::HostSmall => 1.0,
            Arch::HostLarge => 2.0,
        }
    }

    pub fn uses_xla(&self) -> bool {
        matches!(self, Arch::HostLarge)
    }
}

#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub bench: BenchConfig,
    /// Dense-operand column count for SpMM (paper: 100).
    pub spmm_k: usize,
    /// Subset of suite matrices to run (indices into SUITE); all if None.
    pub matrices: Option<Vec<usize>>,
    /// Validate every routine against the oracle before timing.
    pub validate: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig { bench: BenchConfig::from_env(), spmm_k: 100, matrices: None, validate: true }
    }
}

impl SweepConfig {
    pub fn quick() -> Self {
        SweepConfig {
            bench: BenchConfig::quick(),
            spmm_k: 16,
            matrices: Some(vec![0, 2, 7]),
            validate: true,
        }
    }
}

/// Result of a sweep: library and generated-variant timing tables over
/// the same matrices (times are per-invocation medians, seconds).
pub struct SweepResult {
    pub kernel: Kernel,
    pub arch: Arch,
    pub libs: Measurements,
    pub gens: Measurements,
    /// Derivations for the generated routines, aligned with `gens.routines`.
    pub derivations: Vec<String>,
}

impl SweepResult {
    /// Best generated time per matrix.
    pub fn best_gen(&self) -> Vec<f64> {
        self.gens.best_per_matrix(None)
    }

    /// Union table (libs + gens) for coverage analyses.
    pub fn combined(&self) -> Measurements {
        let mut all = self.libs.clone();
        all.extend(&self.gens);
        all
    }

    /// Indices of the library routines inside `combined()`.
    pub fn lib_indices(&self) -> Vec<usize> {
        (0..self.libs.routines.len()).collect()
    }

    /// Indices of the generated routines inside `combined()`.
    pub fn gen_indices(&self) -> Vec<usize> {
        (self.libs.routines.len()..self.libs.routines.len() + self.gens.routines.len()).collect()
    }
}

fn workload_x(ncols: usize) -> Vec<f64> {
    let mut rng = Rng::new(0xC0FFEE);
    (0..ncols).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect()
}

fn workload_b(ncols: usize, k: usize) -> Vec<f64> {
    let mut rng = Rng::new(0xBEEF);
    (0..ncols * k).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect()
}

fn max_abs_rel_err(got: &[f64], want: &[f64]) -> f64 {
    got.iter()
        .zip(want)
        .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
        .fold(0.0, f64::max)
}

/// Run the full sweep for one kernel on one architecture.
pub fn run(kernel: Kernel, arch: Arch, cfg: &SweepConfig, xla: Option<&XlaBackend>) -> SweepResult {
    let mat_idx: Vec<usize> =
        cfg.matrices.clone().unwrap_or_else(|| (0..SUITE.len()).collect());
    let entries: Vec<&SuiteEntry> = mat_idx.iter().map(|&i| &SUITE[i]).collect();
    let mat_names: Vec<String> = entries.iter().map(|e| e.name.to_string()).collect();

    // Build matrices in parallel (TrSv uses the strictly-lower part).
    let mats: Vec<TriMat> = crate::util::pool::parallel_map(
        entries.len(),
        crate::util::pool::default_workers(),
        |i| {
            let m = entries[i].build_scaled(arch.scale());
            if kernel == Kernel::Trsv {
                m.strictly_lower()
            } else {
                m
            }
        },
    );

    // Routine sets.
    let lib_routines: Vec<LibRoutine> =
        ALL_ROUTINES.iter().copied().filter(|r| r.supports(kernel)).collect();
    let tree = tree::enumerate(kernel);

    let mut libs = Measurements::new(
        lib_routines.iter().map(|r| r.label()).collect(),
        mat_names.clone(),
    );
    let mut gen_names: Vec<String> =
        tree.variants.iter().map(|v| format!("{} {}", v.id, v.name())).collect();
    let mut derivations: Vec<String> = tree.variants.iter().map(|v| v.derivation.clone()).collect();
    let use_xla = arch.uses_xla() && xla.is_some();
    if use_xla && kernel != Kernel::Trsv {
        gen_names.push("xla ELL(AOT)/PJRT".to_string());
        derivations.push("orthogonalize(row) → materialize(dep) → split → nstar(padded) → AOT(XLA)".into());
    }
    let mut gens = Measurements::new(gen_names, mat_names.clone());

    for (mi, m) in mats.iter().enumerate() {
        // Workloads + oracle.
        let x = workload_x(m.ncols);
        let b = workload_b(m.ncols, cfg.spmm_k);
        let (want_y, want_c, want_x);
        match kernel {
            Kernel::Spmv => {
                want_y = m.spmv_ref(&x);
                want_c = Vec::new();
                want_x = Vec::new();
            }
            Kernel::Spmm => {
                want_c = m.spmm_ref(&b, cfg.spmm_k);
                want_y = Vec::new();
                want_x = Vec::new();
            }
            Kernel::Trsv => {
                want_x = m.trsv_unit_lower_ref(&x);
                want_y = Vec::new();
                want_c = Vec::new();
            }
        }

        // --- library routines ---
        for (ri, r) in lib_routines.iter().enumerate() {
            let inst = r.prepare(m);
            let t = match kernel {
                Kernel::Spmv => {
                    let mut y = vec![0.0; m.nrows];
                    if cfg.validate {
                        inst.spmv(&x, &mut y);
                        assert!(max_abs_rel_err(&y, &want_y) < 1e-9, "{} wrong on {}", r.label(), mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        inst.spmv(&x, &mut y);
                        black_box(&y);
                    })
                }
                Kernel::Spmm => {
                    let mut c = vec![0.0; m.nrows * cfg.spmm_k];
                    if cfg.validate {
                        inst.spmm(&b, cfg.spmm_k, &mut c);
                        assert!(max_abs_rel_err(&c, &want_c) < 1e-9, "{} wrong on {}", r.label(), mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        inst.spmm(&b, cfg.spmm_k, &mut c);
                        black_box(&c);
                    })
                }
                Kernel::Trsv => {
                    let mut xs = vec![0.0; m.nrows];
                    if cfg.validate {
                        inst.trsv(&x, &mut xs);
                        assert!(max_abs_rel_err(&xs, &want_x) < 1e-7, "{} wrong on {}", r.label(), mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        inst.trsv(&x, &mut xs);
                        black_box(&xs);
                    })
                }
            };
            libs.set(ri, mi, t.median);
        }

        // --- generated variants ---
        for (vi, v) in tree.variants.iter().enumerate() {
            let p = concretize::prepare(v.plan, m);
            let t = match kernel {
                Kernel::Spmv => {
                    let mut y = vec![0.0; m.nrows];
                    if cfg.validate {
                        p.spmv(&x, &mut y);
                        assert!(max_abs_rel_err(&y, &want_y) < 1e-9, "{} wrong on {}", v.id, mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        p.spmv(&x, &mut y);
                        black_box(&y);
                    })
                }
                Kernel::Spmm => {
                    let mut c = vec![0.0; m.nrows * cfg.spmm_k];
                    if cfg.validate {
                        p.spmm(&b, cfg.spmm_k, &mut c);
                        assert!(max_abs_rel_err(&c, &want_c) < 1e-9, "{} wrong on {}", v.id, mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        p.spmm(&b, cfg.spmm_k, &mut c);
                        black_box(&c);
                    })
                }
                Kernel::Trsv => {
                    let mut xs = vec![0.0; m.nrows];
                    if cfg.validate {
                        p.trsv(&x, &mut xs);
                        assert!(max_abs_rel_err(&xs, &want_x) < 1e-7, "{} wrong on {}", v.id, mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        p.trsv(&x, &mut xs);
                        black_box(&xs);
                    })
                }
            };
            gens.set(vi, mi, t.median);
        }

        // --- XLA AOT routine (ELL path with PJRT dispatch) ---
        if use_xla && kernel != Kernel::Trsv {
            let backend = xla.unwrap();
            let ell = Ell::from_tuples(m, EllOrder::ColMajor);
            let n = m.nrows.max(m.ncols);
            let has_bucket = backend.bucket_for(kernel, n, ell.k, cfg.spmm_k).is_some();
            let vi = tree.variants.len();
            let t = if has_bucket {
                match kernel {
                    Kernel::Spmv => {
                        if cfg.validate {
                            let y = backend.spmv(&ell, &x).expect("xla spmv");
                            assert!(
                                max_abs_rel_err(&y, &want_y) < 5e-3,
                                "xla spmv wrong on {}",
                                mat_names[mi]
                            );
                        }
                        time_fn(&cfg.bench, || {
                            let y = backend.spmv(&ell, &x).expect("xla spmv");
                            black_box(&y);
                        })
                    }
                    Kernel::Spmm => {
                        if cfg.validate {
                            let c = backend.spmm(&ell, &b, cfg.spmm_k).expect("xla spmm");
                            assert!(
                                max_abs_rel_err(&c, &want_c) < 2e-2,
                                "xla spmm wrong on {}",
                                mat_names[mi]
                            );
                        }
                        time_fn(&cfg.bench, || {
                            let c = backend.spmm(&ell, &b, cfg.spmm_k).expect("xla spmm");
                            black_box(&c);
                        })
                    }
                    Kernel::Trsv => unreachable!(),
                }
            } else {
                // Coordinator dispatch falls back to the native ELL path.
                let mut y = vec![0.0; m.nrows];
                let mut c = vec![0.0; m.nrows * cfg.spmm_k];
                match kernel {
                    Kernel::Spmv => time_fn(&cfg.bench, || {
                        crate::kernels::spmv::ell_rowwise(&ell, &x, &mut y);
                        black_box(&y);
                    }),
                    Kernel::Spmm => time_fn(&cfg.bench, || {
                        crate::kernels::spmm::ell_rowwise(&ell, &b, cfg.spmm_k, &mut c);
                        black_box(&c);
                    }),
                    Kernel::Trsv => unreachable!(),
                }
            };
            gens.set(vi, mi, t.median);
        }
    }

    libs.validate().expect("library table incomplete");
    gens.validate().expect("generated table incomplete");
    SweepResult { kernel, arch, libs, gens, derivations }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_spmv_native() {
        let cfg = SweepConfig::quick();
        let r = run(Kernel::Spmv, Arch::HostSmall, &cfg, None);
        assert_eq!(r.libs.routines.len(), 7);
        assert!(r.gens.routines.len() >= 15);
        assert_eq!(r.libs.matrices.len(), 3);
        // the generated pool must beat or match the libraries somewhere
        let best_gen = r.best_gen();
        let best_lib = r.libs.best_per_matrix(None);
        let wins = best_gen.iter().zip(&best_lib).filter(|(g, l)| g <= l).count();
        assert!(wins >= 1, "generated variants never competitive: {best_gen:?} vs {best_lib:?}");
    }

    #[test]
    fn quick_sweep_trsv_has_restricted_pools() {
        let cfg = SweepConfig::quick();
        let r = run(Kernel::Trsv, Arch::HostSmall, &cfg, None);
        assert_eq!(r.libs.routines.len(), 4); // MTL4 + SL++ CRS/CCS
        assert!(!r.gens.routines.is_empty());
    }
}
