//! The benchmark sweep — stage 2+3 of the predict→measure planner
//! pipeline (see `search::plan`): produce `Measurements` tables for
//! library routines and generated plans over the 20-matrix suite.
//!
//! For every matrix the sweep first *predicts* each enumerated plan's
//! time from the matrix's memoized [`MatrixStats`] (`search::cost`),
//! then *measures* only the top-K predicted plans (`--shortlist K`).
//! `K = 0` (the default) measures exhaustively, reproducing the paper's
//! tables exactly. Shortlisted sweeps fill the unmeasured cells with
//! calibrated predictions (predicted seconds × the median
//! measured/predicted ratio of the shortlist) so downstream coverage /
//! selection analyses still see a full table; `SweepResult::measured`
//! records which cells are real. Predicted-vs-measured top-1 agreement
//! is reported through `bench_json` so the cost model stays auditable
//! across PRs.

use crate::baselines::{Kernel, LibRoutine, ALL_ROUTINES};
use crate::bench::harness::{black_box, time_fn, BenchConfig};
use crate::concretize;
use crate::matrix::suite::{SuiteEntry, SUITE};
use crate::matrix::{MatrixStats, TriMat};
use crate::runtime::XlaBackend;
use crate::search::calibrate::{self, Sample};
use crate::search::cost::{self, CostParams, FEATURE_NAMES};
use crate::search::coverage::Measurements;
use crate::search::plan::{Plan, PlanSpace};
use crate::search::select;
use crate::storage::{Ell, EllOrder};
use crate::util::rng::Rng;

/// Default column-band width (in doubles) for tiled schedules: 4096
/// doubles of `x` ≈ 32 KiB, comfortably L2-resident next to the
/// streamed row data.
pub const DEFAULT_X_BLOCK: usize = 4096;

/// An evaluation "architecture" (DESIGN.md §5 substitution for the
/// paper's Xeon 5150 / Xeon E5 pair).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    /// Suite at scale 1.0, native backend only (paper: Xeon 5150).
    HostSmall,
    /// Suite at scale 2.0 (larger working set) + the XLA-PJRT AOT
    /// backend in the generated pool (paper: Xeon E5).
    HostLarge,
}

impl Arch {
    pub fn name(&self) -> &'static str {
        match self {
            Arch::HostSmall => "host-small (Xeon 5150 stand-in)",
            Arch::HostLarge => "host-large (Xeon E5 stand-in)",
        }
    }

    /// Short stable slug — the tuning-profile file stem
    /// (`target/tuning/<slug>.profile`).
    pub fn slug(&self) -> &'static str {
        match self {
            Arch::HostSmall => "host-small",
            Arch::HostLarge => "host-large",
        }
    }

    pub fn scale(&self) -> f64 {
        match self {
            Arch::HostSmall => 1.0,
            Arch::HostLarge => 2.0,
        }
    }

    pub fn uses_xla(&self) -> bool {
        matches!(self, Arch::HostLarge)
    }

    /// Cost-model parameters of this architecture. The socket count is
    /// a structural knob taken from the machine actually running the
    /// sweep (`runtime::topology`), not from the stand-in: it shapes
    /// the `remote_bytes` feature, and charging phantom cross-socket
    /// traffic on a single-node box would skew every parallel ranking.
    pub fn cost_params(&self) -> CostParams {
        let sockets = crate::runtime::topology::sockets();
        match self {
            Arch::HostSmall => CostParams::host_small().with_sockets(sockets),
            Arch::HostLarge => {
                CostParams::host_large(crate::util::pool::default_workers().clamp(2, 8))
                    .with_sockets(sockets)
            }
        }
    }

    /// Plan space this architecture explores when the sweep opts in
    /// (`SweepConfig::use_schedules`). `HostSmall` stays serial-only so
    /// the paper's single-core tables remain reproducible; `HostLarge`
    /// (the "modern machine" stand-in) adds the parallel and
    /// cache-blocked schedules.
    pub fn plan_space(&self) -> PlanSpace {
        match self {
            Arch::HostSmall => PlanSpace::serial_only(),
            Arch::HostLarge => {
                let threads = crate::util::pool::default_workers().clamp(2, 8);
                PlanSpace::host(threads, DEFAULT_X_BLOCK)
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub bench: BenchConfig,
    /// Dense-operand column count for SpMM (paper: 100).
    pub spmm_k: usize,
    /// Subset of suite matrices to run (indices into SUITE); all if None.
    pub matrices: Option<Vec<usize>>,
    /// Validate every routine against the oracle before timing.
    pub validate: bool,
    /// Opt in to the schedule axis: cross the generated pool with the
    /// architecture's `Arch::plan_space()`. Off by default so the
    /// paper's single-core tables stay reproducible.
    pub use_schedules: bool,
    /// Measure only the top-K predicted plans per matrix; 0 measures
    /// everything (exhaustive, paper protocol).
    pub shortlist: usize,
    /// Auto-load the fitted tuning profile for the architecture
    /// (`target/tuning/<arch>.profile`, written by `forelem
    /// calibrate`) and rank on its weights instead of the seed. Off by
    /// default so library users and tests stay hermetic; the CLI turns
    /// it on (`--no-profile` opts back out).
    pub use_profile: bool,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            bench: BenchConfig::from_env(),
            spmm_k: 100,
            matrices: None,
            validate: true,
            use_schedules: false,
            shortlist: 0,
            use_profile: false,
        }
    }
}

impl SweepConfig {
    pub fn quick() -> Self {
        SweepConfig {
            bench: BenchConfig::quick(),
            spmm_k: 16,
            matrices: Some(vec![0, 2, 7]),
            validate: true,
            use_schedules: false,
            shortlist: 0,
            use_profile: false,
        }
    }

    /// `quick()` with the schedule axis enabled.
    pub fn quick_scheduled() -> Self {
        SweepConfig { use_schedules: true, ..SweepConfig::quick() }
    }
}

/// Result of a sweep: library and generated-plan timing tables over the
/// same matrices (times are per-invocation medians, seconds), plus the
/// planner's inputs and outputs — the plans, per-matrix statistics,
/// predicted times and the measured mask.
pub struct SweepResult {
    pub kernel: Kernel,
    pub arch: Arch,
    pub libs: Measurements,
    pub gens: Measurements,
    /// Derivations for the generated routines, aligned with `gens.routines`.
    pub derivations: Vec<String>,
    /// The enumerated plans; `gens` rows `0..plans.len()` are theirs
    /// (any extra row is the XLA backend).
    pub plans: Vec<Plan>,
    /// Memoized per-matrix statistics, aligned with `gens.matrices`.
    pub stats: Vec<MatrixStats>,
    /// Predicted seconds, `predicted[plan][matrix]`.
    pub predicted: Vec<Vec<f64>>,
    /// Which generated cells were actually measured (`[plan][matrix]`);
    /// the rest of `gens` holds calibrated predictions.
    pub measured: Vec<Vec<bool>>,
    /// The cost parameters the sweep ranked on (seed or loaded
    /// profile).
    pub params: CostParams,
    /// Whether `params` came from a fitted tuning profile on disk.
    pub profile_loaded: bool,
    /// One calibration sample per measured generated cell — the
    /// plan's feature vector on that matrix plus measured/predicted
    /// seconds, in measurement order. The raw material of
    /// `search::calibrate`.
    pub samples: Vec<Sample>,
}

impl SweepResult {
    /// Best generated time per matrix.
    pub fn best_gen(&self) -> Vec<f64> {
        self.gens.best_per_matrix(None)
    }

    /// Union table (libs + gens) for coverage analyses.
    pub fn combined(&self) -> Measurements {
        let mut all = self.libs.clone();
        all.extend(&self.gens);
        all
    }

    /// Indices of the library routines inside `combined()`.
    pub fn lib_indices(&self) -> Vec<usize> {
        (0..self.libs.routines.len()).collect()
    }

    /// Indices of the generated routines inside `combined()`.
    pub fn gen_indices(&self) -> Vec<usize> {
        (self.libs.routines.len()..self.libs.routines.len() + self.gens.routines.len()).collect()
    }

    /// Per-matrix best measured (layout, traversal, schedule) triples.
    pub fn best_triples(&self) -> Vec<select::BestTriple> {
        select::best_triples(&self.gens, &self.plans)
    }

    /// The plan the cost model ranks first on matrix `mi`.
    pub fn predicted_best(&self, mi: usize) -> usize {
        (0..self.plans.len())
            .min_by(|&a, &b| {
                self.predicted[a][mi]
                    .partial_cmp(&self.predicted[b][mi])
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("non-empty plan pool")
    }

    /// The measured-best plan on matrix `mi` (among measured cells).
    pub fn measured_best(&self, mi: usize) -> usize {
        (0..self.plans.len())
            .filter(|&pi| self.measured[pi][mi])
            .min_by(|&a, &b| {
                self.gens.times[a][mi]
                    .partial_cmp(&self.gens.times[b][mi])
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("at least one measured plan per matrix")
    }

    /// Predicted-vs-measured top-1 agreement: on how many matrices did
    /// the cost model's first pick win the measurements? Returns
    /// `(matches, matrices)`.
    pub fn rank_agreement(&self) -> (usize, usize) {
        let n = self.gens.matrices.len();
        let matches = (0..n).filter(|&mi| self.predicted_best(mi) == self.measured_best(mi)).count();
        (matches, n)
    }
}

fn workload_x(ncols: usize) -> Vec<f64> {
    let mut rng = Rng::new(0xC0FFEE);
    (0..ncols).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect()
}

fn workload_b(ncols: usize, k: usize) -> Vec<f64> {
    let mut rng = Rng::new(0xBEEF);
    (0..ncols * k).map(|_| rng.gen_f64_range(-1.0, 1.0)).collect()
}

fn max_abs_rel_err(got: &[f64], want: &[f64]) -> f64 {
    got.iter()
        .zip(want)
        .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
        .fold(0.0, f64::max)
}

/// Run the full sweep for one kernel on one architecture.
pub fn run(kernel: Kernel, arch: Arch, cfg: &SweepConfig, xla: Option<&XlaBackend>) -> SweepResult {
    let mat_idx: Vec<usize> =
        cfg.matrices.clone().unwrap_or_else(|| (0..SUITE.len()).collect());
    let entries: Vec<&SuiteEntry> = mat_idx.iter().map(|&i| &SUITE[i]).collect();
    let mat_names: Vec<String> = entries.iter().map(|e| e.name.to_string()).collect();

    // Build matrices in parallel (TrSv uses the strictly-lower part).
    let mats: Vec<TriMat> = crate::util::pool::parallel_map(
        entries.len(),
        crate::util::pool::default_workers(),
        |i| {
            let m = entries[i].build_scaled(arch.scale());
            if kernel == Kernel::Trsv {
                m.strictly_lower()
            } else {
                m
            }
        },
    );

    // Stage 1 — enumerate through the engine's planner seam: one
    // cost-ranked plan space serves both the serial-only (paper
    // protocol) and scheduled sweeps, with the same profile-loading
    // behavior as `Engine::compile` (the sweep is the exhaustive
    // measure path of the very pipeline the engine serves).
    let pool = crate::engine::planned_pool(
        kernel,
        arch,
        cfg.use_schedules,
        cfg.spmm_k,
        cfg.use_profile,
        true,
    );
    let space = pool.space;
    let profile_loaded = pool.profile_loaded;
    let plans = pool.plans;

    let lib_routines: Vec<LibRoutine> =
        ALL_ROUTINES.iter().copied().filter(|r| r.supports(kernel)).collect();
    let mut libs = Measurements::new(
        lib_routines.iter().map(|r| r.label()).collect(),
        mat_names.clone(),
    );
    let mut gen_names: Vec<String> =
        plans.iter().map(|p| format!("{} {}", p.id, p.name())).collect();
    let mut derivations: Vec<String> = plans.iter().map(|p| p.derivation.clone()).collect();
    let use_xla = arch.uses_xla() && xla.is_some();
    if use_xla && kernel != Kernel::Trsv {
        gen_names.push("xla ELL(AOT)/PJRT".to_string());
        derivations.push("orthogonalize(row) → materialize(dep) → split → nstar(padded) → AOT(XLA)".into());
    }
    let mut gens = Measurements::new(gen_names, mat_names.clone());
    let mut stats_per_mat: Vec<MatrixStats> = Vec::with_capacity(mats.len());
    let mut predicted: Vec<Vec<f64>> = vec![vec![f64::NAN; mats.len()]; plans.len()];
    let mut measured: Vec<Vec<bool>> = vec![vec![false; mats.len()]; plans.len()];
    let mut samples: Vec<Sample> = Vec::new();
    let execs: Vec<concretize::Plan> = plans.iter().map(|p| p.exec).collect();

    for (mi, m) in mats.iter().enumerate() {
        // Stage 2 — predict: memoized statistics (TrSv ranks on the
        // lowered triangle, which the memo does not cover) and the
        // per-matrix cost ranking.
        let stats = if kernel == Kernel::Trsv {
            MatrixStats::of(m)
        } else {
            entries[mi].stats_scaled(arch.scale())
        };
        stats_per_mat.push(stats);
        // Extract each plan's feature vector once: the prediction is
        // its dot product with the ranked weights (identical to
        // `cost::predict` by construction), and the same vector is
        // archived with the cell's measurement below — so the sample
        // features structurally match what ranked the cell.
        let fvs: Vec<cost::FeatureVec> = plans
            .iter()
            .map(|p| cost::features(kernel, cfg.spmm_k, &p.exec, &stats, &space.params))
            .collect();
        for (pi, fv) in fvs.iter().enumerate() {
            predicted[pi][mi] = fv.dot(&space.params.weights).max(1e-12);
        }
        // Shortlist order: ascending predicted time, index tie-break —
        // the same ordering contract as `cost::rank_execs`, computed
        // from the column just filled instead of re-running the model.
        let mut order: Vec<usize> = (0..plans.len()).collect();
        order.sort_by(|&a, &b| {
            predicted[a][mi]
                .partial_cmp(&predicted[b][mi])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let k_short =
            if cfg.shortlist == 0 { plans.len() } else { cfg.shortlist.min(plans.len()) };
        let shortlist: Vec<usize> = order[..k_short].to_vec();
        for &pi in &shortlist {
            measured[pi][mi] = true;
        }

        // Workloads + oracle.
        let x = workload_x(m.ncols);
        let b = workload_b(m.ncols, cfg.spmm_k);
        let (want_y, want_c, want_x);
        match kernel {
            Kernel::Spmv => {
                want_y = m.spmv_ref(&x);
                want_c = Vec::new();
                want_x = Vec::new();
            }
            Kernel::Spmm => {
                want_c = m.spmm_ref(&b, cfg.spmm_k);
                want_y = Vec::new();
                want_x = Vec::new();
            }
            Kernel::Trsv => {
                want_x = m.trsv_unit_lower_ref(&x);
                want_y = Vec::new();
                want_c = Vec::new();
            }
        }

        // --- library routines ---
        for (ri, r) in lib_routines.iter().enumerate() {
            let inst = r.prepare(m);
            let t = match kernel {
                Kernel::Spmv => {
                    let mut y = vec![0.0; m.nrows];
                    if cfg.validate {
                        inst.spmv(&x, &mut y);
                        assert!(max_abs_rel_err(&y, &want_y) < 1e-9, "{} wrong on {}", r.label(), mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        inst.spmv(&x, &mut y);
                        black_box(&y);
                    })
                }
                Kernel::Spmm => {
                    let mut c = vec![0.0; m.nrows * cfg.spmm_k];
                    if cfg.validate {
                        inst.spmm(&b, cfg.spmm_k, &mut c);
                        assert!(max_abs_rel_err(&c, &want_c) < 1e-9, "{} wrong on {}", r.label(), mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        inst.spmm(&b, cfg.spmm_k, &mut c);
                        black_box(&c);
                    })
                }
                Kernel::Trsv => {
                    let mut xs = vec![0.0; m.nrows];
                    if cfg.validate {
                        inst.trsv(&x, &mut xs);
                        assert!(max_abs_rel_err(&xs, &want_x) < 1e-7, "{} wrong on {}", r.label(), mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        inst.trsv(&x, &mut xs);
                        black_box(&xs);
                    })
                }
            };
            libs.set(ri, mi, t.median);
        }

        // Stage 3 — measure the shortlist. Storage for the whole
        // shortlist is assembled in parallel through the plan-keyed
        // cache (`prepare_many` builds each distinct layout once and
        // Arc-shares it across schedule/traversal variants); timing
        // itself stays single-threaded per the paper protocol.
        let shortlist_execs: Vec<concretize::Plan> =
            shortlist.iter().map(|&pi| execs[pi]).collect();
        let prepared =
            concretize::prepare_many(&shortlist_execs, m, crate::util::pool::default_workers());
        // Schedule auxiliaries (band splits, TrSv level sets) are part
        // of the generated data structure: build them here — in
        // parallel, like the storage itself — not inside the timed
        // region.
        crate::util::pool::parallel_map(
            prepared.len(),
            crate::util::pool::default_workers(),
            |i| match kernel {
                Kernel::Spmv => prepared[i].ensure_bands(),
                Kernel::Trsv => prepared[i].ensure_levels(),
                Kernel::Spmm => {}
            },
        );
        for (si, &pi) in shortlist.iter().enumerate() {
            let p = &prepared[si];
            let id = &plans[pi].id;
            let t = match kernel {
                Kernel::Spmv => {
                    let mut y = vec![0.0; m.nrows];
                    if cfg.validate {
                        p.spmv(&x, &mut y);
                        assert!(max_abs_rel_err(&y, &want_y) < 1e-9, "{} wrong on {}", id, mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        p.spmv(&x, &mut y);
                        black_box(&y);
                    })
                }
                Kernel::Spmm => {
                    let mut c = vec![0.0; m.nrows * cfg.spmm_k];
                    if cfg.validate {
                        p.spmm(&b, cfg.spmm_k, &mut c);
                        assert!(max_abs_rel_err(&c, &want_c) < 1e-9, "{} wrong on {}", id, mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        p.spmm(&b, cfg.spmm_k, &mut c);
                        black_box(&c);
                    })
                }
                Kernel::Trsv => {
                    let mut xs = vec![0.0; m.nrows];
                    if cfg.validate {
                        p.trsv(&x, &mut xs);
                        assert!(max_abs_rel_err(&xs, &want_x) < 1e-7, "{} wrong on {}", id, mat_names[mi]);
                    }
                    time_fn(&cfg.bench, || {
                        p.trsv(&x, &mut xs);
                        black_box(&xs);
                    })
                }
            };
            gens.set(pi, mi, t.median);
            // Archive the calibration sample: the feature vector this
            // cell was ranked with, plus what the clock said.
            samples.push(Sample {
                matrix: mat_names[mi].clone(),
                plan_id: plans[pi].id.clone(),
                features: fvs[pi].0,
                measured_secs: t.median,
                predicted_secs: predicted[pi][mi],
            });
        }

        // Fill the unmeasured cells with calibrated predictions so the
        // coverage / selection analyses see a full table (the measured
        // mask records which cells are real).
        if k_short < plans.len() {
            let mut ratios: Vec<f64> = shortlist
                .iter()
                .map(|&pi| gens.times[pi][mi] / predicted[pi][mi].max(1e-12))
                .collect();
            ratios.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
            let alpha = crate::util::stats::percentile_sorted(&ratios, 50.0).max(1e-12);
            for pi in 0..plans.len() {
                if !measured[pi][mi] {
                    gens.set(pi, mi, (alpha * predicted[pi][mi]).max(1e-12));
                }
            }
        }

        // --- XLA AOT routine (ELL path with PJRT dispatch) ---
        if use_xla && kernel != Kernel::Trsv {
            let backend = xla.unwrap();
            let ell = Ell::from_tuples(m, EllOrder::ColMajor);
            let n = m.nrows.max(m.ncols);
            let has_bucket = backend.bucket_for(kernel, n, ell.k, cfg.spmm_k).is_some();
            let vi = plans.len();
            let t = if has_bucket {
                match kernel {
                    Kernel::Spmv => {
                        if cfg.validate {
                            let y = backend.spmv(&ell, &x).expect("xla spmv");
                            assert!(
                                max_abs_rel_err(&y, &want_y) < 5e-3,
                                "xla spmv wrong on {}",
                                mat_names[mi]
                            );
                        }
                        time_fn(&cfg.bench, || {
                            let y = backend.spmv(&ell, &x).expect("xla spmv");
                            black_box(&y);
                        })
                    }
                    Kernel::Spmm => {
                        if cfg.validate {
                            let c = backend.spmm(&ell, &b, cfg.spmm_k).expect("xla spmm");
                            assert!(
                                max_abs_rel_err(&c, &want_c) < 2e-2,
                                "xla spmm wrong on {}",
                                mat_names[mi]
                            );
                        }
                        time_fn(&cfg.bench, || {
                            let c = backend.spmm(&ell, &b, cfg.spmm_k).expect("xla spmm");
                            black_box(&c);
                        })
                    }
                    Kernel::Trsv => unreachable!(),
                }
            } else {
                // Coordinator dispatch falls back to the native ELL path.
                let mut y = vec![0.0; m.nrows];
                let mut c = vec![0.0; m.nrows * cfg.spmm_k];
                match kernel {
                    Kernel::Spmv => time_fn(&cfg.bench, || {
                        crate::kernels::spmv::ell_rowwise(&ell, &x, &mut y);
                        black_box(&y);
                    }),
                    Kernel::Spmm => time_fn(&cfg.bench, || {
                        crate::kernels::spmm::ell_rowwise(&ell, &b, cfg.spmm_k, &mut c);
                        black_box(&c);
                    }),
                    Kernel::Trsv => unreachable!(),
                }
            };
            gens.set(vi, mi, t.median);
        }
    }

    libs.validate().expect("library table incomplete");
    gens.validate().expect("generated table incomplete");
    SweepResult {
        kernel,
        arch,
        libs,
        gens,
        derivations,
        plans,
        stats: stats_per_mat,
        predicted,
        measured,
        params: space.params,
        profile_loaded,
        samples,
    }
}

pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

pub(crate) fn json_str_array(items: &[String]) -> String {
    let quoted: Vec<String> = items.iter().map(|s| format!("\"{}\"", json_escape(s))).collect();
    format!("[{}]", quoted.join(", "))
}

pub(crate) fn json_num_array(items: &[f64]) -> String {
    let nums: Vec<String> = items.iter().map(|v| format!("{v:e}")).collect();
    format!("[{}]", nums.join(", "))
}

/// Measure and render the body of `bench_json`'s `pool` section: crew
/// counters, a warm-spawn probe, crew-vs-spawning dispatch medians on
/// a small chunked reduction, and the detected topology. The probe
/// warms every worker first (one task per worker, so each lazy spawn
/// happens before counting starts); `warm_spawns` is then the spawn
/// delta across a 15-batch warm loop — 0 unless a worker died, which
/// outside an armed `pool.worker` chaos drill never happens.
fn pool_report() -> String {
    use crate::util::pool;
    let n = pool::workers();
    let data: Vec<f64> = (0..4096).map(|i| (i % 97) as f64).collect();
    let step = data.len() / n.max(1) + 1;
    let expect: f64 = data.iter().sum();
    let batch = |crew: bool| {
        let mut acc = vec![0.0; n];
        let mut tasks = Vec::with_capacity(n);
        for (i, slot) in acc.iter_mut().enumerate() {
            let chunk = &data[(i * step).min(data.len())..((i + 1) * step).min(data.len())];
            tasks.push(move || *slot = chunk.iter().sum());
        }
        if crew {
            pool::scoped_run(tasks);
        } else {
            pool::scoped_run_spawning(tasks);
        }
        let total: f64 = acc.iter().sum();
        assert_eq!(total, expect, "pool probe lost a chunk");
    };
    let median = |crew: bool| {
        let mut ts: Vec<f64> = (0..15)
            .map(|_| {
                let t0 = std::time::Instant::now();
                batch(crew);
                t0.elapsed().as_secs_f64()
            })
            .collect();
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        ts[ts.len() / 2]
    };
    batch(true); // warm: every worker spawns before the counter is read
    let spawns_before = pool::crew_spawns();
    let crew_median = median(true);
    let warm_spawns = pool::crew_spawns() - spawns_before;
    let spawning_median = median(false);
    let topo = crate::runtime::topology::detect();
    let mut s = String::new();
    s.push_str(&format!("    \"crew_size\": {},\n", pool::crew_size()));
    s.push_str(&format!("    \"crew_spawns\": {},\n", pool::crew_spawns()));
    s.push_str(&format!("    \"crew_respawns\": {},\n", pool::crew_respawns()));
    s.push_str(&format!("    \"warm_spawns\": {},\n", warm_spawns));
    s.push_str(&format!("    \"crew_median_secs\": {:e},\n", crew_median));
    s.push_str(&format!("    \"spawning_median_secs\": {:e},\n", spawning_median));
    s.push_str(&format!("    \"sockets\": {},\n", topo.sockets));
    s.push_str(&format!("    \"cpus\": {},\n", topo.cpus.len()));
    s.push_str(&format!(
        "    \"pinning_active\": {},\n",
        crate::runtime::topology::pinning_active()
    ));
    s.push_str(&format!(
        "    \"cache_evictions\": {}\n",
        crate::engine::Engine::cache_evictions()
    ));
    s
}

/// Render the machine-trackable perf record (`BENCH_spmv.json`) from a
/// schedule-extended sweep: median seconds per generated plan × matrix,
/// a per-matrix serial-best vs best-overall summary, the predicted-vs-
/// measured top-1 agreement of the cost model, the coverage curves
/// with and without the schedule axis, and a `simd` section pairing
/// each matrix's best wide plan with its scalar sibling — so the
/// repo's perf trajectory, its planner accuracy, *and* the value of
/// the vector-width axis are comparable across PRs.
///
/// The sweep's pool already contains every scalar serial plan (names
/// carry an `@` marker only for non-serial schedules and wide lanes),
/// so the serial table is the `@`-free subset — no second sweep is
/// run.
pub fn bench_json(scheduled: &SweepResult) -> String {
    let mats = &scheduled.gens.matrices;
    let serial_idx: Vec<usize> = (0..scheduled.gens.routines.len())
        .filter(|&r| !scheduled.gens.routines[r].contains('@'))
        .collect();
    assert!(!serial_idx.is_empty(), "scheduled sweep lost its serial variants");
    let mut out = String::from("{\n");
    out.push_str(&format!("  \"kernel\": \"{}\",\n", json_escape(scheduled.kernel.label())));
    out.push_str(&format!("  \"arch\": \"{}\",\n", json_escape(scheduled.arch.name())));
    out.push_str(&format!("  \"matrices\": {},\n", json_str_array(mats)));
    out.push_str("  \"scheduled\": {\n");
    out.push_str(&format!("    \"routines\": {},\n", json_str_array(&scheduled.gens.routines)));
    let rows: Vec<String> =
        scheduled.gens.times.iter().map(|row| format!("      {}", json_num_array(row))).collect();
    out.push_str(&format!("    \"median_secs\": [\n{}\n    ]\n", rows.join(",\n")));
    out.push_str("  },\n");

    // Predict-vs-measure audit of the planner.
    let (matches, total) = scheduled.rank_agreement();
    out.push_str("  \"predict\": {\n");
    out.push_str(&format!(
        "    \"top1_agreement\": {:.4},\n",
        matches as f64 / total.max(1) as f64
    ));
    let per: Vec<String> = mats
        .iter()
        .enumerate()
        .map(|(mi, name)| {
            let pb = scheduled.predicted_best(mi);
            let mb = scheduled.measured_best(mi);
            format!(
                "      {{\"matrix\": \"{}\", \"predicted_best\": \"{}\", \
                 \"measured_best\": \"{}\", \"agree\": {}}}",
                json_escape(name),
                json_escape(&scheduled.plans[pb].id),
                json_escape(&scheduled.plans[mb].id),
                pb == mb
            )
        })
        .collect();
    out.push_str(&format!("    \"per_matrix\": [\n{}\n    ]\n", per.join(",\n")));
    out.push_str("  },\n");

    // The calibration archive: one sample per measured cell (feature
    // vectors in the FEATURE_NAMES order) plus a preview refit — the
    // exact material `forelem calibrate` consumes to close the
    // predict→measure→refit loop.
    let names: Vec<String> = FEATURE_NAMES.iter().map(|s| s.to_string()).collect();
    out.push_str("  \"calibration\": {\n");
    out.push_str(&format!("    \"feature_names\": {},\n", json_str_array(&names)));
    out.push_str(&format!("    \"profile_loaded\": {},\n", scheduled.profile_loaded));
    out.push_str(&format!(
        "    \"ranked_weights\": {},\n",
        json_num_array(&scheduled.params.weights)
    ));
    let sample_lines: Vec<String> = scheduled
        .samples
        .iter()
        .map(|s| format!("      {}", calibrate::sample_to_json(s)))
        .collect();
    out.push_str(&format!("    \"samples\": [\n{}\n    ],\n", sample_lines.join(",\n")));
    let refit = calibrate::fit(&scheduled.samples, &scheduled.params);
    let (rm, rtot) = calibrate::top1_agreement_recorded(&scheduled.samples);
    let (fm, ftot) = calibrate::top1_agreement(&scheduled.samples, &refit.weights);
    out.push_str("    \"refit\": {\n");
    out.push_str(&format!("      \"weights\": {},\n", json_num_array(&refit.weights)));
    out.push_str(&format!(
        "      \"recorded_top1_agreement\": {:.4},\n",
        rm as f64 / rtot.max(1) as f64
    ));
    out.push_str(&format!(
        "      \"fitted_top1_agreement\": {:.4}\n",
        fm as f64 / ftot.max(1) as f64
    ));
    out.push_str("    }\n");
    out.push_str("  },\n");

    // Coverage with and without the schedule axis (vs the all-plan
    // optimum), the ROADMAP's schedule-aware-selection deliverable.
    let ts: Vec<f64> = (0..=10).map(|t| t as f64 * 5.0).collect();
    let (serial_curve, all_curve) =
        select::schedule_axis_curves(&scheduled.gens, &scheduled.plans, &ts);
    out.push_str("  \"coverage\": {\n");
    out.push_str(&format!("    \"t_pct\": {},\n", json_num_array(&ts)));
    out.push_str(&format!(
        "    \"serial_only\": {},\n",
        json_num_array(&serial_curve.iter().map(|&(_, c)| c).collect::<Vec<_>>())
    ));
    out.push_str(&format!(
        "    \"with_schedules\": {}\n",
        json_num_array(&all_curve.iter().map(|&(_, c)| c).collect::<Vec<_>>())
    ));
    out.push_str("  },\n");

    // The vector-width axis audit: per matrix, the best measured wide
    // plan against its scalar sibling (same stable id minus the
    // `.v{n}` component) and the lane width the planner's first pick
    // carries — so scalar-vs-vectorized medians stay comparable across
    // PRs. Both arrays are empty when the pool has no wide plans
    // (serial-only sweeps keep a well-formed record).
    out.push_str("  \"simd\": {\n");
    out.push_str(&format!(
        "    \"runtime_wide_kernels\": {},\n",
        crate::kernels::simd::avx2_active()
    ));
    let pairs: Vec<String> = mats
        .iter()
        .enumerate()
        .filter_map(|(mi, name)| {
            let wi = (0..scheduled.plans.len())
                .filter(|&pi| scheduled.plans[pi].exec.lanes > 1 && scheduled.measured[pi][mi])
                .min_by(|&a, &b| {
                    scheduled.gens.times[a][mi]
                        .partial_cmp(&scheduled.gens.times[b][mi])
                        .unwrap_or(std::cmp::Ordering::Equal)
                })?;
            let wide = &scheduled.plans[wi];
            let sid = wide.id.strip_suffix(&format!(".v{}", wide.exec.lanes))?;
            let si = scheduled.plans.iter().position(|p| p.id == sid)?;
            let (ws, ss) = (scheduled.gens.times[wi][mi], scheduled.gens.times[si][mi]);
            Some(format!(
                "      {{\"matrix\": \"{}\", \"scalar\": \"{}\", \"scalar_secs\": {:e}, \
                 \"wide\": \"{}\", \"wide_secs\": {:e}, \"speedup\": {:.3}}}",
                json_escape(name),
                json_escape(sid),
                ss,
                json_escape(&wide.id),
                ws,
                ss / ws
            ))
        })
        .collect();
    out.push_str(&format!("    \"scalar_vs_wide\": [\n{}\n    ],\n", pairs.join(",\n")));
    let lane_choice: Vec<String> = mats
        .iter()
        .enumerate()
        .map(|(mi, name)| {
            let pb = &scheduled.plans[scheduled.predicted_best(mi)];
            format!(
                "      {{\"matrix\": \"{}\", \"plan\": \"{}\", \"lanes\": {}}}",
                json_escape(name),
                json_escape(&pb.id),
                pb.exec.lanes
            )
        })
        .collect();
    out.push_str(&format!("    \"planner_lane_choice\": [\n{}\n    ]\n", lane_choice.join(",\n")));
    out.push_str("  },\n");

    // The worker-crew audit: the serving-path invariant is that a
    // warmed crew runs repeated parallel batches with zero new threads
    // (`warm_spawns` — the CI planner guard pins it at 0), and that
    // parked-crew dispatch is no slower than the spawn-per-call path
    // it replaced (`crew_median_secs` vs `spawning_median_secs`, same
    // reduction, same task count). Topology and eviction counters ride
    // along so one record answers "what machine, what placement, did
    // the compile cache churn".
    out.push_str("  \"pool\": {\n");
    out.push_str(&pool_report());
    out.push_str("  },\n");

    let serial_best = scheduled.gens.best_per_matrix(Some(&serial_idx));
    let sched_best = scheduled.gens.best_per_matrix(None);
    let summary: Vec<String> = mats
        .iter()
        .enumerate()
        .map(|(mi, name)| {
            format!(
                "    {{\"matrix\": \"{}\", \"serial_best_secs\": {:e}, \
                 \"scheduled_best_secs\": {:e}, \"speedup\": {:.3}}}",
                json_escape(name),
                serial_best[mi],
                sched_best[mi],
                serial_best[mi] / sched_best[mi]
            )
        })
        .collect();
    out.push_str(&format!("  \"summary\": [\n{}\n  ]\n", summary.join(",\n")));
    out.push_str("}\n");
    out
}

/// Run the schedule-extended SpMV sweep on `arch` and write
/// `BENCH_spmv.json` to `path`.
pub fn write_bench_json(
    path: &str,
    arch: Arch,
    cfg: &SweepConfig,
    xla: Option<&XlaBackend>,
) -> std::io::Result<()> {
    let sched_cfg = SweepConfig { use_schedules: true, ..cfg.clone() };
    let scheduled = run(Kernel::Spmv, arch, &sched_cfg, xla);
    std::fs::write(path, bench_json(&scheduled))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_sweep_spmv_native() {
        let cfg = SweepConfig::quick();
        let r = run(Kernel::Spmv, Arch::HostSmall, &cfg, None);
        assert_eq!(r.libs.routines.len(), 7);
        assert!(r.gens.routines.len() >= 15);
        assert_eq!(r.libs.matrices.len(), 3);
        // exhaustive sweep: every generated cell is measured
        assert!(r.measured.iter().all(|row| row.iter().all(|&b| b)));
        // …and every measured cell left a calibration sample whose
        // features reproduce the prediction under the ranked weights.
        assert_eq!(r.samples.len(), r.plans.len() * r.gens.matrices.len());
        assert!(!r.profile_loaded);
        for s in &r.samples {
            let dot: f64 =
                s.features.iter().zip(&r.params.weights).map(|(f, w)| f * w).sum();
            assert_eq!(dot.max(1e-12), s.predicted_secs, "{} on {}", s.plan_id, s.matrix);
            assert!(s.measured_secs > 0.0 && s.measured_secs.is_finite());
        }
        // the generated pool must beat or match the libraries somewhere
        let best_gen = r.best_gen();
        let best_lib = r.libs.best_per_matrix(None);
        let wins = best_gen.iter().zip(&best_lib).filter(|(g, l)| g <= l).count();
        assert!(wins >= 1, "generated variants never competitive: {best_gen:?} vs {best_lib:?}");
    }

    #[test]
    fn quick_sweep_trsv_has_restricted_pools() {
        let cfg = SweepConfig::quick();
        let r = run(Kernel::Trsv, Arch::HostSmall, &cfg, None);
        assert_eq!(r.libs.routines.len(), 4); // MTL4 + SL++ CRS/CCS
        assert!(!r.gens.routines.is_empty());
    }

    #[test]
    fn scheduled_trsv_sweep_measures_level_plans() {
        // The last Serial-pinned kernel is unpinned: a scheduled TrSv
        // sweep enumerates (and oracle-validates, inside run()) the
        // level-scheduled CSR/CSC plans.
        let mut cfg = SweepConfig::quick_scheduled();
        cfg.matrices = Some(vec![0]);
        let r = run(Kernel::Trsv, Arch::HostLarge, &cfg, None);
        let level_plans: Vec<_> =
            r.gens.routines.iter().filter(|n| n.contains("@par(")).collect();
        assert_eq!(level_plans.len(), 2, "csr+csc level plans: {:?}", r.gens.routines);
        assert!(r.gens.routines.iter().all(|n| !n.contains("@tile")));
    }

    #[test]
    fn scheduled_sweep_extends_pool_on_host_large_only() {
        let mut cfg = SweepConfig::quick_scheduled();
        cfg.matrices = Some(vec![0]);
        // HostSmall stays serial even when schedules are requested, so
        // the paper tables remain reproducible.
        let small = run(Kernel::Spmv, Arch::HostSmall, &cfg, None);
        let serial_cfg = SweepConfig { use_schedules: false, ..cfg.clone() };
        let small_serial = run(Kernel::Spmv, Arch::HostSmall, &serial_cfg, None);
        assert_eq!(small.gens.routines.len(), small_serial.gens.routines.len());
        // HostLarge opts into the parallel/tiled schedules (validated
        // against the oracle inside run()).
        let large = run(Kernel::Spmv, Arch::HostLarge, &cfg, None);
        assert!(
            large.gens.routines.len() > small.gens.routines.len(),
            "schedule axis did not extend the pool: {} vs {}",
            large.gens.routines.len(),
            small.gens.routines.len()
        );
        assert!(large.gens.routines.iter().any(|r| r.contains("@par(")));
        assert!(large.gens.routines.iter().any(|r| r.contains("@tile(")));
        // …and the vector-width axis (wide plans are oracle-validated
        // against the reference inside run() like every other cell).
        assert!(large.gens.routines.iter().any(|r| r.contains("@v8")));
        assert!(small.gens.routines.iter().all(|r| !r.contains("@v")));
    }

    #[test]
    fn shortlist_measures_topk_and_fills_the_rest() {
        let mut cfg = SweepConfig::quick();
        cfg.matrices = Some(vec![0]);
        cfg.shortlist = 3;
        let r = run(Kernel::Spmv, Arch::HostSmall, &cfg, None);
        assert!(r.plans.len() > 3);
        for mi in 0..r.gens.matrices.len() {
            let n_measured = (0..r.plans.len()).filter(|&pi| r.measured[pi][mi]).count();
            assert_eq!(n_measured, 3, "matrix {mi}");
            // The model's first pick is always on the shortlist…
            assert!(r.measured[r.predicted_best(mi)][mi]);
            // …and the shortlist is exactly the top-3 predicted plans.
            let execs: Vec<crate::concretize::Plan> =
                r.plans.iter().map(|p| p.exec).collect();
            let order = cost::rank_execs(
                Kernel::Spmv,
                cfg.spmm_k,
                &execs,
                &r.stats[mi],
                &Arch::HostSmall.cost_params(),
            );
            for &pi in &order[..3] {
                assert!(r.measured[pi][mi], "top-predicted plan {pi} not measured");
            }
        }
        // Unmeasured cells are filled with finite calibrated predictions.
        r.gens.validate().expect("shortlisted table must still be full");
        let (matches, total) = r.rank_agreement();
        assert!(matches <= total);
        assert_eq!(total, 1);
    }

    #[test]
    fn exhaustive_shortlist_equals_plan_count() {
        let mut cfg = SweepConfig::quick();
        cfg.matrices = Some(vec![2]);
        cfg.shortlist = 10_000; // larger than the pool → everything measured
        let r = run(Kernel::Spmv, Arch::HostSmall, &cfg, None);
        assert!(r.measured.iter().all(|row| row.iter().all(|&b| b)));
    }

    #[test]
    fn best_triples_come_from_measured_plans() {
        let mut cfg = SweepConfig::quick_scheduled();
        cfg.matrices = Some(vec![0, 2]);
        cfg.shortlist = 5;
        let r = run(Kernel::Spmv, Arch::HostLarge, &cfg, None);
        let triples = r.best_triples();
        assert_eq!(triples.len(), 2);
        for (mi, t) in triples.iter().enumerate() {
            assert!(t.plan_index < r.plans.len());
            assert_eq!(t.plan_id, r.plans[t.plan_index].id);
            // The winner of the full (filled) table is the measured
            // winner: calibrated fills sit above the shortlist's best.
            assert_eq!(t.plan_index, r.measured_best(mi));
        }
    }

    #[test]
    fn shortlist_samples_only_measured_cells() {
        let mut cfg = SweepConfig::quick();
        cfg.matrices = Some(vec![0, 2]);
        cfg.shortlist = 3;
        let r = run(Kernel::Spmv, Arch::HostSmall, &cfg, None);
        // 3 measured plans per matrix → exactly 6 samples, and every
        // sample names a measured (plan, matrix) cell.
        assert_eq!(r.samples.len(), 6);
        for s in &r.samples {
            let pi = r.plans.iter().position(|p| p.id == s.plan_id).expect("known plan");
            let mi = r.gens.matrices.iter().position(|m| *m == s.matrix).expect("known matrix");
            assert!(r.measured[pi][mi], "sample for unmeasured cell {}/{}", s.plan_id, s.matrix);
            assert_eq!(s.measured_secs, r.gens.times[pi][mi]);
        }
    }

    /// The closed loop, end to end in-process: sweep → bench-json →
    /// parse samples back → NNLS refit → agreement re-score. The
    /// fitted weights must reproduce the archive losslessly enough
    /// that the refit's sample count and per-matrix grouping match,
    /// and fitting must never *hurt* agreement on its own training
    /// samples by more than the seed's (the CI guard asserts the same
    /// on the real bench record).
    #[test]
    fn bench_json_samples_refit_roundtrip() {
        let mut cfg = SweepConfig::quick_scheduled();
        cfg.matrices = Some(vec![0, 2]);
        let r = run(Kernel::Spmv, Arch::HostLarge, &cfg, None);
        let js = bench_json(&r);
        let parsed = calibrate::samples_from_json(&js);
        assert_eq!(parsed.len(), r.samples.len());
        for (a, b) in parsed.iter().zip(&r.samples) {
            assert_eq!(a.matrix, b.matrix);
            assert_eq!(a.plan_id, b.plan_id);
            assert_eq!(a.features, b.features, "features must round-trip bit-exactly");
            assert_eq!(a.measured_secs, b.measured_secs);
        }
        let fitted = calibrate::fit(&parsed, &r.params);
        assert!(fitted.weights.iter().all(|w| w.is_finite() && *w >= 0.0));
        let (_, total) = calibrate::top1_agreement(&parsed, &fitted.weights);
        assert_eq!(total, 2, "one agreement group per matrix");
    }

    #[test]
    fn bench_json_is_well_formed() {
        let mut cfg = SweepConfig::quick_scheduled();
        cfg.matrices = Some(vec![0]);
        let scheduled = run(Kernel::Spmv, Arch::HostLarge, &cfg, None);
        let js = bench_json(&scheduled);
        assert!(js.starts_with("{\n"));
        assert!(js.contains("\"kernel\": \"SPMV\""));
        assert!(js.contains("\"scheduled\""));
        assert!(js.contains("\"serial_best_secs\""));
        assert!(js.contains("\"summary\""));
        assert!(js.contains("\"speedup\""));
        // the planner audit sections
        assert!(js.contains("\"predict\""));
        assert!(js.contains("\"top1_agreement\""));
        assert!(js.contains("\"predicted_best\""));
        // the calibration archive
        assert!(js.contains("\"calibration\""));
        assert!(js.contains("\"feature_names\""));
        assert!(js.contains("\"samples\""));
        assert!(js.contains("\"refit\""));
        assert!(js.contains("\"recorded_top1_agreement\""));
        assert!(js.contains("\"fitted_top1_agreement\""));
        assert!(js.contains("\"coverage\""));
        assert!(js.contains("\"serial_only\""));
        assert!(js.contains("\"with_schedules\""));
        // the vector-width audit
        assert!(js.contains("\"simd\""));
        assert!(js.contains("\"runtime_wide_kernels\""));
        assert!(js.contains("\"scalar_vs_wide\""));
        assert!(js.contains("\"planner_lane_choice\""));
        assert!(js.contains("\"lanes\""));
        // the worker-crew audit: a warmed crew serves with zero spawns
        // (workers only die at the chaos drill's armed pool.worker
        // point, which runs in its own process — never here)
        assert!(js.contains("\"pool\""));
        assert!(js.contains("\"crew_size\""));
        assert!(js.contains("\"warm_spawns\": 0,"));
        assert!(js.contains("\"crew_median_secs\""));
        assert!(js.contains("\"spawning_median_secs\""));
        assert!(js.contains("\"sockets\""));
        assert!(js.contains("\"pinning_active\""));
        assert!(js.contains("\"cache_evictions\""));
        // crude structural balance check
        let opens = js.matches('{').count();
        let closes = js.matches('}').count();
        assert_eq!(opens, closes);
        let b_opens = js.matches('[').count();
        let b_closes = js.matches(']').count();
        assert_eq!(b_opens, b_closes);
    }
}
