//! The coordinator: the L3 driver that sweeps (kernel × architecture ×
//! matrix × routine), producing the timing tables every paper table and
//! figure is computed from.
//!
//! * Data-structure *builds* run in parallel on the worker pool;
//!   *measurements* run single-threaded (the paper's protocol is
//!   single-core execution time).
//! * Two "architectures" (DESIGN.md §5): `host-small` (suite scale 1.0,
//!   native backend) and `host-large` (scale 2.0, native + the XLA-PJRT
//!   AOT backend joining the generated-variant pool, with graceful
//!   native fallback when no shape bucket fits).
//! * Every routine is validated against the dense oracle before it is
//!   timed — a mis-generated structure fails loudly, never silently.

pub mod delta_bench;
pub mod serve;
pub mod sweep;

pub use sweep::{Arch, SweepConfig, SweepResult};
