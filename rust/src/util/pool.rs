//! A minimal scoped worker pool built on `std::thread` (tokio is not
//! available offline). The coordinator uses it to build per-(variant ×
//! matrix) data structures in parallel; *measurements* are always taken
//! single-threaded on the calling thread, matching the paper's single-core
//! protocol.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for every `i in 0..n` across up to `workers` threads and
/// collect results in index order.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers > 0);
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n);
    if workers == 1 {
        return (0..n).map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker failed to fill slot"))
        .collect()
}

/// Number of workers to use for *build* parallelism (measurement stays
/// on one core).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_ok() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_ok() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn more_workers_than_items() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }
}
