//! A minimal scoped worker pool built on `std::thread` (tokio is not
//! available offline). The coordinator uses it to build per-(variant ×
//! matrix) data structures in parallel, and the `Schedule::Parallel`
//! generated kernels use [`scoped_run`] to execute disjoint row-range
//! tasks; paper-protocol *measurements* of `Serial` plans are always
//! taken single-threaded on the calling thread.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(i)` for every `i in 0..n` across up to `workers` threads and
/// collect results in index order.
///
/// Work distribution claims *contiguous index chunks* (a handful per
/// worker), not single items: the result buffer is one `Mutex<Vec<T>>`
/// per chunk — O(workers) synchronization objects — instead of a mutex
/// per item, which at 100k items allocated 100k mutexes and serialized
/// on allocator traffic. Chunks are still claimed dynamically, so
/// uneven per-item cost load-balances.
///
/// A panic in `f` poisons the claim loop: sibling workers stop
/// claiming chunks at their next iteration, the scope joins, and the
/// original panic payload is re-raised on the calling thread — one
/// panicking item unwinds the whole map instead of completing it with
/// a hole (or, worse, hanging a caller that coordinates with the
/// workers).
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers > 0);
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n);
    if workers == 1 {
        return (0..n).map(&f).collect();
    }
    // A few chunks per worker balances dynamic claiming against
    // synchronization overhead.
    let nchunks = (workers * 4).min(n);
    let chunk = n.div_ceil(nchunks);
    let nchunks = n.div_ceil(chunk);
    let next = AtomicUsize::new(0);
    let poisoned = AtomicBool::new(false);
    let payload: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);
    let out: Vec<Mutex<Vec<T>>> = (0..nchunks).map(|_| Mutex::new(Vec::new())).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                if poisoned.load(Ordering::Acquire) {
                    break;
                }
                let c = next.fetch_add(1, Ordering::Relaxed);
                if c >= nchunks {
                    break;
                }
                let lo = c * chunk;
                let hi = ((c + 1) * chunk).min(n);
                match catch_unwind(AssertUnwindSafe(|| (lo..hi).map(&f).collect::<Vec<T>>())) {
                    Ok(vals) => {
                        *out[c].lock().unwrap_or_else(|p| p.into_inner()) = vals;
                    }
                    Err(p) => {
                        poisoned.store(true, Ordering::Release);
                        let mut slot = payload.lock().unwrap_or_else(|p| p.into_inner());
                        if slot.is_none() {
                            *slot = Some(p);
                        }
                        break;
                    }
                }
            });
        }
    });
    if let Some(p) = payload.into_inner().unwrap_or_else(|p| p.into_inner()) {
        resume_unwind(p);
    }
    let mut flat = Vec::with_capacity(n);
    for m in out {
        flat.extend(m.into_inner().unwrap_or_else(|p| p.into_inner()));
    }
    assert_eq!(flat.len(), n, "worker failed to fill a chunk");
    flat
}

/// Run every task on its own scoped thread and join them all. Tasks own
/// their captures (typically a disjoint `&mut` chunk of an output slice
/// plus shared `&` storage), so the hot path takes no locks.
pub fn scoped_run<F>(tasks: Vec<F>)
where
    F: FnOnce() + Send,
{
    std::thread::scope(|scope| {
        for t in tasks {
            scope.spawn(t);
        }
    });
}

/// Number of workers to use for *build* parallelism (measurement of
/// `Serial` plans stays on one core).
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_ok() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_ok() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn more_workers_than_items() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn large_n_in_order() {
        // Chunked claiming must still reassemble exact index order.
        let out = parallel_map(100_000, 4, |i| i as u64);
        assert_eq!(out.len(), 100_000);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64));
    }

    #[test]
    fn ragged_tail_chunk() {
        // n not divisible by the chunk size: last chunk is short.
        let out = parallel_map(1001, 3, |i| i);
        assert_eq!(out, (0..1001).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_item_unwinds_the_whole_map() {
        // One poisoned item: siblings stop claiming, the map unwinds
        // with the original payload instead of hanging or returning a
        // result with a hole.
        let r = std::panic::catch_unwind(|| {
            parallel_map(1000, 4, |i| {
                if i == 500 {
                    panic!("injected worker panic");
                }
                i
            })
        });
        let p = r.expect_err("map must propagate the worker panic");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "injected worker panic", "original payload must survive");
    }

    #[test]
    fn scoped_run_fills_disjoint_chunks() {
        // The exact pattern the Schedule::Parallel kernels use: split an
        // output slice into owned chunks, one task per chunk, no locks.
        let mut y = vec![0u32; 10];
        let mut tasks = Vec::new();
        let mut rest = &mut y[..];
        for (val, n) in [(1u32, 4usize), (2, 6)] {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(n);
            rest = tail;
            tasks.push(move || chunk.fill(val));
        }
        scoped_run(tasks);
        assert_eq!(&y[..4], &[1; 4]);
        assert_eq!(&y[4..], &[2; 6]);
    }
}
