//! The persistent worker crew built on `std::thread` (tokio/rayon are
//! not available offline). Workers are spawned **once per process**,
//! parked on per-worker queues between calls, optionally pinned to
//! cores (`runtime::topology`, `numa` feature), and reused by
//! [`parallel_map`] and [`scoped_run`] — the executors behind the
//! coordinator's build parallelism and every `Schedule::Parallel`
//! generated kernel — so the warm serving path performs **zero**
//! thread spawns. Paper-protocol *measurements* of `Serial` plans are
//! still taken single-threaded on the calling thread.
//!
//! # Dispatch contract
//!
//! [`scoped_run`] hands task `i` to crew worker `i % crew_size()`,
//! deterministically. The `Schedule::Parallel` drivers and the
//! first-touch pass (`concretize::exec::Prepared::first_touch`) build
//! their task lists from the same nnz-balanced partition ranges, so
//! the worker that first touches a range is the worker that later
//! executes it — the property the NUMA placement layer rests on.
//!
//! # Lifetimes, panics, worker death
//!
//! Submitted tasks may borrow the caller's stack (the kernels pass
//! disjoint `&mut` output chunks): the submitter blocks until every
//! task in its batch has run *or been dropped*, which is what makes
//! the internal lifetime erasure sound. A panic inside a task is
//! caught on the worker, carried back through the batch, and re-raised
//! on the submitting thread — the same semantics `std::thread::scope`
//! gave the previous per-call implementation. Workers themselves only
//! die at the `pool.worker` chaos fault point (or an internal bug):
//! batch accounting is tied to `Job::drop`, so a dying worker poisons
//! and completes the batches it was holding instead of stranding their
//! submitters, and the next submission to the dead slot respawns the
//! worker ([`crew_respawns`] counts these).

use std::any::Any;
use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

// ------------------------------------------------------------ sizing

/// Crew size, decided once per process: the `FORELEM_THREADS` env
/// override (CI and the chaos harness pin it for determinism) or the
/// machine's available parallelism.
pub fn workers() -> usize {
    static WORKERS: OnceLock<usize> = OnceLock::new();
    *WORKERS.get_or_init(|| thread_count(std::env::var("FORELEM_THREADS").ok().as_deref()))
}

/// The pure sizing rule behind [`workers`], separated so the override
/// parse is testable without touching process-global state: a positive
/// integer wins, anything else falls back to available parallelism.
fn thread_count(env: Option<&str>) -> usize {
    env.and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

/// Number of workers to use for *build* parallelism (measurement of
/// `Serial` plans stays on one core). Same value as [`workers`]; the
/// name is kept for the coordinator/engine call sites.
pub fn default_workers() -> usize {
    workers()
}

// ---------------------------------------------------------- counters

static CREW_SPAWNS: AtomicUsize = AtomicUsize::new(0);
static CREW_RESPAWNS: AtomicUsize = AtomicUsize::new(0);

/// OS threads the crew has ever spawned (initial crew + respawns).
/// Flat after warm-up: the bench-json `pool` section asserts the delta
/// across a warm serving loop is zero.
pub fn crew_spawns() -> usize {
    CREW_SPAWNS.load(Ordering::Relaxed)
}

/// Workers respawned after a death (the `pool.worker` chaos drill
/// proves this is the recovery path, not a steady-state cost).
pub fn crew_respawns() -> usize {
    CREW_RESPAWNS.load(Ordering::Relaxed)
}

/// Number of crew slots (== [`workers`]). Does not spawn threads:
/// workers attach to their slot lazily on first submission.
pub fn crew_size() -> usize {
    crew().slots.len()
}

// ------------------------------------------------------------- batch

type Payload = Box<dyn Any + Send>;
type Task = Box<dyn FnOnce() + Send + 'static>;

/// Join state shared by one `scoped_run` call and its queued jobs.
struct Batch {
    inner: Mutex<BatchInner>,
    done: Condvar,
}

struct BatchInner {
    remaining: usize,
    payload: Option<Payload>,
}

impl Batch {
    fn new(n: usize) -> Arc<Self> {
        Arc::new(Batch {
            inner: Mutex::new(BatchInner { remaining: n, payload: None }),
            done: Condvar::new(),
        })
    }

    /// Record the first panic payload of the batch.
    fn poison(&self, p: Payload) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if g.payload.is_none() {
            g.payload = Some(p);
        }
    }

    fn complete_one(&self) {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        g.remaining -= 1;
        if g.remaining == 0 {
            self.done.notify_all();
        }
    }

    /// Block until every slot has completed; yields the first panic
    /// payload, if any.
    fn wait(&self) -> Option<Payload> {
        let mut g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        while g.remaining > 0 {
            g = self.done.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        g.payload.take()
    }
}

/// One queued task plus its batch slot. Slot completion is tied to
/// `Drop`, not to a successful run: a worker that dies between dequeue
/// and run (fault injection, internal bug) drops the job during unwind
/// and the batch completes — poisoned — instead of stranding its
/// submitter on the condvar. Draining a dead worker's queue likewise
/// completes every held batch.
struct Job {
    task: Option<Task>,
    batch: Arc<Batch>,
}

impl Job {
    fn run(mut self) {
        if let Some(task) = self.task.take() {
            if let Err(p) = catch_unwind(AssertUnwindSafe(task)) {
                self.batch.poison(p);
            }
        }
        // Dropping `self` completes the slot.
    }
}

impl Drop for Job {
    fn drop(&mut self) {
        if self.task.take().is_some() {
            self.batch.poison(Box::new("crew worker died before running its task"));
        }
        self.batch.complete_one();
    }
}

// -------------------------------------------------------------- crew

/// One worker's mailbox. `alive` lives under the same mutex as the
/// queue, closing the race between a dying worker draining its jobs
/// and a submitter enqueueing new ones.
struct Slot {
    state: Mutex<SlotState>,
    ready: Condvar,
}

struct SlotState {
    jobs: VecDeque<Job>,
    alive: bool,
    /// Distinguishes the first (lazy) spawn from a post-death respawn
    /// for the [`crew_respawns`] counter.
    ever_spawned: bool,
}

struct Crew {
    slots: Vec<Arc<Slot>>,
}

fn crew() -> &'static Crew {
    static CREW: OnceLock<Crew> = OnceLock::new();
    CREW.get_or_init(|| {
        let n = workers();
        let slots = (0..n)
            .map(|_| {
                Arc::new(Slot {
                    state: Mutex::new(SlotState {
                        jobs: VecDeque::new(),
                        alive: false,
                        ever_spawned: false,
                    }),
                    ready: Condvar::new(),
                })
            })
            .collect();
        Crew { slots }
    })
}

thread_local! {
    static IS_CREW_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

impl Crew {
    /// Enqueue `job` on worker `idx`, (re)spawning the worker if its
    /// slot is dead. If the OS refuses a thread, the job runs inline on
    /// the submitter — degraded but never lost.
    fn submit_to(&self, idx: usize, job: Job) {
        let slot = &self.slots[idx];
        let mut g = slot.state.lock().unwrap_or_else(|e| e.into_inner());
        if !g.alive {
            let respawn = g.ever_spawned;
            if spawn_worker(Arc::clone(slot), idx).is_ok() {
                g.alive = true;
                g.ever_spawned = true;
                CREW_SPAWNS.fetch_add(1, Ordering::Relaxed);
                if respawn {
                    CREW_RESPAWNS.fetch_add(1, Ordering::Relaxed);
                }
            } else {
                drop(g);
                job.run();
                return;
            }
        }
        g.jobs.push_back(job);
        drop(g);
        slot.ready.notify_one();
    }
}

fn spawn_worker(slot: Arc<Slot>, idx: usize) -> std::io::Result<()> {
    std::thread::Builder::new()
        .name(format!("forelem-crew-{idx}"))
        .spawn(move || worker_loop(slot, idx))
        .map(|_| ())
}

/// Marks the slot dead and drains its queue when the worker thread
/// unwinds (the `pool.worker` fault point is the only intended killer:
/// task panics are caught in `Job::run` and never reach the loop).
struct DeathSentinel {
    slot: Arc<Slot>,
}

impl Drop for DeathSentinel {
    fn drop(&mut self) {
        let drained: Vec<Job> = {
            let mut g = self.slot.state.lock().unwrap_or_else(|e| e.into_inner());
            g.alive = false;
            g.jobs.drain(..).collect()
        };
        // Dropping outside the lock poisons + completes their batches.
        drop(drained);
    }
}

fn worker_loop(slot: Arc<Slot>, idx: usize) {
    IS_CREW_WORKER.with(|f| f.set(true));
    crate::runtime::topology::pin_worker(idx);
    let _sentinel = DeathSentinel { slot: Arc::clone(&slot) };
    loop {
        let job = {
            let mut g = slot.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(j) = g.jobs.pop_front() {
                    break j;
                }
                g = slot.ready.wait(g).unwrap_or_else(|e| e.into_inner());
            }
        };
        // The drill's worker-death seam: an armed panic here unwinds
        // the loop while `job` is held, exercising the drop-guard
        // accounting and the respawn path.
        crate::faultpoint!("pool.worker");
        job.run();
    }
}

// --------------------------------------------------------- execution

/// Run every task on the persistent crew and block until all complete.
/// Task `i` goes to worker `i % crew_size()` (see the module docs for
/// why that mapping is load-bearing). Tasks own their captures
/// (typically a disjoint `&mut` chunk of an output slice plus shared
/// `&` storage), so the hot path takes no locks beyond the mailbox
/// push/pop. A panicking task unwinds the whole call on the submitting
/// thread with the original payload, like `std::thread::scope` did.
///
/// Runs inline (serially) for a single task, a one-worker crew, or
/// when called from inside a crew worker — a nested submission would
/// park a worker waiting on its own queue.
pub fn scoped_run<F>(tasks: Vec<F>)
where
    F: FnOnce() + Send,
{
    let n = tasks.len();
    if n == 0 {
        return;
    }
    if n == 1 || IS_CREW_WORKER.with(|f| f.get()) {
        for t in tasks {
            t();
        }
        return;
    }
    let crew = crew();
    let nworkers = crew.slots.len();
    if nworkers <= 1 {
        for t in tasks {
            t();
        }
        return;
    }
    let batch = Batch::new(n);
    for (i, t) in tasks.into_iter().enumerate() {
        let task: Box<dyn FnOnce() + Send + '_> = Box::new(t);
        // SAFETY: the fat pointer is only given a longer lifetime
        // bound; `batch.wait()` below blocks until every `Job` has run
        // or been dropped (slot completion is tied to `Job::drop`), so
        // no task — and no borrow it captures — outlives this frame.
        let task: Task = unsafe { std::mem::transmute(task) };
        crew.submit_to(i % nworkers, Job { task: Some(task), batch: Arc::clone(&batch) });
    }
    if let Some(p) = batch.wait() {
        resume_unwind(p);
    }
}

/// The pre-crew executor: one scoped OS thread per task, spawned per
/// invocation. Retained as the measurement baseline the bench-json
/// `pool` section (and the crew bit-identity tests) compare crew
/// dispatch against; the serving path never calls it.
pub fn scoped_run_spawning<F>(tasks: Vec<F>)
where
    F: FnOnce() + Send,
{
    std::thread::scope(|scope| {
        for t in tasks {
            scope.spawn(t);
        }
    });
}

/// Run `f(i)` for every `i in 0..n` across up to `workers` crew
/// workers and collect results in index order.
///
/// Work distribution claims *contiguous index chunks* (a handful per
/// worker), not single items: the result buffer is one `Mutex<Vec<T>>`
/// per chunk — O(workers) synchronization objects — instead of a mutex
/// per item, which at 100k items allocated 100k mutexes and serialized
/// on allocator traffic. Chunks are still claimed dynamically, so
/// uneven per-item cost load-balances.
///
/// A panic in `f` poisons the claim loop: sibling workers stop
/// claiming chunks at their next iteration, the batch joins, and the
/// original panic payload is re-raised on the calling thread — one
/// panicking item unwinds the whole map instead of completing it with
/// a hole (or, worse, hanging a caller that coordinates with the
/// workers).
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers > 0);
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.min(n);
    if workers == 1 {
        return (0..n).map(&f).collect();
    }
    // A few chunks per worker balances dynamic claiming against
    // synchronization overhead.
    let nchunks = (workers * 4).min(n);
    let chunk = n.div_ceil(nchunks);
    let nchunks = n.div_ceil(chunk);
    let next = AtomicUsize::new(0);
    let poisoned = AtomicBool::new(false);
    let payload: Mutex<Option<Payload>> = Mutex::new(None);
    let out: Vec<Mutex<Vec<T>>> = (0..nchunks).map(|_| Mutex::new(Vec::new())).collect();
    let claim_loop = || loop {
        if poisoned.load(Ordering::Acquire) {
            break;
        }
        let c = next.fetch_add(1, Ordering::Relaxed);
        if c >= nchunks {
            break;
        }
        let lo = c * chunk;
        let hi = ((c + 1) * chunk).min(n);
        match catch_unwind(AssertUnwindSafe(|| (lo..hi).map(&f).collect::<Vec<T>>())) {
            Ok(vals) => {
                *out[c].lock().unwrap_or_else(|p| p.into_inner()) = vals;
            }
            Err(p) => {
                poisoned.store(true, Ordering::Release);
                let mut slot = payload.lock().unwrap_or_else(|p| p.into_inner());
                if slot.is_none() {
                    *slot = Some(p);
                }
                break;
            }
        }
    };
    scoped_run((0..workers).map(|_| &claim_loop).collect());
    if let Some(p) = payload.into_inner().unwrap_or_else(|p| p.into_inner()) {
        resume_unwind(p);
    }
    let mut flat = Vec::with_capacity(n);
    for m in out {
        flat.extend(m.into_inner().unwrap_or_else(|p| p.into_inner()));
    }
    assert_eq!(flat.len(), n, "worker failed to fill a chunk");
    flat
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let out = parallel_map(100, 8, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn empty_ok() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_ok() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn more_workers_than_items() {
        let out = parallel_map(3, 64, |i| i);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn large_n_in_order() {
        // Chunked claiming must still reassemble exact index order.
        let out = parallel_map(100_000, 4, |i| i as u64);
        assert_eq!(out.len(), 100_000);
        assert!(out.iter().enumerate().all(|(i, &v)| v == i as u64));
    }

    #[test]
    fn ragged_tail_chunk() {
        // n not divisible by the chunk size: last chunk is short.
        let out = parallel_map(1001, 3, |i| i);
        assert_eq!(out, (0..1001).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_item_unwinds_the_whole_map() {
        // One poisoned item: siblings stop claiming, the map unwinds
        // with the original payload instead of hanging or returning a
        // result with a hole.
        let r = std::panic::catch_unwind(|| {
            parallel_map(1000, 4, |i| {
                if i == 500 {
                    panic!("injected worker panic");
                }
                i
            })
        });
        let p = r.expect_err("map must propagate the worker panic");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "injected worker panic", "original payload must survive");
    }

    #[test]
    fn scoped_run_fills_disjoint_chunks() {
        // The exact pattern the Schedule::Parallel kernels use: split an
        // output slice into owned chunks, one task per chunk, no locks.
        let mut y = vec![0u32; 10];
        let mut tasks = Vec::new();
        let mut rest = &mut y[..];
        for (val, n) in [(1u32, 4usize), (2, 6)] {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(n);
            rest = tail;
            tasks.push(move || chunk.fill(val));
        }
        scoped_run(tasks);
        assert_eq!(&y[..4], &[1; 4]);
        assert_eq!(&y[4..], &[2; 6]);
    }

    #[test]
    fn scoped_run_panic_unwinds_with_payload() {
        // A task panic must come back to the submitter with the
        // original payload — the std::thread::scope contract the crew
        // preserves.
        let r = std::panic::catch_unwind(|| {
            scoped_run(vec![
                Box::new(|| {}) as Box<dyn FnOnce() + Send>,
                Box::new(|| panic!("crew task panic")),
                Box::new(|| {}),
            ]);
        });
        let p = r.expect_err("scoped_run must propagate the task panic");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "crew task panic");
    }

    #[test]
    fn scoped_run_matches_spawning_baseline() {
        // Same disjoint-chunk job through both executors: identical
        // result (the crew changes dispatch, never the work).
        let run = |spawning: bool| {
            let mut y = vec![0u64; 24];
            let mut tasks: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
            let mut rest = &mut y[..];
            for t in 0..4u64 {
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(6);
                rest = tail;
                tasks.push(Box::new(move || {
                    for (i, v) in chunk.iter_mut().enumerate() {
                        *v = t * 100 + i as u64;
                    }
                }));
            }
            if spawning {
                scoped_run_spawning(tasks);
            } else {
                scoped_run(tasks);
            }
            y
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn warm_crew_spawns_no_new_threads() {
        // Warm the crew, snapshot the spawn counter, then run many
        // batches: the warm path must not create a single OS thread.
        // (Respawns only happen at the chaos fault point, which lib
        // tests never arm.)
        scoped_run((0..3).map(|_| || {}).collect());
        let before = crew_spawns();
        for _ in 0..16 {
            let mut y = vec![0.0f64; 64];
            let (a, b) = y.split_at_mut(32);
            scoped_run(vec![
                Box::new(move || a.fill(1.0)) as Box<dyn FnOnce() + Send>,
                Box::new(move || b.fill(2.0)),
            ]);
        }
        let _ = parallel_map(512, 4, |i| i * 3);
        assert_eq!(crew_spawns(), before, "warm serving path spawned threads");
    }

    #[test]
    fn nested_scoped_run_completes_inline() {
        // A task that itself calls scoped_run must not deadlock the
        // crew: nested submissions run inline on the worker.
        let flags: Vec<_> = (0..4).map(|_| AtomicBool::new(false)).collect();
        {
            let fr = &flags;
            let tasks: Vec<Box<dyn FnOnce() + Send>> = vec![
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() + Send>> = vec![
                        Box::new(|| fr[0].store(true, Ordering::Relaxed)),
                        Box::new(|| fr[1].store(true, Ordering::Relaxed)),
                    ];
                    scoped_run(inner);
                }),
                Box::new(move || {
                    fr[2].store(true, Ordering::Relaxed);
                    fr[3].store(true, Ordering::Relaxed);
                }),
            ];
            scoped_run(tasks);
        }
        assert!(flags.iter().all(|f| f.load(Ordering::Relaxed)));
    }

    #[test]
    fn thread_count_env_override() {
        // Positive integer wins; junk, zero and absence fall back to
        // the machine's parallelism (always >= 1).
        assert_eq!(thread_count(Some("3")), 3);
        assert_eq!(thread_count(Some(" 12 ")), 12);
        assert!(thread_count(Some("0")) >= 1);
        assert!(thread_count(Some("-2")) >= 1);
        assert!(thread_count(Some("lots")) >= 1);
        assert!(thread_count(None) >= 1);
        assert_ne!(thread_count(Some("0")), 0);
    }

    #[test]
    fn crew_size_matches_workers() {
        assert_eq!(crew_size(), workers());
        assert!(crew_size() >= 1);
    }
}
