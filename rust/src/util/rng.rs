//! Deterministic PRNGs for matrix generation, search-space sampling and
//! property testing. No external `rand` crate is available offline, so we
//! implement SplitMix64 (seeding) and Xoshiro256** (bulk generation),
//! following the public-domain reference implementations by Blackman &
//! Vigna.

/// SplitMix64 — used to expand a single `u64` seed into a full
/// Xoshiro256** state. Also usable standalone as a cheap PRNG.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically from a single u64 via SplitMix64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` using Lemire's multiply-shift rejection.
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be > 0");
        let bound = bound as u64;
        // Multiply-high method; bias negligible for our bounds but we do
        // a single rejection round to keep it exact.
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn gen_f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.gen_f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Sample from a (rough) power-law distribution over `[1, max]` with
    /// exponent `alpha` — used for scale-free graph/circuit matrices.
    pub fn gen_powerlaw(&mut self, max: usize, alpha: f64) -> usize {
        // Inverse-CDF sampling of a truncated Pareto.
        let u = self.gen_f64();
        let max_f = max as f64;
        let x = (1.0 - u * (1.0 - max_f.powf(1.0 - alpha))).powf(1.0 / (1.0 - alpha));
        (x as usize).clamp(1, max)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::MIN_POSITIVE);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices out of `n` (k << n assumed; rejection).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 3 > n {
            // dense case: shuffle a full index vector
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx.sort_unstable();
            return idx;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let x = self.gen_range(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn gen_range_in_bounds() {
        let mut r = Rng::new(7);
        for bound in [1usize, 2, 3, 10, 1000, 1 << 20] {
            for _ in 0..200 {
                assert!(r.gen_range(bound) < bound);
            }
        }
    }

    #[test]
    fn gen_f64_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean} too far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(5);
        let s = r.sample_distinct(1000, 50);
        assert_eq!(s.len(), 50);
        let mut d = s.clone();
        d.dedup();
        assert_eq!(d.len(), 50);
        assert!(s.iter().all(|&x| x < 1000));
    }

    #[test]
    fn powerlaw_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let x = r.gen_powerlaw(500, 2.2);
            assert!((1..=500).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
