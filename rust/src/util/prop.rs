//! A minimal property-based testing driver (proptest is not available
//! offline). Provides: a `Gen` context wrapping the repo PRNG, value
//! generators, and `forall` which runs a property over N random cases and
//! reports the failing seed so a failure is reproducible.
//!
//! Shrinking is deliberately out of scope — failures report the exact
//! (seed, case index) which regenerates the input deterministically.

use crate::util::rng::Rng;

/// Generation context handed to properties.
pub struct Gen {
    pub rng: Rng,
    /// Soft size bound generators should respect (grows over the run so
    /// early cases are small, mimicking proptest's sizing).
    pub size: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.gen_range(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.gen_f64_range(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.gen_bool(0.5)
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_range(xs.len())]
    }

    /// A vector of f64 values with magnitudes well away from f64 edge
    /// cases (suitable for kernel numerics checked with relative error).
    pub fn vec_f64(&mut self, len: usize) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(-8.0, 8.0)).collect()
    }
}

/// Run `prop` over `cases` random inputs. Panics with the reproducing
/// seed/case on first failure. `name` labels the property in the message.
pub fn forall<F: FnMut(&mut Gen) -> Result<(), String>>(name: &str, cases: usize, mut prop: F) {
    let base_seed = match std::env::var("FORELEM_PROP_SEED") {
        Ok(s) => s.parse::<u64>().expect("FORELEM_PROP_SEED must be a u64"),
        Err(_) => 0xF0E1_D2C3_B4A5_9687,
    };
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Rng::new(seed), size: 4 + case * 24 / cases.max(1) };
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (reproduce with FORELEM_PROP_SEED={base_seed}): {msg}"
            );
        }
    }
}

/// Assert two f64 slices are elementwise close (absolute + relative).
pub fn assert_close(a: &[f64], b: &[f64], tol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch: {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = x.abs().max(y.abs()).max(1.0);
        if (x - y).abs() > tol * scale {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol}, scale {scale})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("x+x is even-ish", 50, |g| {
            let x = g.usize_in(0, 1000);
            if (x + x) % 2 == 0 { Ok(()) } else { Err("odd".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn forall_reports_failure() {
        forall("always-fails", 5, |_| Err("nope".into()));
    }

    #[test]
    fn close_checks() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-12], 1e-9).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-9).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-9).is_err());
    }

    #[test]
    fn gen_ranges() {
        let mut g = Gen { rng: Rng::new(1), size: 8 };
        for _ in 0..100 {
            let x = g.usize_in(3, 7);
            assert!((3..=7).contains(&x));
        }
        let v = g.vec_f64(16);
        assert_eq!(v.len(), 16);
        assert!(v.iter().all(|x| x.abs() <= 8.0));
    }
}
