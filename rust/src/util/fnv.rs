//! Tiny FNV-1a 64-bit fold — the one hashing primitive behind
//! `TriMat::fingerprint` and the engine's config digest, so the two
//! stay bit-compatible by construction (no external hashing crates
//! offline).

/// Incremental FNV-1a over little-endian `u64` words.
#[derive(Clone, Copy, Debug)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    pub fn new() -> Self {
        Fnv1a(Self::OFFSET)
    }

    /// Fold the 8 little-endian bytes of `v` into the state.
    pub fn eat_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    /// Fold raw bytes (e.g. a str's UTF-8) into the state.
    pub fn eat_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(Self::PRIME);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_sensitive() {
        let mut a = Fnv1a::new();
        a.eat_u64(1);
        a.eat_u64(2);
        let mut b = Fnv1a::new();
        b.eat_u64(1);
        b.eat_u64(2);
        assert_eq!(a.finish(), b.finish());
        let mut c = Fnv1a::new();
        c.eat_u64(2);
        c.eat_u64(1);
        assert_ne!(a.finish(), c.finish());
        // Byte folding differs from word folding of the same value.
        let mut d = Fnv1a::new();
        d.eat_bytes(b"csr.row.serial");
        let mut e = Fnv1a::new();
        e.eat_bytes(b"csr.row.par4");
        assert_ne!(d.finish(), e.finish());
        // Known FNV-1a property: hashing nothing is the offset basis.
        assert_eq!(Fnv1a::new().finish(), 0xcbf2_9ce4_8422_2325);
    }
}
