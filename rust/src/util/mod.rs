//! Utility substrate: PRNG, statistics, worker pool, CLI parsing and a
//! property-testing driver — all dependency-free (the offline crate cache
//! contains only the `xla` closure; see DESIGN.md §5 Substitutions).

pub mod cli;
pub mod fnv;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
