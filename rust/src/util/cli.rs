//! Tiny hand-rolled CLI argument parser (clap is not available offline).
//!
//! Supports: positional subcommand + `--flag`, `--key value`, `--key=value`.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — first element must be
    /// the program name and is skipped.
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut it = argv.into_iter().skip(1).peekable();
        let mut out = Args::default();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn parse() -> Self {
        Self::parse_from(std::env::args())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Boolean-flag lookup that survives the parser's greedy option
    /// rule: `--check FILE` parses as option `check=FILE`, silently
    /// disabling `flag("check")`. This treats the key's presence —
    /// with or without a swallowed value — as the flag being set, and
    /// returns the swallowed token so the caller can restore it to its
    /// intended positional role.
    pub fn flag_with_capture(&self, name: &str) -> (bool, Option<&str>) {
        if self.flag(name) {
            (true, None)
        } else if let Some(v) = self.get(name) {
            (true, Some(v))
        } else {
            (false, None)
        }
    }

    /// Uniform argument validation for subcommands that take **no
    /// positional arguments** (the sweep-style ones: `table*`,
    /// `bench-json`, `run`, …): look up the given boolean flags
    /// capture-aware and reject any stray positional token — whether
    /// it arrived bare (`forelem table1 foo`) or was swallowed by the
    /// greedy option rule after a boolean flag (`--quick 3`,
    /// `--no-profile x`), where it would otherwise silently disable
    /// the flag. Returns the flag values in `names` order.
    pub fn strict_bool_flags(&self, names: &[&str]) -> Result<Vec<bool>, String> {
        let mut stray: Vec<String> = self.positional.iter().map(|p| format!("'{p}'")).collect();
        let mut vals = Vec::with_capacity(names.len());
        for n in names {
            let (set, swallowed) = self.flag_with_capture(n);
            if let Some(tok) = swallowed {
                stray.push(format!("'{tok}' (after --{n}, which takes no value)"));
            }
            vals.push(set);
        }
        if stray.is_empty() {
            Ok(vals)
        } else {
            Err(format!(
                "unexpected positional argument(s): {} — this subcommand takes only \
                 --flag and --key value options",
                stray.join(", ")
            ))
        }
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a float, got '{v}'")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse_from(std::iter::once("prog".to_string()).chain(s.iter().map(|x| x.to_string())))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["table1", "mat.mtx"]);
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.positional, vec!["mat.mtx"]);
    }

    #[test]
    fn options_both_syntaxes() {
        let a = parse(&["bench", "--repeats", "10", "--seed=42"]);
        assert_eq!(a.get_usize("repeats", 0), 10);
        assert_eq!(a.get("seed"), Some("42"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["bench", "--verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_option() {
        // `--fast --n 3`: `--fast` must not consume `--n`.
        let a = parse(&["x", "--fast", "--n", "3"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get_usize("n", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.get_or("backend", "native"), "native");
        assert_eq!(a.get_f64("t", 0.1), 0.1);
    }

    #[test]
    fn strict_bool_flags_rejects_stray_tokens_uniformly() {
        // Clean: flags in any position, real options untouched.
        let a = parse(&["table1", "--quick", "--matrices", "3", "--schedules"]);
        assert_eq!(
            a.strict_bool_flags(&["quick", "schedules", "no-profile"]),
            Ok(vec![true, true, false])
        );
        assert_eq!(a.get_usize("matrices", 0), 3);
        // Bare positional: rejected with the token named.
        let b = parse(&["table1", "mat.mtx", "--quick"]);
        let err = b.strict_bool_flags(&["quick"]).unwrap_err();
        assert!(err.contains("'mat.mtx'"), "{err}");
        // Swallowed by a boolean flag: rejected, not silently dropped
        // (the old path only warned for --no-profile).
        let c = parse(&["bench-json", "--quick", "3"]);
        let err = c.strict_bool_flags(&["quick", "no-profile"]).unwrap_err();
        assert!(err.contains("'3'") && err.contains("--quick"), "{err}");
        let d = parse(&["table2", "--no-profile", "x", "--spmm-k", "8"]);
        let err = d.strict_bool_flags(&["quick", "no-profile"]).unwrap_err();
        assert!(err.contains("--no-profile"), "{err}");
    }

    #[test]
    fn flag_with_capture_recovers_swallowed_positionals() {
        // `--check FILE`: the parser records check=FILE; the loose
        // lookup must still see the flag and hand the file back.
        let a = parse(&["calibrate", "--check", "BENCH.json"]);
        assert!(!a.flag("check"));
        assert_eq!(a.flag_with_capture("check"), (true, Some("BENCH.json")));
        // Trailing flag: set, nothing swallowed.
        let b = parse(&["calibrate", "BENCH.json", "--check"]);
        assert_eq!(b.flag_with_capture("check"), (true, None));
        assert_eq!(b.positional, vec!["BENCH.json"]);
        // Absent entirely.
        assert_eq!(parse(&["calibrate"]).flag_with_capture("check"), (false, None));
    }
}
