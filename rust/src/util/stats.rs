//! Robust summary statistics for the benchmark harness.
//!
//! The paper reports per-(kernel, matrix, routine) execution times with 10
//! repetitions "to remove fluctuation"; we follow the same protocol but
//! summarize with the median (and median absolute deviation) which is
//! robust to scheduler noise on a shared host.

/// Summary of a sample of measurements (seconds).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    /// Median absolute deviation, scaled by 1.4826 (≈ σ for normal data).
    pub mad: f64,
    pub stddev: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Self {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let min = sorted[0];
        let max = sorted[n - 1];
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let median = percentile_sorted(&sorted, 50.0);
        let mut devs: Vec<f64> = sorted.iter().map(|x| (x - median).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = percentile_sorted(&devs, 50.0) * 1.4826;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / if n > 1 { (n - 1) as f64 } else { 1.0 };
        Summary { n, min, max, mean, median, mad, stddev: var.sqrt() }
    }
}

/// Percentile (linear interpolation) of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// The paper's headline metric: percentage reduction of execution time of
/// `ours` relative to `theirs` — `100 * (1 - ours/theirs)`.
/// Positive = we are faster; negative = slower (Table 3 has a few).
pub fn pct_reduction(ours: f64, theirs: f64) -> f64 {
    100.0 * (1.0 - ours / theirs)
}

/// Geometric mean (used for aggregate speedup summaries in EXPERIMENTS.md).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!((s.mean - 3.0).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[2.5]);
        assert_eq!(s.median, 2.5);
        assert_eq!(s.mad, 0.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn median_even() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn median_robust_to_outlier() {
        let s = Summary::of(&[1.0, 1.1, 0.9, 1.0, 50.0]);
        assert!(s.median < 1.2);
        assert!(s.mean > 5.0);
    }

    #[test]
    fn percentiles() {
        let v = vec![0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile_sorted(&v, 0.0), 0.0);
        assert_eq!(percentile_sorted(&v, 100.0), 4.0);
        assert!((percentile_sorted(&v, 25.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reduction_metric() {
        assert!((pct_reduction(0.5, 1.0) - 50.0).abs() < 1e-12);
        assert!((pct_reduction(1.0, 1.0) - 0.0).abs() < 1e-12);
        assert!(pct_reduction(2.0, 1.0) < 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
