//! Concretization, stage 3: emit the generated routine as C-like source
//! text — the artifact the paper's compiler would hand to the backend C
//! compiler. The executors in `exec.rs` are the semantically identical
//! monomorphized Rust (DESIGN.md §5); this module keeps the *inspectable*
//! code artifact, used by `examples/derive_formats.rs` and the docs.

use crate::baselines::Kernel;
use crate::concretize::layout::{lane_legal, schedule_legal, Layout, Plan, Schedule, Traversal};
use crate::storage::{CooOrder, EllOrder};

/// Emit the generated C-like code for (kernel, plan). A schedule that
/// is illegal for the (layout, kernel) pair — e.g. tiling anything but
/// CSR SpMV — is not code-generated; the serial nest is emitted and
/// the header says so, rather than mislabeling an SpMV band nest as
/// another kernel. A wide plan (`lanes > 1`) carries a vectorize note
/// in the header: the inner loop runs `lanes` elements per step via
/// gathered loads, scalar-tailed — the text nest below is the scalar
/// semantics the lanes must reproduce.
pub fn emit(kernel: Kernel, plan: &Plan) -> String {
    let legal = schedule_legal(plan.layout, plan.traversal, plan.schedule, kernel);
    let sched_note = if legal {
        plan.schedule.label()
    } else {
        format!("{} illegal here; serial", plan.schedule.label())
    };
    let vectorized = plan.lanes > 1
        && lane_legal(plan.layout, plan.traversal, plan.schedule, plan.lanes, kernel);
    let lane_note = if vectorized {
        format!(", vectorize v{} (gathered, scalar tail)", plan.lanes)
    } else {
        String::new()
    };
    let header = format!(
        "/* generated: {} over {} ({:?} traversal, {} schedule{}) */\n",
        kernel.label(),
        plan.layout.literature_name(),
        plan.traversal,
        sched_note,
        lane_note,
    );
    let body = match kernel {
        Kernel::Spmv => emit_spmv(plan),
        Kernel::Spmm => emit_spmm(plan),
        Kernel::Trsv => emit_trsv(plan),
    };
    let body = if legal { apply_schedule(kernel, plan, body) } else { body };
    format!("{header}{body}")
}

/// `emit`, prefixed with the planner's analytic resource footprint for
/// the given matrix statistics — so the inspectable artifact also shows
/// *why* the predict→measure pipeline ranked this plan where it did.
/// `dense_k` is the SpMM dense-operand width the footprint assumes
/// (ignored for SpMV/TrSv).
pub fn emit_with_cost(
    kernel: Kernel,
    plan: &Plan,
    dense_k: usize,
    stats: &crate::matrix::MatrixStats,
    params: &crate::search::cost::CostParams,
) -> String {
    let r = crate::search::cost::resources(kernel, dense_k, plan, stats);
    let t = crate::search::cost::predict(kernel, dense_k, plan, stats, params);
    format!(
        "/* predicted on {}x{} nnz={}: {:.1} KB streamed, {:.1} KB gathered \
         (ws {:.1} KB), {:.0} kflop, grain {} -> {:.2} us */\n{}",
        stats.nrows,
        stats.ncols,
        stats.nnz,
        r.streamed_bytes / 1e3,
        r.gathered_bytes / 1e3,
        r.gather_working_set / 1e3,
        r.flops / 1e3,
        r.parallel_grain,
        t * 1e6,
        emit(kernel, plan)
    )
}

fn indent(body: &str) -> String {
    body.lines().map(|l| format!("  {l}\n")).collect()
}

/// Wrap the serial loop nest in the schedule's outer structure: a
/// `parallel forelem` worker loop over disjoint nnz-balanced row
/// ranges (or, for TrSv, over the dependence level sets built at
/// prepare time), a column-band loop over the per-band row splits, or
/// a B-panel sweep for SpMM. Callers guarantee legality
/// (`schedule_legal`), so each arm only ever sees the nests it is
/// generated for.
fn apply_schedule(kernel: Kernel, plan: &Plan, body: String) -> String {
    match plan.schedule {
        Schedule::Serial => body,
        // The level nest replaces the serial solve entirely (wrapping
        // it would nest the full row loop inside the per-level forelem
        // and shadow its binding): one row's gather — or one finalized
        // column's scatter — becomes the forelem body.
        Schedule::Parallel { threads } if kernel == Kernel::Trsv => match plan.layout {
            // schedule_legal admits exactly (Csr, RowWise) and
            // (Csc, ColScatter) here.
            Layout::Csc => format!(
                "/* level-scheduled solve (scatter): level[] = dependence level sets built at\n   prepare(); x[j] is final when its level is reached; spin barrier between\n   levels; scatter targets owner-partitioned across {threads} workers */\n\
                 for (i = 0; i < n; i++) x[i] = b[i];\n\
                 for (l = 0; l < nlevels; l++) {{\n\
                 \x20 parallel forelem (j; j \u{2208} level[l]) {{\n\
                 \x20   for (k = L_ptr[j]; k < L_ptr[j+1]; k++)\n\
                 \x20     x[L_row[k]] -= L_val[k] * x[j];\n\
                 \x20 }}\n  barrier(t);\n}}\n"
            ),
            _ => format!(
                "/* level-scheduled solve (gather): level[] = dependence level sets built at\n   prepare(); rows within a level are independent; spin barrier between levels */\n\
                 for (l = 0; l < nlevels; l++) {{\n\
                 \x20 parallel forelem (i; i \u{2208} level[l]) {{  /* {threads} workers */\n\
                 \x20   sum = 0;\n\
                 \x20   for (k = L_ptr[i]; k < L_ptr[i+1]; k++)\n\
                 \x20     sum += L_val[k] * x[L_col[k]];\n\
                 \x20   x[i] = b[i] - sum;\n\
                 \x20 }}\n  barrier(t);\n}}\n"
            ),
        },
        Schedule::Parallel { threads } => format!(
            "/* {threads} workers from the persistent crew (range t always runs on worker\n   t % crew — the same worker that first-touched its pages when NUMA\n   placement is active); rows[t] = nnz-balanced disjoint ranges; y chunks\n   owned per worker */\n\
             parallel forelem (t; t \u{2208} 0..{threads}) {{\n{}}}\n",
            indent(&body)
        ),
        Schedule::Tiled { x_block } if kernel == Kernel::Spmm => format!(
            "/* B-panel sweep: C columns [p0, p0+{panel}) per pass so the gathered B rows\n   stay L1-resident; the structure is re-streamed once per panel */\n\
             for (p0 = 0; p0 < k; p0 += {panel}) {{  /* panel of min({panel}, k) B/C columns */\n{}}}\n",
            indent(&body),
            // Nominal width from the x_block byte budget; the executor
            // clamps it to the run's actual dense k.
            panel = crate::concretize::exec::spmm_panel_cols(x_block, usize::MAX),
        ),
        Schedule::Tiled { x_block } => format!(
            "/* CSB-style two-pass: x band of {x_block} columns stays L2-resident;\n   band_ptr = per-band row_ptr split built at prepare() */\n\
             for (i = 0; i < nrows; i++) y[i] = 0;\n\
             for (b = 0; b < nbands; b++)\n  for (i = 0; i < nrows; i++)\n    for (k = band_ptr[b][i]; k < band_ptr[b+1][i]; k++)\n      y[i] += PA_val[k] * x[PA_col[k]];\n"
        ),
        Schedule::ParallelTiled { threads, x_block } if kernel == Kernel::Spmm => format!(
            "/* {threads} crew workers \u{00d7} {panel}-column B panels (rows[t] nnz-balanced) */\n\
             parallel forelem (t; t \u{2208} 0..{threads}) {{\n\
             \x20 for (p0 = 0; p0 < k; p0 += {panel}) {{  /* panel of min({panel}, k) B/C columns */\n{}  }}\n}}\n",
            indent(&indent(&body)),
            panel = crate::concretize::exec::spmm_panel_cols(x_block, usize::MAX),
        ),
        Schedule::ParallelTiled { threads, x_block } => format!(
            "/* {threads} crew workers \u{00d7} {x_block}-column L2-resident bands */\n\
             parallel forelem (t; t \u{2208} 0..{threads}) {{  /* rows[t] nnz-balanced */\n\
             \x20 for (i \u{2208} rows[t]) y[i] = 0;\n\
             \x20 for (b = 0; b < nbands; b++)\n    for (i \u{2208} rows[t])\n      for (k = band_ptr[b][i]; k < band_ptr[b+1][i]; k++)\n        y[i] += PA_val[k] * x[PA_col[k]];\n}}\n"
        ),
    }
}

fn emit_spmv(plan: &Plan) -> String {
    match (plan.layout, plan.traversal) {
        (Layout::CooAos(order), _) => format!(
            "/* tuples[] layout: {:?} */\n\
             for (p = 0; p < nnz; p++)\n  y[T[p].row] += T[p].val * x[T[p].col];\n",
            order
        ),
        (Layout::CooSoa(order), _) => format!(
            "/* split arrays, order: {:?} */\n\
             for (p = 0; p < nnz; p++)\n  y[row[p]] += val[p] * x[col[p]];\n",
            order
        ),
        (Layout::Csr, _) => "for (i = 0; i < nrows; i++) {\n  sum = 0;\n  for (k = PA_ptr[i]; k < PA_ptr[i+1]; k++)\n    sum += PA_val[k] * x[PA_col[k]];\n  y[i] = sum;\n}\n".into(),
        (Layout::CsrAos, _) => "for (i = 0; i < nrows; i++) {\n  sum = 0;\n  for (k = PA_ptr[i]; k < PA_ptr[i+1]; k++)\n    sum += PA[k].val * x[PA[k].col];\n  y[i] = sum;\n}\n".into(),
        (Layout::Csc, _) => "for (j = 0; j < ncols; j++)\n  for (k = PA_ptr[j]; k < PA_ptr[j+1]; k++)\n    y[PA_row[k]] += PA_val[k] * x[j];\n".into(),
        (Layout::CscAos, _) => "for (j = 0; j < ncols; j++)\n  for (k = PA_ptr[j]; k < PA_ptr[j+1]; k++)\n    y[PA[k].row] += PA[k].val * x[j];\n".into(),
        (Layout::Ell(EllOrder::RowMajor), Traversal::RowWisePadded) =>
            "/* padded ℕ*: PA_len[i] == K for all i; padding (0.0, col 0) */\n\
             for (i = 0; i < nrows; i++) {\n  sum = 0;\n  for (p = 0; p < K; p++)\n    sum += PA_val[i*K + p] * x[PA_col[i*K + p]];\n  y[i] = sum;\n}\n".into(),
        (Layout::Ell(EllOrder::RowMajor), _) =>
            "for (i = 0; i < nrows; i++) {\n  sum = 0;\n  for (p = 0; p < PA_len[i]; p++)\n    sum += PA_val[i*K + p] * x[PA_col[i*K + p]];\n  y[i] = sum;\n}\n".into(),
        (Layout::Ell(EllOrder::ColMajor), _) =>
            "/* ITPACK: plane-major after loop interchange */\n\
             for (p = 0; p < K; p++)\n  for (i = 0; i < nrows; i++)\n    y[i] += PA_val[p*nrows + i] * x[PA_col[p*nrows + i]];\n".into(),
        (Layout::Jds { permuted: true }, _) =>
            "/* JDS: rows permuted by decreasing length (perm[]) */\n\
             for (d = 0; d < ndiags; d++)\n  for (q = 0; q < diag_len[d]; q++)\n    yp[q] += PA_val[jd_ptr[d]+q] * x[PA_col[jd_ptr[d]+q]];\n\
             for (q = 0; q < nrows; q++) y[perm[q]] = yp[q];\n".into(),
        (Layout::Jds { permuted: false }, _) =>
            "/* unpermuted jagged storage: explicit per-diagonal row lists */\n\
             for (d = 0; d < ndiags; d++)\n  for (q = 0; q < diag_len[d]; q++)\n    y[diag_row[d][q]] += PA_val[jd_ptr[d]+q] * x[PA_col[jd_ptr[d]+q]];\n".into(),
        (Layout::Bcsr { br, bc }, _) => format!(
            "/* {br}x{bc} register blocks */\n\
             for (bi = 0; bi < nblock_rows; bi++)\n  for (k = brp[bi]; k < brp[bi+1]; k++)\n    for (r = 0; r < {br}; r++)\n      for (c = 0; c < {bc}; c++)\n        y[bi*{br}+r] += blk[k][r][c] * x[bcol[k]*{bc}+c];\n"
        ),
        (Layout::HybridEllCoo, _) =>
            "/* hybrid: ELL head (width = cutoff) + COO tail */\n\
             for (i = 0; i < nrows; i++)\n  for (p = 0; p < ell_len[i]; p++)\n    y[i] += ell_val[...] * x[ell_col[...]];\n\
             for (t = 0; t < tail_nnz; t++)\n  y[tail_row[t]] += tail_val[t] * x[tail_col[t]];\n".into(),
        (Layout::Sell { s }, _) => format!(
            "/* sliced ELLPACK, slice height {s}: per-slice padded planes */\n\
             for (b = 0; b < nslices; b++)\n  for (p = 0; p < width[b]; p++)\n    for (r = 0; r < rows(b); r++)\n      y[b*{s}+r] += val[ptr[b] + p*rows(b) + r] * x[col[ptr[b] + p*rows(b) + r]];\n"
        ),
        (Layout::SellSigma { s, sigma }, _) => format!(
            "/* SELL-\u{3c3}: rows sorted by length within \u{3c3}={sigma} windows (perm[]),\n   then sliced by {s} with per-slice padded planes; output scattered\n   through the window-bounded permutation */\n\
             for (b = 0; b < nslices; b++)\n  for (p = 0; p < width[b]; p++)\n    for (r = 0; r < rows(b); r++)\n      y[perm[b*{s}+r]] += val[ptr[b] + p*rows(b) + r] * x[col[ptr[b] + p*rows(b) + r]];\n"
        ),
        (Layout::Dia, _) =>
            "/* diagonal storage: offsets[] and dense planes */\n\
             for (d = 0; d < ndiags; d++)\n  for (i = lo(d); i < hi(d); i++)\n    y[i] += plane[d][i] * x[i + offsets[d]];\n".into(),
    }
}

fn emit_spmm(plan: &Plan) -> String {
    // The SpMM nest is the SpMV nest with the dense k-loop innermost.
    let spmv = emit_spmv(plan);
    format!(
        "/* SpMM: inner dense loop over the {{0..k}} columns of B; the\n   SpMV nest below gains `for (v = 0; v < k; v++)` at its core,\n   with x[..] -> B[..][v] and y[..] -> C[..][v]. */\n{spmv}"
    )
}

fn emit_trsv(plan: &Plan) -> String {
    match plan.layout {
        Layout::Csr | Layout::CsrAos => "for (i = 0; i < n; i++) {\n  sum = 0;\n  for (k = L_ptr[i]; k < L_ptr[i+1]; k++)\n    sum += L_val[k] * x[L_col[k]];\n  x[i] = b[i] - sum;\n}\n".into(),
        Layout::Csc | Layout::CscAos => "for (i = 0; i < n; i++) x[i] = b[i];\nfor (j = 0; j < n; j++)\n  for (k = L_ptr[j]; k < L_ptr[j+1]; k++)\n    x[L_row[k]] -= L_val[k] * x[j];\n".into(),
        Layout::CooAos(CooOrder::RowMajor) => "/* row-major tuples: single forward pass */\np = 0;\nfor (i = 0; i < n; i++) {\n  sum = 0;\n  while (p < nnz && T[p].row == i) { sum += T[p].val * x[T[p].col]; p++; }\n  x[i] = b[i] - sum;\n}\n".into(),
        Layout::Ell(_) => "for (i = 0; i < n; i++) {\n  sum = 0;\n  for (p = 0; p < L_len[i]; p++)\n    sum += L_val[idx(i,p)] * x[L_col[idx(i,p)]];\n  x[i] = b[i] - sum;\n}\n".into(),
        Layout::HybridEllCoo => "/* merge ELL head and COO tail row cursors */\n…\n".into(),
        _ => "/* TrSv not generated for this layout (dependences) */\n".into(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emits_for_every_layout() {
        let plans = [
            Plan::serial(Layout::Csr, Traversal::RowWise),
            Plan::serial(Layout::Ell(EllOrder::ColMajor), Traversal::PlaneWise),
            Plan::serial(Layout::Jds { permuted: true }, Traversal::DiagMajor),
            Plan::serial(Layout::Bcsr { br: 3, bc: 3 }, Traversal::Blocked),
            Plan::serial(Layout::Dia, Traversal::DiagMajor),
        ];
        for p in plans {
            for k in [Kernel::Spmv, Kernel::Spmm, Kernel::Trsv] {
                let txt = emit(k, &p);
                assert!(txt.starts_with("/* generated:"), "{txt}");
                assert!(txt.len() > 40);
            }
        }
    }

    #[test]
    fn itpack_code_mentions_interchange_order() {
        let p = Plan::serial(Layout::Ell(EllOrder::ColMajor), Traversal::PlaneWise);
        let txt = emit(Kernel::Spmv, &p);
        assert!(txt.contains("ITPACK"));
        assert!(txt.contains("p*nrows + i"));
    }

    #[test]
    fn csr_code_has_ptr_loop() {
        let p = Plan::serial(Layout::Csr, Traversal::RowWise);
        assert!(emit(Kernel::Spmv, &p).contains("PA_ptr[i+1]"));
    }

    #[test]
    fn parallel_schedule_wraps_nest_in_parallel_forelem() {
        let p = Plan::serial(Layout::Csr, Traversal::RowWise)
            .with_schedule(Schedule::Parallel { threads: 4 });
        let txt = emit(Kernel::Spmv, &p);
        assert!(txt.contains("parallel forelem"), "{txt}");
        assert!(txt.contains("par(4) schedule"), "{txt}");
        // the artifact records where its workers come from and the
        // range->worker mapping the first-touch pass depends on
        assert!(txt.contains("persistent crew"), "{txt}");
        assert!(txt.contains("t % crew"), "{txt}");
        // the serial nest is indented inside the worker loop
        assert!(txt.contains("  for (i = 0; i < nrows; i++)"), "{txt}");
    }

    #[test]
    fn tiled_schedule_emits_band_nest() {
        let p = Plan::serial(Layout::Csr, Traversal::RowWise)
            .with_schedule(Schedule::Tiled { x_block: 4096 });
        let txt = emit(Kernel::Spmv, &p);
        assert!(txt.contains("band_ptr[b][i]"), "{txt}");
        assert!(txt.contains("4096"), "{txt}");
        let pt = Plan::serial(Layout::Csr, Traversal::RowWise)
            .with_schedule(Schedule::ParallelTiled { threads: 2, x_block: 1024 });
        let txt = emit(Kernel::Spmv, &pt);
        assert!(txt.contains("parallel forelem"), "{txt}");
        assert!(txt.contains("band_ptr"), "{txt}");
    }

    #[test]
    fn emit_with_cost_prepends_footprint() {
        let p = Plan::serial(Layout::Csr, Traversal::RowWise);
        let stats = crate::matrix::MatrixStats::nominal();
        let params = crate::search::cost::CostParams::host_small();
        let txt = emit_with_cost(Kernel::Spmv, &p, 1, &stats, &params);
        assert!(txt.starts_with("/* predicted on"), "{txt}");
        assert!(txt.contains("KB streamed"), "{txt}");
        assert!(txt.contains("/* generated:"), "{txt}");
    }

    #[test]
    fn spmm_tiled_schedule_emits_panel_sweep() {
        let p = Plan::serial(Layout::Csr, Traversal::RowWise)
            .with_schedule(Schedule::Tiled { x_block: 4096 });
        let txt = emit(Kernel::Spmm, &p);
        assert!(txt.contains("B-panel sweep"), "{txt}");
        assert!(txt.contains("p0 += 32"), "{txt}");
        assert!(!txt.contains("band_ptr"), "{txt}");
        let pt = Plan::serial(Layout::Bcsr { br: 2, bc: 2 }, Traversal::Blocked)
            .with_schedule(Schedule::ParallelTiled { threads: 4, x_block: 4096 });
        let txt = emit(Kernel::Spmm, &pt);
        assert!(txt.contains("parallel forelem"), "{txt}");
        assert!(txt.contains("min(32, k) B/C columns"), "{txt}");
    }

    #[test]
    fn trsv_parallel_schedule_emits_level_nest() {
        let par = Plan::serial(Layout::Csr, Traversal::RowWise)
            .with_schedule(Schedule::Parallel { threads: 4 });
        let txt = emit(Kernel::Trsv, &par);
        assert!(txt.contains("level-scheduled"), "{txt}");
        assert!(txt.contains("parallel forelem (i; i \u{2208} level[l])"), "{txt}");
        assert!(txt.contains("barrier(t)"), "{txt}");
        let csc = Plan::serial(Layout::Csc, Traversal::ColScatter)
            .with_schedule(Schedule::Parallel { threads: 2 });
        assert!(emit(Kernel::Trsv, &csc).contains("level-scheduled"));
    }

    #[test]
    fn wide_plans_carry_a_vectorize_note() {
        let p = Plan::serial(Layout::Csr, Traversal::RowWise).with_lanes(8);
        let txt = emit(Kernel::Spmv, &p);
        assert!(txt.contains("vectorize v8"), "{txt}");
        assert!(txt.contains("scalar tail"), "{txt}");
        // The nest itself is the scalar semantics the lanes reproduce.
        assert!(txt.contains("PA_ptr[i+1]"), "{txt}");
        // Scalar plans are annotation-free, and an illegal lane choice
        // (TrSv never vectorizes) is not advertised as vectorized.
        let s = Plan::serial(Layout::Csr, Traversal::RowWise);
        assert!(!emit(Kernel::Spmv, &s).contains("vectorize"));
        let t = Plan::serial(Layout::Csr, Traversal::RowWise).with_lanes(8);
        assert!(!emit(Kernel::Trsv, &t).contains("vectorize"));
    }

    #[test]
    fn illegal_schedule_falls_back_to_serial_nest() {
        // Tiled SpMM exists only for the micro-kernel formats; an ELL
        // plan must not be mislabeled with a panel sweep.
        let p = Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWise)
            .with_schedule(Schedule::Tiled { x_block: 4096 });
        let txt = emit(Kernel::Spmm, &p);
        assert!(txt.contains("illegal here; serial"), "{txt}");
        assert!(!txt.contains("B-panel"), "{txt}");
        // TrSv reschedules only onto the level-capable SoA formats.
        let par = Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWise)
            .with_schedule(Schedule::Parallel { threads: 4 });
        let txt = emit(Kernel::Trsv, &par);
        assert!(!txt.contains("parallel forelem"), "{txt}");
        // Tiled TrSv stays illegal even for CSR.
        let tiled = Plan::serial(Layout::Csr, Traversal::RowWise)
            .with_schedule(Schedule::Tiled { x_block: 4096 });
        let txt = emit(Kernel::Trsv, &tiled);
        assert!(txt.contains("illegal here; serial"), "{txt}");
    }
}
