//! Concretization, stage 1 (paper §6.2.1): map a fully-transformed chain
//! state onto a *physical* storage layout + traversal schedule. This is
//! the one-to-one mapping of the materialized symbolic `PA` sequences
//! onto allocated arrays; `exec.rs` then builds the arrays from the
//! tuple reservoir and binds the generated loop nest.
//!
//! # The three plan axes
//!
//! A concretization [`Plan`] spans three orthogonal axes:
//!
//! 1. **[`Layout`]** — *how the tuples are stored*: the physical data
//!    structure (CSR, ELL, JDS, BCSR, SELL, …) the chain's materialized
//!    `PA` sequences map onto. This is the paper's "generated data
//!    structure".
//! 2. **[`Traversal`]** — *in what order the loop nest walks the
//!    storage*: row-wise, plane-wise (post-interchange), diagonal-major,
//!    etc. Layout × Traversal reproduces the paper's 130-executables /
//!    25-structures distinction.
//! 3. **[`Schedule`]** — *how the walk is mapped onto the machine*:
//!    serial, parallel over nnz-balanced disjoint row ranges, cache-
//!    blocked over L2-resident `x` column bands, or both combined. The
//!    paper's evaluation is single-core (its tables are reproduced with
//!    `Schedule::Serial`); the schedule axis is this reproduction's
//!    extension of the same search philosophy to the hardware knobs
//!    that ADHA and Marmoset show must be co-optimized with layout.
//!
//! `layout.rs` maps chain states to Serial plans; `search::tree`
//! crosses them with a [`Schedule`] pool, pruning per kernel (TrSv's
//! loop-carried dependence forces `Serial`); `exec.rs` binds each
//! triple to a concrete executor.

use crate::baselines::Kernel;
use crate::forelem::ir::{Blocking, ChainState, NStarMat, Orth};
use crate::storage::{CooOrder, EllOrder};

/// Physical storage layout descriptor — the "generated data structure".
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Layout {
    CooAos(CooOrder),
    CooSoa(CooOrder),
    Csr,
    CsrAos,
    Csc,
    CscAos,
    /// Padded rectangular; order = ITPACK direction after interchange.
    Ell(EllOrder),
    /// Jagged diagonal; `permuted` = ℕ* sorting applied.
    Jds { permuted: bool },
    Bcsr { br: usize, bc: usize },
    HybridEllCoo,
    /// Sliced ELLPACK with slice height `s`.
    Sell { s: usize },
    /// Row-sigma-sorted sliced ELLPACK: rows sorted by length within
    /// windows of `sigma` rows before slicing (SELL-σ).
    SellSigma { s: usize, sigma: usize },
    Dia,
}

impl Layout {
    /// Short stable slug used in plan ids (content-derived, so a plan
    /// keeps its id across enumeration-order changes).
    pub fn slug(&self) -> String {
        match self {
            Layout::CooAos(o) => format!("coo-aos-{}", coo_order_slug(*o)),
            Layout::CooSoa(o) => format!("coo-soa-{}", coo_order_slug(*o)),
            Layout::Csr => "csr".to_string(),
            Layout::CsrAos => "csr-aos".to_string(),
            Layout::Csc => "csc".to_string(),
            Layout::CscAos => "csc-aos".to_string(),
            Layout::Ell(EllOrder::RowMajor) => "ell-rm".to_string(),
            Layout::Ell(EllOrder::ColMajor) => "ell-cm".to_string(),
            Layout::Jds { permuted: true } => "jds".to_string(),
            Layout::Jds { permuted: false } => "jds-unperm".to_string(),
            Layout::Bcsr { br, bc } => format!("bcsr{br}x{bc}"),
            Layout::HybridEllCoo => "hyb".to_string(),
            Layout::Sell { s } => format!("sell{s}"),
            Layout::SellSigma { s, sigma } => format!("sell{s}s{sigma}"),
            Layout::Dia => "dia".to_string(),
        }
    }

    /// Literature name, where one exists (paper §6.2.2).
    pub fn literature_name(&self) -> &'static str {
        match self {
            Layout::CooAos(_) | Layout::CooSoa(_) => "coordinate (COO)",
            Layout::Csr | Layout::CsrAos => "Compressed Row Storage (CSR)",
            Layout::Csc | Layout::CscAos => "Compressed Column Storage (CCS)",
            Layout::Ell(EllOrder::ColMajor) => "ITPACK/ELLPACK (column-major)",
            Layout::Ell(EllOrder::RowMajor) => "ELLPACK (row-major)",
            Layout::Jds { permuted: true } => "Jagged Diagonal Storage (JDS)",
            Layout::Jds { permuted: false } => "unpermuted jagged storage",
            Layout::Bcsr { .. } => "Blocked CSR (BCSR)",
            Layout::HybridEllCoo => "hybrid ELL+COO",
            Layout::Sell { .. } => "Sliced ELLPACK (SELL)",
            Layout::SellSigma { .. } => "row-sorted Sliced ELLPACK (SELL-\u{3c3})",
            Layout::Dia => "diagonal storage (DIA)",
        }
    }
}

pub(crate) fn coo_order_slug(o: CooOrder) -> &'static str {
    match o {
        CooOrder::Unsorted => "any",
        CooOrder::RowMajor => "rm",
        CooOrder::ColMajor => "cm",
    }
}

/// Traversal schedule of the generated loop nest over the layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Traversal {
    /// Flat walk over a single materialized sequence.
    Flat,
    /// Row loop outer, exact lengths inner.
    RowWise,
    /// Row loop outer, padded width inner (branch-free).
    RowWisePadded,
    /// Slot loop outer (post-interchange / ITPACK schedule).
    PlaneWise,
    /// Jagged-diagonal-major.
    DiagMajor,
    /// Column loop outer, scatter into the output.
    ColScatter,
    /// Block-row loop with dense micro-kernel.
    Blocked,
    /// Slice loop outer, per-slice padded plane loops (SELL schedule).
    SlicePlane,
}

impl Traversal {
    /// Short stable slug used in plan ids.
    pub fn slug(&self) -> &'static str {
        match self {
            Traversal::Flat => "flat",
            Traversal::RowWise => "row",
            Traversal::RowWisePadded => "rowpad",
            Traversal::PlaneWise => "plane",
            Traversal::DiagMajor => "diag",
            Traversal::ColScatter => "colscat",
            Traversal::Blocked => "blk",
            Traversal::SlicePlane => "slice",
        }
    }
}

/// Execution schedule of the generated loop nest — the third plan axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Schedule {
    /// Single-threaded, unblocked — the paper's measurement protocol.
    Serial,
    /// Disjoint nnz-balanced row ranges across `threads` workers; each
    /// worker owns a `&mut` chunk of the output (no locks).
    Parallel { threads: usize },
    /// Cache-blocked: the `x` gather is tiled into `x_block`-column
    /// bands (CSB-style two-pass over a per-band row_ptr split built at
    /// `prepare()` time) so each band stays L2-resident.
    Tiled { x_block: usize },
    /// Both: parallel row ranges, each traversed band-by-band.
    ParallelTiled { threads: usize, x_block: usize },
}

impl Schedule {
    /// Short display label, e.g. `par(4)` or `tile(4096)`.
    pub fn label(&self) -> String {
        match self {
            Schedule::Serial => "serial".to_string(),
            Schedule::Parallel { threads } => format!("par({threads})"),
            Schedule::Tiled { x_block } => format!("tile({x_block})"),
            Schedule::ParallelTiled { threads, x_block } => {
                format!("par({threads})+tile({x_block})")
            }
        }
    }

    /// Short stable slug used in plan ids.
    pub fn slug(&self) -> String {
        match self {
            Schedule::Serial => "serial".to_string(),
            Schedule::Parallel { threads } => format!("par{threads}"),
            Schedule::Tiled { x_block } => format!("tile{x_block}"),
            Schedule::ParallelTiled { threads, x_block } => format!("par{threads}-tile{x_block}"),
        }
    }

    pub fn is_serial(&self) -> bool {
        matches!(self, Schedule::Serial)
    }
}

/// A concretization plan: what to allocate, how to walk it, how the
/// walk is scheduled onto the machine, and how wide each inner-loop
/// step is (the vector-lane axis).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Plan {
    pub layout: Layout,
    pub traversal: Traversal,
    pub schedule: Schedule,
    /// Vector lanes of the inner loop: 1 = scalar (the default build),
    /// 4/8 = the monomorphized wide micro-kernels (`kernels::simd`).
    /// A fourth plan axis, priced by the cost model's `gather_lanes`
    /// feature and gated per format by [`lane_legal`].
    pub lanes: usize,
}

impl Plan {
    /// A serial plan — the paper's original Layout × Traversal space.
    pub fn serial(layout: Layout, traversal: Traversal) -> Plan {
        Plan { layout, traversal, schedule: Schedule::Serial, lanes: 1 }
    }

    /// The same plan under a different schedule.
    pub fn with_schedule(self, schedule: Schedule) -> Plan {
        Plan { schedule, ..self }
    }

    /// The same plan at a different vector width.
    pub fn with_lanes(self, lanes: usize) -> Plan {
        Plan { lanes, ..self }
    }
}

/// Is `schedule` legal for this (layout, traversal, kernel)?
///
/// Pruning rules:
/// - `Serial` is always legal.
/// - TrSv reschedules only onto dependence **level sets**: the loop
///   nest carries a true dependence over rows (x[i] needs all x[j]
///   with L[i][j] ≠ 0), so plain row ranges are illegal — but the
///   compressed SoA formats (CSR gather, CSC scatter) build level sets
///   at `prepare()` and run each level's mutually independent rows in
///   parallel (`Schedule::Parallel`). Band-reordered accumulation
///   (`Tiled`) stays illegal: it would reassociate a row's sum across
///   the dependence.
/// - `Parallel` SpMV/SpMM requires a layout whose output rows
///   partition into disjoint contiguous ranges: CSR (SoA), ELL, SELL
///   (slice ranges), BCSR (block-row ranges), permuted JDS
///   (prefix-property row ranges in the permuted output) and SELL-σ
///   with slice-aligned sort windows (`σ % s == 0`: whole-window
///   ranges own exactly their σ output rows, since the permutation
///   never crosses a window).
///   Scatter-shaped layouts (COO, CSC, DIA, hybrid tails, unpermuted
///   JDS) would need atomics or merges. The branch-free
///   `RowWisePadded` ELL traversal is excluded: its parallel executor
///   would be identical to the exact-length row-wise one, and
///   duplicating the executable under two names would skew the
///   variant tables.
/// - `Tiled` SpMV is generated for the CSR gather only (the band
///   split is a CSR-specific auxiliary structure). `Tiled` SpMM is
///   the B-panel sweep, generated for the register-blocked
///   micro-kernel formats (CSR, BCSR) where a panel keeps the
///   gathered B rows L1-resident.
pub fn schedule_legal(
    layout: Layout,
    traversal: Traversal,
    schedule: Schedule,
    kernel: Kernel,
) -> bool {
    if schedule.is_serial() {
        return true;
    }
    if kernel == Kernel::Trsv {
        return match schedule {
            Schedule::Parallel { threads } => {
                threads > 0
                    && matches!(
                        (layout, traversal),
                        (Layout::Csr, Traversal::RowWise) | (Layout::Csc, Traversal::ColScatter)
                    )
            }
            _ => false,
        };
    }
    // SELL-σ joins the row-partitionable pool when its σ windows are
    // slice-aligned: the sort permutation never crosses a window, so
    // whole-window ranges are a lock-free output split (σ = 8·s from
    // the chain mapping always qualifies).
    let sigma_aligned = matches!(layout, Layout::SellSigma { s, sigma } if sigma % s == 0);
    let row_partitionable = (sigma_aligned
        || matches!(
            layout,
            Layout::Csr
                | Layout::Ell(_)
                | Layout::Sell { .. }
                | Layout::Bcsr { .. }
                | Layout::Jds { permuted: true }
        ))
        && traversal != Traversal::RowWisePadded;
    let tileable = match kernel {
        Kernel::Spmv => layout == Layout::Csr,
        Kernel::Spmm => matches!(layout, Layout::Csr | Layout::Bcsr { .. }),
        Kernel::Trsv => false,
    };
    match schedule {
        Schedule::Serial => true,
        Schedule::Parallel { threads } => threads > 0 && row_partitionable,
        Schedule::Tiled { x_block } => x_block > 0 && tileable,
        Schedule::ParallelTiled { threads, x_block } => threads > 0 && x_block > 0 && tileable,
    }
}

/// Is a vector width of `lanes` legal for this plan shape? The lane
/// axis composes only with the plans whose inner loop the wide
/// micro-kernels (`kernels::simd`) actually cover:
///
/// - `lanes == 1` (scalar) is always legal — every plan has a scalar
///   body; 4 and 8 are the monomorphized widths (half / full AVX2
///   register of f64); anything else is rejected.
/// - SpMV vectorizes the gather-heavy inner loops: CSR row-wise
///   (within-row lane accumulators), ELL row-wise, and SELL-σ's
///   slice-plane walk *across* rows — which needs the slice height to
///   tile evenly (`s % lanes == 0`) so a lane group never straddles a
///   slice boundary.
/// - SpMM widens only the CSR register-blocked micro-kernel
///   (`axpy_k8`); TrSv never vectorizes — its loop-carried dependence
///   serializes the row sums the lanes would split.
/// - Only the `Serial` and `Parallel` schedules compose: the band/panel
///   sweeps (`Tiled`/`ParallelTiled`) restructure the same inner loop
///   the lane axis would, and crossing the two would square the plan
///   count for no measured payoff.
pub fn lane_legal(
    layout: Layout,
    traversal: Traversal,
    schedule: Schedule,
    lanes: usize,
    kernel: Kernel,
) -> bool {
    if lanes == 1 {
        return true;
    }
    if lanes != 4 && lanes != 8 {
        return false;
    }
    if !matches!(schedule, Schedule::Serial | Schedule::Parallel { .. }) {
        return false;
    }
    match kernel {
        Kernel::Spmv => match (layout, traversal) {
            (Layout::Csr, Traversal::RowWise) => true,
            (Layout::Ell(_), Traversal::RowWise) => true,
            (Layout::SellSigma { s, .. }, Traversal::SlicePlane) => s % lanes == 0,
            _ => false,
        },
        Kernel::Spmm => matches!((layout, traversal), (Layout::Csr, Traversal::RowWise)),
        Kernel::Trsv => false,
    }
}

#[derive(Debug, PartialEq, Eq)]
pub enum ConcretizeError {
    NotConcretizable(&'static str),
}

impl std::fmt::Display for ConcretizeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConcretizeError::NotConcretizable(msg) => {
                write!(f, "state not concretizable: {msg}")
            }
        }
    }
}

impl std::error::Error for ConcretizeError {}

/// Map a chain state to its concretization plan(s). Most states map to
/// exactly one plan; padded-ELL row-major admits two traversals (exact
/// and branch-free padded) — both are returned and become distinct
/// *executables* over the same *data structure*, mirroring the paper's
/// 130-executables / 25-structures distinction.
pub fn plans(s: &ChainState) -> Result<Vec<Plan>, ConcretizeError> {
    use ConcretizeError::NotConcretizable;
    let Some(dependent) = s.materialized else {
        return Err(NotConcretizable("materialization is a prerequisite of concretization"));
    };

    // Blocked states first.
    if let Some(b) = s.blocked {
        return match b {
            Blocking::Tile { br, bc } => {
                Ok(vec![Plan::serial(Layout::Bcsr { br, bc }, Traversal::Blocked)])
            }
            Blocking::FillCutoff => {
                Ok(vec![Plan::serial(Layout::HybridEllCoo, Traversal::RowWise)])
            }
            Blocking::RowSlice { s: h } => {
                // ℕ* sorting applied to the sliced nest permutes rows by
                // length within a bounded window before the per-slice
                // padding: SELL-σ (σ = 8·s keeps the output scatter
                // cache-local while covering several slices).
                if s.sorted {
                    Ok(vec![Plan::serial(
                        Layout::SellSigma { s: h, sigma: h * 8 },
                        Traversal::SlicePlane,
                    )])
                } else {
                    Ok(vec![Plan::serial(Layout::Sell { s: h }, Traversal::SlicePlane)])
                }
            }
        };
    }

    if !dependent {
        // Loop-independent materialization: a single flat sequence.
        let order = CooOrder::Unsorted;
        let layout = if s.split { Layout::CooSoa(order) } else { Layout::CooAos(order) };
        return Ok(vec![Plan::serial(layout, Traversal::Flat)]);
    }

    match s.orth {
        Orth::Diag => Ok(vec![Plan::serial(Layout::Dia, Traversal::DiagMajor)]),
        Orth::Row => match (s.nstar, s.sorted, s.interchanged, s.dim_reduced) {
            // No ℕ* materialization: grouped flat sequence (row-major COO).
            (None, false, false, false) => {
                let layout = if s.split {
                    Layout::CooSoa(CooOrder::RowMajor)
                } else {
                    Layout::CooAos(CooOrder::RowMajor)
                };
                Ok(vec![Plan::serial(layout, Traversal::Flat)])
            }
            // Exact ℕ* + dim reduction = CSR.
            (Some(NStarMat::Exact), false, false, true) => {
                let layout = if s.split { Layout::Csr } else { Layout::CsrAos };
                Ok(vec![Plan::serial(layout, Traversal::RowWise)])
            }
            // Exact ℕ* without dim reduction: nested sequences —
            // physically CSR arrays, same traversal (allocation detail).
            (Some(NStarMat::Exact), false, false, false) => {
                let layout = if s.split { Layout::Csr } else { Layout::CsrAos };
                Ok(vec![Plan::serial(layout, Traversal::RowWise)])
            }
            // Padded, no interchange: ELL row-major; two executables.
            (Some(NStarMat::Padded), false, false, false) => Ok(vec![
                Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWise),
                Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWisePadded),
            ]),
            // Padded + interchange: ITPACK plane-wise.
            (Some(NStarMat::Padded), false, true, false) => Ok(vec![Plan::serial(
                Layout::Ell(EllOrder::ColMajor),
                Traversal::PlaneWise,
            )]),
            // Padded + sorted (+ maybe interchange): sorted ELL — treat
            // sorted padded rows as JDS-adjacent; plane-wise schedule.
            (Some(NStarMat::Padded), true, xch, false) => {
                let _ = xch;
                Ok(vec![Plan::serial(Layout::Jds { permuted: true }, Traversal::DiagMajor)])
            }
            // Sorted + interchanged + exact = JDS (with or without the
            // final dim reduction, which only flattens the allocation).
            (Some(NStarMat::Exact), true, true, _) => {
                Ok(vec![Plan::serial(Layout::Jds { permuted: true }, Traversal::DiagMajor)])
            }
            // Unsorted + interchanged + exact = unpermuted jagged.
            (Some(NStarMat::Exact), false, true, _) => {
                Ok(vec![Plan::serial(Layout::Jds { permuted: false }, Traversal::DiagMajor)])
            }
            // Sorted without interchange: CSR with permuted rows — the
            // permutation only reorders row visits; storage is CSR-like.
            (Some(NStarMat::Exact), true, false, reduced) => {
                let _ = reduced;
                let layout = if s.split { Layout::Csr } else { Layout::CsrAos };
                Ok(vec![Plan::serial(layout, Traversal::RowWise)])
            }
            (None, ..) => Err(NotConcretizable("row nest needs ℕ* materialization or stays COO")),
            (Some(NStarMat::Padded), _, _, true) => {
                Err(NotConcretizable("padded sequences cannot be dimensionality-reduced"))
            }
        },
        Orth::Col => match (s.nstar, s.dim_reduced) {
            (None, false) => {
                let layout = if s.split {
                    Layout::CooSoa(CooOrder::ColMajor)
                } else {
                    Layout::CooAos(CooOrder::ColMajor)
                };
                Ok(vec![Plan::serial(layout, Traversal::Flat)])
            }
            (Some(NStarMat::Exact), _) => {
                let layout = if s.split { Layout::Csc } else { Layout::CscAos };
                Ok(vec![Plan::serial(layout, Traversal::ColScatter)])
            }
            _ => Err(NotConcretizable("column nest variant not generated")),
        },
        Orth::RowCol => {
            // Un-blocked (row,col) orthogonalization materializes to the
            // row-major grouped sequence (one tuple per (i,j) group).
            let layout = if s.split {
                Layout::CooSoa(CooOrder::RowMajor)
            } else {
                Layout::CooAos(CooOrder::RowMajor)
            };
            Ok(vec![Plan::serial(layout, Traversal::Flat)])
        }
        Orth::None => Err(NotConcretizable("unreachable: dependent without orthogonalization")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Kernel;
    use crate::forelem::ir::{ChainState, NStarMat, Orth};
    use crate::transforms::{self, Step};

    fn state(steps: &[Step]) -> ChainState {
        transforms::apply_chain(Kernel::Spmv, steps).unwrap()
    }

    #[test]
    fn unmaterialized_not_concretizable() {
        let s = ChainState::initial(Kernel::Spmv);
        assert!(plans(&s).is_err());
    }

    #[test]
    fn fig8_chain_yields_itpack() {
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Padded),
            Step::Interchange,
        ]);
        let p = plans(&s).unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].layout, Layout::Ell(crate::storage::EllOrder::ColMajor));
        assert_eq!(p[0].layout.literature_name(), "ITPACK/ELLPACK (column-major)");
    }

    #[test]
    fn csr_and_csc_chains() {
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Exact),
            Step::DimReduce,
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::Csr);

        let s = state(&[
            Step::Orthogonalize(Orth::Col),
            Step::Materialize,
            Step::NStar(NStarMat::Exact),
            Step::DimReduce,
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::CscAos);
    }

    #[test]
    fn jds_requires_sort_and_interchange() {
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStarSort,
            Step::NStar(NStarMat::Exact),
            Step::Interchange,
            Step::DimReduce,
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::Jds { permuted: true });
    }

    #[test]
    fn padded_rowmajor_has_two_executables() {
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Padded),
        ]);
        let p = plans(&s).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].layout, p[1].layout);
        assert_ne!(p[0].traversal, p[1].traversal);
    }

    #[test]
    fn sorted_row_slice_yields_sell_sigma() {
        // The SELL-σ derivation: block(slice) → materialize → nstar_sort.
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Block(transforms::BlockStep::RowSlice32),
            Step::Materialize,
            Step::NStarSort,
        ]);
        let p = plans(&s).unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].layout, Layout::SellSigma { s: 32, sigma: 256 });
        assert_eq!(p[0].traversal, Traversal::SlicePlane);
        assert_eq!(p[0].layout.slug(), "sell32s256");
        assert_eq!(p[0].layout.literature_name(), "row-sorted Sliced ELLPACK (SELL-\u{3c3})");
        // Unsorted slicing still maps to plain SELL.
        let plain = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Block(transforms::BlockStep::RowSlice32),
            Step::Materialize,
        ]);
        assert_eq!(plans(&plain).unwrap()[0].layout, Layout::Sell { s: 32 });
        // Slice-aligned σ windows (σ = 8·s) are a lock-free output
        // split, so the litmus format sits in the scheduled pool…
        let par = Schedule::Parallel { threads: 4 };
        assert!(schedule_legal(
            Layout::SellSigma { s: 32, sigma: 256 },
            Traversal::SlicePlane,
            par,
            Kernel::Spmv
        ));
        assert!(schedule_legal(
            Layout::SellSigma { s: 32, sigma: 256 },
            Traversal::SlicePlane,
            Schedule::Serial,
            Kernel::Spmm
        ));
        // …but an unaligned window cuts a slice: serial-only, and no
        // schedule ever tiles or TrSv-reschedules the permuted format.
        assert!(!schedule_legal(
            Layout::SellSigma { s: 32, sigma: 40 },
            Traversal::SlicePlane,
            par,
            Kernel::Spmv
        ));
        assert!(!schedule_legal(
            Layout::SellSigma { s: 32, sigma: 256 },
            Traversal::SlicePlane,
            Schedule::Tiled { x_block: 4096 },
            Kernel::Spmv
        ));
        assert!(!schedule_legal(
            Layout::SellSigma { s: 32, sigma: 256 },
            Traversal::SlicePlane,
            par,
            Kernel::Trsv
        ));
    }

    #[test]
    fn blocked_states() {
        let s = state(&[
            Step::Orthogonalize(Orth::RowCol),
            Step::Block(transforms::BlockStep::Tile3x3),
            Step::Materialize,
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::Bcsr { br: 3, bc: 3 });

        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Block(transforms::BlockStep::FillCutoff),
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::HybridEllCoo);
    }

    #[test]
    fn plans_are_serial_by_default() {
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Exact),
            Step::DimReduce,
        ]);
        for p in plans(&s).unwrap() {
            assert_eq!(p.schedule, Schedule::Serial);
        }
    }

    #[test]
    fn schedule_legality_prunes_per_kernel() {
        use Traversal::RowWise;
        let par = Schedule::Parallel { threads: 4 };
        let tiled = Schedule::Tiled { x_block: 4096 };
        // TrSv reschedules only onto the level-capable SoA formats.
        assert!(schedule_legal(Layout::Csr, RowWise, par, Kernel::Trsv));
        assert!(schedule_legal(Layout::Csc, Traversal::ColScatter, par, Kernel::Trsv));
        assert!(!schedule_legal(Layout::CsrAos, RowWise, par, Kernel::Trsv));
        assert!(!schedule_legal(Layout::Ell(EllOrder::RowMajor), RowWise, par, Kernel::Trsv));
        assert!(!schedule_legal(Layout::Csr, RowWise, tiled, Kernel::Trsv));
        assert!(!schedule_legal(
            Layout::Csr,
            RowWise,
            Schedule::ParallelTiled { threads: 4, x_block: 4096 },
            Kernel::Trsv
        ));
        assert!(schedule_legal(Layout::Csr, RowWise, Schedule::Serial, Kernel::Trsv));
        // Parallel only for row-partitionable layouts.
        assert!(schedule_legal(Layout::Csr, RowWise, par, Kernel::Spmv));
        assert!(schedule_legal(Layout::Sell { s: 32 }, Traversal::SlicePlane, par, Kernel::Spmm));
        assert!(schedule_legal(Layout::Bcsr { br: 2, bc: 2 }, Traversal::Blocked, par, Kernel::Spmv));
        assert!(schedule_legal(Layout::Jds { permuted: true }, Traversal::DiagMajor, par, Kernel::Spmv));
        assert!(!schedule_legal(Layout::Jds { permuted: false }, Traversal::DiagMajor, par, Kernel::Spmv));
        assert!(!schedule_legal(Layout::Csc, Traversal::ColScatter, par, Kernel::Spmv));
        assert!(!schedule_legal(Layout::Dia, Traversal::DiagMajor, par, Kernel::Spmv));
        // The padded ELL traversal would duplicate the exact-length
        // parallel executor — pruned.
        assert!(schedule_legal(Layout::Ell(EllOrder::RowMajor), RowWise, par, Kernel::Spmv));
        assert!(!schedule_legal(
            Layout::Ell(EllOrder::RowMajor),
            Traversal::RowWisePadded,
            par,
            Kernel::Spmv
        ));
        // Tiled SpMV is the CSR band gather; tiled SpMM is the B-panel
        // sweep of the register-blocked micro-kernel formats.
        assert!(schedule_legal(Layout::Csr, RowWise, tiled, Kernel::Spmv));
        assert!(schedule_legal(Layout::Csr, RowWise, tiled, Kernel::Spmm));
        assert!(schedule_legal(Layout::Bcsr { br: 2, bc: 2 }, Traversal::Blocked, tiled, Kernel::Spmm));
        assert!(!schedule_legal(Layout::Bcsr { br: 2, bc: 2 }, Traversal::Blocked, tiled, Kernel::Spmv));
        assert!(!schedule_legal(Layout::Ell(EllOrder::RowMajor), RowWise, tiled, Kernel::Spmv));
        assert!(!schedule_legal(Layout::Ell(EllOrder::RowMajor), RowWise, tiled, Kernel::Spmm));
        let pt = Schedule::ParallelTiled { threads: 4, x_block: 4096 };
        assert!(schedule_legal(Layout::Csr, RowWise, pt, Kernel::Spmv));
        assert!(schedule_legal(Layout::Csr, RowWise, pt, Kernel::Spmm));
        assert!(!schedule_legal(Layout::Sell { s: 8 }, Traversal::SlicePlane, pt, Kernel::Spmm));
    }

    #[test]
    fn lane_legality_gates_by_format_and_schedule() {
        use Traversal::RowWise;
        let ser = Schedule::Serial;
        let par = Schedule::Parallel { threads: 4 };
        // Scalar is legal everywhere — every plan has a scalar body.
        assert!(lane_legal(Layout::Dia, Traversal::DiagMajor, ser, 1, Kernel::Spmv));
        assert!(lane_legal(Layout::Csr, RowWise, ser, 1, Kernel::Trsv));
        // Only the monomorphized widths exist.
        for bad in [0, 2, 3, 5, 16] {
            assert!(!lane_legal(Layout::Csr, RowWise, ser, bad, Kernel::Spmv), "lanes={bad}");
        }
        // SpMV: CSR / ELL row-wise and slice-aligned SELL-σ vectorize.
        assert!(lane_legal(Layout::Csr, RowWise, ser, 4, Kernel::Spmv));
        assert!(lane_legal(Layout::Csr, RowWise, par, 8, Kernel::Spmv));
        assert!(lane_legal(Layout::Ell(EllOrder::RowMajor), RowWise, ser, 8, Kernel::Spmv));
        assert!(lane_legal(Layout::Ell(EllOrder::ColMajor), RowWise, par, 4, Kernel::Spmv));
        assert!(lane_legal(
            Layout::SellSigma { s: 32, sigma: 256 },
            Traversal::SlicePlane,
            ser,
            8,
            Kernel::Spmv
        ));
        // …but a slice height the lane group doesn't tile stays scalar.
        assert!(!lane_legal(
            Layout::SellSigma { s: 6, sigma: 48 },
            Traversal::SlicePlane,
            ser,
            4,
            Kernel::Spmv
        ));
        // Scatter/padded/other shapes don't vectorize.
        assert!(!lane_legal(Layout::Csc, Traversal::ColScatter, ser, 4, Kernel::Spmv));
        assert!(!lane_legal(Layout::Ell(EllOrder::RowMajor), Traversal::RowWisePadded, ser, 4, Kernel::Spmv));
        assert!(!lane_legal(Layout::Sell { s: 32 }, Traversal::SlicePlane, ser, 4, Kernel::Spmv));
        // SpMM widens only the CSR micro-kernel; TrSv never.
        assert!(lane_legal(Layout::Csr, RowWise, ser, 8, Kernel::Spmm));
        assert!(!lane_legal(Layout::Bcsr { br: 2, bc: 2 }, Traversal::Blocked, ser, 8, Kernel::Spmm));
        assert!(!lane_legal(Layout::Csr, RowWise, par, 4, Kernel::Trsv));
        // The band/panel sweeps don't compose with the lane axis.
        assert!(!lane_legal(Layout::Csr, RowWise, Schedule::Tiled { x_block: 4096 }, 4, Kernel::Spmv));
        assert!(!lane_legal(
            Layout::Csr,
            RowWise,
            Schedule::ParallelTiled { threads: 4, x_block: 4096 },
            8,
            Kernel::Spmm
        ));
    }
}
