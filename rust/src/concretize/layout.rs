//! Concretization, stage 1 (paper §6.2.1): map a fully-transformed chain
//! state onto a *physical* storage layout + traversal schedule. This is
//! the one-to-one mapping of the materialized symbolic `PA` sequences
//! onto allocated arrays; `exec.rs` then builds the arrays from the
//! tuple reservoir and binds the generated loop nest.

use crate::forelem::ir::{Blocking, ChainState, NStarMat, Orth};
use crate::storage::{CooOrder, EllOrder};

/// Physical storage layout descriptor — the "generated data structure".
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Layout {
    CooAos(CooOrder),
    CooSoa(CooOrder),
    Csr,
    CsrAos,
    Csc,
    CscAos,
    /// Padded rectangular; order = ITPACK direction after interchange.
    Ell(EllOrder),
    /// Jagged diagonal; `permuted` = ℕ* sorting applied.
    Jds { permuted: bool },
    Bcsr { br: usize, bc: usize },
    HybridEllCoo,
    /// Sliced ELLPACK with slice height `s`.
    Sell { s: usize },
    Dia,
}

impl Layout {
    /// Literature name, where one exists (paper §6.2.2).
    pub fn literature_name(&self) -> &'static str {
        match self {
            Layout::CooAos(_) | Layout::CooSoa(_) => "coordinate (COO)",
            Layout::Csr | Layout::CsrAos => "Compressed Row Storage (CSR)",
            Layout::Csc | Layout::CscAos => "Compressed Column Storage (CCS)",
            Layout::Ell(EllOrder::ColMajor) => "ITPACK/ELLPACK (column-major)",
            Layout::Ell(EllOrder::RowMajor) => "ELLPACK (row-major)",
            Layout::Jds { permuted: true } => "Jagged Diagonal Storage (JDS)",
            Layout::Jds { permuted: false } => "unpermuted jagged storage",
            Layout::Bcsr { .. } => "Blocked CSR (BCSR)",
            Layout::HybridEllCoo => "hybrid ELL+COO",
            Layout::Sell { .. } => "Sliced ELLPACK (SELL)",
            Layout::Dia => "diagonal storage (DIA)",
        }
    }
}

/// Traversal schedule of the generated loop nest over the layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Traversal {
    /// Flat walk over a single materialized sequence.
    Flat,
    /// Row loop outer, exact lengths inner.
    RowWise,
    /// Row loop outer, padded width inner (branch-free).
    RowWisePadded,
    /// Slot loop outer (post-interchange / ITPACK schedule).
    PlaneWise,
    /// Jagged-diagonal-major.
    DiagMajor,
    /// Column loop outer, scatter into the output.
    ColScatter,
    /// Block-row loop with dense micro-kernel.
    Blocked,
    /// Slice loop outer, per-slice padded plane loops (SELL schedule).
    SlicePlane,
}

/// A concretization plan: what to allocate and how to walk it.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Plan {
    pub layout: Layout,
    pub traversal: Traversal,
}

#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum ConcretizeError {
    #[error("state not concretizable: {0}")]
    NotConcretizable(&'static str),
}

/// Map a chain state to its concretization plan(s). Most states map to
/// exactly one plan; padded-ELL row-major admits two traversals (exact
/// and branch-free padded) — both are returned and become distinct
/// *executables* over the same *data structure*, mirroring the paper's
/// 130-executables / 25-structures distinction.
pub fn plans(s: &ChainState) -> Result<Vec<Plan>, ConcretizeError> {
    use ConcretizeError::NotConcretizable;
    let Some(dependent) = s.materialized else {
        return Err(NotConcretizable("materialization is a prerequisite of concretization"));
    };

    // Blocked states first.
    if let Some(b) = s.blocked {
        return match b {
            Blocking::Tile { br, bc } => Ok(vec![Plan {
                layout: Layout::Bcsr { br, bc },
                traversal: Traversal::Blocked,
            }]),
            Blocking::FillCutoff => Ok(vec![Plan {
                layout: Layout::HybridEllCoo,
                traversal: Traversal::RowWise,
            }]),
            Blocking::RowSlice { s } => Ok(vec![Plan {
                layout: Layout::Sell { s },
                traversal: Traversal::SlicePlane,
            }]),
        };
    }

    if !dependent {
        // Loop-independent materialization: a single flat sequence.
        let order = CooOrder::Unsorted;
        let layout = if s.split { Layout::CooSoa(order) } else { Layout::CooAos(order) };
        return Ok(vec![Plan { layout, traversal: Traversal::Flat }]);
    }

    match s.orth {
        Orth::Diag => Ok(vec![Plan { layout: Layout::Dia, traversal: Traversal::DiagMajor }]),
        Orth::Row => match (s.nstar, s.sorted, s.interchanged, s.dim_reduced) {
            // No ℕ* materialization: grouped flat sequence (row-major COO).
            (None, false, false, false) => {
                let layout = if s.split {
                    Layout::CooSoa(CooOrder::RowMajor)
                } else {
                    Layout::CooAos(CooOrder::RowMajor)
                };
                Ok(vec![Plan { layout, traversal: Traversal::Flat }])
            }
            // Exact ℕ* + dim reduction = CSR.
            (Some(NStarMat::Exact), false, false, true) => {
                let layout = if s.split { Layout::Csr } else { Layout::CsrAos };
                Ok(vec![Plan { layout, traversal: Traversal::RowWise }])
            }
            // Exact ℕ* without dim reduction: nested sequences —
            // physically CSR arrays, same traversal (allocation detail).
            (Some(NStarMat::Exact), false, false, false) => {
                let layout = if s.split { Layout::Csr } else { Layout::CsrAos };
                Ok(vec![Plan { layout, traversal: Traversal::RowWise }])
            }
            // Padded, no interchange: ELL row-major; two executables.
            (Some(NStarMat::Padded), false, false, false) => Ok(vec![
                Plan { layout: Layout::Ell(EllOrder::RowMajor), traversal: Traversal::RowWise },
                Plan { layout: Layout::Ell(EllOrder::RowMajor), traversal: Traversal::RowWisePadded },
            ]),
            // Padded + interchange: ITPACK plane-wise.
            (Some(NStarMat::Padded), false, true, false) => Ok(vec![Plan {
                layout: Layout::Ell(EllOrder::ColMajor),
                traversal: Traversal::PlaneWise,
            }]),
            // Padded + sorted (+ maybe interchange): sorted ELL — treat
            // sorted padded rows as JDS-adjacent; plane-wise schedule.
            (Some(NStarMat::Padded), true, xch, false) => {
                let _ = xch;
                Ok(vec![Plan {
                    layout: Layout::Jds { permuted: true },
                    traversal: Traversal::DiagMajor,
                }])
            }
            // Sorted + interchanged + exact = JDS (with or without the
            // final dim reduction, which only flattens the allocation).
            (Some(NStarMat::Exact), true, true, _) => Ok(vec![Plan {
                layout: Layout::Jds { permuted: true },
                traversal: Traversal::DiagMajor,
            }]),
            // Unsorted + interchanged + exact = unpermuted jagged.
            (Some(NStarMat::Exact), false, true, _) => Ok(vec![Plan {
                layout: Layout::Jds { permuted: false },
                traversal: Traversal::DiagMajor,
            }]),
            // Sorted without interchange: CSR with permuted rows — the
            // permutation only reorders row visits; storage is CSR-like.
            (Some(NStarMat::Exact), true, false, reduced) => {
                let _ = reduced;
                let layout = if s.split { Layout::Csr } else { Layout::CsrAos };
                Ok(vec![Plan { layout, traversal: Traversal::RowWise }])
            }
            (None, ..) => Err(NotConcretizable("row nest needs ℕ* materialization or stays COO")),
            (Some(NStarMat::Padded), _, _, true) => {
                Err(NotConcretizable("padded sequences cannot be dimensionality-reduced"))
            }
        },
        Orth::Col => match (s.nstar, s.dim_reduced) {
            (None, false) => {
                let layout = if s.split {
                    Layout::CooSoa(CooOrder::ColMajor)
                } else {
                    Layout::CooAos(CooOrder::ColMajor)
                };
                Ok(vec![Plan { layout, traversal: Traversal::Flat }])
            }
            (Some(NStarMat::Exact), _) => {
                let layout = if s.split { Layout::Csc } else { Layout::CscAos };
                Ok(vec![Plan { layout, traversal: Traversal::ColScatter }])
            }
            _ => Err(NotConcretizable("column nest variant not generated")),
        },
        Orth::RowCol => {
            // Un-blocked (row,col) orthogonalization materializes to the
            // row-major grouped sequence (one tuple per (i,j) group).
            let layout = if s.split {
                Layout::CooSoa(CooOrder::RowMajor)
            } else {
                Layout::CooAos(CooOrder::RowMajor)
            };
            Ok(vec![Plan { layout, traversal: Traversal::Flat }])
        }
        Orth::None => Err(NotConcretizable("unreachable: dependent without orthogonalization")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::Kernel;
    use crate::forelem::ir::{ChainState, NStarMat, Orth};
    use crate::transforms::{self, Step};

    fn state(steps: &[Step]) -> ChainState {
        transforms::apply_chain(Kernel::Spmv, steps).unwrap()
    }

    #[test]
    fn unmaterialized_not_concretizable() {
        let s = ChainState::initial(Kernel::Spmv);
        assert!(plans(&s).is_err());
    }

    #[test]
    fn fig8_chain_yields_itpack() {
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Padded),
            Step::Interchange,
        ]);
        let p = plans(&s).unwrap();
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].layout, Layout::Ell(crate::storage::EllOrder::ColMajor));
        assert_eq!(p[0].layout.literature_name(), "ITPACK/ELLPACK (column-major)");
    }

    #[test]
    fn csr_and_csc_chains() {
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Exact),
            Step::DimReduce,
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::Csr);

        let s = state(&[
            Step::Orthogonalize(Orth::Col),
            Step::Materialize,
            Step::NStar(NStarMat::Exact),
            Step::DimReduce,
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::CscAos);
    }

    #[test]
    fn jds_requires_sort_and_interchange() {
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStarSort,
            Step::NStar(NStarMat::Exact),
            Step::Interchange,
            Step::DimReduce,
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::Jds { permuted: true });
    }

    #[test]
    fn padded_rowmajor_has_two_executables() {
        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Split,
            Step::NStar(NStarMat::Padded),
        ]);
        let p = plans(&s).unwrap();
        assert_eq!(p.len(), 2);
        assert_eq!(p[0].layout, p[1].layout);
        assert_ne!(p[0].traversal, p[1].traversal);
    }

    #[test]
    fn blocked_states() {
        let s = state(&[
            Step::Orthogonalize(Orth::RowCol),
            Step::Block(transforms::BlockStep::Tile3x3),
            Step::Materialize,
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::Bcsr { br: 3, bc: 3 });

        let s = state(&[
            Step::Orthogonalize(Orth::Row),
            Step::Materialize,
            Step::Block(transforms::BlockStep::FillCutoff),
        ]);
        assert_eq!(plans(&s).unwrap()[0].layout, Layout::HybridEllCoo);
    }
}
