//! Concretization, stage 2: build the physical storage from the tuple
//! reservoir and bind the generated loop nest as an executor. A
//! `Prepared` value is "the automatically instantiated routine +
//! reassembled data structure" of the paper — ready to run on the
//! native backend.
//!
//! Execution is layered (see `storage::ops` for the full picture):
//! the **registry** ([`build_ops`]) is the single place a [`Layout`] is
//! bound to its storage builder, yielding an `Arc<dyn SparseOps>`; the
//! **schedule drivers** on [`Prepared`] then map the plan's
//! [`Schedule`] onto the trait — serial nest, nnz-balanced parallel
//! ranges, cache-blocked band sweep, or B-panel sweep. There is no
//! schedule × storage × kernel match pyramid left here: formats are
//! behind the trait, schedules are one `match` each.
//!
//! [`prepare_many`] is the plan-keyed **storage cache**: the sweep's
//! shortlist typically contains several schedule/traversal variants of
//! the same layout, and the cache builds each distinct
//! `(layout, matrix)` storage exactly once, sharing it (`Arc`) across
//! all its variants — a large constant-factor win for the
//! predict→measure pipeline's prepare phase.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, OnceLock};

use crate::baselines::Kernel;
use crate::concretize::layout::{lane_legal, schedule_legal, Layout, Plan, Schedule, Traversal};
use crate::kernels::levels::LevelSets;
use crate::kernels::par;
use crate::matrix::TriMat;
use crate::storage::*;

/// The format registry — the one place a `Layout` is bound to its
/// storage builder. Adding a format = one `SparseOps` impl
/// (`storage::ops`) + one arm here + its chain in `layout::plans`.
pub fn build_ops(layout: Layout, m: &TriMat) -> Arc<dyn SparseOps> {
    match layout {
        Layout::CooAos(order) => Arc::new(CooAos::from_tuples(m, order)),
        Layout::CooSoa(order) => Arc::new(CooSoa::from_tuples(m, order)),
        Layout::Csr => Arc::new(Csr::from_tuples(m)),
        Layout::CsrAos => Arc::new(CsrAos::from_tuples(m)),
        Layout::Csc => Arc::new(Csc::from_tuples(m)),
        Layout::CscAos => Arc::new(CscAos::from_tuples(m)),
        Layout::Ell(order) => Arc::new(Ell::from_tuples(m, order)),
        Layout::Jds { permuted } => {
            let jds = Jds::from_tuples(m, permuted);
            let rows = JdsRows::build(&jds, m);
            Arc::new(JdsOps { jds, rows })
        }
        Layout::Bcsr { br, bc } => Arc::new(Bcsr::from_tuples(m, br, bc)),
        Layout::HybridEllCoo => {
            Arc::new(HybridEllCoo::from_tuples(m, None, EllOrder::ColMajor))
        }
        Layout::Sell { s } => Arc::new(Sell::from_tuples(m, s)),
        Layout::SellSigma { s, sigma } => Arc::new(SellSigma::from_tuples(m, s, sigma)),
        Layout::Dia => Arc::new(Dia::from_tuples(m)),
    }
}

/// A concretized routine + data structure, bound to a matrix.
pub struct Prepared {
    pub plan: Plan,
    /// The format storage behind the `SparseOps` trait — `Arc`-shared
    /// across schedule/traversal variants by the `prepare_many` cache.
    pub ops: Arc<dyn SparseOps>,
    /// Per-band CSR row splits for `Schedule::Tiled` /
    /// `Schedule::ParallelTiled` SpMV plans — part of the generated
    /// data structure. Built once on first SpMV use (or eagerly via
    /// [`Prepared::ensure_bands`]): a tiled plan prepared for SpMM
    /// sweeps B panels and never reads them, so building eagerly would
    /// waste O(nbands × nrows) per SpMM-only prepare.
    bands: OnceLock<Option<CsrBands>>,
    /// Dependence level sets for `Schedule::Parallel` TrSv plans.
    /// Built on demand (`ensure_levels` hoists the build out of timed
    /// regions); `OnceLock` so sharing a `Prepared` across threads
    /// stays safe.
    levels: OnceLock<LevelSets>,
    pub nrows: usize,
    pub ncols: usize,
}

/// Which kernels a plan's generated loop nest supports (TrSv requires a
/// dependence-respecting traversal; SpMM is generated for every layout
/// the SpMV nest covers except DIA, which the tree prunes for SpMM).
/// The plan's schedule must also be legal for the kernel
/// (`layout::schedule_legal`): TrSv reschedules only onto the
/// level-capable compressed formats, and non-serial SpMV/SpMM
/// schedules exist only for row-partitionable layouts.
pub fn supports(plan: &Plan, kernel: Kernel) -> bool {
    if !schedule_legal(plan.layout, plan.traversal, plan.schedule, kernel) {
        return false;
    }
    if !lane_legal(plan.layout, plan.traversal, plan.schedule, plan.lanes, kernel) {
        return false;
    }
    match kernel {
        Kernel::Spmv => true,
        Kernel::Spmm => !matches!(plan.layout, Layout::Dia),
        Kernel::Trsv => matches!(
            (plan.layout, plan.traversal),
            (Layout::Csr, Traversal::RowWise)
                | (Layout::CsrAos, Traversal::RowWise)
                | (Layout::Csc, Traversal::ColScatter)
                | (Layout::CscAos, Traversal::ColScatter)
                | (Layout::CooAos(CooOrder::RowMajor), Traversal::Flat)
                | (Layout::Ell(_), Traversal::RowWise)
                | (Layout::HybridEllCoo, Traversal::RowWise)
        ),
    }
}

/// Dense-column panel width of a `Tiled`/`ParallelTiled` SpMM plan.
/// The schedule's `x_block` knob is a byte budget for the gathered
/// operand band; for SpMM the gathered operand is a B row per visited
/// slot, so the panel spans a few cache lines (the default
/// `x_block = 4096` gives 32 columns = 256 B) — narrow enough that a
/// mean row's worth of B panels stays L1-resident at the paper's
/// k = 100, wide enough for the 4-wide register-blocked micro-kernel.
pub fn spmm_panel_cols(x_block: usize, k: usize) -> usize {
    (x_block / 128).max(4).min(k.max(1))
}

fn with_ops(plan: Plan, m: &TriMat, ops: Arc<dyn SparseOps>) -> Prepared {
    Prepared {
        plan,
        ops,
        bands: OnceLock::new(),
        levels: OnceLock::new(),
        nrows: m.nrows,
        ncols: m.ncols,
    }
}

/// Bind an already-built storage (a delta-repaired one, from
/// `SparseOps::repair`) to a plan — the `engine::version` seam. The
/// auxiliary `OnceLock`s start empty on purpose: band splits and TrSv
/// level sets derived from the *pre-delta* storage are stale by
/// construction, so the repaired generation re-derives them lazily
/// from its own structure (this is what makes "level-set patching"
/// honest — the patched CSR rebuilds its levels on first solve).
pub(crate) fn prepared_from_ops(
    plan: Plan,
    nrows: usize,
    ncols: usize,
    ops: Arc<dyn SparseOps>,
) -> Prepared {
    Prepared { plan, ops, bands: OnceLock::new(), levels: OnceLock::new(), nrows, ncols }
}

/// Build the storage for a plan from the tuple reservoir.
///
/// Internal seam: this is the post-selection half of the pipeline.
/// Library users should go through `crate::engine::Engine::compile`,
/// which picks the plan, shares storage across repeated compiles and
/// returns the serving-ready `Executable`; `prepare` remains public
/// for the engine, the sweep's exhaustive path, and tests.
pub fn prepare(plan: Plan, m: &TriMat) -> Prepared {
    with_ops(plan, m, build_ops(plan.layout, m))
}

/// Fallible [`prepare`]: validates the reservoir first and isolates a
/// panicking storage build behind `catch_unwind`, returning a typed
/// error either way. This is the seam for callers that must not crash
/// on a hostile reservoir or a format bug (the engine's candidate
/// preparation, embedding hosts driving `concretize` directly).
pub fn try_prepare(plan: Plan, m: &TriMat) -> Result<Prepared, crate::error::ForelemError> {
    m.validate()?;
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prepare(plan, m))).map_err(|_| {
        crate::error::ForelemError::UnsupportedPlan {
            plan_id: format!("{plan:?}"),
            reason: "storage build panicked".into(),
        }
    })
}

/// Build the storage for many plans against the same reservoir in
/// parallel. This is the plan-keyed storage cache: each distinct
/// layout's storage is assembled exactly once (`build_ops`) and shared
/// (`Arc`) across every schedule/traversal variant that uses it, so a
/// predict→measure shortlist with, say, five CSR variants pays for one
/// CSR build. Assembly runs on all cores while *measurement* stays
/// single-threaded per the paper protocol.
pub fn prepare_many(plans: &[Plan], m: &TriMat, workers: usize) -> Vec<Prepared> {
    prepare_many_counted(plans, m, workers).0
}

/// [`prepare_many`] plus the number of storages actually built — the
/// observable the cache tests pin (`builds == distinct layouts`).
pub fn prepare_many_counted(
    plans: &[Plan],
    m: &TriMat,
    workers: usize,
) -> (Vec<Prepared>, usize) {
    let mut layouts: Vec<Layout> = Vec::new();
    for p in plans {
        if !layouts.contains(&p.layout) {
            layouts.push(p.layout);
        }
    }
    let builds = AtomicUsize::new(0);
    let built: Vec<Arc<dyn SparseOps>> =
        crate::util::pool::parallel_map(layouts.len(), workers.max(1), |i| {
            builds.fetch_add(1, Ordering::Relaxed);
            build_ops(layouts[i], m)
        });
    let prepared = crate::util::pool::parallel_map(plans.len(), workers.max(1), |i| {
        let plan = plans[i];
        let li = layouts.iter().position(|l| *l == plan.layout).expect("layout interned above");
        with_ops(plan, m, Arc::clone(&built[li]))
    });
    (prepared, builds.into_inner())
}

impl Prepared {
    /// Total bytes of the generated data structure, including the
    /// tiled schedules' per-band row splits and (once built) the level
    /// sets of a parallel TrSv plan.
    pub fn bytes(&self) -> usize {
        self.ops.bytes()
            + self.bands.get().and_then(|b| b.as_ref()).map_or(0, |b| b.bytes())
            + self.levels.get().map_or(0, |l| l.bytes())
    }

    fn tile_width(&self) -> Option<usize> {
        match self.plan.schedule {
            Schedule::Tiled { x_block } => Some(x_block),
            Schedule::ParallelTiled { x_block, .. } => Some(x_block),
            _ => None,
        }
    }

    /// The tiled plan's per-band row splits, built on first call
    /// (formats without a band structure — and non-tiled plans —
    /// return `None` and fall back to their serial/panel nests).
    pub fn bands(&self) -> Option<&CsrBands> {
        self.bands
            .get_or_init(|| self.tile_width().and_then(|xb| self.ops.build_bands(xb)))
            .as_ref()
    }

    /// Build the tiled-SpMV band splits now (idempotent) so a timed
    /// run doesn't pay for them.
    pub fn ensure_bands(&self) {
        let _ = self.bands();
    }

    /// Build the TrSv level sets now (idempotent) so a timed solve
    /// doesn't pay for them. No-op unless this is a level-scheduled
    /// TrSv plan (`Parallel` over a level-capable format).
    pub fn ensure_levels(&self) {
        if self.levels.get().is_some()
            || !matches!(self.plan.schedule, Schedule::Parallel { .. })
            || !supports(&self.plan, Kernel::Trsv)
        {
            return;
        }
        if let Some(lv) = self.ops.build_levels() {
            let _ = self.levels.set(lv);
        }
    }

    /// NUMA first-touch pass: re-walk each of this plan's parallel
    /// partition ranges on the crew worker that will later execute it
    /// (`util::pool` dispatches task `i` to worker `i % crew` — the
    /// same deterministic mapping every serve uses), so the
    /// kernel-visible pages of the generated structure are
    /// first-touch-placed on that worker's NUMA node. The walk is a
    /// zero-operand `spmv_range` into scratch output, split by exactly
    /// the nnz-balanced ranges of the serving drivers.
    ///
    /// A no-op for serial/tiled plans, for formats whose parallel
    /// drivers own a scatter split instead of the contiguous range
    /// kernels ([`SparseOps::has_range_kernels`]), and whenever the
    /// balance collapses to one range. Callers gate on
    /// `runtime::topology::numa_active()` — on a single-node machine
    /// the pass is placement-neutral (the engine skips it to keep
    /// prepare latency flat). Idempotent and side-effect-free on the
    /// structure itself: results stay bit-identical (pinned by tests).
    pub fn first_touch(&self) {
        let threads = match self.plan.schedule {
            Schedule::Parallel { threads } => threads,
            Schedule::ParallelTiled { threads, .. } => threads,
            _ => return,
        };
        if !self.ops.has_range_kernels() {
            return;
        }
        let ops = &*self.ops;
        let ranges =
            par::balanced_ranges(ops.par_units(), threads, |u| ops.unit_weight_prefix(u));
        if ranges.len() <= 1 {
            return;
        }
        let t = self.plan.traversal;
        let x = vec![0.0; self.ncols.max(1)];
        let mut y = vec![0.0; self.nrows];
        let chunks = par::chunks_for(&mut y, &ranges, ops.rows_per_unit());
        let mut tasks = Vec::with_capacity(ranges.len());
        let xr: &[f64] = &x;
        for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
            tasks.push(move || ops.spmv_range(t, xr, chunk, lo, hi));
        }
        crate::util::pool::scoped_run(tasks);
    }

    /// Run the generated SpMV under the plan's schedule (and vector
    /// width: `lanes > 1` plans — `lane_legal` admits them only under
    /// `Serial`/`Parallel` — route through the `kernels::simd`
    /// micro-kernels via the trait's lane hooks).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let t = self.plan.traversal;
        let lanes = self.plan.lanes;
        match self.plan.schedule {
            Schedule::Serial if lanes > 1 => self.ops.spmv_serial_lanes(t, x, y, lanes),
            Schedule::Parallel { threads } if lanes > 1 => {
                self.ops.spmv_parallel_lanes(t, x, y, threads, lanes)
            }
            Schedule::Serial => self.ops.spmv_serial(t, x, y),
            Schedule::Parallel { threads } => self.ops.spmv_parallel(t, x, y, threads),
            Schedule::Tiled { .. } => match self.bands() {
                Some(bands) => self.ops.spmv_tiled(bands, x, y),
                None => self.ops.spmv_serial(t, x, y),
            },
            Schedule::ParallelTiled { threads, .. } => match self.bands() {
                Some(bands) => self.ops.spmv_parallel_tiled(bands, x, y, threads),
                None => self.ops.spmv_parallel(t, x, y, threads),
            },
        }
    }

    /// Run the generated SpMM (`b` is ncols×k row-major) under the
    /// plan's schedule. Tiled schedules sweep B/C column panels so the
    /// gathered B-row granule stays L1-resident.
    pub fn spmm(&self, b: &[f64], k: usize, c: &mut [f64]) {
        let t = self.plan.traversal;
        let lanes = self.plan.lanes;
        match self.plan.schedule {
            Schedule::Serial if lanes > 1 => self.ops.spmm_serial_lanes(t, b, k, c, lanes),
            Schedule::Parallel { threads } if lanes > 1 => {
                self.ops.spmm_parallel_lanes(t, b, k, c, threads, lanes)
            }
            Schedule::Serial => self.ops.spmm_serial(t, b, k, c),
            Schedule::Parallel { threads } => self.ops.spmm_parallel(t, b, k, c, threads),
            Schedule::Tiled { x_block } => spmm_tiled(&*self.ops, t, b, k, c, x_block),
            Schedule::ParallelTiled { threads, x_block } => {
                spmm_parallel_tiled(&*self.ops, t, b, k, c, threads, x_block)
            }
        }
    }

    /// Run the generated unit-lower TrSv (storage holds strictly-lower
    /// L). Parallel plans execute the barrier-light level schedule over
    /// the level sets built at prepare time.
    pub fn trsv(&self, b: &[f64], x: &mut [f64]) {
        match self.plan.schedule {
            Schedule::Parallel { threads } => {
                let lv = self.levels.get_or_init(|| {
                    self.ops
                        .build_levels()
                        .expect("schedule_legal admits parallel TrSv only with level sets")
                });
                self.ops.trsv_level(lv, b, x, threads);
            }
            _ => self.ops.trsv_serial(b, x),
        }
    }
}

/// Serial B-panel sweep (`Schedule::Tiled` SpMM).
fn spmm_tiled(ops: &dyn SparseOps, t: Traversal, b: &[f64], k: usize, c: &mut [f64], xb: usize) {
    if !ops.supports_spmm_panel() || k == 0 {
        return ops.spmm_serial(t, b, k, c);
    }
    let panel = spmm_panel_cols(xb, k);
    if panel >= k {
        return ops.spmm_serial(t, b, k, c);
    }
    let units = ops.par_units();
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + panel).min(k);
        ops.spmm_panel(t, b, k, c, k0..k1, 0..units);
        k0 = k1;
    }
}

/// Parallel rows × B-panel sweep (`Schedule::ParallelTiled` SpMM):
/// nnz-balanced unit ranges, each worker sweeping its chunk panel by
/// panel.
fn spmm_parallel_tiled(
    ops: &dyn SparseOps,
    t: Traversal,
    b: &[f64],
    k: usize,
    c: &mut [f64],
    threads: usize,
    xb: usize,
) {
    if !ops.supports_spmm_panel() || k == 0 {
        return ops.spmm_parallel(t, b, k, c, threads);
    }
    let ranges = par::balanced_ranges(ops.par_units(), threads, |u| ops.unit_weight_prefix(u));
    if ranges.len() <= 1 {
        return spmm_tiled(ops, t, b, k, c, xb);
    }
    let panel = spmm_panel_cols(xb, k);
    let chunks = par::chunks_for(c, &ranges, ops.rows_per_unit() * k);
    let mut tasks = Vec::with_capacity(ranges.len());
    for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || {
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + panel).min(k);
                ops.spmm_panel(t, b, k, chunk, k0..k1, lo..hi);
                k0 = k1;
            }
        });
    }
    crate::util::pool::scoped_run(tasks);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    fn all_spmv_plans() -> Vec<Plan> {
        use crate::storage::{CooOrder, EllOrder};
        vec![
            Plan::serial(Layout::CooAos(CooOrder::Unsorted), Traversal::Flat),
            Plan::serial(Layout::CooSoa(CooOrder::RowMajor), Traversal::Flat),
            Plan::serial(Layout::Csr, Traversal::RowWise),
            Plan::serial(Layout::CsrAos, Traversal::RowWise),
            Plan::serial(Layout::Csc, Traversal::ColScatter),
            Plan::serial(Layout::CscAos, Traversal::ColScatter),
            Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWise),
            Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWisePadded),
            Plan::serial(Layout::Ell(EllOrder::ColMajor), Traversal::PlaneWise),
            Plan::serial(Layout::Jds { permuted: true }, Traversal::DiagMajor),
            Plan::serial(Layout::Jds { permuted: false }, Traversal::DiagMajor),
            Plan::serial(Layout::Bcsr { br: 2, bc: 3 }, Traversal::Blocked),
            Plan::serial(Layout::HybridEllCoo, Traversal::RowWise),
            Plan::serial(Layout::SellSigma { s: 8, sigma: 64 }, Traversal::SlicePlane),
            Plan::serial(Layout::Dia, Traversal::DiagMajor),
        ]
    }

    #[test]
    fn every_plan_executes_spmv_correctly() {
        let m = gen::powerlaw(45, 2.0, 22, 60);
        let x: Vec<f64> = (0..45).map(|i| (i as f64 * 0.11).sin() + 0.7).collect();
        let want = m.spmv_ref(&x);
        for plan in all_spmv_plans() {
            let p = prepare(plan, &m);
            let mut y = vec![0.0; 45];
            p.spmv(&x, &mut y);
            assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
    }

    #[test]
    fn every_supporting_plan_executes_spmm() {
        let m = gen::uniform_random(20, 26, 140, 61);
        let k = 4;
        let b: Vec<f64> = (0..26 * k).map(|i| i as f64 * 0.05 - 1.0).collect();
        let want = m.spmm_ref(&b, k);
        for plan in all_spmv_plans() {
            if !supports(&plan, Kernel::Spmm) {
                continue;
            }
            let p = prepare(plan, &m);
            let mut c = vec![0.0; 20 * k];
            p.spmm(&b, k, &mut c);
            assert_close(&c, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
    }

    #[test]
    fn every_supporting_plan_executes_trsv() {
        let m = gen::uniform_random(30, 30, 200, 62);
        let l = m.strictly_lower();
        let bvec: Vec<f64> = (0..30).map(|i| 1.0 - i as f64 * 0.03).collect();
        let want = l.trsv_unit_lower_ref(&bvec);
        let mut count = 0;
        for plan in all_spmv_plans() {
            if !supports(&plan, Kernel::Trsv) {
                continue;
            }
            count += 1;
            let p = prepare(plan, &l);
            let mut x = vec![0.0; 30];
            p.trsv(&bvec, &mut x);
            assert_close(&x, &want, 1e-9).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
        assert!(count >= 5, "expected several TrSv-capable plans, got {count}");
    }

    #[test]
    fn level_scheduled_trsv_matches_serial() {
        let m = gen::uniform_random(40, 40, 320, 67);
        let l = m.strictly_lower();
        let bvec: Vec<f64> = (0..40).map(|i| (i as f64 * 0.21).cos()).collect();
        let want = l.trsv_unit_lower_ref(&bvec);
        let par = Schedule::Parallel { threads: 4 };
        let mut ran = 0;
        for base in all_spmv_plans() {
            let plan = base.with_schedule(par);
            if !supports(&plan, Kernel::Trsv) {
                continue;
            }
            ran += 1;
            let p = prepare(plan, &l);
            p.ensure_levels();
            assert!(p.levels.get().is_some(), "{plan:?}: levels not built by ensure_levels");
            let bytes_with_levels = p.bytes();
            assert!(bytes_with_levels > p.ops.bytes(), "{plan:?}: levels not in bytes()");
            let mut x = vec![0.0; 40];
            p.trsv(&bvec, &mut x);
            assert_close(&x, &want, 1e-9).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
        assert_eq!(ran, 2, "expected the CSR and CSC level-scheduled TrSv plans");
    }

    #[test]
    fn trsv_non_serial_only_for_level_capable_layouts() {
        let par = Schedule::Parallel { threads: 2 };
        for base in all_spmv_plans() {
            let plan = base.with_schedule(par);
            let legal = supports(&plan, Kernel::Trsv);
            let expected =
                matches!(plan.layout, Layout::Csr | Layout::Csc) && supports(&base, Kernel::Trsv);
            assert_eq!(legal, expected, "{plan:?}");
        }
        // Tiling never applies to TrSv.
        for base in all_spmv_plans() {
            let tiled = base.with_schedule(Schedule::Tiled { x_block: 64 });
            assert!(!supports(&tiled, Kernel::Trsv), "{tiled:?}");
        }
    }

    #[test]
    fn prepare_many_matches_serial_prepare() {
        let m = gen::powerlaw(40, 2.0, 20, 66);
        let plans = all_spmv_plans();
        let many = prepare_many(&plans, &m, 4);
        assert_eq!(many.len(), plans.len());
        let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.13).sin() + 0.5).collect();
        let want = m.spmv_ref(&x);
        for (plan, p) in plans.iter().zip(&many) {
            assert_eq!(p.plan, *plan);
            let serial = prepare(*plan, &m);
            assert_eq!(p.bytes(), serial.bytes(), "{plan:?}: bytes differ");
            let mut y = vec![0.0; 40];
            p.spmv(&x, &mut y);
            assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
    }

    #[test]
    fn storage_cache_builds_each_layout_once() {
        let m = gen::uniform_random(30, 30, 180, 68);
        // Five CSR variants + two ELL variants + one SELL: 3 layouts.
        let plans = vec![
            Plan::serial(Layout::Csr, Traversal::RowWise),
            Plan::serial(Layout::Csr, Traversal::RowWise)
                .with_schedule(Schedule::Parallel { threads: 3 }),
            Plan::serial(Layout::Csr, Traversal::RowWise)
                .with_schedule(Schedule::Tiled { x_block: 8 }),
            Plan::serial(Layout::Csr, Traversal::RowWise)
                .with_schedule(Schedule::ParallelTiled { threads: 3, x_block: 8 }),
            Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWise),
            Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWisePadded),
            Plan::serial(Layout::Sell { s: 4 }, Traversal::SlicePlane),
        ];
        let (prepared, builds) = prepare_many_counted(&plans, &m, 4);
        assert_eq!(builds, 3, "storage built more than once per distinct layout");
        // All CSR variants share one storage; the two ELL traversals too.
        for i in 1..4 {
            assert!(Arc::ptr_eq(&prepared[0].ops, &prepared[i].ops), "CSR not shared at {i}");
        }
        assert!(Arc::ptr_eq(&prepared[4].ops, &prepared[5].ops), "ELL not shared");
        assert!(!Arc::ptr_eq(&prepared[0].ops, &prepared[4].ops));
        // Tiled variants still get their own bands (lazily, per plan).
        assert!(prepared[0].bands().is_none());
        assert!(prepared[2].bands().is_some());
        assert!(prepared[3].bands().is_some());
    }

    #[test]
    fn shared_storage_results_are_bit_identical_to_fresh_prepare() {
        let m = gen::powerlaw(36, 2.0, 18, 69);
        let x: Vec<f64> = (0..36).map(|i| (i as f64 * 0.17).sin() - 0.2).collect();
        let schedules = [
            Schedule::Serial,
            Schedule::Parallel { threads: 3 },
            Schedule::Tiled { x_block: 8 },
            Schedule::ParallelTiled { threads: 2, x_block: 8 },
        ];
        let plans: Vec<Plan> = schedules
            .iter()
            .map(|&s| Plan::serial(Layout::Csr, Traversal::RowWise).with_schedule(s))
            .collect();
        let shared = prepare_many(&plans, &m, 4);
        for (plan, p) in plans.iter().zip(&shared) {
            let fresh = prepare(*plan, &m);
            let mut y_shared = vec![0.0; 36];
            let mut y_fresh = vec![0.0; 36];
            p.spmv(&x, &mut y_shared);
            fresh.spmv(&x, &mut y_fresh);
            assert_eq!(y_shared, y_fresh, "{plan:?}: shared storage changed the result bits");
        }
    }

    /// The first-touch contract: the pass only *reads* the structure
    /// and writes scratch, so results stay bit-identical to an
    /// untouched prepare — on every legal plan, including the formats
    /// that skip it (no range kernels) and serial plans (no-op).
    #[test]
    fn first_touch_is_result_neutral() {
        let m = gen::powerlaw(48, 2.0, 24, 72);
        let x: Vec<f64> = (0..48).map(|i| (i as f64 * 0.13).cos() + 0.2).collect();
        let schedules = [
            Schedule::Serial,
            Schedule::Parallel { threads: 3 },
            Schedule::ParallelTiled { threads: 3, x_block: 16 },
        ];
        let mut parallel_touched = 0;
        for base in all_spmv_plans() {
            for sch in schedules {
                let plan = base.with_schedule(sch);
                if !supports(&plan, Kernel::Spmv) {
                    continue;
                }
                let touched = prepare(plan, &m);
                touched.first_touch();
                touched.first_touch(); // idempotent
                if !matches!(sch, Schedule::Serial) && touched.ops.has_range_kernels() {
                    parallel_touched += 1;
                }
                let fresh = prepare(plan, &m);
                let (mut y_t, mut y_f) = (vec![0.0; 48], vec![0.0; 48]);
                touched.spmv(&x, &mut y_t);
                fresh.spmv(&x, &mut y_f);
                assert_eq!(y_t, y_f, "{plan:?}: first_touch changed the result bits");
            }
        }
        assert!(parallel_touched >= 4, "too few range-backed parallel plans: {parallel_touched}");
    }

    #[test]
    fn storage_bytes_positive() {
        let m = gen::banded(30, 3, 0.8, 63);
        for plan in all_spmv_plans() {
            let p = prepare(plan, &m);
            assert!(p.ops.bytes() > 0);
            assert_eq!(p.ops.slug(), plan.layout.slug(), "{plan:?}: slug drifted");
        }
    }

    #[test]
    fn every_legal_schedule_executes_spmv_correctly() {
        let m = gen::powerlaw(52, 2.0, 26, 64);
        let x: Vec<f64> = (0..52).map(|i| (i as f64 * 0.19).cos() + 0.3).collect();
        let want = m.spmv_ref(&x);
        let schedules = [
            Schedule::Parallel { threads: 3 },
            Schedule::Tiled { x_block: 16 },
            Schedule::ParallelTiled { threads: 3, x_block: 16 },
        ];
        let mut ran = 0;
        for base in all_spmv_plans() {
            for sch in schedules {
                let plan = base.with_schedule(sch);
                if !supports(&plan, Kernel::Spmv) {
                    continue;
                }
                ran += 1;
                let p = prepare(plan, &m);
                if matches!(sch, Schedule::Tiled { .. } | Schedule::ParallelTiled { .. }) {
                    p.ensure_bands();
                    assert!(p.bands().is_some(), "{plan:?}: tiled plan has no band splits");
                }
                let mut y = vec![0.0; 52];
                p.spmv(&x, &mut y);
                assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
            }
        }
        assert!(ran >= 7, "too few scheduled plans exercised: {ran}");
    }

    #[test]
    fn every_legal_schedule_executes_spmm_correctly() {
        let m = gen::uniform_random(24, 31, 160, 65);
        let k = 6;
        let b: Vec<f64> = (0..31 * k).map(|i| i as f64 * 0.04 - 0.6).collect();
        let want = m.spmm_ref(&b, k);
        let schedules = [
            Schedule::Parallel { threads: 4 },
            Schedule::Tiled { x_block: 256 },
            Schedule::ParallelTiled { threads: 3, x_block: 256 },
        ];
        let mut panel_ran = 0;
        for base in all_spmv_plans() {
            for sch in schedules {
                let plan = base.with_schedule(sch);
                if !supports(&plan, Kernel::Spmm) {
                    continue;
                }
                if !matches!(sch, Schedule::Parallel { .. }) {
                    panel_ran += 1;
                }
                let p = prepare(plan, &m);
                let mut c = vec![0.0; 24 * k];
                p.spmm(&b, k, &mut c);
                assert_close(&c, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
            }
        }
        // CSR and BCSR × {Tiled, ParallelTiled}.
        assert_eq!(panel_ran, 4, "B-panel SpMM plans missing from the space");
    }

    #[test]
    fn every_legal_lane_plan_executes_spmv_and_spmm() {
        let m = gen::uniform_random(50, 50, 500, 71);
        let x: Vec<f64> = (0..50).map(|i| (i as f64 * 0.23).sin() + 0.4).collect();
        let want = m.spmv_ref(&x);
        let k = 9; // odd, so the widened axpy exercises its remainder
        let b: Vec<f64> = (0..50 * k).map(|i| i as f64 * 0.03 - 0.8).collect();
        let want_c = m.spmm_ref(&b, k);
        let schedules = [Schedule::Serial, Schedule::Parallel { threads: 3 }];
        let mut ran = 0;
        for base in all_spmv_plans() {
            for sch in schedules {
                for lanes in [4usize, 8] {
                    let plan = base.with_schedule(sch).with_lanes(lanes);
                    if !supports(&plan, Kernel::Spmv) {
                        continue;
                    }
                    ran += 1;
                    let p = prepare(plan, &m);
                    let mut y = vec![0.0; 50];
                    p.spmv(&x, &mut y);
                    assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
                    if supports(&plan, Kernel::Spmm) {
                        let mut c = vec![0.0; 50 * k];
                        p.spmm(&b, k, &mut c);
                        assert_close(&c, &want_c, 1e-10)
                            .unwrap_or_else(|e| panic!("{plan:?} spmm: {e}"));
                    }
                }
            }
        }
        // CSR + ELL row-wise + SELL-σ (s = 8: both widths divide it),
        // each × {Serial, Parallel} × {4, 8}.
        assert_eq!(ran, 12, "lane plan coverage drifted");
    }

    #[test]
    fn lane_plans_gate_through_supports() {
        let csr = Plan::serial(Layout::Csr, Traversal::RowWise);
        assert!(supports(&csr.with_lanes(4), Kernel::Spmv));
        assert!(supports(&csr.with_lanes(8), Kernel::Spmm));
        assert!(!supports(&csr.with_lanes(4), Kernel::Trsv));
        assert!(!supports(&csr.with_lanes(3), Kernel::Spmv));
        assert!(!supports(
            &csr.with_schedule(Schedule::Tiled { x_block: 64 }).with_lanes(4),
            Kernel::Spmv
        ));
        let dia = Plan::serial(Layout::Dia, Traversal::DiagMajor);
        assert!(!supports(&dia.with_lanes(4), Kernel::Spmv));
    }

    #[test]
    fn spmm_panel_cols_is_sane() {
        assert_eq!(spmm_panel_cols(4096, 100), 32);
        assert_eq!(spmm_panel_cols(4096, 16), 16); // clamped to k
        assert_eq!(spmm_panel_cols(64, 100), 4); // floor of 4 columns
        assert_eq!(spmm_panel_cols(4096, 1), 1); // k = 1 degenerates
    }
}
