//! Concretization, stage 2: build the physical storage from the tuple
//! reservoir and bind the generated loop nest as an executor. A
//! `Prepared` value is "the automatically instantiated routine +
//! reassembled data structure" of the paper — ready to run on the
//! native backend.

use crate::baselines::Kernel;
use crate::concretize::layout::{schedule_legal, Layout, Plan, Schedule, Traversal};
use crate::kernels::{par, spmm, spmv, trsv};
use crate::matrix::TriMat;
use crate::storage::*;

/// Physical storage instance for a plan.
pub enum Storage {
    CooAos(CooAos),
    CooSoa(CooSoa),
    Csr(Csr),
    CsrAos(CsrAos),
    Csc(Csc),
    CscAos(CscAos),
    Ell(Ell),
    Jds(Jds, JdsRows),
    Bcsr(Bcsr),
    Hybrid(HybridEllCoo),
    Sell(Sell),
    Dia(Dia),
}

impl Storage {
    pub fn bytes(&self) -> usize {
        match self {
            Storage::CooAos(s) => s.bytes(),
            Storage::CooSoa(s) => s.bytes(),
            Storage::Csr(s) => s.bytes(),
            Storage::CsrAos(s) => s.bytes(),
            Storage::Csc(s) => s.bytes(),
            Storage::CscAos(s) => s.bytes(),
            Storage::Ell(s) => s.bytes(),
            Storage::Jds(s, r) => s.bytes() + r.rows.iter().map(|v| v.len() * 4).sum::<usize>(),
            Storage::Bcsr(s) => s.bytes(),
            Storage::Hybrid(s) => s.bytes(),
            Storage::Sell(s) => s.bytes(),
            Storage::Dia(s) => s.bytes(),
        }
    }
}

/// A concretized routine + data structure, bound to a matrix.
pub struct Prepared {
    pub plan: Plan,
    pub storage: Storage,
    /// Per-band CSR row splits for `Schedule::Tiled` /
    /// `Schedule::ParallelTiled` plans — part of the generated data
    /// structure, built once here at prepare time.
    pub bands: Option<CsrBands>,
    pub nrows: usize,
    pub ncols: usize,
}

/// Which kernels a plan's generated loop nest supports (TrSv requires a
/// dependence-respecting traversal; SpMM is generated for every layout
/// the SpMV nest covers except DIA, which the tree prunes for SpMM).
/// The plan's schedule must also be legal for the kernel
/// (`layout::schedule_legal`): TrSv stays `Serial`, and non-serial
/// schedules exist only for row-partitionable layouts.
pub fn supports(plan: &Plan, kernel: Kernel) -> bool {
    if !schedule_legal(plan.layout, plan.traversal, plan.schedule, kernel) {
        return false;
    }
    match kernel {
        Kernel::Spmv => true,
        Kernel::Spmm => !matches!(plan.layout, Layout::Dia),
        Kernel::Trsv => matches!(
            (plan.layout, plan.traversal),
            (Layout::Csr, Traversal::RowWise)
                | (Layout::CsrAos, Traversal::RowWise)
                | (Layout::Csc, Traversal::ColScatter)
                | (Layout::CscAos, Traversal::ColScatter)
                | (Layout::CooAos(CooOrder::RowMajor), Traversal::Flat)
                | (Layout::Ell(_), Traversal::RowWise)
                | (Layout::HybridEllCoo, Traversal::RowWise)
        ),
    }
}

/// Build the storage for a plan from the tuple reservoir.
pub fn prepare(plan: Plan, m: &TriMat) -> Prepared {
    let storage = match plan.layout {
        Layout::CooAos(order) => Storage::CooAos(CooAos::from_tuples(m, order)),
        Layout::CooSoa(order) => Storage::CooSoa(CooSoa::from_tuples(m, order)),
        Layout::Csr => Storage::Csr(Csr::from_tuples(m)),
        Layout::CsrAos => Storage::CsrAos(CsrAos::from_tuples(m)),
        Layout::Csc => Storage::Csc(Csc::from_tuples(m)),
        Layout::CscAos => Storage::CscAos(CscAos::from_tuples(m)),
        Layout::Ell(order) => Storage::Ell(Ell::from_tuples(m, order)),
        Layout::Jds { permuted } => {
            let j = Jds::from_tuples(m, permuted);
            let r = JdsRows::build(&j, m);
            Storage::Jds(j, r)
        }
        Layout::Bcsr { br, bc } => Storage::Bcsr(Bcsr::from_tuples(m, br, bc)),
        Layout::HybridEllCoo => {
            Storage::Hybrid(HybridEllCoo::from_tuples(m, None, EllOrder::ColMajor))
        }
        Layout::Sell { s } => Storage::Sell(Sell::from_tuples(m, s)),
        Layout::Dia => Storage::Dia(Dia::from_tuples(m)),
    };
    // Tiled CSR schedules carry their per-band row splits as part of
    // the generated data structure.
    let x_block = match plan.schedule {
        Schedule::Tiled { x_block } => Some(x_block),
        Schedule::ParallelTiled { x_block, .. } => Some(x_block),
        _ => None,
    };
    let bands = match (&storage, x_block) {
        (Storage::Csr(s), Some(xb)) => Some(CsrBands::build(s, xb)),
        _ => None,
    };
    Prepared { plan, storage, bands, nrows: m.nrows, ncols: m.ncols }
}

/// Build the storage for many plans against the same reservoir in
/// parallel (`util::pool::parallel_map` over plans). Used by the sweep
/// so the large suite's CSR/ELL/SELL planes are assembled on all cores
/// while *measurement* stays single-threaded per the paper protocol.
pub fn prepare_many(plans: &[Plan], m: &TriMat, workers: usize) -> Vec<Prepared> {
    crate::util::pool::parallel_map(plans.len(), workers.max(1), |i| prepare(plans[i], m))
}

impl Prepared {
    /// Total bytes of the generated data structure, including the
    /// tiled schedules' per-band row splits (part of what the plan
    /// allocates, unlike the transient workspace of e.g. permuted JDS).
    pub fn bytes(&self) -> usize {
        self.storage.bytes() + self.bands.as_ref().map_or(0, |b| b.bytes())
    }

    /// Run the generated SpMV under the plan's schedule.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        match self.plan.schedule {
            Schedule::Serial => self.spmv_serial(x, y),
            Schedule::Parallel { threads } => match &self.storage {
                Storage::Csr(s) => par::csr_spmv(s, x, y, threads),
                Storage::Ell(s) => par::ell_spmv(s, x, y, threads),
                Storage::Sell(s) => par::sell_spmv(s, x, y, threads),
                Storage::Bcsr(s) => par::bcsr_spmv(s, x, y, threads),
                Storage::Jds(s, _) if s.permuted => par::jds_spmv(s, x, y, threads),
                _ => self.spmv_serial(x, y), // pruned by schedule_legal
            },
            Schedule::Tiled { .. } => match (&self.storage, &self.bands) {
                (Storage::Csr(s), Some(bands)) => par::csr_spmv_tiled(s, bands, x, y),
                _ => self.spmv_serial(x, y),
            },
            Schedule::ParallelTiled { threads, .. } => match (&self.storage, &self.bands) {
                (Storage::Csr(s), Some(bands)) => {
                    par::csr_spmv_parallel_tiled(s, bands, x, y, threads)
                }
                _ => self.spmv_serial(x, y),
            },
        }
    }

    /// The serial loop nest (the paper's single-core executors).
    fn spmv_serial(&self, x: &[f64], y: &mut [f64]) {
        match (&self.storage, self.plan.traversal) {
            (Storage::CooAos(s), _) => spmv::coo_aos(s, x, y),
            (Storage::CooSoa(s), _) => spmv::coo_soa(s, x, y),
            (Storage::Csr(s), _) => spmv::csr(s, x, y),
            (Storage::CsrAos(s), _) => spmv::csr_aos(s, x, y),
            (Storage::Csc(s), _) => spmv::csc(s, x, y),
            (Storage::CscAos(s), _) => spmv::csc_aos(s, x, y),
            (Storage::Ell(s), Traversal::RowWisePadded) => spmv::ell_rowwise_padded(s, x, y),
            (Storage::Ell(s), Traversal::PlaneWise) => spmv::ell_planewise(s, x, y),
            (Storage::Ell(s), _) => spmv::ell_rowwise(s, x, y),
            (Storage::Jds(s, _), _) if s.permuted => spmv::jds_permuted(s, x, y),
            (Storage::Jds(s, r), _) => spmv::jds(s, r, x, y),
            (Storage::Bcsr(s), _) => spmv::bcsr(s, x, y),
            (Storage::Hybrid(s), _) => spmv::hybrid(s, x, y),
            (Storage::Sell(s), _) => crate::storage::sell::spmv(s, x, y),
            (Storage::Dia(s), _) => spmv::dia(s, x, y),
        }
    }

    /// Run the generated SpMM (`b` is ncols×k row-major) under the
    /// plan's schedule.
    pub fn spmm(&self, b: &[f64], k: usize, c: &mut [f64]) {
        match self.plan.schedule {
            // Tiling is only generated for the SpMV gather; a tiled
            // plan asked for SpMM falls back to the serial nest.
            Schedule::Serial | Schedule::Tiled { .. } => self.spmm_serial(b, k, c),
            Schedule::Parallel { threads } | Schedule::ParallelTiled { threads, .. } => {
                match &self.storage {
                    Storage::Csr(s) => par::csr_spmm(s, b, k, c, threads),
                    Storage::Ell(s) => par::ell_spmm(s, b, k, c, threads),
                    Storage::Sell(s) => par::sell_spmm(s, b, k, c, threads),
                    Storage::Bcsr(s) => par::bcsr_spmm(s, b, k, c, threads),
                    Storage::Jds(s, _) if s.permuted => par::jds_spmm(s, b, k, c, threads),
                    _ => self.spmm_serial(b, k, c), // pruned by schedule_legal
                }
            }
        }
    }

    fn spmm_serial(&self, b: &[f64], k: usize, c: &mut [f64]) {
        match (&self.storage, self.plan.traversal) {
            (Storage::CooAos(s), _) => spmm::coo_aos(s, b, k, c),
            (Storage::CooSoa(s), _) => spmm::coo_soa(s, b, k, c),
            (Storage::Csr(s), _) => spmm::csr(s, b, k, c),
            (Storage::CsrAos(s), _) => spmm::csr_aos(s, b, k, c),
            (Storage::Csc(s), _) => spmm::csc(s, b, k, c),
            (Storage::CscAos(s), _) => spmm::csc_aos(s, b, k, c),
            (Storage::Ell(s), Traversal::PlaneWise) => spmm::ell_planewise(s, b, k, c),
            (Storage::Ell(s), _) => spmm::ell_rowwise(s, b, k, c),
            (Storage::Jds(s, r), _) => spmm::jds(s, r, b, k, c),
            (Storage::Bcsr(s), _) => spmm::bcsr(s, b, k, c),
            (Storage::Hybrid(s), _) => spmm::hybrid(s, b, k, c),
            (Storage::Sell(s), _) => crate::storage::sell::spmm(s, b, k, c),
            (Storage::Dia(_), _) => panic!("SpMM over DIA pruned by the tree"),
        }
    }

    /// Run the generated unit-lower TrSv (storage holds strictly-lower L).
    pub fn trsv(&self, b: &[f64], x: &mut [f64]) {
        match &self.storage {
            Storage::Csr(s) => trsv::csr(s, b, x),
            Storage::CsrAos(s) => trsv::csr_aos(s, b, x),
            Storage::Csc(s) => trsv::csc(s, b, x),
            Storage::CscAos(s) => trsv::csc_aos(s, b, x),
            Storage::CooAos(s) => trsv::coo_rowmajor(s, b, x),
            Storage::Ell(s) => trsv::ell_rowwise(s, b, x),
            Storage::Hybrid(s) => trsv::hybrid(s, b, x),
            _ => panic!("TrSv unsupported for this plan (checked by supports())"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    fn all_spmv_plans() -> Vec<Plan> {
        use crate::storage::{CooOrder, EllOrder};
        vec![
            Plan::serial(Layout::CooAos(CooOrder::Unsorted), Traversal::Flat),
            Plan::serial(Layout::CooSoa(CooOrder::RowMajor), Traversal::Flat),
            Plan::serial(Layout::Csr, Traversal::RowWise),
            Plan::serial(Layout::CsrAos, Traversal::RowWise),
            Plan::serial(Layout::Csc, Traversal::ColScatter),
            Plan::serial(Layout::CscAos, Traversal::ColScatter),
            Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWise),
            Plan::serial(Layout::Ell(EllOrder::RowMajor), Traversal::RowWisePadded),
            Plan::serial(Layout::Ell(EllOrder::ColMajor), Traversal::PlaneWise),
            Plan::serial(Layout::Jds { permuted: true }, Traversal::DiagMajor),
            Plan::serial(Layout::Jds { permuted: false }, Traversal::DiagMajor),
            Plan::serial(Layout::Bcsr { br: 2, bc: 3 }, Traversal::Blocked),
            Plan::serial(Layout::HybridEllCoo, Traversal::RowWise),
            Plan::serial(Layout::Dia, Traversal::DiagMajor),
        ]
    }

    #[test]
    fn every_plan_executes_spmv_correctly() {
        let m = gen::powerlaw(45, 2.0, 22, 60);
        let x: Vec<f64> = (0..45).map(|i| (i as f64 * 0.11).sin() + 0.7).collect();
        let want = m.spmv_ref(&x);
        for plan in all_spmv_plans() {
            let p = prepare(plan, &m);
            let mut y = vec![0.0; 45];
            p.spmv(&x, &mut y);
            assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
    }

    #[test]
    fn every_supporting_plan_executes_spmm() {
        let m = gen::uniform_random(20, 26, 140, 61);
        let k = 4;
        let b: Vec<f64> = (0..26 * k).map(|i| i as f64 * 0.05 - 1.0).collect();
        let want = m.spmm_ref(&b, k);
        for plan in all_spmv_plans() {
            if !supports(&plan, Kernel::Spmm) {
                continue;
            }
            let p = prepare(plan, &m);
            let mut c = vec![0.0; 20 * k];
            p.spmm(&b, k, &mut c);
            assert_close(&c, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
    }

    #[test]
    fn every_supporting_plan_executes_trsv() {
        let m = gen::uniform_random(30, 30, 200, 62);
        let l = m.strictly_lower();
        let bvec: Vec<f64> = (0..30).map(|i| 1.0 - i as f64 * 0.03).collect();
        let want = l.trsv_unit_lower_ref(&bvec);
        let mut count = 0;
        for plan in all_spmv_plans() {
            if !supports(&plan, Kernel::Trsv) {
                continue;
            }
            count += 1;
            let p = prepare(plan, &l);
            let mut x = vec![0.0; 30];
            p.trsv(&bvec, &mut x);
            assert_close(&x, &want, 1e-9).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
        assert!(count >= 5, "expected several TrSv-capable plans, got {count}");
    }

    #[test]
    fn prepare_many_matches_serial_prepare() {
        let m = gen::powerlaw(40, 2.0, 20, 66);
        let plans = all_spmv_plans();
        let many = prepare_many(&plans, &m, 4);
        assert_eq!(many.len(), plans.len());
        let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.13).sin() + 0.5).collect();
        let want = m.spmv_ref(&x);
        for (plan, p) in plans.iter().zip(&many) {
            assert_eq!(p.plan, *plan);
            let serial = prepare(*plan, &m);
            assert_eq!(p.bytes(), serial.bytes(), "{plan:?}: bytes differ");
            let mut y = vec![0.0; 40];
            p.spmv(&x, &mut y);
            assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
    }

    #[test]
    fn storage_bytes_positive() {
        let m = gen::banded(30, 3, 0.8, 63);
        for plan in all_spmv_plans() {
            let p = prepare(plan, &m);
            assert!(p.storage.bytes() > 0);
        }
    }

    #[test]
    fn every_legal_schedule_executes_spmv_correctly() {
        let m = gen::powerlaw(52, 2.0, 26, 64);
        let x: Vec<f64> = (0..52).map(|i| (i as f64 * 0.19).cos() + 0.3).collect();
        let want = m.spmv_ref(&x);
        let schedules = [
            Schedule::Parallel { threads: 3 },
            Schedule::Tiled { x_block: 16 },
            Schedule::ParallelTiled { threads: 3, x_block: 16 },
        ];
        let mut ran = 0;
        for base in all_spmv_plans() {
            for sch in schedules {
                let plan = base.with_schedule(sch);
                if !supports(&plan, Kernel::Spmv) {
                    continue;
                }
                ran += 1;
                let p = prepare(plan, &m);
                if matches!(sch, Schedule::Tiled { .. } | Schedule::ParallelTiled { .. }) {
                    assert!(p.bands.is_some(), "{plan:?}: bands not built at prepare time");
                }
                let mut y = vec![0.0; 52];
                p.spmv(&x, &mut y);
                assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
            }
        }
        assert!(ran >= 7, "too few scheduled plans exercised: {ran}");
    }

    #[test]
    fn every_legal_schedule_executes_spmm_correctly() {
        let m = gen::uniform_random(24, 31, 160, 65);
        let k = 6;
        let b: Vec<f64> = (0..31 * k).map(|i| i as f64 * 0.04 - 0.6).collect();
        let want = m.spmm_ref(&b, k);
        for base in all_spmv_plans() {
            let plan = base.with_schedule(Schedule::Parallel { threads: 4 });
            if !supports(&plan, Kernel::Spmm) {
                continue;
            }
            let p = prepare(plan, &m);
            let mut c = vec![0.0; 24 * k];
            p.spmm(&b, k, &mut c);
            assert_close(&c, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
    }

    #[test]
    fn trsv_rejects_non_serial_schedules() {
        for base in all_spmv_plans() {
            let par = base.with_schedule(Schedule::Parallel { threads: 2 });
            assert!(!supports(&par, Kernel::Trsv), "{par:?}");
        }
    }
}
