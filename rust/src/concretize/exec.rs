//! Concretization, stage 2: build the physical storage from the tuple
//! reservoir and bind the generated loop nest as an executor. A
//! `Prepared` value is "the automatically instantiated routine +
//! reassembled data structure" of the paper — ready to run on the
//! native backend.

use crate::baselines::Kernel;
use crate::concretize::layout::{Layout, Plan, Traversal};
use crate::kernels::{spmm, spmv, trsv};
use crate::matrix::TriMat;
use crate::storage::*;

/// Physical storage instance for a plan.
pub enum Storage {
    CooAos(CooAos),
    CooSoa(CooSoa),
    Csr(Csr),
    CsrAos(CsrAos),
    Csc(Csc),
    CscAos(CscAos),
    Ell(Ell),
    Jds(Jds, JdsRows),
    Bcsr(Bcsr),
    Hybrid(HybridEllCoo),
    Sell(Sell),
    Dia(Dia),
}

impl Storage {
    pub fn bytes(&self) -> usize {
        match self {
            Storage::CooAos(s) => s.bytes(),
            Storage::CooSoa(s) => s.bytes(),
            Storage::Csr(s) => s.bytes(),
            Storage::CsrAos(s) => s.bytes(),
            Storage::Csc(s) => s.bytes(),
            Storage::CscAos(s) => s.bytes(),
            Storage::Ell(s) => s.bytes(),
            Storage::Jds(s, r) => s.bytes() + r.rows.iter().map(|v| v.len() * 4).sum::<usize>(),
            Storage::Bcsr(s) => s.bytes(),
            Storage::Hybrid(s) => s.bytes(),
            Storage::Sell(s) => s.bytes(),
            Storage::Dia(s) => s.bytes(),
        }
    }
}

/// A concretized routine + data structure, bound to a matrix.
pub struct Prepared {
    pub plan: Plan,
    pub storage: Storage,
    pub nrows: usize,
    pub ncols: usize,
}

/// Which kernels a plan's generated loop nest supports (TrSv requires a
/// dependence-respecting traversal; SpMM is generated for every layout
/// the SpMV nest covers except DIA, which the tree prunes for SpMM).
pub fn supports(plan: &Plan, kernel: Kernel) -> bool {
    match kernel {
        Kernel::Spmv => true,
        Kernel::Spmm => !matches!(plan.layout, Layout::Dia),
        Kernel::Trsv => matches!(
            (plan.layout, plan.traversal),
            (Layout::Csr, Traversal::RowWise)
                | (Layout::CsrAos, Traversal::RowWise)
                | (Layout::Csc, Traversal::ColScatter)
                | (Layout::CscAos, Traversal::ColScatter)
                | (Layout::CooAos(CooOrder::RowMajor), Traversal::Flat)
                | (Layout::Ell(_), Traversal::RowWise)
                | (Layout::HybridEllCoo, Traversal::RowWise)
        ),
    }
}

/// Build the storage for a plan from the tuple reservoir.
pub fn prepare(plan: Plan, m: &TriMat) -> Prepared {
    let storage = match plan.layout {
        Layout::CooAos(order) => Storage::CooAos(CooAos::from_tuples(m, order)),
        Layout::CooSoa(order) => Storage::CooSoa(CooSoa::from_tuples(m, order)),
        Layout::Csr => Storage::Csr(Csr::from_tuples(m)),
        Layout::CsrAos => Storage::CsrAos(CsrAos::from_tuples(m)),
        Layout::Csc => Storage::Csc(Csc::from_tuples(m)),
        Layout::CscAos => Storage::CscAos(CscAos::from_tuples(m)),
        Layout::Ell(order) => Storage::Ell(Ell::from_tuples(m, order)),
        Layout::Jds { permuted } => {
            let j = Jds::from_tuples(m, permuted);
            let r = JdsRows::build(&j, m);
            Storage::Jds(j, r)
        }
        Layout::Bcsr { br, bc } => Storage::Bcsr(Bcsr::from_tuples(m, br, bc)),
        Layout::HybridEllCoo => {
            Storage::Hybrid(HybridEllCoo::from_tuples(m, None, EllOrder::ColMajor))
        }
        Layout::Sell { s } => Storage::Sell(Sell::from_tuples(m, s)),
        Layout::Dia => Storage::Dia(Dia::from_tuples(m)),
    };
    Prepared { plan, storage, nrows: m.nrows, ncols: m.ncols }
}

impl Prepared {
    /// Run the generated SpMV.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        match (&self.storage, self.plan.traversal) {
            (Storage::CooAos(s), _) => spmv::coo_aos(s, x, y),
            (Storage::CooSoa(s), _) => spmv::coo_soa(s, x, y),
            (Storage::Csr(s), _) => spmv::csr(s, x, y),
            (Storage::CsrAos(s), _) => spmv::csr_aos(s, x, y),
            (Storage::Csc(s), _) => spmv::csc(s, x, y),
            (Storage::CscAos(s), _) => spmv::csc_aos(s, x, y),
            (Storage::Ell(s), Traversal::RowWisePadded) => spmv::ell_rowwise_padded(s, x, y),
            (Storage::Ell(s), Traversal::PlaneWise) => spmv::ell_planewise(s, x, y),
            (Storage::Ell(s), _) => spmv::ell_rowwise(s, x, y),
            (Storage::Jds(s, _), _) if s.permuted => spmv::jds_permuted(s, x, y),
            (Storage::Jds(s, r), _) => spmv::jds(s, r, x, y),
            (Storage::Bcsr(s), _) => spmv::bcsr(s, x, y),
            (Storage::Hybrid(s), _) => spmv::hybrid(s, x, y),
            (Storage::Sell(s), _) => crate::storage::sell::spmv(s, x, y),
            (Storage::Dia(s), _) => spmv::dia(s, x, y),
        }
    }

    /// Run the generated SpMM (`b` is ncols×k row-major).
    pub fn spmm(&self, b: &[f64], k: usize, c: &mut [f64]) {
        match (&self.storage, self.plan.traversal) {
            (Storage::CooAos(s), _) => spmm::coo_aos(s, b, k, c),
            (Storage::CooSoa(s), _) => spmm::coo_soa(s, b, k, c),
            (Storage::Csr(s), _) => spmm::csr(s, b, k, c),
            (Storage::CsrAos(s), _) => spmm::csr_aos(s, b, k, c),
            (Storage::Csc(s), _) => spmm::csc(s, b, k, c),
            (Storage::CscAos(s), _) => spmm::csc_aos(s, b, k, c),
            (Storage::Ell(s), Traversal::PlaneWise) => spmm::ell_planewise(s, b, k, c),
            (Storage::Ell(s), _) => spmm::ell_rowwise(s, b, k, c),
            (Storage::Jds(s, r), _) => spmm::jds(s, r, b, k, c),
            (Storage::Bcsr(s), _) => spmm::bcsr(s, b, k, c),
            (Storage::Hybrid(s), _) => spmm::hybrid(s, b, k, c),
            (Storage::Sell(s), _) => crate::storage::sell::spmm(s, b, k, c),
            (Storage::Dia(_), _) => panic!("SpMM over DIA pruned by the tree"),
        }
    }

    /// Run the generated unit-lower TrSv (storage holds strictly-lower L).
    pub fn trsv(&self, b: &[f64], x: &mut [f64]) {
        match &self.storage {
            Storage::Csr(s) => trsv::csr(s, b, x),
            Storage::CsrAos(s) => trsv::csr_aos(s, b, x),
            Storage::Csc(s) => trsv::csc(s, b, x),
            Storage::CscAos(s) => trsv::csc_aos(s, b, x),
            Storage::CooAos(s) => trsv::coo_rowmajor(s, b, x),
            Storage::Ell(s) => trsv::ell_rowwise(s, b, x),
            Storage::Hybrid(s) => trsv::hybrid(s, b, x),
            _ => panic!("TrSv unsupported for this plan (checked by supports())"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    fn all_spmv_plans() -> Vec<Plan> {
        use crate::storage::{CooOrder, EllOrder};
        vec![
            Plan { layout: Layout::CooAos(CooOrder::Unsorted), traversal: Traversal::Flat },
            Plan { layout: Layout::CooSoa(CooOrder::RowMajor), traversal: Traversal::Flat },
            Plan { layout: Layout::Csr, traversal: Traversal::RowWise },
            Plan { layout: Layout::CsrAos, traversal: Traversal::RowWise },
            Plan { layout: Layout::Csc, traversal: Traversal::ColScatter },
            Plan { layout: Layout::CscAos, traversal: Traversal::ColScatter },
            Plan { layout: Layout::Ell(EllOrder::RowMajor), traversal: Traversal::RowWise },
            Plan { layout: Layout::Ell(EllOrder::RowMajor), traversal: Traversal::RowWisePadded },
            Plan { layout: Layout::Ell(EllOrder::ColMajor), traversal: Traversal::PlaneWise },
            Plan { layout: Layout::Jds { permuted: true }, traversal: Traversal::DiagMajor },
            Plan { layout: Layout::Jds { permuted: false }, traversal: Traversal::DiagMajor },
            Plan { layout: Layout::Bcsr { br: 2, bc: 3 }, traversal: Traversal::Blocked },
            Plan { layout: Layout::HybridEllCoo, traversal: Traversal::RowWise },
            Plan { layout: Layout::Dia, traversal: Traversal::DiagMajor },
        ]
    }

    #[test]
    fn every_plan_executes_spmv_correctly() {
        let m = gen::powerlaw(45, 2.0, 22, 60);
        let x: Vec<f64> = (0..45).map(|i| (i as f64 * 0.11).sin() + 0.7).collect();
        let want = m.spmv_ref(&x);
        for plan in all_spmv_plans() {
            let p = prepare(plan, &m);
            let mut y = vec![0.0; 45];
            p.spmv(&x, &mut y);
            assert_close(&y, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
    }

    #[test]
    fn every_supporting_plan_executes_spmm() {
        let m = gen::uniform_random(20, 26, 140, 61);
        let k = 4;
        let b: Vec<f64> = (0..26 * k).map(|i| i as f64 * 0.05 - 1.0).collect();
        let want = m.spmm_ref(&b, k);
        for plan in all_spmv_plans() {
            if !supports(&plan, Kernel::Spmm) {
                continue;
            }
            let p = prepare(plan, &m);
            let mut c = vec![0.0; 20 * k];
            p.spmm(&b, k, &mut c);
            assert_close(&c, &want, 1e-10).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
    }

    #[test]
    fn every_supporting_plan_executes_trsv() {
        let m = gen::uniform_random(30, 30, 200, 62);
        let l = m.strictly_lower();
        let bvec: Vec<f64> = (0..30).map(|i| 1.0 - i as f64 * 0.03).collect();
        let want = l.trsv_unit_lower_ref(&bvec);
        let mut count = 0;
        for plan in all_spmv_plans() {
            if !supports(&plan, Kernel::Trsv) {
                continue;
            }
            count += 1;
            let p = prepare(plan, &l);
            let mut x = vec![0.0; 30];
            p.trsv(&bvec, &mut x);
            assert_close(&x, &want, 1e-9).unwrap_or_else(|e| panic!("{plan:?}: {e}"));
        }
        assert!(count >= 5, "expected several TrSv-capable plans, got {count}");
    }

    #[test]
    fn storage_bytes_positive() {
        let m = gen::banded(30, 3, 0.8, 63);
        for plan in all_spmv_plans() {
            let p = prepare(plan, &m);
            assert!(p.storage.bytes() > 0);
        }
    }
}
