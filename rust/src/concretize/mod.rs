//! Concretization (paper §6.2.1): the one-to-one mapping of materialized
//! loop structures and symbolic `PA` sequences onto physically allocated
//! arrays + executable loops. Three stages: `layout` (state → plan),
//! `exec` (plan + reservoir → storage + bound executor), `codegen`
//! (plan → inspectable C-like source text).

pub mod codegen;
pub mod exec;
pub mod layout;

pub use exec::{prepare, prepare_many, supports, Prepared, Storage};
pub use layout::{plans, schedule_legal, ConcretizeError, Layout, Plan, Schedule, Traversal};
