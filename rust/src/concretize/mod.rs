//! Concretization (paper §6.2.1): the one-to-one mapping of materialized
//! loop structures and symbolic `PA` sequences onto physically allocated
//! arrays + executable loops. Three stages: `layout` (state → plan),
//! `exec` (plan + reservoir → `SparseOps` storage + schedule driver),
//! `codegen` (plan → inspectable C-like source text). The format
//! registry (`exec::build_ops`) and the `storage::ops::SparseOps` trait
//! replace the old per-storage enum dispatch.
//!
//! **Internal plumbing.** Since the `forelem::engine` redesign this
//! module is the engine's backend, not the crate's front door: the
//! free functions re-exported here (`prepare`, `prepare_many`, …) are
//! the thin seam `Engine::compile` (and the sweep's exhaustive path)
//! drive after plan selection. Embedding users should call
//! [`crate::engine::Engine`] — it owns plan selection, calibrated
//! prediction, the process-wide storage cache and autotuning, none of
//! which a bare `prepare` gives you.

pub mod codegen;
pub mod exec;
pub mod layout;

pub use exec::{
    build_ops, prepare, prepare_many, prepare_many_counted, spmm_panel_cols, supports,
    try_prepare, Prepared,
};
pub use layout::{plans, schedule_legal, ConcretizeError, Layout, Plan, Schedule, Traversal};
