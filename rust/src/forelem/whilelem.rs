//! The `whilelem` construct (paper §2.3): an unordered loop whose
//! iterations are re-executed until no enabled iteration changes state,
//! under *just scheduling* (every tuple gets a fair share of execution).
//!
//! This module executes the paper's running example — the sorted-list
//! insertion algorithm over tuples `⟨i, j⟩_V` — in three automatically
//! generated flavours (§2.3.2, §2.3.6, §2.3.7): the array-ordered sweep,
//! the vector-storage ITPACK variant, and the delayed/levelized bulk
//! sort. They demonstrate that the *same* whilelem specification yields
//! different generated codes, all converging to the same fixpoint.

use crate::util::rng::Rng;

/// The tuple reservoir of the sorted-list example: chain tuples
/// `⟨i, i+1⟩` with values `v[i]`; the whilelem body swaps `V(t.i), V(t.j)`
/// whenever `V(t.i) > V(t.j)`.
#[derive(Clone, Debug)]
pub struct ChainReservoir {
    /// `v[i]` — the data tuples; chain tuple k is `⟨k, k+1⟩`.
    pub v: Vec<f64>,
}

impl ChainReservoir {
    pub fn new(v: Vec<f64>) -> Self {
        Self { v }
    }

    pub fn is_sorted(&self) -> bool {
        self.v.windows(2).all(|w| w[0] <= w[1])
    }

    /// §2.3.2 "Array Ordered By Tuple Field Values": repeated ordered
    /// sweeps until a fixpoint — the generated bubble-sort-like code.
    /// Returns the number of whilelem rounds executed.
    pub fn run_array_sweep(&mut self) -> usize {
        let n = self.v.len();
        let mut rounds = 0;
        let mut changed = true;
        while changed {
            changed = false;
            rounds += 1;
            for i in 0..n.saturating_sub(1) {
                if self.v[i] > self.v[i + 1] {
                    self.v.swap(i, i + 1);
                    changed = true;
                }
            }
        }
        rounds
    }

    /// Just scheduling (paper §2.3, [14]): tuples fire in a fair random
    /// order, each round visiting every tuple exactly once in a fresh
    /// permutation — the semantics against which generated codes are
    /// validated. Returns rounds until quiescence.
    pub fn run_just_scheduled(&mut self, rng: &mut Rng) -> usize {
        let n = self.v.len();
        if n < 2 {
            return 0;
        }
        let mut order: Vec<usize> = (0..n - 1).collect();
        let mut rounds = 0;
        loop {
            rng.shuffle(&mut order);
            let mut changed = false;
            rounds += 1;
            for &i in &order {
                if self.v[i] > self.v[i + 1] {
                    self.v.swap(i, i + 1);
                    changed = true;
                }
            }
            if !changed {
                return rounds;
            }
        }
    }

    /// §2.3.7 "Automatic Generation of Sort Algorithms": the levelized
    /// execution strategy — groups whose size doubles every level (the
    /// merge-sort-like schedule). Implemented as the generated code the
    /// paper sketches: level `l` processes tuples within blocks of size
    /// `2^l` to quiescence before the next level.
    pub fn run_levelized(&mut self) -> usize {
        let n = self.v.len();
        let mut total_rounds = 0;
        let mut width = 2usize;
        while width < n * 2 {
            // Within each block, run the whilelem to quiescence.
            for start in (0..n).step_by(width) {
                let end = (start + width).min(n);
                let mut changed = true;
                while changed {
                    changed = false;
                    total_rounds += 1;
                    for i in start..end.saturating_sub(1) {
                        if self.v[i] > self.v[i + 1] {
                            self.v.swap(i, i + 1);
                            changed = true;
                        }
                    }
                }
            }
            width *= 2;
        }
        total_rounds
    }
}

/// §2.3.3 / §2.3.4 — the *linked-list* concretizations of the same
/// whilelem specification: tuples `⟨i, j⟩_V` stored as chain records in
/// an arena. Two generated codes operate on it:
///
/// * `run_swap_values` (§2.3.3) — swap `V(t.i), V(t.j)` through the
///   links ("linked list ordered by tuple field values");
/// * `run_global_substitution` (§2.3.4) — leave the values in place and
///   substitute the *tuple fields* `i, j` in every tuple instead (the
///   special Global Substitution operation), i.e. relink the chain.
#[derive(Clone, Debug)]
pub struct LinkedChain {
    /// `next[r]` — arena index of the successor record (usize::MAX = end).
    pub next: Vec<usize>,
    /// `v[r]` — the data tuple of record r.
    pub v: Vec<f64>,
    /// Arena index of the chain head.
    pub head: usize,
}

impl LinkedChain {
    /// Build a chain whose traversal order is `order` (arena indices)
    /// over values `v`.
    pub fn new(v: Vec<f64>) -> Self {
        let n = v.len();
        let next = (1..=n).map(|i| if i == n { usize::MAX } else { i }).collect();
        LinkedChain { next, v, head: if n == 0 { usize::MAX } else { 0 } }
    }

    /// Read the values in chain order.
    pub fn to_vec(&self) -> Vec<f64> {
        let mut out = Vec::with_capacity(self.v.len());
        let mut r = self.head;
        while r != usize::MAX {
            out.push(self.v[r]);
            r = self.next[r];
        }
        out
    }

    /// §2.3.3 — generated code: walk the chain, swap out-of-order data
    /// values through the links, repeat until quiescent. Returns rounds.
    pub fn run_swap_values(&mut self) -> usize {
        let mut rounds = 0;
        let mut changed = true;
        while changed {
            changed = false;
            rounds += 1;
            let mut r = self.head;
            while r != usize::MAX {
                let nxt = self.next[r];
                if nxt != usize::MAX && self.v[r] > self.v[nxt] {
                    self.v.swap(r, nxt);
                    changed = true;
                }
                r = nxt;
            }
        }
        rounds
    }

    /// §2.3.4 — Global Substitution: substituting `i, j` for `j, i` in
    /// all tuples has the same effect as the value swap, realized by
    /// relinking the records (values never move). The generated
    /// `substitute` walks the whole reservoir, exactly as the paper's
    /// listing does.
    fn substitute(&mut self, a: usize, b: usize) {
        // swap the identities of records a and b in every link field
        for r in 0..self.next.len() {
            let t = self.next[r];
            if t == a {
                self.next[r] = b;
            } else if t == b {
                self.next[r] = a;
            }
        }
        self.next.swap(a, b);
        if self.head == a {
            self.head = b;
        } else if self.head == b {
            self.head = a;
        }
    }

    /// §2.3.4 — generated code using Global Substitution instead of
    /// value swaps. Returns rounds until quiescence.
    pub fn run_global_substitution(&mut self) -> usize {
        let mut rounds = 0;
        let mut changed = true;
        while changed {
            changed = false;
            rounds += 1;
            let mut r = self.head;
            while r != usize::MAX {
                let nxt = self.next[r];
                if nxt != usize::MAX && self.v[r] > self.v[nxt] {
                    self.substitute(r, nxt);
                    changed = true;
                    // after relinking, the record now *after* the moved
                    // one is `r` again via nxt's links; continue from nxt
                    r = nxt;
                } else {
                    r = nxt;
                }
            }
        }
        rounds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrambled(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut v: Vec<f64> = (0..n).map(|i| i as f64).collect();
        rng.shuffle(&mut v);
        v
    }

    #[test]
    fn all_strategies_reach_same_fixpoint() {
        let input = scrambled(64, 1);
        let mut want = input.clone();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());

        let mut a = ChainReservoir::new(input.clone());
        a.run_array_sweep();
        assert_eq!(a.v, want);

        let mut b = ChainReservoir::new(input.clone());
        let mut rng = Rng::new(7);
        b.run_just_scheduled(&mut rng);
        assert_eq!(b.v, want);

        let mut c = ChainReservoir::new(input);
        c.run_levelized();
        assert_eq!(c.v, want);
    }

    #[test]
    fn sorted_input_quiesces_immediately() {
        let mut r = ChainReservoir::new((0..10).map(|i| i as f64).collect());
        assert_eq!(r.run_array_sweep(), 1);
        assert!(r.is_sorted());
    }

    #[test]
    fn empty_and_singleton() {
        let mut e = ChainReservoir::new(vec![]);
        assert_eq!(e.run_array_sweep(), 1);
        let mut s = ChainReservoir::new(vec![3.0]);
        let mut rng = Rng::new(1);
        assert_eq!(s.run_just_scheduled(&mut rng), 0);
    }

    #[test]
    fn linked_chain_swap_values_sorts() {
        let input = scrambled(40, 5);
        let mut want = input.clone();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut c = LinkedChain::new(input);
        c.run_swap_values();
        assert_eq!(c.to_vec(), want);
    }

    #[test]
    fn linked_chain_global_substitution_sorts() {
        let input = scrambled(40, 6);
        let mut want = input.clone();
        want.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut c = LinkedChain::new(input.clone());
        c.run_global_substitution();
        assert_eq!(c.to_vec(), want);
        // values never moved in the arena — only links did (§2.3.4)
        assert_eq!(c.v, input);
    }

    #[test]
    fn linked_chain_empty_and_single() {
        let mut e = LinkedChain::new(vec![]);
        assert_eq!(e.run_swap_values(), 1);
        assert!(e.to_vec().is_empty());
        let mut s = LinkedChain::new(vec![1.0]);
        s.run_global_substitution();
        assert_eq!(s.to_vec(), vec![1.0]);
    }

    #[test]
    fn just_scheduling_terminates_on_adversarial_input() {
        // reverse-sorted worst case
        let mut r = ChainReservoir::new((0..100).rev().map(|i| i as f64).collect());
        let mut rng = Rng::new(42);
        let rounds = r.run_just_scheduled(&mut rng);
        assert!(r.is_sorted());
        assert!(rounds <= 1000, "took {rounds} rounds");
    }
}
