//! Canonical AST reconstruction: `program(&ChainState) -> Program`.
//!
//! After every transformation the IR is re-rendered from the chain state.
//! This is sound because, for the sparse-BLAS kernel family, the
//! transformation algebra is confluent — the state (orthogonalization ×
//! materialization × splitting × ℕ\* flavour × sorting × interchange ×
//! dimensionality reduction × blocking) uniquely determines the canonical
//! loop nest, which is exactly the form the paper's listings show at each
//! node of the Fig 10 tree.

use crate::baselines::Kernel;
use crate::forelem::ir::*;

fn fl(var: &str, domain: Domain) -> Loop {
    Loop { var: var.into(), domain, ordered: false, kind: LoopKind::Forelem }
}

fn forl(var: &str, domain: Domain) -> Loop {
    Loop { var: var.into(), domain, ordered: true, kind: LoopKind::For }
}

/// Value access expression for the (possibly materialized/split) A data.
/// `subs` are the sequence subscripts in nesting order.
fn val_access(s: &ChainState, subs: &[&str]) -> Expr {
    match s.materialized {
        None => Expr::AddrFn { name: "A".into(), arg: "t".into() },
        Some(_) => {
            let subs_e: Vec<Expr> = subs.iter().map(|x| Expr::var(x)).collect();
            if s.split {
                // structure splitting: PA.val[i][k]
                Expr::Index { array: "PA.val".into(), subs: subs_e }
            } else {
                // sequence of structures: PA[i][k].val
                let inner = Expr::Index { array: "PA".into(), subs: subs_e };
                Expr::Field { tuple: crate::forelem::pretty::render_expr(&inner), field: "val".into() }
            }
        }
    }
}

/// Column-token access (`t.col` before materialization, `PA…col` after).
fn col_access(s: &ChainState, subs: &[&str]) -> Expr {
    match s.materialized {
        None => Expr::field("t", "col"),
        Some(_) => {
            let subs_e: Vec<Expr> = subs.iter().map(|x| Expr::var(x)).collect();
            if s.split {
                Expr::Index { array: "PA.col".into(), subs: subs_e }
            } else {
                let inner = Expr::Index { array: "PA".into(), subs: subs_e };
                Expr::Field { tuple: crate::forelem::pretty::render_expr(&inner), field: "col".into() }
            }
        }
    }
}

/// Row-token access for states where the row is not an induction var.
fn row_access(s: &ChainState, subs: &[&str]) -> Expr {
    match s.materialized {
        None => Expr::field("t", "row"),
        Some(_) => {
            let subs_e: Vec<Expr> = subs.iter().map(|x| Expr::var(x)).collect();
            if s.split {
                Expr::Index { array: "PA.row".into(), subs: subs_e }
            } else {
                let inner = Expr::Index { array: "PA".into(), subs: subs_e };
                Expr::Field { tuple: crate::forelem::pretty::render_expr(&inner), field: "row".into() }
            }
        }
    }
}

/// The output-update statement(s) for a kernel, given row/col/val exprs.
fn kernel_body(kernel: Kernel, row: Expr, col: Expr, val: Expr) -> Vec<Stmt> {
    match kernel {
        Kernel::Spmv => vec![Stmt::AddAssign {
            lhs: Expr::Index { array: "C".into(), subs: vec![row] },
            rhs: Expr::mul(val, Expr::Index { array: "B".into(), subs: vec![col] }),
        }],
        Kernel::Spmm => vec![
            Stmt::Comment("inner dense loop over the k columns of B".into()),
            Stmt::AddAssign {
                lhs: Expr::Index { array: "C".into(), subs: vec![row, Expr::var("v")] },
                rhs: Expr::mul(val, Expr::Index { array: "B".into(), subs: vec![col, Expr::var("v")] }),
            },
        ],
        Kernel::Trsv => vec![Stmt::SubAssign {
            lhs: Expr::Index { array: "x".into(), subs: vec![row] },
            rhs: Expr::mul(val, Expr::Index { array: "x".into(), subs: vec![col] }),
        }],
    }
}

/// Reconstruct the canonical program for a chain state.
pub fn program(s: &ChainState) -> Program {
    let label = if s.history.is_empty() {
        format!("{} — forelem normal form", s.kernel.label())
    } else {
        format!("{} — after {}", s.kernel.label(), s.history.join(" \u{2192} "))
    };

    let mut loops: Vec<Loop> = Vec::new();

    // --- outer structure from orthogonalization / blocking -------------
    match (s.orth, s.blocked) {
        (Orth::RowCol, Some(Blocking::Tile { br, bc })) => {
            loops.push(fl("ii", Domain::Blocked { bound: "n".into(), factor: br.to_string() }));
            loops.push(fl("jj", Domain::Blocked { bound: "m".into(), factor: bc.to_string() }));
            loops.push(fl("i", Domain::Nat { bound: format!("[ii\u{b7}{br},(ii+1)\u{b7}{br})") }));
            loops.push(fl("j", Domain::Nat { bound: format!("[jj\u{b7}{bc},(jj+1)\u{b7}{bc})") }));
        }
        (Orth::Row, _) => loops.push(fl("i", Domain::Nat { bound: "Nrows".into() })),
        (Orth::Col, _) => loops.push(fl("j", Domain::Nat { bound: "Ncols".into() })),
        (Orth::RowCol, _) => {
            loops.push(fl("i", Domain::Nat { bound: "Nrows".into() }));
            loops.push(fl("j", Domain::Nat { bound: "Ncols".into() }));
        }
        (Orth::Diag, _) => loops.push(fl("d", Domain::FieldValues {
            reservoir: "T".into(),
            field: "diag".into(),
        })),
        (Orth::None, _) => {}
    }

    // ℕ* sorting permutes the outer row loop.
    if s.sorted {
        if let Some(first) = loops.first_mut() {
            if let Domain::Nat { bound } = &first.domain {
                first.domain = Domain::Nat { bound: format!("perm({bound})") };
            }
        }
    }

    // --- inner structure from materialization ---------------------------
    let (body, pre, post);
    match s.materialized {
        None => {
            // Reservoir loop with conditions from orthogonalization.
            let conds = match s.orth {
                Orth::None => vec![],
                Orth::Row => vec![("row".to_string(), "i".to_string())],
                Orth::Col => vec![("col".to_string(), "j".to_string())],
                Orth::RowCol => {
                    vec![("row".to_string(), "i".to_string()), ("col".to_string(), "j".to_string())]
                }
                Orth::Diag => vec![("diag".to_string(), "d".to_string())],
            };
            loops.push(fl("t", Domain::Reservoir { name: "T".into(), conds }));
            let row = match s.orth {
                Orth::Row | Orth::RowCol => Expr::var("i"),
                _ => row_access(s, &[]),
            };
            let col = match s.orth {
                Orth::Col | Orth::RowCol => Expr::var("j"),
                Orth::Diag => Expr::Add(Box::new(Expr::field("t", "row")), Box::new(Expr::var("d"))),
                _ => col_access(s, &[]),
            };
            body = kernel_body(s.kernel, row, col, val_access(s, &[]));
            pre = vec![];
            post = vec![];
        }
        Some(dependent) => {
            if !dependent {
                // Loop-independent: single flat sequence.
                loops.push(fl("p", Domain::NStar));
                body = kernel_body(s.kernel, row_access(s, &["p"]), col_access(s, &["p"]), val_access(s, &["p"]));
                pre = vec![];
                post = vec![];
            } else {
                // Loop-dependent: nested sequence under the orth loop(s).
                let inner = if s.dim_reduced {
                    forl("k", Domain::PtrRange { ptr: "PA_ptr".into(), of: "i".into() })
                } else {
                    match s.nstar {
                        None => fl("k", Domain::NStar),
                        Some(NStarMat::Exact) => fl("k", Domain::NStarLen { len_expr: "PA_len[i]".into() }),
                        Some(NStarMat::Padded) => fl("k", Domain::NStarLen { len_expr: "K".into() }),
                    }
                };
                if s.interchanged && !s.dim_reduced {
                    // k becomes outermost (paper §5.2 / Fig 3b).
                    let outer_pos = loops.len().saturating_sub(1);
                    loops.insert(outer_pos, inner);
                } else {
                    loops.push(inner);
                }
                let subs: Vec<&str> = if s.dim_reduced { vec!["k"] } else { vec!["i", "k"] };
                let (row, col) = match s.orth {
                    Orth::Col => (row_access(s, &subs), Expr::var("j")),
                    Orth::Diag => (row_access(s, &subs), Expr::Add(
                        Box::new(row_access(s, &subs)),
                        Box::new(Expr::var("d")),
                    )),
                    _ => (Expr::var("i"), col_access(s, &subs)),
                };
                body = kernel_body(s.kernel, row, col, val_access(s, &subs));
                pre = vec![];
                post = vec![];
            }
        }
    }

    Program { label, loops, pre, body, post }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forelem::pretty::render;
    use crate::transforms;

    #[test]
    fn initial_spmv_is_single_reservoir_loop() {
        let s = ChainState::initial(Kernel::Spmv);
        let p = program(&s);
        assert_eq!(p.loops.len(), 1);
        let txt = render(&p);
        assert!(txt.contains("forelem (t; t \u{2208} T)"), "{txt}");
        assert!(txt.contains("C[t.row] += A(t) * B[t.col];"), "{txt}");
    }

    #[test]
    fn orthogonalized_row_shows_condition() {
        let mut s = ChainState::initial(Kernel::Spmv);
        transforms::orthogonalize(&mut s, Orth::Row).unwrap();
        let txt = render(&program(&s));
        assert!(txt.contains("T.row[i]"), "{txt}");
        assert!(txt.contains("C[i] +="), "{txt}");
    }

    #[test]
    fn dim_reduced_shows_ptr_loop() {
        let mut s = ChainState::initial(Kernel::Spmv);
        transforms::orthogonalize(&mut s, Orth::Row).unwrap();
        transforms::materialize(&mut s).unwrap();
        transforms::split(&mut s).unwrap();
        transforms::nstar_materialize(&mut s, NStarMat::Exact).unwrap();
        transforms::dim_reduce(&mut s).unwrap();
        let txt = render(&program(&s));
        assert!(txt.contains("PA_ptr[i]"), "{txt}");
        assert!(txt.contains("PA.val[k]"), "{txt}");
    }

    #[test]
    fn interchanged_padded_puts_k_outer() {
        let mut s = ChainState::initial(Kernel::Spmv);
        transforms::orthogonalize(&mut s, Orth::Row).unwrap();
        transforms::materialize(&mut s).unwrap();
        transforms::nstar_materialize(&mut s, NStarMat::Padded).unwrap();
        transforms::interchange(&mut s).unwrap();
        let p = program(&s);
        // first loop must now be the k loop
        assert_eq!(p.loops[0].var, "k");
        assert_eq!(p.loops[1].var, "i");
    }
}
