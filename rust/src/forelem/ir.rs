//! The forelem intermediate representation (paper §3).
//!
//! Programs are loop nests over *tuple reservoirs*: `forelem (t; t ∈ T)`
//! iterates every tuple of `T` exactly once in an explicitly undefined
//! order; subsets are selected with field conditions `T.field[v]`;
//! `whilelem` additionally revisits tuples until quiescence. Data is
//! reached through *address functions* applied to token tuples
//! (`A(t)`, `B[t.col]`, …).
//!
//! Two views of a program coexist here:
//!
//! 1. the **AST** (`Program`, `Loop`, `Stmt`, `Expr`) — what gets pretty-
//!    printed and inspected, reproducing the paper's listings; and
//! 2. the **chain state** (`ChainState`) — the normalized record of which
//!    transformations have been applied, from which the canonical AST is
//!    reconstructed after every step (the transformation algebra for this
//!    kernel family is confluent, so the state determines the program).
//!
//! Transformations (`crate::transforms`) are state transitions with
//! legality predicates; `crate::concretize` maps a final state onto a
//! physical storage format plus executor.

use crate::baselines::Kernel;

/// A loop iteration domain, mirroring the forms the paper's
/// transformations produce.
#[derive(Clone, Debug, PartialEq)]
pub enum Domain {
    /// `t ∈ T` or `t ∈ T.(f1,..)[(v1,..)]` — reservoir with conditions.
    Reservoir { name: String, conds: Vec<(String, String)> },
    /// `i ∈ T.field` — all values of a tuple field (orthogonalization).
    FieldValues { reservoir: String, field: String },
    /// `i ∈ ℕ_b` — encapsulated natural-number range with symbolic bound.
    Nat { bound: String },
    /// `p ∈ ℕ*` — materialized sequence subscripts, implicit extent.
    NStar,
    /// `k ∈ PA_len[i]` (exact) or `k ∈ K` (padded) after ℕ* materialization.
    NStarLen { len_expr: String },
    /// `k ∈ [PA_ptr[i], PA_ptr[i+1])` after dimensionality reduction.
    PtrRange { ptr: String, of: String },
    /// `ii ∈ ℕ_{b/x}` — blocked partition of an encapsulated range.
    Blocked { bound: String, factor: String },
}

/// One loop level. `ordered` distinguishes concretized `for` loops from
/// order-free `forelem` loops.
#[derive(Clone, Debug, PartialEq)]
pub struct Loop {
    pub var: String,
    pub domain: Domain,
    pub ordered: bool,
    pub kind: LoopKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoopKind {
    Forelem,
    Whilelem,
    For,
}

/// Expressions — the minimal language the sparse-BLAS specs need.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    /// `A(t)` — address function applied to a token tuple.
    AddrFn { name: String, arg: String },
    /// `B[t.col]` / `PA[i][k]` — array access with subscript expressions.
    Index { array: String, subs: Vec<Expr> },
    /// `t.field`.
    Field { tuple: String, field: String },
    /// Scalar variable.
    Var(String),
    Const(f64),
    Mul(Box<Expr>, Box<Expr>),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Div(Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn var(s: &str) -> Expr {
        Expr::Var(s.to_string())
    }

    pub fn idx(array: &str, subs: Vec<Expr>) -> Expr {
        Expr::Index { array: array.to_string(), subs }
    }

    pub fn field(tuple: &str, field: &str) -> Expr {
        Expr::Field { tuple: tuple.to_string(), field: field.to_string() }
    }

    pub fn mul(a: Expr, b: Expr) -> Expr {
        Expr::Mul(Box::new(a), Box::new(b))
    }
}

/// Statements.
#[derive(Clone, Debug, PartialEq)]
pub enum Stmt {
    /// `lhs = rhs`.
    Assign { lhs: Expr, rhs: Expr },
    /// `lhs += rhs`.
    AddAssign { lhs: Expr, rhs: Expr },
    /// `lhs -= rhs`.
    SubAssign { lhs: Expr, rhs: Expr },
    /// Declaration with initializer: `sum = 0`.
    Decl { name: String, init: Expr },
    Comment(String),
}

/// A full loop nest plus body.
#[derive(Clone, Debug, PartialEq)]
pub struct Program {
    /// Human-readable label, e.g. "SpMV (forelem normal form)".
    pub label: String,
    pub loops: Vec<Loop>,
    /// Statements preceding the innermost body at each level are not
    /// modeled; `pre`/`post` attach to the innermost loop's parent
    /// (sufficient for the BLAS specs: `sum = 0` / `C[i] = sum`).
    pub pre: Vec<Stmt>,
    pub body: Vec<Stmt>,
    pub post: Vec<Stmt>,
}

// ---------------------------------------------------------------------
// Chain state
// ---------------------------------------------------------------------

/// Orthogonalization choice (paper §4.1). `Diag` orthogonalizes on the
/// derived field `col - row` (legal because address functions may be any
/// invertible function of the token fields, §2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Orth {
    None,
    Row,
    Col,
    RowCol,
    Diag,
}

/// ℕ* materialization flavour (paper §4.3.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum NStarMat {
    /// `PA_len[q] = max len` + padding.
    Padded,
    /// `PA_len[q] = len(PA[q])`, no padding.
    Exact,
}

/// Loop-blocking flavour (paper §5.3 / §6.2.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Blocking {
    /// Block both orthogonalized dimensions → submatrix (BCSR-like).
    Tile { br: usize, bc: usize },
    /// Partition ℕ* by row fill → hybrid ELL+COO.
    FillCutoff,
    /// Partition the row dimension into slices of `s`, each padded to
    /// its own width → sliced ELLPACK (SELL).
    RowSlice { s: usize },
}

/// The normalized record of a transformation chain.
#[derive(Clone, Debug, PartialEq)]
pub struct ChainState {
    pub kernel: Kernel,
    pub orth: Orth,
    /// `Some(dependent)` once materialized; `dependent` iff the inner
    /// reservoir condition referenced an outer loop (paper §4.2.2).
    pub materialized: Option<bool>,
    /// Structure splitting applied (AoS → SoA).
    pub split: bool,
    pub nstar: Option<NStarMat>,
    /// ℕ* sorting applied (rows permuted by decreasing length).
    pub sorted: bool,
    /// Post-materialization loop interchange applied (k outermost).
    pub interchanged: bool,
    /// Dimensionality reduction applied (nested → flat + ptr).
    pub dim_reduced: bool,
    pub blocked: Option<Blocking>,
    /// Horizontal iteration-space reduction applied (drop unused fields).
    pub hisr: bool,
    /// Names of applied transformations, in order.
    pub history: Vec<&'static str>,
}

impl ChainState {
    /// The starting point: the minimal forelem representation (Fig 10
    /// node 1) of a kernel.
    pub fn initial(kernel: Kernel) -> Self {
        ChainState {
            kernel,
            orth: Orth::None,
            materialized: None,
            split: false,
            nstar: None,
            sorted: false,
            interchanged: false,
            dim_reduced: false,
            blocked: None,
            hisr: false,
            history: Vec::new(),
        }
    }

    /// Stable key identifying the *data structure* this state
    /// concretizes to (independent of kernel and of transformations that
    /// don't change storage). Used to count distinct generated formats
    /// (paper: "25 different data structures").
    pub fn layout_key(&self) -> String {
        format!(
            "orth={:?} split={} nstar={:?} sorted={} xchg={} dimred={} blocked={}",
            self.orth,
            self.split,
            self.nstar,
            self.sorted,
            self.interchanged,
            self.dim_reduced,
            self.blocked_key(),
        )
    }

    fn blocked_key(&self) -> String {
        match self.blocked {
            None => "none".into(),
            Some(Blocking::Tile { br, bc }) => format!("tile{br}x{bc}"),
            Some(Blocking::FillCutoff) => "fill".into(),
            Some(Blocking::RowSlice { s }) => format!("slice{s}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_state_is_clean() {
        let s = ChainState::initial(Kernel::Spmv);
        assert_eq!(s.orth, Orth::None);
        assert!(s.materialized.is_none());
        assert!(s.history.is_empty());
    }

    #[test]
    fn layout_key_ignores_kernel() {
        let a = ChainState::initial(Kernel::Spmv);
        let b = ChainState::initial(Kernel::Trsv);
        assert_eq!(a.layout_key(), b.layout_key());
    }

    #[test]
    fn expr_builders() {
        let e = Expr::mul(Expr::idx("B", vec![Expr::field("t", "col")]), Expr::AddrFn {
            name: "A".into(),
            arg: "t".into(),
        });
        match e {
            Expr::Mul(a, b) => {
                assert!(matches!(*a, Expr::Index { .. }));
                assert!(matches!(*b, Expr::AddrFn { .. }));
            }
            _ => panic!(),
        }
    }
}
