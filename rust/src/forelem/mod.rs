//! The forelem IR (paper §2–3): tuple reservoirs, forelem/whilelem loop
//! nests, address functions — plus the canonical-AST reconstruction and
//! the pretty-printer that reproduces the paper's listings.

pub mod build;
pub mod ir;
pub mod pretty;
pub mod specs;
pub mod whilelem;

pub use ir::{Blocking, ChainState, Domain, Expr, Loop, LoopKind, NStarMat, Orth, Program, Stmt};
