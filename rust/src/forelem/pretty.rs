//! Pretty-printer: renders a `Program` in the paper's listing style
//! (`forelem (t; t ∈ T.row[i]) …`). Used by `examples/derive_formats.rs`
//! to show each derivation step, and by tests asserting the IR shape.

use crate::forelem::ir::*;

pub fn render_expr(e: &Expr) -> String {
    match e {
        Expr::AddrFn { name, arg } => format!("{name}({arg})"),
        Expr::Index { array, subs } => {
            let s: Vec<String> = subs.iter().map(render_expr).collect();
            format!("{array}[{}]", s.join("]["))
        }
        Expr::Field { tuple, field } => format!("{tuple}.{field}"),
        Expr::Var(v) => v.clone(),
        Expr::Const(c) => format!("{c}"),
        Expr::Mul(a, b) => format!("{} * {}", render_expr(a), render_expr(b)),
        Expr::Add(a, b) => format!("{} + {}", render_expr(a), render_expr(b)),
        Expr::Sub(a, b) => format!("{} - {}", render_expr(a), render_expr(b)),
        Expr::Div(a, b) => format!("{} / {}", render_expr(a), render_expr(b)),
    }
}

pub fn render_stmt(s: &Stmt) -> String {
    match s {
        Stmt::Assign { lhs, rhs } => format!("{} = {};", render_expr(lhs), render_expr(rhs)),
        Stmt::AddAssign { lhs, rhs } => format!("{} += {};", render_expr(lhs), render_expr(rhs)),
        Stmt::SubAssign { lhs, rhs } => format!("{} -= {};", render_expr(lhs), render_expr(rhs)),
        Stmt::Decl { name, init } => format!("{name} = {};", render_expr(init)),
        Stmt::Comment(c) => format!("/* {c} */"),
    }
}

fn render_domain(var: &str, d: &Domain) -> String {
    match d {
        Domain::Reservoir { name, conds } => {
            if conds.is_empty() {
                format!("{var}; {var} \u{2208} {name}")
            } else if conds.len() == 1 {
                let (f, v) = &conds[0];
                format!("{var}; {var} \u{2208} {name}.{f}[{v}]")
            } else {
                let fs: Vec<&str> = conds.iter().map(|(f, _)| f.as_str()).collect();
                let vs: Vec<&str> = conds.iter().map(|(_, v)| v.as_str()).collect();
                format!("{var}; {var} \u{2208} {name}.({})[({})]", fs.join(","), vs.join(","))
            }
        }
        Domain::FieldValues { reservoir, field } => {
            format!("{var}; {var} \u{2208} {reservoir}.{field}")
        }
        Domain::Nat { bound } => format!("{var}; {var} \u{2208} \u{2115}_{bound}"),
        Domain::NStar => format!("{var}; {var} \u{2208} \u{2115}*"),
        Domain::NStarLen { len_expr } => format!("{var}; {var} \u{2208} {len_expr}"),
        Domain::PtrRange { ptr, of } => {
            format!("{var} = {ptr}[{of}]; {var} < {ptr}[{of}+1]; {var}++")
        }
        Domain::Blocked { bound, factor } => {
            format!("{var}; {var} \u{2208} \u{2115}_{{{bound}/{factor}}}")
        }
    }
}

fn render_loop(l: &Loop) -> String {
    let kw = match (l.kind, l.ordered) {
        (LoopKind::For, _) | (_, true) => "for",
        (LoopKind::Forelem, false) => "forelem",
        (LoopKind::Whilelem, false) => "whilelem",
    };
    format!("{kw} ({})", render_domain(&l.var, &l.domain))
}

/// Render a whole program with 2-space indentation per level.
pub fn render(p: &Program) -> String {
    let mut out = String::new();
    out.push_str(&format!("// {}\n", p.label));
    let n = p.loops.len();
    for (d, l) in p.loops.iter().enumerate() {
        // `pre` statements sit just inside the second-to-innermost level.
        if d + 1 == n {
            for s in &p.pre {
                out.push_str(&"  ".repeat(d));
                out.push_str(&render_stmt(s));
                out.push('\n');
            }
        }
        out.push_str(&"  ".repeat(d));
        out.push_str(&render_loop(l));
        out.push('\n');
    }
    for s in &p.body {
        out.push_str(&"  ".repeat(n));
        out.push_str(&render_stmt(s));
        out.push('\n');
    }
    for s in &p.post {
        out.push_str(&"  ".repeat(n.saturating_sub(1)));
        out.push_str(&render_stmt(s));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_minimal_spmv_form() {
        let p = Program {
            label: "SpMV normal form".into(),
            loops: vec![Loop {
                var: "t".into(),
                domain: Domain::Reservoir { name: "T".into(), conds: vec![] },
                ordered: false,
                kind: LoopKind::Forelem,
            }],
            pre: vec![],
            body: vec![Stmt::AddAssign {
                lhs: Expr::idx("C", vec![Expr::field("t", "row")]),
                rhs: Expr::mul(
                    Expr::AddrFn { name: "A".into(), arg: "t".into() },
                    Expr::idx("B", vec![Expr::field("t", "col")]),
                ),
            }],
            post: vec![],
        };
        let txt = render(&p);
        assert!(txt.contains("forelem (t; t \u{2208} T)"), "{txt}");
        assert!(txt.contains("C[t.row] += A(t) * B[t.col];"), "{txt}");
    }

    #[test]
    fn renders_conditions_and_nat() {
        let l1 = Loop {
            var: "i".into(),
            domain: Domain::Nat { bound: "Nrows".into() },
            ordered: false,
            kind: LoopKind::Forelem,
        };
        let l2 = Loop {
            var: "t".into(),
            domain: Domain::Reservoir { name: "T".into(), conds: vec![("row".into(), "i".into())] },
            ordered: false,
            kind: LoopKind::Forelem,
        };
        let p = Program { label: "x".into(), loops: vec![l1, l2], pre: vec![], body: vec![], post: vec![] };
        let txt = render(&p);
        assert!(txt.contains("\u{2115}_Nrows"), "{txt}");
        assert!(txt.contains("T.row[i]"), "{txt}");
    }

    #[test]
    fn renders_ptr_range_as_for() {
        let l = Loop {
            var: "k".into(),
            domain: Domain::PtrRange { ptr: "PA_ptr".into(), of: "i".into() },
            ordered: true,
            kind: LoopKind::For,
        };
        let p = Program { label: "x".into(), loops: vec![l], pre: vec![], body: vec![], post: vec![] };
        let txt = render(&p);
        assert!(txt.contains("for (k = PA_ptr[i]; k < PA_ptr[i+1]; k++)"), "{txt}");
    }

    #[test]
    fn renders_multi_field_condition() {
        let l = Loop {
            var: "t".into(),
            domain: Domain::Reservoir {
                name: "T".into(),
                conds: vec![("row".into(), "i".into()), ("col".into(), "j".into())],
            },
            ordered: false,
            kind: LoopKind::Forelem,
        };
        let p = Program { label: "x".into(), loops: vec![l], pre: vec![], body: vec![], post: vec![] };
        assert!(render(&p).contains("T.(row,col)[(i,j)]"));
    }
}
