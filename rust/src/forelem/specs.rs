//! The paper's sparse-BLAS specifications as forelem IR listings
//! (Fig 5: SpMV, Fig 6: Triangular Solve, Fig 7: LU factorization).
//! `build::program` reconstructs the *canonical* minimal forms used by
//! the transformation pipeline; this module renders the *paper-faithful*
//! listings (with their outer dense loops and multi-condition selections)
//! for documentation, the `derive` CLI and tests.

use crate::forelem::ir::*;

fn fl(var: &str, domain: Domain) -> Loop {
    Loop { var: var.into(), domain, ordered: false, kind: LoopKind::Forelem }
}

fn forl(var: &str, domain: Domain) -> Loop {
    Loop { var: var.into(), domain, ordered: true, kind: LoopKind::For }
}

/// Fig 5 — SpMV with the row loop written out:
/// ```text
/// for (i = 1; i <= N; i++) {
///   sum = 0;
///   forelem (t; t ∈ T.row[i])
///     sum += B[t.col] * A(t);
///   C[i] = sum;
/// }
/// ```
pub fn spmv_fig5() -> Program {
    Program {
        label: "Fig 5 — Sparse Matrix times Vector Multiplication".into(),
        loops: vec![
            forl("i", Domain::Nat { bound: "N".into() }),
            fl("t", Domain::Reservoir { name: "T".into(), conds: vec![("row".into(), "i".into())] }),
        ],
        pre: vec![Stmt::Decl { name: "sum".into(), init: Expr::Const(0.0) }],
        body: vec![Stmt::AddAssign {
            lhs: Expr::var("sum"),
            rhs: Expr::mul(
                Expr::idx("B", vec![Expr::field("t", "col")]),
                Expr::AddrFn { name: "A".into(), arg: "t".into() },
            ),
        }],
        post: vec![Stmt::Assign {
            lhs: Expr::idx("C", vec![Expr::var("i")]),
            rhs: Expr::var("sum"),
        }],
    }
}

/// Fig 6 — Triangular Solve `Ax = b` (two forelem loops per column).
/// Returned as the pair of loop nests of the paper's listing.
pub fn trsv_fig6() -> Vec<Program> {
    vec![
        Program {
            label: "Fig 6a — pivot: x[i] = b[i] / A(t), t ∈ T.(col,row)[(i,i)]".into(),
            loops: vec![
                forl("i", Domain::Nat { bound: "N (descending)".into() }),
                fl(
                    "t",
                    Domain::Reservoir {
                        name: "T".into(),
                        conds: vec![("col".into(), "i".into()), ("row".into(), "i".into())],
                    },
                ),
            ],
            pre: vec![],
            body: vec![Stmt::Assign {
                lhs: Expr::idx("x", vec![Expr::var("i")]),
                rhs: Expr::Div(
                    Box::new(Expr::idx("b", vec![Expr::var("i")])),
                    Box::new(Expr::AddrFn { name: "A".into(), arg: "t".into() }),
                ),
            }],
            post: vec![],
        },
        Program {
            label: "Fig 6b — update: b[i] = b[t.row] - A(t) * x[i], t ∈ T.col[i]".into(),
            loops: vec![fl(
                "t",
                Domain::Reservoir { name: "T".into(), conds: vec![("col".into(), "i".into())] },
            )],
            pre: vec![],
            body: vec![Stmt::Assign {
                lhs: Expr::idx("b", vec![Expr::var("i")]),
                rhs: Expr::Sub(
                    Box::new(Expr::idx("b", vec![Expr::field("t", "row")])),
                    Box::new(Expr::mul(
                        Expr::AddrFn { name: "A".into(), arg: "t".into() },
                        Expr::idx("x", vec![Expr::var("i")]),
                    )),
                ),
            }],
            post: vec![],
        },
    ]
}

/// Fig 7 — LU factorization: "every inner loop over the same sparse
/// matrix A defines a different set of matrix elements to be iterated".
pub fn lu_fig7() -> Vec<Program> {
    vec![
        Program {
            label: "Fig 7a — column scale: A(t) /= A(p), t ∈ T.(col,row)[(k, (k,N])]".into(),
            loops: vec![
                forl("k", Domain::Nat { bound: "N".into() }),
                fl(
                    "t",
                    Domain::Reservoir {
                        name: "T".into(),
                        conds: vec![("col".into(), "k".into()), ("row".into(), "(k,\u{221e})".into())],
                    },
                ),
            ],
            pre: vec![],
            body: vec![Stmt::Assign {
                lhs: Expr::AddrFn { name: "A".into(), arg: "t".into() },
                rhs: Expr::Div(
                    Box::new(Expr::AddrFn { name: "A".into(), arg: "t".into() }),
                    Box::new(Expr::AddrFn { name: "A".into(), arg: "(k,k)".into() }),
                ),
            }],
            post: vec![],
        },
        Program {
            label: "Fig 7b — submatrix update: A(i,j) -= A(i,k) * A(k,j)".into(),
            loops: vec![
                fl(
                    "u",
                    Domain::Reservoir {
                        name: "T".into(),
                        conds: vec![("col".into(), "k".into()), ("row".into(), "i".into())],
                    },
                ),
                fl(
                    "v",
                    Domain::Reservoir {
                        name: "T".into(),
                        conds: vec![("row".into(), "k".into()), ("col".into(), "j".into())],
                    },
                ),
            ],
            pre: vec![],
            body: vec![Stmt::SubAssign {
                lhs: Expr::AddrFn { name: "A".into(), arg: "(i,j)".into() },
                rhs: Expr::mul(
                    Expr::AddrFn { name: "A".into(), arg: "u".into() },
                    Expr::AddrFn { name: "A".into(), arg: "v".into() },
                ),
            }],
            post: vec![],
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forelem::pretty::render;

    #[test]
    fn fig5_renders_paper_shape() {
        let txt = render(&spmv_fig5());
        assert!(txt.contains("sum = 0;"), "{txt}");
        assert!(txt.contains("T.row[i]"), "{txt}");
        assert!(txt.contains("sum += B[t.col] * A(t);"), "{txt}");
        assert!(txt.contains("C[i] = sum;"), "{txt}");
    }

    #[test]
    fn fig6_has_two_nests_with_conditions() {
        let ps = trsv_fig6();
        assert_eq!(ps.len(), 2);
        let a = render(&ps[0]);
        assert!(a.contains("T.(col,row)[(i,i)]"), "{a}");
        assert!(a.contains("x[i] = b[i] / A(t);"), "{a}");
        let b = render(&ps[1]);
        assert!(b.contains("T.col[i]"), "{b}");
    }

    #[test]
    fn fig7_iterates_different_subsets() {
        let ps = lu_fig7();
        let a = render(&ps[0]);
        assert!(a.contains("col"), "{a}");
        let b = render(&ps[1]);
        assert!(b.contains("A(u) * A(v)"), "{b}");
    }
}
