//! Unit-lower triangular solve `L x = b` — the paper's third kernel
//! (Fig 6 shows the forelem form). The storage holds the *strictly*
//! lower part; the diagonal is implied 1. Forward substitution is
//! inherently ordered, so (as the paper observes in §6.4.2) the legal
//! transformation space is smaller: row-oriented formats keep the
//! gather form, column-oriented formats become the scatter ("right-
//! looking") form, and no ℕ\*-sorting/interchange variants are legal.

use crate::storage::*;

/// CSR forward substitution (gather).
pub fn csr(l: &Csr, b: &[f64], x: &mut [f64]) {
    x.copy_from_slice(b);
    for i in 0..l.nrows {
        let (s, e) = (l.row_ptr[i] as usize, l.row_ptr[i + 1] as usize);
        let sum: f64 = l.cols[s..e]
            .iter()
            .zip(&l.vals[s..e])
            .map(|(&c, &v)| v * x[c as usize])
            .sum();
        x[i] -= sum;
    }
}

/// CSR AoS.
pub fn csr_aos(l: &CsrAos, b: &[f64], x: &mut [f64]) {
    x.copy_from_slice(b);
    for i in 0..l.nrows {
        let (s, e) = (l.row_ptr[i] as usize, l.row_ptr[i + 1] as usize);
        let mut sum = 0.0;
        for &(c, v) in &l.pairs[s..e] {
            sum += v * x[c as usize];
        }
        x[i] -= sum;
    }
}

/// CSC forward substitution (scatter / right-looking).
pub fn csc(l: &Csc, b: &[f64], x: &mut [f64]) {
    x.copy_from_slice(b);
    for j in 0..l.ncols {
        let xj = x[j];
        let (s, e) = (l.col_ptr[j] as usize, l.col_ptr[j + 1] as usize);
        for (&r, &v) in l.rows[s..e].iter().zip(&l.vals[s..e]) {
            x[r as usize] -= v * xj;
        }
    }
}

/// CSC AoS.
pub fn csc_aos(l: &CscAos, b: &[f64], x: &mut [f64]) {
    x.copy_from_slice(b);
    for j in 0..l.ncols {
        let xj = x[j];
        let (s, e) = (l.col_ptr[j] as usize, l.col_ptr[j + 1] as usize);
        for &(r, v) in &l.pairs[s..e] {
            x[r as usize] -= v * xj;
        }
    }
}

/// Row-major COO: a single pass works because entries are grouped by row
/// in ascending order and cols < row are already solved.
pub fn coo_rowmajor(l: &CooAos, b: &[f64], x: &mut [f64]) {
    debug_assert_eq!(l.order, CooOrder::RowMajor);
    x.copy_from_slice(b);
    let mut idx = 0usize;
    let n = l.tuples.len();
    for i in 0..l.nrows {
        let mut sum = 0.0;
        while idx < n && l.tuples[idx].0 as usize == i {
            let (_, c, v) = l.tuples[idx];
            sum += v * x[c as usize];
            idx += 1;
        }
        x[i] -= sum;
    }
}

/// ELL row-wise.
pub fn ell_rowwise(l: &Ell, b: &[f64], x: &mut [f64]) {
    x.copy_from_slice(b);
    for i in 0..l.nrows {
        let mut sum = 0.0;
        for p in 0..l.row_len[i] as usize {
            let ix = l.index(i, p);
            sum += l.vals[ix] * x[l.cols[ix] as usize];
        }
        x[i] -= sum;
    }
}

/// Hybrid ELL+COO (tail is row-major: merge two row cursors).
pub fn hybrid(l: &HybridEllCoo, b: &[f64], x: &mut [f64]) {
    x.copy_from_slice(b);
    let e = &l.ell;
    let t = &l.tail;
    let mut tidx = 0usize;
    for i in 0..l.nrows {
        let mut sum = 0.0;
        for p in 0..e.row_len[i] as usize {
            let ix = e.index(i, p);
            sum += e.vals[ix] * x[e.cols[ix] as usize];
        }
        while tidx < t.rows.len() && t.rows[tidx] as usize == i {
            sum += t.vals[tidx] * x[t.cols[tidx] as usize];
            tidx += 1;
        }
        x[i] -= sum;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    fn check_all(m: &crate::matrix::TriMat) {
        let l = m.strictly_lower();
        let b: Vec<f64> = (0..l.nrows).map(|i| ((i % 13) as f64 - 6.0) * 0.5).collect();
        let want = l.trsv_unit_lower_ref(&b);
        let mut x = vec![0.0; l.nrows];
        let tol = 1e-9;

        csr(&Csr::from_tuples(&l), &b, &mut x);
        assert_close(&x, &want, tol).unwrap();
        csr_aos(&CsrAos::from_tuples(&l), &b, &mut x);
        assert_close(&x, &want, tol).unwrap();
        csc(&Csc::from_tuples(&l), &b, &mut x);
        assert_close(&x, &want, tol).unwrap();
        csc_aos(&CscAos::from_tuples(&l), &b, &mut x);
        assert_close(&x, &want, tol).unwrap();
        coo_rowmajor(&CooAos::from_tuples(&l, CooOrder::RowMajor), &b, &mut x);
        assert_close(&x, &want, tol).unwrap();
        ell_rowwise(&Ell::from_tuples(&l, EllOrder::RowMajor), &b, &mut x);
        assert_close(&x, &want, tol).unwrap();
        ell_rowwise(&Ell::from_tuples(&l, EllOrder::ColMajor), &b, &mut x);
        assert_close(&x, &want, tol).unwrap();
        hybrid(&HybridEllCoo::from_tuples(&l, None, EllOrder::RowMajor), &b, &mut x);
        assert_close(&x, &want, tol).unwrap();
    }

    #[test]
    fn trsv_matches_oracle_random() {
        check_all(&gen::uniform_random(40, 40, 300, 38));
    }

    #[test]
    fn trsv_matches_oracle_banded() {
        check_all(&gen::banded(50, 4, 0.7, 39));
    }

    #[test]
    fn trsv_matches_oracle_fem() {
        check_all(&gen::fem_blocks(12, 3, 3, 40));
    }

    #[test]
    fn identity_solve_is_b() {
        let l = crate::matrix::TriMat::new(5, 5); // no strictly-lower entries
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let mut x = vec![0.0; 5];
        csr(&Csr::from_tuples(&l), &b, &mut x);
        assert_eq!(x, b);
    }

    #[test]
    fn solve_then_multiply_recovers_b() {
        let m = gen::uniform_random(30, 30, 200, 41);
        let l = m.strictly_lower();
        let b: Vec<f64> = (0..30).map(|i| (i as f64 * 0.7).cos()).collect();
        let mut x = vec![0.0; 30];
        csc(&Csc::from_tuples(&l), &b, &mut x);
        // (I + L) x == b
        let lx = l.spmv_ref(&x);
        let back: Vec<f64> = (0..30).map(|i| x[i] + lx[i]).collect();
        assert_close(&back, &b, 1e-9).unwrap();
    }
}
