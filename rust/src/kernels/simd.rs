//! Vectorized micro-kernels — the execution side of the plan's fourth
//! axis (`concretize::Plan::lanes`).
//!
//! Every kernel here exists at const-generic vector widths
//! (`const LANES: usize`, instantiated at 4 and 8) so the hot loop is
//! monomorphized and branch-free per width; the planner picks the width
//! structurally (`lane_legal` gates it by format, `cost::features`
//! prices it through the `gather_lanes` feature) and `Prepared` routes
//! lanes > 1 plans through [`SparseOps::spmv_serial_lanes`]-family
//! hooks into these dispatchers.
//!
//! Two implementations back each width:
//!
//! * **Scalar lane-structured fallback** (always compiled): the loop is
//!   restructured into `LANES` independent accumulators (CSR/ELL) or
//!   `LANES`-row plane groups (SELL-σ) with software prefetch of the
//!   upcoming column-index/value cache lines — the shape the
//!   auto-vectorizer wants, correct on every target. This is what the
//!   default build runs, so the container's no-toolchain constraint
//!   holds: `--no-default-features`-equivalent builds stay pure Rust.
//! * **AVX2 gather + FMA fast path** (`--features simd`, x86-64 only):
//!   `core::arch` intrinsics behind runtime
//!   `is_x86_feature_detected!("avx2")`/`"fma"` dispatch. Machines
//!   without AVX2 silently use the scalar lane path.
//!
//! Accuracy contract (asserted by `tests/simd.rs`): the SELL-σ lane
//! kernels accumulate each output row in the exact serial plane order
//! (the vector width runs *across* rows), so they are bit-identical to
//! `sell_sigma::spmv` on both paths — the AVX2 path vectorizes only the
//! exactly-rounded multiplies. CSR/ELL lane kernels reassociate the
//! per-row reduction into `LANES` partial sums (and the AVX2 path fuses
//! multiply-add), so they agree with the serial kernels to a few ULP on
//! well-conditioned data and bit-exactly on integer-valued data.
//!
//! [`SparseOps::spmv_serial_lanes`]: crate::storage::SparseOps::spmv_serial_lanes

use crate::kernels::{par, spmm};
use crate::storage::{sell_sigma, Csr, Ell, SellSigma};

/// Whether the AVX2 + FMA fast path is compiled in *and* available on
/// the running machine. Always `false` without `--features simd` or
/// off x86-64; the answer is detected once and cached.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub fn avx2_active() -> bool {
    use std::sync::OnceLock;
    static DETECTED: OnceLock<bool> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    })
}

/// Scalar-build stub: the fast path is not compiled in.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
pub fn avx2_active() -> bool {
    false
}

/// Hint the prefetcher at `data[idx..]` (no-op off x86-64 or past the
/// end). `_mm_prefetch` is SSE-baseline on x86-64, so this needs no
/// feature gate — the scalar lane kernels use it too.
#[inline(always)]
fn prefetch_read<T>(data: &[T], idx: usize) {
    #[cfg(target_arch = "x86_64")]
    if idx < data.len() {
        // Safety: the pointer stays inside `data` (bounds-checked above)
        // and prefetch never faults on a mapped address.
        unsafe {
            core::arch::x86_64::_mm_prefetch::<{ core::arch::x86_64::_MM_HINT_T0 }>(
                data.as_ptr().add(idx) as *const i8,
            );
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = (data, idx);
}

// ------------------------------------------------------------- CSR --

/// CSR SpMV at vector width `lanes` (full matrix).
pub fn csr_spmv(a: &Csr, x: &[f64], y: &mut [f64], lanes: usize) {
    assert_eq!(x.len(), a.ncols);
    assert_eq!(y.len(), a.nrows);
    csr_spmv_rows(a, x, y, 0, lanes);
}

/// CSR SpMV at vector width `lanes` over the rows `row0..row0+y.len()`
/// (the `spmv_range` chunk convention).
pub fn csr_spmv_rows(a: &Csr, x: &[f64], y: &mut [f64], row0: usize, lanes: usize) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if avx2_active() {
        match lanes {
            4 => return unsafe { avx2::csr_rows::<4>(a, x, y, row0) },
            8 => return unsafe { avx2::csr_rows::<8>(a, x, y, row0) },
            _ => {}
        }
    }
    match lanes {
        4 => csr_rows_lanes::<4>(a, x, y, row0),
        8 => csr_rows_lanes::<8>(a, x, y, row0),
        // `lane_legal` admits only 4/8 here; anything else degrades to
        // the scalar range kernel rather than panicking mid-sweep.
        _ => par::csr_rows(a, x, y, row0),
    }
}

fn csr_rows_lanes<const LANES: usize>(a: &Csr, x: &[f64], y: &mut [f64], row0: usize) {
    for (r, yi) in y.iter_mut().enumerate() {
        let i = row0 + r;
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        *yi = row_dot_lanes::<LANES>(&a.cols[s..e], &a.vals[s..e], x);
    }
}

/// One sparse dot product with `LANES` independent accumulators; the
/// remainder runs scalar into the reduced sum.
#[inline(always)]
fn row_dot_lanes<const LANES: usize>(cols: &[u32], vals: &[f64], x: &[f64]) -> f64 {
    let len = cols.len();
    let mut acc = [0.0f64; LANES];
    let mut p = 0usize;
    while p + LANES <= len {
        prefetch_read(cols, p + 16 * LANES);
        prefetch_read(vals, p + 8 * LANES);
        let it = cols[p..p + LANES].iter().zip(&vals[p..p + LANES]);
        for (al, (&c, &v)) in acc.iter_mut().zip(it) {
            *al += v * x[c as usize];
        }
        p += LANES;
    }
    let mut sum: f64 = acc.iter().sum();
    while p < len {
        sum += vals[p] * x[cols[p] as usize];
        p += 1;
    }
    sum
}

// ------------------------------------------------------------- ELL --

/// ELL row-wise SpMV at vector width `lanes` (full matrix).
pub fn ell_spmv(a: &Ell, x: &[f64], y: &mut [f64], lanes: usize) {
    assert_eq!(x.len(), a.ncols);
    assert_eq!(y.len(), a.nrows);
    ell_spmv_rows(a, x, y, 0, lanes);
}

/// ELL row-wise SpMV at vector width `lanes` over the rows
/// `row0..row0+y.len()`.
pub fn ell_spmv_rows(a: &Ell, x: &[f64], y: &mut [f64], row0: usize, lanes: usize) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if avx2_active() && matches!(a.order, crate::storage::EllOrder::RowMajor) {
        // Row-major slots are contiguous, so the CSR gather kernel
        // applies; column-major (ITPACK) keeps the scalar lane shape.
        match lanes {
            4 => return unsafe { avx2::ell_rows::<4>(a, x, y, row0) },
            8 => return unsafe { avx2::ell_rows::<8>(a, x, y, row0) },
            _ => {}
        }
    }
    match lanes {
        4 => ell_rows_lanes::<4>(a, x, y, row0),
        8 => ell_rows_lanes::<8>(a, x, y, row0),
        _ => par::ell_rows(a, x, y, row0),
    }
}

fn ell_rows_lanes<const LANES: usize>(a: &Ell, x: &[f64], y: &mut [f64], row0: usize) {
    for (r, yi) in y.iter_mut().enumerate() {
        let i = row0 + r;
        let len = a.row_len[i] as usize;
        let mut acc = [0.0f64; LANES];
        let mut p = 0usize;
        while p + LANES <= len {
            for (l, al) in acc.iter_mut().enumerate() {
                let ix = a.index(i, p + l);
                *al += a.vals[ix] * x[a.cols[ix] as usize];
            }
            p += LANES;
        }
        let mut sum: f64 = acc.iter().sum();
        while p < len {
            let ix = a.index(i, p);
            sum += a.vals[ix] * x[a.cols[ix] as usize];
            p += 1;
        }
        *yi = sum;
    }
}

// ---------------------------------------------------------- SELL-σ --

/// SELL-σ slice-plane SpMV at vector width `lanes` (full matrix). The
/// width runs *across* rows inside a plane, so each output row still
/// accumulates in the serial plane order: bit-identical to
/// [`sell_sigma::spmv`] on every path.
pub fn sell_sigma_spmv(a: &SellSigma, x: &[f64], y: &mut [f64], lanes: usize) {
    assert_eq!(x.len(), a.ncols);
    assert_eq!(y.len(), a.nrows);
    match lanes {
        4 => {
            for sb in 0..a.nslices {
                sell_slice_dispatch::<4>(a, x, y, sb, 0);
            }
        }
        8 => {
            for sb in 0..a.nslices {
                sell_slice_dispatch::<8>(a, x, y, sb, 0);
            }
        }
        _ => sell_sigma::spmv(a, x, y),
    }
}

/// SELL-σ SpMV at vector width `lanes` over the σ windows `[w0, w1)`
/// (the `spmv_range` chunk convention: `y` starts at row `row0`).
pub fn sell_sigma_spmv_range(
    a: &SellSigma,
    x: &[f64],
    y: &mut [f64],
    w0: usize,
    w1: usize,
    row0: usize,
    lanes: usize,
) {
    if lanes != 4 && lanes != 8 {
        return sell_sigma::spmv_range(a, x, y, w0, w1, row0);
    }
    let spw = a.slices_per_window().expect("window not slice-aligned");
    let sb1 = (w1 * spw).min(a.nslices);
    for sb in w0 * spw..sb1 {
        if lanes == 4 {
            sell_slice_dispatch::<4>(a, x, y, sb, row0);
        } else {
            sell_slice_dispatch::<8>(a, x, y, sb, row0);
        }
    }
}

#[inline(always)]
fn sell_slice_dispatch<const LANES: usize>(
    a: &SellSigma,
    x: &[f64],
    y: &mut [f64],
    sb: usize,
    row0: usize,
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if avx2_active() {
        return unsafe { avx2::sell_slice::<LANES>(a, x, y, sb, row0) };
    }
    sell_slice_lanes::<LANES>(a, x, y, sb, row0);
}

/// One SELL-σ slice with the plane loop grouped into `LANES`-row
/// blocks. Grouping across rows never reorders a single row's
/// additions, so this is exactly the serial accumulation.
fn sell_slice_lanes<const LANES: usize>(
    a: &SellSigma,
    x: &[f64],
    y: &mut [f64],
    sb: usize,
    row0: usize,
) {
    let lo = sb * a.s;
    let hi = ((sb + 1) * a.s).min(a.nrows);
    let rows = hi - lo;
    let base = a.slice_ptr[sb] as usize;
    let w = a.widths[sb] as usize;
    for q in lo..hi {
        y[a.perm[q] as usize - row0] = 0.0;
    }
    for p in 0..w {
        let plane = base + p * rows;
        let mut ri = 0usize;
        while ri + LANES <= rows {
            prefetch_read(&a.vals, plane + ri + 4 * LANES);
            prefetch_read(&a.cols, plane + ri + 4 * LANES);
            for l in 0..LANES {
                let r = ri + l;
                if (p as u32) < a.row_len[lo + r] {
                    let ix = plane + r;
                    y[a.perm[lo + r] as usize - row0] += a.vals[ix] * x[a.cols[ix] as usize];
                }
            }
            ri += LANES;
        }
        while ri < rows {
            if (p as u32) < a.row_len[lo + ri] {
                let ix = plane + ri;
                y[a.perm[lo + ri] as usize - row0] += a.vals[ix] * x[a.cols[ix] as usize];
            }
            ri += 1;
        }
    }
}

// ------------------------------------------------------------ SpMM --

/// CSR SpMM with the register-blocked micro-kernel widened to `lanes`
/// (full matrix). The axpy is element-wise, so every width accumulates
/// each `c[i][j]` in the identical nonzero order.
pub fn csr_spmm(a: &Csr, b: &[f64], k: usize, c: &mut [f64], lanes: usize) {
    csr_spmm_rows(a, b, k, c, 0, lanes);
}

/// CSR SpMM at vector width `lanes` over the rows
/// `row0..row0 + c.len()/k` (the `spmm_range` chunk convention).
pub fn csr_spmm_rows(a: &Csr, b: &[f64], k: usize, c: &mut [f64], row0: usize, lanes: usize) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if avx2_active() {
        match lanes {
            4 => return unsafe { avx2::csr_rows_mm::<4>(a, b, k, c, row0) },
            8 => return unsafe { avx2::csr_rows_mm::<8>(a, b, k, c, row0) },
            _ => {}
        }
    }
    match lanes {
        8 => csr_rows_mm_lanes::<8>(a, b, k, c, row0),
        4 => csr_rows_mm_lanes::<4>(a, b, k, c, row0),
        _ => par::csr_rows_mm(a, b, k, c, row0),
    }
}

fn csr_rows_mm_lanes<const LANES: usize>(
    a: &Csr,
    b: &[f64],
    k: usize,
    c: &mut [f64],
    row0: usize,
) {
    for (r, crow) in c.chunks_mut(k).enumerate() {
        let i = row0 + r;
        crow.fill(0.0);
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        for (&col, &v) in a.cols[s..e].iter().zip(&a.vals[s..e]) {
            let brow = &b[col as usize * k..col as usize * k + k];
            if LANES >= 8 {
                spmm::axpy_k8(crow, brow, v);
            } else {
                spmm::axpy_k4(crow, brow, v);
            }
        }
    }
}

// ---------------------------------------------- AVX2 + FMA fast path --

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    //! `core::arch` implementations, entered only after
    //! [`avx2_active`](super::avx2_active) returns true. Callers hold
    //! the usual kernel preconditions (in-bounds column indices,
    //! matching slice lengths), which is all the gather/load intrinsics
    //! need beyond the detected CPU features.

    use core::arch::x86_64::*;

    use crate::storage::{Csr, Ell, SellSigma};

    /// Horizontal sum of a 4-lane double register.
    #[inline(always)]
    unsafe fn hsum(v: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(v);
        let hi = _mm256_extractf128_pd::<1>(v);
        let s = _mm_add_pd(lo, hi);
        _mm_cvtsd_f64(_mm_add_sd(s, _mm_unpackhi_pd(s, s)))
    }

    /// Sparse dot product: 32-bit index gather + FMA into `LANES`
    /// accumulator lanes, scalar remainder.
    #[inline(always)]
    unsafe fn row_dot<const LANES: usize>(cols: &[u32], vals: &[f64], x: &[f64]) -> f64 {
        let len = cols.len();
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut p = 0usize;
        while p + LANES <= len {
            super::prefetch_read(cols, p + 16 * LANES);
            super::prefetch_read(vals, p + 8 * LANES);
            let idx = _mm_loadu_si128(cols.as_ptr().add(p) as *const __m128i);
            let xs = _mm256_i32gather_pd::<8>(x.as_ptr(), idx);
            let vs = _mm256_loadu_pd(vals.as_ptr().add(p));
            acc0 = _mm256_fmadd_pd(vs, xs, acc0);
            if LANES == 8 {
                let idx1 = _mm_loadu_si128(cols.as_ptr().add(p + 4) as *const __m128i);
                let xs1 = _mm256_i32gather_pd::<8>(x.as_ptr(), idx1);
                let vs1 = _mm256_loadu_pd(vals.as_ptr().add(p + 4));
                acc1 = _mm256_fmadd_pd(vs1, xs1, acc1);
            }
            p += LANES;
        }
        let folded = if LANES == 8 { _mm256_add_pd(acc0, acc1) } else { acc0 };
        let mut sum = hsum(folded);
        while p < len {
            sum += vals[p] * x[cols[p] as usize];
            p += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn csr_rows<const LANES: usize>(a: &Csr, x: &[f64], y: &mut [f64], row0: usize) {
        for (r, yi) in y.iter_mut().enumerate() {
            let i = row0 + r;
            let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
            *yi = row_dot::<LANES>(&a.cols[s..e], &a.vals[s..e], x);
        }
    }

    /// Row-major ELL only (slots contiguous per row); the dispatcher
    /// keeps column-major on the scalar lane path.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn ell_rows<const LANES: usize>(a: &Ell, x: &[f64], y: &mut [f64], row0: usize) {
        for (r, yi) in y.iter_mut().enumerate() {
            let i = row0 + r;
            let s = i * a.k;
            let e = s + a.row_len[i] as usize;
            *yi = row_dot::<LANES>(&a.cols[s..e], &a.vals[s..e], x);
        }
    }

    /// One SELL-σ slice: vectorized gather + multiply across rows of a
    /// plane, scalar scatter-adds through the window permutation. The
    /// multiplies are exactly rounded per lane and each row's adds stay
    /// in plane order, so the result is bit-identical to the serial
    /// kernel (no FMA on this path by construction).
    #[target_feature(enable = "avx2")]
    pub unsafe fn sell_slice<const LANES: usize>(
        a: &SellSigma,
        x: &[f64],
        y: &mut [f64],
        sb: usize,
        row0: usize,
    ) {
        let lo = sb * a.s;
        let hi = ((sb + 1) * a.s).min(a.nrows);
        let rows = hi - lo;
        let base = a.slice_ptr[sb] as usize;
        let w = a.widths[sb] as usize;
        for q in lo..hi {
            y[a.perm[q] as usize - row0] = 0.0;
        }
        for p in 0..w {
            let plane = base + p * rows;
            let mut ri = 0usize;
            while ri + LANES <= rows {
                super::prefetch_read(&a.vals, plane + ri + 4 * LANES);
                super::prefetch_read(&a.cols, plane + ri + 4 * LANES);
                let mut g = 0usize;
                while g < LANES {
                    let at = ri + g;
                    let active = (0..4).all(|l| (p as u32) < a.row_len[lo + at + l]);
                    if active {
                        let idx =
                            _mm_loadu_si128(a.cols.as_ptr().add(plane + at) as *const __m128i);
                        let xs = _mm256_i32gather_pd::<8>(x.as_ptr(), idx);
                        let vs = _mm256_loadu_pd(a.vals.as_ptr().add(plane + at));
                        let mut prod = [0.0f64; 4];
                        _mm256_storeu_pd(prod.as_mut_ptr(), _mm256_mul_pd(vs, xs));
                        for (l, &pv) in prod.iter().enumerate() {
                            y[a.perm[lo + at + l] as usize - row0] += pv;
                        }
                    } else {
                        for l in 0..4 {
                            let r = at + l;
                            if (p as u32) < a.row_len[lo + r] {
                                let ix = plane + r;
                                y[a.perm[lo + r] as usize - row0] +=
                                    a.vals[ix] * x[a.cols[ix] as usize];
                            }
                        }
                    }
                    g += 4;
                }
                ri += LANES;
            }
            while ri < rows {
                if (p as u32) < a.row_len[lo + ri] {
                    let ix = plane + ri;
                    y[a.perm[lo + ri] as usize - row0] += a.vals[ix] * x[a.cols[ix] as usize];
                }
                ri += 1;
            }
        }
    }

    /// `crow += v * brow`, FMA-fused, `LANES` doubles per step.
    #[inline(always)]
    unsafe fn axpy<const LANES: usize>(crow: &mut [f64], brow: &[f64], v: f64) {
        let vv = _mm256_set1_pd(v);
        let kl = crow.len() & !(LANES - 1);
        let mut j = 0usize;
        while j < kl {
            let cj = _mm256_loadu_pd(crow.as_ptr().add(j));
            let bj = _mm256_loadu_pd(brow.as_ptr().add(j));
            _mm256_storeu_pd(crow.as_mut_ptr().add(j), _mm256_fmadd_pd(vv, bj, cj));
            if LANES == 8 {
                let cj1 = _mm256_loadu_pd(crow.as_ptr().add(j + 4));
                let bj1 = _mm256_loadu_pd(brow.as_ptr().add(j + 4));
                _mm256_storeu_pd(crow.as_mut_ptr().add(j + 4), _mm256_fmadd_pd(vv, bj1, cj1));
            }
            j += LANES;
        }
        while j < crow.len() {
            crow[j] += v * brow[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn csr_rows_mm<const LANES: usize>(
        a: &Csr,
        b: &[f64],
        k: usize,
        c: &mut [f64],
        row0: usize,
    ) {
        for (r, crow) in c.chunks_mut(k).enumerate() {
            let i = row0 + r;
            crow.fill(0.0);
            let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
            for (&col, &v) in a.cols[s..e].iter().zip(&a.vals[s..e]) {
                axpy::<LANES>(crow, &b[col as usize * k..col as usize * k + k], v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::spmv;
    use crate::matrix::coo::TriMat;
    use crate::matrix::gen;
    use crate::storage::EllOrder;

    fn sample(nrows: usize, ncols: usize, seed: u64) -> TriMat {
        gen::uniform_random(nrows, ncols, nrows * ncols / 3, seed)
    }

    #[test]
    fn csr_lane_kernels_match_serial() {
        let m = sample(37, 29, 7);
        let a = Csr::from_tuples(&m);
        let x: Vec<f64> = (0..29).map(|i| 0.5 + (i as f64) * 0.01).collect();
        let mut y0 = vec![0.0; 37];
        spmv::csr(&a, &x, &mut y0);
        for lanes in [4usize, 8] {
            let mut y = vec![7.0; 37];
            csr_spmv(&a, &x, &mut y, lanes);
            for (a, b) in y.iter().zip(&y0) {
                assert!((a - b).abs() <= 1e-12 * b.abs().max(1.0), "lanes={lanes}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn ell_lane_kernels_match_serial_in_both_orders() {
        let m = sample(23, 31, 11);
        let x: Vec<f64> = (0..31).map(|i| 1.0 + (i as f64) * 0.02).collect();
        for order in [EllOrder::RowMajor, EllOrder::ColMajor] {
            let a = Ell::from_tuples(&m, order);
            let mut y0 = vec![0.0; 23];
            spmv::ell_rowwise(&a, &x, &mut y0);
            for lanes in [4usize, 8] {
                let mut y = vec![-3.0; 23];
                ell_spmv(&a, &x, &mut y, lanes);
                for (a, b) in y.iter().zip(&y0) {
                    assert!((a - b).abs() <= 1e-12 * b.abs().max(1.0));
                }
            }
        }
    }

    #[test]
    fn sell_sigma_lane_kernels_are_bit_identical_to_serial() {
        let m = sample(61, 40, 13);
        let a = SellSigma::from_tuples(&m, 8, 16);
        let x: Vec<f64> = (0..40).map(|i| 0.25 + (i as f64) * 0.03).collect();
        let mut y0 = vec![0.0; 61];
        sell_sigma::spmv(&a, &x, &mut y0);
        for lanes in [4usize, 8] {
            let mut y = vec![9.0; 61];
            sell_sigma_spmv(&a, &x, &mut y, lanes);
            assert_eq!(y, y0, "across-row lanes must preserve serial accumulation");
        }
        // The window-range form composes to the same bits.
        let mut y = vec![0.0; 61];
        let nw = a.nwindows();
        let mid = nw / 2;
        let (head, tail) = y.split_at_mut(mid * a.sigma);
        sell_sigma_spmv_range(&a, &x, head, 0, mid, 0, 4);
        sell_sigma_spmv_range(&a, &x, tail, mid, nw, mid * a.sigma, 8);
        assert_eq!(y, y0);
    }

    #[test]
    fn spmm_lane_kernels_are_bit_identical_to_serial() {
        let m = sample(19, 17, 5);
        let a = Csr::from_tuples(&m);
        let k = 6;
        let b: Vec<f64> = (0..17 * k).map(|i| 0.1 + (i as f64) * 0.005).collect();
        let mut c0 = vec![0.0; 19 * k];
        spmm::csr(&a, &b, k, &mut c0);
        for lanes in [4usize, 8] {
            let mut c = vec![2.0; 19 * k];
            csr_spmm(&a, &b, k, &mut c, lanes);
            if avx2_active() {
                // The AVX2 axpy fuses each mul+add (one rounding per
                // nonzero instead of two): equal to tight tolerance,
                // not to the bit.
                for (g, w) in c.iter().zip(&c0) {
                    assert!((g - w).abs() <= 1e-12 * w.abs().max(1.0), "{g} vs {w}");
                }
            } else {
                assert_eq!(c, c0, "element-wise axpy keeps every width bit-identical");
            }
        }
    }

    #[test]
    fn unknown_widths_degrade_to_scalar() {
        let m = sample(12, 12, 3);
        let a = Csr::from_tuples(&m);
        let x = vec![1.0; 12];
        let mut y0 = vec![0.0; 12];
        spmv::csr(&a, &x, &mut y0);
        let mut y = vec![0.0; 12];
        csr_spmv(&a, &x, &mut y, 3);
        assert_eq!(y, y0);
    }
}
