//! Schedule-aware kernels — the executors behind `Schedule::Parallel`,
//! `Schedule::Tiled` and `Schedule::ParallelTiled` (the third plan
//! axis; see `concretize::layout`).
//!
//! Parallel kernels partition the *output* dimension into disjoint
//! contiguous ranges — rows for CSR/ELL, slices for SELL, block-rows
//! for BCSR, permuted-row prefixes for JDS — balanced by nonzero count,
//! and hand each worker an owned `&mut` chunk of the output obtained by
//! splitting the slice. No worker ever writes another worker's rows, so
//! the hot path takes no locks and needs no atomics.
//!
//! Tiled kernels run the CSB-style two-pass CSR SpMV: the `x` gather is
//! restricted to one `x_block`-column band at a time using the per-band
//! row splits built at `prepare()` time (`storage::CsrBands`), so the
//! randomly-gathered part of the working set stays L2-resident.
//!
//! Workers come from the process-wide **persistent crew**
//! (`util::pool::scoped_run`): std-only threads spawned once and
//! parked on condvars between calls (tokio/rayon are unavailable
//! offline), so a warm invocation pays a wake+dispatch handshake
//! (~single-digit µs) instead of per-call spawn+join (~tens of µs).
//! Task `i` always lands on worker `i % crew` — the deterministic
//! mapping the NUMA first-touch pass relies on: the worker that
//! touched a partition's pages at prepare time is the worker that
//! serves it. The remaining dispatch cost is still *part of the
//! schedule's measured time on purpose*: on small matrices the
//! parallel variants genuinely lose to `Serial`, and the search sees
//! exactly that and selects per-matrix — the same
//! let-the-measurements-decide philosophy the paper applies to
//! layouts. The ≥2× CSR speedup target applies to the large suite
//! matrices, where dispatch cost is noise.

use crate::storage::{Bcsr, Csr, CsrBands, Ell, Jds, Sell};
use crate::util::pool::scoped_run;

use super::spmm::axpy_k4;

/// Split `0..n` units into at most `threads` contiguous ranges with
/// approximately equal cumulative weight. `cum(i)` is the total weight
/// of units `0..i` (monotone non-decreasing, `cum(0) == 0`). Every
/// returned range is non-empty and the ranges cover `0..n` exactly.
pub fn balanced_ranges(
    n: usize,
    threads: usize,
    cum: impl Fn(usize) -> usize,
) -> Vec<(usize, usize)> {
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let total = cum(n);
    let mut ranges = Vec::with_capacity(threads);
    let mut lo = 0usize;
    for t in 0..threads {
        if lo >= n {
            break;
        }
        let hi = if t + 1 == threads {
            n
        } else {
            // Smallest hi > lo with cum(hi) >= the t+1-th weight share.
            let target = (total as u128 * (t as u128 + 1) / threads as u128) as usize;
            let (mut a, mut b) = (lo + 1, n);
            while a < b {
                let mid = (a + b) / 2;
                if cum(mid) >= target {
                    b = mid;
                } else {
                    a = mid + 1;
                }
            }
            a
        };
        ranges.push((lo, hi));
        lo = hi;
    }
    ranges
}

/// Split `y` into per-range `&mut` chunks (range `(lo, hi)` gets
/// `y[lo * unit..hi * unit]`, the tail chunk clamped to `y.len()`).
pub(crate) fn chunks_for<'a>(
    mut y: &'a mut [f64],
    ranges: &[(usize, usize)],
    unit: usize,
) -> Vec<&'a mut [f64]> {
    let total = y.len();
    let mut chunks = Vec::with_capacity(ranges.len());
    let mut consumed = 0usize;
    for &(_lo, hi) in ranges {
        let end = (hi * unit).min(total);
        let (chunk, tail) = std::mem::take(&mut y).split_at_mut(end - consumed);
        y = tail;
        consumed = end;
        chunks.push(chunk);
    }
    debug_assert_eq!(consumed, total);
    chunks
}

// ---------------------------------------------------------------- CSR

pub(crate) fn csr_rows(a: &Csr, x: &[f64], y: &mut [f64], row0: usize) {
    for (r, yi) in y.iter_mut().enumerate() {
        let i = row0 + r;
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        *yi = a.cols[s..e]
            .iter()
            .zip(&a.vals[s..e])
            .map(|(&c, &v)| v * x[c as usize])
            .sum();
    }
}

/// CSR SpMV over nnz-balanced disjoint row ranges.
pub fn csr_spmv(a: &Csr, x: &[f64], y: &mut [f64], threads: usize) {
    assert_eq!(x.len(), a.ncols);
    assert_eq!(y.len(), a.nrows);
    let ranges = balanced_ranges(a.nrows, threads, |i| a.row_ptr[i] as usize);
    if ranges.len() <= 1 {
        return crate::kernels::spmv::csr(a, x, y);
    }
    let chunks = chunks_for(y, &ranges, 1);
    let mut tasks = Vec::with_capacity(chunks.len());
    for (&(lo, _hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || csr_rows(a, x, chunk, lo));
    }
    scoped_run(tasks);
}

pub(crate) fn csr_rows_mm(a: &Csr, b: &[f64], k: usize, c: &mut [f64], row0: usize) {
    for r in 0..c.len() / k {
        let i = row0 + r;
        let crow = &mut c[r * k..r * k + k];
        crow.fill(0.0);
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        for p in s..e {
            let col = a.cols[p] as usize;
            axpy_k4(crow, &b[col * k..col * k + k], a.vals[p]);
        }
    }
}

/// CSR SpMM over nnz-balanced disjoint row ranges (register-blocked
/// micro-kernel inner loop).
pub fn csr_spmm(a: &Csr, b: &[f64], k: usize, c: &mut [f64], threads: usize) {
    assert_eq!(c.len(), a.nrows * k);
    let ranges = balanced_ranges(a.nrows, threads, |i| a.row_ptr[i] as usize);
    if ranges.len() <= 1 {
        return crate::kernels::spmm::csr(a, b, k, c);
    }
    let chunks = chunks_for(c, &ranges, k);
    let mut tasks = Vec::with_capacity(chunks.len());
    for (&(lo, _hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || csr_rows_mm(a, b, k, chunk, lo));
    }
    scoped_run(tasks);
}

fn csr_rows_tiled(a: &Csr, bands: &CsrBands, x: &[f64], y: &mut [f64], row0: usize) {
    y.fill(0.0);
    let nrows = a.nrows;
    for band in 0..bands.nbands {
        let base = band * nrows;
        for (r, yi) in y.iter_mut().enumerate() {
            let i = row0 + r;
            let s = bands.split[base + i] as usize;
            let e = bands.split[base + nrows + i] as usize;
            if s == e {
                continue;
            }
            let mut sum = 0.0;
            for (&col, &v) in a.cols[s..e].iter().zip(&a.vals[s..e]) {
                sum += v * x[col as usize];
            }
            *yi += sum;
        }
    }
}

/// Cache-blocked CSR SpMV: two passes over the per-band row splits so
/// each `x` band stays L2-resident.
pub fn csr_spmv_tiled(a: &Csr, bands: &CsrBands, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), a.ncols);
    assert_eq!(y.len(), a.nrows);
    csr_rows_tiled(a, bands, x, y, 0);
}

/// Parallel + cache-blocked CSR SpMV: nnz-balanced row ranges, each
/// traversed band-by-band.
pub fn csr_spmv_parallel_tiled(
    a: &Csr,
    bands: &CsrBands,
    x: &[f64],
    y: &mut [f64],
    threads: usize,
) {
    assert_eq!(x.len(), a.ncols);
    assert_eq!(y.len(), a.nrows);
    let ranges = balanced_ranges(a.nrows, threads, |i| a.row_ptr[i] as usize);
    if ranges.len() <= 1 {
        return csr_spmv_tiled(a, bands, x, y);
    }
    let chunks = chunks_for(y, &ranges, 1);
    let mut tasks = Vec::with_capacity(chunks.len());
    for (&(lo, _hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || csr_rows_tiled(a, bands, x, chunk, lo));
    }
    scoped_run(tasks);
}

// ---------------------------------------------------------------- ELL

fn ell_len_prefix(a: &Ell) -> Vec<usize> {
    let mut pref = vec![0usize; a.nrows + 1];
    for i in 0..a.nrows {
        pref[i + 1] = pref[i] + a.row_len[i] as usize;
    }
    pref
}

pub(crate) fn ell_rows(a: &Ell, x: &[f64], y: &mut [f64], row0: usize) {
    for (r, yi) in y.iter_mut().enumerate() {
        let i = row0 + r;
        let mut sum = 0.0;
        for p in 0..a.row_len[i] as usize {
            let ix = a.index(i, p);
            sum += a.vals[ix] * x[a.cols[ix] as usize];
        }
        *yi = sum;
    }
}

/// ELL SpMV (either element order) over nnz-balanced row ranges.
pub fn ell_spmv(a: &Ell, x: &[f64], y: &mut [f64], threads: usize) {
    assert_eq!(y.len(), a.nrows);
    let pref = ell_len_prefix(a);
    let ranges = balanced_ranges(a.nrows, threads, |i| pref[i]);
    if ranges.len() <= 1 {
        return crate::kernels::spmv::ell_rowwise(a, x, y);
    }
    let chunks = chunks_for(y, &ranges, 1);
    let mut tasks = Vec::with_capacity(chunks.len());
    for (&(lo, _hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || ell_rows(a, x, chunk, lo));
    }
    scoped_run(tasks);
}

pub(crate) fn ell_rows_mm(a: &Ell, b: &[f64], k: usize, c: &mut [f64], row0: usize) {
    for r in 0..c.len() / k {
        let i = row0 + r;
        let crow = &mut c[r * k..r * k + k];
        crow.fill(0.0);
        for p in 0..a.row_len[i] as usize {
            let ix = a.index(i, p);
            let col = a.cols[ix] as usize;
            axpy_k4(crow, &b[col * k..col * k + k], a.vals[ix]);
        }
    }
}

/// ELL SpMM over nnz-balanced row ranges.
pub fn ell_spmm(a: &Ell, b: &[f64], k: usize, c: &mut [f64], threads: usize) {
    assert_eq!(c.len(), a.nrows * k);
    let pref = ell_len_prefix(a);
    let ranges = balanced_ranges(a.nrows, threads, |i| pref[i]);
    if ranges.len() <= 1 {
        return crate::kernels::spmm::ell_rowwise(a, b, k, c);
    }
    let chunks = chunks_for(c, &ranges, k);
    let mut tasks = Vec::with_capacity(chunks.len());
    for (&(lo, _hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || ell_rows_mm(a, b, k, chunk, lo));
    }
    scoped_run(tasks);
}

// --------------------------------------------------------------- SELL

pub(crate) fn sell_slices(
    a: &Sell,
    x: &[f64],
    y: &mut [f64],
    slice0: usize,
    slice1: usize,
    row0: usize,
) {
    for sb in slice0..slice1 {
        let lo = sb * a.s;
        let hi = ((sb + 1) * a.s).min(a.nrows);
        let rows = hi - lo;
        let base = a.slice_ptr[sb] as usize;
        let w = a.widths[sb] as usize;
        let yb = &mut y[lo - row0..lo - row0 + rows];
        yb.fill(0.0);
        for p in 0..w {
            let plane = base + p * rows;
            for (ri, ybr) in yb.iter_mut().enumerate() {
                let ix = plane + ri;
                *ybr += a.vals[ix] * x[a.cols[ix] as usize];
            }
        }
    }
}

/// SELL SpMV over nnz-balanced disjoint *slice* ranges (slice
/// boundaries are row boundaries, so output chunks stay disjoint).
pub fn sell_spmv(a: &Sell, x: &[f64], y: &mut [f64], threads: usize) {
    assert_eq!(y.len(), a.nrows);
    let ranges = balanced_ranges(a.nslices, threads, |sb| a.slice_ptr[sb] as usize);
    if ranges.len() <= 1 {
        return crate::storage::sell::spmv(a, x, y);
    }
    // Row chunk for slice range (lo, hi): rows lo*s .. min(hi*s, nrows).
    let chunks = chunks_for(y, &ranges, a.s);
    let mut tasks = Vec::with_capacity(chunks.len());
    for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || sell_slices(a, x, chunk, lo, hi, lo * a.s));
    }
    scoped_run(tasks);
}

pub(crate) fn sell_slices_mm(
    a: &Sell,
    bm: &[f64],
    k: usize,
    c: &mut [f64],
    slice0: usize,
    slice1: usize,
    row0: usize,
) {
    for sb in slice0..slice1 {
        let lo = sb * a.s;
        let hi = ((sb + 1) * a.s).min(a.nrows);
        let rows = hi - lo;
        let base = a.slice_ptr[sb] as usize;
        let w = a.widths[sb] as usize;
        let c0 = (lo - row0) * k;
        c[c0..c0 + rows * k].fill(0.0);
        for p in 0..w {
            let plane = base + p * rows;
            for ri in 0..rows {
                let ix = plane + ri;
                let v = a.vals[ix];
                if v == 0.0 {
                    continue;
                }
                let col = a.cols[ix] as usize;
                let crow = &mut c[c0 + ri * k..c0 + ri * k + k];
                axpy_k4(crow, &bm[col * k..col * k + k], v);
            }
        }
    }
}

/// SELL SpMM over nnz-balanced slice ranges.
pub fn sell_spmm(a: &Sell, bm: &[f64], k: usize, c: &mut [f64], threads: usize) {
    assert_eq!(c.len(), a.nrows * k);
    let ranges = balanced_ranges(a.nslices, threads, |sb| a.slice_ptr[sb] as usize);
    if ranges.len() <= 1 {
        return crate::storage::sell::spmm(a, bm, k, c);
    }
    let chunks = chunks_for(c, &ranges, a.s * k);
    let mut tasks = Vec::with_capacity(chunks.len());
    for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || sell_slices_mm(a, bm, k, chunk, lo, hi, lo * a.s));
    }
    scoped_run(tasks);
}

// --------------------------------------------------------------- BCSR

pub(crate) fn bcsr_block_rows(
    a: &Bcsr,
    x: &[f64],
    y: &mut [f64],
    brow0: usize,
    brow1: usize,
    row0: usize,
) {
    y.fill(0.0);
    let (br, bc) = (a.br, a.bc);
    for bi in brow0..brow1 {
        let (s, e) = (a.block_row_ptr[bi] as usize, a.block_row_ptr[bi + 1] as usize);
        let i0 = bi * br;
        let rmax = br.min(a.nrows - i0);
        for kblk in s..e {
            let j0 = a.block_cols[kblk] as usize * bc;
            let cmax = bc.min(a.ncols - j0);
            let payload = &a.blocks[kblk * br * bc..(kblk + 1) * br * bc];
            let xs = &x[j0..j0 + cmax];
            for r in 0..rmax {
                let prow = &payload[r * bc..r * bc + cmax];
                let sum: f64 = prow.iter().zip(xs).map(|(&p, &xv)| p * xv).sum();
                y[i0 + r - row0] += sum;
            }
        }
    }
}

/// BCSR SpMV over block-balanced disjoint block-row ranges.
pub fn bcsr_spmv(a: &Bcsr, x: &[f64], y: &mut [f64], threads: usize) {
    assert_eq!(y.len(), a.nrows);
    let ranges = balanced_ranges(a.nblock_rows, threads, |bi| a.block_row_ptr[bi] as usize);
    if ranges.len() <= 1 {
        return crate::kernels::spmv::bcsr(a, x, y);
    }
    let chunks = chunks_for(y, &ranges, a.br);
    let mut tasks = Vec::with_capacity(chunks.len());
    for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || bcsr_block_rows(a, x, chunk, lo, hi, lo * a.br));
    }
    scoped_run(tasks);
}

pub(crate) fn bcsr_block_rows_mm(
    a: &Bcsr,
    b: &[f64],
    k: usize,
    c: &mut [f64],
    brow0: usize,
    brow1: usize,
    row0: usize,
) {
    c.fill(0.0);
    let (br, bc) = (a.br, a.bc);
    for bi in brow0..brow1 {
        let (s, e) = (a.block_row_ptr[bi] as usize, a.block_row_ptr[bi + 1] as usize);
        let i0 = bi * br;
        let rmax = br.min(a.nrows - i0);
        for blk in s..e {
            let j0 = a.block_cols[blk] as usize * bc;
            let cmax = bc.min(a.ncols - j0);
            let payload = &a.blocks[blk * br * bc..(blk + 1) * br * bc];
            for r in 0..rmax {
                let co = (i0 + r - row0) * k;
                let crow = &mut c[co..co + k];
                for cc in 0..cmax {
                    let v = payload[r * bc + cc];
                    if v == 0.0 {
                        continue;
                    }
                    axpy_k4(crow, &b[(j0 + cc) * k..(j0 + cc) * k + k], v);
                }
            }
        }
    }
}

/// BCSR SpMM over block-balanced block-row ranges (register-blocked
/// micro-kernel inner loop).
pub fn bcsr_spmm(a: &Bcsr, b: &[f64], k: usize, c: &mut [f64], threads: usize) {
    assert_eq!(c.len(), a.nrows * k);
    let ranges = balanced_ranges(a.nblock_rows, threads, |bi| a.block_row_ptr[bi] as usize);
    if ranges.len() <= 1 {
        return crate::kernels::spmm::bcsr(a, b, k, c);
    }
    let chunks = chunks_for(c, &ranges, a.br * k);
    let mut tasks = Vec::with_capacity(chunks.len());
    for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
        tasks.push(move || bcsr_block_rows_mm(a, b, k, chunk, lo, hi, lo * a.br));
    }
    scoped_run(tasks);
}

// ---------------------------------------------------------------- JDS

/// Cumulative nonzeros of the first `q` permuted rows: permuted row `q`
/// participates in every diagonal `d` with `diag_len[d] > q`, and
/// `diag_len` is non-increasing for ℕ*-sorted JDS.
fn jds_permuted_prefix(a: &Jds) -> Vec<usize> {
    let mut pref = vec![0usize; a.nrows + 1];
    for q in 0..a.nrows {
        let len = a.diag_len.partition_point(|&dl| dl as usize > q);
        pref[q + 1] = pref[q] + len;
    }
    pref
}

fn jds_prows(a: &Jds, x: &[f64], yp: &mut [f64], lo: usize, hi: usize) {
    yp.fill(0.0);
    for d in 0..a.ndiags() {
        let n = a.diag_len[d] as usize;
        if n <= lo {
            break; // diag_len is non-increasing: later diagonals shorter
        }
        let hi2 = hi.min(n);
        let s = a.jd_ptr[d] as usize;
        for q in lo..hi2 {
            yp[q - lo] += a.vals[s + q] * x[a.cols[s + q] as usize];
        }
    }
}

/// Permuted JDS SpMV over nnz-balanced permuted-row ranges: workers
/// fill disjoint chunks of the permuted output, then one serial pass
/// scatters through `perm`.
pub fn jds_spmv(a: &Jds, x: &[f64], y: &mut [f64], threads: usize) {
    debug_assert!(a.permuted);
    assert_eq!(y.len(), a.nrows);
    let pref = jds_permuted_prefix(a);
    let ranges = balanced_ranges(a.nrows, threads, |q| pref[q]);
    if ranges.len() <= 1 {
        return crate::kernels::spmv::jds_permuted(a, x, y);
    }
    let mut yp = vec![0.0f64; a.nrows];
    {
        let chunks = chunks_for(&mut yp, &ranges, 1);
        let mut tasks = Vec::with_capacity(chunks.len());
        for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
            tasks.push(move || jds_prows(a, x, chunk, lo, hi));
        }
        scoped_run(tasks);
    }
    for (off, &r) in a.perm.iter().enumerate() {
        y[r as usize] = yp[off];
    }
}

fn jds_prows_mm(a: &Jds, b: &[f64], k: usize, cp: &mut [f64], lo: usize, hi: usize) {
    cp.fill(0.0);
    for d in 0..a.ndiags() {
        let n = a.diag_len[d] as usize;
        if n <= lo {
            break;
        }
        let hi2 = hi.min(n);
        let s = a.jd_ptr[d] as usize;
        for q in lo..hi2 {
            let col = a.cols[s + q] as usize;
            let co = (q - lo) * k;
            axpy_k4(&mut cp[co..co + k], &b[col * k..col * k + k], a.vals[s + q]);
        }
    }
}

/// Permuted JDS SpMM over nnz-balanced permuted-row ranges.
pub fn jds_spmm(a: &Jds, b: &[f64], k: usize, c: &mut [f64], threads: usize) {
    debug_assert!(a.permuted);
    assert_eq!(c.len(), a.nrows * k);
    let pref = jds_permuted_prefix(a);
    let ranges = balanced_ranges(a.nrows, threads, |q| pref[q]);
    let mut cp = vec![0.0f64; a.nrows * k];
    if ranges.len() <= 1 {
        // Serial fallback: same permuted accumulate + scatter, one range.
        jds_prows_mm(a, b, k, &mut cp, 0, a.nrows);
        for (off, &r) in a.perm.iter().enumerate() {
            c[r as usize * k..r as usize * k + k].copy_from_slice(&cp[off * k..off * k + k]);
        }
        return;
    }
    {
        let chunks = chunks_for(&mut cp, &ranges, k);
        let mut tasks = Vec::with_capacity(chunks.len());
        for (&(lo, hi), chunk) in ranges.iter().zip(chunks) {
            tasks.push(move || jds_prows_mm(a, b, k, chunk, lo, hi));
        }
        scoped_run(tasks);
    }
    for (off, &r) in a.perm.iter().enumerate() {
        c[r as usize * k..r as usize * k + k].copy_from_slice(&cp[off * k..off * k + k]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::storage::EllOrder;
    use crate::util::prop::assert_close;

    #[test]
    fn balanced_ranges_cover_and_balance() {
        // Uniform weights: ranges must be near-equal and cover 0..n.
        let r = balanced_ranges(100, 4, |i| i * 10);
        assert_eq!(r.len(), 4);
        assert_eq!(r[0].0, 0);
        assert_eq!(r.last().unwrap().1, 100);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
        for &(lo, hi) in &r {
            assert!(hi - lo >= 20 && hi - lo <= 30, "unbalanced: {lo}..{hi}");
        }
    }

    #[test]
    fn balanced_ranges_skewed_weights() {
        // One huge row: it gets its own range; remaining ranges cover rest.
        let weights: Vec<usize> = (0..10).map(|i| if i == 0 { 1000 } else { 1 }).collect();
        let mut pref = vec![0usize];
        for &w in &weights {
            pref.push(pref.last().unwrap() + w);
        }
        let r = balanced_ranges(10, 4, |i| pref[i]);
        assert_eq!(r[0], (0, 1));
        assert_eq!(r.last().unwrap().1, 10);
    }

    #[test]
    fn balanced_ranges_more_threads_than_units() {
        let r = balanced_ranges(3, 8, |i| i);
        assert_eq!(r.len(), 3);
        assert_eq!(r, vec![(0, 1), (1, 2), (2, 3)]);
    }

    #[test]
    fn balanced_ranges_empty_and_zero_weight() {
        assert!(balanced_ranges(0, 4, |_| 0).is_empty());
        let r = balanced_ranges(5, 3, |_| 0); // all rows empty
        assert_eq!(r.last().unwrap().1, 5);
        assert_eq!(r[0].0, 0);
        for w in r.windows(2) {
            assert_eq!(w[0].1, w[1].0);
        }
    }

    fn check_spmv_all(m: &crate::matrix::TriMat, threads: usize) {
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.23).sin() + 0.4).collect();
        let want = m.spmv_ref(&x);
        let mut y = vec![0.0; m.nrows];
        let tol = 1e-10;

        let csr = Csr::from_tuples(m);
        csr_spmv(&csr, &x, &mut y, threads);
        assert_close(&y, &want, tol).unwrap();
        for xb in [1, 3, 64] {
            let bands = CsrBands::build(&csr, xb);
            csr_spmv_tiled(&csr, &bands, &x, &mut y);
            assert_close(&y, &want, tol).unwrap_or_else(|e| panic!("tiled xb={xb}: {e}"));
            csr_spmv_parallel_tiled(&csr, &bands, &x, &mut y, threads);
            assert_close(&y, &want, tol).unwrap_or_else(|e| panic!("par+tiled xb={xb}: {e}"));
        }
        for order in [EllOrder::RowMajor, EllOrder::ColMajor] {
            let e = Ell::from_tuples(m, order);
            ell_spmv(&e, &x, &mut y, threads);
            assert_close(&y, &want, tol).unwrap();
        }
        let s = Sell::from_tuples(m, 4);
        sell_spmv(&s, &x, &mut y, threads);
        assert_close(&y, &want, tol).unwrap();
        let bc = Bcsr::from_tuples(m, 2, 3);
        bcsr_spmv(&bc, &x, &mut y, threads);
        assert_close(&y, &want, tol).unwrap();
        let j = Jds::from_tuples(m, true);
        jds_spmv(&j, &x, &mut y, threads);
        assert_close(&y, &want, tol).unwrap();
    }

    fn check_spmm_all(m: &crate::matrix::TriMat, k: usize, threads: usize) {
        let b: Vec<f64> = (0..m.ncols * k).map(|i| ((i * 11 % 17) as f64 - 8.0) * 0.1).collect();
        let want = m.spmm_ref(&b, k);
        let mut c = vec![0.0; m.nrows * k];
        let tol = 1e-10;

        csr_spmm(&Csr::from_tuples(m), &b, k, &mut c, threads);
        assert_close(&c, &want, tol).unwrap();
        ell_spmm(&Ell::from_tuples(m, EllOrder::RowMajor), &b, k, &mut c, threads);
        assert_close(&c, &want, tol).unwrap();
        sell_spmm(&Sell::from_tuples(m, 8), &b, k, &mut c, threads);
        assert_close(&c, &want, tol).unwrap();
        bcsr_spmm(&Bcsr::from_tuples(m, 3, 2), &b, k, &mut c, threads);
        assert_close(&c, &want, tol).unwrap();
        jds_spmm(&Jds::from_tuples(m, true), &b, k, &mut c, threads);
        assert_close(&c, &want, tol).unwrap();
    }

    #[test]
    fn parallel_kernels_match_oracle() {
        for threads in [1, 2, 3, 4, 7] {
            check_spmv_all(&gen::uniform_random(43, 37, 350, 50), threads);
            check_spmm_all(&gen::powerlaw(30, 2.0, 15, 51), 5, threads);
        }
    }

    #[test]
    fn parallel_kernels_adversarial_shapes() {
        // Mostly-empty rows.
        let mut sparse = crate::matrix::TriMat::new(12, 12);
        sparse.push(0, 11, 2.0);
        sparse.push(11, 0, 3.0);
        check_spmv_all(&sparse, 4);
        check_spmm_all(&sparse, 3, 4);
        // Single dense row among empties.
        let mut hog = crate::matrix::TriMat::new(8, 20);
        for j in 0..20 {
            hog.push(3, j, (j + 1) as f64 * 0.1);
        }
        hog.push(7, 0, 1.0);
        check_spmv_all(&hog, 4);
        check_spmm_all(&hog, 4, 4);
        // 1×N single row.
        let mut wide = crate::matrix::TriMat::new(1, 30);
        for j in (0..30).step_by(2) {
            wide.push(0, j, j as f64 + 0.5);
        }
        check_spmv_all(&wide, 4);
        // nrows < threads.
        check_spmv_all(&gen::uniform_random(3, 9, 12, 52), 8);
        check_spmm_all(&gen::uniform_random(3, 9, 12, 53), 2, 8);
    }

    #[test]
    fn k_not_multiple_of_four() {
        // The 4-wide micro-kernel must handle ragged k tails.
        for k in [1, 2, 3, 5, 7, 9] {
            check_spmm_all(&gen::uniform_random(17, 19, 90, 54), k, 3);
        }
    }
}
