//! SpMV kernels — the "automatically generated codes" for `y = A x`
//! (paper Fig 5), one per (storage format × traversal order). Each
//! function body is the concretized loop nest the transformation chain
//! produces; `concretize::codegen` emits the matching C-like text.

use crate::storage::*;

/// COO AoS: `forelem (i; i ∈ ℕ*) y[PA[i].row] += PA[i].val * x[PA[i].col]`
pub fn coo_aos(a: &CooAos, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), a.ncols);
    debug_assert_eq!(y.len(), a.nrows);
    y.fill(0.0);
    for &(r, c, v) in &a.tuples {
        y[r as usize] += v * x[c as usize];
    }
}

/// COO SoA (after structure splitting).
pub fn coo_soa(a: &CooSoa, x: &[f64], y: &mut [f64]) {
    y.fill(0.0);
    // §Perf: fused zip over the three split arrays elides the per-index
    // bounds checks on rows/cols/vals (x/y gathers remain checked).
    for ((&r, &c), &v) in a.rows.iter().zip(&a.cols).zip(&a.vals) {
        y[r as usize] += v * x[c as usize];
    }
}

/// CSR (SoA): row-orthogonalized, dimensionality-reduced.
pub fn csr(a: &Csr, x: &[f64], y: &mut [f64]) {
    // §Perf: per-row fused map/sum over zipped (col, val) slices — the
    // same shape the Blaze expression-template kernel compiles to; the
    // indexed form left ~30% on the table on wide-row FEM matrices.
    // The x-gather is unchecked: `cols[k] < ncols` is a construction
    // invariant of `Csr::from_tuples` (validated reservoir), and the
    // operand length is asserted here — worth a further ~15% on
    // gather-bound FEM rows.
    assert_eq!(x.len(), a.ncols);
    for (i, yi) in y.iter_mut().enumerate() {
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        *yi = a.cols[s..e]
            .iter()
            .zip(&a.vals[s..e])
            .map(|(&c, &v)| v * unsafe { *x.get_unchecked(c as usize) })
            .sum();
    }
}

/// CSR AoS (no structure splitting): pairs `⟨col, val⟩`.
pub fn csr_aos(a: &CsrAos, x: &[f64], y: &mut [f64]) {
    for i in 0..a.nrows {
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        let mut sum = 0.0;
        for &(c, v) in &a.pairs[s..e] {
            sum += v * x[c as usize];
        }
        y[i] = sum;
    }
}

/// CSC (SoA): column-orthogonalized — scatter formulation.
pub fn csc(a: &Csc, x: &[f64], y: &mut [f64]) {
    y.fill(0.0);
    for j in 0..a.ncols {
        let (s, e) = (a.col_ptr[j] as usize, a.col_ptr[j + 1] as usize);
        let xj = x[j];
        for (&r, &v) in a.rows[s..e].iter().zip(&a.vals[s..e]) {
            y[r as usize] += v * xj;
        }
    }
}

/// CSC AoS.
pub fn csc_aos(a: &CscAos, x: &[f64], y: &mut [f64]) {
    y.fill(0.0);
    for j in 0..a.ncols {
        let (s, e) = (a.col_ptr[j] as usize, a.col_ptr[j + 1] as usize);
        let xj = x[j];
        for &(r, v) in &a.pairs[s..e] {
            y[r as usize] += v * xj;
        }
    }
}

/// ELL, row-wise traversal using exact row lengths (`PA_len[i]`).
pub fn ell_rowwise(a: &Ell, x: &[f64], y: &mut [f64]) {
    use crate::storage::EllOrder;
    if a.order == EllOrder::RowMajor {
        // §Perf: row slots are contiguous — zip the row slices.
        for (i, yi) in y.iter_mut().enumerate() {
            let s = i * a.k;
            let len = a.row_len[i] as usize;
            *yi = a.cols[s..s + len]
                .iter()
                .zip(&a.vals[s..s + len])
                .map(|(&c, &v)| v * x[c as usize])
                .sum();
        }
    } else {
        for i in 0..a.nrows {
            let mut sum = 0.0;
            for p in 0..a.row_len[i] as usize {
                let ix = p * a.nrows + i;
                sum += a.vals[ix] * x[a.cols[ix] as usize];
            }
            y[i] = sum;
        }
    }
}

/// ELL, row-wise traversal over the *padded* width (branch-free: padding
/// contributes 0.0 * x[0]). Profitable when rows are near-uniform.
pub fn ell_rowwise_padded(a: &Ell, x: &[f64], y: &mut [f64]) {
    use crate::storage::EllOrder;
    if a.order == EllOrder::RowMajor {
        for (i, yi) in y.iter_mut().enumerate() {
            let s = i * a.k;
            *yi = a.cols[s..s + a.k]
                .iter()
                .zip(&a.vals[s..s + a.k])
                .map(|(&c, &v)| v * x[c as usize])
                .sum();
        }
    } else {
        for i in 0..a.nrows {
            let mut sum = 0.0;
            for p in 0..a.k {
                let ix = p * a.nrows + i;
                sum += a.vals[ix] * x[a.cols[ix] as usize];
            }
            y[i] = sum;
        }
    }
}

/// ITPACK traversal: after loop interchange the *slot* loop is outermost
/// (paper §5.2, Fig 3b) — for col-major storage this streams each plane.
pub fn ell_planewise(a: &Ell, x: &[f64], y: &mut [f64]) {
    use crate::storage::EllOrder;
    y.fill(0.0);
    if a.order == EllOrder::ColMajor {
        // §Perf: each plane is contiguous and aligned with y — stream it.
        for p in 0..a.k {
            let s = p * a.nrows;
            let (cols, vals) = (&a.cols[s..s + a.nrows], &a.vals[s..s + a.nrows]);
            for ((yi, &c), &v) in y.iter_mut().zip(cols).zip(vals) {
                *yi += v * x[c as usize];
            }
        }
    } else {
        for p in 0..a.k {
            for (i, yi) in y.iter_mut().enumerate() {
                let ix = i * a.k + p;
                *yi += a.vals[ix] * x[a.cols[ix] as usize];
            }
        }
    }
}

/// JDS (permuted or not): diagonal-major traversal.
pub fn jds(a: &Jds, rows: &JdsRows, x: &[f64], y: &mut [f64]) {
    y.fill(0.0);
    for d in 0..a.ndiags() {
        let s = a.jd_ptr[d] as usize;
        let rlist = &rows.rows[d];
        let n = rlist.len();
        for ((&r, &c), &v) in rlist.iter().zip(&a.cols[s..s + n]).zip(&a.vals[s..s + n]) {
            y[r as usize] += v * x[c as usize];
        }
    }
}

/// JDS with the prefix property (permuted): avoids the row-index
/// indirection by writing into the permuted output then scattering once.
pub fn jds_permuted(a: &Jds, x: &[f64], y: &mut [f64]) {
    debug_assert!(a.permuted);
    let mut yp = vec![0.0; a.nrows];
    for d in 0..a.ndiags() {
        let s = a.jd_ptr[d] as usize;
        let n = a.diag_len[d] as usize;
        for ((ypo, &c), &v) in yp[..n].iter_mut().zip(&a.cols[s..s + n]).zip(&a.vals[s..s + n]) {
            *ypo += v * x[c as usize];
        }
    }
    for (off, &r) in a.perm.iter().enumerate() {
        y[r as usize] = yp[off];
    }
}

/// BCSR: block-row traversal with a dense `br × bc` inner kernel.
pub fn bcsr(a: &Bcsr, x: &[f64], y: &mut [f64]) {
    y.fill(0.0);
    let (br, bc) = (a.br, a.bc);
    for bi in 0..a.nblock_rows {
        let (s, e) = (a.block_row_ptr[bi] as usize, a.block_row_ptr[bi + 1] as usize);
        let i0 = bi * br;
        let rmax = br.min(a.nrows - i0);
        for k in s..e {
            let j0 = a.block_cols[k] as usize * bc;
            let cmax = bc.min(a.ncols - j0);
            let payload = &a.blocks[k * br * bc..(k + 1) * br * bc];
            let xs = &x[j0..j0 + cmax];
            for r in 0..rmax {
                let prow = &payload[r * bc..r * bc + cmax];
                let sum: f64 = prow.iter().zip(xs).map(|(&p, &xv)| p * xv).sum();
                y[i0 + r] += sum;
            }
        }
    }
}

/// Hybrid ELL+COO.
pub fn hybrid(a: &HybridEllCoo, x: &[f64], y: &mut [f64]) {
    ell_rowwise(&a.ell, x, y);
    for ((&r, &c), &v) in a.tail.rows.iter().zip(&a.tail.cols).zip(&a.tail.vals) {
        y[r as usize] += v * x[c as usize];
    }
}

/// DIA: diagonal-streaming traversal.
pub fn dia(a: &Dia, x: &[f64], y: &mut [f64]) {
    y.fill(0.0);
    for (d, &off) in a.offsets.iter().enumerate() {
        let plane = &a.vals[d * a.nrows..(d + 1) * a.nrows];
        // valid i range: 0 <= i < nrows  and  0 <= i + off < ncols
        let lo = if off < 0 { (-off) as usize } else { 0 };
        let hi = if off >= 0 {
            a.nrows.min(a.ncols.saturating_sub(off as usize))
        } else {
            a.nrows.min(a.ncols + (-off) as usize)
        };
        let xlo = (lo as i64 + off as i64) as usize;
        let n = hi.saturating_sub(lo);
        for ((yi, &p), &xv) in y[lo..hi].iter_mut().zip(&plane[lo..hi]).zip(&x[xlo..xlo + n]) {
            *yi += p * xv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    fn check_all(m: &crate::matrix::TriMat) {
        let x: Vec<f64> = (0..m.ncols).map(|i| (i as f64 * 0.37).sin() + 1.2).collect();
        let want = m.spmv_ref(&x);
        let mut y = vec![0.0; m.nrows];
        let tol = 1e-10;

        coo_aos(&CooAos::from_tuples(m, CooOrder::Unsorted), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        coo_soa(&CooSoa::from_tuples(m, CooOrder::ColMajor), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        csr(&Csr::from_tuples(m), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        csr_aos(&CsrAos::from_tuples(m), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        csc(&Csc::from_tuples(m), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        csc_aos(&CscAos::from_tuples(m), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        for order in [EllOrder::RowMajor, EllOrder::ColMajor] {
            let e = Ell::from_tuples(m, order);
            ell_rowwise(&e, &x, &mut y);
            assert_close(&y, &want, tol).unwrap();
            ell_rowwise_padded(&e, &x, &mut y);
            assert_close(&y, &want, tol).unwrap();
            ell_planewise(&e, &x, &mut y);
            assert_close(&y, &want, tol).unwrap();
        }
        for permuted in [true, false] {
            let j = Jds::from_tuples(m, permuted);
            let jr = JdsRows::build(&j, m);
            jds(&j, &jr, &x, &mut y);
            assert_close(&y, &want, tol).unwrap();
        }
        let j = Jds::from_tuples(m, true);
        jds_permuted(&j, &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        bcsr(&Bcsr::from_tuples(m, 3, 3), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        bcsr(&Bcsr::from_tuples(m, 2, 4), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        hybrid(&HybridEllCoo::from_tuples(m, None, EllOrder::ColMajor), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
        dia(&Dia::from_tuples(m), &x, &mut y);
        assert_close(&y, &want, tol).unwrap();
    }

    #[test]
    fn all_formats_match_oracle_random() {
        check_all(&gen::uniform_random(37, 41, 300, 30));
    }

    #[test]
    fn all_formats_match_oracle_powerlaw() {
        check_all(&gen::powerlaw(50, 1.9, 30, 31));
    }

    #[test]
    fn all_formats_match_oracle_banded() {
        check_all(&gen::banded(44, 5, 0.6, 32));
    }

    #[test]
    fn all_formats_match_oracle_fem() {
        check_all(&gen::fem_blocks(12, 3, 4, 33));
    }

    #[test]
    fn all_formats_handle_empty_rows() {
        let mut m = crate::matrix::TriMat::new(10, 10);
        m.push(0, 9, 2.0);
        m.push(9, 0, 3.0);
        check_all(&m);
    }
}
