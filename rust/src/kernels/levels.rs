//! Level-scheduled triangular solve — the executor behind
//! `Schedule::Parallel` TrSv plans, removing the last kernel that was
//! pinned to `Serial`.
//!
//! Forward substitution carries a true dependence (`x[i]` needs every
//! `x[j]` with `L[i][j] != 0`), so row ranges cannot simply be split
//! across workers the way SpMV output rows can. But the dependence
//! graph is a DAG whose *level sets* — row `i` belongs to level
//! `1 + max(level[j])` over its dependencies — partition the rows into
//! waves of mutually independent solves. [`LevelSets`] materializes
//! that partition once at `prepare()` time (O(nnz)); the kernels then
//! execute level-by-level with all workers advancing in lockstep.
//!
//! Synchronization is a spin barrier over `std::sync::atomic` (no
//! locks, no per-level thread spawns): workers are spawned once per
//! solve and the `x` cells are shared as relaxed `AtomicU64` bit
//! patterns, with the barrier's acquire/release edges ordering every
//! cross-level read after the write it depends on. Within a level each
//! row is written by exactly one worker, and the per-row dot product
//! runs in the same order as the serial kernel, so the CSR solve is
//! *bit-identical* to `trsv::csr` (the CSC scatter reassociates sums
//! across levels and agrees to rounding).
//!
//! # Supernoded waves
//!
//! Banded matrices degenerate to near-per-row levels, where a barrier
//! per level costs more than the row it guards. [`LevelSets`] therefore
//! groups levels into *waves*: a maximal run of adjacent levels, each
//! narrower than [`SUPERNODE_MAX_WIDTH`], merges into one **serial
//! wave** (worker 0 executes the whole run in level order — the
//! dependences inside the run are satisfied by that single-thread
//! ordering — and everyone barriers once at the end); a wide level is
//! its own **parallel wave**, split across workers as before. The
//! barrier count drops from `nlevels` to [`LevelSets::nwaves`], which
//! is what the cost model's sync feature charges
//! (`MatrixStats::sync_waves`). Execution order per row/column is
//! unchanged, so CSR stays bit-identical to serial and CSC stays
//! deterministic.

use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use crate::storage::{Csc, Csr};
use crate::util::pool::scoped_run;

/// Levels at or below this width join a supernoded serial wave: too
/// narrow for a useful parallel split, so trading their barriers for a
/// short single-worker run is a straight win. Shared with
/// `MatrixStats`' `sync_waves` estimate so planner and executor agree.
pub const SUPERNODE_MAX_WIDTH: usize = 4;

/// The supernode merge rule, in one place: partition levels (given
/// their widths) into waves — each maximal run of adjacent levels of
/// width ≤ [`SUPERNODE_MAX_WIDTH`] is one wave, every wide level is
/// its own wave. Returns the `wave_ptr` level-offset array
/// (`wave_ptr[w]..wave_ptr[w+1]` = the levels of wave `w`). Both the
/// executable [`LevelSets`] and the planner's `MatrixStats.sync_waves`
/// estimate are built from this routine, so they cannot drift.
pub fn wave_partition(widths: &[usize]) -> Vec<u32> {
    let mut wave_ptr: Vec<u32> = vec![0];
    let mut in_narrow_run = false;
    for (l, &w) in widths.iter().enumerate() {
        if w <= SUPERNODE_MAX_WIDTH {
            if in_narrow_run {
                *wave_ptr.last_mut().unwrap() = (l + 1) as u32;
                continue;
            }
            in_narrow_run = true;
        } else {
            in_narrow_run = false;
        }
        wave_ptr.push((l + 1) as u32);
    }
    if wave_ptr.len() == 1 {
        wave_ptr.push(0); // no levels: one empty wave
    }
    wave_ptr
}

/// Number of barrier waves the supernoded schedule executes over the
/// given per-level widths.
pub fn count_waves(widths: &[usize]) -> usize {
    wave_partition(widths).len() - 1
}

/// Rows of a strictly-lower triangular matrix grouped into dependence
/// level sets: every row in level `l` depends only on rows in levels
/// `< l` — plus the supernoded wave partition over those levels (see
/// the module docs). Built once at `prepare()` time; part of the
/// generated data structure of a parallel TrSv plan.
#[derive(Clone, Debug)]
pub struct LevelSets {
    /// `level_ptr[l]..level_ptr[l+1]` indexes `rows` for level `l`.
    pub level_ptr: Vec<u32>,
    /// All rows, grouped by level, ascending within each level.
    pub rows: Vec<u32>,
    /// `wave_ptr[w]..wave_ptr[w+1]` is the range of *levels* wave `w`
    /// executes between two barriers ([`wave_partition`]). A wave
    /// spanning more than one level — or a single level of width ≤
    /// [`SUPERNODE_MAX_WIDTH`] — is a serial wave (worker 0 runs it
    /// alone).
    pub wave_ptr: Vec<u32>,
    /// Widest level, cached at build time so the executors' serial
    /// fallback check is O(1) per solve, not an O(nlevels) rescan
    /// inside the timed region.
    pub max_level_width: u32,
}

impl LevelSets {
    fn from_levels(level: &[u32]) -> Self {
        let n = level.len();
        let nlevels = level.iter().map(|&l| l as usize + 1).max().unwrap_or(0);
        let mut level_ptr = vec![0u32; nlevels + 1];
        for &l in level {
            level_ptr[l as usize + 1] += 1;
        }
        for l in 0..nlevels {
            level_ptr[l + 1] += level_ptr[l];
        }
        let mut rows = vec![0u32; n];
        let mut next = level_ptr.clone();
        // Row index order is ascending, so each level's slice stays
        // ascending — the deterministic intra-level visit order.
        for (i, &l) in level.iter().enumerate() {
            rows[next[l as usize] as usize] = i as u32;
            next[l as usize] += 1;
        }
        // Supernode: group levels into waves with the shared merge rule.
        let widths: Vec<usize> =
            (0..nlevels).map(|l| (level_ptr[l + 1] - level_ptr[l]) as usize).collect();
        let wave_ptr = wave_partition(&widths);
        let max_level_width = widths.iter().copied().max().unwrap_or(0) as u32;
        LevelSets { level_ptr, rows, wave_ptr, max_level_width }
    }

    /// Level sets of a strictly-lower CSR matrix:
    /// `level[i] = 1 + max(level[j])` over row `i`'s stored columns.
    pub fn from_csr(l: &Csr) -> Self {
        LevelSets::from_levels(&assign_levels(&l.row_ptr, &l.cols))
    }

    /// Level sets of a strictly-lower CSC matrix: when column `j` is
    /// visited, `level[j]` is final (all its updates came from earlier
    /// columns), so its entries push `level[j] + 1` to their rows.
    pub fn from_csc(l: &Csc) -> Self {
        let n = l.nrows;
        let mut level = vec![0u32; n];
        for j in 0..l.ncols.min(n) {
            let lj = level[j] + 1;
            let (s, e) = (l.col_ptr[j] as usize, l.col_ptr[j + 1] as usize);
            for &r in &l.rows[s..e] {
                debug_assert!((r as usize) > j, "storage must be strictly lower");
                let cell = &mut level[r as usize];
                *cell = (*cell).max(lj);
            }
        }
        LevelSets::from_levels(&level)
    }

    pub fn nlevels(&self) -> usize {
        self.level_ptr.len().saturating_sub(1)
    }

    /// Rows of level `l`, ascending.
    pub fn level_rows(&self, l: usize) -> &[u32] {
        &self.rows[self.level_ptr[l] as usize..self.level_ptr[l + 1] as usize]
    }

    /// Widest level — the solve's maximum exploitable parallelism
    /// (cached at build time).
    pub fn max_width(&self) -> usize {
        self.max_level_width as usize
    }

    /// Barrier waves of the supernoded schedule (≤ [`nlevels`](Self::nlevels)).
    pub fn nwaves(&self) -> usize {
        self.wave_ptr.len().saturating_sub(1)
    }

    /// The level range wave `w` executes between two barriers.
    pub fn wave_levels(&self, w: usize) -> Range<usize> {
        self.wave_ptr[w] as usize..self.wave_ptr[w + 1] as usize
    }

    /// Serial waves (supernoded narrow runs) run on worker 0 alone;
    /// the rest are single wide levels split across all workers.
    pub fn wave_is_serial(&self, w: usize) -> bool {
        let lr = self.wave_levels(w);
        lr.len() != 1 || self.level_rows(lr.start).len() <= SUPERNODE_MAX_WIDTH
    }

    pub fn bytes(&self) -> usize {
        (self.level_ptr.len() + self.rows.len() + self.wave_ptr.len()) * 4
    }
}

/// Dependence-level assignment over CSR-shaped `(row_ptr, cols)` arrays
/// of a strictly-lower structure: `level[i] = 1 + max(level[dep])`.
/// Shared by [`LevelSets::from_csr`] and `MatrixStats`' `dep_levels`
/// estimate so the two can never drift.
pub fn assign_levels(row_ptr: &[u32], cols: &[u32]) -> Vec<u32> {
    let n = row_ptr.len().saturating_sub(1);
    let mut level = vec![0u32; n];
    for i in 0..n {
        let (s, e) = (row_ptr[i] as usize, row_ptr[i + 1] as usize);
        let mut lv = 0u32;
        for &c in &cols[s..e] {
            debug_assert!((c as usize) < i, "storage must be strictly lower");
            lv = lv.max(level[c as usize] + 1);
        }
        level[i] = lv;
    }
    level
}

/// Sense-reversing spin barrier over atomics: one `wait()` per worker
/// per level, no locks, no syscalls on the fast path. The release on
/// the generation bump pairs with the acquire in the spin loop, so
/// every write before a `wait()` is visible after it.
///
/// The barrier carries a poison flag for worker-panic safety: a worker
/// that panics mid-wave will never arrive, which without the flag
/// would spin every sibling forever. The panicking worker calls
/// [`poison`](Self::poison) before unwinding; waiters observe the flag
/// at `wait()` entry and inside the spin loop, and `wait()` returns
/// `false` so they bail out of the wave loop instead of deadlocking.
struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
    poisoned: AtomicBool,
}

impl SpinBarrier {
    fn new(n: usize) -> Self {
        SpinBarrier {
            n,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
            poisoned: AtomicBool::new(false),
        }
    }

    /// Mark the barrier dead: every current and future `wait()` returns
    /// `false`. Called by a worker about to unwind out of its wave.
    fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
    }

    fn is_poisoned(&self) -> bool {
        self.poisoned.load(Ordering::Acquire)
    }

    /// Returns `true` on a normal release, `false` if the barrier was
    /// poisoned (the caller must stop executing waves).
    #[must_use]
    fn wait(&self) -> bool {
        if self.is_poisoned() {
            return false;
        }
        let arrived_gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
            self.count.store(0, Ordering::Relaxed);
            self.generation.fetch_add(1, Ordering::AcqRel);
        } else {
            let mut polls = 0u32;
            while self.generation.load(Ordering::Acquire) == arrived_gen {
                if self.is_poisoned() {
                    return false;
                }
                std::hint::spin_loop();
                polls += 1;
                // Pure spin on the fast path; after ~2^12 polls assume
                // oversubscription (fewer cores than workers — CI
                // runners) and let the OS run the stragglers.
                if polls >= 1 << 12 {
                    std::thread::yield_now();
                }
            }
        }
        true
    }
}

/// The contiguous share of `len` items worker `w` of `t` owns.
fn share(len: usize, w: usize, t: usize) -> Range<usize> {
    (w * len / t)..((w + 1) * len / t)
}

fn read(xa: &[AtomicU64], i: usize) -> f64 {
    f64::from_bits(xa[i].load(Ordering::Relaxed))
}

fn write(xa: &[AtomicU64], i: usize, v: f64) {
    xa[i].store(v.to_bits(), Ordering::Relaxed);
}

/// Level-scheduled CSR forward substitution (gather form), one barrier
/// per supernoded wave. A parallel wave's rows are split contiguously
/// across the workers; a serial wave's levels run on worker 0 in level
/// order. Every row's dot product runs in serial order, so the result
/// is bit-identical to `trsv::csr`.
pub fn csr_trsv_level(l: &Csr, lv: &LevelSets, b: &[f64], x: &mut [f64], threads: usize) {
    let t = threads.max(1).min(l.nrows.max(1));
    if t <= 1 || lv.nlevels() <= 1 || lv.max_width() <= SUPERNODE_MAX_WIDTH {
        // No exploitable width anywhere: the supernoded schedule would
        // be one serial wave — skip the spawns entirely.
        return crate::kernels::trsv::csr(l, b, x);
    }
    let xa: Vec<AtomicU64> = b.iter().map(|v| AtomicU64::new(v.to_bits())).collect();
    {
        let barrier = SpinBarrier::new(t);
        let xa = &xa;
        let barrier = &barrier;
        let solve_row = |i: usize| {
            let (s, e) = (l.row_ptr[i] as usize, l.row_ptr[i + 1] as usize);
            let sum: f64 = l.cols[s..e]
                .iter()
                .zip(&l.vals[s..e])
                .map(|(&c, &v)| v * read(xa, c as usize))
                .sum();
            write(xa, i, read(xa, i) - sum);
        };
        let solve_row = &solve_row;
        let tasks: Vec<_> = (0..t)
            .map(|w| {
                move || {
                    for wi in 0..lv.nwaves() {
                        if barrier.is_poisoned() {
                            return;
                        }
                        let wave = catch_unwind(AssertUnwindSafe(|| {
                            let levels = lv.wave_levels(wi);
                            if lv.wave_is_serial(wi) {
                                if w == 0 {
                                    for li in levels {
                                        for &i in lv.level_rows(li) {
                                            solve_row(i as usize);
                                        }
                                    }
                                }
                            } else {
                                let rows = lv.level_rows(levels.start);
                                for &i in &rows[share(rows.len(), w, t)] {
                                    solve_row(i as usize);
                                }
                            }
                        }));
                        if let Err(p) = wave {
                            // Release the siblings before unwinding, or
                            // they spin on this wave's barrier forever.
                            barrier.poison();
                            resume_unwind(p);
                        }
                        if !barrier.wait() {
                            return;
                        }
                    }
                }
            })
            .collect();
        scoped_run(tasks);
    }
    for (xi, a) in x.iter_mut().zip(&xa) {
        *xi = f64::from_bits(a.load(Ordering::Relaxed));
    }
}

/// Level-scheduled CSC forward substitution (scatter / right-looking
/// form, owner-computes). Workers own disjoint contiguous ranges of
/// `x`; in each level every worker scans the level's columns and
/// applies only the updates landing in its range (column entries are
/// row-sorted, so the owned slice is found by binary search). Each `x`
/// cell therefore receives its updates from a single worker in a fixed
/// (level, column) order — deterministic for every thread count, equal
/// to the serial solve up to rounding (the level grouping reassociates
/// the per-row sums).
pub fn csc_trsv_level(l: &Csc, lv: &LevelSets, b: &[f64], x: &mut [f64], threads: usize) {
    let n = l.nrows;
    let t = threads.max(1).min(n.max(1));
    if t <= 1 || lv.nlevels() <= 1 || lv.max_width() <= SUPERNODE_MAX_WIDTH {
        return crate::kernels::trsv::csc(l, b, x);
    }
    let xa: Vec<AtomicU64> = b.iter().map(|v| AtomicU64::new(v.to_bits())).collect();
    {
        let barrier = SpinBarrier::new(t);
        let xa = &xa;
        let barrier = &barrier;
        // Scatter every update of column j landing in `rows[lo..hi]` of
        // the owner range; `own = 0..n` scatters unconditionally (the
        // serial-wave path, where worker 0 is the only one running).
        let scatter_col = |j: usize, own: &Range<usize>| {
            if j >= l.ncols {
                return;
            }
            let xj = read(xa, j);
            let (s, e) = (l.col_ptr[j] as usize, l.col_ptr[j + 1] as usize);
            let rows = &l.rows[s..e];
            let lo = s + rows.partition_point(|&r| (r as usize) < own.start);
            let hi = s + rows.partition_point(|&r| (r as usize) < own.end);
            for p in lo..hi {
                let r = l.rows[p] as usize;
                write(xa, r, read(xa, r) - l.vals[p] * xj);
            }
        };
        let scatter_col = &scatter_col;
        let tasks: Vec<_> = (0..t)
            .map(|w| {
                let own = share(n, w, t);
                move || {
                    let all = 0..n;
                    for wi in 0..lv.nwaves() {
                        if barrier.is_poisoned() {
                            return;
                        }
                        let wave = catch_unwind(AssertUnwindSafe(|| {
                            let levels = lv.wave_levels(wi);
                            if lv.wave_is_serial(wi) {
                                // Worker 0 walks the merged levels in order,
                                // applying *all* updates — the single-thread
                                // level ordering satisfies the run's internal
                                // dependences; everyone else waits.
                                if w == 0 {
                                    for li in levels {
                                        for &j in lv.level_rows(li) {
                                            scatter_col(j as usize, &all);
                                        }
                                    }
                                }
                            } else {
                                // x[j] is final for every column j of this
                                // wave's level: all its updates were
                                // scattered in earlier waves.
                                for &j in lv.level_rows(levels.start) {
                                    scatter_col(j as usize, &own);
                                }
                            }
                        }));
                        if let Err(p) = wave {
                            barrier.poison();
                            resume_unwind(p);
                        }
                        if !barrier.wait() {
                            return;
                        }
                    }
                }
            })
            .collect();
        scoped_run(tasks);
    }
    for (xi, a) in x.iter_mut().zip(&xa) {
        *xi = f64::from_bits(a.load(Ordering::Relaxed));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{gen, TriMat};
    use crate::util::prop::assert_close;

    fn lower(m: &TriMat) -> TriMat {
        m.strictly_lower()
    }

    fn check_both(l: &TriMat, threads: usize) {
        let b: Vec<f64> = (0..l.nrows).map(|i| ((i % 11) as f64 - 5.0) * 0.4 + 0.1).collect();
        let want = l.trsv_unit_lower_ref(&b);
        let csr = Csr::from_tuples(l);
        let lv = LevelSets::from_csr(&csr);
        let mut x = vec![0.0; l.nrows];
        csr_trsv_level(&csr, &lv, &b, &mut x, threads);
        assert_close(&x, &want, 1e-9).unwrap_or_else(|e| panic!("csr t={threads}: {e}"));

        let csc = Csc::from_tuples(l);
        let lvc = LevelSets::from_csc(&csc);
        assert_eq!(lv.level_ptr, lvc.level_ptr, "CSR/CSC level structure must agree");
        assert_eq!(lv.rows, lvc.rows);
        csc_trsv_level(&csc, &lvc, &b, &mut x, threads);
        assert_close(&x, &want, 1e-9).unwrap_or_else(|e| panic!("csc t={threads}: {e}"));
    }

    #[test]
    fn level_sets_partition_rows() {
        let l = lower(&gen::uniform_random(40, 40, 300, 91));
        let csr = Csr::from_tuples(&l);
        let lv = LevelSets::from_csr(&csr);
        assert_eq!(lv.rows.len(), 40);
        let mut seen: Vec<u32> = lv.rows.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..40).collect::<Vec<u32>>());
        // Every row's dependencies sit in strictly earlier levels.
        let mut level_of = vec![0usize; 40];
        for li in 0..lv.nlevels() {
            for &i in lv.level_rows(li) {
                level_of[i as usize] = li;
            }
        }
        for i in 0..csr.nrows {
            let (s, e) = (csr.row_ptr[i] as usize, csr.row_ptr[i + 1] as usize);
            for &c in &csr.cols[s..e] {
                assert!(level_of[c as usize] < level_of[i], "dep not in earlier level");
            }
        }
        assert!(lv.max_width() >= 1);
        assert!(lv.bytes() > 0);
    }

    #[test]
    fn single_chain_is_fully_serial() {
        // x[i] depends on x[i-1]: one row per level, nlevels == n —
        // and the supernode rule collapses the whole chain into a
        // single serial wave (one barrier instead of twelve).
        let mut m = TriMat::new(12, 12);
        for i in 1..12 {
            m.push(i, i - 1, 0.5);
        }
        let csr = Csr::from_tuples(&m);
        let lv = LevelSets::from_csr(&csr);
        assert_eq!(lv.nlevels(), 12);
        assert_eq!(lv.max_width(), 1);
        assert_eq!(lv.nwaves(), 1);
        assert!(lv.wave_is_serial(0));
        check_both(&m, 4);
    }

    #[test]
    fn empty_matrix_is_one_level() {
        let m = TriMat::new(8, 8);
        let lv = LevelSets::from_csr(&Csr::from_tuples(&m));
        assert_eq!(lv.nlevels(), 1);
        assert_eq!(lv.max_width(), 8);
        assert_eq!(lv.nwaves(), 1);
        assert!(!lv.wave_is_serial(0)); // one wide level: parallel wave
        check_both(&m, 3);
    }

    #[test]
    fn supernoding_merges_narrow_runs_only() {
        // Level widths by construction: level 0 = {0..8} (8 rows, wide),
        // then a 6-deep chain 8→9→…→14 of width-1 levels, then a wide
        // fan level {15..20} depending on row 14. Expected waves:
        // [wide 0][merged narrow run 1..7][wide 7].
        let mut m = TriMat::new(21, 21);
        for i in 8..15 {
            m.push(i, i - 1, 0.5); // the chain
        }
        for i in 15..21 {
            m.push(i, 14, 0.25); // wide fan off the chain's end
        }
        let csr = Csr::from_tuples(&m);
        let lv = LevelSets::from_csr(&csr);
        assert_eq!(lv.nlevels(), 9); // level 0 + 7 chain levels + fan
        assert_eq!(lv.nwaves(), 3, "wave_ptr = {:?}", lv.wave_ptr);
        assert!(!lv.wave_is_serial(0));
        assert!(lv.wave_is_serial(1));
        assert_eq!(lv.wave_levels(1), 1..8);
        assert!(!lv.wave_is_serial(2));
        assert_eq!(count_waves(&[8, 1, 1, 1, 1, 1, 1, 1, 6]), 3);
        // Wave execution stays correct and (for CSR) bit-identical.
        check_both(&m, 4);
        let b: Vec<f64> = (0..21).map(|i| (i as f64 * 0.31).cos()).collect();
        let mut serial = vec![0.0; 21];
        crate::kernels::trsv::csr(&csr, &b, &mut serial);
        for t in [2, 3, 8] {
            let mut x = vec![0.0; 21];
            csr_trsv_level(&csr, &lv, &b, &mut x, t);
            assert_eq!(x, serial, "t={t}: supernoded solve drifted from serial");
        }
    }

    #[test]
    fn count_waves_rule() {
        assert_eq!(count_waves(&[]), 1);
        assert_eq!(count_waves(&[1, 1, 1]), 1);
        assert_eq!(count_waves(&[10, 10]), 2);
        assert_eq!(count_waves(&[10, 1, 1, 10, 2]), 4);
        assert_eq!(count_waves(&[1, 10, 1]), 3);
        assert_eq!(count_waves(&[SUPERNODE_MAX_WIDTH, SUPERNODE_MAX_WIDTH + 1]), 2);
    }

    #[test]
    fn matches_serial_on_random_triangles() {
        for seed in [92, 93, 94] {
            let l = lower(&gen::uniform_random(50, 50, 420, seed));
            for t in [1, 2, 3, 4, 8] {
                check_both(&l, t);
            }
        }
    }

    #[test]
    fn matches_serial_on_dense_rows_and_banded() {
        // One dense row depending on everything before it.
        let mut m = TriMat::new(20, 20);
        for j in 0..19 {
            m.push(19, j, (j as f64 - 9.0) * 0.1);
        }
        m.push(3, 1, 0.7);
        m.push(7, 3, -0.4);
        check_both(&m, 4);
        // Banded: long dependence chains, narrow levels.
        check_both(&lower(&gen::banded(40, 3, 0.9, 95)), 4);
    }

    #[test]
    fn csr_level_solve_is_bit_identical_to_serial() {
        let l = lower(&gen::uniform_random(60, 60, 500, 96));
        let csr = Csr::from_tuples(&l);
        let lv = LevelSets::from_csr(&csr);
        let b: Vec<f64> = (0..60).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut serial = vec![0.0; 60];
        crate::kernels::trsv::csr(&csr, &b, &mut serial);
        for t in [2, 3, 5] {
            let mut x = vec![0.0; 60];
            csr_trsv_level(&csr, &lv, &b, &mut x, t);
            assert_eq!(x, serial, "t={t}: per-row dot order must match serial exactly");
        }
    }

    #[test]
    fn poisoned_barrier_releases_waiters() {
        // The worker-panic safety contract: a waiter spinning on a
        // barrier whose sibling died must observe the poison and bail
        // out (wait() -> false) rather than deadlock.
        let b = SpinBarrier::new(2);
        std::thread::scope(|s| {
            let waiter = s.spawn(|| b.wait());
            std::thread::sleep(std::time::Duration::from_millis(20));
            b.poison();
            let released = waiter.join().unwrap();
            assert!(!released, "poisoned wait must report failure, not release");
        });
        assert!(b.is_poisoned());
        assert!(!b.wait(), "a poisoned barrier stays dead");
    }

    #[test]
    fn barrier_releases_normally_without_poison() {
        let b = SpinBarrier::new(3);
        std::thread::scope(|s| {
            let hs: Vec<_> = (0..3).map(|_| s.spawn(|| b.wait() && b.wait())).collect();
            for h in hs {
                assert!(h.join().unwrap(), "both generations must release cleanly");
            }
        });
    }

    #[test]
    fn threads_beyond_rows_ok() {
        let l = lower(&gen::uniform_random(5, 5, 8, 97));
        check_both(&l, 16);
    }
}
