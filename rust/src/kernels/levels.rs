//! Level-scheduled triangular solve — the executor behind
//! `Schedule::Parallel` TrSv plans, removing the last kernel that was
//! pinned to `Serial`.
//!
//! Forward substitution carries a true dependence (`x[i]` needs every
//! `x[j]` with `L[i][j] != 0`), so row ranges cannot simply be split
//! across workers the way SpMV output rows can. But the dependence
//! graph is a DAG whose *level sets* — row `i` belongs to level
//! `1 + max(level[j])` over its dependencies — partition the rows into
//! waves of mutually independent solves. [`LevelSets`] materializes
//! that partition once at `prepare()` time (O(nnz)); the kernels then
//! execute level-by-level with all workers advancing in lockstep.
//!
//! Synchronization is a spin barrier over `std::sync::atomic` (no
//! locks, no per-level thread spawns): workers are spawned once per
//! solve and the `x` cells are shared as relaxed `AtomicU64` bit
//! patterns, with the barrier's acquire/release edges ordering every
//! cross-level read after the write it depends on. Within a level each
//! row is written by exactly one worker, and the per-row dot product
//! runs in the same order as the serial kernel, so the CSR solve is
//! *bit-identical* to `trsv::csr` (the CSC scatter reassociates sums
//! across levels and agrees to rounding).

use std::ops::Range;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::storage::{Csc, Csr};
use crate::util::pool::scoped_run;

/// Rows of a strictly-lower triangular matrix grouped into dependence
/// level sets: every row in level `l` depends only on rows in levels
/// `< l`. Built once at `prepare()` time; part of the generated data
/// structure of a parallel TrSv plan.
#[derive(Clone, Debug)]
pub struct LevelSets {
    /// `level_ptr[l]..level_ptr[l+1]` indexes `rows` for level `l`.
    pub level_ptr: Vec<u32>,
    /// All rows, grouped by level, ascending within each level.
    pub rows: Vec<u32>,
}

impl LevelSets {
    fn from_levels(level: &[u32]) -> Self {
        let n = level.len();
        let nlevels = level.iter().map(|&l| l as usize + 1).max().unwrap_or(0);
        let mut level_ptr = vec![0u32; nlevels + 1];
        for &l in level {
            level_ptr[l as usize + 1] += 1;
        }
        for l in 0..nlevels {
            level_ptr[l + 1] += level_ptr[l];
        }
        let mut rows = vec![0u32; n];
        let mut next = level_ptr.clone();
        // Row index order is ascending, so each level's slice stays
        // ascending — the deterministic intra-level visit order.
        for (i, &l) in level.iter().enumerate() {
            rows[next[l as usize] as usize] = i as u32;
            next[l as usize] += 1;
        }
        LevelSets { level_ptr, rows }
    }

    /// Level sets of a strictly-lower CSR matrix:
    /// `level[i] = 1 + max(level[j])` over row `i`'s stored columns.
    pub fn from_csr(l: &Csr) -> Self {
        LevelSets::from_levels(&assign_levels(&l.row_ptr, &l.cols))
    }

    /// Level sets of a strictly-lower CSC matrix: when column `j` is
    /// visited, `level[j]` is final (all its updates came from earlier
    /// columns), so its entries push `level[j] + 1` to their rows.
    pub fn from_csc(l: &Csc) -> Self {
        let n = l.nrows;
        let mut level = vec![0u32; n];
        for j in 0..l.ncols.min(n) {
            let lj = level[j] + 1;
            let (s, e) = (l.col_ptr[j] as usize, l.col_ptr[j + 1] as usize);
            for &r in &l.rows[s..e] {
                debug_assert!((r as usize) > j, "storage must be strictly lower");
                let cell = &mut level[r as usize];
                *cell = (*cell).max(lj);
            }
        }
        LevelSets::from_levels(&level)
    }

    pub fn nlevels(&self) -> usize {
        self.level_ptr.len().saturating_sub(1)
    }

    /// Rows of level `l`, ascending.
    pub fn level_rows(&self, l: usize) -> &[u32] {
        &self.rows[self.level_ptr[l] as usize..self.level_ptr[l + 1] as usize]
    }

    /// Widest level — the solve's maximum exploitable parallelism.
    pub fn max_width(&self) -> usize {
        (0..self.nlevels()).map(|l| self.level_rows(l).len()).max().unwrap_or(0)
    }

    pub fn bytes(&self) -> usize {
        (self.level_ptr.len() + self.rows.len()) * 4
    }
}

/// Dependence-level assignment over CSR-shaped `(row_ptr, cols)` arrays
/// of a strictly-lower structure: `level[i] = 1 + max(level[dep])`.
/// Shared by [`LevelSets::from_csr`] and `MatrixStats`' `dep_levels`
/// estimate so the two can never drift.
pub fn assign_levels(row_ptr: &[u32], cols: &[u32]) -> Vec<u32> {
    let n = row_ptr.len().saturating_sub(1);
    let mut level = vec![0u32; n];
    for i in 0..n {
        let (s, e) = (row_ptr[i] as usize, row_ptr[i + 1] as usize);
        let mut lv = 0u32;
        for &c in &cols[s..e] {
            debug_assert!((c as usize) < i, "storage must be strictly lower");
            lv = lv.max(level[c as usize] + 1);
        }
        level[i] = lv;
    }
    level
}

/// Sense-reversing spin barrier over atomics: one `wait()` per worker
/// per level, no locks, no syscalls on the fast path. The release on
/// the generation bump pairs with the acquire in the spin loop, so
/// every write before a `wait()` is visible after it.
struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    fn new(n: usize) -> Self {
        SpinBarrier { n, count: AtomicUsize::new(0), generation: AtomicUsize::new(0) }
    }

    fn wait(&self) {
        let arrived_gen = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
            self.count.store(0, Ordering::Relaxed);
            self.generation.fetch_add(1, Ordering::AcqRel);
        } else {
            let mut polls = 0u32;
            while self.generation.load(Ordering::Acquire) == arrived_gen {
                std::hint::spin_loop();
                polls += 1;
                // Pure spin on the fast path; after ~2^12 polls assume
                // oversubscription (fewer cores than workers — CI
                // runners) and let the OS run the stragglers.
                if polls >= 1 << 12 {
                    std::thread::yield_now();
                }
            }
        }
    }
}

/// The contiguous share of `len` items worker `w` of `t` owns.
fn share(len: usize, w: usize, t: usize) -> Range<usize> {
    (w * len / t)..((w + 1) * len / t)
}

fn read(xa: &[AtomicU64], i: usize) -> f64 {
    f64::from_bits(xa[i].load(Ordering::Relaxed))
}

fn write(xa: &[AtomicU64], i: usize, v: f64) {
    xa[i].store(v.to_bits(), Ordering::Relaxed);
}

/// Level-scheduled CSR forward substitution (gather form). Each level's
/// rows are split contiguously across the workers; every row's dot
/// product runs in serial order, so the result is bit-identical to
/// `trsv::csr`.
pub fn csr_trsv_level(l: &Csr, lv: &LevelSets, b: &[f64], x: &mut [f64], threads: usize) {
    let t = threads.max(1).min(l.nrows.max(1));
    if t <= 1 || lv.nlevels() <= 1 {
        return crate::kernels::trsv::csr(l, b, x);
    }
    let xa: Vec<AtomicU64> = b.iter().map(|v| AtomicU64::new(v.to_bits())).collect();
    {
        let barrier = SpinBarrier::new(t);
        let xa = &xa;
        let barrier = &barrier;
        let tasks: Vec<_> = (0..t)
            .map(|w| {
                move || {
                    for li in 0..lv.nlevels() {
                        let rows = lv.level_rows(li);
                        for &i in &rows[share(rows.len(), w, t)] {
                            let i = i as usize;
                            let (s, e) = (l.row_ptr[i] as usize, l.row_ptr[i + 1] as usize);
                            let sum: f64 = l.cols[s..e]
                                .iter()
                                .zip(&l.vals[s..e])
                                .map(|(&c, &v)| v * read(xa, c as usize))
                                .sum();
                            write(xa, i, read(xa, i) - sum);
                        }
                        barrier.wait();
                    }
                }
            })
            .collect();
        scoped_run(tasks);
    }
    for (xi, a) in x.iter_mut().zip(&xa) {
        *xi = f64::from_bits(a.load(Ordering::Relaxed));
    }
}

/// Level-scheduled CSC forward substitution (scatter / right-looking
/// form, owner-computes). Workers own disjoint contiguous ranges of
/// `x`; in each level every worker scans the level's columns and
/// applies only the updates landing in its range (column entries are
/// row-sorted, so the owned slice is found by binary search). Each `x`
/// cell therefore receives its updates from a single worker in a fixed
/// (level, column) order — deterministic for every thread count, equal
/// to the serial solve up to rounding (the level grouping reassociates
/// the per-row sums).
pub fn csc_trsv_level(l: &Csc, lv: &LevelSets, b: &[f64], x: &mut [f64], threads: usize) {
    let n = l.nrows;
    let t = threads.max(1).min(n.max(1));
    if t <= 1 || lv.nlevels() <= 1 {
        return crate::kernels::trsv::csc(l, b, x);
    }
    let xa: Vec<AtomicU64> = b.iter().map(|v| AtomicU64::new(v.to_bits())).collect();
    {
        let barrier = SpinBarrier::new(t);
        let xa = &xa;
        let barrier = &barrier;
        let tasks: Vec<_> = (0..t)
            .map(|w| {
                let own = share(n, w, t);
                move || {
                    for li in 0..lv.nlevels() {
                        // x[j] is final for every level-li column j: all
                        // its updates were scattered in earlier levels.
                        for &j in lv.level_rows(li) {
                            let j = j as usize;
                            if j >= l.ncols {
                                continue;
                            }
                            let xj = read(xa, j);
                            let (s, e) = (l.col_ptr[j] as usize, l.col_ptr[j + 1] as usize);
                            let rows = &l.rows[s..e];
                            let lo = s + rows.partition_point(|&r| (r as usize) < own.start);
                            let hi = s + rows.partition_point(|&r| (r as usize) < own.end);
                            for p in lo..hi {
                                let r = l.rows[p] as usize;
                                write(xa, r, read(xa, r) - l.vals[p] * xj);
                            }
                        }
                        barrier.wait();
                    }
                }
            })
            .collect();
        scoped_run(tasks);
    }
    for (xi, a) in x.iter_mut().zip(&xa) {
        *xi = f64::from_bits(a.load(Ordering::Relaxed));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{gen, TriMat};
    use crate::util::prop::assert_close;

    fn lower(m: &TriMat) -> TriMat {
        m.strictly_lower()
    }

    fn check_both(l: &TriMat, threads: usize) {
        let b: Vec<f64> = (0..l.nrows).map(|i| ((i % 11) as f64 - 5.0) * 0.4 + 0.1).collect();
        let want = l.trsv_unit_lower_ref(&b);
        let csr = Csr::from_tuples(l);
        let lv = LevelSets::from_csr(&csr);
        let mut x = vec![0.0; l.nrows];
        csr_trsv_level(&csr, &lv, &b, &mut x, threads);
        assert_close(&x, &want, 1e-9).unwrap_or_else(|e| panic!("csr t={threads}: {e}"));

        let csc = Csc::from_tuples(l);
        let lvc = LevelSets::from_csc(&csc);
        assert_eq!(lv.level_ptr, lvc.level_ptr, "CSR/CSC level structure must agree");
        assert_eq!(lv.rows, lvc.rows);
        csc_trsv_level(&csc, &lvc, &b, &mut x, threads);
        assert_close(&x, &want, 1e-9).unwrap_or_else(|e| panic!("csc t={threads}: {e}"));
    }

    #[test]
    fn level_sets_partition_rows() {
        let l = lower(&gen::uniform_random(40, 40, 300, 91));
        let csr = Csr::from_tuples(&l);
        let lv = LevelSets::from_csr(&csr);
        assert_eq!(lv.rows.len(), 40);
        let mut seen: Vec<u32> = lv.rows.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..40).collect::<Vec<u32>>());
        // Every row's dependencies sit in strictly earlier levels.
        let mut level_of = vec![0usize; 40];
        for li in 0..lv.nlevels() {
            for &i in lv.level_rows(li) {
                level_of[i as usize] = li;
            }
        }
        for i in 0..csr.nrows {
            let (s, e) = (csr.row_ptr[i] as usize, csr.row_ptr[i + 1] as usize);
            for &c in &csr.cols[s..e] {
                assert!(level_of[c as usize] < level_of[i], "dep not in earlier level");
            }
        }
        assert!(lv.max_width() >= 1);
        assert!(lv.bytes() > 0);
    }

    #[test]
    fn single_chain_is_fully_serial() {
        // x[i] depends on x[i-1]: one row per level, nlevels == n.
        let mut m = TriMat::new(12, 12);
        for i in 1..12 {
            m.push(i, i - 1, 0.5);
        }
        let csr = Csr::from_tuples(&m);
        let lv = LevelSets::from_csr(&csr);
        assert_eq!(lv.nlevels(), 12);
        assert_eq!(lv.max_width(), 1);
        check_both(&m, 4);
    }

    #[test]
    fn empty_matrix_is_one_level() {
        let m = TriMat::new(8, 8);
        let lv = LevelSets::from_csr(&Csr::from_tuples(&m));
        assert_eq!(lv.nlevels(), 1);
        assert_eq!(lv.max_width(), 8);
        check_both(&m, 3);
    }

    #[test]
    fn matches_serial_on_random_triangles() {
        for seed in [92, 93, 94] {
            let l = lower(&gen::uniform_random(50, 50, 420, seed));
            for t in [1, 2, 3, 4, 8] {
                check_both(&l, t);
            }
        }
    }

    #[test]
    fn matches_serial_on_dense_rows_and_banded() {
        // One dense row depending on everything before it.
        let mut m = TriMat::new(20, 20);
        for j in 0..19 {
            m.push(19, j, (j as f64 - 9.0) * 0.1);
        }
        m.push(3, 1, 0.7);
        m.push(7, 3, -0.4);
        check_both(&m, 4);
        // Banded: long dependence chains, narrow levels.
        check_both(&lower(&gen::banded(40, 3, 0.9, 95)), 4);
    }

    #[test]
    fn csr_level_solve_is_bit_identical_to_serial() {
        let l = lower(&gen::uniform_random(60, 60, 500, 96));
        let csr = Csr::from_tuples(&l);
        let lv = LevelSets::from_csr(&csr);
        let b: Vec<f64> = (0..60).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut serial = vec![0.0; 60];
        crate::kernels::trsv::csr(&csr, &b, &mut serial);
        for t in [2, 3, 5] {
            let mut x = vec![0.0; 60];
            csr_trsv_level(&csr, &lv, &b, &mut x, t);
            assert_eq!(x, serial, "t={t}: per-row dot order must match serial exactly");
        }
    }

    #[test]
    fn threads_beyond_rows_ok() {
        let l = lower(&gen::uniform_random(5, 5, 8, 97));
        check_both(&l, 16);
    }
}
