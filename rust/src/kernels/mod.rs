//! Compute kernels over the generated storage formats — one function per
//! (kernel × format × traversal), each the concretization of a specific
//! transformation chain. `search::tree` binds these into the paper's
//! variant space; `concretize::codegen` emits the matching C-like text.

pub mod levels;
pub mod par;
pub mod simd;
pub mod spmm;
pub mod spmv;
pub mod trsv;
