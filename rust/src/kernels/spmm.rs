//! SpMM kernels — `C = A B` with `B` dense `ncols × k` (row-major), the
//! paper's "sparse matrix times k vectors" workload (§6.3, Fig 10;
//! Table 2 uses k = 100). Each variant is the SpMV loop nest with the
//! dense `k` loop innermost — which is exactly what the extra inner
//! forelem loop concretizes to — so data-structure effects are the same
//! but amortized differently (reuse of A across k columns).

use crate::storage::*;

/// Register-blocked micro-kernel shared by the CSR and BCSR SpMM nests
/// (and their `Schedule::Parallel` counterparts in `kernels::par`):
/// `C_row += v * B_row` with a 4-wide unroll over the dense k
/// dimension, keeping four independent accumulators live per step so
/// the FMA chain is not serialized on one register.
#[inline(always)]
pub fn axpy_k4(crow: &mut [f64], brow: &[f64], v: f64) {
    debug_assert_eq!(crow.len(), brow.len());
    let k4 = crow.len() & !3;
    let (cm, ct) = crow.split_at_mut(k4);
    let (bm, bt) = brow.split_at(k4);
    for (cc, bb) in cm.chunks_exact_mut(4).zip(bm.chunks_exact(4)) {
        cc[0] += v * bb[0];
        cc[1] += v * bb[1];
        cc[2] += v * bb[2];
        cc[3] += v * bb[3];
    }
    for (cj, &bj) in ct.iter_mut().zip(bt) {
        *cj += v * bj;
    }
}

/// [`axpy_k4`] widened to an 8-wide unroll — the register-blocked
/// micro-kernel the `lanes = 8` SpMM plans select (`kernels::simd`).
/// Element-wise like the 4-wide form, so every unroll width
/// accumulates each output slot in the identical order.
#[inline(always)]
pub fn axpy_k8(crow: &mut [f64], brow: &[f64], v: f64) {
    debug_assert_eq!(crow.len(), brow.len());
    let k8 = crow.len() & !7;
    let (cm, ct) = crow.split_at_mut(k8);
    let (bm, bt) = brow.split_at(k8);
    for (cc, bb) in cm.chunks_exact_mut(8).zip(bm.chunks_exact(8)) {
        cc[0] += v * bb[0];
        cc[1] += v * bb[1];
        cc[2] += v * bb[2];
        cc[3] += v * bb[3];
        cc[4] += v * bb[4];
        cc[5] += v * bb[5];
        cc[6] += v * bb[6];
        cc[7] += v * bb[7];
    }
    for (cj, &bj) in ct.iter_mut().zip(bt) {
        *cj += v * bj;
    }
}

/// COO AoS.
pub fn coo_aos(a: &CooAos, b: &[f64], k: usize, c: &mut [f64]) {
    c.fill(0.0);
    for &(r, cc, v) in &a.tuples {
        let brow = &b[cc as usize * k..cc as usize * k + k];
        let crow = &mut c[r as usize * k..r as usize * k + k];
        crow.iter_mut().zip(brow).for_each(|(cj, &bj)| *cj += v * bj);
    }
}

/// COO SoA.
pub fn coo_soa(a: &CooSoa, b: &[f64], k: usize, c: &mut [f64]) {
    c.fill(0.0);
    for i in 0..a.vals.len() {
        let (r, cc, v) = (a.rows[i] as usize, a.cols[i] as usize, a.vals[i]);
        let brow = &b[cc * k..cc * k + k];
        let crow = &mut c[r * k..r * k + k];
        crow.iter_mut().zip(brow).for_each(|(cj, &bj)| *cj += v * bj);
    }
}

/// CSR, row-wise: accumulates each output row in place (register/L1
/// resident for modest k) through the register-blocked micro-kernel.
pub fn csr(a: &Csr, b: &[f64], k: usize, c: &mut [f64]) {
    for i in 0..a.nrows {
        let crow = &mut c[i * k..i * k + k];
        crow.fill(0.0);
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        for p in s..e {
            let col = a.cols[p] as usize;
            axpy_k4(crow, &b[col * k..col * k + k], a.vals[p]);
        }
    }
}

/// CSR row-dot panel variant — the batching queue's correctness
/// anchor. Each output slot `C[i][j]` is computed as a *scalar* dot
/// product folding from 0.0 over the row's entries in `p`-ascending
/// order: the exact operation sequence `kernels::spmv::csr` performs
/// for `x = B[:, j]`, so every column of the panel result is
/// bit-identical to the per-request SpMV it replaced. (`csr` above
/// produces the same bits for the canonical set — `axpy_k4`
/// accumulates each slot element-wise in the same order — but this
/// form *is* the per-column SpMV loop, so the contract is structural
/// rather than an argument about unroll shapes.)
pub fn csr_rowdot_k(a: &Csr, b: &[f64], k: usize, c: &mut [f64]) {
    for i in 0..a.nrows {
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        let crow = &mut c[i * k..i * k + k];
        for (j, cj) in crow.iter_mut().enumerate() {
            let mut acc = 0.0;
            for p in s..e {
                acc += a.vals[p] * b[a.cols[p] as usize * k + j];
            }
            *cj = acc;
        }
    }
}

/// CSR AoS.
pub fn csr_aos(a: &CsrAos, b: &[f64], k: usize, c: &mut [f64]) {
    for i in 0..a.nrows {
        let crow = &mut c[i * k..i * k + k];
        crow.fill(0.0);
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        for &(col, v) in &a.pairs[s..e] {
            let brow = &b[col as usize * k..col as usize * k + k];
            crow.iter_mut().zip(brow).for_each(|(cj, &bj)| *cj += v * bj);
        }
    }
}

/// CSC: scatter per column, B-row reused across the whole column.
pub fn csc(a: &Csc, b: &[f64], k: usize, c: &mut [f64]) {
    c.fill(0.0);
    for col in 0..a.ncols {
        let (s, e) = (a.col_ptr[col] as usize, a.col_ptr[col + 1] as usize);
        let brow = &b[col * k..col * k + k];
        for p in s..e {
            let v = a.vals[p];
            let crow = &mut c[a.rows[p] as usize * k..a.rows[p] as usize * k + k];
            crow.iter_mut().zip(brow).for_each(|(cj, &bj)| *cj += v * bj);
        }
    }
}

/// CSC AoS.
pub fn csc_aos(a: &CscAos, b: &[f64], k: usize, c: &mut [f64]) {
    c.fill(0.0);
    for col in 0..a.ncols {
        let (s, e) = (a.col_ptr[col] as usize, a.col_ptr[col + 1] as usize);
        let brow = &b[col * k..col * k + k];
        for &(r, v) in &a.pairs[s..e] {
            let crow = &mut c[r as usize * k..r as usize * k + k];
            crow.iter_mut().zip(brow).for_each(|(cj, &bj)| *cj += v * bj);
        }
    }
}

/// ELL row-wise (exact lengths).
pub fn ell_rowwise(a: &Ell, b: &[f64], k: usize, c: &mut [f64]) {
    for i in 0..a.nrows {
        let crow = &mut c[i * k..i * k + k];
        crow.fill(0.0);
        for p in 0..a.row_len[i] as usize {
            let ix = a.index(i, p);
            let v = a.vals[ix];
            let brow = &b[a.cols[ix] as usize * k..a.cols[ix] as usize * k + k];
            crow.iter_mut().zip(brow).for_each(|(cj, &bj)| *cj += v * bj);
        }
    }
}

/// ELL plane-wise (ITPACK traversal after loop interchange).
pub fn ell_planewise(a: &Ell, b: &[f64], k: usize, c: &mut [f64]) {
    c.fill(0.0);
    for p in 0..a.k {
        for i in 0..a.nrows {
            let ix = a.index(i, p);
            let v = a.vals[ix];
            if v == 0.0 {
                continue; // padding
            }
            let brow = &b[a.cols[ix] as usize * k..a.cols[ix] as usize * k + k];
            let crow = &mut c[i * k..i * k + k];
            crow.iter_mut().zip(brow).for_each(|(cj, &bj)| *cj += v * bj);
        }
    }
}

/// JDS diagonal-major.
pub fn jds(a: &Jds, rows: &JdsRows, b: &[f64], k: usize, c: &mut [f64]) {
    c.fill(0.0);
    for d in 0..a.ndiags() {
        let s = a.jd_ptr[d] as usize;
        for (off, &r) in rows.rows[d].iter().enumerate() {
            let v = a.vals[s + off];
            let col = a.cols[s + off] as usize;
            let brow = &b[col * k..col * k + k];
            let crow = &mut c[r as usize * k..r as usize * k + k];
            crow.iter_mut().zip(brow).for_each(|(cj, &bj)| *cj += v * bj);
        }
    }
}

/// BCSR: dense (br×bc)·(bc×k) micro-GEMM per block.
pub fn bcsr(a: &Bcsr, b: &[f64], k: usize, c: &mut [f64]) {
    c.fill(0.0);
    let (br, bc) = (a.br, a.bc);
    for bi in 0..a.nblock_rows {
        let (s, e) = (a.block_row_ptr[bi] as usize, a.block_row_ptr[bi + 1] as usize);
        let i0 = bi * br;
        let rmax = br.min(a.nrows - i0);
        for blk in s..e {
            let j0 = a.block_cols[blk] as usize * bc;
            let cmax = bc.min(a.ncols - j0);
            let payload = &a.blocks[blk * br * bc..(blk + 1) * br * bc];
            for r in 0..rmax {
                let crow = &mut c[(i0 + r) * k..(i0 + r) * k + k];
                for cc in 0..cmax {
                    let v = payload[r * bc + cc];
                    if v == 0.0 {
                        continue; // block fill-in
                    }
                    axpy_k4(crow, &b[(j0 + cc) * k..(j0 + cc) * k + k], v);
                }
            }
        }
    }
}

/// CSR SpMM over one B/C column panel (`Schedule::Tiled` /
/// `ParallelTiled`): rows `row0..row0 + c.len()/k` of `C`, columns
/// `cols` only. `b` and `c` keep the full row stride `k`; each output
/// row × panel cell is written exactly once across the panel sweep, so
/// the driver needs no pre-zeroing. Narrow panels keep the gathered
/// B-row granule to a few cache lines (L1-resident at the paper's
/// k = 100) at the cost of re-streaming the sparse structure per panel.
pub fn csr_panel(
    a: &Csr,
    b: &[f64],
    k: usize,
    c: &mut [f64],
    cols: std::ops::Range<usize>,
    row0: usize,
) {
    let (k0, k1) = (cols.start, cols.end);
    for r in 0..c.len() / k {
        let i = row0 + r;
        let crow = &mut c[r * k + k0..r * k + k1];
        crow.fill(0.0);
        let (s, e) = (a.row_ptr[i] as usize, a.row_ptr[i + 1] as usize);
        for p in s..e {
            let col = a.cols[p] as usize;
            axpy_k4(crow, &b[col * k + k0..col * k + k1], a.vals[p]);
        }
    }
}

/// BCSR SpMM over one B/C column panel for block rows `brow0..brow1`
/// (`c` holds rows `brow0 * br ..`, full row stride `k`).
pub fn bcsr_panel(
    a: &Bcsr,
    b: &[f64],
    k: usize,
    c: &mut [f64],
    cols: std::ops::Range<usize>,
    brow0: usize,
    brow1: usize,
) {
    let (k0, k1) = (cols.start, cols.end);
    for r in 0..c.len() / k {
        c[r * k + k0..r * k + k1].fill(0.0);
    }
    let (br, bc) = (a.br, a.bc);
    let row0 = brow0 * br;
    for bi in brow0..brow1 {
        let (s, e) = (a.block_row_ptr[bi] as usize, a.block_row_ptr[bi + 1] as usize);
        let i0 = bi * br;
        let rmax = br.min(a.nrows - i0);
        for blk in s..e {
            let j0 = a.block_cols[blk] as usize * bc;
            let cmax = bc.min(a.ncols - j0);
            let payload = &a.blocks[blk * br * bc..(blk + 1) * br * bc];
            for r in 0..rmax {
                let co = (i0 + r - row0) * k;
                let crow = &mut c[co + k0..co + k1];
                for cc in 0..cmax {
                    let v = payload[r * bc + cc];
                    if v == 0.0 {
                        continue; // block fill-in
                    }
                    axpy_k4(crow, &b[(j0 + cc) * k + k0..(j0 + cc) * k + k1], v);
                }
            }
        }
    }
}

/// Hybrid ELL+COO.
pub fn hybrid(a: &HybridEllCoo, b: &[f64], k: usize, c: &mut [f64]) {
    ell_rowwise(&a.ell, b, k, c);
    for i in 0..a.tail.vals.len() {
        let (r, col, v) = (a.tail.rows[i] as usize, a.tail.cols[i] as usize, a.tail.vals[i]);
        let brow = &b[col * k..col * k + k];
        let crow = &mut c[r * k..r * k + k];
        crow.iter_mut().zip(brow).for_each(|(cj, &bj)| *cj += v * bj);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::gen;
    use crate::util::prop::assert_close;

    fn check_all(m: &crate::matrix::TriMat, k: usize) {
        let b: Vec<f64> = (0..m.ncols * k).map(|i| ((i * 7 % 23) as f64 - 11.0) * 0.1).collect();
        let want = m.spmm_ref(&b, k);
        let mut c = vec![0.0; m.nrows * k];
        let tol = 1e-10;

        coo_aos(&CooAos::from_tuples(m, CooOrder::RowMajor), &b, k, &mut c);
        assert_close(&c, &want, tol).unwrap();
        coo_soa(&CooSoa::from_tuples(m, CooOrder::Unsorted), &b, k, &mut c);
        assert_close(&c, &want, tol).unwrap();
        csr(&Csr::from_tuples(m), &b, k, &mut c);
        assert_close(&c, &want, tol).unwrap();
        csr_aos(&CsrAos::from_tuples(m), &b, k, &mut c);
        assert_close(&c, &want, tol).unwrap();
        csc(&Csc::from_tuples(m), &b, k, &mut c);
        assert_close(&c, &want, tol).unwrap();
        csc_aos(&CscAos::from_tuples(m), &b, k, &mut c);
        assert_close(&c, &want, tol).unwrap();
        for order in [EllOrder::RowMajor, EllOrder::ColMajor] {
            let e = Ell::from_tuples(m, order);
            ell_rowwise(&e, &b, k, &mut c);
            assert_close(&c, &want, tol).unwrap();
            ell_planewise(&e, &b, k, &mut c);
            assert_close(&c, &want, tol).unwrap();
        }
        let j = Jds::from_tuples(m, true);
        let jr = JdsRows::build(&j, m);
        jds(&j, &jr, &b, k, &mut c);
        assert_close(&c, &want, tol).unwrap();
        bcsr(&Bcsr::from_tuples(m, 2, 2), &b, k, &mut c);
        assert_close(&c, &want, tol).unwrap();
        hybrid(&HybridEllCoo::from_tuples(m, None, EllOrder::RowMajor), &b, k, &mut c);
        assert_close(&c, &want, tol).unwrap();
    }

    #[test]
    fn spmm_matches_oracle_small_k() {
        check_all(&gen::uniform_random(23, 29, 150, 34), 3);
    }

    #[test]
    fn spmm_matches_oracle_k8() {
        check_all(&gen::powerlaw(30, 2.0, 16, 35), 8);
    }

    #[test]
    fn spmm_k1_equals_spmv() {
        let m = gen::banded(25, 3, 0.7, 36);
        let x: Vec<f64> = (0..m.ncols).map(|i| i as f64 * 0.1 - 1.0).collect();
        let mut c = vec![0.0; m.nrows];
        csr(&Csr::from_tuples(&m), &x, 1, &mut c);
        let want = m.spmv_ref(&x);
        assert_close(&c, &want, 1e-12).unwrap();
    }

    #[test]
    fn panel_sweep_equals_full_spmm() {
        let m = gen::uniform_random(19, 23, 130, 38);
        let k = 10;
        let b: Vec<f64> = (0..m.ncols * k).map(|i| ((i * 5 % 19) as f64 - 9.0) * 0.2).collect();
        let want = m.spmm_ref(&b, k);
        let a = Csr::from_tuples(&m);
        for panel in [1, 3, 4, 7, 10, 64] {
            let mut c = vec![f64::NAN; m.nrows * k]; // panels must overwrite every cell
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + panel).min(k);
                csr_panel(&a, &b, k, &mut c, k0..k1, 0);
                k0 = k1;
            }
            assert_close(&c, &want, 1e-10).unwrap_or_else(|e| panic!("panel={panel}: {e}"));
        }
        let bl = Bcsr::from_tuples(&m, 2, 3);
        for panel in [2, 5, 10] {
            let mut c = vec![f64::NAN; m.nrows * k];
            let mut k0 = 0;
            while k0 < k {
                let k1 = (k0 + panel).min(k);
                bcsr_panel(&bl, &b, k, &mut c, k0..k1, 0, bl.nblock_rows);
                k0 = k1;
            }
            assert_close(&c, &want, 1e-10).unwrap_or_else(|e| panic!("bcsr panel={panel}: {e}"));
        }
    }

    /// The batching bit-identity contract, at the kernel layer: the
    /// row-dot panel produces (a) exactly the bits of `spmm::csr`, and
    /// (b) per column `j`, exactly the bits of `spmv::csr` on
    /// `x = B[:, j]`. `==` on the raw f64s, not a tolerance.
    #[test]
    fn csr_rowdot_bitwise_matches_spmm_and_per_column_spmv() {
        for (m, k) in [
            (gen::uniform_random(23, 29, 150, 34), 3),
            (gen::powerlaw(30, 2.0, 16, 35), 8),
            (gen::banded(25, 3, 0.7, 36), 1),
        ] {
            let a = Csr::from_tuples(&m);
            let b: Vec<f64> =
                (0..m.ncols * k).map(|i| ((i * 7 % 23) as f64 - 11.0) * 0.1).collect();
            let mut c_dot = vec![f64::NAN; m.nrows * k];
            csr_rowdot_k(&a, &b, k, &mut c_dot);
            let mut c_axpy = vec![f64::NAN; m.nrows * k];
            csr(&a, &b, k, &mut c_axpy);
            assert_eq!(c_dot, c_axpy, "rowdot vs axpy spmm bits, k={k}");
            for j in 0..k {
                let x: Vec<f64> = (0..m.ncols).map(|col| b[col * k + j]).collect();
                let mut y = vec![f64::NAN; m.nrows];
                crate::kernels::spmv::csr(&a, &x, &mut y);
                let col: Vec<f64> = (0..m.nrows).map(|i| c_dot[i * k + j]).collect();
                assert_eq!(col, y, "panel column {j} vs solo SpMV bits");
            }
        }
    }

    #[test]
    fn ell_planewise_skips_padding_correctly() {
        // A matrix whose genuine values include rows shorter than K —
        // padding slots must not contribute even when x has garbage at 0.
        let m = gen::powerlaw(20, 2.0, 10, 37);
        check_all(&m, 4);
    }
}
